"""Pure-jnp oracles for the Pallas kernels.

These are the correctness reference for `python/tests/test_kernels.py`
(hypothesis sweeps shapes against these) and the semantic contract the
Rust native executor implements for the same par_loop names.

All functions operate on *padded* arrays ([ny_pad, nx_pad], row-major,
x fastest — matching the Rust `Dataset` layout) and return full padded
arrays whose edge values are unspecified (the Rust PJRT executor writes
back only the requested interior sub-range).
"""

import jax.numpy as jnp

G_SMALL = 1.0e-16


def laplacian2d(u, kappa):
    """5-point conductivity-weighted Laplacian (the `diff_lap` kernel).

    out[j, i] = kappa[j, i] * (u[j-1,i] + u[j+1,i] + u[j,i-1] + u[j,i+1]
                               - 4 u[j,i])   on the interior; edges zero.
    """
    out = jnp.zeros_like(u)
    lap = (
        u[:-2, 1:-1]
        + u[2:, 1:-1]
        + u[1:-1, :-2]
        + u[1:-1, 2:]
        - 4.0 * u[1:-1, 1:-1]
    )
    return out.at[1:-1, 1:-1].set(kappa[1:-1, 1:-1] * lap)


def axpy_update(u, lap, alpha):
    """Explicit Euler update (the `diff_update` kernel): u + alpha*lap."""
    return u + alpha * lap


def ideal_gas(density, energy, gamma=1.4):
    """CloverLeaf's EOS (the `cl2d_ideal_gas` kernel): returns
    (pressure, soundspeed), matching the Rust kernel bit-for-bit in
    exact arithmetic:

        p   = (γ-1) ρ e
        ss  = sqrt(v² (p·pe - pv)),  v = 1/ρ, pe = (γ-1)ρ, pv = -ρ p v
    """
    d = jnp.maximum(density, G_SMALL)
    v = 1.0 / d
    p = (gamma - 1.0) * d * energy
    pe = (gamma - 1.0) * d
    pv = -d * p * v
    ss2 = v * v * (p * pe - pv)
    return p, jnp.sqrt(jnp.maximum(ss2, G_SMALL))


def laplacian3d(u):
    """7-point Laplacian oracle; halo planes zero."""
    out = jnp.zeros_like(u)
    lap = (
        u[:-2, 1:-1, 1:-1]
        + u[2:, 1:-1, 1:-1]
        + u[1:-1, :-2, 1:-1]
        + u[1:-1, 2:, 1:-1]
        + u[1:-1, 1:-1, :-2]
        + u[1:-1, 1:-1, 2:]
        - 6.0 * u[1:-1, 1:-1, 1:-1]
    )
    return out.at[1:-1, 1:-1, 1:-1].set(lap)


def deriv4_z(u, h):
    """4th-order central d/dz oracle; two halo planes zero at each end."""
    out = jnp.zeros_like(u)
    d = (8.0 * (u[3:-1] - u[1:-3]) - (u[4:] - u[:-4])) / (12.0 * h)
    return out.at[2:-2].set(d)
