"""L1 Pallas kernels, 3D: the 7-point Laplacian and the 4th-order
central first derivative used by the OpenSBLI-style RHS.

Same conventions as stencil2d: interpret=True (CPU image), z-slab
streaming via dynamic slices plays the HBM↔VMEM schedule role, padded
arrays [nz_pad, ny_pad, nx_pad] row-major x-fastest.

VMEM accounting (per program instance, f64):
    laplacian3d: (TILE_Z+2 + TILE_Z) * ny_pad * nx_pad * 8 B
                 → TILE_Z=4, 130×130 planes: ~4.9 MiB (< 16 MiB VMEM)
    deriv4_z:    (TILE_Z+4 + TILE_Z) * plane ≈ same order
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_Z = 4


def _pick_tile(interior, want):
    return next(t for t in range(min(want, interior), 0, -1) if interior % t == 0)


def _lap3d_kernel(u_ref, o_ref, *, tile_z):
    pid = jnp.int64(pl.program_id(0))
    z0 = pid * tile_z
    u = pl.load(u_ref, (pl.dslice(z0, tile_z + 2), slice(None), slice(None)))
    mid = u[1:-1, 1:-1, 1:-1]
    lap = (
        u[:-2, 1:-1, 1:-1]
        + u[2:, 1:-1, 1:-1]
        + u[1:-1, :-2, 1:-1]
        + u[1:-1, 2:, 1:-1]
        + u[1:-1, 1:-1, :-2]
        + u[1:-1, 1:-1, 2:]
        - 6.0 * mid
    )
    out = jnp.zeros(u[1:-1].shape, u.dtype)
    out = out.at[:, 1:-1, 1:-1].set(lap)
    pl.store(o_ref, (pl.dslice(z0 + 1, tile_z), slice(None), slice(None)), out)


def laplacian3d(u, *, tile_z=None):
    """7-point Laplacian over a padded [nz, ny, nx] array; halo planes of
    the output are zero."""
    nz, ny, nx = u.shape
    interior = nz - 2
    if tile_z is None:
        tile_z = _pick_tile(interior, TILE_Z)
    assert interior % tile_z == 0, (nz, tile_z)
    out = pl.pallas_call(
        functools.partial(_lap3d_kernel, tile_z=tile_z),
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), u.dtype),
        grid=(interior // tile_z,),
        interpret=True,
    )(u)
    zero = jnp.zeros((1, ny, nx), out.dtype)
    return out.at[0:1].set(zero).at[nz - 1 : nz].set(zero)


def _deriv4_z_kernel(u_ref, o_ref, *, tile_z, inv12h):
    pid = jnp.int64(pl.program_id(0))
    z0 = pid * tile_z
    u = pl.load(u_ref, (pl.dslice(z0, tile_z + 4), slice(None), slice(None)))
    d = (8.0 * (u[3:-1] - u[1:-3]) - (u[4:] - u[:-4])) * inv12h
    pl.store(o_ref, (pl.dslice(z0 + 2, tile_z), slice(None), slice(None)), d)


def deriv4_z(u, h, *, tile_z=None):
    """4th-order central ∂/∂z over a padded (depth ≥ 2) array; the two
    halo planes at each end of the output are zero."""
    nz, ny, nx = u.shape
    interior = nz - 4
    if tile_z is None:
        tile_z = _pick_tile(interior, TILE_Z)
    assert interior % tile_z == 0, (nz, tile_z)
    out = pl.pallas_call(
        functools.partial(_deriv4_z_kernel, tile_z=tile_z, inv12h=1.0 / (12.0 * h)),
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), u.dtype),
        grid=(interior // tile_z,),
        interpret=True,
    )(u)
    zero = jnp.zeros((2, ny, nx), out.dtype)
    return out.at[0:2].set(zero).at[nz - 2 : nz].set(zero)
