"""L1 Pallas kernels: the stencil hot-spots.

All kernels run with ``interpret=True`` — on this CPU image, real-TPU
lowering would emit a Mosaic custom-call the CPU PJRT plugin cannot
execute. The *structure* is still written for TPU:

* pointwise kernels (EOS, axpy) tile with ``BlockSpec`` so each program
  instance works on a VMEM-resident block (8×128-aligned when possible);
* the Laplacian streams row-tiles through the kernel with dynamic slices
  (`pl.dslice`) because its ±1 halo makes non-overlapping BlockSpec
  windows insufficient — the row-tile is the HBM↔VMEM schedule that the
  paper's CUDA version expressed with thread blocks
  (DESIGN.md §Hardware-Adaptation).

VMEM accounting (per program instance, f64):
    laplacian2d: (TILE_ROWS+2 + TILE_ROWS*2) * nx_pad * 8 B
                 → TILE_ROWS=32, nx_pad≤1026: ~0.8 MiB  (« 16 MiB VMEM)
    eos/axpy:    3–4 blocks of 32×256 → ≤ 0.3 MiB
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height for the Laplacian streaming kernel.
TILE_ROWS = 32
# Block shape for the pointwise kernels.
BLOCK = (32, 256)


def _lap_kernel(u_ref, k_ref, o_ref, *, tile_rows, ny_pad):
    """One program instance computes `tile_rows` interior rows."""
    # program_id is int32; slice starts must match the x64 index type.
    pid = jnp.int64(pl.program_id(0))
    row0 = pid * tile_rows  # first *interior* row of this tile (0-based
    # within the interior, so padded row index row0+1)

    # Load tile_rows+2 rows (the tile plus its ±1 halo rows).
    u = pl.load(u_ref, (pl.dslice(row0, tile_rows + 2), slice(None)))
    k = pl.load(k_ref, (pl.dslice(row0 + 1, tile_rows), slice(None)))

    up = u[:-2, 1:-1]
    down = u[2:, 1:-1]
    left = u[1:-1, :-2]
    right = u[1:-1, 2:]
    mid = u[1:-1, 1:-1]
    lap = k[:, 1:-1] * (up + down + left + right - 4.0 * mid)

    # Store interior columns of the tile's rows; halo columns stay 0.
    out = jnp.zeros_like(k)
    out = out.at[:, 1:-1].set(lap)
    pl.store(o_ref, (pl.dslice(row0 + 1, tile_rows), slice(None)), out)
    del ny_pad


def laplacian2d(u, kappa, *, tile_rows=None):
    """Pallas 5-point weighted Laplacian over a padded [ny_pad, nx_pad]
    array. `tile_rows` must divide the interior height; when omitted, the
    largest divisor ≤ TILE_ROWS is chosen automatically.
    """
    ny_pad, nx_pad = u.shape
    interior = ny_pad - 2
    if tile_rows is None:
        tile_rows = next(
            t for t in range(min(TILE_ROWS, interior), 0, -1) if interior % t == 0
        )
    assert interior % tile_rows == 0, (ny_pad, tile_rows)
    grid = (interior // tile_rows,)
    out = pl.pallas_call(
        functools.partial(_lap_kernel, tile_rows=tile_rows, ny_pad=ny_pad),
        out_shape=jax.ShapeDtypeStruct((ny_pad, nx_pad), u.dtype),
        grid=grid,
        interpret=True,
    )(u, kappa)
    # The kernel stores interior rows only; the halo rows of the output
    # are uninitialised — pin them to the contract's zeros.
    zero = jnp.zeros((1, nx_pad), out.dtype)
    return out.at[0:1, :].set(zero).at[ny_pad - 1 : ny_pad, :].set(zero)


def _axpy_kernel(u_ref, l_ref, o_ref, *, alpha):
    o_ref[...] = u_ref[...] + alpha * l_ref[...]


def axpy_update(u, lap, alpha):
    """Pointwise explicit-Euler update, BlockSpec-tiled."""
    ny, nx = u.shape
    by = min(BLOCK[0], ny)
    bx = min(BLOCK[1], nx)
    # fall back to one block when the shape doesn't divide evenly
    if ny % by or nx % bx:
        by, bx = ny, nx
    grid = (ny // by, nx // bx)
    spec = pl.BlockSpec((by, bx), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_axpy_kernel, alpha=alpha),
        out_shape=jax.ShapeDtypeStruct((ny, nx), u.dtype),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        interpret=True,
    )(u, lap)


def _eos_kernel(d_ref, e_ref, p_ref, ss_ref, *, gamma):
    d = jnp.maximum(d_ref[...], 1.0e-16)
    e = e_ref[...]
    v = 1.0 / d
    p = (gamma - 1.0) * d * e
    pe = (gamma - 1.0) * d
    pv = -d * p * v
    ss2 = v * v * (p * pe - pv)
    p_ref[...] = p
    ss_ref[...] = jnp.sqrt(jnp.maximum(ss2, 1.0e-16))


def ideal_gas(density, energy, gamma=1.4):
    """CloverLeaf EOS as a BlockSpec-tiled pointwise Pallas kernel."""
    ny, nx = density.shape
    by = min(BLOCK[0], ny)
    bx = min(BLOCK[1], nx)
    if ny % by or nx % bx:
        by, bx = ny, nx
    grid = (ny // by, nx // bx)
    spec = pl.BlockSpec((by, bx), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_eos_kernel, gamma=gamma),
        out_shape=[
            jax.ShapeDtypeStruct((ny, nx), density.dtype),
            jax.ShapeDtypeStruct((ny, nx), density.dtype),
        ],
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        interpret=True,
    )(density, energy)
