"""L2: JAX compute graphs composing the L1 Pallas kernels.

Each function is the full-sweep semantic of one Rust par_loop (see the
PJRT-executor contract in rust/src/exec/pjrt.rs: compute everywhere, the
executor writes back only the tile's sub-range), plus a fused multi-loop
chain used for HLO fusion analysis in the perf pass.

Everything is f64 (jax_enable_x64) to match the Rust native executor.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels import stencil2d  # noqa: E402

ALPHA = 0.1


def diff_lap(u, kappa):
    """`diff_lap` par_loop: conductivity-weighted 5-point Laplacian."""
    return (stencil2d.laplacian2d(u, kappa),)


def diff_update(u, lap):
    """`diff_update` par_loop: u += alpha * lap."""
    return (stencil2d.axpy_update(u, lap, ALPHA),)


def cl2d_ideal_gas(density, energy):
    """`cl2d_ideal_gas` par_loop: EOS -> (pressure, soundspeed)."""
    p, ss = stencil2d.ideal_gas(density, energy)
    return (p, ss)


def diff_chain(u, kappa, steps: int):
    """A fused diffusion chain (L2-level loop fusion study): `steps`
    timesteps of lap+update in one XLA program. Used by the perf pass to
    compare per-loop dispatch against whole-chain fusion, mirroring what
    tiling buys the paper at the memory level.
    """

    def body(u, _):
        lap = stencil2d.laplacian2d(u, kappa)
        return stencil2d.axpy_update(u, lap, ALPHA), None

    out, _ = jax.lax.scan(body, u, None, length=steps)
    return (out,)
