"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and data; assert_allclose against ref.py is THE
kernel-correctness signal of the build (the Rust side then checks the
PJRT artifacts against its native executor).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, stencil2d

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rng_array(shape, seed, lo=-10.0, hi=10.0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.uniform(lo, hi, size=shape))


# ----------------------------------------------------------------- laplacian


@given(
    ny=st.integers(min_value=3, max_value=40),
    nx=st.integers(min_value=3, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_laplacian_matches_ref(ny, nx, seed):
    u = rng_array((ny, nx), seed)
    k = rng_array((ny, nx), seed + 1, lo=0.1, hi=2.0)
    got = stencil2d.laplacian2d(u, k, tile_rows=1)
    want = ref.laplacian2d(u, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-13, atol=1e-13)


@pytest.mark.parametrize("tile_rows", [1, 2, 4, 8, 16])
def test_laplacian_tile_size_invariance(tile_rows):
    ny = 2 + 16  # interior 16 divides all tile sizes
    u = rng_array((ny, 21), 7)
    k = rng_array((ny, 21), 8, lo=0.5, hi=1.5)
    got = stencil2d.laplacian2d(u, k, tile_rows=tile_rows)
    want = ref.laplacian2d(u, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-13)


def test_laplacian_of_linear_field_is_zero():
    ny, nx = 18, 12
    y, x = jnp.mgrid[0:ny, 0:nx]
    u = 3.0 * x + 2.0 * y  # harmonic
    k = jnp.ones((ny, nx))
    got = stencil2d.laplacian2d(u.astype(jnp.float64), k, tile_rows=16)
    np.testing.assert_allclose(np.asarray(got[1:-1, 1:-1]), 0.0, atol=1e-11)


def test_laplacian_edges_are_zero():
    u = rng_array((10, 10), 3)
    k = rng_array((10, 10), 4)
    got = np.asarray(stencil2d.laplacian2d(u, k, tile_rows=8))
    assert (got[0, :] == 0).all() and (got[-1, :] == 0).all()
    assert (got[:, 0] == 0).all() and (got[:, -1] == 0).all()


# ----------------------------------------------------------------- axpy


@given(
    ny=st.integers(min_value=1, max_value=48),
    nx=st.integers(min_value=1, max_value=48),
    alpha=st.floats(min_value=-2.0, max_value=2.0, allow_nan=False),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_axpy_matches_ref(ny, nx, alpha, seed):
    u = rng_array((ny, nx), seed)
    lap = rng_array((ny, nx), seed + 1)
    got = stencil2d.axpy_update(u, lap, alpha)
    want = ref.axpy_update(u, lap, alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-13, atol=1e-15)


def test_axpy_blocked_path_used_for_aligned_shapes():
    # 64x512 divides the (32, 256) block exactly -> multi-block grid.
    u = rng_array((64, 512), 11)
    lap = rng_array((64, 512), 12)
    got = stencil2d.axpy_update(u, lap, 0.5)
    want = ref.axpy_update(u, lap, 0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-13, atol=1e-15)


# ----------------------------------------------------------------- ideal gas


@given(
    ny=st.integers(min_value=1, max_value=40),
    nx=st.integers(min_value=1, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ideal_gas_matches_ref(ny, nx, seed):
    d = rng_array((ny, nx), seed, lo=0.1, hi=5.0)
    e = rng_array((ny, nx), seed + 1, lo=0.1, hi=5.0)
    p_got, ss_got = stencil2d.ideal_gas(d, e)
    p_want, ss_want = ref.ideal_gas(d, e)
    np.testing.assert_allclose(np.asarray(p_got), np.asarray(p_want), rtol=1e-13)
    np.testing.assert_allclose(np.asarray(ss_got), np.asarray(ss_want), rtol=1e-13)


def test_ideal_gas_physical_sanity():
    d = jnp.full((8, 8), 1.0)
    e = jnp.full((8, 8), 2.5)
    p, ss = stencil2d.ideal_gas(d, e)
    # p = 0.4 * 1.0 * 2.5 = 1.0; ss = sqrt(v^2(p*pe - pv)) = sqrt(1.4*p/rho)
    np.testing.assert_allclose(np.asarray(p), 1.0, rtol=1e-14)
    np.testing.assert_allclose(np.asarray(ss), np.sqrt(1.4), rtol=1e-14)


def test_ideal_gas_clamps_vacuum():
    d = jnp.zeros((4, 4))
    e = jnp.ones((4, 4))
    p, ss = stencil2d.ideal_gas(d, e)
    assert np.isfinite(np.asarray(p)).all()
    assert np.isfinite(np.asarray(ss)).all()
