"""L1 correctness, 3D kernels: Pallas vs jnp oracle (hypothesis sweeps)."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, stencil3d

settings.register_profile("ci3d", max_examples=15, deadline=None)
settings.load_profile("ci3d")


def rng(shape, seed):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.uniform(-3.0, 3.0, size=shape))


@given(
    nz=st.integers(min_value=3, max_value=14),
    ny=st.integers(min_value=3, max_value=12),
    nx=st.integers(min_value=3, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_laplacian3d_matches_ref(nz, ny, nx, seed):
    u = rng((nz, ny, nx), seed)
    got = stencil3d.laplacian3d(u, tile_z=1)
    want = ref.laplacian3d(u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-13, atol=1e-13)


@pytest.mark.parametrize("tile_z", [1, 2, 4, 8])
def test_laplacian3d_tile_invariance(tile_z):
    u = rng((2 + 8, 9, 7), 5)
    got = stencil3d.laplacian3d(u, tile_z=tile_z)
    want = ref.laplacian3d(u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-13)


def test_laplacian3d_of_linear_field_is_zero():
    z, y, x = jnp.mgrid[0:10, 0:8, 0:6]
    u = (1.0 * x + 2.0 * y + 3.0 * z).astype(jnp.float64)
    got = stencil3d.laplacian3d(u)
    np.testing.assert_allclose(np.asarray(got[1:-1, 1:-1, 1:-1]), 0.0, atol=1e-11)


@given(
    nz=st.integers(min_value=5, max_value=16),
    ny=st.integers(min_value=2, max_value=10),
    nx=st.integers(min_value=2, max_value=10),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_deriv4_matches_ref(nz, ny, nx, seed):
    u = rng((nz, ny, nx), seed)
    got = stencil3d.deriv4_z(u, 0.37, tile_z=1)
    want = ref.deriv4_z(u, 0.37)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


def test_deriv4_exact_on_cubics():
    # 4th-order central differences are exact for polynomials up to deg 4.
    h = 0.25
    z = (jnp.arange(20) * h)[:, None, None] * jnp.ones((1, 4, 4))
    u = z**3 - 2.0 * z
    got = stencil3d.deriv4_z(u.astype(jnp.float64), h, tile_z=16)
    want = 3.0 * z**2 - 2.0
    np.testing.assert_allclose(
        np.asarray(got[2:-2]), np.asarray(want[2:-2]), rtol=1e-11
    )
