"""L2 correctness: the model-level compositions and the AOT path."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref


def fields(ny=18, nx=14, seed=0):
    r = np.random.default_rng(seed)
    u = jnp.asarray(r.uniform(-1, 1, size=(ny, nx)))
    k = jnp.asarray(r.uniform(0.5, 1.5, size=(ny, nx)))
    return u, k


def test_diff_chain_equals_manual_steps():
    u, k = fields()
    (chained,) = model.diff_chain(u, k, 4)
    manual = u
    for _ in range(4):
        lap = ref.laplacian2d(manual, k)
        manual = ref.axpy_update(manual, lap, model.ALPHA)
    np.testing.assert_allclose(np.asarray(chained), np.asarray(manual), rtol=1e-12)


def test_diff_lap_shapes_and_dtype():
    u, k = fields()
    (lap,) = model.diff_lap(u, k)
    assert lap.shape == u.shape
    assert lap.dtype == jnp.float64


def test_hlo_text_lowering_roundtrips():
    spec = jax.ShapeDtypeStruct((10, 10), jnp.float64)
    lowered = jax.jit(model.diff_lap).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f64" in text, "artifacts must be double precision"


def test_ideal_gas_model_tuple():
    u, k = fields(seed=3)
    d = jnp.abs(u) + 0.5
    p, ss = model.cl2d_ideal_gas(d, k + 1.0)
    assert p.shape == d.shape and ss.shape == d.shape
    assert (np.asarray(ss) > 0).all()
