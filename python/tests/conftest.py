"""Make the `compile` package importable when pytest is invoked from the
repo root (the tests import `compile.kernels` etc. relative to
`python/`, which is not automatically on sys.path)."""

import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
