//! Property-based tests (in-tree xorshift PRNG — the vendored crate set
//! has no proptest): random loop chains over random datasets/stencils
//! must produce identical numerics under every engine's schedule, and
//! tile plans must satisfy their structural invariants.

use ops_oc::exec::{Engine, Metrics, NativeExecutor, World};
use ops_oc::memory::{AppCalib, GpuCalib, GpuExplicitEngine, GpuOpts, KnlCalib, KnlEngine, Link};
use ops_oc::ops::kernel::kernel;
use ops_oc::ops::stencil::shapes;
use ops_oc::ops::*;
use ops_oc::tiling::plan::{plan_auto, plan_chain};

/// Deterministic xorshift64* PRNG.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
    fn f64(&mut self) -> f64 {
        (self.next() >> 11) as f64 / (1u64 << 53) as f64
    }
}

const APP: AppCalib = AppCalib::CLOVERLEAF_2D;

struct Fixture {
    datasets: Vec<Dataset>,
    stencils: Vec<Stencil>,
    chain: Vec<LoopInst>,
}

/// Random fixture: `nds` datasets, a chain of `nloops` loops with random
/// source/dest datasets, random access modes, random (possibly partial)
/// ranges. Reads go through a radius-2 star so every kernel read is
/// covered by the declared stencil.
fn random_fixture(seed: u64, nds: u32, nloops: usize, ny: usize) -> Fixture {
    let mut rng = Rng::new(seed);
    let mut datasets = vec![];
    for i in 0..nds {
        datasets.push(Dataset {
            id: DatasetId(i),
            block: BlockId(0),
            name: format!("d{i}"),
            size: [24, ny, 1],
            halo_lo: [3, 3, 0],
            halo_hi: [3, 3, 0],
            elem_bytes: 8,
        });
    }
    let stencils = vec![
        Stencil {
            id: StencilId(0),
            name: "pt".into(),
            points: shapes::point(),
        },
        Stencil {
            id: StencilId(1),
            name: "star2".into(),
            points: shapes::star2d(2),
        },
    ];
    let mut chain = vec![];
    for li in 0..nloops {
        let src = DatasetId(rng.below(nds as u64) as u32);
        let mut dst = DatasetId(rng.below(nds as u64) as u32);
        while dst == src {
            dst = DatasetId(rng.below(nds as u64) as u32);
        }
        let acc = match rng.below(3) {
            1 => Access::ReadWrite,
            _ => Access::Write,
        };
        // random sub-range along y sometimes (boundary-strip loops)
        let (y0, y1) = if rng.below(4) == 0 {
            let a = rng.below(ny as u64 - 1) as isize;
            let len = 1 + rng.below((ny as isize - a) as u64) as isize;
            (a, (a + len).min(ny as isize))
        } else {
            (0, ny as isize)
        };
        let coef = 0.25 + 0.5 * rng.f64();
        chain.push(LoopInst {
            name: format!("loop{li}"),
            block: BlockId(0),
            range: [(0, 24), (y0, y1), (0, 1)],
            args: vec![
                Arg::dat(src, StencilId(1), Access::Read),
                Arg::dat(dst, StencilId(0), acc),
            ],
            kernel: kernel(move |c| {
                let v = c.r(0, 0, 0)
                    + 0.5 * (c.r(0, 1, 0) + c.r(0, -1, 0) + c.r(0, 0, 1) + c.r(0, 0, -1))
                    + 0.25 * (c.r(0, 0, 2) + c.r(0, 0, -2) + c.r(0, 2, 0) + c.r(0, -2, 0));
                let old = c.r(1, 0, 0);
                c.w(1, 0, 0, coef * v + 0.1 * old);
            }),
            kernel_ir: None,
            seq: li as u64,
            bw_efficiency: 1.0,
        });
    }
    Fixture {
        datasets,
        stencils,
        chain,
    }
}

fn init_store(f: &Fixture, seed: u64) -> DataStore {
    let mut store = DataStore::new();
    let mut rng = Rng::new(seed ^ 0xABCD);
    for d in &f.datasets {
        store.alloc(d);
        for v in store.buf_mut(d.id) {
            *v = rng.f64() * 2.0 - 1.0;
        }
    }
    store
}

fn run_engine(f: &Fixture, engine: &mut dyn Engine, seed: u64) -> Vec<Vec<f64>> {
    let mut store = init_store(f, seed);
    let mut reds: Vec<Reduction> = vec![];
    let mut metrics = Metrics::new();
    let mut exec = NativeExecutor::new();
    {
        let mut world = World {
            datasets: &f.datasets,
            stencils: &f.stencils,
            store: &mut store,
            reds: &mut reds,
            metrics: &mut metrics,
            exec: &mut exec,
        };
        engine.run_chain(&f.chain, &mut world, true);
    }
    f.datasets.iter().map(|d| store.buf(d.id).to_vec()).collect()
}

fn run_sequential(f: &Fixture, seed: u64) -> Vec<Vec<f64>> {
    let mut store = init_store(f, seed);
    let mut reds: Vec<Reduction> = vec![];
    let mut exec = NativeExecutor::new();
    for l in &f.chain {
        use ops_oc::exec::Executor;
        exec.run_loop(l, l.range, &f.datasets, &mut store, &mut reds);
    }
    f.datasets.iter().map(|d| store.buf(d.id).to_vec()).collect()
}

fn small_knl() -> KnlCalib {
    KnlCalib {
        mcdram_bytes: 64 << 10,
        cache_granule: 1 << 10,
        ..KnlCalib::default()
    }
}

fn small_gpu() -> GpuCalib {
    GpuCalib {
        hbm_bytes: 48 << 10,
        ..GpuCalib::default()
    }
}

#[test]
fn prop_random_chains_tile_identically_knl() {
    for seed in 1..=40u64 {
        let f = random_fixture(seed, 2 + (seed % 5) as u32, 3 + (seed % 12) as usize, 64);
        let want = run_sequential(&f, seed);
        let mut e = KnlEngine::new(small_knl(), APP, true);
        let got = run_engine(&f, &mut e, seed);
        assert_eq!(want, got, "KNL tiled mismatch for seed {seed}");
    }
}

#[test]
fn prop_random_chains_tile_identically_gpu() {
    for seed in 1..=40u64 {
        let f = random_fixture(
            seed.wrapping_mul(7919),
            2 + (seed % 4) as u32,
            3 + (seed % 10) as usize,
            96,
        );
        let want = run_sequential(&f, seed);
        let mut e =
            GpuExplicitEngine::new(small_gpu(), APP, Link::PciE, GpuOpts::default()).unwrap();
        let got = run_engine(&f, &mut e, seed);
        assert_eq!(want, got, "GPU explicit mismatch for seed {seed}");
    }
}

#[test]
fn prop_plans_partition_and_footprints_cover() {
    for seed in 1..=60u64 {
        let f = random_fixture(seed.wrapping_mul(31), 3, 4 + (seed % 8) as usize, 80);
        for nt in [2usize, 3, 7] {
            let plan = plan_chain(&f.chain, &f.datasets, &f.stencils, nt);
            // (1) per-loop ranges partition the loop's range
            for (li, l) in f.chain.iter().enumerate() {
                let mut cursor = l.range[plan.tile_dim].0;
                for tile in &plan.tiles {
                    if let Some(r) = &tile.loop_ranges[li] {
                        assert_eq!(r[plan.tile_dim].0, cursor, "gap/overlap seed {seed}");
                        cursor = r[plan.tile_dim].1;
                    }
                }
                assert_eq!(cursor, l.range[plan.tile_dim].1, "uncovered seed {seed}");
            }
            // (2) footprints cover every stencil-extended access
            for tile in &plan.tiles {
                for (li, r) in tile.loop_ranges.iter().enumerate() {
                    let Some(r) = r else { continue };
                    for (dat, st, _) in f.chain[li].dat_args() {
                        let s = &f.stencils[st.0 as usize];
                        let lo = r[plan.tile_dim].0 + s.min_extent()[plan.tile_dim] as isize;
                        let hi = r[plan.tile_dim].1 + s.max_extent()[plan.tile_dim] as isize;
                        let ds = &f.datasets[dat.0 as usize];
                        let dlo = -(ds.halo_lo[plan.tile_dim] as isize);
                        let dhi =
                            ds.size[plan.tile_dim] as isize + ds.halo_hi[plan.tile_dim] as isize;
                        let fp = tile.footprints[dat.0 as usize]
                            .as_ref()
                            .expect("touched dataset must have footprint");
                        assert!(
                            fp.full.lo <= lo.max(dlo) && fp.full.hi >= hi.min(dhi),
                            "footprint misses access: seed {seed}"
                        );
                    }
                }
            }
            // (3) the final loop is never shifted
            assert_eq!(*plan.shifts.last().unwrap(), 0, "seed {seed}");
        }
    }
}

#[test]
fn prop_auto_plan_respects_budget() {
    for seed in 100..=130u64 {
        let f = random_fixture(seed, 4, 6, 128);
        let total = ops_oc::tiling::plan::chain_bytes(&f.chain, &f.datasets);
        for denom in [2u64, 5, 11] {
            let target = (total / denom).max(1);
            match plan_auto(&f.chain, &f.datasets, &f.stencils, target) {
                // success now *guarantees* the footprint fits the target
                Ok(plan) => {
                    let fp = plan.max_footprint_bytes(&f.datasets);
                    assert!(
                        fp <= target,
                        "seed {seed} denom {denom}: footprint {fp} > target {target} \
                         with {} tiles",
                        plan.num_tiles()
                    );
                }
                // failure is typed and only legal when even single-plane
                // tiles (the practical floor for skewed slabs) overflow
                Err(e) => {
                    let floor = ops_oc::tiling::plan::plan_chain(
                        &f.chain,
                        &f.datasets,
                        &f.stencils,
                        usize::MAX,
                    );
                    assert!(
                        floor.max_footprint_bytes(&f.datasets) > target,
                        "seed {seed} denom {denom}: error {e} but the floor plan fits"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Temporal-fusion shift properties (`tiling::dependency::compute_fused_shifts`)

use ops_oc::tiling::analysis::fuse_chain;
use ops_oc::tiling::dependency::{compute_fused_shifts, compute_shifts, dep_radius};

/// Largest single-pair dependency radius in the chain (periodic copies
/// are structurally identical, so this also bounds cross-copy pairs).
fn max_radius(f: &Fixture, tile_dim: usize) -> isize {
    let mut r = 0isize;
    for a in &f.chain {
        for b in &f.chain {
            if let Some(d) = dep_radius(a, b, &f.stencils, tile_dim) {
                r = r.max(d.abs());
            }
        }
    }
    r
}

fn max_abs(shifts: &[isize]) -> isize {
    shifts.iter().map(|s| s.abs()).max().unwrap_or(0)
}

/// Fused shifts are *defined* as the shifts of the concatenated chain,
/// and they grow linearly, not quadratically: shifts depend only on
/// later loops, so the last `k-1` copies of a `k`-fused chain see
/// exactly the `(k-1)`-fused problem (suffix stability), and each
/// additional leading copy adds at most one period's worth of radii.
#[test]
fn prop_fused_shifts_grow_linearly_and_match_concatenation() {
    for seed in 1..=25u64 {
        let f = random_fixture(seed.wrapping_mul(101), 3, 3 + (seed % 6) as usize, 64);
        let n = f.chain.len();
        let rmax = max_radius(&f, 1);
        let mut prev = compute_fused_shifts(&f.chain, &f.stencils, 1, 1);
        assert_eq!(prev, compute_shifts(&f.chain, &f.stencils, 1), "seed {seed}");
        for k in 2..=8usize {
            let shifts = compute_fused_shifts(&f.chain, &f.stencils, 1, k);
            assert_eq!(shifts.len(), n * k, "seed {seed} k={k}");
            // definitionally the concatenated chain's shifts
            assert_eq!(
                shifts,
                compute_shifts(&fuse_chain(&f.chain, k), &f.stencils, 1),
                "seed {seed} k={k}: fused shifts must equal concatenation"
            );
            // suffix stability: the trailing k-1 copies are untouched
            assert_eq!(
                shifts[n..],
                prev[..],
                "seed {seed} k={k}: deeper fusion must not move later copies"
            );
            // linear growth: one leading copy adds <= one period of radii
            assert!(
                max_abs(&shifts) <= max_abs(&prev) + n as isize * rmax,
                "seed {seed} k={k}: super-linear shift growth ({} > {} + {n}*{rmax})",
                max_abs(&shifts),
                max_abs(&prev)
            );
            prev = shifts;
        }
        // no overflow at depths far past any tuner grid
        let deep = compute_fused_shifts(&f.chain, &f.stencils, 1, 64);
        assert!(
            max_abs(&deep) <= 64 * n as isize * rmax.max(1),
            "seed {seed}: deep fusion shifts exceed the linear bound"
        );
    }
}

/// Loops with no cross-loop dependencies (disjoint datasets, point
/// stencils) must stay unshifted at every fusion depth: fusion skews
/// only what dependencies force.
#[test]
fn prop_independent_loops_stay_unshifted_at_any_depth() {
    let mut f = random_fixture(7, 6, 1, 64);
    // rebuild the chain as: loop i reads dataset 2i (point), writes
    // dataset 2i+1 (point) — no dataset shared across loops, radius 0
    f.chain = (0..3u32)
        .map(|i| LoopInst {
            name: format!("ind{i}"),
            block: BlockId(0),
            range: [(0, 24), (0, 64), (0, 1)],
            args: vec![
                Arg::dat(DatasetId(2 * i), StencilId(0), Access::Read),
                Arg::dat(DatasetId(2 * i + 1), StencilId(0), Access::Write),
            ],
            kernel: kernel(|c| {
                let v = c.r(0, 0, 0);
                c.w(1, 0, 0, v * 0.5);
            }),
            kernel_ir: None,
            seq: i as u64,
            bw_efficiency: 1.0,
        })
        .collect();
    for k in [1usize, 2, 4, 16, 64] {
        let shifts = compute_fused_shifts(&f.chain, &f.stencils, 1, k);
        assert!(
            shifts.iter().all(|&s| s == 0),
            "independent loops picked up a shift at k={k}: {shifts:?}"
        );
    }
}

/// Deep fusion where the cumulative skew exceeds the engines' tile
/// width: numerics must stay bit-exact against sequential execution of
/// the same super-chain (tiny MCDRAM/HBM targets force multi-plane
/// tiles far narrower than the k-deep skew halo).
#[test]
fn prop_deep_fused_chains_stay_bitexact_past_tile_width() {
    for seed in [3u64, 9, 17] {
        let mut f = random_fixture(seed.wrapping_mul(977), 3, 4, 96);
        f.chain = fuse_chain(&f.chain, 8);
        let want = run_sequential(&f, seed);
        let mut knl = KnlEngine::new(small_knl(), APP, true);
        assert_eq!(
            want,
            run_engine(&f, &mut knl, seed),
            "KNL deep-fused mismatch for seed {seed}"
        );
        let mut gpu =
            GpuExplicitEngine::new(small_gpu(), APP, Link::PciE, GpuOpts::default()).unwrap();
        assert_eq!(
            want,
            run_engine(&f, &mut gpu, seed),
            "GPU deep-fused mismatch for seed {seed}"
        );
    }
}

#[test]
fn prop_plan_source_auto_never_panics_on_degenerate_targets() {
    for seed in 200..=220u64 {
        let f = random_fixture(seed, 3, 5, 96);
        for target in [0u64, 1, 64, u64::MAX] {
            let plan = ops_oc::tiling::plan::PlanSource::Auto.plan(
                &f.chain,
                &f.datasets,
                &f.stencils,
                target,
            );
            assert!(plan.num_tiles() >= 1, "seed {seed} target {target}");
        }
    }
}
