//! Property tests for the fleet serving layer:
//!
//! * **deterministic**: the same (cluster, workload, opts) triple
//!   served twice yields bit-identical placements, start/end times,
//!   latencies and checksums — across every placement policy;
//! * **solo-exact**: every request's store checksum equals a fresh solo
//!   run of the same (member, app, size, steps) — multi-tenancy and
//!   queueing never perturb numerics;
//! * **batching-invariant**: sharing one frozen Program per fingerprint
//!   changes how often freeze-time work runs (once per fingerprint vs
//!   once per request), never what any request computes or where it
//!   lands;
//! * **quantiles bracket**: the reported latency quantile bounds
//!   bracket the exact rank-rule quantile of the recorded latencies;
//! * **failure-correct**: a rank failure mid-service re-decomposes the
//!   sharded member onto its survivors and the retried request matches
//!   a fresh run on the degraded member bit-for-bit.

use ops_oc::fleet::{serve, solo_run, Cluster, FleetOpts, FleetRun, Policy, Scenario, Workload};

const HETERO: &str = "fleet:gpu-explicit:pcie:cyclic,gpu-explicit:nvlink:cyclic";
const WORKLOAD: &str =
    "tenants=5,reqs=2,apps=cloverleaf2d|cloverleaf3d,sizes=0.004|0.008,steps=4,seed=41";

fn run(spec: &str, workload: &str, opts: &FleetOpts) -> FleetRun {
    let cluster = Cluster::parse(spec).expect("cluster spec");
    let w = Workload::parse(workload).expect("workload spec");
    serve(&cluster, &w, opts).expect("serve")
}

#[test]
fn same_seed_same_placements_and_latencies() {
    for policy in [Policy::FirstFit, Policy::BestFit, Policy::TierAware] {
        let opts = FleetOpts { policy, ..FleetOpts::default() };
        let a = run(HETERO, WORKLOAD, &opts);
        let b = run(HETERO, WORKLOAD, &opts);
        assert_eq!(a.completed(), b.completed(), "{policy:?}");
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{policy:?}");
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.id, y.id, "{policy:?}: replay order diverged");
            assert_eq!(x.target, y.target, "{policy:?}: placement diverged");
            assert_eq!(x.start_s.to_bits(), y.start_s.to_bits(), "{policy:?}");
            assert_eq!(x.end_s.to_bits(), y.end_s.to_bits(), "{policy:?}");
            assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits(), "{policy:?}");
            assert_eq!(x.checksum, y.checksum, "{policy:?}: numerics diverged");
        }
    }
}

#[test]
fn every_request_matches_a_solo_run() {
    let cluster = Cluster::parse(HETERO).unwrap();
    let fleet = run(HETERO, WORKLOAD, &FleetOpts::default());
    assert_eq!(fleet.completed(), 10);
    // Solo checksums per (target, app, size) actually served — computed
    // once per distinct triple, then compared against every outcome.
    let mut solo: std::collections::HashMap<(usize, &str, u64), u64> = Default::default();
    for o in &fleet.outcomes {
        let key = (o.target, o.app.name(), o.size_gb.to_bits());
        let expect = *solo.entry(key).or_insert_with(|| {
            let (_, sum) = solo_run(&cluster.targets[o.target], o.app, o.size_gb, 4)
                .expect("solo run");
            sum
        });
        assert_eq!(
            o.checksum, expect,
            "request {} ({} {:.3} GB on target {}) diverged from its solo run",
            o.id,
            o.app.name(),
            o.size_gb,
            o.target
        );
        assert!(!o.oom);
        assert!(o.latency_s >= o.service_s, "latency includes service");
    }
}

#[test]
fn batching_never_changes_results() {
    let batched = run(HETERO, WORKLOAD, &FleetOpts::default());
    let unbatched = run(
        HETERO,
        WORKLOAD,
        &FleetOpts { batching: false, ..FleetOpts::default() },
    );
    // Distinct fingerprints == distinct (app, size) pairs the trace
    // actually drew — derived from the workload, not hard-coded.
    let drawn: std::collections::HashSet<(&str, u64)> = Workload::parse(WORKLOAD)
        .unwrap()
        .generate()
        .iter()
        .map(|r| (r.app.name(), r.size_gb.to_bits()))
        .collect();
    assert_eq!(batched.distinct_fingerprints, drawn.len());
    assert_eq!(
        batched.programs_built as usize, batched.distinct_fingerprints,
        "batching freezes once per fingerprint"
    );
    assert_eq!(
        unbatched.programs_built as usize,
        unbatched.completed(),
        "no batching freezes once per request"
    );
    assert!(batched.metrics.analysis_builds < unbatched.metrics.analysis_builds);
    assert!(batched.metrics.analysis_reuse_hits > 0);
    // ... but every observable result is identical.
    assert_eq!(batched.completed(), unbatched.completed());
    assert_eq!(batched.makespan_s.to_bits(), unbatched.makespan_s.to_bits());
    for (x, y) in batched.outcomes.iter().zip(&unbatched.outcomes) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.target, y.target);
        assert_eq!(x.checksum, y.checksum, "batching changed request {} numerics", x.id);
        assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits());
    }
}

#[test]
fn latency_quantiles_bracket_exact_sample_quantiles() {
    let fleet = run(HETERO, WORKLOAD, &FleetOpts::default());
    let mut exact: Vec<f64> = fleet.outcomes.iter().map(|o| o.latency_s).collect();
    exact.sort_by(f64::total_cmp);
    let n = exact.len();
    let hist = fleet
        .metrics
        .obs
        .histogram("request_latency_s")
        .expect("serving records a latency histogram");
    assert_eq!(hist.count() as usize, n);
    for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
        // the histogram's rank rule: rank = ceil(q*count) in 1..=count
        let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
        let sample = exact[rank - 1];
        let (lo, hi) = hist.quantile_bounds(q).expect("non-empty");
        assert!(
            lo <= sample && sample <= hi,
            "q={q}: exact sample {sample} outside histogram bracket [{lo}, {hi}]"
        );
        assert_eq!(fleet.latency_quantile(q).to_bits(), hi.to_bits());
    }
}

#[test]
fn rank_failure_redecomposes_and_matches_degraded_solo() {
    let spec = "fleet:gpu-explicit:pcie:cyclic:x2,gpu-explicit:pcie:cyclic";
    let workload = "tenants=4,reqs=1,apps=cloverleaf2d,sizes=0.005,steps=4,seed=13";
    let opts = FleetOpts {
        scenarios: vec![Scenario::parse("fail:0@0.0000001").unwrap()],
        ..FleetOpts::default()
    };
    let fleet = run(spec, workload, &opts);
    assert_eq!(fleet.completed(), 4, "failure must not drop requests");
    assert_eq!(fleet.failovers, 1);
    assert!(fleet.per_target[0].degraded);
    assert!(!fleet.per_target[0].retired, "x2 degrades, it does not retire");

    let cluster = Cluster::parse(spec).unwrap();
    let degraded = cluster.targets[0].degrade().expect("x2 has survivors");
    let retried: Vec<_> = fleet.outcomes.iter().filter(|o| o.retried).collect();
    assert_eq!(retried.len(), 1, "exactly the in-flight request retries");
    let o = retried[0];
    assert_eq!(o.target, 0, "the retry lands on the degraded member");
    let (_, degraded_sum) = solo_run(&degraded, o.app, o.size_gb, 4).unwrap();
    assert_eq!(
        o.checksum, degraded_sum,
        "retried request must equal a fresh run on the surviving cluster"
    );
    // the failed attempt's wasted time is part of the latency
    assert!(o.latency_s > o.service_s);
    // the untouched member keeps serving: every non-retried request on
    // target 1 matches ITS solo run too
    let (_, t1_sum) = solo_run(&cluster.targets[1], o.app, o.size_gb, 4).unwrap();
    for other in fleet.outcomes.iter().filter(|r| r.target == 1) {
        assert_eq!(other.checksum, t1_sum);
        assert!(!other.retried);
    }
}
