//! Codec-subsystem properties: randomized codec-annotated stacks
//! round-trip through the full spec grammar; a ratio-1.0 codec is
//! bit-identical to no codec at all; effective bandwidth is monotone in
//! the compression ratio; codec-bound attribution flips exactly where
//! the hand-computed throughput threshold says it must; and sharded
//! codec streams are rank-namespaced exactly once.

use ops_oc::bench_support::run_cl2d_cfg;
use ops_oc::codec::CodecSpec;
use ops_oc::coordinator::Config;
use ops_oc::exec::Metrics;
use ops_oc::memory::AppCalib;
use ops_oc::topology::{Tier, Topology};

/// Deterministic xorshift (no rng dependency).
struct XorShift(u64);

impl XorShift {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

/// A random valid codec: short form, long form, or long form with a
/// read-only override. f64 `Display` round-trips exactly, so arbitrary
/// two-decimal values exercise the render→parse inverse fully.
fn random_codec(rng: &mut XorShift) -> CodecSpec {
    let mut c = CodecSpec::new(1.0 + (rng.below(700) as f64) / 100.0);
    if rng.below(2) == 0 {
        c.compress_gbs = 0.5 + (rng.below(400) as f64) / 4.0;
        c.decompress_gbs = 0.5 + (rng.below(400) as f64) / 4.0;
        if rng.below(2) == 0 {
            c.ro_ratio = Some(1.0 + (rng.below(900) as f64) / 100.0);
        }
    }
    c
}

/// Property (satellite): 200 randomized codec-annotated stacks
/// round-trip exactly through `Topology::spec()` → `Config::parse_spec`
/// — including the `~c:` colon that the option-token split must stitch
/// back together.
#[test]
fn randomized_codec_stacks_round_trip() {
    let mut rng = XorShift(0xC0DE_CAFE_0000_0001);
    for case in 0..200 {
        let n = 2 + rng.below(4) as usize; // 2..=5 tiers
        let mut tiers = Vec::new();
        let mut lats = Vec::new();
        let mut codecs = Vec::new();
        for i in 0..n {
            let cap = if i + 1 == n {
                None // unbounded home tier
            } else {
                Some((1 + rng.below(64)) << 20)
            };
            let bw = 0.25 + (rng.below(10_000) as f64) / 7.0;
            tiers.push(Tier::new(&format!("t{i}"), cap, bw));
            if i > 0 {
                lats.push((rng.below(100_000) as f64) * 1e-9);
                // ~2/3 of the links carry a codec
                codecs.push((rng.below(3) != 0).then(|| random_codec(&mut rng)));
            }
        }
        let topo = Topology::from_tiers(None, tiers, &lats)
            .and_then(|t| t.with_codecs(codecs))
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        let s = topo.spec();
        let (t, tuned) = Config::parse_spec(&s).unwrap_or_else(|e| panic!("case {case} {s}: {e}"));
        assert!(!tuned);
        let parsed = &t.tiered().unwrap_or_else(|| panic!("{s}")).topology;
        assert_eq!(parsed, &topo, "case {case}: {s}");
        // equality above covers the codecs; spot-check the accessor too
        for l in 0..topo.num_tiers() - 1 {
            assert_eq!(parsed.codec(l), topo.codec(l), "case {case} link {l}");
        }
    }
}

fn run(spec: &str, gb: f64) -> (Metrics, bool) {
    let (t, _) = Config::parse_spec(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
    let cfg = Config::for_target(t, AppCalib::CLOVERLEAF_2D);
    run_cl2d_cfg(&cfg, false, 8, 256, gb, 2, 0)
}

/// Assert two runs are bit-identical: clocks, byte ledgers, and the
/// whole per-resource timeline accounting.
fn assert_bit_identical(a: &Metrics, b: &Metrics, what: &str) {
    assert_eq!(a.elapsed_s.to_bits(), b.elapsed_s.to_bits(), "{what}: elapsed");
    assert_eq!(a.loop_bytes, b.loop_bytes, "{what}: loop bytes");
    assert_eq!(a.loop_time_s.to_bits(), b.loop_time_s.to_bits(), "{what}: loop time");
    assert_eq!(a.h2d_bytes, b.h2d_bytes, "{what}: h2d");
    assert_eq!(a.d2h_bytes, b.d2h_bytes, "{what}: d2h");
    assert_eq!(a.codec_bytes_saved, 0, "{what}: identity saves nothing");
    assert_eq!(b.codec_bytes_saved, 0, "{what}: codec-free twin");
    assert_eq!(
        a.per_resource.keys().collect::<Vec<_>>(),
        b.per_resource.keys().collect::<Vec<_>>(),
        "{what}: stream sets"
    );
    for (k, sa) in &a.per_resource {
        let sb = &b.per_resource[k];
        assert_eq!(sa.busy_s.to_bits(), sb.busy_s.to_bits(), "{what}: {k} busy");
        assert_eq!(sa.bytes, sb.bytes, "{what}: {k} bytes");
        assert_eq!(sa.events, sb.events, "{what}: {k} events");
    }
}

/// Equivalence bar (tentpole): a ratio-1.0 codec takes the exact legacy
/// code path — bit-identical clocks, bytes and ledger to no codec —
/// even with absurd modelled throughputs, on two- and three-tier stacks
/// and through the sharded wrapper.
#[test]
fn identity_codec_twin_is_bit_identical() {
    let cases = [
        (
            "tiers:hbm=64k@509.7+host=inf@11~c:1:cyclic",
            "tiers:hbm=64k@509.7+host=inf@11:cyclic",
        ),
        // identity is about the ratio, not the throughputs: the engine
        // must strip it before any codec-stream scheduling happens
        (
            "tiers:hbm=64k@509.7+host=inf@11~c:1@0.001/0.001:cyclic",
            "tiers:hbm=64k@509.7+host=inf@11:cyclic",
        ),
        (
            "tiers:hbm=64k@509.7+host=256k@11~0.00001~c:1+nvme=inf@6~0.00002~c:1:cyclic:prefetch",
            "tiers:hbm=64k@509.7+host=256k@11~0.00001+nvme=inf@6~0.00002:cyclic:prefetch",
        ),
        (
            "tiers:hbm=256k@509.7+host=inf@11~c:1:cyclic:x2",
            "tiers:hbm=256k@509.7+host=inf@11:cyclic:x2",
        ),
    ];
    for (with, without) in cases {
        let (ma, oa) = run(with, 0.01);
        let (mb, ob) = run(without, 0.01);
        assert_eq!(oa, ob, "{with}");
        assert_bit_identical(&ma, &mb, with);
    }
}

/// Property (satellite): with the codec kernels fast enough to stay off
/// the critical path, wall clock is monotone non-increasing in the
/// compression ratio — more compression never costs time — and a real
/// ratio is strictly faster than identity on a transfer-bound cell.
#[test]
fn effective_bandwidth_is_monotone_in_ratio() {
    let mut prev = f64::INFINITY;
    let mut first = 0.0;
    let mut last = 0.0;
    for (i, ratio) in ["1", "1.5", "2.5", "3.5", "6"].iter().enumerate() {
        let spec = format!("tiers:hbm=64k@509.7+host=inf@11~c:{ratio}@1000/1000:cyclic");
        let (m, oom) = run(&spec, 0.01);
        assert!(!oom, "{spec}");
        assert!(
            m.elapsed_s <= prev * (1.0 + 1e-9),
            "ratio {ratio}: {} !<= {prev}",
            m.elapsed_s
        );
        prev = m.elapsed_s;
        if i == 0 {
            first = m.elapsed_s;
        }
        last = m.elapsed_s;
    }
    assert!(
        last < first * 0.999,
        "a 6:1 codec must beat identity on a transfer-bound cell: {last} !< {first}"
    );
}

/// Property (satellite): the codec-bound flip sits where the arithmetic
/// says. On a zero-latency link of bandwidth `bw` with ratio `r` and
/// symmetric codec throughput `t`, the codec stream's busy time per
/// logical byte is `1/t` against the wire's `1/(r·bw)` — so the run is
/// codec-bound iff `t < r·bw`. Here `r·bw = 3.5 × 11 = 38.5` GB/s;
/// probe a decade either side.
#[test]
fn codec_bound_detection_matches_hand_computed_threshold() {
    let (slow, oom) = run("tiers:hbm=64k@509.7+host=inf@11~c:3.5@5/5:cyclic", 0.01);
    assert!(!oom);
    assert_eq!(
        slow.bound().name(),
        "codec",
        "5 GB/s codec kernels against a 38.5 GB/s effective wire must dominate"
    );
    assert!(slow.stream_util(ops_oc::exec::StreamClass::Codec) > 0.0);
    assert!(slow.codec_bytes_saved > 0);

    let (fast, oom) = run("tiers:hbm=64k@509.7+host=inf@11~c:3.5@500/500:cyclic", 0.01);
    assert!(!oom);
    assert_ne!(
        fast.bound().name(),
        "codec",
        "500 GB/s codec kernels cannot be the bottleneck (bound: {:?})",
        fast.bound().name()
    );
    // same wire model: both save the same bytes, the slow codec just
    // pays more stream time for them
    assert_eq!(slow.codec_bytes_saved, fast.codec_bytes_saved);
    let slow_busy = slow.per_resource["codec"].busy_s;
    let fast_busy = fast.per_resource["codec"].busy_s;
    assert!(
        (slow_busy / fast_busy - 100.0).abs() < 1.0,
        "busy time scales inversely with throughput: {slow_busy} vs {fast_busy}"
    );
}

/// Property (satellite): sharded runs namespace codec streams exactly
/// once — `r<rank>:codec`, never a bare `codec` and never a double
/// `r0:r0:` prefix — and every rank carries one.
#[test]
fn sharded_codec_streams_are_rank_namespaced_idempotently() {
    for ranks in [2usize, 4] {
        let spec = format!("tiers:hbm=256k@509.7+host=inf@11~c:3.5:cyclic:x{ranks}");
        let (m, oom) = run(&spec, 0.01);
        assert!(!oom, "{spec}");
        assert!(m.codec_bytes_saved > 0, "{spec}");
        let codec_keys: Vec<&str> = m
            .per_resource
            .keys()
            .map(|k| k.as_str())
            .filter(|k| k.contains("codec"))
            .collect();
        assert_eq!(codec_keys.len(), ranks, "{spec}: {codec_keys:?}");
        for key in &codec_keys {
            let (rank, rest) = key.split_once(':').unwrap_or_else(|| panic!("{key}"));
            assert_eq!(rest, "codec", "{spec}: {key} must namespace exactly once");
            let digits = rank.strip_prefix('r').unwrap_or_else(|| panic!("{key}"));
            let r: usize = digits.parse().unwrap_or_else(|_| panic!("{key}"));
            assert!(r < ranks, "{spec}: {key}");
        }
        for r in 0..ranks {
            assert!(
                codec_keys.contains(&format!("r{r}:codec").as_str()),
                "{spec}: rank {r} missing from {codec_keys:?}"
            );
        }
    }
}
