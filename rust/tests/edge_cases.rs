//! Edge-case coverage: degenerate chains, tiny grids, single-tile plans,
//! empty ranges, metrics/report plumbing, and the periodic-exchange API.

#![allow(deprecated)] // exercises the legacy OpsContext shim on purpose

use ops_oc::apps::diffusion::Diffusion2D;
use ops_oc::coordinator::{Config, Platform, Summary};
use ops_oc::memory::gpu_explicit::tile_traffic;
use ops_oc::memory::{AppCalib, HaloModel, Link};
use ops_oc::ops::kernel::kernel;
use ops_oc::ops::stencil::{shapes, StencilId};
use ops_oc::ops::{
    Access, Arg, BlockId, Dataset, DatasetId, LoopInst, OpsContext, RedOp, Stencil,
};
use ops_oc::tiling::footprint::Interval;
use ops_oc::tiling::plan::{plan_auto, plan_chain};

fn ctx(p: Platform) -> OpsContext {
    OpsContext::new(Config::new(p, AppCalib::CLOVERLEAF_2D).build_engine())
}

#[test]
fn empty_flush_is_harmless() {
    let mut c = ctx(Platform::KnlCacheTiled);
    c.flush();
    c.flush();
    assert_eq!(c.metrics().chains, 0);
}

#[test]
fn empty_range_loop_executes_nothing_but_counts() {
    let mut c = ctx(Platform::KnlFlatDdr4);
    let b = c.decl_block("g", [8, 8, 1]);
    let d = c.decl_dat(b, "d", [8, 8, 1], [0; 3], [0; 3]);
    let s = c.decl_stencil("pt", shapes::point());
    c.par_loop(
        "empty",
        b,
        [(4, 4), (0, 8), (0, 1)],
        kernel(|c| c.w(0, 0, 0, f64::NAN)),
        vec![Arg::dat(d, s, Access::Write)],
    );
    c.flush();
    let buf = c.fetch(d);
    assert!(buf.iter().all(|v| *v == 0.0), "no NaN may be written");
}

#[test]
fn single_row_grid_tiles_to_one_tile() {
    // tiled dimension extent 1: plan must degenerate gracefully
    let mut c = ctx(Platform::KnlCacheTiled);
    let b = c.decl_block("g", [64, 1, 1]);
    let d = c.decl_dat(b, "d", [64, 1, 1], [1, 0, 0], [1, 0, 0]);
    let s = c.decl_stencil("pt", shapes::point());
    for _ in 0..3 {
        c.par_loop(
            "w",
            b,
            [(0, 64), (0, 1), (0, 1)],
            kernel(|c| {
                let v = c.r(0, 0, 0);
                c.w(0, 0, 0, v + 1.0);
            }),
            vec![Arg::dat(d, s, Access::ReadWrite)],
        );
    }
    c.flush();
    assert_eq!(c.value_at(d, [10, 0, 0]), 3.0);
}

#[test]
fn chain_of_one_loop_everywhere() {
    for p in [
        Platform::KnlCacheTiled,
        Platform::GpuExplicit {
            link: Link::PciE,
            cyclic: true,
            prefetch: true,
        },
        Platform::GpuUnified {
            link: Link::NvLink,
            tiled: true,
            prefetch: true,
        },
    ] {
        let mut c = ctx(p);
        let b = c.decl_block("g", [16, 64, 1]);
        let d = c.decl_dat(b, "d", [16, 64, 1], [1, 1, 0], [1, 1, 0]);
        let s = c.decl_stencil("pt", shapes::point());
        let r = c.decl_reduction("sum", RedOp::Sum);
        c.par_loop(
            "ones",
            b,
            [(0, 16), (0, 64), (0, 1)],
            kernel(|c| {
                c.w(0, 0, 0, 1.0);
                c.red_sum(0, 1.0);
            }),
            vec![
                Arg::dat(d, s, Access::Write),
                Arg::GblRed { red: r, op: RedOp::Sum },
            ],
        );
        assert_eq!(c.reduction_result(r), 1024.0, "on {}", p.label());
    }
}

#[test]
fn reductions_sum_correctly_across_tiles() {
    // sums must be partition-independent (associativity of disjoint tiles)
    let run = |p: Platform| {
        let mut c = ctx(p);
        let app = Diffusion2D::new(&mut c, 16, 512, 1);
        app.init(&mut c);
        app.total_heat(&mut c)
    };
    let a = run(Platform::KnlFlatDdr4);
    let b = run(Platform::KnlCacheTiled);
    assert!((a - b).abs() < 1e-9 * a.abs());
}

#[test]
fn exchange_periodic_wraps_correctly() {
    let mut c = ctx(Platform::KnlFlatDdr4);
    let b = c.decl_block("g", [8, 8, 1]);
    let d = c.decl_dat(b, "d", [8, 8, 1], [2, 2, 0], [2, 2, 0]);
    let s = c.decl_stencil("pt", shapes::point());
    c.par_loop(
        "iota",
        b,
        [(0, 8), (0, 8), (0, 1)],
        kernel(|c| {
            let [x, y, _] = c.idx();
            c.w(0, 0, 0, (10 * y + x) as f64);
        }),
        vec![Arg::dat(d, s, Access::Write)],
    );
    c.exchange_periodic(d, 1, 2); // flushes, then wraps y
    assert_eq!(c.value_at(d, [3, -1, 0]), c.value_at(d, [3, 7, 0]));
    assert_eq!(c.value_at(d, [3, -2, 0]), c.value_at(d, [3, 6, 0]));
    assert_eq!(c.value_at(d, [5, 8, 0]), c.value_at(d, [5, 0, 0]));
    assert_eq!(c.value_at(d, [5, 9, 0]), c.value_at(d, [5, 1, 0]));
    assert!(c.metrics().halo_exchanges >= 1);
}

#[test]
fn summary_row_roundtrip() {
    let mut c = ctx(Platform::GpuExplicit {
        link: Link::NvLink,
        cyclic: true,
        prefetch: false,
    });
    let app = Diffusion2D::new(&mut c, 16, 256, 1 << 12);
    app.run(&mut c, 4, 2);
    let s = Summary::from_metrics("t", c.problem_bytes(), c.metrics(), c.oom());
    assert!(s.avg_bw_gbs > 0.0);
    assert!(s.row().contains('t'));
    assert!(!s.oom);
}

#[test]
fn metrics_survive_reset_boundaries() {
    let mut c = ctx(Platform::KnlCacheTiled);
    let app = Diffusion2D::new(&mut c, 16, 256, 1);
    app.init(&mut c);
    c.flush();
    let warm = c.metrics().loop_bytes;
    assert!(warm > 0);
    c.reset_metrics();
    assert_eq!(c.metrics().loop_bytes, 0);
    app.step(&mut c);
    c.flush();
    assert!(c.metrics().loop_bytes > 0);
}

// ---------------------------------------------------------------------------
// Targeted edge cases for memory/halo.rs and tiling/footprint.rs: zero-depth
// halos, single-tile plans and the write-first skip path.

fn ds(id: u32, halo: i32, ny: usize) -> Dataset {
    Dataset {
        id: DatasetId(id),
        block: BlockId(0),
        name: format!("d{id}"),
        size: [32, ny, 1],
        halo_lo: [halo, halo, 0],
        halo_hi: [halo, halo, 0],
        elem_bytes: 8,
    }
}

fn st(id: u32, pts: Vec<[i32; 3]>) -> Stencil {
    Stencil {
        id: StencilId(id),
        name: format!("s{id}"),
        points: pts,
    }
}

fn lp(name: &str, ny: isize, args: Vec<Arg>) -> LoopInst {
    LoopInst {
        name: name.into(),
        block: BlockId(0),
        range: [(0, 32), (0, ny), (0, 1)],
        args,
        kernel: kernel(|_| {}),
        kernel_ir: None,
        seq: 0,
        bw_efficiency: 1.0,
    }
}

#[test]
fn zero_depth_halos_cost_no_exchange() {
    // point-stencil reads over a halo-less dataset: the MPI model must
    // charge nothing, tiled or untiled.
    let datasets = vec![ds(0, 0, 64)];
    let stencils = vec![st(0, shapes::point())];
    let chain = vec![
        lp("w", 64, vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)]),
        lp("r", 64, vec![Arg::dat(DatasetId(0), StencilId(0), Access::Read)]),
    ];
    let h = HaloModel::knl();
    for l in &chain {
        let (t, n) = h.per_loop_cost(l, &datasets, &stencils, 1);
        assert_eq!((t, n), (0.0, 0));
    }
    let (t, n) = h.per_chain_cost(&chain, &datasets, &stencils, 1, 0);
    assert_eq!((t, n), (0.0, 0));
}

#[test]
fn single_tile_plan_has_no_edges() {
    let datasets = vec![ds(0, 2, 64)];
    let stencils = vec![st(0, shapes::star2d(1))];
    let chain = vec![lp(
        "r",
        64,
        vec![Arg::dat(DatasetId(0), StencilId(0), Access::Read)],
    )];
    let plan = plan_chain(&chain, &datasets, &stencils, 1);
    assert_eq!(plan.num_tiles(), 1);
    let d = DatasetId(0);
    assert!(plan.left_edge(0, d).is_empty());
    assert!(plan.right_edge(0, d).is_empty());
    // with no left edge, the whole footprint must be freshly uploaded
    let fp = plan.tiles[0].footprints[0].as_ref().unwrap().full;
    assert_eq!(plan.right_footprint(0, d), fp);
    // the footprint covers the stencil reach, clamped to the dataset
    assert_eq!(fp, Interval::new(-1, 65));
    // auto-planner agrees when the target is unbounded
    let auto = plan_auto(&chain, &datasets, &stencils, u64::MAX).unwrap();
    assert_eq!(auto.num_tiles(), 1);
}

#[test]
fn plan_auto_degenerate_targets_error_instead_of_panicking() {
    let datasets = vec![ds(0, 2, 64)];
    let stencils = vec![st(0, shapes::star2d(1))];
    let chain = vec![lp(
        "r",
        64,
        vec![Arg::dat(DatasetId(0), StencilId(0), Access::Read)],
    )];
    // a zero slot target is a typed error, not a division-by-zero or an
    // infinite planning loop
    let e = plan_auto(&chain, &datasets, &stencils, 0).unwrap_err();
    assert!(e.to_string().contains("slot target is zero"), "{e}");
    // a target below one halo-widened slab reports the minimum slab size
    let e = plan_auto(&chain, &datasets, &stencils, 8).unwrap_err();
    assert!(e.to_string().contains("halo-widened slab"), "{e}");
    // an empty chain cannot be planned
    let e = plan_auto(&[], &datasets, &stencils, 1 << 20).unwrap_err();
    assert!(e.to_string().contains("empty loop chain"), "{e}");
    // a chain that touches no datasets is trivially one tile, any target
    let red_only = vec![lp("red", 64, vec![])];
    let p = plan_auto(&red_only, &datasets, &stencils, 0).unwrap();
    assert_eq!(p.num_tiles(), 1);
}

#[test]
fn engines_survive_infeasible_slot_targets() {
    // an HBM so small that even single-plane slabs overflow a slot: the
    // engine must stream at the single-plane floor, not panic, and stay
    // bit-exact (the seed's best-effort behaviour, now via PlanSource)
    use ops_oc::memory::{GpuCalib, GpuExplicitEngine, GpuOpts};
    let p = Platform::GpuExplicit {
        link: Link::PciE,
        cyclic: true,
        prefetch: true,
    };
    let mut c = ctx(p);
    // 512 B of "HBM": a slot target of ~157 B is below one 272 B plane,
    // so plan_auto's typed error path (and the floor fallback) is hit
    let mut tiny = OpsContext::new(Box::new(
        GpuExplicitEngine::new(
            GpuCalib {
                hbm_bytes: 512,
                ..GpuCalib::default()
            },
            AppCalib::CLOVERLEAF_2D,
            Link::PciE,
            GpuOpts::default(),
        )
        .unwrap(),
    ));
    for c in [&mut c, &mut tiny] {
        let b = c.decl_block("g", [32, 256, 1]);
        let d = c.decl_dat(b, "d", [32, 256, 1], [1, 1, 0], [1, 1, 0]);
        let s = c.decl_stencil("pt", shapes::point());
        for _ in 0..3 {
            c.par_loop(
                "acc",
                b,
                [(0, 32), (0, 256), (0, 1)],
                kernel(|c| {
                    let v = c.r(0, 0, 0);
                    c.w(0, 0, 0, v + 1.0);
                }),
                vec![Arg::dat(d, s, Access::ReadWrite)],
            );
        }
        c.flush();
    }
    let d = DatasetId(0);
    assert_eq!(c.fetch(d), tiny.fetch(d), "floor plan must stay bit-exact");
    assert!(tiny.metrics().tiles >= c.metrics().tiles);
}

#[test]
fn write_first_dataset_skips_upload_but_keeps_download() {
    // temp is written (whole range) before being read: §4.1 write-first.
    let datasets = vec![ds(0, 2, 256), ds(1, 2, 256)];
    let stencils = vec![st(0, shapes::point()), st(1, shapes::star2d(1))];
    let chain = vec![
        lp(
            "mk_temp",
            256,
            vec![
                Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                Arg::dat(DatasetId(1), StencilId(0), Access::Write),
            ],
        ),
        lp(
            "use_temp",
            256,
            vec![
                Arg::dat(DatasetId(1), StencilId(1), Access::Read),
                Arg::dat(DatasetId(0), StencilId(0), Access::ReadWrite),
            ],
        ),
    ];
    let summary = ops_oc::tiling::chain_access_summary(&chain);
    assert!(summary[&DatasetId(1)].write_first);
    assert!(summary[&DatasetId(1)].skip_upload());
    assert!(!summary[&DatasetId(1)].skip_download());

    let plan = plan_chain(&chain, &datasets, &stencils, 4);
    let with_skip = |skip_up: bool| -> (u64, u64) {
        let skip_upload = vec![false, skip_up];
        let skip_download = vec![false, false];
        let mut up = 0;
        let mut down = 0;
        for t in 0..plan.num_tiles() {
            let tr = tile_traffic(&plan, t, &datasets, &skip_upload, &skip_download);
            up += tr.upload;
            down += tr.download;
        }
        (up, down)
    };
    let (up_skip, down_skip) = with_skip(true);
    let (up_all, down_all) = with_skip(false);
    assert!(
        up_skip < up_all,
        "write-first skip must cut uploads: {up_skip} !< {up_all}"
    );
    assert_eq!(down_skip, down_all, "downloads unaffected by upload skip");
    assert!(down_skip > 0, "written data still comes back");
}

#[test]
fn empty_and_degenerate_intervals_behave() {
    let e = Interval::empty();
    assert_eq!(e.len(), 0);
    assert!(e.intersect(&Interval::new(-5, 5)).is_empty());
    assert_eq!(e.hull(&Interval::new(2, 3)), Interval::new(2, 3));
    // inverted interval counts as empty everywhere
    let inv = Interval::new(9, 3);
    assert!(inv.is_empty());
    assert!(inv.clamp_to(0, 100).is_empty());
}

#[test]
fn gbl_const_and_idx_args_are_inert_for_tiling() {
    let mut c = ctx(Platform::KnlCacheTiled);
    let b = c.decl_block("g", [8, 128, 1]);
    let d = c.decl_dat(b, "d", [8, 128, 1], [0; 3], [0; 3]);
    let s = c.decl_stencil("pt", shapes::point());
    for _ in 0..4 {
        c.par_loop(
            "scale",
            b,
            [(0, 8), (0, 128), (0, 1)],
            kernel(|c| {
                let [x, _, _] = c.idx();
                let v = c.r(0, 0, 0);
                c.w(0, 0, 0, v + c.gbl(0) + x as f64 * c.gbl(1));
            }),
            vec![
                Arg::dat(d, s, Access::ReadWrite),
                Arg::GblConst {
                    values: vec![2.0, 0.5],
                },
                Arg::Idx,
            ],
        );
    }
    c.flush();
    // 4 iterations of +2.0 + x*0.5
    assert_eq!(c.value_at(d, [2, 64, 0]), 4.0 * (2.0 + 1.0));
}
