//! Integration: the AOT artifacts (JAX/Pallas → HLO text) loaded through
//! the PJRT runtime must reproduce the native executor bit-for-bit-ish
//! (≤1 ulp-scale differences from XLA instruction ordering), including
//! under tiled execution where the executor writes back sub-ranges.
//!
//! Requires `make artifacts`; tests skip (with a loud message) when the
//! artifacts directory is missing so `cargo test` works pre-AOT.

#![allow(deprecated)] // exercises the legacy OpsContext shim on purpose

use ops_oc::apps::diffusion::Diffusion2D;
use ops_oc::coordinator::{Config, Platform};
use ops_oc::exec::PjrtExecutor;
use ops_oc::memory::{AppCalib, Link};
use ops_oc::ops::OpsContext;
use ops_oc::runtime::{default_artifacts_dir, Runtime};

fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.txt").exists()
}

/// Build a context whose executor routes the diffusion kernels to PJRT.
fn pjrt_ctx(platform: Platform, nx: usize, ny: usize) -> (OpsContext, Diffusion2D, usize) {
    let cfg = Config::new(platform, AppCalib::CLOVERLEAF_2D);
    let mut ctx = OpsContext::new(cfg.build_engine());
    let app = Diffusion2D::new(&mut ctx, nx, ny, 1);
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let arts = rt
        .load_manifest(&default_artifacts_dir().join("manifest.txt"))
        .expect("manifest loads");
    let mut exec = PjrtExecutor::new();
    let mut bound = 0;
    for (_k, (spec, art)) in arts {
        // Only diffusion kernels bind to this context's datasets.
        if spec.kernel.starts_with("diff_") {
            exec.register(&spec, art, ctx.datasets()).expect("register");
            bound += 1;
        }
    }
    ctx.set_executor(Box::new(exec));
    (ctx, app, bound)
}

#[test]
fn pjrt_executes_diffusion_like_native() {
    if !artifacts_available() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    // native reference
    let cfg = Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_2D);
    let mut nat = OpsContext::new(cfg.build_engine());
    let app_n = Diffusion2D::new(&mut nat, 64, 64, 1);
    app_n.run(&mut nat, 5, 1);
    let want = nat.fetch(app_n.u);

    // PJRT-backed
    let (mut ctx, app, bound) = pjrt_ctx(Platform::KnlFlatDdr4, 64, 64);
    assert_eq!(bound, 2, "diff_lap + diff_update must bind");
    app.run(&mut ctx, 5, 1);
    let got = ctx.fetch(app.u);

    assert_eq!(want.len(), got.len());
    for (i, (a, b)) in want.iter().zip(&got).enumerate() {
        assert!(
            (a - b).abs() <= 1e-12 * a.abs().max(1.0),
            "mismatch at {i}: native {a} vs pjrt {b}"
        );
    }
}

#[test]
fn pjrt_under_tiled_streaming_matches_native() {
    if !artifacts_available() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let cfg = Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_2D);
    let mut nat = OpsContext::new(cfg.build_engine());
    let app_n = Diffusion2D::new(&mut nat, 64, 64, 1);
    app_n.run(&mut nat, 4, 2);
    let want = nat.fetch(app_n.u);

    let (mut ctx, app, _) = pjrt_ctx(
        Platform::GpuExplicit {
            link: Link::PciE,
            cyclic: true,
            prefetch: true,
        },
        64,
        64,
    );
    app.run(&mut ctx, 4, 2);
    let got = ctx.fetch(app.u);
    assert!(ctx.metrics().tiles == 0 || ctx.metrics().tiles >= 1);
    for (i, (a, b)) in want.iter().zip(&got).enumerate() {
        assert!(
            (a - b).abs() <= 1e-12 * a.abs().max(1.0),
            "tiled mismatch at {i}: {a} vs {b}"
        );
    }
}

#[test]
fn unbound_kernels_fall_back_to_native() {
    if !artifacts_available() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    // Bind only diff_lap; diff_update and init/sum must fall back.
    let cfg = Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_2D);
    let mut ctx = OpsContext::new(cfg.build_engine());
    let app = Diffusion2D::new(&mut ctx, 64, 64, 1);
    let rt = Runtime::cpu().unwrap();
    let arts = rt
        .load_manifest(&default_artifacts_dir().join("manifest.txt"))
        .unwrap();
    let mut exec = PjrtExecutor::new();
    for (_k, (spec, art)) in arts {
        if spec.kernel == "diff_lap" {
            exec.register(&spec, art, ctx.datasets()).unwrap();
        }
    }
    ctx.set_executor(Box::new(exec));
    app.run(&mut ctx, 2, 1);
    let heat = app.total_heat(&mut ctx);
    assert!(heat.is_finite() && heat > 0.0);
}
