//! Property tests for the tile-plan auto-tuner (in-tree xorshift PRNG —
//! the vendored crate set has no proptest):
//!
//! * **never worse**: across ≥100 random (chain, dataset, platform)
//!   cases, the tuner's chosen plan never *models* slower than the
//!   default `HBM/3`-style heuristic, and the stored scores are exactly
//!   reproducible by independent cost-model replays;
//! * **deterministic**: same inputs + same seed ⇒ same plan, bit for
//!   bit; different seeds may explore differently but keep the bound;
//! * **strict gain exists**: on an engineered chain whose byte-estimate
//!   inflates the heuristic tile count, tuning is *strictly* faster;
//! * **bit-exact**: tuned execution of random chains matches untiled
//!   sequential execution exactly.

use ops_oc::distributed::{DecompKind, Interconnect};
use ops_oc::exec::{Engine, Executor, Metrics, NativeExecutor, World};
use ops_oc::memory::{AppCalib, GpuCalib, GpuOpts, KnlCalib, Link, UnifiedCalib};
use ops_oc::ops::kernel::kernel;
use ops_oc::ops::stencil::shapes;
use ops_oc::ops::*;
use ops_oc::tuner::{model_chain_time, tune, TuneOpts, TunedEngine, TunerTarget};

/// Deterministic xorshift64* PRNG.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
    fn below(&mut self, n: u64) -> u64 {
        self.next() % n.max(1)
    }
    fn f64(&mut self) -> f64 {
        (self.next() >> 11) as f64 / (1u64 << 53) as f64
    }
    fn flip(&mut self) -> bool {
        self.below(2) == 1
    }
}

struct Fixture {
    datasets: Vec<Dataset>,
    stencils: Vec<Stencil>,
    chain: Vec<LoopInst>,
}

/// Random fixture: `nds` datasets, `nloops` loops with random
/// source/dest, random access modes, occasional boundary-strip ranges.
fn random_fixture(seed: u64, nds: u32, nloops: usize, ny: usize) -> Fixture {
    let mut rng = Rng::new(seed);
    let mut datasets = vec![];
    for i in 0..nds {
        datasets.push(Dataset {
            id: DatasetId(i),
            block: BlockId(0),
            name: format!("d{i}"),
            size: [24, ny, 1],
            halo_lo: [2, 2, 0],
            halo_hi: [2, 2, 0],
            elem_bytes: 8,
        });
    }
    let stencils = vec![
        Stencil {
            id: StencilId(0),
            name: "pt".into(),
            points: shapes::point(),
        },
        Stencil {
            id: StencilId(1),
            name: "star".into(),
            points: shapes::star2d(1),
        },
    ];
    let mut chain = vec![];
    for li in 0..nloops {
        let src = DatasetId(rng.below(nds as u64) as u32);
        let mut dst = DatasetId(rng.below(nds as u64) as u32);
        while dst == src {
            dst = DatasetId(rng.below(nds as u64) as u32);
        }
        let acc = match rng.below(3) {
            1 => Access::ReadWrite,
            _ => Access::Write,
        };
        let (y0, y1) = if rng.below(4) == 0 {
            let a = rng.below(ny as u64 - 1) as isize;
            let len = 1 + rng.below((ny as isize - a) as u64) as isize;
            (a, (a + len).min(ny as isize))
        } else {
            (0, ny as isize)
        };
        let coef = 0.25 + 0.5 * rng.f64();
        chain.push(LoopInst {
            name: format!("loop{li}"),
            block: BlockId(0),
            range: [(0, 24), (y0, y1), (0, 1)],
            args: vec![
                Arg::dat(src, StencilId(1), Access::Read),
                Arg::dat(dst, StencilId(0), acc),
            ],
            kernel: kernel(move |c| {
                let v = c.r(0, 0, 0)
                    + 0.5 * (c.r(0, 1, 0) + c.r(0, -1, 0) + c.r(0, 0, 1) + c.r(0, 0, -1));
                let old = c.r(1, 0, 0);
                c.w(1, 0, 0, coef * v + 0.1 * old);
            }),
            kernel_ir: None,
            seq: li as u64,
            bw_efficiency: 0.8 + 0.2 * rng.f64(),
        });
    }
    Fixture {
        datasets,
        stencils,
        chain,
    }
}

/// A random tunable platform: rotates KNL / GPU-explicit / unified /
/// sharded, with randomised toggles and small fast memories so the
/// fixtures genuinely tile.
fn random_target(rng: &mut Rng) -> TunerTarget {
    let gpu = GpuCalib {
        hbm_bytes: (32 + rng.below(96)) << 10,
        ..GpuCalib::default()
    };
    match rng.below(4) {
        0 => TunerTarget::Knl {
            calib: KnlCalib {
                mcdram_bytes: (64 + rng.below(128)) << 10,
                cache_granule: 1 << 10,
                ..KnlCalib::default()
            },
            app: AppCalib::CLOVERLEAF_2D,
        },
        1 => TunerTarget::GpuExplicit {
            calib: gpu,
            app: AppCalib::CLOVERLEAF_2D,
            link: if rng.flip() { Link::PciE } else { Link::NvLink },
            opts: GpuOpts {
                cyclic: rng.flip(),
                prefetch: rng.flip(),
                slots: 3,
            },
        },
        2 => TunerTarget::GpuUnified {
            gpu,
            um: UnifiedCalib {
                page_bytes: 4 << 10,
                ..UnifiedCalib::default()
            },
            app: AppCalib::CLOVERLEAF_2D,
            link: if rng.flip() { Link::PciE } else { Link::NvLink },
            tiled: true,
            prefetch: rng.flip(),
        },
        _ => TunerTarget::Sharded {
            inner: Box::new(TunerTarget::GpuExplicit {
                calib: gpu,
                app: AppCalib::CLOVERLEAF_2D,
                link: Link::NvLink,
                opts: GpuOpts::default(),
            }),
            ranks: 2 + 2 * rng.below(2) as u32,
            kind: if rng.flip() {
                DecompKind::OneD
            } else {
                DecompKind::TwoD
            },
            link: Interconnect::NvLink,
            overlap: rng.flip(),
        },
    }
}

/// ≥100 random cases: tuned never models slower than the heuristic, and
/// both stored scores replay exactly.
#[test]
fn prop_tuned_never_models_slower_than_heuristic() {
    let opts = TuneOpts {
        budget: 16,
        seed: 0xABCD,
    };
    let mut cases = 0;
    for seed in 1..=35u64 {
        let f = random_fixture(seed, 2 + (seed % 3) as u32, 3 + (seed % 5) as usize, 64);
        let mut prng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15));
        for _ in 0..3 {
            let target = random_target(&mut prng);
            let choice = tune(&target, &opts, &f.chain, &f.datasets, &f.stencils, true);
            assert!(
                choice.tuned_model_s <= choice.heuristic_model_s,
                "seed {seed} {target:?}: tuned {} > heuristic {}",
                choice.tuned_model_s,
                choice.heuristic_model_s
            );
            // both scores are exactly reproducible from fresh engines
            let h = model_chain_time(
                &mut *target.build(target.heuristic()),
                &f.chain,
                &f.datasets,
                &f.stencils,
                true,
            );
            assert_eq!(h, choice.heuristic_model_s, "seed {seed}: heuristic replay");
            let t = model_chain_time(
                &mut *target.build(choice.candidate),
                &f.chain,
                &f.datasets,
                &f.stencils,
                true,
            );
            assert_eq!(t, choice.tuned_model_s, "seed {seed}: tuned replay");
            assert!(choice.evals >= 1 && choice.evals <= opts.budget);
            cases += 1;
        }
    }
    assert!(cases >= 100, "only {cases} cases exercised");
}

/// Same seed ⇒ same plan; the bound holds under any seed.
#[test]
fn prop_tuning_is_deterministic_per_seed() {
    for seed in 1..=12u64 {
        let f = random_fixture(seed, 3, 5, 96);
        let mut prng = Rng::new(seed);
        let target = random_target(&mut prng);
        let opts = TuneOpts {
            budget: 20,
            seed: seed ^ 0x5EED,
        };
        let a = tune(&target, &opts, &f.chain, &f.datasets, &f.stencils, true);
        let b = tune(&target, &opts, &f.chain, &f.datasets, &f.stencils, true);
        assert_eq!(a.candidate, b.candidate, "seed {seed}");
        assert_eq!(a.tuned_model_s, b.tuned_model_s, "seed {seed}");
        assert_eq!(a.heuristic_model_s, b.heuristic_model_s, "seed {seed}");
        assert_eq!(a.evals, b.evals, "seed {seed}");
        // a different search seed may pick differently but never worse
        let c = tune(
            &target,
            &TuneOpts {
                budget: 20,
                seed: seed ^ 0xFACE,
            },
            &f.chain,
            &f.datasets,
            &f.stencils,
            true,
        );
        assert!(c.tuned_model_s <= c.heuristic_model_s, "seed {seed}");
    }
}

/// Engineered strict win: a boundary-strip dataset inflates `plan_auto`'s
/// plane-byte estimate, so the heuristic over-tiles and pays avoidable
/// per-tile latencies; the tuner must find a strictly faster count.
#[test]
fn tuned_strictly_beats_inflated_heuristic() {
    let ny = 512usize;
    let mut datasets = vec![];
    for i in 0..3u32 {
        datasets.push(Dataset {
            id: DatasetId(i),
            block: BlockId(0),
            name: format!("d{i}"),
            size: [16, ny, 1],
            halo_lo: [1, 1, 0],
            halo_hi: [1, 1, 0],
            elem_bytes: 8,
        });
    }
    let stencils = vec![
        Stencil {
            id: StencilId(0),
            name: "pt".into(),
            points: shapes::point(),
        },
        Stencil {
            id: StencilId(1),
            name: "star".into(),
            points: shapes::star2d(1),
        },
    ];
    let chain = vec![
        // full-range sweep: D0 -> D2
        LoopInst {
            name: "full".into(),
            block: BlockId(0),
            range: [(0, 16), (0, ny as isize), (0, 1)],
            args: vec![
                Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                Arg::dat(DatasetId(2), StencilId(0), Access::Write),
            ],
            kernel: kernel(|c| {
                let v = c.r(0, 0, -1) + c.r(0, 0, 1);
                c.w(1, 0, 0, 0.5 * v);
            }),
            kernel_ir: None,
            seq: 0,
            bw_efficiency: 1.0,
        },
        // boundary strip: touches D1 on 2 rows only, but plan_auto's
        // byte estimate charges D1 for the full extent
        LoopInst {
            name: "strip".into(),
            block: BlockId(0),
            range: [(0, 16), (0, 2), (0, 1)],
            args: vec![Arg::dat(DatasetId(1), StencilId(0), Access::ReadWrite)],
            kernel: kernel(|c| {
                let v = c.r(0, 0, 0);
                c.w(0, 0, 0, v + 1.0);
            }),
            kernel_ir: None,
            seq: 1,
            bw_efficiency: 1.0,
        },
    ];
    let target = TunerTarget::GpuExplicit {
        calib: GpuCalib {
            hbm_bytes: 90 << 10,
            ..GpuCalib::default()
        },
        app: AppCalib::CLOVERLEAF_2D,
        // toggles already optimal, so any gain must come from the count
        link: Link::PciE,
        opts: GpuOpts::default(),
    };
    let choice = tune(
        &target,
        &TuneOpts::default(),
        &chain,
        &datasets,
        &stencils,
        true,
    );
    assert!(
        choice.tuned_model_s < choice.heuristic_model_s,
        "expected a strict win over the inflated heuristic: tuned {} vs heuristic {} \
         (candidate {:?})",
        choice.tuned_model_s,
        choice.heuristic_model_s,
        choice.candidate
    );
    assert!(choice.candidate.tiles.is_some());
}

/// Tuned execution is bit-exact against sequential untiled execution.
#[test]
fn prop_tuned_numerics_bitexact() {
    for seed in 1..=10u64 {
        let f = random_fixture(seed.wrapping_mul(131), 3, 4 + (seed % 4) as usize, 64);
        let init = |store: &mut DataStore| {
            let mut rng = Rng::new(seed ^ 0xF00D);
            for d in &f.datasets {
                store.alloc(d);
                for v in store.buf_mut(d.id) {
                    *v = rng.f64() * 2.0 - 1.0;
                }
            }
        };
        // reference: sequential untiled
        let mut store_ref = DataStore::new();
        init(&mut store_ref);
        let mut reds_ref: Vec<Reduction> = vec![];
        let mut exec_ref = NativeExecutor::new();
        for l in &f.chain {
            exec_ref.run_loop(l, l.range, &f.datasets, &mut store_ref, &mut reds_ref);
        }
        // tuned engine (distinct budget per seed keeps cache keys apart)
        let mut prng = Rng::new(seed.wrapping_mul(0xC0FFEE));
        let target = random_target(&mut prng);
        let mut e = TunedEngine::new(
            target,
            TuneOpts {
                budget: 12,
                seed,
            },
        );
        let mut store = DataStore::new();
        init(&mut store);
        let mut reds: Vec<Reduction> = vec![];
        let mut metrics = Metrics::new();
        let mut exec = NativeExecutor::new();
        {
            let mut world = World {
                datasets: &f.datasets,
                stencils: &f.stencils,
                store: &mut store,
                reds: &mut reds,
                metrics: &mut metrics,
                exec: &mut exec,
            };
            e.run_chain(&f.chain, &mut world, true);
        }
        for d in &f.datasets {
            assert_eq!(
                store_ref.buf(d.id),
                store.buf(d.id),
                "seed {seed}: tuned numerics must match untiled for {}",
                d.name
            );
        }
        assert!(metrics.tuned_model_s <= metrics.heuristic_model_s);
    }
}
