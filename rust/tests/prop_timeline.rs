//! Property tests for the discrete-event timeline scheduler (in-tree
//! xorshift PRNG — the vendored crate set has no proptest):
//!
//! * **deterministic**: the same random chain priced twice through
//!   fresh engines yields bit-identical makespans and per-stream busy
//!   accounting — on every engine family and on raw [`Timeline`] op
//!   sequences;
//! * **non-negative & causally sound**: makespans are ≥ 0 and never
//!   shorter than the critical path of any single resource (a stream's
//!   busy time cannot exceed the wall clock it fits inside);
//! * **`slots: 3` never models slower than `slots: 2`**: double
//!   buffering only *adds* a synchronisation edge between the upload
//!   and download streams, so across random chains and platform
//!   calibrations triple buffering's makespan is never the larger one.

use ops_oc::exec::timeline::{EventKind, StreamClass, Timeline};
use ops_oc::exec::{Engine, Metrics, NullExecutor, World};
use ops_oc::memory::{
    AppCalib, GpuCalib, GpuExplicitEngine, GpuOpts, KnlCalib, KnlEngine, Link, PlainEngine,
    UnifiedCalib, UnifiedEngine,
};
use ops_oc::ops::kernel::kernel;
use ops_oc::ops::stencil::shapes;
use ops_oc::ops::*;

/// Deterministic xorshift64* PRNG.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
    fn below(&mut self, n: u64) -> u64 {
        self.next() % n.max(1)
    }
    fn f64(&mut self) -> f64 {
        (self.next() >> 11) as f64 / (1u64 << 53) as f64
    }
}

struct Fixture {
    datasets: Vec<Dataset>,
    stencils: Vec<Stencil>,
    chain: Vec<LoopInst>,
}

/// Random chain over random datasets: random dataset pairs, access
/// modes and (sometimes partial) ranges — the same shape family the
/// tiling property tests use.
fn random_fixture(seed: u64, nds: u32, nloops: usize, ny: usize) -> Fixture {
    let mut rng = Rng::new(seed);
    let mut datasets = vec![];
    for i in 0..nds {
        datasets.push(Dataset {
            id: DatasetId(i),
            block: BlockId(0),
            name: format!("d{i}"),
            size: [24, ny, 1],
            halo_lo: [2, 2, 0],
            halo_hi: [2, 2, 0],
            elem_bytes: 8,
        });
    }
    let stencils = vec![
        Stencil {
            id: StencilId(0),
            name: "pt".into(),
            points: shapes::point(),
        },
        Stencil {
            id: StencilId(1),
            name: "star".into(),
            points: shapes::star2d(1),
        },
    ];
    let mut chain = vec![];
    for li in 0..nloops {
        let src = DatasetId(rng.below(nds as u64) as u32);
        let mut dst = DatasetId(rng.below(nds as u64) as u32);
        while dst == src {
            dst = DatasetId(rng.below(nds as u64) as u32);
        }
        let acc = match rng.below(3) {
            1 => Access::ReadWrite,
            _ => Access::Write,
        };
        let (y0, y1) = if rng.below(4) == 0 {
            let a = rng.below(ny as u64 - 1) as isize;
            let len = 1 + rng.below((ny as isize - a) as u64) as isize;
            (a, (a + len).min(ny as isize))
        } else {
            (0, ny as isize)
        };
        chain.push(LoopInst {
            name: format!("loop{li}"),
            block: BlockId(0),
            range: [(0, 24), (y0, y1), (0, 1)],
            args: vec![
                Arg::dat(src, StencilId(1), Access::Read),
                Arg::dat(dst, StencilId(0), acc),
            ],
            kernel: kernel(|_| {}),
            kernel_ir: None,
            seq: li as u64,
            bw_efficiency: 0.5 + 0.5 * rng.f64(),
        });
    }
    Fixture {
        datasets,
        stencils,
        chain,
    }
}

/// Price the chain through an engine with numerics suppressed; returns
/// the full metrics (makespan + attribution).
fn price(f: &Fixture, engine: &mut dyn Engine, cyclic: bool) -> Metrics {
    let mut store = DataStore::new();
    f.datasets.iter().for_each(|d| store.alloc(d));
    let mut reds: Vec<Reduction> = vec![];
    let mut metrics = Metrics::new();
    let mut exec = NullExecutor;
    let mut world = World {
        datasets: &f.datasets,
        stencils: &f.stencils,
        store: &mut store,
        reds: &mut reds,
        metrics: &mut metrics,
        exec: &mut exec,
    };
    engine.run_chain(&f.chain, &mut world, cyclic);
    metrics
}

const APP: AppCalib = AppCalib::CLOVERLEAF_2D;

fn small_gpu(seed: u64) -> GpuCalib {
    GpuCalib {
        // 32–160 KiB "HBM" so the ~100 KiB fixtures genuinely stream
        hbm_bytes: (32 + (seed % 5) * 32) << 10,
        ..GpuCalib::default()
    }
}

/// Every engine family, over one fixture.
fn engine_zoo(seed: u64) -> Vec<Box<dyn Engine>> {
    vec![
        Box::new(PlainEngine::knl_flat_ddr4(APP.knl_ddr4)),
        Box::new(KnlEngine::new(
            KnlCalib {
                mcdram_bytes: 64 << 10,
                cache_granule: 1 << 10,
                ..KnlCalib::default()
            },
            APP,
            seed % 2 == 0,
        )),
        Box::new(
            GpuExplicitEngine::new(small_gpu(seed), APP, Link::PciE, GpuOpts::default()).unwrap(),
        ),
        Box::new(UnifiedEngine::new(
            small_gpu(seed),
            UnifiedCalib {
                page_bytes: 4 << 10,
                ..UnifiedCalib::default()
            },
            APP,
            Link::NvLink,
            seed % 2 == 0,
            seed % 3 == 0,
        )),
    ]
}

#[test]
fn prop_makespans_are_deterministic_and_nonnegative() {
    for seed in 1..=30u64 {
        let f = random_fixture(seed, 2 + (seed % 4) as u32, 2 + (seed % 8) as usize, 96);
        for (i, (mut a, mut b)) in engine_zoo(seed).into_iter().zip(engine_zoo(seed)).enumerate() {
            let ma = price(&f, a.as_mut(), true);
            let mb = price(&f, b.as_mut(), true);
            assert!(ma.elapsed_s >= 0.0, "seed {seed} engine {i}: negative makespan");
            assert!(
                ma.elapsed_s.to_bits() == mb.elapsed_s.to_bits(),
                "seed {seed} engine {i}: nondeterministic makespan {} vs {}",
                ma.elapsed_s,
                mb.elapsed_s
            );
            assert_eq!(
                ma.per_resource.len(),
                mb.per_resource.len(),
                "seed {seed} engine {i}: stream sets differ"
            );
            for (name, st) in &ma.per_resource {
                let other = &mb.per_resource[name];
                assert!(
                    st.busy_s.to_bits() == other.busy_s.to_bits()
                        && st.bytes == other.bytes
                        && st.events == other.events,
                    "seed {seed} engine {i}: stream {name} accounting differs"
                );
                assert!(st.busy_s >= 0.0, "seed {seed} engine {i}: negative busy");
            }
        }
    }
}

#[test]
fn prop_makespan_covers_every_resource_critical_path() {
    // A stream's busy time is a lower bound on the wall clock it ran
    // inside — events on one resource never overlap. (The unified
    // engine's bulk-prefetch stream is the documented exception: it
    // pipelines internally via `push_overlapping`, so it is exercised
    // for determinism above but excluded here.)
    for seed in 1..=30u64 {
        let f = random_fixture(seed, 2 + (seed % 4) as u32, 2 + (seed % 8) as usize, 96);
        let engines: Vec<Box<dyn Engine>> = vec![
            Box::new(PlainEngine::knl_flat_ddr4(APP.knl_ddr4)),
            Box::new(KnlEngine::new(
                KnlCalib {
                    mcdram_bytes: 64 << 10,
                    cache_granule: 1 << 10,
                    ..KnlCalib::default()
                },
                APP,
                seed % 2 == 0,
            )),
            Box::new(
                GpuExplicitEngine::new(small_gpu(seed), APP, Link::PciE, GpuOpts::default())
                    .unwrap(),
            ),
        ];
        for (i, mut e) in engines.into_iter().enumerate() {
            let m = price(&f, e.as_mut(), true);
            for (name, st) in &m.per_resource {
                assert!(
                    st.busy_s <= m.elapsed_s * (1.0 + 1e-12) + 1e-15,
                    "seed {seed} engine {i}: stream {name} busy {} exceeds makespan {}",
                    st.busy_s,
                    m.elapsed_s
                );
            }
        }
    }
}

#[test]
fn prop_triple_buffering_never_models_slower_than_double() {
    for seed in 1..=40u64 {
        let f = random_fixture(
            seed.wrapping_mul(2654435761),
            2 + (seed % 5) as u32,
            2 + (seed % 10) as usize,
            64 + (seed % 3) as usize * 64,
        );
        for link in [Link::PciE, Link::NvLink] {
            for (cyclic, prefetch) in [(true, true), (false, false), (true, false)] {
                let mk = |slots: u8| {
                    GpuExplicitEngine::new(
                        small_gpu(seed),
                        APP,
                        link,
                        GpuOpts {
                            cyclic,
                            prefetch,
                            slots,
                        },
                    )
                    .unwrap()
                };
                let m3 = price(&f, &mut mk(3), cyclic);
                let m2 = price(&f, &mut mk(2), cyclic);
                assert!(
                    m3.elapsed_s <= m2.elapsed_s * (1.0 + 1e-12),
                    "seed {seed} {link:?} cyclic={cyclic} prefetch={prefetch}: \
                     3 slots {} slower than 2 slots {}",
                    m3.elapsed_s,
                    m2.elapsed_s
                );
            }
        }
    }
}

#[test]
fn prop_raw_timeline_folds_are_deterministic() {
    // Random op sequences straight against the Timeline: same seed ⇒
    // bit-identical makespan; makespan ≥ per-resource critical path.
    for seed in 1..=50u64 {
        let build = || {
            let mut rng = Rng::new(seed);
            let mut tl = Timeline::new(false);
            let res: Vec<_> = (0..(2 + rng.below(4)))
                .map(|i| tl.resource(&format!("r{i}"), StreamClass::ALL[i as usize % 4]))
                .collect();
            let mut ends = vec![0.0f64];
            for _ in 0..(3 + rng.below(40)) {
                let r = res[rng.below(res.len() as u64) as usize];
                match rng.below(4) {
                    0 => {
                        let a = res[rng.below(res.len() as u64) as usize];
                        tl.wait(a, r);
                    }
                    1 => {
                        let t = ends[rng.below(ends.len() as u64) as usize];
                        tl.wait_until(r, t);
                    }
                    _ => {
                        let end = tl.push(
                            r,
                            EventKind::Compute,
                            "",
                            rng.f64() * 1e-3,
                            rng.below(1 << 20),
                        );
                        ends.push(end);
                    }
                }
            }
            tl
        };
        let a = build();
        let b = build();
        assert!(a.makespan().to_bits() == b.makespan().to_bits(), "seed {seed}");
        assert!(a.makespan() >= 0.0);
        // Fold into a metrics sink (the public absorption path) and
        // check the per-resource critical-path bound there.
        let makespan = a.makespan();
        let mut m = Metrics::new();
        m.absorb_timeline(a);
        assert!(m.elapsed_s.to_bits() == makespan.to_bits());
        for (name, st) in &m.per_resource {
            assert!(
                st.busy_s <= makespan + 1e-15,
                "seed {seed}: resource {name} busy {} exceeds makespan {makespan}",
                st.busy_s
            );
        }
    }
}
