//! Sharded execution is a pure re-scheduler: running an application
//! across N modelled ranks (1D and 2D decompositions, any inner engine)
//! must produce **bit-for-bit** the same numerics as single-device
//! untiled execution — the same bar the tiling layer is held to.
//!
//! Also checks the modelled-time side: per-rank metrics are populated,
//! halo exchanges are counted, sharding yields strong-scaling speedup,
//! and comm/compute overlap beats the no-overlap ablation.

#![allow(deprecated)] // exercises the legacy OpsContext shim on purpose

use ops_oc::apps::cloverleaf2d::CloverLeaf2D;
use ops_oc::apps::diffusion::Diffusion2D;
use ops_oc::coordinator::{Config, InnerPlatform, Platform};
use ops_oc::distributed::{DecompKind, Interconnect};
use ops_oc::memory::{AppCalib, Link};
use ops_oc::ops::OpsContext;

fn sharded(ranks: u32, decomp: DecompKind, overlap: bool) -> Platform {
    Platform::Sharded {
        ranks,
        inner: InnerPlatform::GpuExplicit {
            link: Link::NvLink,
            cyclic: true,
            prefetch: true,
        },
        link: Interconnect::NvLink,
        decomp,
        overlap,
    }
}

fn sharded_knl(ranks: u32, decomp: DecompKind) -> Platform {
    Platform::Sharded {
        ranks,
        inner: InnerPlatform::KnlCacheTiled,
        link: Interconnect::InfiniBand,
        decomp,
        overlap: true,
    }
}

fn run_cl2d(p: Platform) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut ctx = OpsContext::new(Config::new(p, AppCalib::CLOVERLEAF_2D).build_engine());
    let mut app = CloverLeaf2D::new(&mut ctx, 20, 20, 1);
    app.run(&mut ctx, 3, 0);
    (
        ctx.fetch(app.density0),
        ctx.fetch(app.energy0),
        ctx.fetch(app.xvel0),
    )
}

#[test]
fn cloverleaf2d_sharded_matches_untiled_bitexact() {
    let reference = run_cl2d(Platform::KnlFlatDdr4);
    for decomp in [DecompKind::OneD, DecompKind::TwoD] {
        for ranks in [2u32, 4] {
            let got = run_cl2d(sharded(ranks, decomp, true));
            assert_eq!(reference.0, got.0, "density0 x{ranks} {}", decomp.label());
            assert_eq!(reference.1, got.1, "energy0 x{ranks} {}", decomp.label());
            assert_eq!(reference.2, got.2, "xvel0 x{ranks} {}", decomp.label());
        }
    }
    // a different inner engine must not change numerics either
    let knl = run_cl2d(sharded_knl(4, DecompKind::TwoD));
    assert_eq!(reference.0, knl.0, "density0 sharded KNL");
}

fn run_diffusion(p: Platform) -> Vec<f64> {
    let mut ctx = OpsContext::new(Config::new(p, AppCalib::CLOVERLEAF_2D).build_engine());
    let app = Diffusion2D::new(&mut ctx, 48, 48, 1);
    app.run(&mut ctx, 8, 2);
    ctx.fetch(app.u)
}

#[test]
fn diffusion_sharded_matches_untiled_bitexact() {
    let reference = run_diffusion(Platform::KnlFlatDdr4);
    for decomp in [DecompKind::OneD, DecompKind::TwoD] {
        for ranks in [2u32, 4] {
            let got = run_diffusion(sharded(ranks, decomp, true));
            assert_eq!(reference, got, "u x{ranks} {}", decomp.label());
            let knl = run_diffusion(sharded_knl(ranks, decomp));
            assert_eq!(reference, knl, "u x{ranks} {} (KNL inner)", decomp.label());
        }
    }
}

#[test]
fn no_overlap_ablation_keeps_numerics() {
    let with = run_diffusion(sharded(4, DecompKind::OneD, true));
    let without = run_diffusion(sharded(4, DecompKind::OneD, false));
    assert_eq!(with, without);
}

/// Auto-tuned sharded execution stays bit-exact too: whatever per-rank
/// candidate the tuner picks, the decomposed numerics must equal the
/// single-device untiled reference.
#[test]
fn tuned_sharded_matches_untiled_bitexact() {
    use ops_oc::tuner::TuneOpts;
    let tune = TuneOpts {
        budget: 10,
        seed: 0x5A,
    };
    let run_tuned = |p: Platform| {
        let cfg = Config::new(p, AppCalib::CLOVERLEAF_2D)
            .with_tuning(tune)
            .unwrap();
        let mut ctx = OpsContext::new(cfg.build_engine());
        let mut app = CloverLeaf2D::new(&mut ctx, 20, 20, 1);
        app.run(&mut ctx, 3, 0);
        let out = (
            ctx.fetch(app.density0),
            ctx.fetch(app.energy0),
            ctx.fetch(app.xvel0),
        );
        (out, ctx.metrics().clone())
    };
    let reference = run_cl2d(Platform::KnlFlatDdr4);
    for decomp in [DecompKind::OneD, DecompKind::TwoD] {
        for ranks in [2u32, 4] {
            let (got, m) = run_tuned(sharded(ranks, decomp, true));
            assert_eq!(reference.0, got.0, "density0 tuned x{ranks} {}", decomp.label());
            assert_eq!(reference.1, got.1, "energy0 tuned x{ranks} {}", decomp.label());
            assert_eq!(reference.2, got.2, "xvel0 tuned x{ranks} {}", decomp.label());
            assert!(
                m.tuned_model_s <= m.heuristic_model_s,
                "never-worse must hold under sharding"
            );
        }
    }
    let (knl, _) = run_tuned(sharded_knl(4, DecompKind::TwoD));
    assert_eq!(reference.0, knl.0, "density0 tuned sharded KNL");
}

/// The acceptance-criterion cell: CloverLeaf 2D at a modelled 48 GB on
/// 4 explicitly-streamed NVLink GPUs completes and reports per-rank and
/// aggregate metrics.
#[test]
fn cl2d_48gb_x4_reports_per_rank_metrics() {
    let p = Config::parse_platform("gpu-explicit:nvlink:cyclic:x4").unwrap();
    assert_eq!(p.ranks(), 4);
    let (m, oom) = ops_oc::bench_support::run_cl2d(p, 8, 6144, 48.0, 2, 0);
    assert!(!oom, "explicit streaming must fit 48 GB sharded");
    assert_eq!(m.per_rank.len(), 4);
    for (r, rs) in m.per_rank.iter().enumerate() {
        assert!(rs.compute_s > 0.0, "rank {r} compute time");
        assert!(rs.loop_bytes > 0, "rank {r} loop bytes");
        assert!(rs.average_bandwidth_gbs() > 0.0, "rank {r} avg bw");
        assert!(rs.exchange_bytes > 0, "rank {r} exchange bytes");
    }
    // aggregate weighted Average Bandwidth (§5.1) is well defined…
    assert!(m.average_bandwidth_gbs() > 0.0);
    // …and halo exchanges were injected into the clock.
    assert!(m.halo_exchanges > 0);
    assert!(m.halo_time_s > 0.0);
    assert!(m.elapsed_s > 0.0);
}

#[test]
fn sharding_shows_strong_scaling_and_overlap_gain() {
    let run = |p: Platform| ops_oc::bench_support::run_cl2d(p, 8, 6144, 48.0, 2, 0).0;
    let m1 = run(sharded(1, DecompKind::OneD, true));
    let m4 = run(sharded(4, DecompKind::OneD, true));
    let m4_no = run(sharded(4, DecompKind::OneD, false));
    assert!(
        m4.elapsed_s < m1.elapsed_s,
        "strong scaling: x4 {} !< x1 {}",
        m4.elapsed_s,
        m1.elapsed_s
    );
    assert!(
        m4.elapsed_s < m4_no.elapsed_s,
        "overlap must beat the ablation: {} !< {}",
        m4.elapsed_s,
        m4_no.elapsed_s
    );
}

#[test]
fn opensbli_and_cl3d_run_sharded() {
    // the remaining two apps complete under sharding (numerics parity for
    // OpenSBLI/CL3D is covered by the cross-engine equivalence suite at
    // rank granularity; here we assert the sharded path executes them)
    let p = sharded(2, DecompKind::OneD, true);
    let (m, oom) = ops_oc::bench_support::run_sbli_tall(p, 1, 24.0, 1);
    assert!(!oom);
    assert_eq!(m.per_rank.len(), 2);
    let (m3, oom3) = ops_oc::bench_support::run_cl3d(p, [8, 8, 512], 24.0, 1, 0);
    assert!(!oom3);
    assert_eq!(m3.per_rank.len(), 2);
}
