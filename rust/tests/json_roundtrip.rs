//! Schema-stability test for the `--json` metrics record.
//!
//! Downstream sweep tooling (BENCH_*.json trajectories, plotting
//! scripts) parses these records; this test serialises a record, parses
//! it back with a strict flat-JSON parser (the crate is dependency-free,
//! so the parser lives here), and pins the exact key set and value
//! types — including the tuner fields — so the schema cannot drift
//! silently.

use ops_oc::coordinator::json_record;
use ops_oc::exec::Metrics;
use ops_oc::topology::Topology;
use std::collections::BTreeMap;

/// The topology most records in this suite report against.
fn topo() -> Topology {
    ops_oc::topology::preset("knl").unwrap()
}

/// A flat JSON value: the record never nests.
#[derive(Debug, Clone, PartialEq)]
enum Val {
    Str(String),
    Num(f64),
    Bool(bool),
}

/// Strict parser for one flat JSON object: `{"k":v,...}` with string,
/// number and boolean values. Panics (failing the test) on anything
/// malformed — that *is* the assertion.
fn parse_flat(s: &str) -> BTreeMap<String, Val> {
    let mut out = BTreeMap::new();
    let b: Vec<char> = s.chars().collect();
    let mut i = 0usize;
    let eat = |b: &[char], i: &mut usize, c: char| {
        assert_eq!(b.get(*i), Some(&c), "expected {c:?} at {i}: {s}");
        *i += 1;
    };
    let parse_string = |b: &[char], i: &mut usize| -> String {
        assert_eq!(b[*i], '"');
        *i += 1;
        let mut out = String::new();
        while b[*i] != '"' {
            if b[*i] == '\\' {
                *i += 1;
            }
            out.push(b[*i]);
            *i += 1;
        }
        *i += 1;
        out
    };
    eat(&b, &mut i, '{');
    loop {
        let key = parse_string(&b, &mut i);
        eat(&b, &mut i, ':');
        let val = match b[i] {
            '"' => Val::Str(parse_string(&b, &mut i)),
            't' => {
                i += 4;
                Val::Bool(true)
            }
            'f' => {
                i += 5;
                Val::Bool(false)
            }
            _ => {
                let start = i;
                while matches!(b[i], '0'..='9' | '-' | '+' | '.' | 'e' | 'E') {
                    i += 1;
                }
                let txt: String = b[start..i].iter().collect();
                Val::Num(txt.parse().unwrap_or_else(|_| panic!("bad number {txt:?}")))
            }
        };
        assert!(
            out.insert(key.clone(), val).is_none(),
            "duplicate key {key:?}"
        );
        match b[i] {
            ',' => i += 1,
            '}' => {
                i += 1;
                break;
            }
            c => panic!("unexpected {c:?} at {i}"),
        }
    }
    assert_eq!(i, b.len(), "trailing garbage");
    out
}

/// The pinned schema: every key the record must carry, with its type.
const SCHEMA: &[(&str, &str)] = &[
    ("app", "str"),
    ("platform", "str"),
    ("topology", "str"),
    ("ranks", "num"),
    ("size_gb", "num"),
    ("oom", "bool"),
    ("runtime_s", "num"),
    ("avg_bandwidth_gbs", "num"),
    ("eff_bandwidth_gbs", "num"),
    ("halo_time_s", "num"),
    ("tiles", "num"),
    ("bound", "str"),
    ("util_compute", "num"),
    ("util_upload", "num"),
    ("util_download", "num"),
    ("util_exchange", "num"),
    ("util_codec", "num"),
    ("codec_bytes_saved", "num"),
    ("tuned", "bool"),
    ("tune_evals", "num"),
    ("tune_cache_hits", "num"),
    ("tuned_model_s", "num"),
    ("heuristic_model_s", "num"),
    ("tune_model_speedup", "num"),
    ("analysis_builds", "num"),
    ("analysis_reuse_hits", "num"),
    ("fused_steps", "num"),
    ("exec_backend", "str"),
    ("kir_kernels_compiled", "num"),
    ("kir_fallback_loops", "num"),
    ("program_freeze_s", "num"),
    ("spans_recorded", "num"),
    ("span_max_depth", "num"),
];

fn assert_schema(rec: &BTreeMap<String, Val>) {
    for (key, ty) in SCHEMA {
        let v = rec
            .get(*key)
            .unwrap_or_else(|| panic!("missing key {key:?}"));
        let got = match v {
            Val::Str(_) => "str",
            Val::Num(_) => "num",
            Val::Bool(_) => "bool",
        };
        assert_eq!(&got, ty, "key {key:?}");
    }
    // Beyond the fixed keys, only these dynamic families are allowed:
    // * `util_tier_*` — per-tier utilisation of multi-tier topologies,
    //   numeric, in [0, 1];
    // * `p50_*` / `p90_*` / `p99_*` — obs-registry histogram quantiles,
    //   numeric, >= 0;
    // * `roofline_*` — per-stream roofline rows (peak/achieved GB/s and
    //   fraction of peak), numeric, >= 0.
    for (key, v) in rec {
        if SCHEMA.iter().any(|(k, _)| k == key) {
            continue;
        }
        let quantile = ["p50_", "p90_", "p99_"].iter().any(|p| key.starts_with(p));
        let roofline = key.starts_with("roofline_");
        let tier = key.starts_with("util_tier_");
        assert!(
            tier || quantile || roofline,
            "unexpected extra key {key:?}: {:?}",
            rec.keys().collect::<Vec<_>>()
        );
        match v {
            Val::Num(u) if tier => {
                assert!((0.0..=1.0 + 1e-9).contains(u), "{key} = {u}")
            }
            Val::Num(u) => assert!(*u >= 0.0, "{key} = {u}"),
            v => panic!("{key}: {v:?}"),
        }
    }
}

#[test]
fn json_record_roundtrips_and_schema_is_stable() {
    let mut m = Metrics::new();
    m.record_loop("k", 2_000_000_000, 0.01);
    m.elapsed_s = 0.04;
    m.halo_time_s = 0.001;
    m.tiles = 12;
    let rec = parse_flat(&json_record(
        "cloverleaf2d",
        "KNL cache tiled",
        1,
        24.0,
        &topo(),
        &m,
        false,
    ));
    assert_schema(&rec);
    assert_eq!(rec["topology"], Val::Str("tiers:knl".into()));
    assert_eq!(rec["bound"], Val::Str("idle".into()));
    // record_loop feeds the obs registry: the loop-time quantiles ride
    // along under the pinned p50_/p99_ prefixes
    assert!(rec.contains_key("p50_loop_time_s"), "{:?}", rec.keys());
    assert!(rec.contains_key("p99_loop_time_s"), "{:?}", rec.keys());
    assert_eq!(rec["spans_recorded"], Val::Num(0.0));
    assert_eq!(rec["util_compute"], Val::Num(0.0));
    assert_eq!(rec["app"], Val::Str("cloverleaf2d".into()));
    assert_eq!(rec["ranks"], Val::Num(1.0));
    assert_eq!(rec["oom"], Val::Bool(false));
    assert_eq!(rec["tiles"], Val::Num(12.0));
    assert_eq!(rec["tuned"], Val::Bool(false));
    assert_eq!(rec["tune_model_speedup"], Val::Num(1.0));
    assert_eq!(rec["analysis_builds"], Val::Num(0.0));
    assert_eq!(rec["analysis_reuse_hits"], Val::Num(0.0));
    assert_eq!(rec["fused_steps"], Val::Num(0.0));
    match &rec["avg_bandwidth_gbs"] {
        Val::Num(v) => assert!((v - 200.0).abs() < 1e-9),
        v => panic!("{v:?}"),
    }
}

#[test]
fn json_record_tuner_fields_roundtrip() {
    let mut m = Metrics::new();
    m.record_loop("k", 1_000_000_000, 0.01);
    m.elapsed_s = 0.02;
    m.tune_evals = 48;
    m.tune_cache_hits = 7;
    m.tuned_model_s = 0.5;
    m.heuristic_model_s = 0.75;
    let rec = parse_flat(&json_record(
        "opensbli",
        "auto-tuned [GPU explicit]",
        4,
        48.0,
        &topo(),
        &m,
        false,
    ));
    assert_schema(&rec);
    assert_eq!(rec["tuned"], Val::Bool(true));
    assert_eq!(rec["tune_evals"], Val::Num(48.0));
    assert_eq!(rec["tune_cache_hits"], Val::Num(7.0));
    assert_eq!(rec["tune_model_speedup"], Val::Num(1.5));
    assert_eq!(rec["ranks"], Val::Num(4.0));
}

#[test]
fn json_record_escaping_survives_the_roundtrip() {
    let m = Metrics::new();
    let rec = parse_flat(&json_record("we\"ird\\app", "p", 1, 6.0, &topo(), &m, true));
    assert_eq!(rec["app"], Val::Str("we\"ird\\app".into()));
    assert_eq!(rec["oom"], Val::Bool(true));
}

#[test]
fn real_run_produces_a_parseable_record() {
    use ops_oc::bench_support::run_cl2d_tuned;
    use ops_oc::coordinator::Config;
    use ops_oc::tuner::TuneOpts;
    let (t, tuned) = Config::parse_spec("gpu-explicit:pcie:cyclic:tuned").unwrap();
    assert!(tuned);
    let p = t.platform().unwrap();
    let (m, oom) = run_cl2d_tuned(
        p,
        Some(TuneOpts {
            budget: 8,
            seed: 0x10,
        }),
        8,
        256,
        0.01,
        1,
        0,
    );
    let cfg = Config::new(p, ops_oc::memory::AppCalib::CLOVERLEAF_2D);
    let rec = parse_flat(&json_record(
        "cloverleaf2d",
        &p.label(),
        p.ranks(),
        0.01,
        &cfg.topology(),
        &m,
        oom,
    ));
    assert_schema(&rec);
    assert_eq!(
        rec["topology"],
        Val::Str("tiers:gpu-explicit-pcie".into()),
        "legacy platforms report their preset topology"
    );
    assert_eq!(rec["tuned"], Val::Bool(true));
    match &rec["tune_model_speedup"] {
        Val::Num(v) => assert!(*v >= 1.0 - 1e-12, "never-worse guarantee: {v}"),
        v => panic!("{v:?}"),
    }
    // the cell ran through the timeline scheduler: attribution names a
    // real stream and utilisations are sane fractions of wall time
    match &rec["bound"] {
        Val::Str(b) => assert!(
            ["compute", "upload", "download", "exchange"].contains(&b.as_str()),
            "bound {b:?}"
        ),
        v => panic!("{v:?}"),
    }
    for key in ["util_compute", "util_upload", "util_download", "util_exchange"] {
        match &rec[key] {
            Val::Num(u) => assert!((0.0..=1.0 + 1e-9).contains(u), "{key} = {u}"),
            v => panic!("{v:?}"),
        }
    }
    match &rec["util_upload"] {
        // an explicit-streaming cell at this size moves real traffic
        Val::Num(u) => assert!(*u > 0.0, "upload stream must be attributed"),
        v => panic!("{v:?}"),
    }
    // the cell ran on the Program/Session path: chain analyses were
    // built once per shape and reused thereafter
    match (&rec["analysis_builds"], &rec["analysis_reuse_hits"]) {
        (Val::Num(b), Val::Num(h)) => {
            assert!(*b >= 1.0, "at least one analysis built: {b}");
            assert!(*h + *b >= *b, "counters parse: {b}/{h}");
        }
        v => panic!("{v:?}"),
    }
    match &rec["program_freeze_s"] {
        Val::Num(v) => assert!(*v >= 0.0),
        v => panic!("{v:?}"),
    }
    // the cell ran with the span tracer on: lifecycle spans were
    // recorded and roofline rows cover the streams that ran
    match &rec["spans_recorded"] {
        Val::Num(n) => assert!(*n >= 1.0, "spans must be recorded: {n}"),
        v => panic!("{v:?}"),
    }
    assert!(
        rec.keys().any(|k| k.starts_with("roofline_")),
        "roofline rows must appear for a streamed run: {:?}",
        rec.keys().collect::<Vec<_>>()
    );
    match rec.get("roofline_upload_achieved_gbs") {
        Some(Val::Num(g)) => assert!(*g > 0.0, "upload stream moved bytes"),
        v => panic!("roofline_upload_achieved_gbs: {v:?}"),
    }
}

#[test]
fn codec_run_reports_savings_and_codec_utilisation() {
    use ops_oc::bench_support::run_cl2d_cfg;
    use ops_oc::coordinator::Config;
    use ops_oc::memory::AppCalib;

    // Same three-tier shape as above, with a 3.5:1 codec on the nvme
    // link: the record must carry the codec fields and show savings.
    let (t, _) = Config::parse_spec(
        "tiers:hbm=64k@509.7+host=256k@11~0.00001+nvme=inf@6~0.00002~c:3.5:cyclic",
    )
    .unwrap();
    let cfg = Config::for_target(t, AppCalib::CLOVERLEAF_2D);
    let (m, oom) = run_cl2d_cfg(&cfg, false, 8, 256, 0.01, 1, 0);
    assert!(!oom);
    assert!(m.codec_bytes_saved > 0, "codec must shrink wire traffic");
    let rec = parse_flat(&json_record(
        "cloverleaf2d",
        &cfg.label(),
        cfg.ranks(),
        0.01,
        &cfg.topology(),
        &m,
        oom,
    ));
    assert_schema(&rec);
    match &rec["topology"] {
        Val::Str(s) => assert!(s.contains("~c:3.5"), "{s}"),
        v => panic!("{v:?}"),
    }
    match &rec["codec_bytes_saved"] {
        Val::Num(v) => assert!(*v > 0.0),
        v => panic!("{v:?}"),
    }
    match &rec["util_codec"] {
        Val::Num(u) => assert!(*u > 0.0, "codec stream must be attributed"),
        v => panic!("{v:?}"),
    }
    match rec.get("util_tier_host_codec") {
        Some(Val::Num(u)) => assert!(*u > 0.0, "per-tier codec utilisation"),
        v => panic!("util_tier_host_codec: {v:?}"),
    }
}

/// The pinned fleet-report schema (`ops-oc fleet --json`): every fixed
/// key with its type. Per-target fields are a dynamic family covered by
/// the prefix rule in [`assert_fleet_schema`].
const FLEET_SCHEMA: &[(&str, &str)] = &[
    ("fleet_spec", "str"),
    ("policy", "str"),
    ("fleet_targets", "num"),
    ("fleet_requests", "num"),
    ("fleet_completed", "num"),
    ("fleet_distinct_fingerprints", "num"),
    ("fleet_programs_built", "num"),
    ("fleet_failovers", "num"),
    ("fleet_retired", "num"),
    ("fleet_added", "num"),
    ("fleet_makespan_s", "num"),
    ("fleet_throughput_rps", "num"),
    ("p50_latency_s", "num"),
    ("p99_latency_s", "num"),
    ("mean_latency_s", "num"),
    ("fleet_analysis_builds", "num"),
    ("fleet_analysis_reuse_hits", "num"),
    ("fleet_tune_evals", "num"),
    ("fleet_tune_cache_hits", "num"),
    ("fleet_program_freeze_s", "num"),
    ("oom", "bool"),
];

fn assert_fleet_schema(rec: &BTreeMap<String, Val>) {
    for (key, ty) in FLEET_SCHEMA {
        let v = rec
            .get(*key)
            .unwrap_or_else(|| panic!("missing fleet key {key:?}"));
        let got = match v {
            Val::Str(_) => "str",
            Val::Num(_) => "num",
            Val::Bool(_) => "bool",
        };
        assert_eq!(&got, ty, "fleet key {key:?}");
    }
    // Beyond the fixed keys, only the per-target family is allowed:
    // `fleet_target_<i>_*`, where spec/bound/state are strings and
    // every other member (requests, util) is a non-negative number.
    for (key, v) in rec {
        if FLEET_SCHEMA.iter().any(|(k, _)| k == key) {
            continue;
        }
        assert!(
            key.starts_with("fleet_target_"),
            "unexpected extra fleet key {key:?}: {:?}",
            rec.keys().collect::<Vec<_>>()
        );
        let stringy = ["_spec", "_bound", "_state"].iter().any(|s| key.ends_with(s));
        match v {
            Val::Str(s) if stringy => {
                if key.ends_with("_state") {
                    assert!(
                        ["live", "degraded", "retired"].contains(&s.as_str()),
                        "{key} = {s:?}"
                    );
                }
            }
            Val::Num(u) if !stringy => assert!(*u >= 0.0, "{key} = {u}"),
            v => panic!("{key}: {v:?}"),
        }
    }
}

#[test]
fn fleet_report_roundtrips_and_schema_is_stable() {
    use ops_oc::fleet::{fleet_json, serve, Cluster, FleetOpts, Workload};
    let cluster = Cluster::parse("fleet:gpu-explicit:pcie:cyclic*2").unwrap();
    let w = Workload::parse("tenants=3,reqs=1,sizes=0.005,steps=4,seed=6").unwrap();
    let run = serve(&cluster, &w, &FleetOpts::default()).unwrap();
    let rec = parse_flat(&fleet_json(&run));
    assert_fleet_schema(&rec);
    assert_eq!(rec["fleet_requests"], Val::Num(3.0));
    assert_eq!(rec["fleet_completed"], Val::Num(3.0));
    assert_eq!(rec["fleet_targets"], Val::Num(2.0));
    assert_eq!(rec["policy"], Val::Str("first-fit".into()));
    assert_eq!(rec["fleet_distinct_fingerprints"], Val::Num(1.0));
    assert_eq!(rec["fleet_programs_built"], Val::Num(1.0));
    assert_eq!(rec["oom"], Val::Bool(false));
    assert_eq!(
        rec["fleet_spec"],
        Val::Str("fleet:gpu-explicit:pcie:cyclic,gpu-explicit:pcie:cyclic".into())
    );
    // quantiles are histogram upper bounds over a real latency series
    match (&rec["p50_latency_s"], &rec["p99_latency_s"]) {
        (Val::Num(p50), Val::Num(p99)) => {
            assert!(*p50 > 0.0 && p99 >= p50, "p50={p50} p99={p99}")
        }
        v => panic!("{v:?}"),
    }
    // both per-target families are present and well-typed
    for i in 0..2 {
        assert!(rec.contains_key(&format!("fleet_target_{i}_util")));
        assert_eq!(
            rec[&format!("fleet_target_{i}_state")],
            Val::Str("live".into())
        );
    }
}

#[test]
fn three_tier_run_reports_topology_and_per_tier_utilisation() {
    use ops_oc::bench_support::run_cl2d_cfg;
    use ops_oc::coordinator::Config;
    use ops_oc::memory::AppCalib;

    // hbm and host both far smaller than the 0.01 GB modelled problem:
    // the run streams through BOTH boundaries and must not OOM (the
    // unbounded nvme home tier holds the data).
    let (t, _) = Config::parse_spec(
        "tiers:hbm=64k@509.7+host=256k@11~0.00001+nvme=inf@6~0.00002:cyclic",
    )
    .unwrap();
    let cfg = Config::for_target(t, AppCalib::CLOVERLEAF_2D);
    let (m, oom) = run_cl2d_cfg(&cfg, false, 8, 256, 0.01, 1, 0);
    assert!(!oom, "three-tier streaming must model past the host tier");
    let rec = parse_flat(&json_record(
        "cloverleaf2d",
        &cfg.label(),
        cfg.ranks(),
        0.01,
        &cfg.topology(),
        &m,
        oom,
    ));
    assert_schema(&rec);
    match &rec["topology"] {
        Val::Str(s) => assert!(s.starts_with("tiers:hbm=64k@509.7"), "{s}"),
        v => panic!("{v:?}"),
    }
    // per-tier utilisation fields for both streamed boundaries
    for key in ["util_tier_hbm_upload", "util_tier_host_upload"] {
        match rec.get(key) {
            Some(Val::Num(u)) => assert!(*u > 0.0, "{key} must show traffic"),
            v => panic!("{key}: {v:?} (keys: {:?})", rec.keys().collect::<Vec<_>>()),
        }
    }
}
