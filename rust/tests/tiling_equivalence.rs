//! Cross-engine application-level equivalence: every memory engine is a
//! pure re-scheduler — all three paper applications must produce
//! identical numerics on every platform configuration, and the §4.1
//! optimisation toggles must change *transfers*, never *results*.

#![allow(deprecated)] // exercises the legacy OpsContext shim on purpose

use ops_oc::apps::cloverleaf2d::CloverLeaf2D;
use ops_oc::apps::cloverleaf3d::CloverLeaf3D;
use ops_oc::apps::opensbli::OpenSbli;
use ops_oc::coordinator::{Config, Platform, TieredTarget};
use ops_oc::memory::{AppCalib, GpuOpts, Link};
use ops_oc::ops::OpsContext;

fn all_platforms() -> Vec<Platform> {
    let mut v = vec![
        Platform::KnlFlatDdr4,
        Platform::KnlFlatMcdram,
        Platform::KnlCache,
        Platform::KnlCacheTiled,
    ];
    for link in [Link::PciE, Link::NvLink] {
        v.push(Platform::GpuBaseline { link });
        for cyclic in [false, true] {
            for prefetch in [false, true] {
                v.push(Platform::GpuExplicit {
                    link,
                    cyclic,
                    prefetch,
                });
            }
        }
        for tiled in [false, true] {
            for pf in [false, true] {
                v.push(Platform::GpuUnified {
                    link,
                    tiled,
                    prefetch: pf,
                });
            }
        }
    }
    v
}

#[test]
fn cloverleaf2d_identical_on_all_platforms() {
    let reference: Option<Vec<f64>> = None;
    let mut reference = reference;
    for p in all_platforms() {
        let mut ctx = OpsContext::new(Config::new(p, AppCalib::CLOVERLEAF_2D).build_engine());
        let mut app = CloverLeaf2D::new(&mut ctx, 16, 16, 1);
        app.run(&mut ctx, 3, 2);
        let d = ctx.fetch(app.density0);
        match &reference {
            None => reference = Some(d),
            Some(r) => assert_eq!(r, &d, "density0 differs on {}", p.label()),
        }
    }
}

#[test]
fn cloverleaf3d_identical_on_key_platforms() {
    let platforms = [
        Platform::KnlFlatDdr4,
        Platform::KnlCacheTiled,
        Platform::GpuExplicit {
            link: Link::PciE,
            cyclic: true,
            prefetch: true,
        },
        Platform::GpuUnified {
            link: Link::NvLink,
            tiled: true,
            prefetch: true,
        },
    ];
    let mut reference: Option<Vec<f64>> = None;
    for p in platforms {
        let mut ctx = OpsContext::new(Config::new(p, AppCalib::CLOVERLEAF_3D).build_engine());
        let mut app = CloverLeaf3D::new(&mut ctx, 8, 8, 8, 1);
        app.run(&mut ctx, 2, 0);
        let d = ctx.fetch(app.energy0);
        match &reference {
            None => reference = Some(d),
            Some(r) => assert_eq!(r, &d, "energy0 differs on {}", p.label()),
        }
    }
}

#[test]
fn opensbli_identical_on_key_platforms() {
    let platforms = [
        Platform::KnlFlatDdr4,
        Platform::KnlCacheTiled,
        Platform::GpuExplicit {
            link: Link::NvLink,
            cyclic: true,
            prefetch: false,
        },
    ];
    let mut reference: Option<Vec<f64>> = None;
    for p in platforms {
        let mut ctx = OpsContext::new(Config::new(p, AppCalib::OPENSBLI).build_engine());
        let mut app = OpenSbli::new(&mut ctx, 16, 1, 1);
        app.run(&mut ctx, 2);
        let d = ctx.fetch(app.q[1]);
        match &reference {
            None => reference = Some(d),
            Some(r) => assert_eq!(r, &d, "rhou differs on {}", p.label()),
        }
    }
}

/// Auto-tuned plans are re-schedules too: on every tunable platform the
/// three apps must stay bit-exact against untiled execution, whatever
/// candidate the search picks.
#[test]
fn tuned_plans_stay_bitexact_on_all_apps() {
    use ops_oc::tuner::TuneOpts;
    let tune = TuneOpts {
        budget: 12,
        seed: 0xE0,
    };
    let tuned_specs = [
        "knl-cache-tiled:tuned",
        "gpu-explicit:pcie:cyclic:prefetch:tuned",
        "gpu-explicit:nvlink:tuned",
        "gpu-unified:pcie:tiled:prefetch:tuned",
        "tiers:gpu-explicit-pcie:cyclic:prefetch:tuned",
        "tiers:hbm=16g@509.7+host=48g@11~0.00001+nvme=inf@6~0.00002:tuned",
    ];
    // CloverLeaf 2D
    let reference = {
        let mut ctx = OpsContext::new(
            Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_2D).build_engine(),
        );
        let mut app = CloverLeaf2D::new(&mut ctx, 16, 16, 1);
        app.run(&mut ctx, 3, 2);
        ctx.fetch(app.density0)
    };
    for spec in tuned_specs {
        let (p, tuned) = Config::parse_spec(spec).unwrap();
        assert!(tuned, "{spec}");
        let cfg = Config::for_target(p, AppCalib::CLOVERLEAF_2D)
            .with_tuning(tune)
            .unwrap();
        let mut ctx = OpsContext::new(cfg.build_engine());
        let mut app = CloverLeaf2D::new(&mut ctx, 16, 16, 1);
        app.run(&mut ctx, 3, 2);
        assert_eq!(
            reference,
            ctx.fetch(app.density0),
            "cl2d density0 differs on tuned {spec}"
        );
    }
    // CloverLeaf 3D
    let reference = {
        let mut ctx = OpsContext::new(
            Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_3D).build_engine(),
        );
        let mut app = CloverLeaf3D::new(&mut ctx, 8, 8, 8, 1);
        app.run(&mut ctx, 2, 0);
        ctx.fetch(app.energy0)
    };
    for spec in ["knl-cache-tiled:tuned", "gpu-explicit:pcie:cyclic:tuned"] {
        let (p, _) = Config::parse_spec(spec).unwrap();
        let cfg = Config::for_target(p, AppCalib::CLOVERLEAF_3D)
            .with_tuning(tune)
            .unwrap();
        let mut ctx = OpsContext::new(cfg.build_engine());
        let mut app = CloverLeaf3D::new(&mut ctx, 8, 8, 8, 1);
        app.run(&mut ctx, 2, 0);
        assert_eq!(
            reference,
            ctx.fetch(app.energy0),
            "cl3d energy0 differs on tuned {spec}"
        );
    }
    // OpenSBLI
    let reference = {
        let mut ctx =
            OpsContext::new(Config::new(Platform::KnlFlatDdr4, AppCalib::OPENSBLI).build_engine());
        let mut app = OpenSbli::new(&mut ctx, 16, 1, 1);
        app.run(&mut ctx, 2);
        ctx.fetch(app.q[1])
    };
    for spec in ["knl-cache-tiled:tuned", "gpu-explicit:nvlink:cyclic:tuned"] {
        let (p, _) = Config::parse_spec(spec).unwrap();
        let cfg = Config::for_target(p, AppCalib::OPENSBLI).with_tuning(tune).unwrap();
        let mut ctx = OpsContext::new(cfg.build_engine());
        let mut app = OpenSbli::new(&mut ctx, 16, 1, 1);
        app.run(&mut ctx, 2);
        assert_eq!(
            reference,
            ctx.fetch(app.q[1]),
            "opensbli rhou differs on tuned {spec}"
        );
    }
}

/// Build the legacy `gpu-explicit` config and its tiered twin: the same
/// (shrunken) HBM, the same link and §4.1 toggles, the topology coming
/// from the compatibility mapping [`Platform::topology`] so the preset
/// name (and therefore the NVLink clock boost) rides along.
fn gpu_explicit_pair(link: Link, cyclic: bool, prefetch: bool, hbm: u64, app: AppCalib) -> (Config, Config) {
    let p = Platform::GpuExplicit {
        link,
        cyclic,
        prefetch,
    };
    let mut legacy = Config::new(p, app);
    legacy.gpu.hbm_bytes = hbm;
    let mut tiered = legacy.clone();
    let mut tt = TieredTarget::new(p.topology(&legacy.knl, &legacy.gpu));
    tt.opts = GpuOpts {
        cyclic,
        prefetch,
        slots: 3,
    };
    tiered.tiered = Some(tt);
    (legacy, tiered)
}

/// The acceptance pin: the `gpu-explicit` preset executed through the
/// generic `TieredEngine` is bit-exact — numerics *and* modelled clocks
/// — against the legacy engine, across links and §4.1 toggles, at the
/// application level.
#[test]
fn tiered_gpu_preset_matches_legacy_engine_bitexact_cl2d() {
    for link in [Link::PciE, Link::NvLink] {
        for cyclic in [false, true] {
            for prefetch in [false, true] {
                let (lc, tc) =
                    gpu_explicit_pair(link, cyclic, prefetch, 8 << 10, AppCalib::CLOVERLEAF_2D);
                let run = |cfg: &Config| {
                    let mut ctx = OpsContext::new(cfg.build_engine());
                    let mut app = CloverLeaf2D::new(&mut ctx, 16, 16, 1);
                    app.run(&mut ctx, 3, 2);
                    let m = ctx.metrics().clone();
                    (ctx.fetch(app.density0), m)
                };
                let (dl, ml) = run(&lc);
                let (dt, mt) = run(&tc);
                let tag = format!("{link:?} cyclic={cyclic} prefetch={prefetch}");
                assert_eq!(dl, dt, "numerics differ: {tag}");
                assert_eq!(ml.elapsed_s, mt.elapsed_s, "modelled clock differs: {tag}");
                assert_eq!(ml.tiles, mt.tiles, "{tag}");
                assert_eq!(ml.h2d_bytes, mt.h2d_bytes, "{tag}");
                assert_eq!(ml.d2h_bytes, mt.d2h_bytes, "{tag}");
                assert_eq!(ml.loop_time_s, mt.loop_time_s, "{tag}");
            }
        }
    }
}

#[test]
fn tiered_gpu_preset_matches_legacy_engine_bitexact_cl3d_and_sbli() {
    let (lc, tc) = gpu_explicit_pair(Link::NvLink, true, true, 16 << 10, AppCalib::CLOVERLEAF_3D);
    let run3d = |cfg: &Config| {
        let mut ctx = OpsContext::new(cfg.build_engine());
        let mut app = CloverLeaf3D::new(&mut ctx, 8, 8, 8, 1);
        app.run(&mut ctx, 2, 0);
        let m = ctx.metrics().clone();
        (ctx.fetch(app.energy0), m)
    };
    let (dl, ml) = run3d(&lc);
    let (dt, mt) = run3d(&tc);
    assert_eq!(dl, dt, "cl3d numerics");
    assert_eq!(ml.elapsed_s, mt.elapsed_s, "cl3d clock");
    assert_eq!(ml.tiles, mt.tiles);

    let (lc, tc) = gpu_explicit_pair(Link::PciE, true, false, 8 << 10, AppCalib::OPENSBLI);
    let run_sbli = |cfg: &Config| {
        let mut ctx = OpsContext::new(cfg.build_engine());
        let mut app = OpenSbli::new(&mut ctx, 16, 1, 1);
        app.run(&mut ctx, 2);
        let m = ctx.metrics().clone();
        (ctx.fetch(app.q[1]), m)
    };
    let (dl, ml) = run_sbli(&lc);
    let (dt, mt) = run_sbli(&tc);
    assert_eq!(dl, dt, "opensbli numerics");
    assert_eq!(ml.elapsed_s, mt.elapsed_s, "opensbli clock");
    assert_eq!(ml.h2d_bytes, mt.h2d_bytes);
}

/// A three-tier stack is still a pure re-scheduler: all three apps stay
/// bit-exact against the flat reference while streaming through two
/// capacity boundaries.
#[test]
fn three_tier_stack_preserves_numerics_on_all_apps() {
    // host small enough that the apps' main chains overflow it, so the
    // nvme boundary genuinely streams
    let (three, _) =
        Config::parse_spec("tiers:hbm=8k@509.7+host=16k@11~0.00001+nvme=inf@6~0.00002").unwrap();
    let three = Config::for_target(three, AppCalib::CLOVERLEAF_2D);
    // CloverLeaf 2D
    let reference = {
        let mut ctx = OpsContext::new(
            Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_2D).build_engine(),
        );
        let mut app = CloverLeaf2D::new(&mut ctx, 16, 16, 1);
        app.run(&mut ctx, 3, 2);
        ctx.fetch(app.density0)
    };
    {
        let mut ctx = OpsContext::new(three.build_engine());
        let mut app = CloverLeaf2D::new(&mut ctx, 16, 16, 1);
        app.run(&mut ctx, 3, 2);
        assert_eq!(reference, ctx.fetch(app.density0), "cl2d on three tiers");
        let m = ctx.metrics().clone();
        assert!(m.tiles > 0);
        assert!(
            m.per_resource.contains_key("hbm:upload")
                && m.per_resource.contains_key("host:upload"),
            "per-tier streams must be attributed: {:?}",
            m.per_resource.keys().collect::<Vec<_>>()
        );
    }
    // CloverLeaf 3D
    let reference = {
        let mut ctx = OpsContext::new(
            Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_3D).build_engine(),
        );
        let mut app = CloverLeaf3D::new(&mut ctx, 8, 8, 8, 1);
        app.run(&mut ctx, 2, 0);
        ctx.fetch(app.energy0)
    };
    {
        let mut ctx = OpsContext::new(three.build_engine());
        let mut app = CloverLeaf3D::new(&mut ctx, 8, 8, 8, 1);
        app.run(&mut ctx, 2, 0);
        assert_eq!(reference, ctx.fetch(app.energy0), "cl3d on three tiers");
    }
    // OpenSBLI
    let reference = {
        let mut ctx =
            OpsContext::new(Config::new(Platform::KnlFlatDdr4, AppCalib::OPENSBLI).build_engine());
        let mut app = OpenSbli::new(&mut ctx, 16, 1, 1);
        app.run(&mut ctx, 2);
        ctx.fetch(app.q[1])
    };
    {
        let mut ctx = OpsContext::new(three.build_engine());
        let mut app = OpenSbli::new(&mut ctx, 16, 1, 1);
        app.run(&mut ctx, 2);
        assert_eq!(reference, ctx.fetch(app.q[1]), "opensbli on three tiers");
    }
}

/// Sharded tiered targets (per-rank inner topologies) re-schedule too.
#[test]
fn sharded_tiered_stack_preserves_numerics() {
    let reference = {
        let mut ctx = OpsContext::new(
            Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_2D).build_engine(),
        );
        let mut app = CloverLeaf2D::new(&mut ctx, 16, 16, 1);
        app.run(&mut ctx, 2, 0);
        ctx.fetch(app.density0)
    };
    let (t, _) = Config::parse_spec("tiers:hbm=8k@509.7+host=inf@11~0.00001:x2:ib").unwrap();
    let cfg = Config::for_target(t, AppCalib::CLOVERLEAF_2D);
    let mut ctx = OpsContext::new(cfg.build_engine());
    let mut app = CloverLeaf2D::new(&mut ctx, 16, 16, 1);
    app.run(&mut ctx, 2, 0);
    assert_eq!(reference, ctx.fetch(app.density0), "sharded tiered numerics");
    assert!(ctx.metrics().per_rank.len() == 2);
}

#[test]
fn optimisation_toggles_change_traffic_not_results() {
    let run = |cyclic: bool, prefetch: bool| {
        let p = Platform::GpuExplicit {
            link: Link::PciE,
            cyclic,
            prefetch,
        };
        let mut ctx = OpsContext::new(Config::new(p, AppCalib::CLOVERLEAF_2D).build_engine());
        let mut app = CloverLeaf2D::new(&mut ctx, 16, 16, 1 << 14);
        app.run(&mut ctx, 3, 0);
        let m = ctx.metrics().clone();
        (ctx.fetch(app.density0), m)
    };
    let (d_base, m_base) = run(false, false);
    let (d_cyc, m_cyc) = run(true, false);
    let (d_all, m_all) = run(true, true);
    assert_eq!(d_base, d_cyc);
    assert_eq!(d_base, d_all);
    assert!(
        m_cyc.d2h_bytes < m_base.d2h_bytes,
        "Cyclic must cut downloads: {} !< {}",
        m_cyc.d2h_bytes,
        m_base.d2h_bytes
    );
    assert!(
        m_all.elapsed_s <= m_cyc.elapsed_s + 1e-12,
        "Prefetch must not slow things down"
    );
}

#[test]
fn oversubscribed_platforms_report_oom_where_paper_segfaults() {
    // model scale pushes the 16x16 problem to ~26 GB modelled
    let scale = 1 << 22;
    for (p, should_fit) in [
        (Platform::KnlFlatMcdram, false),
        (Platform::KnlFlatDdr4, true),
        (Platform::KnlCacheTiled, true),
        (Platform::GpuBaseline { link: Link::PciE }, false),
        (
            Platform::GpuExplicit {
                link: Link::PciE,
                cyclic: true,
                prefetch: true,
            },
            true,
        ),
    ] {
        let mut ctx = OpsContext::new(Config::new(p, AppCalib::CLOVERLEAF_2D).build_engine());
        let mut app = CloverLeaf2D::new(&mut ctx, 16, 16, scale);
        app.run(&mut ctx, 1, 0);
        assert_eq!(
            !ctx.oom(),
            should_fit,
            "{}: oom={} problem={:.1} GB",
            p.label(),
            ctx.oom(),
            ctx.problem_bytes() as f64 / 1e9
        );
    }
}
