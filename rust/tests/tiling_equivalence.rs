//! Cross-engine application-level equivalence: every memory engine is a
//! pure re-scheduler — all three paper applications must produce
//! identical numerics on every platform configuration, and the §4.1
//! optimisation toggles must change *transfers*, never *results*.

#![allow(deprecated)] // exercises the legacy OpsContext shim on purpose

use ops_oc::apps::cloverleaf2d::CloverLeaf2D;
use ops_oc::apps::cloverleaf3d::CloverLeaf3D;
use ops_oc::apps::opensbli::OpenSbli;
use ops_oc::coordinator::{Config, Platform};
use ops_oc::memory::{AppCalib, Link};
use ops_oc::ops::OpsContext;

fn all_platforms() -> Vec<Platform> {
    let mut v = vec![
        Platform::KnlFlatDdr4,
        Platform::KnlFlatMcdram,
        Platform::KnlCache,
        Platform::KnlCacheTiled,
    ];
    for link in [Link::PciE, Link::NvLink] {
        v.push(Platform::GpuBaseline { link });
        for cyclic in [false, true] {
            for prefetch in [false, true] {
                v.push(Platform::GpuExplicit {
                    link,
                    cyclic,
                    prefetch,
                });
            }
        }
        for tiled in [false, true] {
            for pf in [false, true] {
                v.push(Platform::GpuUnified {
                    link,
                    tiled,
                    prefetch: pf,
                });
            }
        }
    }
    v
}

#[test]
fn cloverleaf2d_identical_on_all_platforms() {
    let reference: Option<Vec<f64>> = None;
    let mut reference = reference;
    for p in all_platforms() {
        let mut ctx = OpsContext::new(Config::new(p, AppCalib::CLOVERLEAF_2D).build_engine());
        let mut app = CloverLeaf2D::new(&mut ctx, 16, 16, 1);
        app.run(&mut ctx, 3, 2);
        let d = ctx.fetch(app.density0);
        match &reference {
            None => reference = Some(d),
            Some(r) => assert_eq!(r, &d, "density0 differs on {}", p.label()),
        }
    }
}

#[test]
fn cloverleaf3d_identical_on_key_platforms() {
    let platforms = [
        Platform::KnlFlatDdr4,
        Platform::KnlCacheTiled,
        Platform::GpuExplicit {
            link: Link::PciE,
            cyclic: true,
            prefetch: true,
        },
        Platform::GpuUnified {
            link: Link::NvLink,
            tiled: true,
            prefetch: true,
        },
    ];
    let mut reference: Option<Vec<f64>> = None;
    for p in platforms {
        let mut ctx = OpsContext::new(Config::new(p, AppCalib::CLOVERLEAF_3D).build_engine());
        let mut app = CloverLeaf3D::new(&mut ctx, 8, 8, 8, 1);
        app.run(&mut ctx, 2, 0);
        let d = ctx.fetch(app.energy0);
        match &reference {
            None => reference = Some(d),
            Some(r) => assert_eq!(r, &d, "energy0 differs on {}", p.label()),
        }
    }
}

#[test]
fn opensbli_identical_on_key_platforms() {
    let platforms = [
        Platform::KnlFlatDdr4,
        Platform::KnlCacheTiled,
        Platform::GpuExplicit {
            link: Link::NvLink,
            cyclic: true,
            prefetch: false,
        },
    ];
    let mut reference: Option<Vec<f64>> = None;
    for p in platforms {
        let mut ctx = OpsContext::new(Config::new(p, AppCalib::OPENSBLI).build_engine());
        let mut app = OpenSbli::new(&mut ctx, 16, 1, 1);
        app.run(&mut ctx, 2);
        let d = ctx.fetch(app.q[1]);
        match &reference {
            None => reference = Some(d),
            Some(r) => assert_eq!(r, &d, "rhou differs on {}", p.label()),
        }
    }
}

/// Auto-tuned plans are re-schedules too: on every tunable platform the
/// three apps must stay bit-exact against untiled execution, whatever
/// candidate the search picks.
#[test]
fn tuned_plans_stay_bitexact_on_all_apps() {
    use ops_oc::tuner::TuneOpts;
    let tune = TuneOpts {
        budget: 12,
        seed: 0xE0,
    };
    let tuned_specs = [
        "knl-cache-tiled:tuned",
        "gpu-explicit:pcie:cyclic:prefetch:tuned",
        "gpu-explicit:nvlink:tuned",
        "gpu-unified:pcie:tiled:prefetch:tuned",
    ];
    // CloverLeaf 2D
    let reference = {
        let mut ctx = OpsContext::new(
            Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_2D).build_engine(),
        );
        let mut app = CloverLeaf2D::new(&mut ctx, 16, 16, 1);
        app.run(&mut ctx, 3, 2);
        ctx.fetch(app.density0)
    };
    for spec in tuned_specs {
        let (p, tuned) = Config::parse_spec(spec).unwrap();
        assert!(tuned, "{spec}");
        let cfg = Config::new(p, AppCalib::CLOVERLEAF_2D)
            .with_tuning(tune)
            .unwrap();
        let mut ctx = OpsContext::new(cfg.build_engine());
        let mut app = CloverLeaf2D::new(&mut ctx, 16, 16, 1);
        app.run(&mut ctx, 3, 2);
        assert_eq!(
            reference,
            ctx.fetch(app.density0),
            "cl2d density0 differs on tuned {spec}"
        );
    }
    // CloverLeaf 3D
    let reference = {
        let mut ctx = OpsContext::new(
            Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_3D).build_engine(),
        );
        let mut app = CloverLeaf3D::new(&mut ctx, 8, 8, 8, 1);
        app.run(&mut ctx, 2, 0);
        ctx.fetch(app.energy0)
    };
    for spec in ["knl-cache-tiled:tuned", "gpu-explicit:pcie:cyclic:tuned"] {
        let (p, _) = Config::parse_spec(spec).unwrap();
        let cfg = Config::new(p, AppCalib::CLOVERLEAF_3D)
            .with_tuning(tune)
            .unwrap();
        let mut ctx = OpsContext::new(cfg.build_engine());
        let mut app = CloverLeaf3D::new(&mut ctx, 8, 8, 8, 1);
        app.run(&mut ctx, 2, 0);
        assert_eq!(
            reference,
            ctx.fetch(app.energy0),
            "cl3d energy0 differs on tuned {spec}"
        );
    }
    // OpenSBLI
    let reference = {
        let mut ctx =
            OpsContext::new(Config::new(Platform::KnlFlatDdr4, AppCalib::OPENSBLI).build_engine());
        let mut app = OpenSbli::new(&mut ctx, 16, 1, 1);
        app.run(&mut ctx, 2);
        ctx.fetch(app.q[1])
    };
    for spec in ["knl-cache-tiled:tuned", "gpu-explicit:nvlink:cyclic:tuned"] {
        let (p, _) = Config::parse_spec(spec).unwrap();
        let cfg = Config::new(p, AppCalib::OPENSBLI).with_tuning(tune).unwrap();
        let mut ctx = OpsContext::new(cfg.build_engine());
        let mut app = OpenSbli::new(&mut ctx, 16, 1, 1);
        app.run(&mut ctx, 2);
        assert_eq!(
            reference,
            ctx.fetch(app.q[1]),
            "opensbli rhou differs on tuned {spec}"
        );
    }
}

#[test]
fn optimisation_toggles_change_traffic_not_results() {
    let run = |cyclic: bool, prefetch: bool| {
        let p = Platform::GpuExplicit {
            link: Link::PciE,
            cyclic,
            prefetch,
        };
        let mut ctx = OpsContext::new(Config::new(p, AppCalib::CLOVERLEAF_2D).build_engine());
        let mut app = CloverLeaf2D::new(&mut ctx, 16, 16, 1 << 14);
        app.run(&mut ctx, 3, 0);
        let m = ctx.metrics().clone();
        (ctx.fetch(app.density0), m)
    };
    let (d_base, m_base) = run(false, false);
    let (d_cyc, m_cyc) = run(true, false);
    let (d_all, m_all) = run(true, true);
    assert_eq!(d_base, d_cyc);
    assert_eq!(d_base, d_all);
    assert!(
        m_cyc.d2h_bytes < m_base.d2h_bytes,
        "Cyclic must cut downloads: {} !< {}",
        m_cyc.d2h_bytes,
        m_base.d2h_bytes
    );
    assert!(
        m_all.elapsed_s <= m_cyc.elapsed_s + 1e-12,
        "Prefetch must not slow things down"
    );
}

#[test]
fn oversubscribed_platforms_report_oom_where_paper_segfaults() {
    // model scale pushes the 16x16 problem to ~26 GB modelled
    let scale = 1 << 22;
    for (p, should_fit) in [
        (Platform::KnlFlatMcdram, false),
        (Platform::KnlFlatDdr4, true),
        (Platform::KnlCacheTiled, true),
        (Platform::GpuBaseline { link: Link::PciE }, false),
        (
            Platform::GpuExplicit {
                link: Link::PciE,
                cyclic: true,
                prefetch: true,
            },
            true,
        ),
    ] {
        let mut ctx = OpsContext::new(Config::new(p, AppCalib::CLOVERLEAF_2D).build_engine());
        let mut app = CloverLeaf2D::new(&mut ctx, 16, 16, scale);
        app.run(&mut ctx, 1, 0);
        assert_eq!(
            !ctx.oom(),
            should_fit,
            "{}: oom={} problem={:.1} GB",
            p.label(),
            ctx.oom(),
            ctx.problem_bytes() as f64 / 1e9
        );
    }
}
