//! Record-once / replay-many equivalence: a [`Session`] driving a frozen
//! (or dynamically re-recorded) chain must be **bit-exact** with the
//! legacy per-step `OpsContext` path for all three apps across
//! {plain, KNL cache tiled, GPU explicit, sharded ×2 (two variants)} —
//! while analysing each chain shape exactly once.
//!
//! Also home of the Platform::spec ↔ Config::parse_platform round-trip
//! property test over every constructible platform.

#![allow(deprecated)] // compares against the legacy OpsContext shim on purpose

use ops_oc::apps::cloverleaf2d::CloverLeaf2D;
use ops_oc::apps::cloverleaf3d::CloverLeaf3D;
use ops_oc::apps::diffusion::Diffusion2D;
use ops_oc::apps::opensbli::OpenSbli;
use ops_oc::coordinator::{json_record, Config, InnerPlatform, Platform};
use ops_oc::distributed::{DecompKind, Interconnect};
use ops_oc::memory::{AppCalib, Link};
use ops_oc::ops::{Drive, OpsContext};
use ops_oc::program::{ProgramBuilder, Session};
use std::sync::Arc;

/// The platform matrix of the equivalence sweep: plain, KNL tiled, GPU
/// explicit, and two sharded-×2 variants (1D over GPU ranks, 2D over
/// KNL ranks).
fn platforms() -> Vec<Platform> {
    vec![
        Platform::KnlFlatDdr4,
        Platform::KnlCacheTiled,
        Platform::GpuExplicit {
            link: Link::PciE,
            cyclic: true,
            prefetch: true,
        },
        Config::parse_platform("gpu-explicit:pcie:cyclic:prefetch:x2:1d").unwrap(),
        Config::parse_platform("knl-cache-tiled:x2:2d:ib").unwrap(),
    ]
}

// ---------------------------------------------------------------- diffusion

fn diffusion_legacy(p: Platform, steps: usize) -> (Vec<f64>, f64) {
    let cfg = Config::new(p, AppCalib::CLOVERLEAF_2D);
    let mut c = OpsContext::new(cfg.build_engine());
    let app = Diffusion2D::new(&mut c, 48, 48, 1);
    app.run(&mut c, steps, 1);
    (c.fetch(app.u), c.metrics().elapsed_s)
}

fn diffusion_session(p: Platform, steps: usize) -> (Vec<f64>, ops_oc::exec::Metrics) {
    let cfg = Config::new(p, AppCalib::CLOVERLEAF_2D);
    let mut b = ProgramBuilder::new();
    let app = Diffusion2D::new(&mut b, 48, 48, 1);
    let chains = app.record_chains(&mut b, 1);
    let prog = Arc::new(b.freeze().expect("diffusion freezes"));
    let mut s = Session::new(prog, &cfg);
    // mirror the legacy driver exactly: init chain, reset, cyclic, steps
    s.run_chain(chains.init);
    s.reset_metrics();
    s.set_cyclic_phase(true);
    s.replay(chains.step, steps);
    (s.fetch(app.u), s.metrics().clone())
}

#[test]
fn diffusion_replay_is_bit_exact_with_legacy_on_all_platforms() {
    for p in platforms() {
        let (want, elapsed) = diffusion_legacy(p, 12);
        let (got, m) = diffusion_session(p, 12);
        assert_eq!(want, got, "numerics differ on {}", p.label());
        assert_eq!(
            elapsed, m.elapsed_s,
            "modelled clock differs on {}",
            p.label()
        );
    }
}

/// The acceptance criterion: for a 100-step diffusion run the chain
/// analysis runs exactly once — `analysis_builds == 1`,
/// `analysis_reuse_hits == 99` — and the `--json` record carries it.
#[test]
fn hundred_step_diffusion_analyses_once() {
    for p in platforms() {
        let (got, m) = diffusion_session(p, 100);
        assert!(got.iter().all(|v| v.is_finite()));
        assert_eq!(m.analysis_builds, 1, "builds on {}", p.label());
        assert_eq!(m.analysis_reuse_hits, 99, "reuse on {}", p.label());
        let topo = Config::new(p, AppCalib::CLOVERLEAF_2D).topology();
        let rec = json_record("diffusion", &p.label(), p.ranks(), 0.001, &topo, &m, false);
        assert!(rec.contains("\"analysis_builds\":1"), "{rec}");
        assert!(rec.contains("\"analysis_reuse_hits\":99"), "{rec}");
        // the legacy path, by contrast, re-analyses every flush
        let cfg = Config::new(p, AppCalib::CLOVERLEAF_2D);
        let mut c = OpsContext::new(cfg.build_engine());
        let app = Diffusion2D::new(&mut c, 48, 48, 1);
        app.run(&mut c, 100, 1);
        assert_eq!(c.metrics().analysis_builds, 100, "legacy on {}", p.label());
        assert_eq!(c.metrics().analysis_reuse_hits, 0);
    }
}

// -------------------------------------------------------------- cloverleaf2d

fn cl2d_legacy(p: Platform) -> (Vec<f64>, Vec<f64>, f64) {
    let cfg = Config::new(p, AppCalib::CLOVERLEAF_2D);
    let mut c = OpsContext::new(cfg.build_engine());
    let mut app = CloverLeaf2D::new(&mut c, 16, 16, 1);
    app.run(&mut c, 3, 2);
    (
        c.fetch(app.density0),
        c.fetch(app.xvel0),
        c.metrics().elapsed_s,
    )
}

fn cl2d_session(p: Platform) -> (Vec<f64>, Vec<f64>, ops_oc::exec::Metrics) {
    let cfg = Config::new(p, AppCalib::CLOVERLEAF_2D);
    let mut b = ProgramBuilder::new();
    let mut app = CloverLeaf2D::new(&mut b, 16, 16, 1);
    let prog = Arc::new(b.freeze().expect("cloverleaf2d freezes"));
    let mut s = Session::new(prog, &cfg);
    app.run(&mut s, 3, 2);
    (
        s.fetch(app.density0),
        s.fetch(app.xvel0),
        s.metrics().clone(),
    )
}

#[test]
fn cloverleaf2d_session_is_bit_exact_with_legacy_on_all_platforms() {
    for p in platforms() {
        let (d_want, v_want, elapsed) = cl2d_legacy(p);
        let (d_got, v_got, m) = cl2d_session(p);
        assert_eq!(d_want, d_got, "density0 differs on {}", p.label());
        assert_eq!(v_want, v_got, "xvel0 differs on {}", p.label());
        assert_eq!(elapsed, m.elapsed_s, "clock differs on {}", p.label());
        // dt is data-dependent so chains are re-recorded per step, but
        // identical shapes hit the session's analysis memo: far fewer
        // builds than chain executions.
        assert!(
            m.analysis_builds < m.chains,
            "{}: {} builds for {} chains",
            p.label(),
            m.analysis_builds,
            m.chains
        );
        assert!(m.analysis_reuse_hits > 0, "{}", p.label());
    }
}

// -------------------------------------------------------------- cloverleaf3d

#[test]
fn cloverleaf3d_session_is_bit_exact_with_legacy_on_all_platforms() {
    for p in platforms() {
        let cfg = Config::new(p, AppCalib::CLOVERLEAF_3D);
        let (want, w_elapsed) = {
            let mut c = OpsContext::new(cfg.build_engine());
            let mut app = CloverLeaf3D::new(&mut c, 8, 8, 8, 1);
            app.run(&mut c, 2, 0);
            (c.fetch(app.density0), c.metrics().elapsed_s)
        };
        let mut b = ProgramBuilder::new();
        let mut app = CloverLeaf3D::new(&mut b, 8, 8, 8, 1);
        let prog = Arc::new(b.freeze().expect("cloverleaf3d freezes"));
        let mut s = Session::new(prog, &cfg);
        app.run(&mut s, 2, 0);
        assert_eq!(want, s.fetch(app.density0), "density0 differs on {}", p.label());
        assert_eq!(w_elapsed, s.metrics().elapsed_s, "clock differs on {}", p.label());
    }
}

// ------------------------------------------------------------------ opensbli

#[test]
fn opensbli_session_is_bit_exact_with_legacy_on_all_platforms() {
    for p in platforms() {
        let cfg = Config::new(p, AppCalib::OPENSBLI);
        let (want, w_elapsed) = {
            let mut c = OpsContext::new(cfg.build_engine());
            let mut app = OpenSbli::new(&mut c, 16, 1, 1);
            app.run(&mut c, 2);
            (c.fetch(app.q[4]), c.metrics().elapsed_s)
        };
        let mut b = ProgramBuilder::new();
        let mut app = OpenSbli::new(&mut b, 16, 1, 1);
        let prog = Arc::new(b.freeze().expect("opensbli freezes"));
        let mut s = Session::new(prog, &cfg);
        app.run(&mut s, 2);
        assert_eq!(want, s.fetch(app.q[4]), "rhoE differs on {}", p.label());
        assert_eq!(w_elapsed, s.metrics().elapsed_s, "clock differs on {}", p.label());
    }
}

/// OpenSBLI has no data-dependent control flow, so its whole multi-step
/// chain freezes: record once, replay per chain, bit-exact with the
/// dynamic driver.
#[test]
fn opensbli_frozen_chain_matches_dynamic_driver() {
    let p = Platform::KnlCacheTiled;
    let cfg = Config::new(p, AppCalib::OPENSBLI);

    // dynamic session (re-records the chain every iteration)
    let mut b = ProgramBuilder::new();
    let mut app = OpenSbli::new(&mut b, 16, 1, 1);
    let prog = Arc::new(b.freeze().unwrap());
    let mut dynamic = Session::new(prog, &cfg);
    app.run(&mut dynamic, 3);
    let want = dynamic.fetch(app.q[1]);

    // frozen chain, replayed with halo exchanges between replays
    let mut b = ProgramBuilder::new();
    let mut app = OpenSbli::new(&mut b, 16, 1, 1);
    let step_chain = app.record_step_chain(&mut b);
    let init_chain = b.record_chain("sbli_init", |r| app.initialise(r));
    let prog = Arc::new(b.freeze().expect("frozen opensbli validates"));
    let mut s = Session::new(prog, &cfg);
    s.run_chain(init_chain);
    s.reset_metrics();
    s.set_cyclic_phase(true);
    for _ in 0..3 {
        app.exchange_halos(&mut s);
        s.run_chain(step_chain);
    }
    assert_eq!(want, s.fetch(app.q[1]));
    assert_eq!(s.metrics().analysis_builds, 1);
    assert_eq!(s.metrics().analysis_reuse_hits, 2);
}

// ------------------------------------------------- platform spec round-trip

/// Property: `Platform::spec()` → `Config::parse_platform` round-trips
/// for every constructible platform (sharded forms need ranks ≥ 2; `x1`
/// collapses by design).
#[test]
fn platform_spec_round_trips_for_every_constructible_platform() {
    let links = [Link::PciE, Link::NvLink];
    let bools = [false, true];
    let mut all: Vec<Platform> = vec![
        Platform::KnlFlatDdr4,
        Platform::KnlFlatMcdram,
        Platform::KnlCache,
        Platform::KnlCacheTiled,
    ];
    for link in links {
        all.push(Platform::GpuBaseline { link });
        for a in bools {
            for b in bools {
                all.push(Platform::GpuExplicit {
                    link,
                    cyclic: a,
                    prefetch: b,
                });
                all.push(Platform::GpuUnified {
                    link,
                    tiled: a,
                    prefetch: b,
                });
            }
        }
    }
    let inners: Vec<InnerPlatform> = all
        .iter()
        .filter_map(|p| InnerPlatform::try_from_platform(*p))
        .collect();
    let base = all.clone();
    for inner in &inners {
        for ranks in [2u32, 3, 5, 8, 64] {
            for ic in [
                Interconnect::PciePeer,
                Interconnect::NvLink,
                Interconnect::InfiniBand,
            ] {
                for decomp in [DecompKind::OneD, DecompKind::TwoD] {
                    for overlap in bools {
                        all.push(Platform::Sharded {
                            ranks,
                            inner: *inner,
                            link: ic,
                            decomp,
                            overlap,
                        });
                    }
                }
            }
        }
    }
    // plus every rank count for one representative inner platform
    for ranks in 2..=64u32 {
        all.push(Platform::Sharded {
            ranks,
            inner: inners[0],
            link: Interconnect::InfiniBand,
            decomp: DecompKind::OneD,
            overlap: true,
        });
    }
    assert!(all.len() > base.len() + 100, "sweep is non-trivial");
    for p in all {
        let spec = p.spec();
        let parsed = Config::parse_platform(&spec)
            .unwrap_or_else(|e| panic!("spec {spec:?} failed to parse: {e}"));
        assert_eq!(parsed, p, "round trip failed for {spec:?}");
    }
}
