//! Property tests for the obs telemetry layer:
//!
//! * histogram quantile bounds bracket the exact sample quantiles and
//!   stay within the log-linear bucket width (≤ 1/16 relative);
//! * merging two histograms is equivalent to recording the union of
//!   their samples;
//! * the span trees produced by real engine runs over randomised chains
//!   are well-nested on every platform.
//!
//! All randomness comes from the same seeded xorshift64* generator the
//! tuner uses, so failures reproduce deterministically.

use ops_oc::obs::Histogram;

/// Deterministic xorshift64* (the tuner's generator).
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n.max(1)
    }

    /// A positive sample spanning ~9 decades.
    fn sample(&mut self) -> f64 {
        let mantissa = 1.0 + (self.below(1_000_000) as f64) / 1_000_000.0;
        let exp = self.below(30) as i32 - 15;
        mantissa * 2f64.powi(exp)
    }
}

/// The exact rank a quantile resolves to — the same definition
/// `Histogram::quantile_bounds` uses.
fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len() as u64;
    let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).clamp(1, n);
    sorted[(rank - 1) as usize]
}

#[test]
fn quantile_bounds_bracket_the_exact_quantiles() {
    let mut rng = Rng::new(0xDECAF);
    for case in 0..40 {
        let n = 1 + rng.below(400) as usize;
        let samples: Vec<f64> = (0..n).map(|_| rng.sample()).collect();
        let mut h = Histogram::default();
        for &s in &samples {
            h.record(s);
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for q in [0.0, 0.01, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let exact = exact_quantile(&sorted, q);
            let (lo, hi) = h.quantile_bounds(q).expect("non-empty histogram");
            assert!(
                lo <= exact && exact <= hi,
                "case {case} q={q}: exact {exact} outside [{lo}, {hi}]"
            );
            assert!(
                hi - lo <= lo / 16.0 + 1e-300,
                "case {case} q={q}: bucket too wide: [{lo}, {hi}]"
            );
        }
    }
}

#[test]
fn merging_histograms_matches_recording_the_union() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..40 {
        let na = rng.below(200) as usize;
        let nb = rng.below(200) as usize;
        let a: Vec<f64> = (0..na).map(|_| rng.sample()).collect();
        let b: Vec<f64> = (0..nb).map(|_| rng.sample()).collect();

        let mut ha = Histogram::default();
        a.iter().for_each(|&v| ha.record(v));
        let mut hb = Histogram::default();
        b.iter().for_each(|&v| hb.record(v));
        ha.merge(&hb);

        let mut hu = Histogram::default();
        a.iter().chain(b.iter()).for_each(|&v| hu.record(v));

        assert_eq!(ha.count(), hu.count(), "case {case}");
        assert_eq!(ha.min(), hu.min(), "case {case}");
        assert_eq!(ha.max(), hu.max(), "case {case}");
        let scale = hu.sum().abs().max(1e-300);
        assert!(
            (ha.sum() - hu.sum()).abs() / scale < 1e-9,
            "case {case}: sums diverge: {} vs {}",
            ha.sum(),
            hu.sum()
        );
        assert_eq!(
            ha.buckets().collect::<Vec<_>>(),
            hu.buckets().collect::<Vec<_>>(),
            "case {case}: bucket contents must be identical"
        );
        for q in [0.1, 0.5, 0.95] {
            assert_eq!(
                ha.quantile_bounds(q),
                hu.quantile_bounds(q),
                "case {case} q={q}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Span well-nestedness over randomised chains on real engines.

mod spans {
    use super::Rng;
    use ops_oc::exec::{Engine, Metrics, NativeExecutor, World};
    use ops_oc::memory::{AppCalib, GpuCalib, GpuExplicitEngine, GpuOpts, Link, PlainEngine};
    use ops_oc::ops::kernel::kernel;
    use ops_oc::ops::stencil::{shapes, StencilId};
    use ops_oc::ops::*;

    const APP: AppCalib = AppCalib::CLOVERLEAF_2D;

    fn fixture(rng: &mut Rng) -> (Vec<Dataset>, Vec<Stencil>, DataStore, Vec<LoopInst>) {
        let nds = 2 + rng.below(3) as u32;
        let ny = 64 + rng.below(4) as usize * 64;
        let mut datasets = vec![];
        let mut store = DataStore::new();
        for i in 0..nds {
            let d = Dataset {
                id: DatasetId(i),
                block: BlockId(0),
                name: format!("d{i}"),
                size: [32, ny, 1],
                halo_lo: [1, 1, 0],
                halo_hi: [1, 1, 0],
                elem_bytes: 8,
            };
            store.alloc(&d);
            datasets.push(d);
        }
        let stencils = vec![
            Stencil {
                id: StencilId(0),
                name: "pt".into(),
                points: shapes::point(),
            },
            Stencil {
                id: StencilId(1),
                name: "star".into(),
                points: shapes::star2d(1),
            },
        ];
        let nloops = 1 + rng.below(5) as usize;
        let mut chain = vec![];
        for l in 0..nloops {
            let src = DatasetId(rng.below(nds as u64) as u32);
            let dst = DatasetId(((src.0 + 1) % nds.max(1)) as u32);
            chain.push(LoopInst {
                name: format!("sweep{l}"),
                block: BlockId(0),
                range: [(0, 32), (0, ny as isize), (0, 1)],
                args: vec![
                    Arg::dat(src, StencilId(1), Access::Read),
                    Arg::dat(dst, StencilId(0), Access::Write),
                ],
                kernel: kernel(|c| {
                    let v = c.r(0, -1, 0) + c.r(0, 1, 0);
                    c.w(1, 0, 0, 0.5 * v);
                }),
                kernel_ir: None,
                seq: l as u64,
                bw_efficiency: 1.0,
            });
        }
        (datasets, stencils, store, chain)
    }

    fn run(engine: &mut dyn Engine, fx: &(Vec<Dataset>, Vec<Stencil>, DataStore, Vec<LoopInst>)) {
        let (datasets, stencils, _, chain) = fx;
        let mut store = DataStore::new();
        datasets.iter().for_each(|d| store.alloc(d));
        let mut reds = vec![];
        let mut metrics = Metrics::new();
        let mut exec = NativeExecutor::new();
        let mut world = World {
            datasets,
            stencils,
            store: &mut store,
            reds: &mut reds,
            metrics: &mut metrics,
            exec: &mut exec,
        };
        engine.run_chain(chain, &mut world, true);
    }

    fn assert_well_nested(spans: &[ops_oc::obs::SpanRec]) {
        assert!(!spans.is_empty(), "engines must record lifecycle spans");
        for s in spans {
            assert!(s.end_s >= s.start_s, "{}: negative duration", s.name);
            match s.parent {
                None => assert_eq!(s.depth, 0, "{}: root depth", s.name),
                Some(pid) => {
                    let p = spans
                        .iter()
                        .find(|p| p.id == pid)
                        .unwrap_or_else(|| panic!("{}: missing parent {pid}", s.name));
                    assert_eq!(s.depth, p.depth + 1, "{}", s.name);
                    assert!(p.id < s.id, "{}: parent created first", s.name);
                    assert!(s.start_s >= p.start_s - 1e-9, "{}", s.name);
                    assert!(s.end_s <= p.end_s + 1e-9, "{}", s.name);
                }
            }
        }
    }

    #[test]
    fn span_trees_are_well_nested_across_random_chains_and_platforms() {
        let mut rng = Rng::new(0xC0FFEE);
        for case in 0..12 {
            let fx = fixture(&mut rng);

            ops_oc::obs::reset();
            let mut plain = PlainEngine::knl_flat_ddr4(50.0);
            run(&mut plain, &fx);
            let stats = ops_oc::obs::span_stats();
            assert_eq!(stats.open, 0, "case {case}: all plain spans closed");
            assert_well_nested(&ops_oc::obs::snapshot_spans());

            ops_oc::obs::reset();
            let mut gpu = GpuExplicitEngine::new(
                GpuCalib {
                    hbm_bytes: 64 << 10, // force multi-tile streaming
                    ..GpuCalib::default()
                },
                APP,
                Link::PciE,
                GpuOpts::default(),
            )
            .unwrap();
            run(&mut gpu, &fx);
            let spans = ops_oc::obs::snapshot_spans();
            assert_eq!(ops_oc::obs::span_stats().open, 0, "case {case}");
            assert_well_nested(&spans);
            assert!(
                spans.iter().any(|s| s.name == "tile"),
                "case {case}: streamed run must record tile spans"
            );
        }
    }
}
