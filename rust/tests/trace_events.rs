//! Acceptance test for `--trace`-level event collection: an explicit
//! streaming chain must emit **one compute event per executed tile**,
//! and upload/download events only for datasets the §4.1 rules do not
//! skip — read-only data is never downloaded, write-first data never
//! uploaded (and, in cyclic phases with the Cyclic optimisation, not
//! downloaded either).

use ops_oc::exec::timeline::EventKind;
use ops_oc::exec::{Engine, Metrics, NativeExecutor, World};
use ops_oc::memory::{AppCalib, GpuCalib, GpuExplicitEngine, GpuOpts, Link};
use ops_oc::ops::kernel::kernel;
use ops_oc::ops::stencil::shapes;
use ops_oc::ops::*;

/// Chain: `temp = f(input)` — `input` is read-only, `temp` write-first.
fn fixture(ny: usize) -> (Vec<Dataset>, Vec<Stencil>, DataStore, Vec<LoopInst>) {
    let mut datasets = vec![];
    let mut store = DataStore::new();
    for (i, name) in ["input", "temp"].iter().enumerate() {
        let d = Dataset {
            id: DatasetId(i as u32),
            block: BlockId(0),
            name: name.to_string(),
            size: [64, ny, 1],
            halo_lo: [2, 2, 0],
            halo_hi: [2, 2, 0],
            elem_bytes: 8,
        };
        store.alloc(&d);
        datasets.push(d);
    }
    let stencils = vec![
        Stencil {
            id: StencilId(0),
            name: "pt".into(),
            points: shapes::point(),
        },
        Stencil {
            id: StencilId(1),
            name: "star".into(),
            points: shapes::star2d(1),
        },
    ];
    let chain = vec![LoopInst {
        name: "mk_temp".into(),
        block: BlockId(0),
        range: [(0, 64), (0, ny as isize), (0, 1)],
        args: vec![
            Arg::dat(DatasetId(0), StencilId(1), Access::Read),
            Arg::dat(DatasetId(1), StencilId(0), Access::Write),
        ],
        kernel: kernel(|c| {
            let v = c.r(0, -1, 0) + c.r(0, 1, 0);
            c.w(1, 0, 0, 0.5 * v);
        }),
        kernel_ir: None,
        seq: 0,
        bw_efficiency: 1.0,
    }];
    (datasets, stencils, store, chain)
}

fn run_traced(cyclic_phase: bool) -> Metrics {
    let (datasets, stencils, mut store, chain) = fixture(512);
    let mut reds = vec![];
    let mut metrics = Metrics::new();
    metrics.enable_trace();
    let mut exec = NativeExecutor::new();
    let mut e = GpuExplicitEngine::new(
        GpuCalib {
            hbm_bytes: 256 << 10, // the ~0.8 MiB problem streams in tiles
            ..GpuCalib::default()
        },
        AppCalib::CLOVERLEAF_2D,
        Link::PciE,
        GpuOpts::default(),
    )
    .unwrap();
    let mut world = World {
        datasets: &datasets,
        stencils: &stencils,
        store: &mut store,
        reds: &mut reds,
        metrics: &mut metrics,
        exec: &mut exec,
    };
    e.run_chain(&chain, &mut world, cyclic_phase);
    metrics
}

fn count(m: &Metrics, kind: EventKind) -> u64 {
    m.trace_events().iter().filter(|e| e.kind == kind).count() as u64
}

#[test]
fn one_compute_event_per_executed_tile() {
    let m = run_traced(true);
    assert!(m.tiles >= 3, "fixture must stream in several tiles");
    assert_eq!(
        count(&m, EventKind::Compute),
        m.tiles,
        "exactly one compute event per executed tile"
    );
    // every compute event sits on the compute stream
    assert!(m
        .trace_events()
        .iter()
        .filter(|e| e.kind == EventKind::Compute)
        .all(|e| e.resource == "compute"));
}

#[test]
fn transfers_are_traced_only_for_non_skipped_datasets() {
    // Cyclic phase + Cyclic opt: `input` is read-only (never
    // downloaded), `temp` is write-first (never uploaded, and its
    // downloads are skipped too) — so the trace has uploads but NO
    // download events, and the uploaded bytes are exactly `input`'s
    // footprint traffic.
    let cyc = run_traced(true);
    assert!(count(&cyc, EventKind::Upload) >= 1, "input must be uploaded");
    assert_eq!(
        count(&cyc, EventKind::Download),
        0,
        "read-only + write-first datasets must produce no download events"
    );
    let up_bytes: u64 = cyc
        .trace_events()
        .iter()
        .filter(|e| e.kind == EventKind::Upload)
        .map(|e| e.bytes)
        .sum();
    assert_eq!(up_bytes, cyc.h2d_bytes, "trace uploads cover all H2D traffic");
    assert!(cyc.d2h_bytes == 0, "nothing may be downloaded at all");

    // Outside the cyclic phase the write-first skip no longer applies:
    // `temp` is downloaded, and the events appear.
    let warm = run_traced(false);
    assert!(
        count(&warm, EventKind::Download) >= 1,
        "non-cyclic runs download written data"
    );
    let down_bytes: u64 = warm
        .trace_events()
        .iter()
        .filter(|e| e.kind == EventKind::Download)
        .map(|e| e.bytes)
        .sum();
    assert_eq!(down_bytes, warm.d2h_bytes);
    // uploads are identical in both phases (upload skipping does not
    // depend on the cyclic flag)
    assert_eq!(warm.h2d_bytes, cyc.h2d_bytes);
}

#[test]
fn trace_events_are_well_formed_and_ordered_per_resource() {
    let m = run_traced(true);
    use std::collections::HashMap;
    let mut last_end: HashMap<&str, f64> = HashMap::new();
    for ev in m.trace_events() {
        assert!(ev.end_s >= ev.start_s, "negative duration");
        assert!(ev.start_s >= 0.0);
        let prev = last_end.entry(ev.resource.as_str()).or_insert(0.0);
        assert!(
            ev.start_s >= *prev - 1e-12,
            "events overlap on {}: {} < {}",
            ev.resource,
            ev.start_s,
            prev
        );
        *prev = ev.end_s;
        assert!(ev.end_s <= m.elapsed_s + 1e-12, "event past the makespan");
    }
    // the Chrome export of this trace is parseable non-empty JSON
    let json = ops_oc::exec::chrome_trace_json(m.trace_events());
    assert!(json.contains("\"traceEvents\""));
    assert!(json.contains("\"ph\":\"X\""));
}

/// Regression test for the sharded-tiered rank prefix: a rank whose
/// inner engine is a multi-tier stack already uses `:`-joined stream
/// names (`host:upload`), and the re-namespacing layer must prefix each
/// of them with `r{r}:` exactly once — streams, trace events and
/// lifecycle spans all agreeing. A double `r0:r0:` row would split one
/// rank's attribution across two ledger keys.
#[test]
fn sharded_tiered_streams_trace_and_spans_agree_on_rank_prefixes() {
    use ops_oc::bench_support::run_cl2d_cfg;
    use ops_oc::coordinator::Config;
    use ops_oc::memory::AppCalib;
    let (target, _) = Config::parse_spec(
        "tiers:hbm=64k@509.7+host=256k@11~0.00001+nvme=inf@6~0.00002:cyclic:x2",
    )
    .expect("sharded three-tier spec parses");
    let cfg = Config::for_target(target, AppCalib::CLOVERLEAF_2D);
    let (m, oom) = run_cl2d_cfg(&cfg, true, 8, 256, 0.01, 1, 0);
    assert!(!oom);
    let double = |name: &str| name.contains("r0:r0:") || name.contains("r1:r1:");
    // streams: each rank's tier boundary streams appear once-prefixed
    for r in 0..2 {
        let key = format!("r{r}:host:upload");
        assert!(m.per_resource.contains_key(&key), "missing stream {key}");
    }
    for key in m.per_resource.keys() {
        assert!(!double(key), "double rank prefix in stream {key}");
    }
    // trace events agree with the stream ledger
    assert!(!m.trace_events().is_empty(), "trace must be populated");
    for ev in m.trace_events() {
        assert!(
            !double(&ev.resource),
            "double rank prefix in trace event {}",
            ev.resource
        );
    }
    // lifecycle spans agree too (the cell runner reset the tracer, so
    // the thread's tracer still holds exactly this cell's spans)
    let spans = ops_oc::obs::snapshot_spans();
    assert!(
        spans.iter().any(|s| s.name.starts_with("r0:")),
        "per-rank spans must carry the rank prefix"
    );
    for s in &spans {
        assert!(!double(&s.name), "double rank prefix in span {}", s.name);
    }
}

/// Regression test for sharded span namespacing: the per-rank
/// re-namespacing that prefixes a rank's streams and trace events with
/// `r{r}:` must apply to its lifecycle spans too, and the resulting
/// span tree must stay well-nested.
#[test]
fn sharded_runs_namespace_spans_per_rank() {
    use ops_oc::bench_support::run_cl2d;
    use ops_oc::coordinator::{InnerPlatform, Platform};
    use ops_oc::distributed::{DecompKind, Interconnect};
    use ops_oc::memory::Link;
    let p = Platform::Sharded {
        ranks: 2,
        inner: InnerPlatform::GpuExplicit {
            link: Link::NvLink,
            cyclic: true,
            prefetch: true,
        },
        link: Interconnect::NvLink,
        decomp: DecompKind::OneD,
        overlap: true,
    };
    let (m, oom) = run_cl2d(p, 8, 256, 0.01, 1, 0);
    assert!(!oom);
    assert!(m.spans_recorded > 0, "cells record lifecycle spans");
    // the cell runner resets the tracer before the run, so the thread's
    // tracer still holds exactly this cell's spans
    let spans = ops_oc::obs::snapshot_spans();
    for r in 0..2 {
        let rank = format!("r{r}:rank");
        assert!(
            spans.iter().any(|s| s.name == rank),
            "missing {rank} span"
        );
        assert!(
            spans
                .iter()
                .any(|s| s.name.starts_with(&format!("r{r}:")) && s.name != rank),
            "rank {r}'s inner-engine spans must carry the r{r}: prefix"
        );
    }
    // well-nested: children sit strictly inside their parent
    for s in &spans {
        if let Some(pid) = s.parent {
            let parent = spans
                .iter()
                .find(|p| p.id == pid)
                .expect("parent span present in the snapshot");
            assert_eq!(s.depth, parent.depth + 1, "{}", s.name);
            assert!(s.start_s >= parent.start_s - 1e-9, "{}", s.name);
            assert!(s.end_s <= parent.end_s + 1e-9, "{}", s.name);
            assert!(parent.id < s.id, "parents are created before children");
        }
    }
}
