//! Declarative memory-topology tests: spec round-trips (presets and
//! randomized stacks), typed errors for malformed tier tokens, and the
//! three-tier end-to-end runs the `tiers:` grammar exists for.

use ops_oc::coordinator::{Config, Target};
use ops_oc::memory::AppCalib;
use ops_oc::topology::{self, spec, LinkSpec, Tier, Topology};

// ---------------------------------------------------------------------------
// Round-trips

/// Property (satellite): `Topology::spec()` → `Config::parse_spec`
/// round-trips for every preset.
#[test]
fn preset_specs_round_trip_through_the_config_parser() {
    for p in topology::presets() {
        let s = p.spec();
        let (target, tuned) = Config::parse_spec(&s).unwrap_or_else(|e| panic!("{s}: {e}"));
        assert!(!tuned);
        let Target::Tiered(tt) = target else {
            panic!("{s} must parse as a tiered target");
        };
        assert_eq!(tt.topology, p, "{s}");
        // the full grammar rendering reproduces the same stack too
        // (modulo the cosmetic preset name), for every multi-tier preset
        if p.num_tiers() >= 2 {
            let full = p.spec_full();
            let (t2, _) = Config::parse_spec(&full).unwrap_or_else(|e| panic!("{full}: {e}"));
            let tt2 = t2.tiered().unwrap().topology.clone();
            assert!(tt2.same_stack(&p), "{full}");
        }
    }
}

/// A tiny deterministic xorshift so the randomized stacks are
/// reproducible without any rng dependency.
struct XorShift(u64);

impl XorShift {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

/// Property (satellite): randomized valid tier stacks round-trip
/// through render → parse exactly.
#[test]
fn randomized_stacks_round_trip() {
    let mut rng = XorShift(0x5EED_CAFE_F00D_0001);
    for case in 0..200 {
        let n = 2 + rng.below(4) as usize; // 2..=5 tiers
        let mut tiers = Vec::new();
        let mut lats = Vec::new();
        for i in 0..n {
            let cap = if i + 1 == n && rng.below(2) == 0 {
                None // unbounded home tier half the time
            } else {
                // mix raw byte counts with suffix-aligned capacities
                Some(match rng.below(4) {
                    0 => 1 + rng.below(1 << 20),
                    1 => (1 + rng.below(1000)) << 10,
                    2 => (1 + rng.below(1000)) << 20,
                    _ => (1 + rng.below(64)) << 30,
                })
            };
            // bandwidths/latencies from raw bits of a bounded range so
            // arbitrary f64 Display round-tripping is exercised
            let bw = 0.25 + (rng.below(10_000) as f64) / 7.0;
            tiers.push(Tier::new(&format!("t{i}"), cap, bw));
            if i > 0 {
                lats.push((rng.below(100_000) as f64) * 1e-9);
            }
        }
        let topo = Topology::from_tiers(None, tiers, &lats)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        let s = topo.spec();
        let parsed = spec::parse_stack(s.strip_prefix("tiers:").unwrap())
            .unwrap_or_else(|e| panic!("case {case} {s}: {e}"));
        assert_eq!(parsed, topo, "case {case}: {s}");
        // and through the full Config grammar
        let (t, _) = Config::parse_spec(&s).unwrap();
        assert_eq!(&t.tiered().unwrap().topology, &topo, "case {case}: {s}");
    }
}

// ---------------------------------------------------------------------------
// Malformed specs → typed errors naming the offending token

#[test]
fn malformed_tier_tokens_are_typed_errors_naming_the_token() {
    let cases = [
        // (spec, must-mention)
        ("tiers:hbm=0g@550+host=inf@11", "hbm=0g@550"),
        ("tiers:hbm=0g@550+host=inf@11", "zero capacity"),
        ("tiers:hbm=16q@550+host=inf@11", "unknown capacity suffix"),
        ("tiers:hbm=16q@550+host=inf@11", "hbm=16q@550"),
        ("tiers:hbm=16g@550+hbm=inf@11", "duplicate tier name"),
        ("tiers:hbm=16g@550", "single-tier"),
        ("tiers:", "empty tiers: spec"),
        ("tiers:hbm=16g@550+host=inf@oops", "bad bandwidth"),
        ("tiers:hbm=16g@550~1e-5+host=inf@11", "first (fastest) tier"),
        // satellite bugfix: codec annotations are link properties too —
        // the first tier has no inbound link to attach one to, and the
        // error must name the offending tier token
        ("tiers:hbm=16g@550~c:3.5+host=inf@11", "first (fastest) tier"),
        ("tiers:hbm=16g@550~c:3.5+host=inf@11", "hbm=16g@550~c:3.5"),
        ("tiers:hbm=16g@550+host=inf@11~c:0.5", "ratio"),
        ("tiers:hbm=16g@550+host=inf@11~c:3.5~c:2", "more than one ~c:"),
    ];
    for (s, needle) in cases {
        let e = Config::parse_spec(s).unwrap_err().to_string();
        assert!(e.contains(needle), "{s}: expected {needle:?} in {e:?}");
    }
    // unbounded non-home tier is rejected at validation
    let e = Config::parse_spec("tiers:hbm=16g@550+host=inf@11+nvme=4t@6")
        .unwrap_err()
        .to_string();
    assert!(e.contains("unbounded"), "{e}");
}

// ---------------------------------------------------------------------------
// Three-tier end-to-end: the acceptance scenario

fn three_tier_cfg() -> Config {
    // hbm and host both far below the modelled problem size: both
    // boundaries stream, data lives on the unbounded nvme tier.
    let (t, _) = Config::parse_spec(
        "tiers:hbm=64k@509.7+host=256k@11~0.00001+nvme=inf@6~0.00002:cyclic:prefetch",
    )
    .unwrap();
    Config::for_target(t, AppCalib::CLOVERLEAF_2D)
}

#[test]
fn three_tier_runs_all_apps_past_the_host_tier() {
    let cfg = three_tier_cfg();
    // 0.01 GB modelled ≫ the 256 KiB host tier
    let (m, oom) = ops_oc::bench_support::run_cl2d_cfg(&cfg, false, 8, 256, 0.01, 1, 0);
    assert!(!oom, "cl2d three-tier must not OOM past host DRAM");
    assert!(m.tiles > 1, "must stream in tiles, got {}", m.tiles);
    for s in ["hbm:upload", "host:upload", "hbm:download", "host:download"] {
        assert!(m.per_resource.contains_key(s), "cl2d missing stream {s}");
    }
    assert!(m.resource_util("host:upload").unwrap() > 0.0);
    assert!(m.effective_bandwidth_gbs() > 0.0);

    let (m, oom) = ops_oc::bench_support::run_cl3d_cfg(&cfg, false, [8, 8, 128], 0.01, 1, 0);
    assert!(!oom, "cl3d three-tier must not OOM");
    assert!(m.per_resource.contains_key("host:upload"), "cl3d host stream");

    let (m, oom) = ops_oc::bench_support::run_sbli_tall_cfg(&cfg, false, 1, 0.01, 1);
    assert!(!oom, "opensbli three-tier must not OOM");
    assert!(m.per_resource.contains_key("host:upload"), "sbli host stream");
}

#[test]
fn three_tier_traces_per_tier_events() {
    let cfg = three_tier_cfg();
    let (m, oom) = ops_oc::bench_support::run_cl2d_cfg(&cfg, true, 8, 256, 0.01, 1, 0);
    assert!(!oom);
    let evs = m.trace_events();
    assert!(!evs.is_empty(), "tracing must collect events");
    for stream in ["compute", "hbm:upload", "host:upload"] {
        assert!(
            evs.iter().any(|e| e.resource == stream),
            "no events on {stream}"
        );
    }
    // the export renders them
    let json = ops_oc::exec::chrome_trace_json(evs);
    assert!(json.contains("host:upload"), "trace export names tier streams");
}

#[test]
fn bounded_home_tier_reports_oom() {
    // nvme big enough for nothing: the problem must refuse to fit
    let (t, _) =
        Config::parse_spec("tiers:hbm=64k@509.7+host=256k@11~0.00001+nvme=1m@6~0.00002").unwrap();
    let cfg = Config::for_target(t, AppCalib::CLOVERLEAF_2D);
    let (_, oom) = ops_oc::bench_support::run_cl2d_cfg(&cfg, false, 8, 256, 0.01, 1, 0);
    assert!(oom, "a 10 MB problem cannot fit a 1 MiB home tier");
}

#[test]
fn deeper_stacks_model_slower_never_different() {
    // same fastest tier; adding a slow boundary must cost wall clock
    let (two, _) = Config::parse_spec("tiers:hbm=64k@509.7+host=inf@11~0.00001").unwrap();
    let (three, _) = Config::parse_spec(
        "tiers:hbm=64k@509.7+host=256k@11~0.00001+nvme=inf@6~0.00002",
    )
    .unwrap();
    let two = Config::for_target(two, AppCalib::CLOVERLEAF_2D);
    let three = Config::for_target(three, AppCalib::CLOVERLEAF_2D);
    let (m2, _) = ops_oc::bench_support::run_cl2d_cfg(&two, false, 8, 256, 0.01, 1, 0);
    let (m3, _) = ops_oc::bench_support::run_cl2d_cfg(&three, false, 8, 256, 0.01, 1, 0);
    assert!(
        m3.elapsed_s > m2.elapsed_s,
        "the nvme boundary must cost time: {} !> {}",
        m3.elapsed_s,
        m2.elapsed_s
    );
    // §5.1 byte accounting is schedule-independent up to the per-tile
    // u64 truncation of fractional slices.
    let (a, b) = (m2.loop_bytes as f64, m3.loop_bytes as f64);
    assert!(
        (a - b).abs() / a.max(1.0) < 1e-6,
        "loop bytes must agree across schedules: {a} vs {b}"
    );
}

// ---------------------------------------------------------------------------
// LinkSpec unification

#[test]
fn legacy_link_enums_are_linkspec_shims() {
    use ops_oc::distributed::Interconnect;
    use ops_oc::memory::Link;
    assert_eq!(Link::PciE.spec(), LinkSpec::PCIE_HOST);
    assert_eq!(Link::NvLink.spec(), LinkSpec::NVLINK_HOST);
    assert_eq!(Interconnect::PciePeer.spec(), LinkSpec::PCIE_PEER);
    assert_eq!(Interconnect::NvLink.spec(), LinkSpec::NVLINK_PEER);
    assert_eq!(Interconnect::InfiniBand.spec(), LinkSpec::INFINIBAND);
    // and the moved unit constants are re-exported where they were
    assert_eq!(ops_oc::memory::calib_util::GIB, 1u64 << 30);
    assert_eq!(ops_oc::memory::hierarchy::GIB, ops_oc::memory::calib_util::GIB);
    assert_eq!(ops_oc::memory::hierarchy::GB, 1e9);
}
