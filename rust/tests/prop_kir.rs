//! Property tests for the kernel IR (in-tree xorshift PRNG — the
//! vendored crate set has no proptest):
//!
//! * **differential fuzz** — random IR kernels (random expression trees
//!   over reads/literals/locals/globals/indices, random stores and
//!   reduction accumulations, random sub-ranges) must produce
//!   bit-identical stores and reductions when run through the
//!   [`VectorExecutor`]'s compiled row programs vs the
//!   [`NativeExecutor`] running the closure derived from the *same* IR;
//! * **text round-trip** — `KernelIr::parse(ir.to_string())` recovers
//!   the IR exactly, literals included;
//! * **app equivalence** — every paper app is bit-exact under
//!   `--exec vector` vs `--exec native` at the [`Session`] level.

use ops_oc::apps::cloverleaf2d::CloverLeaf2D;
use ops_oc::apps::cloverleaf3d::CloverLeaf3D;
use ops_oc::apps::diffusion::Diffusion2D;
use ops_oc::apps::opensbli::OpenSbli;
use ops_oc::coordinator::{Config, Platform};
use ops_oc::exec::{ExecBackend, Executor, NativeExecutor, VectorExecutor};
use ops_oc::memory::AppCalib;
use ops_oc::ops::kir::{self, Expr, KernelIr, KirBuilder};
use ops_oc::ops::*;
use ops_oc::program::{ProgramBuilder, Session};
use std::sync::Arc;

/// Deterministic xorshift64* PRNG.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
    fn f64(&mut self) -> f64 {
        (self.next() >> 11) as f64 / (1u64 << 53) as f64
    }
}

// ------------------------------------------------------- random kernels

/// Read-only dataset arguments 0..NREAD; stores go to args NREAD and
/// NREAD+1 (never read back, so every generated kernel stays inside the
/// vectorisable subset).
const NREAD: usize = 3;

fn dataset(i: u32) -> Dataset {
    Dataset {
        id: DatasetId(i),
        block: BlockId(0),
        name: format!("d{i}"),
        size: [10, 7, 3],
        halo_lo: [2, 2, 1],
        halo_hi: [2, 2, 1],
        elem_bytes: 8,
    }
}

fn seed_store(store: &mut DataStore, id: DatasetId, scale: f64) {
    for (i, v) in store.buf_mut(id).iter_mut().enumerate() {
        *v = ((i * 2654435761) % 1000) as f64 * scale - 250.0 * scale;
    }
}

/// Random stencil offset within the declared halos ([2, 2, 1]).
fn rand_off(rng: &mut Rng) -> [i32; 3] {
    [
        rng.below(5) as i32 - 2,
        rng.below(5) as i32 - 2,
        rng.below(3) as i32 - 1,
    ]
}

/// Random expression over reads of args `0..NREAD`, literals, iteration
/// indices, already-bound locals, and (optionally) global constants.
/// Division and sqrt are generated unguarded: inf/NaN results are still
/// deterministic, and `select` branches per element rather than
/// blending, so bitwise comparison stays meaningful.
fn rand_expr(rng: &mut Rng, depth: usize, use_gbl: bool, locals: &[Expr]) -> Expr {
    if depth == 0 || rng.below(3) == 0 {
        let mut kinds = 4 + u64::from(use_gbl);
        if locals.is_empty() {
            kinds -= 1;
        }
        return match rng.below(kinds) {
            0 => kir::lit((rng.f64() - 0.5) * 8.0),
            1 => kir::idx(rng.below(3) as usize),
            2 => kir::read(rng.below(NREAD as u64) as usize, rand_off(rng)),
            3 if !locals.is_empty() => locals[rng.below(locals.len() as u64) as usize].clone(),
            _ => kir::gbl(rng.below(2) as usize),
        };
    }
    let a = rand_expr(rng, depth - 1, use_gbl, locals);
    let b = rand_expr(rng, depth - 1, use_gbl, locals);
    match rng.below(13) {
        0 => a + b,
        1 => a - b,
        2 => a * b,
        3 => a / b,
        4 => a.min(b),
        5 => a.max(b),
        6 => a.abs(),
        7 => a.sqrt(),
        8 => -a,
        9 => a.gt(b),
        10 => a.le(b),
        11 => a.ge(b.clone()) * b,
        _ => {
            let c = rand_expr(rng, depth - 1, use_gbl, locals);
            a.lt(b).select(c, kir::lit(0.5))
        }
    }
}

struct RandLoop {
    ir: KernelIr,
    args: Vec<Arg>,
    n_red: usize,
    range: Range3,
}

fn rand_loop(rng: &mut Rng) -> RandLoop {
    let use_gbl = rng.below(2) == 1;
    let mut k = KirBuilder::new();
    let mut locals: Vec<Expr> = vec![];
    for _ in 0..rng.below(3) {
        let e = rand_expr(rng, 2, use_gbl, &locals);
        locals.push(k.let_(e));
    }
    let two_stores = rng.below(2) == 1;
    k.store(NREAD, rand_expr(rng, 3, use_gbl, &locals));
    if two_stores {
        k.store(NREAD + 1, rand_expr(rng, 3, use_gbl, &locals));
    }
    let red_ops = [RedOp::Sum, RedOp::Min, RedOp::Max];
    let n_red = rng.below(3) as usize;
    let mut red_args = vec![];
    for slot in 0..n_red {
        let op = red_ops[rng.below(3) as usize];
        k.reduce(slot, op, rand_expr(rng, 2, use_gbl, &locals));
        red_args.push(Arg::GblRed {
            red: ReductionId(slot as u32),
            op,
        });
    }

    let mut args: Vec<Arg> = (0..NREAD as u32)
        .map(|i| Arg::dat(DatasetId(i), StencilId(0), Access::Read))
        .collect();
    args.push(Arg::dat(
        DatasetId(NREAD as u32),
        StencilId(0),
        Access::Write,
    ));
    if two_stores {
        args.push(Arg::dat(
            DatasetId(NREAD as u32 + 1),
            StencilId(0),
            Access::Write,
        ));
    }
    args.extend(red_args);
    if use_gbl {
        args.push(Arg::GblConst {
            values: vec![rng.f64() * 3.0, rng.f64() - 0.5],
        });
    }

    // random (possibly partial) sub-range of the 10x7x3 interior
    let sub = |rng: &mut Rng, n: isize| {
        let lo = rng.below(n as u64 / 2) as isize;
        let hi = lo + 1 + rng.below((n - lo) as u64) as isize;
        (lo, hi.min(n))
    };
    let range = [sub(rng, 10), sub(rng, 7), sub(rng, 3)];
    RandLoop {
        ir: k.build(),
        args,
        n_red,
        range,
    }
}

/// Run one random loop through both executors on identically seeded
/// stores; every buffer and reduction must be bit-identical.
fn check_differential(seed: u64) {
    let mut rng = Rng::new(seed);
    let rl = rand_loop(&mut rng);
    let datasets: Vec<Dataset> = (0..NREAD as u32 + 2).map(dataset).collect();
    let mut s_nat = DataStore::new();
    let mut s_vec = DataStore::new();
    for d in &datasets {
        s_nat.alloc(d);
        s_vec.alloc(d);
        seed_store(&mut s_nat, d.id, 0.25 + d.id.0 as f64);
        seed_store(&mut s_vec, d.id, 0.25 + d.id.0 as f64);
    }
    let red_op = |i: u32| {
        rl.args
            .iter()
            .find_map(|a| match a {
                Arg::GblRed { red, op } if red.0 == i => Some(*op),
                _ => None,
            })
            .unwrap_or(RedOp::Sum)
    };
    let mk_reds = || -> Vec<Reduction> {
        (0..rl.n_red as u32)
            .map(|i| Reduction::new(ReductionId(i), &format!("r{i}"), red_op(i)))
            .collect()
    };
    let mut r_nat = mk_reds();
    let mut r_vec = mk_reds();

    let ir = Arc::new(rl.ir);
    assert!(
        ir.is_vectorizable(),
        "seed {seed}: generated IR fell outside the vectorisable subset:\n{ir}"
    );
    let l = LoopInst {
        name: format!("fuzz{seed}"),
        block: BlockId(0),
        range: rl.range,
        args: rl.args,
        kernel: ir.to_kernel(),
        kernel_ir: Some(ir),
        seq: 0,
        bw_efficiency: 1.0,
    };

    let mut nexec = NativeExecutor::new();
    nexec.run_loop(&l, l.range, &datasets, &mut s_nat, &mut r_nat);
    let mut vexec = VectorExecutor::new();
    vexec.run_loop(&l, l.range, &datasets, &mut s_vec, &mut r_vec);
    assert_eq!(
        (vexec.vector_loops, vexec.fallback_loops),
        (1, 0),
        "seed {seed}: loop must take the row-program path"
    );

    for d in &datasets {
        let a = s_nat.buf(d.id);
        let b = s_vec.buf(d.id);
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                x.to_bits() == y.to_bits(),
                "seed {seed}: dataset {} differs at {i}: {x:e} vs {y:e}",
                d.id.0
            );
        }
    }
    for (i, (a, b)) in r_nat.iter().zip(&r_vec).enumerate() {
        assert!(
            a.value.to_bits() == b.value.to_bits(),
            "seed {seed}: reduction {i} differs: {} vs {}",
            a.value,
            b.value
        );
    }
}

#[test]
fn random_kernels_bit_exact_across_backends() {
    for seed in 0..300 {
        check_differential(seed);
    }
}

#[test]
fn random_kernels_display_parse_round_trip() {
    for seed in 0..100 {
        let mut rng = Rng::new(seed + 1000);
        let rl = rand_loop(&mut rng);
        let text = rl.ir.to_string();
        let back = KernelIr::parse(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: parse failed: {e}\n{text}"));
        assert_eq!(back, rl.ir, "seed {seed}: round-trip changed the IR");
    }
}

// --------------------------------------------------- app-level equivalence

fn cfgs(app: AppCalib) -> (Config, Config) {
    let native = Config::new(Platform::KnlFlatDdr4, app);
    let vector = native.clone().with_exec(ExecBackend::Vector);
    (native, vector)
}

#[test]
fn diffusion_bit_exact_under_vector_backend() {
    let (c_nat, c_vec) = cfgs(AppCalib::CLOVERLEAF_2D);
    let run = |cfg: &Config| {
        let mut b = ProgramBuilder::new();
        let app = Diffusion2D::new(&mut b, 48, 48, 1);
        let chains = app.record_chains(&mut b, 1);
        let prog = Arc::new(b.freeze().expect("diffusion freezes"));
        let mut s = Session::new(prog, cfg);
        s.run_chain(chains.init);
        s.replay(chains.step, 10);
        (s.fetch(app.u), s.metrics().clone())
    };
    let (want, m_nat) = run(&c_nat);
    let (got, m_vec) = run(&c_vec);
    assert_eq!(want, got, "diffusion numerics differ across backends");
    assert_eq!(m_nat.exec_backend, "native");
    assert_eq!(m_vec.exec_backend, "vector");
    // both step kernels carry IR, and the vector session runs them on
    // the fast path (the init chain's idx-dependent kernel falls back)
    assert!(m_vec.kir_kernels_compiled >= 2, "{m_vec:?}");
    assert_eq!(m_nat.kir_kernels_compiled, m_vec.kir_kernels_compiled);
}

#[test]
fn cloverleaf2d_bit_exact_under_vector_backend() {
    let (c_nat, c_vec) = cfgs(AppCalib::CLOVERLEAF_2D);
    let run = |cfg: &Config| {
        let mut b = ProgramBuilder::new();
        let mut app = CloverLeaf2D::new(&mut b, 16, 16, 1);
        let prog = Arc::new(b.freeze().expect("cloverleaf2d freezes"));
        let mut s = Session::new(prog, cfg);
        app.run(&mut s, 3, 2);
        (s.fetch(app.density0), s.fetch(app.xvel0), s.fetch(app.energy0))
    };
    assert_eq!(run(&c_nat), run(&c_vec), "cloverleaf2d differs across backends");
}

#[test]
fn cloverleaf3d_bit_exact_under_vector_backend() {
    let (c_nat, c_vec) = cfgs(AppCalib::CLOVERLEAF_3D);
    let run = |cfg: &Config| {
        let mut b = ProgramBuilder::new();
        let mut app = CloverLeaf3D::new(&mut b, 8, 8, 8, 1);
        let prog = Arc::new(b.freeze().expect("cloverleaf3d freezes"));
        let mut s = Session::new(prog, cfg);
        app.run(&mut s, 2, 0);
        (s.fetch(app.density0), s.fetch(app.energy0))
    };
    assert_eq!(run(&c_nat), run(&c_vec), "cloverleaf3d differs across backends");
}

#[test]
fn opensbli_bit_exact_under_vector_backend() {
    let (c_nat, c_vec) = cfgs(AppCalib::OPENSBLI);
    let run = |cfg: &Config| {
        let mut b = ProgramBuilder::new();
        let mut app = OpenSbli::new(&mut b, 16, 1, 1);
        let prog = Arc::new(b.freeze().expect("opensbli freezes"));
        let mut s = Session::new(prog, cfg);
        app.run(&mut s, 2);
        (s.fetch(app.q[0]), s.fetch(app.q[4]))
    };
    assert_eq!(run(&c_nat), run(&c_vec), "opensbli differs across backends");
}
