//! Temporal-fusion equivalence: `Session::replay_fused(chain, n, k)`
//! must be **bit-exact** against unfused `replay(chain, n)` of the same
//! recorded step chain — for every app, on every engine family. Fusion
//! is a re-schedule (one skewed super-chain instead of k chain
//! boundaries); the numerics are the same loop bodies in the same
//! order, so equality is to the last bit, witnessed by an FNV over the
//! raw bit patterns of every dataset buffer.

use ops_oc::apps::cloverleaf2d::CloverLeaf2D;
use ops_oc::apps::cloverleaf3d::CloverLeaf3D;
use ops_oc::apps::opensbli::OpenSbli;
use ops_oc::bench_support::store_checksum;
use ops_oc::coordinator::Config;
use ops_oc::memory::AppCalib;
use ops_oc::ops::Drive;
use ops_oc::program::{ProgramBuilder, Session};
use std::sync::Arc;

/// One target per engine family: plain KNL, tiled KNL cache mode, the
/// explicit-streaming GPU engine, a hand-spelled three-tier NVMe stack
/// on the generic N-tier engine, and a sharded two-rank GPU.
fn targets() -> Vec<Config> {
    [
        "knl-flat-ddr4",
        "knl-cache-tiled",
        "gpu-explicit:pcie:cyclic:prefetch",
        "tiers:hbm=64k@509.7+host=256k@11~0.00001+nvme=inf@6~0.00002:cyclic",
        "gpu-explicit:nvlink:cyclic:x2",
    ]
    .iter()
    .map(|s| {
        let (t, _) = Config::parse_spec(s).expect("spec parses");
        Config::for_target(t, AppCalib::CLOVERLEAF_2D)
    })
    .collect()
}

fn cl2d_sum(cfg: &Config, steps: usize, k: usize) -> u64 {
    let mut b = ProgramBuilder::new();
    let mut app = CloverLeaf2D::new(&mut b, 16, 16, 1);
    let step = app.record_step_chain(&mut b);
    let mut sess = Session::new(Arc::new(b.freeze().expect("freeze")), cfg);
    app.initialise(&mut sess);
    sess.flush();
    sess.set_cyclic_phase(true);
    sess.replay_fused(step, steps, k);
    sess.flush();
    store_checksum(&sess)
}

fn cl3d_sum(cfg: &Config, steps: usize, k: usize) -> u64 {
    let mut b = ProgramBuilder::new();
    let mut app = CloverLeaf3D::new(&mut b, 8, 8, 8, 1);
    let step = app.record_step_chain(&mut b);
    let mut sess = Session::new(Arc::new(b.freeze().expect("freeze")), cfg);
    app.initialise(&mut sess);
    sess.flush();
    sess.set_cyclic_phase(true);
    sess.replay_fused(step, steps, k);
    sess.flush();
    store_checksum(&sess)
}

fn sbli_sum(cfg: &Config, steps: usize, k: usize) -> u64 {
    let mut b = ProgramBuilder::new();
    let mut app = OpenSbli::new(&mut b, 16, 2, 1);
    let step = app.record_step_chain(&mut b);
    let mut sess = Session::new(Arc::new(b.freeze().expect("freeze")), cfg);
    app.initialise(&mut sess);
    sess.flush();
    sess.set_cyclic_phase(true);
    sess.replay_fused(step, steps, k);
    sess.flush();
    store_checksum(&sess)
}

// `steps = 5, k = 3` exercises the unfused-tail path (one batch of 3,
// remainder 2); `k = 8 > steps` exercises the clamp.

#[test]
fn cloverleaf2d_fused_replay_is_bit_exact_on_all_targets() {
    for cfg in targets() {
        let base = cl2d_sum(&cfg, 5, 1);
        for k in [2, 3, 4, 8] {
            assert_eq!(
                base,
                cl2d_sum(&cfg, 5, k),
                "cl2d fused k={k} diverged on {}",
                cfg.label()
            );
        }
    }
}

#[test]
fn cloverleaf3d_fused_replay_is_bit_exact_on_all_targets() {
    for cfg in targets() {
        let base = cl3d_sum(&cfg, 3, 1);
        for k in [2, 3] {
            assert_eq!(
                base,
                cl3d_sum(&cfg, 3, k),
                "cl3d fused k={k} diverged on {}",
                cfg.label()
            );
        }
    }
}

#[test]
fn opensbli_fused_replay_is_bit_exact_on_all_targets() {
    for cfg in targets() {
        let base = sbli_sum(&cfg, 4, 1);
        for k in [2, 3] {
            assert_eq!(
                base,
                sbli_sum(&cfg, 4, k),
                "sbli fused k={k} diverged on {}",
                cfg.label()
            );
        }
    }
}

/// The checksum is a real witness: it distinguishes runs that differ
/// (different step counts), so the equalities above are not vacuous.
#[test]
fn checksum_distinguishes_different_trajectories() {
    let cfg = &targets()[0];
    assert_ne!(cl2d_sum(cfg, 5, 1), cl2d_sum(cfg, 4, 1));
    assert_ne!(sbli_sum(cfg, 4, 1), sbli_sum(cfg, 3, 1));
}
