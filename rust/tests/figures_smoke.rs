//! Fast qualitative checks that the modelled system reproduces the
//! paper's headline *shapes* (who wins, roughly by how much, where the
//! crossovers are). The full sweeps live in rust/benches/fig*.rs.

use ops_oc::bench_support::{bw_point, run_cl2d, run_cl3d, run_sbli_tall};
use ops_oc::coordinator::Platform;
use ops_oc::memory::Link;

#[test]
fn knl_cl2d_shapes() {
    let small = 6.0;
    let large = 48.0;
    let steps = 4;
    let ddr_small = bw_point(run_cl2d(Platform::KnlFlatDdr4, 8, 6144, small, steps, 2)).unwrap();
    let ddr_large = bw_point(run_cl2d(Platform::KnlFlatDdr4, 8, 6144, large, steps, 2)).unwrap();
    let mc_small = bw_point(run_cl2d(Platform::KnlFlatMcdram, 8, 6144, small, steps, 2)).unwrap();
    let mc_large = bw_point(run_cl2d(Platform::KnlFlatMcdram, 8, 6144, large, steps, 2));
    let c_small = bw_point(run_cl2d(Platform::KnlCache, 8, 6144, small, steps, 2)).unwrap();
    let c_large = bw_point(run_cl2d(Platform::KnlCache, 8, 6144, large, steps, 2)).unwrap();
    let t_small = bw_point(run_cl2d(Platform::KnlCacheTiled, 8, 6144, small, steps, 2)).unwrap();
    let t_large = bw_point(run_cl2d(Platform::KnlCacheTiled, 8, 6144, large, steps, 2)).unwrap();

    eprintln!("CL2D KNL  6GB: ddr={ddr_small:.0} mc={mc_small:.0} cache={c_small:.0} tiled={t_small:.0}");
    eprintln!("CL2D KNL 48GB: ddr={ddr_large:.0} mc={mc_large:?} cache={c_large:.0} tiled={t_large:.0}");

    // paper: flat series are size-independent; MCDRAM OOMs above 16 GB
    assert!((ddr_small - ddr_large).abs() / ddr_small < 0.1);
    assert!(mc_large.is_none(), "flat MCDRAM must OOM at 48 GB");
    assert!(mc_small > 3.0 * ddr_small, "MCDRAM ~4.8x DDR4");
    // cache mode degrades gracefully; tiling holds within ~15-25%
    assert!(c_small > 0.75 * mc_small, "cache ~ flat at small sizes");
    assert!(c_large < 0.6 * c_small, "untiled cache collapses by 48 GB");
    assert!(t_large > 0.7 * t_small, "tiled keeps most efficiency");
    assert!(t_large > 1.5 * c_large, "paper: 2.2x tiling gain at 48 GB");
}

#[test]
fn gpu_cl2d_shapes() {
    let steps = 4;
    let base = bw_point(run_cl2d(
        Platform::GpuBaseline { link: Link::PciE },
        8,
        6144,
        10.0,
        steps,
        2,
    ))
    .unwrap();
    let oom = bw_point(run_cl2d(
        Platform::GpuBaseline { link: Link::PciE },
        8,
        6144,
        47.0,
        steps,
        2,
    ));
    let pcie = bw_point(run_cl2d(
        Platform::GpuExplicit { link: Link::PciE, cyclic: true, prefetch: true },
        8,
        6144,
        47.0,
        steps,
        2,
    ))
    .unwrap();
    let nvl = bw_point(run_cl2d(
        Platform::GpuExplicit { link: Link::NvLink, cyclic: true, prefetch: true },
        8,
        6144,
        47.0,
        steps,
        2,
    ))
    .unwrap();
    eprintln!("CL2D GPU: baseline={base:.0} oom47={oom:?} pcie47={pcie:.0} nvlink47={nvl:.0}");
    assert!(oom.is_none(), "resident baseline must OOM at 47 GB");
    assert!(base > 400.0, "baseline ~470 GB/s");
    // paper: NVLink 84% of baseline, PCIe 48%. Our mini-CloverLeaf chain
    // has ~5 sweeps/dataset/step vs the original's ~20 (63 vs 153 loops),
    // so the absolute efficiency band sits lower; orderings and the
    // OOM/crossover structure are what we assert (see EXPERIMENTS.md).
    assert!(nvl > pcie, "NVLink beats PCIe");
    assert!(nvl / base > 0.45 && nvl / base < 1.0, "NVLink ratio {:.2}", nvl / base);
    assert!(pcie / base > 0.15 && pcie / base < 0.8, "PCIe ratio {:.2}", pcie / base);
}

#[test]
fn gpu_unified_collapses_and_tiling_recovers() {
    let steps = 4;
    let um = |tiled, prefetch, gb| {
        bw_point(run_cl2d(
            Platform::GpuUnified { link: Link::PciE, tiled, prefetch },
            8,
            6144,
            gb,
            steps,
            2,
        ))
        .unwrap()
    };
    let plain_small = um(false, false, 10.0);
    let plain_large = um(false, false, 36.0);
    let tiled_large = um(true, false, 36.0);
    let pf_large = um(true, true, 36.0);
    eprintln!(
        "CL2D UM: small={plain_small:.0} large={plain_large:.0} tiled={tiled_large:.0} prefetch={pf_large:.0}"
    );
    assert!(plain_large < 0.3 * plain_small, "UM collapses beyond 16 GB");
    assert!(tiled_large > 1.5 * plain_large, "paper: up to 3x from tiling");
    assert!(pf_large > tiled_large, "prefetch helps further");
}

#[test]
fn cl3d_and_sbli_shapes() {
    let c3_large = bw_point(run_cl3d(Platform::KnlCache, [8, 8, 6144], 48.0, 2, 0)).unwrap();
    let t3_large = bw_point(run_cl3d(Platform::KnlCacheTiled, [8, 8, 6144], 48.0, 2, 0)).unwrap();
    eprintln!("CL3D KNL 48GB: cache={c3_large:.0} tiled={t3_large:.0}");
    assert!(t3_large > 1.3 * c3_large, "paper: 1.7x tiling gain");

    let s_cache = bw_point(run_sbli_tall(Platform::KnlCache, 1, 48.0, 2)).unwrap();
    let s_tiled = bw_point(run_sbli_tall(Platform::KnlCacheTiled, 1, 48.0, 2)).unwrap();
    let s_small = bw_point(run_sbli_tall(Platform::KnlCacheTiled, 1, 6.0, 2)).unwrap();
    eprintln!("SBLI KNL 48GB: cache={s_cache:.0} tiled={s_tiled:.0} (6GB tiled={s_small:.0})");
    assert!(s_tiled > 1.2 * s_cache, "paper: 1.5x tiling gain");
    assert!(s_tiled > 0.85 * s_small, "paper: 7% loss at 48 GB");
}
