//! §5.3's OpenSBLI tile-depth study: tiling across 1, 2 or 3 timesteps
//! per chain, PCIe vs NVLink — more depth means more in-tile reuse and
//! more time to hide transfers.
use ops_oc::bench_support::{bw_point, run_sbli_tall, Figure};
use ops_oc::coordinator::Platform;
use ops_oc::memory::Link;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let mut fig = Figure::new(
        "Fig 10: OpenSBLI tiling depth on the P100",
        "effective GB/s (modelled)",
    );
    for link in [Link::PciE, Link::NvLink] {
        let tag = if link == Link::PciE { "P" } else { "N" };
        for spc in [1usize, 2, 3] {
            let s = fig.add_series(&format!("{tag}-{spc} step/chain"));
            // deep chains do halo-deep redundant computation, so keep the
            // sweep small: 3 sizes, 1 chain per cell
            for gb in [16.0, 32.0, 47.0] {
                fig.push(
                    s,
                    gb,
                    bw_point(run_sbli_tall(
                        Platform::GpuExplicit { link, cyclic: true, prefetch: true },
                        spc,
                        gb,
                        1,
                    )),
                );
            }
        }
    }
    println!("{}", fig.render());
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
