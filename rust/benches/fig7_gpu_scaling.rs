//! Figure 7: problem scaling on the P100 — in-memory baseline (OOM past
//! 16 GB) vs explicit tiled streaming over PCIe and NVLink, for all
//! three applications.
use ops_oc::bench_support::{
    bw_point, run_cl2d, run_cl3d, run_sbli_tall, telemetry::BenchRecorder, Figure, GPU_SIZES_GB,
};
use ops_oc::coordinator::Platform;
use ops_oc::memory::Link;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let platforms = |link| Platform::GpuExplicit { link, cyclic: true, prefetch: true };
    let mut rec = BenchRecorder::new("fig7_gpu_scaling");
    for app in ["CloverLeaf 2D", "CloverLeaf 3D", "OpenSBLI"] {
        let mut fig = Figure::new(
            &format!("Fig 7: {app} problem scaling on the P100"),
            "effective GB/s (modelled)",
        );
        let base = fig.add_series("baseline (resident)");
        let pcie = fig.add_series("tiled PCIe");
        let nvl = fig.add_series("tiled NVLink");
        for gb in GPU_SIZES_GB {
            let run = |p| match app {
                "CloverLeaf 2D" => run_cl2d(p, 8, 6144, gb, 4, 0),
                "CloverLeaf 3D" => run_cl3d(p, [8, 8, 6144], gb, 2, 0),
                _ => run_sbli_tall(p, 2, gb, 1),
            };
            let mut cell = |series: usize, plat: &str, res: (ops_oc::exec::Metrics, bool)| {
                rec.point(&format!("{app}|{plat}|{gb:.0}"), app, plat, gb, &res.0, res.1);
                fig.push(series, gb, bw_point(res));
            };
            cell(base, "baseline", run(Platform::GpuBaseline { link: Link::NvLink }));
            cell(pcie, "tiled-pcie", run(platforms(Link::PciE)));
            cell(nvl, "tiled-nvlink", run(platforms(Link::NvLink)));
        }
        println!("{}", fig.render());
    }
    match rec.write() {
        Ok(p) => println!("trajectory: {}", p.display()),
        Err(e) => eprintln!("cannot write trajectory: {e}"),
    }
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
