//! Fleet serving: aggregate throughput vs tenant count across cluster
//! sizes, with the cross-tenant sharing the serving layer exists to
//! demonstrate. The bench asserts the claims the figure illustrates:
//!
//! * **fingerprint batching** — N identical-app tenants freeze ONE
//!   Program: `analysis_builds == 1` for the single distinct
//!   fingerprint, every other tenant counts reuse hits, and the
//!   process-wide tuned-plan cache serves at least N−1 hits;
//! * **bit-exactness under multi-tenancy** — every request's store
//!   checksum equals a solo run of the same (member, app, size, steps);
//! * **serving beats the queue** — aggregate makespan is strictly below
//!   N sequential solo services, at equal per-request numerics;
//! * **failure is survivable** — a rank failure mid-trace re-decomposes
//!   the sharded target onto its survivors and the retried request's
//!   checksum equals a fresh run on the degraded member.

use ops_oc::bench_support::{telemetry::BenchRecorder, Figure};
use ops_oc::fleet::{self, Cluster, FleetApp, FleetOpts, Policy, Scenario, Workload};
use std::time::Instant;

const SIZE_GB: f64 = 0.01;
const STEPS: usize = 4;
const TENANTS: [u32; 3] = [2, 4, 8];
const CLUSTERS: [(&str, &str); 2] = [
    ("tuned-pair", "fleet:tuned-pair"),
    ("tuned-quad", "fleet:gpu-explicit:pcie:cyclic:tuned*4"),
];

fn main() {
    let t0 = Instant::now();
    let mut fig = Figure::new(
        "Fleet serving: aggregate throughput vs tenant count",
        "requests per modelled second",
    );
    let mut rec = BenchRecorder::new("fig_fleet_serving");

    for (label, spec) in CLUSTERS {
        let cluster = Cluster::parse(spec).unwrap();
        let (solo_s, solo_checksum) =
            fleet::solo_run(&cluster.targets[0], FleetApp::CloverLeaf2D, SIZE_GB, STEPS).unwrap();
        assert!(solo_s > 0.0);
        let series = fig.add_series(label);

        for n in TENANTS {
            let w = Workload::parse(&format!(
                "tenants={n},reqs=1,apps=cloverleaf2d,sizes={SIZE_GB},steps={STEPS},seed=17"
            ))
            .unwrap();
            let opts = FleetOpts {
                policy: Policy::BestFit,
                ..FleetOpts::default()
            };
            let run = fleet::serve(&cluster, &w, &opts).unwrap();
            assert_eq!(run.completed(), n as usize);
            assert!(run.outcomes.iter().all(|o| !o.oom));

            // fingerprint batching: one Program, one fused-analysis
            // build, everyone else reuses
            assert_eq!(run.distinct_fingerprints, 1);
            assert_eq!(run.programs_built, 1, "batching must freeze once for {n} tenants");
            assert_eq!(
                run.metrics.analysis_builds, 1,
                "one analysis build per distinct fingerprint ({label}, {n} tenants)"
            );
            assert!(run.metrics.analysis_reuse_hits > 0);
            // the process-wide tuned-plan cache serves every tenant
            // after the first search (identical targets share digests)
            assert!(
                run.metrics.tune_cache_hits >= n as u64 - 1,
                "{label}: expected >= {} tuned-plan cache hits, got {}",
                n - 1,
                run.metrics.tune_cache_hits
            );

            // multi-tenancy must not perturb numerics
            assert!(
                run.outcomes.iter().all(|o| o.checksum == solo_checksum),
                "{label}: a fleet request diverged from the solo checksum"
            );
            // and must beat N sequential solo runs outright
            assert!(
                run.makespan_s < n as f64 * solo_s * 0.999,
                "{label}: serving {n} tenants took {:.6}s, sequential solo {:.6}s",
                run.makespan_s,
                n as f64 * solo_s
            );
            let p50 = run.latency_quantile(0.5);
            let p99 = run.latency_quantile(0.99);
            assert!(p50 > 0.0 && p99 >= p50);
            assert!(run.metrics.spans_recorded > 0, "span tree must record");

            println!(
                "{label:>10} n={n}: makespan={:.6}s throughput={:.1} rps \
                 p50={:.6}s p99={:.6}s tune_hits={}",
                run.makespan_s,
                run.throughput_rps(),
                p50,
                p99,
                run.metrics.tune_cache_hits,
            );
            fig.push(series, n as f64, Some(run.throughput_rps()));
            rec.point(
                &format!("fleet|{label}|{n}tenants"),
                "fleet",
                &format!("{label} best-fit"),
                SIZE_GB * n as f64,
                &run.metrics,
                false,
            );
        }
    }

    // Rank failure mid-trace: the x2 member loses a rank while serving;
    // the in-flight request is re-decomposed onto the survivor and its
    // numerics equal a fresh run on the degraded member.
    {
        let cluster =
            Cluster::parse("fleet:gpu-explicit:pcie:cyclic:x2,gpu-explicit:pcie:cyclic").unwrap();
        let w = Workload::parse(&format!(
            "tenants=4,reqs=1,apps=cloverleaf2d,sizes={SIZE_GB},steps={STEPS},seed=23"
        ))
        .unwrap();
        let opts = FleetOpts {
            scenarios: vec![Scenario::parse("fail:0@0.000000001").unwrap()],
            ..FleetOpts::default()
        };
        let run = fleet::serve(&cluster, &w, &opts).unwrap();
        assert_eq!(run.completed(), 4, "failure must not drop requests");
        assert_eq!(run.failovers, 1);
        assert!(run.per_target[0].degraded);
        let degraded = cluster.targets[0].degrade().unwrap();
        assert_eq!(degraded.target.ranks(), 1, "x2 re-decomposes to the survivor");
        let (_, degraded_checksum) =
            fleet::solo_run(&degraded, FleetApp::CloverLeaf2D, SIZE_GB, STEPS).unwrap();
        let retried: Vec<_> = run.outcomes.iter().filter(|o| o.retried).collect();
        assert_eq!(retried.len(), 1);
        assert_eq!(
            retried[0].checksum, degraded_checksum,
            "retried request must match a fresh run on the surviving cluster"
        );
        rec.point(
            "fleet|rank-failure|4tenants",
            "fleet",
            "x2+single first-fit fail:0",
            SIZE_GB * 4.0,
            &run.metrics,
            false,
        );
        println!(
            "rank-failure: completed={} failovers={} makespan={:.6}s (degraded target bound={})",
            run.completed(),
            run.failovers,
            run.makespan_s,
            run.per_target[0].bound,
        );
    }

    println!("{}", fig.render());
    match rec.write() {
        Ok(p) => println!("trajectory: {}", p.display()),
        Err(e) => eprintln!("cannot write trajectory: {e}"),
    }
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
