//! Three-tier scaling: "beyond 16 GB", extended to "beyond DRAM".
//!
//! The paper's P100 result streams HBM-oversized problems from host
//! memory. A declarative three-tier stack keeps going past *host*
//! capacity: HBM (16 GiB) → host DRAM (modelled at 64 GiB here) → NVMe
//! (unbounded, ~6 GB/s). This figure sweeps the problem size across
//! BOTH boundaries and compares
//!
//! * the legacy two-tier `gpu-explicit:pcie` engine (host unbounded),
//! * the same stack routed through the generic `TieredEngine`
//!   (bit-exact with the legacy engine — the first two series must
//!   agree everywhere), and
//! * the three-tier stack, which pays nothing extra while the problem
//!   fits host and degrades to the NVMe stream past 64 GiB instead of
//!   dying.

use ops_oc::bench_support::{run_cl2d, run_cl2d_cfg, telemetry::BenchRecorder, Figure};
use ops_oc::coordinator::{Config, Platform};
use ops_oc::memory::{AppCalib, Link};
use std::time::Instant;

const HOST_GB: f64 = 64.0;

fn main() {
    let t0 = Instant::now();
    let legacy = Platform::GpuExplicit {
        link: Link::PciE,
        cyclic: true,
        prefetch: true,
    };
    let (two, _) = Config::parse_spec("tiers:gpu-explicit-pcie:cyclic:prefetch").unwrap();
    let two = Config::for_target(two, AppCalib::CLOVERLEAF_2D);
    let (three, _) = Config::parse_spec(
        "tiers:hbm=16g@509.7+host=64g@11~0.00001+nvme=inf@6~0.00002:cyclic:prefetch",
    )
    .unwrap();
    let three = Config::for_target(three, AppCalib::CLOVERLEAF_2D);

    let mut fig = Figure::new(
        "Three-tier scaling: CloverLeaf 2D past HBM (16 GB) and host DRAM (64 GB)",
        "effective GB/s (modelled)",
    );
    let s_legacy = fig.add_series("gpu-explicit (legacy)");
    let s_two = fig.add_series("tiers: hbm+host");
    let s_three = fig.add_series("tiers: hbm+host+nvme");

    // sweep across both capacity boundaries
    let sizes = [6.0, 12.0, 16.0, 24.0, 48.0, 64.0, 96.0, 128.0, 192.0];
    let mut rec = BenchRecorder::new("fig_threetier_scaling");
    let mut in_host: Option<f64> = None; // three-tier bw below the host boundary
    let mut past_host: Option<f64> = None;
    for gb in sizes {
        let (ml, oom_l) = run_cl2d(legacy, 8, 6144, gb, 2, 0);
        let (m2, oom_2) = run_cl2d_cfg(&two, false, 8, 6144, gb, 2, 0);
        let (m3, oom_3) = run_cl2d_cfg(&three, false, 8, 6144, gb, 2, 0);
        assert!(!oom_l && !oom_2 && !oom_3, "streaming never OOMs at {gb} GB");
        rec.point(
            &format!("cloverleaf2d|hbm+host|{gb:.0}"),
            "cloverleaf2d",
            "tiers:hbm+host",
            gb,
            &m2,
            oom_2,
        );
        rec.point(
            &format!("cloverleaf2d|hbm+host+nvme|{gb:.0}"),
            "cloverleaf2d",
            "tiers:hbm+host+nvme",
            gb,
            &m3,
            oom_3,
        );
        assert_eq!(
            ml.elapsed_s, m2.elapsed_s,
            "two-tier TieredEngine must match the legacy engine bit-exactly at {gb} GB"
        );
        let (b2, b3) = (m2.effective_bandwidth_gbs(), m3.effective_bandwidth_gbs());
        assert!(
            b3 <= b2 + 1e-9,
            "a third tier can only cost bandwidth: {b3} > {b2} at {gb} GB"
        );
        if gb <= 48.0 {
            // every chain fits host DRAM: the nvme boundary is silent
            // and the three-tier stack models the two-tier clock exactly
            assert_eq!(
                m2.elapsed_s, m3.elapsed_s,
                "in-host three-tier must be free at {gb} GB"
            );
            in_host = Some(b3);
        }
        if gb >= 2.0 * HOST_GB && past_host.is_none() {
            past_host = Some(b3);
        }
        fig.push(s_legacy, gb, Some(ml.effective_bandwidth_gbs()));
        fig.push(s_two, gb, Some(b2));
        fig.push(s_three, gb, Some(b3));
        // past the host boundary the NVMe stream dominates the model
        if gb >= 2.0 * HOST_GB {
            assert_eq!(
                m3.bound().name(),
                "upload",
                "past host DRAM the run is stream-bound"
            );
            assert!(
                b3 < b2,
                "the nvme stream must cost bandwidth past host DRAM: {b3} !< {b2}"
            );
        }
    }
    let small3 = in_host.expect("swept below the host boundary");
    let big3 = past_host.expect("swept past the host boundary");
    assert!(
        big3 < small3,
        "crossing the host boundary must cost bandwidth: {big3} !< {small3}"
    );
    println!("{}", fig.render());
    println!(
        "three-tier keeps computing at {:.1} GB/s past host DRAM (in-host: {:.1} GB/s)",
        big3, small3
    );
    match rec.write() {
        Ok(p) => println!("trajectory: {}", p.display()),
        Err(e) => eprintln!("cannot write trajectory: {e}"),
    }
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
