//! Tuner-gain figure (extension): heuristic vs auto-tuned modelled
//! performance across all three paper apps × tunable platforms.
//!
//! Per cell: run once with the seed `HBM/3`-style heuristic, once with
//! `--tune`, and report effective bandwidth plus the tuner's own
//! modelled speedup (Σ heuristic model time / Σ tuned model time). The
//! never-worse guarantee means every speedup is ≥ 1.0×; the run asserts
//! that, and that at least one cell is strictly > 1.0×.

use ops_oc::bench_support::{run_cl2d_tuned, run_cl3d_tuned, run_sbli_tall_tuned, Figure};
use ops_oc::coordinator::Config;
use ops_oc::exec::Metrics;
use ops_oc::tuner::TuneOpts;
use std::time::Instant;

const PLATFORMS: &[&str] = &[
    "knl-cache-tiled",
    "gpu-explicit:pcie:cyclic:prefetch",
    "gpu-explicit:nvlink:cyclic:prefetch",
    "gpu-unified:pcie:tiled:prefetch",
    "gpu-explicit:nvlink:cyclic:prefetch:x4",
];

const APPS: &[&str] = &["cloverleaf2d", "cloverleaf3d", "opensbli"];

fn run_cell(app: &str, spec: &str, tune: Option<TuneOpts>, gb: f64) -> Metrics {
    let p = Config::parse_platform(spec).expect("bench spec");
    let steps = 2;
    let (m, oom) = match app {
        "cloverleaf2d" => run_cl2d_tuned(p, tune, 8, 6144, gb, steps, 0),
        "cloverleaf3d" => run_cl3d_tuned(p, tune, [8, 8, 6144], gb, steps, 0),
        _ => run_sbli_tall_tuned(p, tune, 1, gb, steps),
    };
    assert!(!oom, "{app} on {spec} must fit out-of-core");
    m
}

fn main() {
    let t0 = Instant::now();
    let gb = 48.0;
    // half the default budget: unified-memory scoring is page-granular,
    // so full-size sweeps add up
    let tune = TuneOpts {
        budget: 24,
        ..TuneOpts::default()
    };

    let mut fig = Figure::new(
        "Tuner gain: effective GB/s at 48 GB, heuristic vs tuned (x = app*platform cell)",
        "effective GB/s (modelled)",
    );
    let s_heur = fig.add_series("heuristic");
    let s_tuned = fig.add_series("tuned");

    let mut strict_cells = 0usize;
    let mut cells = 0usize;
    println!(
        "{:<14} {:<38} {:>10} {:>10} {:>9} {:>7}",
        "app", "platform", "heur GB/s", "tuned GB/s", "model x", "evals"
    );
    for (ai, app) in APPS.iter().enumerate() {
        for (pi, spec) in PLATFORMS.iter().enumerate() {
            let x = (ai * PLATFORMS.len() + pi) as f64;
            let heur = run_cell(app, spec, None, gb);
            let tuned = run_cell(app, spec, Some(tune), gb);
            let speedup = tuned.tune_model_speedup();
            assert!(
                speedup >= 1.0 - 1e-12,
                "never-worse violated on {app}/{spec}: {speedup}"
            );
            if speedup > 1.0 + 1e-9 {
                strict_cells += 1;
            }
            cells += 1;
            println!(
                "{:<14} {:<38} {:>10.1} {:>10.1} {:>8.3}x {:>7}",
                app,
                spec,
                heur.effective_bandwidth_gbs(),
                tuned.effective_bandwidth_gbs(),
                speedup,
                tuned.tune_evals,
            );
            fig.push(s_heur, x, Some(heur.effective_bandwidth_gbs()));
            fig.push(s_tuned, x, Some(tuned.effective_bandwidth_gbs()));
        }
    }
    println!();
    println!("{}", fig.render());
    println!(
        "strictly improved cells: {strict_cells}/{cells} (all cells >= 1.0x by construction)"
    );
    assert!(
        strict_cells >= 1,
        "expected the tuner to strictly beat the heuristic somewhere"
    );
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
