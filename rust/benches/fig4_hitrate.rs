//! Figure 4: MCDRAM cache hit rate on CloverLeaf 2D, with and without
//! tiling, as the problem grows past the 16 GB cache.
use ops_oc::bench_support::{run_cl2d, Figure, KNL_SIZES_GB};
use ops_oc::coordinator::Platform;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let mut fig = Figure::new(
        "Fig 4: MCDRAM cache hit rate, CloverLeaf 2D",
        "hit rate (%)",
    );
    for (name, p) in [
        ("cache", Platform::KnlCache),
        ("cache tiled", Platform::KnlCacheTiled),
    ] {
        let s = fig.add_series(name);
        for gb in KNL_SIZES_GB {
            let (m, oom) = run_cl2d(p, 8, 6144, gb, 4, 2);
            fig.push(s, gb, (!oom).then(|| m.cache_hit_rate() * 100.0));
        }
    }
    println!("{}", fig.render());
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
