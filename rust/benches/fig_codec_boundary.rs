//! Codec boundary sweep: compression ratio × problem size across each
//! tier boundary.
//!
//! Shen et al. (arXiv 2204.11315) compress GPU stencil state 2–5×
//! before it crosses the host boundary; this figure attaches a `~c:`
//! codec to the NVMe link of the three-tier stack and sweeps the
//! problem size across both capacity boundaries at several ratios.
//! The claims under test:
//!
//! * while the problem fits host DRAM the NVMe codec is silent — every
//!   in-host cell is *bit-identical* to its codec-free twin;
//! * past the host boundary the slowest-tier wire traffic drops by at
//!   least `min(ratio, 2)/2×` (the conservative floor: ceil rounding
//!   and per-tile minimum wire bytes eat into small ratios);
//! * the auto-tuner's codec toggle honours the never-worse guarantee
//!   (`tuned_model_s <= heuristic_model_s`) with codecs in the space;
//! * with slow codec kernels, at least one swept cell flips from
//!   transfer-bound to **codec-bound** — the attribution the codec
//!   stream exists to make visible.

use ops_oc::bench_support::{
    run_cl2d_cfg, slowest_boundary_upload_bytes, telemetry::BenchRecorder, Figure,
};
use ops_oc::coordinator::Config;
use ops_oc::memory::AppCalib;
use ops_oc::tuner::TuneOpts;
use std::time::Instant;

const HOST_GB: f64 = 64.0;

fn stack(codec: &str) -> String {
    format!("tiers:hbm=16g@509.7+host=64g@11~0.00001+nvme=inf@6~0.00002{codec}:cyclic:prefetch")
}

fn cfg_for(spec: &str) -> Config {
    let (t, _) = Config::parse_spec(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
    Config::for_target(t, AppCalib::CLOVERLEAF_2D)
}

fn main() {
    let t0 = Instant::now();
    let plain = cfg_for(&stack(""));
    let ratios = [1.5, 2.5, 3.5];

    let mut fig = Figure::new(
        "Codec boundary sweep: CloverLeaf 2D, NVMe-link codec past host DRAM (64 GB)",
        "effective GB/s (modelled)",
    );
    let s_plain = fig.add_series("no codec");
    let s_ratio: Vec<usize> = ratios
        .iter()
        .map(|r| fig.add_series(&format!("~c:{r}")))
        .collect();

    let sizes = [8.0, 16.0, 32.0, 48.0, 96.0, 128.0, 192.0];
    let mut rec = BenchRecorder::new("fig_codec_boundary");
    for gb in sizes {
        let (mp, oom_p) = run_cl2d_cfg(&plain, false, 8, 6144, gb, 2, 0);
        assert!(!oom_p, "streaming never OOMs at {gb} GB");
        rec.point(
            &format!("cloverleaf2d|plain|{gb:.0}"),
            "cloverleaf2d",
            "tiers:3t",
            gb,
            &mp,
            oom_p,
        );
        fig.push(s_plain, gb, Some(mp.effective_bandwidth_gbs()));
        let plain_bytes = slowest_boundary_upload_bytes(&plain.topology(), &mp);

        for (i, ratio) in ratios.iter().enumerate() {
            let ccfg = cfg_for(&stack(&format!("~c:{ratio}")));
            let (mc, oom_c) = run_cl2d_cfg(&ccfg, false, 8, 6144, gb, 2, 0);
            assert!(!oom_c, "{gb} GB at ratio {ratio}");
            rec.point(
                &format!("cloverleaf2d|c{ratio}|{gb:.0}"),
                "cloverleaf2d",
                &format!("tiers:3t~c:{ratio}"),
                gb,
                &mc,
                oom_c,
            );
            fig.push(s_ratio[i], gb, Some(mc.effective_bandwidth_gbs()));
            // §5.1 byte accounting is schedule- and codec-independent
            assert_eq!(mp.loop_bytes, mc.loop_bytes, "{gb} GB ratio {ratio}");

            if gb <= 48.0 {
                // fits host DRAM: the NVMe boundary (and its codec) is
                // silent — the cell is bit-identical to the plain twin
                assert_eq!(
                    mp.elapsed_s.to_bits(),
                    mc.elapsed_s.to_bits(),
                    "in-host cell must be bit-identical at {gb} GB ratio {ratio}"
                );
                assert_eq!(mc.codec_bytes_saved, 0, "{gb} GB ratio {ratio}");
            } else if gb >= 2.0 * HOST_GB {
                // past host DRAM: the codec pays off on the slowest tier
                let codec_bytes = slowest_boundary_upload_bytes(&ccfg.topology(), &mc);
                assert!(
                    codec_bytes < plain_bytes,
                    "{gb} GB ratio {ratio}: {codec_bytes} !< {plain_bytes}"
                );
                let reduction = plain_bytes as f64 / codec_bytes as f64;
                let floor = ratio.min(2.0) / 2.0;
                assert!(
                    reduction >= floor,
                    "{gb} GB ratio {ratio}: wire reduction {reduction:.2} < floor {floor:.2}"
                );
                assert!(mc.codec_bytes_saved > 0, "{gb} GB ratio {ratio}");
                assert!(
                    mc.elapsed_s <= mp.elapsed_s * (1.0 + 1e-9),
                    "{gb} GB ratio {ratio}: a fast codec never costs time"
                );
            }
        }
    }

    // the tuner's codec toggle keeps the never-worse guarantee with
    // codecs in the candidate space
    let tuned = cfg_for(&stack("~c:3.5"))
        .with_tuning(TuneOpts { budget: 32, seed: 0xC0DEC })
        .expect("tiered targets are tunable");
    let (mt, oom_t) = run_cl2d_cfg(&tuned, false, 8, 6144, 128.0, 2, 0);
    assert!(!oom_t);
    assert!(mt.tune_evals > 0, "the search must actually run");
    assert!(
        mt.tuned_model_s <= mt.heuristic_model_s,
        "codec toggle breaks never-worse: {} > {}",
        mt.tuned_model_s,
        mt.heuristic_model_s
    );
    rec.point(
        "cloverleaf2d|c3.5-tuned|128",
        "cloverleaf2d",
        "tiers:3t~c:3.5:tuned",
        128.0,
        &mt,
        oom_t,
    );

    // slow codec kernels past the boundary: the run must report itself
    // codec-bound — the flip this subsystem exists to attribute
    let slow = cfg_for(&stack("~c:3.5@1/1.5"));
    let (ms, oom_s) = run_cl2d_cfg(&slow, false, 8, 6144, 128.0, 2, 0);
    assert!(!oom_s);
    assert_eq!(
        ms.bound().name(),
        "codec",
        "1 GB/s codec kernels against a 6 GB/s NVMe link must dominate"
    );
    rec.point(
        "cloverleaf2d|c3.5-slowkernels|128",
        "cloverleaf2d",
        "tiers:3t~c:3.5@1/1.5",
        128.0,
        &ms,
        oom_s,
    );

    println!("{}", fig.render());
    println!(
        "codec-bound cell at 128 GB: bound={} (slow kernels), saved {} wire bytes at ratio 3.5",
        ms.bound().name(),
        ms.codec_bytes_saved
    );
    match rec.write() {
        Ok(p) => println!("trajectory: {}", p.display()),
        Err(e) => eprintln!("cannot write trajectory: {e}"),
    }
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
