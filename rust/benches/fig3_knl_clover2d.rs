//! Figure 3: CloverLeaf 2D problem scaling on the KNL — flat DDR4, flat
//! MCDRAM (OOM > 16 GB), cache mode, cache mode + tiling.
use ops_oc::bench_support::{bw_point, run_cl2d, telemetry::BenchRecorder, Figure, KNL_SIZES_GB};
use ops_oc::coordinator::Platform;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let mut fig = Figure::new(
        "Fig 3: CloverLeaf 2D problem scaling on the KNL",
        "effective GB/s (modelled)",
    );
    let mut rec = BenchRecorder::new("fig3_knl_clover2d");
    let series = [
        ("flat DDR4", Platform::KnlFlatDdr4),
        ("flat MCDRAM", Platform::KnlFlatMcdram),
        ("cache", Platform::KnlCache),
        ("cache tiled", Platform::KnlCacheTiled),
    ];
    for (name, p) in series {
        let s = fig.add_series(name);
        for gb in KNL_SIZES_GB {
            let (m, oom) = run_cl2d(p, 8, 6144, gb, 4, 2);
            rec.point(
                &format!("cloverleaf2d|{name}|{gb:.0}"),
                "cloverleaf2d",
                name,
                gb,
                &m,
                oom,
            );
            fig.push(s, gb, bw_point((m, oom)));
        }
    }
    println!("{}", fig.render());
    match rec.write() {
        Ok(p) => println!("trajectory: {}", p.display()),
        Err(e) => eprintln!("cannot write trajectory: {e}"),
    }
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
