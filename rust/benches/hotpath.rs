//! Hot-path microbenchmarks for the §Perf pass: the simulator and
//! planner components that sit on the coordinator's critical path, plus
//! the kernel-backend point-throughput comparison (closure-based
//! [`NativeExecutor`] vs the IR-compiling [`VectorExecutor`]) recorded
//! to `BENCH_hotpath.json`.
//!
//! The backend comparison runs the same [`LoopInst`] — carrying both a
//! handwritten closure and the mirrored kernel IR — through both
//! executors, asserts the outputs are bit-identical, and asserts the
//! vector backend is not slower on the star-stencil case (the CI smoke
//! gate).
use ops_oc::bench_support::telemetry::BenchRecorder;
use ops_oc::exec::{Executor, Metrics, NativeExecutor, VectorExecutor};
use ops_oc::memory::{AddressMap, CacheSim};
use ops_oc::ops::kernel::kernel;
use ops_oc::ops::stencil::shapes;
use ops_oc::ops::*;
use ops_oc::tiling::dependency::compute_shifts;
use ops_oc::tiling::plan::plan_chain;
use std::hint::black_box;
use std::sync::Arc;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: u32, unit_per_iter: f64, unit: &str, mut f: F) {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "{name:<34} {:>10.3} ms/iter   {:>10.1} M{unit}/s",
        dt * 1e3,
        unit_per_iter / dt / 1e6
    );
}

fn fixture(nds: u32, ny: usize) -> (Vec<Dataset>, Vec<Stencil>, Vec<LoopInst>) {
    let datasets: Vec<Dataset> = (0..nds)
        .map(|i| Dataset {
            id: DatasetId(i),
            block: BlockId(0),
            name: format!("d{i}"),
            size: [16, ny, 1],
            halo_lo: [2, 2, 0],
            halo_hi: [2, 2, 0],
            elem_bytes: 8,
        })
        .collect();
    let stencils = vec![
        Stencil { id: StencilId(0), name: "pt".into(), points: shapes::point() },
        Stencil { id: StencilId(1), name: "s1".into(), points: shapes::star2d(1) },
    ];
    let chain: Vec<LoopInst> = (0..128)
        .map(|li| LoopInst {
            name: format!("l{li}"),
            block: BlockId(0),
            range: [(0, 16), (0, ny as isize), (0, 1)],
            args: vec![
                Arg::dat(DatasetId(li % nds), StencilId(1), Access::Read),
                Arg::dat(DatasetId((li + 1) % nds), StencilId(0), Access::Write),
            ],
            kernel: kernel(|c| {
                let v = c.r(0, -1, 0) + c.r(0, 1, 0);
                c.w(1, 0, 0, v);
            }),
            kernel_ir: None,
            seq: li as u64,
            bw_efficiency: 1.0,
        })
        .collect();
    (datasets, stencils, chain)
}

/// Backend-comparison grid: wide x extent so the row programs have
/// something to vectorise.
const KX: usize = 1024;
const KY: usize = 512;

fn kdat(i: u32) -> Dataset {
    Dataset {
        id: DatasetId(i),
        block: BlockId(0),
        name: format!("k{i}"),
        size: [KX, KY, 1],
        halo_lo: [1, 1, 0],
        halo_hi: [1, 1, 0],
        elem_bytes: 8,
    }
}

/// One kernel case: a `LoopInst` carrying a handwritten closure (the
/// native path) and the mirrored IR (the vector path), plus the dataset
/// the kernel writes so outputs can be compared bit-exactly.
struct KernelCase {
    name: &'static str,
    datasets: Vec<Dataset>,
    l: LoopInst,
    out: DatasetId,
}

fn star_case() -> KernelCase {
    let mut k = KirBuilder::new();
    k.store(
        1,
        kir::read(0, [-1, 0, 0]) + kir::read(0, [1, 0, 0]) + kir::read(0, [0, -1, 0])
            + kir::read(0, [0, 1, 0])
            - kir::lit(4.0) * kir::read(0, [0, 0, 0]),
    );
    KernelCase {
        name: "star5",
        datasets: vec![kdat(0), kdat(1)],
        l: LoopInst {
            name: "star5".into(),
            block: BlockId(0),
            range: [(0, KX as isize), (0, KY as isize), (0, 1)],
            args: vec![
                Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                Arg::dat(DatasetId(1), StencilId(0), Access::Write),
            ],
            kernel: kernel(|c| {
                let v = c.r(0, -1, 0) + c.r(0, 1, 0) + c.r(0, 0, -1) + c.r(0, 0, 1)
                    - 4.0 * c.r(0, 0, 0);
                c.w(1, 0, 0, v);
            }),
            kernel_ir: Some(Arc::new(k.build())),
            seq: 0,
            bw_efficiency: 1.0,
        },
        out: DatasetId(1),
    }
}

fn axpy_case() -> KernelCase {
    let mut k = KirBuilder::new();
    k.store(2, kir::read(0, [0, 0, 0]) + kir::lit(2.5) * kir::read(1, [0, 0, 0]));
    KernelCase {
        name: "axpy",
        datasets: vec![kdat(0), kdat(1), kdat(2)],
        l: LoopInst {
            name: "axpy".into(),
            block: BlockId(0),
            range: [(0, KX as isize), (0, KY as isize), (0, 1)],
            args: vec![
                Arg::dat(DatasetId(0), StencilId(0), Access::Read),
                Arg::dat(DatasetId(1), StencilId(0), Access::Read),
                Arg::dat(DatasetId(2), StencilId(0), Access::Write),
            ],
            kernel: kernel(|c| {
                c.w(2, 0, 0, c.r(0, 0, 0) + 2.5 * c.r(1, 0, 0));
            }),
            kernel_ir: Some(Arc::new(k.build())),
            seq: 0,
            bw_efficiency: 1.0,
        },
        out: DatasetId(2),
    }
}

/// Allocate + deterministically seed every dataset of a case.
fn seeded_store(datasets: &[Dataset]) -> DataStore {
    let mut store = DataStore::new();
    for d in datasets {
        store.alloc(d);
        let buf = store.buf_mut(d.id);
        for (j, v) in buf.iter_mut().enumerate() {
            *v = ((j * 31 + d.id.0 as usize * 7) % 1000) as f64 * 1e-3;
        }
    }
    store
}

/// Best-of-3 timing of `iters` loop executions; returns ns/point.
fn time_loop(
    exec: &mut dyn Executor,
    l: &LoopInst,
    datasets: &[Dataset],
    store: &mut DataStore,
    iters: u32,
) -> f64 {
    let mut reds: Vec<Reduction> = vec![];
    exec.run_loop(l, l.range, datasets, store, &mut reds); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        for _ in 0..iters {
            exec.run_loop(l, l.range, datasets, store, &mut reds);
        }
        best = best.min(t0.elapsed().as_secs_f64() / iters as f64);
    }
    best * 1e9 / (KX * KY) as f64
}

/// Run one case through both backends: bit-exact check, ns/point per
/// backend into `rec`, returns `(native_ns, vector_ns)`.
fn run_case(rec: &mut BenchRecorder, case: &KernelCase, iters: u32) -> (f64, f64) {
    let size_gb = case.datasets.iter().map(Dataset::bytes).sum::<u64>() as f64 / 1e9;
    let mut nat_store = seeded_store(&case.datasets);
    let mut vec_store = seeded_store(&case.datasets);
    let mut nexec = NativeExecutor::new();
    let mut vexec = VectorExecutor::new();
    let nat_ns = time_loop(&mut nexec, &case.l, &case.datasets, &mut nat_store, iters);
    let vec_ns = time_loop(&mut vexec, &case.l, &case.datasets, &mut vec_store, iters);
    // the comparison is meaningless if the IR silently fell back
    let (vectorised, fallback) = vexec.kir_loop_stats();
    assert!(
        vectorised > 0 && fallback == 0,
        "{}: vector backend fell back to the closure path",
        case.name
    );
    assert_eq!(
        nat_store.buf(case.out),
        vec_store.buf(case.out),
        "{}: vector output diverged from native",
        case.name
    );
    for (backend, ns) in [("native", nat_ns), ("vector", vec_ns)] {
        let m = Metrics {
            elapsed_s: ns * 1e-9,
            exec_backend: backend.to_string(),
            ..Default::default()
        };
        rec.point(
            &format!("{}|{backend}", case.name),
            case.name,
            backend,
            size_gb,
            &m,
            false,
        );
    }
    println!(
        "kernel {:<10} native {:>7.2} ns/pt   vector {:>7.2} ns/pt   speedup {:>5.2}x",
        case.name,
        nat_ns,
        vec_ns,
        nat_ns / vec_ns
    );
    (nat_ns, vec_ns)
}

fn main() {
    println!("== hot-path microbenches ==");

    // 1. cache simulator: granule access throughput
    let mut sim = CacheSim::new(16 << 30, 1 << 20);
    let n_granules = 200_000u64;
    bench("cache_sim.access_range", 20, n_granules as f64, "granule", || {
        let r = sim.access_range(black_box(0), n_granules * (1 << 20), true, false);
        black_box(r);
    });

    // 2. dependency analysis (O(L^2 * args)) on a 128-loop chain
    let (datasets, stencils, chain) = fixture(25, 4096);
    bench("compute_shifts(128 loops)", 50, 128.0, "loop", || {
        black_box(compute_shifts(&chain, &stencils, 1));
    });

    // 3. full plan construction, 64 tiles
    bench("plan_chain(128 loops, 64 tiles)", 20, 128.0 * 64.0, "loop-tile", || {
        black_box(plan_chain(&chain, &datasets, &stencils, 64));
    });

    // 4. kernel point throughput: closure path vs compiled row programs
    let mut rec = BenchRecorder::new("hotpath");
    let (star_nat, star_vec) = run_case(&mut rec, &star_case(), 20);
    run_case(&mut rec, &axpy_case(), 20);
    let path = rec.write().expect("write BENCH_hotpath.json");
    println!("trajectory -> {}", path.display());
    // CI smoke gate: the vector backend must not be slower than the
    // closure path on the star-stencil case.
    assert!(
        star_vec <= star_nat,
        "vector backend slower on star5: {star_vec:.2} ns/pt vs {star_nat:.2} ns/pt native"
    );

    // 5. address-map slab computation
    let map = AddressMap::new(&datasets, 1 << 20);
    bench("address_map.slab x128", 1000, 128.0, "slab", || {
        for l in &chain {
            for (d, s, _) in l.dat_args() {
                let slab = map.slab(&datasets[d.0 as usize], &stencils[s.0 as usize], &l.range, 1);
                black_box(slab);
            }
        }
    });
}
