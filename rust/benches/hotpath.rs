//! Hot-path microbenchmarks for the §Perf pass: the simulator and
//! planner components that sit on the coordinator's critical path.
use ops_oc::memory::{AddressMap, CacheSim};
use ops_oc::ops::kernel::kernel;
use ops_oc::ops::stencil::shapes;
use ops_oc::ops::*;
use ops_oc::exec::{Executor, NativeExecutor};
use ops_oc::tiling::plan::plan_chain;
use ops_oc::tiling::dependency::compute_shifts;
use std::hint::black_box;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: u32, unit_per_iter: f64, unit: &str, mut f: F) {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "{name:<34} {:>10.3} ms/iter   {:>10.1} M{unit}/s",
        dt * 1e3,
        unit_per_iter / dt / 1e6
    );
}

fn fixture(nds: u32, ny: usize) -> (Vec<Dataset>, Vec<Stencil>, Vec<LoopInst>) {
    let datasets: Vec<Dataset> = (0..nds)
        .map(|i| Dataset {
            id: DatasetId(i),
            block: BlockId(0),
            name: format!("d{i}"),
            size: [16, ny, 1],
            halo_lo: [2, 2, 0],
            halo_hi: [2, 2, 0],
            elem_bytes: 8,
        })
        .collect();
    let stencils = vec![
        Stencil { id: StencilId(0), name: "pt".into(), points: shapes::point() },
        Stencil { id: StencilId(1), name: "s1".into(), points: shapes::star2d(1) },
    ];
    let chain: Vec<LoopInst> = (0..128)
        .map(|li| LoopInst {
            name: format!("l{li}"),
            block: BlockId(0),
            range: [(0, 16), (0, ny as isize), (0, 1)],
            args: vec![
                Arg::dat(DatasetId(li % nds), StencilId(1), Access::Read),
                Arg::dat(DatasetId((li + 1) % nds), StencilId(0), Access::Write),
            ],
            kernel: kernel(|c| {
                let v = c.r(0, -1, 0) + c.r(0, 1, 0);
                c.w(1, 0, 0, v);
            }),
            seq: li as u64,
            bw_efficiency: 1.0,
        })
        .collect();
    (datasets, stencils, chain)
}

fn main() {
    println!("== hot-path microbenches ==");

    // 1. cache simulator: granule access throughput
    let mut sim = CacheSim::new(16 << 30, 1 << 20);
    let n_granules = 200_000u64;
    bench("cache_sim.access_range", 20, n_granules as f64, "granule", || {
        let r = sim.access_range(black_box(0), n_granules * (1 << 20), true, false);
        black_box(r);
    });

    // 2. dependency analysis (O(L^2 * args)) on a 128-loop chain
    let (datasets, stencils, chain) = fixture(25, 4096);
    bench("compute_shifts(128 loops)", 50, 128.0, "loop", || {
        black_box(compute_shifts(&chain, &stencils, 1));
    });

    // 3. full plan construction, 64 tiles
    bench("plan_chain(128 loops, 64 tiles)", 20, 128.0 * 64.0, "loop-tile", || {
        black_box(plan_chain(&chain, &datasets, &stencils, 64));
    });

    // 4. native executor point throughput
    let mut store = DataStore::new();
    datasets.iter().for_each(|d| store.alloc(d));
    let mut reds: Vec<Reduction> = vec![];
    let mut exec = NativeExecutor::new();
    let pts = 16.0 * 4096.0 * 8.0;
    bench("native executor (8 loops)", 10, pts, "point", || {
        for l in chain.iter().take(8) {
            exec.run_loop(l, l.range, &datasets, &mut store, &mut reds);
        }
    });

    // 5. address-map slab computation
    let map = AddressMap::new(&datasets, 1 << 20);
    bench("address_map.slab x128", 1000, 128.0, "slab", || {
        for l in &chain {
            for (d, s, _) in l.dat_args() {
                let slab = map.slab(&datasets[d.0 as usize], &stencils[s.0 as usize], &l.range, 1);
                black_box(slab);
            }
        }
    });
}
