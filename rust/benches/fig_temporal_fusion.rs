//! Temporal super-tiling: fuse `k` replays of the recorded step chain
//! into one skewed super-chain, so each tile's data crosses the slowest
//! memory boundary once per `k` steps instead of once per step.
//!
//! CloverLeaf 2D on a three-tier HBM (16 GiB) → host DRAM (64 GiB) →
//! NVMe (unbounded, ~6 GB/s) stack, sweeping the fusion depth
//! `k ∈ {1, 2, 4, 8}` across problem sizes on both sides of the
//! host-DRAM boundary. The bench asserts the three claims the figure
//! illustrates:
//!
//! * **bit-exactness** — every fused run's store checksum equals the
//!   unfused (`k = 1`) replay of the same chain, at every size;
//! * **≈k× slowest-tier traffic reduction** — past the host boundary
//!   the NVMe→host upload bytes per step fall with `k`, within the
//!   skew-halo overhead;
//! * **tuner never loses** — `fuse = 0` (tuner-chosen depth) is never
//!   slower than the unfused replay.

use ops_oc::bench_support::{
    run_cl2d_fused_cfg, slowest_boundary_upload_bytes, telemetry::BenchRecorder, Figure,
};
use ops_oc::coordinator::Config;
use ops_oc::memory::AppCalib;
use std::time::Instant;

/// Replay count per cell — divisible by every depth in the sweep, so no
/// unfused tail clouds the per-step byte counts.
const REPLAYS: usize = 8;
const DEPTHS: [u32; 4] = [1, 2, 4, 8];
const HOST_GB: f64 = 64.0;

fn main() {
    let t0 = Instant::now();
    let (target, _) = Config::parse_spec(
        "tiers:hbm=16g@509.7+host=64g@11~0.00001+nvme=inf@6~0.00002:cyclic:prefetch",
    )
    .unwrap();
    let cfg = Config::for_target(target, AppCalib::CLOVERLEAF_2D);
    let topo = cfg.topology();

    let mut fig = Figure::new(
        "Temporal fusion: CloverLeaf 2D NVMe-boundary traffic vs fusion depth",
        "slowest-tier GB uploaded per step (modelled)",
    );
    let series: Vec<_> = DEPTHS
        .iter()
        .map(|k| fig.add_series(&format!("fuse k={k}")))
        .collect();
    let s_tuned = fig.add_series("fuse k=tuner");

    let mut rec = BenchRecorder::new("fig_temporal_fusion");
    // one size inside host DRAM (NVMe silent), two past the boundary
    for gb in [24.0, 96.0, 128.0] {
        let runs: Vec<_> = DEPTHS
            .iter()
            .map(|&k| run_cl2d_fused_cfg(&cfg.clone().with_fuse(k), false, 8, 6144, gb, REPLAYS))
            .collect();
        let tuned = run_cl2d_fused_cfg(&cfg.clone().with_fuse(0), false, 8, 6144, gb, REPLAYS);
        let base = &runs[0];
        assert!(!base.oom && !tuned.oom, "streaming never OOMs at {gb} GB");
        assert_eq!(base.k, 1, "fuse=1 must run unfused");

        for (r, &k) in runs.iter().zip(&DEPTHS) {
            assert!(!r.oom);
            assert_eq!(r.k as u32, k, "requested depth is the executed depth");
            // the whole point: fusion is a re-schedule, not a re-numbering
            assert_eq!(
                r.checksum, base.checksum,
                "fused k={k} diverged from the unfused replay at {gb} GB"
            );
            rec.point(
                &format!("cloverleaf2d|fuse{k}|{gb:.0}"),
                "cloverleaf2d",
                &format!("tiers:hbm+host+nvme fuse{k}"),
                gb,
                &r.metrics,
                r.oom,
            );
        }
        assert_eq!(
            tuned.checksum, base.checksum,
            "tuner-fused run diverged at {gb} GB"
        );

        let bytes: Vec<u64> = runs
            .iter()
            .map(|r| slowest_boundary_upload_bytes(&topo, &r.metrics))
            .collect();
        let per_step = |b: u64| b as f64 / REPLAYS as f64 / 1e9;
        for (s, &b) in series.iter().zip(&bytes) {
            fig.push(*s, gb, Some(per_step(b)));
        }
        fig.push(
            s_tuned,
            gb,
            Some(per_step(slowest_boundary_upload_bytes(&topo, &tuned.metrics))),
        );

        // deeper fusion can only remove slowest-boundary traffic
        for w in bytes.windows(2) {
            assert!(
                w[1] <= w[0],
                "slowest-tier bytes must not grow with k at {gb} GB: {w:?}"
            );
        }
        if gb > HOST_GB {
            // past host DRAM every step streams over the NVMe link, and
            // fusing k steps amortises that stream ≈k× (the skew halo
            // re-uploads a few hundred rows per tile boundary, a small
            // fraction of the 6144-row domain)
            assert!(bytes[0] > 0, "past-host runs must stream over NVMe");
            for (i, &k) in DEPTHS.iter().enumerate().skip(1) {
                let ratio = bytes[0] as f64 / bytes[i].max(1) as f64;
                assert!(
                    ratio >= k as f64 / 2.0,
                    "fuse k={k} at {gb} GB only cut NVMe bytes {ratio:.2}x \
                     (expected ≈{k}x, floor {}x)",
                    k as f64 / 2.0
                );
                println!("{gb:>4.0} GB  k={k}: NVMe bytes cut {ratio:.2}x");
            }
            assert!(
                tuned.metrics.fused_steps > 0,
                "past-host tuner must engage fusion accounting"
            );
        }

        // the tuner holds k=1 as the incumbent: it can never model slower
        assert!(
            tuned.metrics.elapsed_s <= base.metrics.elapsed_s * 1.001,
            "tuner-chosen k={} is slower than unfused at {gb} GB: {} > {}",
            tuned.k,
            tuned.metrics.elapsed_s,
            base.metrics.elapsed_s
        );
    }

    println!("{}", fig.render());
    match rec.write() {
        Ok(p) => println!("trajectory: {}", p.display()),
        Err(e) => eprintln!("cannot write trajectory: {e}"),
    }
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
