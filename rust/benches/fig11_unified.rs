//! Figure 11: problem scaling with Unified Memory on the P100 — plain
//! page migration, + tiling, + bulk prefetches; PCIe and NVLink.
use ops_oc::bench_support::{bw_point, run_cl2d, run_sbli_tall, Figure, GPU_SIZES_GB};
use ops_oc::coordinator::Platform;
use ops_oc::memory::Link;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    for app in ["CloverLeaf 2D", "OpenSBLI"] {
        let mut fig = Figure::new(
            &format!("Fig 11: {app} with Unified Memory"),
            "effective GB/s (modelled)",
        );
        for link in [Link::PciE, Link::NvLink] {
            let tag = if link == Link::PciE { "P" } else { "N" };
            for (name, tiled, prefetch) in [
                ("UM", false, false),
                ("UM tiled", true, false),
                ("UM tiled+prefetch", true, true),
            ] {
                let s = fig.add_series(&format!("{tag}-{name}"));
                // SBLI's deep-halo chains are compute-heavy; a 5-point
                // sweep keeps the full shape
                let sizes: &[f64] = if app == "OpenSBLI" {
                    &[6.0, 16.0, 24.0, 36.0, 47.0]
                } else {
                    &GPU_SIZES_GB
                };
                for &gb in sizes {
                    let p = Platform::GpuUnified { link, tiled, prefetch };
                    let v = match app {
                        "CloverLeaf 2D" => bw_point(run_cl2d(p, 8, 6144, gb, 8, 0)),
                        _ => bw_point(run_sbli_tall(p, 2, gb, 1)),
                    };
                    fig.push(s, gb, v);
                }
            }
        }
        println!("{}", fig.render());
    }
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
