//! Figure 12 (extension): multi-device strong/weak scaling of CloverLeaf
//! 2D under sharded execution — 1–8 modelled NVLink P100 ranks, each
//! running the explicit 3-slot streaming engine, halos exchanged over
//! NVLink peer links — plus the comm/compute-overlap ablation.

use ops_oc::bench_support::{run_cl2d, telemetry::BenchRecorder, Figure};
use ops_oc::coordinator::{InnerPlatform, Platform};
use ops_oc::distributed::{DecompKind, Interconnect};
use ops_oc::memory::Link;
use std::time::Instant;

fn sharded(ranks: u32, decomp: DecompKind, overlap: bool) -> Platform {
    Platform::Sharded {
        ranks,
        inner: InnerPlatform::GpuExplicit {
            link: Link::NvLink,
            cyclic: true,
            prefetch: true,
        },
        link: Interconnect::NvLink,
        decomp,
        overlap,
    }
}

fn main() {
    let t0 = Instant::now();
    let steps = 2;
    let ranks_sweep = [1u32, 2, 4, 8];

    // ---- strong scaling: fixed 48 GB problem, growing rank counts ------
    let mut strong = Figure::new(
        "Fig 12a: CloverLeaf 2D strong scaling, 48 GB (x axis = ranks)",
        "effective GB/s (modelled)",
    );
    let s_1d = strong.add_series("1D decomp");
    let s_2d = strong.add_series("2D decomp");
    let s_no = strong.add_series("1D no-overlap");
    let mut rec = BenchRecorder::new("fig12_multidevice_scaling");
    let mut elapsed_1 = 0.0;
    for &r in &ranks_sweep {
        let (m, _) = run_cl2d(sharded(r, DecompKind::OneD, true), 8, 6144, 48.0, steps, 0);
        if r == 1 {
            elapsed_1 = m.elapsed_s;
        }
        rec.point(
            &format!("cloverleaf2d|sharded-1d-x{r}|48"),
            "cloverleaf2d",
            &format!("sharded-1d-x{r}"),
            48.0,
            &m,
            false,
        );
        strong.push(s_1d, r as f64, Some(m.effective_bandwidth_gbs()));
        let (m2, _) = run_cl2d(sharded(r, DecompKind::TwoD, true), 8, 6144, 48.0, steps, 0);
        rec.point(
            &format!("cloverleaf2d|sharded-2d-x{r}|48"),
            "cloverleaf2d",
            &format!("sharded-2d-x{r}"),
            48.0,
            &m2,
            false,
        );
        strong.push(s_2d, r as f64, Some(m2.effective_bandwidth_gbs()));
        let (mn, _) = run_cl2d(sharded(r, DecompKind::OneD, false), 8, 6144, 48.0, steps, 0);
        strong.push(s_no, r as f64, Some(mn.effective_bandwidth_gbs()));
        println!(
            "strong x{r}: speedup {:.2}x vs 1 rank, overlap gain {:.3}x vs no-overlap",
            if m.elapsed_s > 0.0 { elapsed_1 / m.elapsed_s } else { 0.0 },
            if m.elapsed_s > 0.0 { mn.elapsed_s / m.elapsed_s } else { 0.0 },
        );
    }
    println!("{}", strong.render());

    // ---- weak scaling: 12 GB per rank ----------------------------------
    let mut weak = Figure::new(
        "Fig 12b: CloverLeaf 2D weak scaling, 12 GB/rank (x axis = ranks)",
        "effective GB/s (modelled)",
    );
    let w_1d = weak.add_series("1D decomp");
    for &r in &ranks_sweep {
        let gb = 12.0 * r as f64;
        let (m, _) = run_cl2d(sharded(r, DecompKind::OneD, true), 8, 6144, gb, steps, 0);
        weak.push(w_1d, r as f64, Some(m.effective_bandwidth_gbs()));
    }
    println!("{}", weak.render());

    // ---- per-rank detail at x4 (what `ops-oc run … x4` reports) --------
    let (m4, _) = run_cl2d(sharded(4, DecompKind::OneD, true), 8, 6144, 48.0, steps, 0);
    for (r, rs) in m4.per_rank.iter().enumerate() {
        println!(
            "x4 rank {r}: compute {:.4} s, exchange {:.4} s ({:.3} GB), avg bw {:.1} GB/s",
            rs.compute_s,
            rs.exchange_s,
            rs.exchange_bytes as f64 / 1e9,
            rs.average_bandwidth_gbs()
        );
    }

    match rec.write() {
        Ok(p) => println!("trajectory: {}", p.display()),
        Err(e) => eprintln!("cannot write trajectory: {e}"),
    }
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
