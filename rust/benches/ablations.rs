//! Design-choice ablations called out in DESIGN.md §5:
//!
//! 1. **Triple vs double buffering** — the paper's "three slots" let
//!    uploads, downloads and compute all overlap; with two slots the two
//!    copy directions serialise. This ablation quantifies what the third
//!    slot buys on each link.
//! 2. **KNL tile occupancy** — how much of MCDRAM a tile may fill:
//!    too small wastes reuse, too large causes direct-mapped conflicts.
//! 3. **Skew necessity** — plans built with dependency-derived shifts vs
//!    a (wrong) zero-shift schedule: counts how many tiles would read
//!    not-yet-computed data (correctness, not time).
#![allow(deprecated)] // exercises the legacy OpsContext shim on purpose

use ops_oc::apps::cloverleaf2d::CloverLeaf2D;
use ops_oc::bench_support::{base_bytes, model_scale, Figure};
use ops_oc::coordinator::{Config, Platform};
use ops_oc::exec::{Engine, Metrics, NativeExecutor, World};
use ops_oc::memory::{AppCalib, GpuCalib, GpuExplicitEngine, GpuOpts, KnlCalib, KnlEngine, Link};
use ops_oc::ops::OpsContext;
use std::time::Instant;

fn cl2d_ctx(scale: u64) -> (OpsContext, CloverLeaf2D) {
    let cfg = Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_2D);
    let mut ctx = OpsContext::new(cfg.build_engine());
    let app = CloverLeaf2D::new(&mut ctx, 8, 6144, scale);
    (ctx, app)
}

fn run_engine(engine: Box<dyn Engine>, scale: u64, steps: usize) -> Metrics {
    let cfg = Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_2D);
    let mut ctx = OpsContext::new(cfg.build_engine());
    let mut app = CloverLeaf2D::new(&mut ctx, 8, 6144, scale);
    // swap in the engine under test by rebuilding the context
    drop(ctx);
    let mut ctx = OpsContext::new(engine);
    app = CloverLeaf2D::new(&mut ctx, 8, 6144, scale);
    app.run(&mut ctx, steps, 0);
    ctx.metrics().clone()
}

fn main() {
    let t0 = Instant::now();
    let base = base_bytes(|ctx| {
        CloverLeaf2D::new(ctx, 8, 6144, 1);
    });

    // ---- 1. slots ablation -------------------------------------------------
    let mut fig = Figure::new(
        "Ablation: triple vs double buffering (CloverLeaf 2D, explicit)",
        "effective GB/s (modelled)",
    );
    for link in [Link::PciE, Link::NvLink] {
        for slots in [2u8, 3u8] {
            let s = fig.add_series(&format!("{}-{}slot", link.name(), slots));
            for gb in [16.0, 32.0, 47.0] {
                let scale = model_scale(base, gb);
                let e = GpuExplicitEngine::new(
                    GpuCalib::default(),
                    AppCalib::CLOVERLEAF_2D,
                    link,
                    GpuOpts {
                        cyclic: true,
                        prefetch: true,
                        slots,
                    },
                )
                .unwrap();
                let m = run_engine(Box::new(e), scale, 4);
                fig.push(s, gb, Some(m.effective_bandwidth_gbs()));
            }
        }
    }
    println!("{}", fig.render());

    // ---- 2. tile occupancy -------------------------------------------------
    let mut fig = Figure::new(
        "Ablation: KNL tile occupancy (fraction of MCDRAM per tile, 48 GB)",
        "effective GB/s (modelled)",
    );
    let s = fig.add_series("cache tiled");
    for occ in [0.15, 0.25, 0.35, 0.5, 0.7] {
        let scale = model_scale(base, 48.0);
        let mut e = KnlEngine::new(KnlCalib::default(), AppCalib::CLOVERLEAF_2D, true);
        e.tile_occupancy = occ;
        let m = run_engine(Box::new(e), scale, 4);
        // abuse the x axis: occupancy*100 instead of GB
        fig.push(s, occ * 100.0, Some(m.effective_bandwidth_gbs()));
    }
    println!("{}", fig.render());

    // ---- 3. skew necessity -------------------------------------------------
    // Plans with dependency shifts vs zero shifts: count loop-tile slices
    // whose stencil-extended reads exceed what earlier tiles + slices
    // produced (i.e. would-be race reads).
    let (mut ctx, mut app) = cl2d_ctx(1);
    app.initialise(&mut ctx);
    ctx.flush();
    app.step(&mut ctx);
    let chain = ctx.take_chain_for_debug();
    let plan = ops_oc::tiling::plan::plan_chain(&chain, ctx.datasets(), ctx.stencils(), 16);
    let max_shift = *plan.shifts.iter().max().unwrap();
    println!("### Ablation: skew necessity");
    println!(
        "chain: {} loops, dependency-derived max shift = {max_shift} planes",
        chain.len()
    );
    println!(
        "zero-shift schedule would violate {} flow dependencies per tile \
         boundary (every reader with radius > 0); the skewed schedule \
         violates none (verified bit-exact in rust/tests/).",
        chain
            .iter()
            .flat_map(|l| l.dat_args())
            .filter(|(_, s, a)| a.reads() && ctx.stencils()[s.0 as usize].radius(1) > 0)
            .count()
    );

    // keep the world alive for the borrow above
    let _ = (NativeExecutor::new(), World {
        datasets: ctx.datasets(),
        stencils: ctx.stencils(),
        store: &mut Default::default(),
        reds: &mut [],
        metrics: &mut Metrics::new(),
        exec: &mut NativeExecutor::new(),
    });
    println!("\nbench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
