//! Figure 9: the §4.1 optimisations on CloverLeaf 3D (P100).
use ops_oc::bench_support::{bw_point, run_cl3d, Figure, GPU_SIZES_GB};
use ops_oc::coordinator::Platform;
use ops_oc::memory::Link;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let mut fig = Figure::new(
        "Fig 9: tiling optimisations, CloverLeaf 3D on the P100",
        "effective GB/s (modelled)",
    );
    for link in [Link::PciE, Link::NvLink] {
        let tag = if link == Link::PciE { "P" } else { "N" };
        for (name, cyclic, prefetch) in [
            ("NoPrefetch NoCyclic", false, false),
            ("NoPrefetch Cyclic", true, false),
            ("Prefetch Cyclic", true, true),
        ] {
            let s = fig.add_series(&format!("{tag}-{name}"));
            for gb in GPU_SIZES_GB {
                fig.push(
                    s,
                    gb,
                    bw_point(run_cl3d(
                        Platform::GpuExplicit { link, cyclic, prefetch },
                        [8, 8, 6144],
                        gb,
                        2,
                        0,
                    )),
                );
            }
        }
    }
    println!("{}", fig.render());
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
