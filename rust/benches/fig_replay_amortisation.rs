//! Replay amortisation: per-step chain-analysis cost → ~0 after the
//! first replay.
//!
//! The legacy eager `OpsContext` re-runs the `O(L²·A²)` dependency/
//! footprint analysis at every flush; a frozen `Program` pays it once at
//! freeze time and every `Session::replay` reuses it (the run-time
//! tiling amortisation of Reguly et al., 1704.00693). This bench runs
//! the same diffusion and CloverLeaf 2D workloads both ways and reports
//! host-side wall time plus the `analysis_builds`/`analysis_reuse_hits`
//! counters; the counters are asserted, the timings are informative.

#![allow(deprecated)] // measures the legacy OpsContext shim on purpose

use ops_oc::apps::cloverleaf2d::CloverLeaf2D;
use ops_oc::apps::diffusion::Diffusion2D;
use ops_oc::coordinator::{Config, Platform};
use ops_oc::memory::AppCalib;
use ops_oc::ops::{Drive, OpsContext};
use ops_oc::program::{ProgramBuilder, Session};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let steps = 200;
    let p = Platform::KnlCacheTiled;

    println!("### Replay amortisation: per-step analysis cost (host wall clock)");
    println!("(numerics are identical on both paths; only analysis work differs)\n");

    // ---- diffusion, record-once vs eager --------------------------------
    let cfg = Config::new(p, AppCalib::CLOVERLEAF_2D);

    let t0 = Instant::now();
    let mut c = OpsContext::new(cfg.build_engine());
    let app = Diffusion2D::new(&mut c, 128, 128, 1);
    app.run(&mut c, steps, 1);
    let legacy_wall = t0.elapsed().as_secs_f64();
    let legacy_builds = c.metrics().analysis_builds;

    let t0 = Instant::now();
    let mut b = ProgramBuilder::new();
    let app = Diffusion2D::new(&mut b, 128, 128, 1);
    let chains = app.record_chains(&mut b, 1);
    let prog = Arc::new(b.freeze().expect("diffusion freezes"));
    let mut s = Session::new(prog, &cfg);
    s.run_chain(chains.init);
    s.reset_metrics();
    s.set_cyclic_phase(true);
    s.replay(chains.step, steps);
    let replay_wall = t0.elapsed().as_secs_f64();
    let m = s.metrics().clone();

    println!("diffusion 128x128, {steps} steps on {}:", p.label());
    println!(
        "  eager OpsContext : {legacy_wall:>8.3} s wall, {legacy_builds} analyses \
         ({:.1} us analysis-adjacent budget/step)",
        legacy_wall / steps as f64 * 1e6
    );
    println!(
        "  Program/Session  : {replay_wall:>8.3} s wall, {} analysis + {} reuse hits, \
         freeze {:.6} s (amortised {:.3} us/step)",
        m.analysis_builds,
        m.analysis_reuse_hits,
        m.program_freeze_s,
        m.program_freeze_s / steps as f64 * 1e6
    );
    assert_eq!(legacy_builds as usize, steps, "eager path analyses every step");
    assert_eq!(m.analysis_builds, 1, "replay path analyses once");
    assert_eq!(m.analysis_reuse_hits as usize, steps - 1);

    // ---- CloverLeaf 2D (long chains): session memo vs eager -------------
    let steps = 8;
    let t0 = Instant::now();
    let mut c = OpsContext::new(cfg.build_engine());
    let mut app = CloverLeaf2D::new(&mut c, 8, 1024, 1);
    app.run(&mut c, steps, 0);
    let legacy_wall = t0.elapsed().as_secs_f64();
    let legacy_builds = c.metrics().analysis_builds;
    let legacy_chains = c.metrics().chains;

    let t0 = Instant::now();
    let mut b = ProgramBuilder::new();
    let mut app = CloverLeaf2D::new(&mut b, 8, 1024, 1);
    let prog = Arc::new(b.freeze().expect("cloverleaf2d freezes"));
    let mut s = Session::new(prog, &cfg);
    app.run(&mut s, steps, 0);
    let session_wall = t0.elapsed().as_secs_f64();
    let m = s.metrics().clone();

    println!("\ncloverleaf2d 8x1024, {steps} steps (dt re-recorded per step):");
    println!(
        "  eager OpsContext : {legacy_wall:>8.3} s wall, {legacy_builds} analyses over {legacy_chains} chains"
    );
    println!(
        "  Session (memo)   : {session_wall:>8.3} s wall, {} analyses + {} reuse hits over {} chains",
        m.analysis_builds, m.analysis_reuse_hits, m.chains
    );
    assert_eq!(legacy_builds, legacy_chains, "eager path analyses every chain");
    assert!(
        m.analysis_builds < m.chains,
        "session memo must amortise: {} builds for {} chains",
        m.analysis_builds,
        m.chains
    );
    assert!(m.analysis_reuse_hits > 0);
    println!("\nper-step modelled analysis cost after the first replay: ~0 (cache hit)");
}
