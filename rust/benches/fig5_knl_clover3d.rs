//! Figure 5: CloverLeaf 3D problem scaling on the KNL.
use ops_oc::bench_support::{bw_point, run_cl3d, Figure, KNL_SIZES_GB};
use ops_oc::coordinator::Platform;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let mut fig = Figure::new(
        "Fig 5: CloverLeaf 3D problem scaling on the KNL",
        "effective GB/s (modelled)",
    );
    let series = [
        ("flat DDR4", Platform::KnlFlatDdr4),
        ("flat MCDRAM", Platform::KnlFlatMcdram),
        ("cache", Platform::KnlCache),
        ("cache tiled", Platform::KnlCacheTiled),
    ];
    for (name, p) in series {
        let s = fig.add_series(name);
        for gb in KNL_SIZES_GB {
            fig.push(s, gb, bw_point(run_cl3d(p, [8, 8, 6144], gb, 2, 2)));
        }
    }
    println!("{}", fig.render());
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
