//! CloverLeaf 3D: the three-dimensional variant of the hydro mini-app
//! (compressible Euler, staggered grid, predictor–corrector Lagrangian
//! step + directionally-split advection with x/y/z sweeps).
//!
//! Matches the paper's structure: **30 datasets** (7 cell-centred state
//! fields, 6 node-centred velocities, 6 face fluxes, 7 work arrays,
//! 4 geometry fields), ~46 stencil shapes across the kernels, and several
//! hundred parallel loops per timestep chain (the 3D advection is split
//! over three sweep directions and three velocity components).
//!
//! The kernels are the 3D generalisation of [`super::cloverleaf2d`]; the
//! direction-parametrised helpers keep the code compact while emitting
//! distinct named loops per sweep (as OPS code generation does).

use crate::ops::kernel::kernel;
use crate::ops::kir;
use crate::ops::stencil::shapes;
use crate::ops::{
    Access, Arg, BlockId, DatasetId, Declare, Drive, RedOp, Record, ReductionId, StencilId,
};

const G_SMALL: f64 = 1.0e-16;
const G_BIG: f64 = 1.0e21;

/// Sweep direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    X,
    Y,
    Z,
}

impl Dir {
    #[inline]
    fn o(self, k: isize) -> [isize; 3] {
        match self {
            Dir::X => [k, 0, 0],
            Dir::Y => [0, k, 0],
            Dir::Z => [0, 0, k],
        }
    }

    fn name(self) -> &'static str {
        match self {
            Dir::X => "x",
            Dir::Y => "y",
            Dir::Z => "z",
        }
    }

    fn all() -> [Dir; 3] {
        [Dir::X, Dir::Y, Dir::Z]
    }
}

/// Offsets of the `2^3` cells adjacent to a node (cell-to-node).
const CELL_TO_NODE: [[isize; 3]; 8] = [
    [0, 0, 0],
    [-1, 0, 0],
    [0, -1, 0],
    [-1, -1, 0],
    [0, 0, -1],
    [-1, 0, -1],
    [0, -1, -1],
    [-1, -1, -1],
];

/// Offsets of the `2^3` nodes adjacent to a cell (node-to-cell).
const NODE_TO_CELL: [[isize; 3]; 8] = [
    [0, 0, 0],
    [1, 0, 0],
    [0, 1, 0],
    [1, 1, 0],
    [0, 0, 1],
    [1, 0, 1],
    [0, 1, 1],
    [1, 1, 1],
];

/// Van-Leer limited difference as kernel IR (mirrors [`limited`]
/// term-by-term; the data-dependent branch becomes a `select`).
fn limited_ir(diffuw: kir::Expr, diffdw: kir::Expr, sigma: kir::Expr) -> kir::Expr {
    let auw = diffuw.clone().abs();
    let adw = diffdw.clone().abs();
    let wind = diffdw.clone().le(0.0).select(kir::lit(-1.0), kir::lit(1.0));
    let val = (kir::lit(1.0) - sigma.clone())
        * wind
        * (kir::lit(1.0 / 6.0)
            * ((kir::lit(1.0) + sigma.clone()) * auw.clone()
                + (kir::lit(2.0) - sigma) * adw.clone()))
        .min(auw)
        .min(adw);
    (diffuw * diffdw).gt(0.0).select(val, kir::lit(0.0))
}

/// `[isize; 3]` offset → stencil-point form for [`kir::read`].
#[inline]
fn pt(o: [isize; 3]) -> [i32; 3] {
    [o[0] as i32, o[1] as i32, o[2] as i32]
}

/// Van-Leer limited difference (same as 2D).
#[inline]
fn limited(diffuw: f64, diffdw: f64, sigma: f64) -> f64 {
    if diffuw * diffdw > 0.0 {
        let auw = diffuw.abs();
        let adw = diffdw.abs();
        let wind = if diffdw <= 0.0 { -1.0 } else { 1.0 };
        (1.0 - sigma)
            * wind
            * ((1.0 / 6.0) * ((1.0 + sigma) * auw + (2.0 - sigma) * adw))
                .min(auw)
                .min(adw)
    } else {
        0.0
    }
}

pub struct CloverLeaf3D {
    pub block: BlockId,
    pub n: [usize; 3],
    pub d: [f64; 3], // dx, dy, dz
    pub gamma: f64,
    pub dtinit: f64,
    pub dt: f64,

    // cell-centred state
    pub density0: DatasetId,
    pub density1: DatasetId,
    pub energy0: DatasetId,
    pub energy1: DatasetId,
    pub pressure: DatasetId,
    pub viscosity: DatasetId,
    pub soundspeed: DatasetId,
    // node-centred velocities
    pub vel0: [DatasetId; 3],
    pub vel1: [DatasetId; 3],
    // face fluxes per direction
    pub vol_flux: [DatasetId; 3],
    pub mass_flux: [DatasetId; 3],
    // work arrays
    pub work1: DatasetId, // pre_vol
    pub work2: DatasetId, // post_vol
    pub work3: DatasetId, // node_flux
    pub work4: DatasetId, // node_mass_post
    pub work5: DatasetId, // node_mass_pre
    pub work6: DatasetId, // mom_flux
    pub work7: DatasetId, // ener_flux
    // geometry
    pub volume: DatasetId,
    pub area: [DatasetId; 3], // xarea/yarea/zarea

    // stencils
    s_pt: StencilId,
    s_c2n: StencilId,
    s_n2c: StencilId,
    s_p1: [StencilId; 3],
    s_m1: [StencilId; 3],
    s_adv: [StencilId; 3],
    s_mom: [StencilId; 3],
    s_nflux: [StencilId; 3],
    s_face: [StencilId; 3], // node reads the 4 dir-faces around it
    s_star: StencilId,
    s_halo: [StencilId; 3],

    pub r_dt: ReductionId,
    pub r_vol: ReductionId,
    pub r_mass: ReductionId,
    pub r_ie: ReductionId,
    pub r_ke: ReductionId,
    pub r_press: ReductionId,

    step_count: u64,
}

/// Conserved-quantity summary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FieldSummary3D {
    pub volume: f64,
    pub mass: f64,
    pub internal_energy: f64,
    pub kinetic_energy: f64,
    pub pressure: f64,
}

impl CloverLeaf3D {
    pub fn new<D: Declare>(ctx: &mut D, nx: usize, ny: usize, nz: usize, model_scale: u64) -> Self {
        ctx.set_model_elem_bytes(8 * model_scale.max(1));
        let block = ctx.decl_block("clover3d", [nx, ny, nz]);
        let h = [2, 2, 2];
        let cell = [nx, ny, nz];
        let node = [nx + 1, ny + 1, nz + 1];
        let face = |d: Dir| match d {
            Dir::X => [nx + 1, ny, nz],
            Dir::Y => [nx, ny + 1, nz],
            Dir::Z => [nx, ny, nz + 1],
        };

        let dat = |ctx: &mut D, nme: &str, s: [usize; 3]| ctx.decl_dat(block, nme, s, h, h);

        let density0 = dat(ctx, "density0", cell);
        let density1 = dat(ctx, "density1", cell);
        let energy0 = dat(ctx, "energy0", cell);
        let energy1 = dat(ctx, "energy1", cell);
        let pressure = dat(ctx, "pressure", cell);
        let viscosity = dat(ctx, "viscosity", cell);
        let soundspeed = dat(ctx, "soundspeed", cell);
        let vel0 = [
            dat(ctx, "xvel0", node),
            dat(ctx, "yvel0", node),
            dat(ctx, "zvel0", node),
        ];
        let vel1 = [
            dat(ctx, "xvel1", node),
            dat(ctx, "yvel1", node),
            dat(ctx, "zvel1", node),
        ];
        let vol_flux = [
            dat(ctx, "vol_flux_x", face(Dir::X)),
            dat(ctx, "vol_flux_y", face(Dir::Y)),
            dat(ctx, "vol_flux_z", face(Dir::Z)),
        ];
        let mass_flux = [
            dat(ctx, "mass_flux_x", face(Dir::X)),
            dat(ctx, "mass_flux_y", face(Dir::Y)),
            dat(ctx, "mass_flux_z", face(Dir::Z)),
        ];
        let work1 = dat(ctx, "work1", node);
        let work2 = dat(ctx, "work2", node);
        let work3 = dat(ctx, "work3", node);
        let work4 = dat(ctx, "work4", node);
        let work5 = dat(ctx, "work5", node);
        let work6 = dat(ctx, "work6", node);
        let work7 = dat(ctx, "work7", node);
        let volume = dat(ctx, "volume", cell);
        let area = [
            dat(ctx, "xarea", face(Dir::X)),
            dat(ctx, "yarea", face(Dir::Y)),
            dat(ctx, "zarea", face(Dir::Z)),
        ];

        let s_pt = ctx.decl_stencil("s3d_000", shapes::point());
        let s_c2n = ctx.decl_stencil("c2n", CELL_TO_NODE.map(|o| [o[0] as i32, o[1] as i32, o[2] as i32]).to_vec());
        let s_n2c = ctx.decl_stencil("n2c", NODE_TO_CELL.map(|o| [o[0] as i32, o[1] as i32, o[2] as i32]).to_vec());
        let mk_line = |ctx: &mut D, nme: &str, d: Dir, ks: &[i32]| {
            let pts: Vec<[i32; 3]> = ks
                .iter()
                .map(|&k| {
                    let o = d.o(k as isize);
                    [o[0] as i32, o[1] as i32, o[2] as i32]
                })
                .collect();
            ctx.decl_stencil(nme, pts)
        };
        let s_p1 = [
            mk_line(ctx, "xp1", Dir::X, &[0, 1]),
            mk_line(ctx, "yp1", Dir::Y, &[0, 1]),
            mk_line(ctx, "zp1", Dir::Z, &[0, 1]),
        ];
        let s_m1 = [
            mk_line(ctx, "xm1", Dir::X, &[-1, 0]),
            mk_line(ctx, "ym1", Dir::Y, &[-1, 0]),
            mk_line(ctx, "zm1", Dir::Z, &[-1, 0]),
        ];
        let s_adv = [
            mk_line(ctx, "adv_x", Dir::X, &[-2, -1, 0, 1]),
            mk_line(ctx, "adv_y", Dir::Y, &[-2, -1, 0, 1]),
            mk_line(ctx, "adv_z", Dir::Z, &[-2, -1, 0, 1]),
        ];
        let s_mom = [
            mk_line(ctx, "mom_x", Dir::X, &[-1, 0, 1, 2]),
            mk_line(ctx, "mom_y", Dir::Y, &[-1, 0, 1, 2]),
            mk_line(ctx, "mom_z", Dir::Z, &[-1, 0, 1, 2]),
        ];
        // node flux: the 4 dir-faces adjacent to a node: dir offsets {0,1},
        // transverse offsets {-1,0} in both transverse dims.
        let mk_nflux = |ctx: &mut D, nme: &str, d: Dir| {
            let mut pts = vec![];
            for kd in 0..2isize {
                for t1 in -1..1isize {
                    for t2 in -1..1isize {
                        let p = match d {
                            Dir::X => [kd, t1, t2],
                            Dir::Y => [t1, kd, t2],
                            Dir::Z => [t1, t2, kd],
                        };
                        pts.push([p[0] as i32, p[1] as i32, p[2] as i32]);
                    }
                }
            }
            ctx.decl_stencil(nme, pts)
        };
        let s_nflux = [
            mk_nflux(ctx, "nflux_x", Dir::X),
            mk_nflux(ctx, "nflux_y", Dir::Y),
            mk_nflux(ctx, "nflux_z", Dir::Z),
        ];
        // face stencil for PdV / flux_calc: node corners of a dir-face
        let mk_face = |ctx: &mut D, nme: &str, d: Dir| {
            let pts: Vec<[i32; 3]> = match d {
                Dir::X => vec![[0, 0, 0], [0, 1, 0], [0, 0, 1], [0, 1, 1], [1, 0, 0], [1, 1, 0], [1, 0, 1], [1, 1, 1]],
                Dir::Y => vec![[0, 0, 0], [1, 0, 0], [0, 0, 1], [1, 0, 1], [0, 1, 0], [1, 1, 0], [0, 1, 1], [1, 1, 1]],
                Dir::Z => vec![[0, 0, 0], [1, 0, 0], [0, 1, 0], [1, 1, 0], [0, 0, 1], [1, 0, 1], [0, 1, 1], [1, 1, 1]],
            };
            ctx.decl_stencil(nme, pts)
        };
        let s_face = [
            mk_face(ctx, "face_x", Dir::X),
            mk_face(ctx, "face_y", Dir::Y),
            mk_face(ctx, "face_z", Dir::Z),
        ];
        let s_star = ctx.decl_stencil("star3d", shapes::star3d(1));
        // halo mirror reads reach ±4 along their own dimension only
        let mk_halo = |ctx: &mut D, nme: &str, d: usize| {
            let pts: Vec<[i32; 3]> = (-4..=4)
                .map(|k| {
                    let mut p = [0i32; 3];
                    p[d] = k;
                    p
                })
                .collect();
            ctx.decl_stencil(nme, pts)
        };
        let s_halo = [
            mk_halo(ctx, "halo_mirror_x", 0),
            mk_halo(ctx, "halo_mirror_y", 1),
            mk_halo(ctx, "halo_mirror_z", 2),
        ];

        let r_dt = ctx.decl_reduction("dt", RedOp::Min);
        let r_vol = ctx.decl_reduction("vol", RedOp::Sum);
        let r_mass = ctx.decl_reduction("mass", RedOp::Sum);
        let r_ie = ctx.decl_reduction("ie", RedOp::Sum);
        let r_ke = ctx.decl_reduction("ke", RedOp::Sum);
        let r_press = ctx.decl_reduction("press", RedOp::Sum);

        CloverLeaf3D {
            block,
            n: [nx, ny, nz],
            d: [10.0 / nx as f64, 10.0 / ny as f64, 10.0 / nz as f64],
            gamma: 1.4,
            dtinit: 0.04,
            dt: 0.04,
            density0,
            density1,
            energy0,
            energy1,
            pressure,
            viscosity,
            soundspeed,
            vel0,
            vel1,
            vol_flux,
            mass_flux,
            work1,
            work2,
            work3,
            work4,
            work5,
            work6,
            work7,
            volume,
            area,
            s_pt,
            s_c2n,
            s_n2c,
            s_p1,
            s_m1,
            s_adv,
            s_mom,
            s_nflux,
            s_face,
            s_star,
            s_halo,
            r_dt,
            r_vol,
            r_mass,
            r_ie,
            r_ke,
            r_press,
            step_count: 0,
        }
    }

    fn cells(&self) -> crate::ops::Range3 {
        [
            (0, self.n[0] as isize),
            (0, self.n[1] as isize),
            (0, self.n[2] as isize),
        ]
    }

    fn cells_h(&self, d: isize) -> crate::ops::Range3 {
        [
            (-d, self.n[0] as isize + d),
            (-d, self.n[1] as isize + d),
            (-d, self.n[2] as isize + d),
        ]
    }

    fn nodes(&self) -> crate::ops::Range3 {
        [
            (0, self.n[0] as isize + 1),
            (0, self.n[1] as isize + 1),
            (0, self.n[2] as isize + 1),
        ]
    }

    fn faces(&self, dir: Dir) -> crate::ops::Range3 {
        let mut r = self.cells();
        let i = dir as usize;
        r[i] = (0, self.n[i] as isize + 1);
        r
    }

    // ---------------------------------------------------------------- init

    pub fn initialise(&self, ctx: &mut impl Record) {
        let dd = self.d;
        let (nx, ny, nz) = (
            self.n[0] as isize,
            self.n[1] as isize,
            self.n[2] as isize,
        );
        ctx.par_loop(
            "cl3d_init_geom",
            self.block,
            self.cells_h(2),
            kernel(move |c| {
                c.w3(0, 0, 0, 0, dd[0] * dd[1] * dd[2]);
                c.w3(1, 0, 0, 0, dd[1] * dd[2]);
                c.w3(2, 0, 0, 0, dd[0] * dd[2]);
                c.w3(3, 0, 0, 0, dd[0] * dd[1]);
            }),
            vec![
                Arg::dat(self.volume, self.s_pt, Access::Write),
                Arg::dat(self.area[0], self.s_pt, Access::Write),
                Arg::dat(self.area[1], self.s_pt, Access::Write),
                Arg::dat(self.area[2], self.s_pt, Access::Write),
            ],
        );
        let (bx, by, bz) = (nx / 2, ny / 2, nz / 2);
        ctx.par_loop(
            "cl3d_init_state",
            self.block,
            self.cells_h(2),
            kernel(move |c| {
                let [x, y, z] = c.idx();
                let in_box = x >= 0 && x < bx && y >= 0 && y < by && z >= 0 && z < bz;
                if in_box {
                    c.w3(0, 0, 0, 0, 1.0);
                    c.w3(1, 0, 0, 0, 2.5);
                } else {
                    c.w3(0, 0, 0, 0, 0.2);
                    c.w3(1, 0, 0, 0, 1.0);
                }
            }),
            vec![
                Arg::dat(self.density0, self.s_pt, Access::Write),
                Arg::dat(self.energy0, self.s_pt, Access::Write),
            ],
        );
        ctx.par_loop(
            "cl3d_init_vel",
            self.block,
            [(-2, nx + 3), (-2, ny + 3), (-2, nz + 3)],
            kernel(|c| {
                for a in 0..6 {
                    c.w3(a, 0, 0, 0, 0.0);
                }
            }),
            (0..3)
                .map(|i| Arg::dat(self.vel0[i], self.s_pt, Access::Write))
                .chain((0..3).map(|i| Arg::dat(self.vel1[i], self.s_pt, Access::Write)))
                .collect(),
        );
        self.ideal_gas(ctx, false);
        self.halo_cell(ctx, "halo_pressure", self.pressure);
        self.halo_cell(ctx, "halo_density0", self.density0);
        self.halo_cell(ctx, "halo_energy0", self.energy0);
    }

    // ------------------------------------------------------------ kernels

    pub fn ideal_gas(&self, ctx: &mut impl Record, predict: bool) {
        let gamma = self.gamma;
        let (den, ener) = if predict {
            (self.density1, self.energy1)
        } else {
            (self.density0, self.energy0)
        };
        // EOS as kernel IR: the tree mirrors the original closure
        // term-by-term, so the derived closure is bit-identical.
        let mut k = kir::KirBuilder::new();
        let d = k.let_(kir::read(0, [0, 0, 0]).max(G_SMALL));
        let e = kir::read(1, [0, 0, 0]);
        let v = k.let_(kir::lit(1.0) / d.clone());
        let p = k.let_(kir::lit(gamma - 1.0) * d.clone() * e);
        let pe = kir::lit(gamma - 1.0) * d.clone();
        let pv = -d * p.clone() * v.clone();
        let ss2 = v.clone() * v * (p.clone() * pe - pv);
        k.store(2, p);
        k.store(3, ss2.max(G_SMALL).sqrt());
        ctx.par_loop_ir(
            "cl3d_ideal_gas",
            self.block,
            self.cells(),
            k.build(),
            vec![
                Arg::dat(den, self.s_pt, Access::Read),
                Arg::dat(ener, self.s_pt, Access::Read),
                Arg::dat(self.pressure, self.s_pt, Access::Write),
                Arg::dat(self.soundspeed, self.s_pt, Access::Write),
            ],
            1.0,
        );
    }

    /// 3D artificial viscosity (per-direction compression limiter).
    pub fn viscosity_kernel(&self, ctx: &mut impl Record) {
        let dd = self.d;
        ctx.par_loop(
            "cl3d_viscosity",
            self.block,
            self.cells(),
            kernel(move |c| {
                // average velocity gradient along each direction from the
                // 8 corner nodes (args 1..=3 are xvel0/yvel0/zvel0)
                let mut grad = [0.0f64; 3];
                for (i, _) in Dir::all().iter().enumerate() {
                    let mut hi = 0.0;
                    let mut lo = 0.0;
                    for o in NODE_TO_CELL {
                        let on_hi = o[i] == 1;
                        let v = c.r3(1 + i, o[0], o[1], o[2]);
                        if on_hi {
                            hi += v;
                        } else {
                            lo += v;
                        }
                    }
                    grad[i] = 0.25 * (hi - lo) / dd[i];
                }
                let div = grad[0] + grad[1] + grad[2];
                if div >= 0.0 {
                    c.w3(5, 0, 0, 0, 0.0);
                    return;
                }
                // pressure-gradient-limited length scale
                let pg = [
                    (c.r3(0, 1, 0, 0) - c.r3(0, -1, 0, 0)) / (2.0 * dd[0]),
                    (c.r3(0, 0, 1, 0) - c.r3(0, 0, -1, 0)) / (2.0 * dd[1]),
                    (c.r3(0, 0, 0, 1) - c.r3(0, 0, 0, -1)) / (2.0 * dd[2]),
                ];
                let pg2 = pg[0] * pg[0] + pg[1] * pg[1] + pg[2] * pg[2];
                let pgrad = pg2.max(G_SMALL).sqrt();
                let mut grad_len = G_BIG;
                for i in 0..3 {
                    let g = (dd[i] * pgrad / pg[i].abs().max(G_SMALL)).abs();
                    grad_len = grad_len.min(g);
                }
                let limiter = (grad[0] * pg[0] * pg[0]
                    + grad[1] * pg[1] * pg[1]
                    + grad[2] * pg[2] * pg[2])
                    / pg2.max(G_SMALL);
                if limiter > 0.0 {
                    c.w3(5, 0, 0, 0, 0.0);
                } else {
                    c.w3(
                        5,
                        0,
                        0,
                        0,
                        2.0 * c.r3(4, 0, 0, 0) * grad_len * grad_len * limiter * limiter,
                    );
                }
            }),
            vec![
                Arg::dat(self.pressure, self.s_star, Access::Read),
                Arg::dat(self.vel0[0], self.s_n2c, Access::Read),
                Arg::dat(self.vel0[1], self.s_n2c, Access::Read),
                Arg::dat(self.vel0[2], self.s_n2c, Access::Read),
                Arg::dat(self.density0, self.s_pt, Access::Read),
                Arg::dat(self.viscosity, self.s_pt, Access::Write),
            ],
        );
    }

    pub fn calc_dt(&mut self, ctx: &mut impl Drive) -> f64 {
        let dd = self.d;
        ctx.par_loop(
            "cl3d_calc_dt",
            self.block,
            self.cells(),
            kernel(move |c| {
                let cc = c.r3(1, 0, 0, 0) * c.r3(1, 0, 0, 0)
                    + 2.0 * c.r3(2, 0, 0, 0) / c.r3(0, 0, 0, 0).max(G_SMALL);
                let cc = cc.max(G_SMALL).sqrt();
                let dmin = dd[0].min(dd[1]).min(dd[2]);
                let dtct = 0.7 * dmin / cc;
                let mut dt = dtct;
                for (i, _) in Dir::all().iter().enumerate() {
                    let mut vmax: f64 = G_SMALL;
                    for o in NODE_TO_CELL {
                        vmax = vmax.max(c.r3(3 + i, o[0], o[1], o[2]).abs());
                    }
                    dt = dt.min(0.5 * dd[i] / vmax);
                }
                c.red_min(0, dt.min(G_BIG));
            }),
            vec![
                Arg::dat(self.density0, self.s_pt, Access::Read),
                Arg::dat(self.soundspeed, self.s_pt, Access::Read),
                Arg::dat(self.viscosity, self.s_pt, Access::Read),
                Arg::dat(self.vel0[0], self.s_n2c, Access::Read),
                Arg::dat(self.vel0[1], self.s_n2c, Access::Read),
                Arg::dat(self.vel0[2], self.s_n2c, Access::Read),
                Arg::GblRed {
                    red: self.r_dt,
                    op: RedOp::Min,
                },
            ],
        );
        let cand = ctx.reduction_result(self.r_dt);
        self.dt = cand.min(self.dt * 1.5).min(self.dtinit);
        self.dt
    }

    /// PdV with 6 face fluxes; predictor uses vel0 with dt/2.
    pub fn pdv(&self, ctx: &mut impl Record, predict: bool) {
        let dt = self.dt;
        // args: 0 density0, 1..=3 vel0, 4..=6 vel1, 7..=9 areas, 10 volume,
        // 11 energy0, 12 pressure, 13 viscosity, 14 energy1 W, 15 density1 W
        // Sum of the 4 node velocities on the lo/hi dir-face; the
        // predictor halves dt and doubles vel0 instead of adding vel1.
        let face_vel_sum = |dir: usize, hi: isize| -> kir::Expr {
            let mut s0 = kir::lit(0.0); // vel0
            let mut s1 = kir::lit(0.0); // vel1
            for o in NODE_TO_CELL {
                if o[dir] == hi {
                    s0 = s0 + kir::read(1 + dir, pt(o));
                    s1 = s1 + kir::read(4 + dir, pt(o));
                }
            }
            if predict {
                kir::lit(2.0) * s0
            } else {
                s0 + s1
            }
        };
        let frac = if predict { 0.125 * dt * 0.5 } else { 0.125 * dt };
        let mut k = kir::KirBuilder::new();
        let mut total_flux = kir::lit(0.0);
        for dir in 0..3 {
            let area_lo = kir::read(7 + dir, [0, 0, 0]);
            let o = [
                [1, 0, 0][dir] as isize,
                [0, 1, 0][dir] as isize,
                [0, 0, 1][dir] as isize,
            ];
            let area_hi = kir::read(7 + dir, pt(o));
            let lo = area_lo * kir::lit(frac) * face_vel_sum(dir, 0);
            let hi = area_hi * kir::lit(frac) * face_vel_sum(dir, 1);
            total_flux = total_flux + (hi - lo);
        }
        let total_flux = k.let_(total_flux);
        let vol = k.let_(kir::read(10, [0, 0, 0]));
        let volume_change = vol.clone() / (vol.clone() + total_flux.clone()).max(G_SMALL);
        let d0 = k.let_(kir::read(0, [0, 0, 0]));
        let recip = kir::lit(1.0) / (d0.clone() * vol).max(G_SMALL);
        let e1 = kir::read(11, [0, 0, 0])
            - (kir::read(12, [0, 0, 0]) + kir::read(13, [0, 0, 0])) * total_flux * recip;
        k.store(14, e1);
        k.store(15, d0 * volume_change);
        ctx.par_loop_ir(
            if predict { "cl3d_pdv_predict" } else { "cl3d_pdv" },
            self.block,
            self.cells(),
            k.build(),
            vec![
                Arg::dat(self.density0, self.s_pt, Access::Read),
                Arg::dat(self.vel0[0], self.s_n2c, Access::Read),
                Arg::dat(self.vel0[1], self.s_n2c, Access::Read),
                Arg::dat(self.vel0[2], self.s_n2c, Access::Read),
                Arg::dat(self.vel1[0], self.s_n2c, Access::Read),
                Arg::dat(self.vel1[1], self.s_n2c, Access::Read),
                Arg::dat(self.vel1[2], self.s_n2c, Access::Read),
                Arg::dat(self.area[0], self.s_p1[0], Access::Read),
                Arg::dat(self.area[1], self.s_p1[1], Access::Read),
                Arg::dat(self.area[2], self.s_p1[2], Access::Read),
                Arg::dat(self.volume, self.s_pt, Access::Read),
                Arg::dat(self.energy0, self.s_pt, Access::Read),
                Arg::dat(self.pressure, self.s_pt, Access::Read),
                Arg::dat(self.viscosity, self.s_pt, Access::Read),
                Arg::dat(self.energy1, self.s_pt, Access::Write),
                Arg::dat(self.density1, self.s_pt, Access::Write),
            ],
            1.0,
        );
    }

    pub fn revert(&self, ctx: &mut impl Record) {
        let mut k = kir::KirBuilder::new();
        k.store(2, kir::read(0, [0, 0, 0]));
        k.store(3, kir::read(1, [0, 0, 0]));
        ctx.par_loop_ir(
            "cl3d_revert",
            self.block,
            self.cells(),
            k.build(),
            vec![
                Arg::dat(self.density0, self.s_pt, Access::Read),
                Arg::dat(self.energy0, self.s_pt, Access::Read),
                Arg::dat(self.density1, self.s_pt, Access::Write),
                Arg::dat(self.energy1, self.s_pt, Access::Write),
            ],
            1.0,
        );
    }

    pub fn accelerate(&self, ctx: &mut impl Record) {
        let dt = self.dt;
        let dd = self.d;
        let vol = dd[0] * dd[1] * dd[2];
        let mut k = kir::KirBuilder::new();
        let mut nm = kir::lit(0.0);
        for o in CELL_TO_NODE {
            nm = nm + kir::read(0, pt(o));
        }
        let nodal_mass = k.let_(nm * kir::lit(0.125 * vol));
        let sbm = k.let_(kir::lit(0.125 * dt) / nodal_mass.max(G_SMALL));
        // per direction: sum over the 4 cell-pairs straddling the node
        for dir in 0..3 {
            let mut dp = kir::lit(0.0);
            let mut dv = kir::lit(0.0);
            for o in CELL_TO_NODE {
                if o[dir] == 0 {
                    let mut om = o;
                    om[dir] = -1;
                    dp = dp + (kir::read(1, pt(o)) - kir::read(1, pt(om)));
                    dv = dv + (kir::read(2, pt(o)) - kir::read(2, pt(om)));
                }
            }
            // dv_dir = sbm * area_dir * (dp + dv), area_dir = vol/d[dir]
            let v = kir::read(3 + dir, [0, 0, 0]) - sbm.clone() * kir::lit(vol / dd[dir]) * (dp + dv);
            k.store(6 + dir, v);
        }
        ctx.par_loop_ir(
            "cl3d_accelerate",
            self.block,
            self.nodes(),
            k.build(),
            vec![
                Arg::dat(self.density0, self.s_c2n, Access::Read),
                Arg::dat(self.pressure, self.s_c2n, Access::Read),
                Arg::dat(self.viscosity, self.s_c2n, Access::Read),
                Arg::dat(self.vel0[0], self.s_pt, Access::Read),
                Arg::dat(self.vel0[1], self.s_pt, Access::Read),
                Arg::dat(self.vel0[2], self.s_pt, Access::Read),
                Arg::dat(self.vel1[0], self.s_pt, Access::Write),
                Arg::dat(self.vel1[1], self.s_pt, Access::Write),
                Arg::dat(self.vel1[2], self.s_pt, Access::Write),
            ],
            1.0,
        );
    }

    pub fn flux_calc(&self, ctx: &mut impl Record) {
        let dt = self.dt;
        for dir in Dir::all() {
            let i = dir as usize;
            // average of 4 face-node velocities, vel0+vel1
            let mut k = kir::KirBuilder::new();
            let mut s = kir::lit(0.0);
            for o in NODE_TO_CELL {
                if o[i] == 0 {
                    s = s + (kir::read(1, pt(o)) + kir::read(2, pt(o)));
                }
            }
            k.store(3, kir::lit(0.125 * dt) * kir::read(0, [0, 0, 0]) * s);
            ctx.par_loop_ir(
                &format!("cl3d_flux_calc_{}", dir.name()),
                self.block,
                self.faces(dir),
                k.build(),
                vec![
                    Arg::dat(self.area[i], self.s_pt, Access::Read),
                    Arg::dat(self.vel0[i], self.s_face[i], Access::Read),
                    Arg::dat(self.vel1[i], self.s_face[i], Access::Read),
                    Arg::dat(self.vol_flux[i], self.s_pt, Access::Write),
                ],
                1.0,
            );
        }
    }

    /// Cell advection along `dir`; `remaining` = bitmask of sweep dirs not
    /// yet done (incl. this one) — controls the telescoping pre/post
    /// volumes of the split scheme.
    pub fn advec_cell(&self, ctx: &mut impl Record, dir: Dir, remaining: [bool; 3]) {
        let i = dir as usize;
        let dn = dir.name();

        // pass 1: pre/post volumes (the `remaining` mask is a record-time
        // constant, so the telescoping unrolls into the IR tree)
        let mut k = kir::KirBuilder::new();
        let mut pre = kir::read(0, [0, 0, 0]);
        for (d2, rem) in remaining.iter().enumerate() {
            if *rem {
                let o = Dir::all()[d2].o(1);
                pre = pre + (kir::read(1 + d2, pt(o)) - kir::read(1 + d2, [0, 0, 0]));
            }
        }
        let pre = k.let_(pre);
        let oi = Dir::all()[i].o(1);
        let post = pre.clone() - (kir::read(1 + i, pt(oi)) - kir::read(1 + i, [0, 0, 0]));
        k.store(4, pre);
        k.store(5, post);
        ctx.par_loop_ir(
            &format!("cl3d_advec_cell_{dn}_pre"),
            self.block,
            self.cells_h(2),
            k.build(),
            vec![
                Arg::dat(self.volume, self.s_pt, Access::Read),
                Arg::dat(self.vol_flux[0], self.s_p1[0], Access::Read),
                Arg::dat(self.vol_flux[1], self.s_p1[1], Access::Read),
                Arg::dat(self.vol_flux[2], self.s_p1[2], Access::Read),
                Arg::dat(self.work1, self.s_pt, Access::Write),
                Arg::dat(self.work2, self.s_pt, Access::Write),
            ],
            1.0,
        );

        // pass 2: limited upwind mass/energy fluxes. Both upwind
        // orientations are built as subtrees and the sign of the volume
        // flux selects between them — the selected side evaluates the
        // exact arithmetic the branchy closure used to run.
        let mut k = kir::KirBuilder::new();
        let vf = k.let_(kir::read(0, [0, 0, 0]));
        let orient = |k: &mut kir::KirBuilder, up: isize, don: isize, down: isize| {
            let ou = pt(Dir::all()[i].o(up));
            let od = pt(Dir::all()[i].o(don));
            let ow = pt(Dir::all()[i].o(down));
            let pre_d = k.let_(kir::read(1, od).max(G_SMALL));
            let sig = vf.clone().abs() / pre_d.clone();
            let den_d = k.let_(kir::read(2, od));
            let lim = limited_ir(
                den_d.clone() - kir::read(2, ou),
                kir::read(2, ow) - den_d.clone(),
                sig,
            );
            let mf = k.let_(vf.clone() * (den_d.clone() + lim));
            let sigm = mf.clone().abs() / (den_d * pre_d).max(G_SMALL);
            let en_d = k.let_(kir::read(3, od));
            let lime = limited_ir(
                en_d.clone() - kir::read(3, ou),
                kir::read(3, ow) - en_d.clone(),
                sigm,
            );
            (mf.clone(), mf * (en_d + lime))
        };
        let (mf_up, ef_up) = orient(&mut k, -2, -1, 0);
        let (mf_dn, ef_dn) = orient(&mut k, 1, 0, -1);
        let cond = vf.gt(0.0);
        k.store(4, cond.clone().select(mf_up, mf_dn));
        k.store(5, cond.select(ef_up, ef_dn));
        ctx.par_loop_ir(
            &format!("cl3d_advec_cell_{dn}_flux"),
            self.block,
            self.faces(dir),
            k.build(),
            vec![
                Arg::dat(self.vol_flux[i], self.s_pt, Access::Read),
                Arg::dat(self.work1, self.s_adv[i], Access::Read),
                Arg::dat(self.density1, self.s_adv[i], Access::Read),
                Arg::dat(self.energy1, self.s_adv[i], Access::Read),
                Arg::dat(self.mass_flux[i], self.s_pt, Access::Write),
                Arg::dat(self.work7, self.s_pt, Access::Write),
            ],
            1.0,
        );

        // pass 3: conservative update
        let mut k = kir::KirBuilder::new();
        let o1 = pt(Dir::all()[i].o(1));
        let pre_vol = kir::read(0, [0, 0, 0]);
        let post_vol = kir::read(1, [0, 0, 0]);
        let den = kir::read(2, [0, 0, 0]);
        let en = kir::read(3, [0, 0, 0]);
        let pre_mass = k.let_(den * pre_vol);
        let post_mass = k.let_(pre_mass.clone() + kir::read(4, [0, 0, 0]) - kir::read(4, o1));
        let post_en = (en * pre_mass + kir::read(5, [0, 0, 0]) - kir::read(5, o1))
            / post_mass.clone().max(G_SMALL);
        k.store(2, post_mass / post_vol.max(G_SMALL));
        k.store(3, post_en);
        ctx.par_loop_ir(
            &format!("cl3d_advec_cell_{dn}_upd"),
            self.block,
            self.cells(),
            k.build(),
            vec![
                Arg::dat(self.work1, self.s_pt, Access::Read),
                Arg::dat(self.work2, self.s_pt, Access::Read),
                Arg::dat(self.density1, self.s_pt, Access::ReadWrite),
                Arg::dat(self.energy1, self.s_pt, Access::ReadWrite),
                Arg::dat(self.mass_flux[i], self.s_p1[i], Access::Read),
                Arg::dat(self.work7, self.s_p1[i], Access::Read),
            ],
            1.0,
        );
    }

    /// Momentum advection for one velocity component along one direction.
    pub fn advec_mom(&self, ctx: &mut impl Record, vc: usize, dir: Dir) {
        let i = dir as usize;
        let vel = self.vel1[vc];
        let dn = dir.name();
        let (nx, ny, nz) = (
            self.n[0] as isize,
            self.n[1] as isize,
            self.n[2] as isize,
        );
        let nodes_h = [(-1, nx + 2), (-1, ny + 2), (-1, nz + 2)];

        // node flux from the 4 dir-faces around the node
        ctx.par_loop(
            &format!("cl3d_mom_node_flux_{dn}_v{vc}"),
            self.block,
            nodes_h,
            kernel(move |c| {
                let mut f = 0.0;
                for kd in 0..2isize {
                    for t1 in -1..1isize {
                        for t2 in -1..1isize {
                            let o = match Dir::all()[i] {
                                Dir::X => [kd, t1, t2],
                                Dir::Y => [t1, kd, t2],
                                Dir::Z => [t1, t2, kd],
                            };
                            f += c.r3(0, o[0], o[1], o[2]);
                        }
                    }
                }
                c.w3(1, 0, 0, 0, 0.125 * f);
            }),
            vec![
                Arg::dat(self.mass_flux[i], self.s_nflux[i], Access::Read),
                Arg::dat(self.work3, self.s_pt, Access::Write),
            ],
        );

        // node masses
        ctx.par_loop(
            &format!("cl3d_mom_node_mass_{dn}_v{vc}"),
            self.block,
            nodes_h,
            kernel(move |c| {
                let mut post = 0.0;
                for o in CELL_TO_NODE {
                    post += c.r3(0, o[0], o[1], o[2]);
                }
                post *= 0.125;
                let om = Dir::all()[i].o(-1);
                let pre = post - (c.r3(1, 0, 0, 0) - c.r3(1, om[0], om[1], om[2]));
                c.w3(2, 0, 0, 0, post);
                c.w3(3, 0, 0, 0, pre);
            }),
            vec![
                Arg::dat(self.density1, self.s_c2n, Access::Read),
                Arg::dat(self.work3, self.s_m1[i], Access::Read),
                Arg::dat(self.work4, self.s_pt, Access::Write),
                Arg::dat(self.work5, self.s_pt, Access::Write),
            ],
        );

        // limited momentum flux
        let flux_range = [(-1, nx + 1), (-1, ny + 1), (-1, nz + 1)];
        ctx.par_loop(
            &format!("cl3d_mom_flux_{dn}_v{vc}"),
            self.block,
            flux_range,
            kernel(move |c| {
                let nf = c.r3(0, 0, 0, 0);
                let (up, don, down): (isize, isize, isize) =
                    if nf < 0.0 { (2, 1, 0) } else { (-1, 0, 1) };
                let ou = Dir::all()[i].o(up);
                let od = Dir::all()[i].o(don);
                let ow = Dir::all()[i].o(down);
                let v_d = c.r3(2, od[0], od[1], od[2]);
                let v_u = c.r3(2, ou[0], ou[1], ou[2]);
                let v_w = c.r3(2, ow[0], ow[1], ow[2]);
                let sigma = nf.abs() / c.r3(1, od[0], od[1], od[2]).max(G_SMALL);
                let vdiffuw = v_d - v_u;
                let vdiffdw = v_w - v_d;
                let limiter = if vdiffuw * vdiffdw > 0.0 {
                    let auw = vdiffuw.abs();
                    let adw = vdiffdw.abs();
                    let wind = if vdiffdw <= 0.0 { -1.0 } else { 1.0 };
                    wind * (((2.0 - sigma) * adw + (1.0 + sigma) * auw) / 6.0)
                        .min(auw)
                        .min(adw)
                } else {
                    0.0
                };
                c.w3(3, 0, 0, 0, nf * (v_d + limiter * (1.0 - sigma)));
            }),
            vec![
                Arg::dat(self.work3, self.s_pt, Access::Read),
                Arg::dat(self.work5, self.s_mom[i], Access::Read),
                Arg::dat(vel, self.s_mom[i], Access::Read),
                Arg::dat(self.work6, self.s_pt, Access::Write),
            ],
        );

        // velocity update
        ctx.par_loop(
            &format!("cl3d_mom_vel_{dn}_v{vc}"),
            self.block,
            self.nodes(),
            kernel(move |c| {
                let om = Dir::all()[i].o(-1);
                let v = (c.r3(0, 0, 0, 0) * c.r3(1, 0, 0, 0) + c.r3(2, om[0], om[1], om[2])
                    - c.r3(2, 0, 0, 0))
                    / c.r3(3, 0, 0, 0).max(G_SMALL);
                c.w3(0, 0, 0, 0, v);
            }),
            vec![
                Arg::dat(vel, self.s_pt, Access::ReadWrite),
                Arg::dat(self.work5, self.s_pt, Access::Read),
                Arg::dat(self.work6, self.s_m1[i], Access::Read),
                Arg::dat(self.work4, self.s_pt, Access::Read),
            ],
        );
    }

    pub fn reset_field(&self, ctx: &mut impl Record) {
        let mut k = kir::KirBuilder::new();
        k.store(2, kir::read(0, [0, 0, 0]));
        k.store(3, kir::read(1, [0, 0, 0]));
        ctx.par_loop_ir(
            "cl3d_reset_field",
            self.block,
            self.cells(),
            k.build(),
            vec![
                Arg::dat(self.density1, self.s_pt, Access::Read),
                Arg::dat(self.energy1, self.s_pt, Access::Read),
                Arg::dat(self.density0, self.s_pt, Access::Write),
                Arg::dat(self.energy0, self.s_pt, Access::Write),
            ],
            1.0,
        );
        let mut k = kir::KirBuilder::new();
        for i in 0..3 {
            k.store(3 + i, kir::read(i, [0, 0, 0]));
        }
        ctx.par_loop_ir(
            "cl3d_reset_vel",
            self.block,
            self.nodes(),
            k.build(),
            (0..3)
                .map(|i| Arg::dat(self.vel1[i], self.s_pt, Access::Read))
                .chain((0..3).map(|i| Arg::dat(self.vel0[i], self.s_pt, Access::Write)))
                .collect(),
            1.0,
        );
    }

    // ------------------------------------------------ halo strips (3D)

    #[allow(clippy::too_many_arguments)]
    fn halo_faces(
        &self,
        ctx: &mut impl Record,
        name: &str,
        d: DatasetId,
        sizes: [isize; 3],
        node: bool,
        flip_dir: Option<usize>,
    ) {
        for dim in 0..3 {
            let mut lo_range = [
                (-2, sizes[0] + 2),
                (-2, sizes[1] + 2),
                (-2, sizes[2] + 2),
            ];
            lo_range[dim] = (-2, 0);
            let mut hi_range = lo_range;
            hi_range[dim] = (sizes[dim], sizes[dim] + 2);
            let s = sizes[dim];
            let sgn = if flip_dir == Some(dim) { -1.0 } else { 1.0 };
            let nd = node;
            ctx.par_loop(
                &format!("{name}_lo{dim}"),
                self.block,
                lo_range,
                kernel(move |c| {
                    let i = c.idx()[dim];
                    let off = if nd { -2 * i } else { -1 - 2 * i };
                    let mut o = [0isize; 3];
                    o[dim] = off;
                    let v = c.r3(0, o[0], o[1], o[2]);
                    c.w3(0, 0, 0, 0, sgn * v);
                }),
                vec![Arg::dat(d, self.s_halo[dim], Access::ReadWrite)],
            );
            ctx.par_loop(
                &format!("{name}_hi{dim}"),
                self.block,
                hi_range,
                kernel(move |c| {
                    let i = c.idx()[dim];
                    let off = if nd {
                        2 * (s - 1) - 2 * i
                    } else {
                        2 * s - 2 * i - 1
                    };
                    let mut o = [0isize; 3];
                    o[dim] = off;
                    let v = c.r3(0, o[0], o[1], o[2]);
                    c.w3(0, 0, 0, 0, sgn * v);
                }),
                vec![Arg::dat(d, self.s_halo[dim], Access::ReadWrite)],
            );
        }
    }

    fn halo_cell(&self, ctx: &mut impl Record, name: &str, d: DatasetId) {
        let s = [
            self.n[0] as isize,
            self.n[1] as isize,
            self.n[2] as isize,
        ];
        self.halo_faces(ctx, name, d, s, false, None);
    }

    fn halo_vel(&self, ctx: &mut impl Record, name: &str, d: DatasetId, flip_dir: usize) {
        let s = [
            self.n[0] as isize + 1,
            self.n[1] as isize + 1,
            self.n[2] as isize + 1,
        ];
        self.halo_faces(ctx, name, d, s, true, Some(flip_dir));
    }

    fn update_halo_hydro(&self, ctx: &mut impl Record) {
        self.halo_cell(ctx, "halo_density1", self.density1);
        self.halo_cell(ctx, "halo_energy1", self.energy1);
        self.halo_cell(ctx, "halo_pressure", self.pressure);
        self.halo_cell(ctx, "halo_viscosity", self.viscosity);
    }

    fn update_halo_vel(&self, ctx: &mut impl Record) {
        self.halo_vel(ctx, "halo_xvel1", self.vel1[0], 0);
        self.halo_vel(ctx, "halo_yvel1", self.vel1[1], 1);
        self.halo_vel(ctx, "halo_zvel1", self.vel1[2], 2);
    }

    // ------------------------------------------------------------ driver

    /// EOS + viscosity block that precedes the `calc_dt` trigger.
    fn pre_dt(&self, ctx: &mut impl Record) {
        self.ideal_gas(ctx, false);
        self.halo_cell(ctx, "halo_pressure", self.pressure);
        self.viscosity_kernel(ctx);
        self.halo_cell(ctx, "halo_viscosity", self.viscosity);
    }

    /// Lagrangian step + split advection for one sweep order. All
    /// kernels capture the *current* `self.dt` by value, so this block
    /// records cleanly into a frozen chain.
    fn post_dt(&self, ctx: &mut impl Record, order: [Dir; 3]) {
        self.pdv(ctx, true);
        self.ideal_gas(ctx, true);
        self.update_halo_hydro(ctx);
        self.revert(ctx);
        self.accelerate(ctx);
        self.update_halo_vel(ctx);
        self.pdv(ctx, false);
        self.flux_calc(ctx);

        let mut remaining = [true, true, true];
        for (k, dir) in order.iter().enumerate() {
            self.advec_cell(ctx, *dir, remaining);
            remaining[*dir as usize] = false;
            if k == 0 {
                self.halo_cell(ctx, "halo_density1", self.density1);
                self.halo_cell(ctx, "halo_energy1", self.energy1);
            }
            for vc in 0..3 {
                self.advec_mom(ctx, vc, *dir);
            }
        }
        self.reset_field(ctx);
    }

    /// One timestep: Lagrangian step + x/y/z split advection (sweep order
    /// rotates with step parity, as in the original).
    pub fn step(&mut self, ctx: &mut impl Drive) -> f64 {
        self.pre_dt(ctx);
        let dt = self.calc_dt(ctx); // trigger

        let orders: [[Dir; 3]; 2] = [[Dir::X, Dir::Y, Dir::Z], [Dir::Z, Dir::Y, Dir::X]];
        let order = orders[(self.step_count % 2) as usize];
        self.step_count += 1;
        self.post_dt(ctx, order);
        dt
    }

    /// Record one **fixed-`dt` double step** (both sweep orders, no
    /// `calc_dt`, no summary) once — the record-once API for frozen
    /// replay via [`crate::program::Session::replay`] /
    /// [`crate::program::Session::replay_fused`]. The adaptive timestep
    /// is a reduction trigger, so a frozen chain pins `dt = dtinit`
    /// (`dt` is captured by value at record time); recording both sweep
    /// orders makes the chain self-similar under repetition, which is
    /// what temporal fusion needs.
    pub fn record_step_chain(
        &mut self,
        b: &mut crate::program::ProgramBuilder,
    ) -> crate::program::ChainId {
        self.dt = self.dtinit;
        let orders: [[Dir; 3]; 2] = [[Dir::X, Dir::Y, Dir::Z], [Dir::Z, Dir::Y, Dir::X]];
        b.record_chain("cl3d_step2", |r| {
            for order in orders {
                self.pre_dt(r);
                self.post_dt(r, order);
            }
        })
    }

    pub fn field_summary(&self, ctx: &mut impl Drive) -> FieldSummary3D {
        let mut k = kir::KirBuilder::new();
        let vol = k.let_(kir::read(0, [0, 0, 0]));
        let den = k.let_(kir::read(1, [0, 0, 0]));
        let en = kir::read(2, [0, 0, 0]);
        let press = kir::read(3, [0, 0, 0]);
        let mut vsqrd = kir::lit(0.0);
        for o in NODE_TO_CELL {
            for vdim in 0..3 {
                let v = kir::read(4 + vdim, pt(o));
                vsqrd = vsqrd + kir::lit(0.125) * v.clone() * v;
            }
        }
        let mass = k.let_(den.clone() * vol.clone());
        k.reduce(0, RedOp::Sum, vol);
        k.reduce(1, RedOp::Sum, mass.clone());
        k.reduce(2, RedOp::Sum, mass.clone() * en);
        k.reduce(3, RedOp::Sum, kir::lit(0.5) * mass.clone() * vsqrd);
        k.reduce(4, RedOp::Sum, mass * press / den.max(G_SMALL));
        ctx.par_loop_ir(
            "cl3d_field_summary",
            self.block,
            self.cells(),
            k.build(),
            vec![
                Arg::dat(self.volume, self.s_pt, Access::Read),
                Arg::dat(self.density0, self.s_pt, Access::Read),
                Arg::dat(self.energy0, self.s_pt, Access::Read),
                Arg::dat(self.pressure, self.s_pt, Access::Read),
                Arg::dat(self.vel0[0], self.s_n2c, Access::Read),
                Arg::dat(self.vel0[1], self.s_n2c, Access::Read),
                Arg::dat(self.vel0[2], self.s_n2c, Access::Read),
                Arg::GblRed { red: self.r_vol, op: RedOp::Sum },
                Arg::GblRed { red: self.r_mass, op: RedOp::Sum },
                Arg::GblRed { red: self.r_ie, op: RedOp::Sum },
                Arg::GblRed { red: self.r_ke, op: RedOp::Sum },
                Arg::GblRed { red: self.r_press, op: RedOp::Sum },
            ],
            1.0,
        );
        FieldSummary3D {
            volume: ctx.reduction_result(self.r_vol),
            mass: ctx.reduction_result(self.r_mass),
            internal_energy: ctx.reduction_result(self.r_ie),
            kinetic_energy: ctx.reduction_result(self.r_ke),
            pressure: ctx.reduction_result(self.r_press),
        }
    }

    pub fn run(&mut self, ctx: &mut impl Drive, steps: usize, summary_every: usize) {
        self.initialise(ctx);
        ctx.flush();
        ctx.reset_metrics();
        ctx.set_cyclic_phase(true);
        for s in 0..steps {
            self.step(ctx);
            if summary_every > 0 && (s + 1) % summary_every == 0 {
                let _ = self.field_summary(ctx);
            }
        }
        ctx.flush();
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::coordinator::{Config, Platform};
    use crate::memory::{AppCalib, Link};
    use crate::ops::OpsContext;

    fn ctx(p: Platform) -> OpsContext {
        OpsContext::new(Config::new(p, AppCalib::CLOVERLEAF_3D).build_engine())
    }

    #[test]
    fn dataset_count_matches_paper() {
        let mut c = ctx(Platform::KnlFlatDdr4);
        let _app = CloverLeaf3D::new(&mut c, 8, 8, 8, 1);
        assert_eq!(c.datasets().len(), 30, "paper: 30 variables/gridpoint");
    }

    #[test]
    fn mass_conserved_and_ke_develops() {
        let mut c = ctx(Platform::KnlFlatDdr4);
        let mut app = CloverLeaf3D::new(&mut c, 12, 12, 12, 1);
        app.initialise(&mut c);
        let s0 = app.field_summary(&mut c);
        for _ in 0..4 {
            app.step(&mut c);
        }
        let s1 = app.field_summary(&mut c);
        assert!(
            ((s1.mass - s0.mass) / s0.mass).abs() < 1e-10,
            "mass {} -> {}",
            s0.mass,
            s1.mass
        );
        assert!(s1.kinetic_energy > 1e-10);
        assert!(s1.internal_energy.is_finite() && s1.internal_energy > 0.0);
    }

    #[test]
    fn dt_positive_and_fields_finite() {
        let mut c = ctx(Platform::KnlFlatDdr4);
        let mut app = CloverLeaf3D::new(&mut c, 10, 10, 10, 1);
        app.initialise(&mut c);
        for _ in 0..4 {
            let dt = app.step(&mut c);
            assert!(dt > 0.0 && dt.is_finite());
        }
        let den = c.fetch(app.density0);
        assert!(den.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn tiled_3d_matches_untiled_bitexact() {
        let run = |p: Platform| {
            let mut c = ctx(p);
            let mut app = CloverLeaf3D::new(&mut c, 10, 10, 10, 1);
            app.run(&mut c, 3, 2);
            (c.fetch(app.density0), c.fetch(app.vel0[2]))
        };
        let a = run(Platform::KnlFlatDdr4);
        let b = run(Platform::KnlCacheTiled);
        let g = run(Platform::GpuExplicit {
            link: Link::PciE,
            cyclic: true,
            prefetch: true,
        });
        assert_eq!(a.0, b.0, "density0 KNL tiled");
        assert_eq!(a.1, b.1, "zvel0 KNL tiled");
        assert_eq!(a.0, g.0, "density0 GPU explicit");
    }

    #[test]
    fn tiling_happens_along_z() {
        let mut c = ctx(Platform::KnlCacheTiled);
        let mut app = CloverLeaf3D::new(&mut c, 8, 8, 32, 1 << 16);
        app.run(&mut c, 2, 0);
        assert!(c.metrics().tiles > 2, "tiles: {}", c.metrics().tiles);
    }
}
