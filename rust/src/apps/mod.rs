//! The stencil applications of the paper's evaluation, expressed in the
//! DSL: CloverLeaf 2D/3D (compressible Euler, explicit hydro) and an
//! OpenSBLI-style 3D Taylor–Green vortex (compressible Navier–Stokes,
//! RK3), plus a small diffusion demo used by the quickstart and the PJRT
//! end-to-end example.

pub mod cloverleaf2d;
pub mod cloverleaf3d;
pub mod diffusion;
pub mod opensbli;
