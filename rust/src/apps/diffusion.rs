//! A small 2D heat-diffusion app: the quickstart workload and the
//! end-to-end PJRT demonstration (its two kernels ship as AOT-compiled
//! JAX/Pallas artifacts).
//!
//! Each timestep is a two-loop chain with exactly the §4.1 structure:
//! a *write-first temporary* (the Laplacian) followed by a read-modify-
//! write update of the state — so read-only/write-first/Cyclic data
//! movement optimisations all have something to act on.

use crate::ops::kernel::kernel;
use crate::ops::kir;
use crate::ops::stencil::shapes;
use crate::ops::{
    Access, Arg, BlockId, DatasetId, Declare, Drive, RedOp, Record, ReductionId, StencilId,
};
use crate::program::{ChainId, ProgramBuilder};

/// Handles for the diffusion problem.
pub struct Diffusion2D {
    pub block: BlockId,
    /// Temperature field (state, read-modify-write each step).
    pub u: DatasetId,
    /// Laplacian workspace (write-first temporary).
    pub lap: DatasetId,
    /// Conductivity map (read-only).
    pub kappa: DatasetId,
    s_pt: StencilId,
    s_star: StencilId,
    pub sum: ReductionId,
    pub nx: usize,
    pub ny: usize,
    pub alpha: f64,
}

impl Diffusion2D {
    /// Declare data on `ctx` (an [`OpsContext`](crate::ops::OpsContext)
    /// or a [`ProgramBuilder`]). `model_scale` multiplies the modelled
    /// bytes per element (1 = actual size).
    pub fn new<D: Declare>(ctx: &mut D, nx: usize, ny: usize, model_scale: u64) -> Self {
        ctx.set_model_elem_bytes(8 * model_scale.max(1));
        let block = ctx.decl_block("grid", [nx, ny, 1]);
        let size = [nx, ny, 1];
        let h = [1, 1, 0];
        let u = ctx.decl_dat(block, "u", size, h, h);
        let lap = ctx.decl_dat(block, "lap", size, h, h);
        let kappa = ctx.decl_dat(block, "kappa", size, h, h);
        let s_pt = ctx.decl_stencil("pt", shapes::point());
        let s_star = ctx.decl_stencil("star1", shapes::star2d(1));
        let sum = ctx.decl_reduction("heat", RedOp::Sum);
        Diffusion2D {
            block,
            u,
            lap,
            kappa,
            s_pt,
            s_star,
            sum,
            nx,
            ny,
            alpha: 0.1,
        }
    }

    /// Initial condition: a hot square in the centre over uniform
    /// conductivity; zero halos (Dirichlet walls).
    pub fn init(&self, ctx: &mut impl Record) {
        let (nx, ny) = (self.nx as isize, self.ny as isize);
        let full = [(-1, nx + 1), (-1, ny + 1), (0, 1)];
        let (cx0, cx1) = (nx / 4, 3 * nx / 4);
        let (cy0, cy1) = (ny / 4, 3 * ny / 4);
        ctx.par_loop(
            "diff_init",
            self.block,
            full,
            kernel(move |c| {
                let [x, y, _] = c.idx();
                let hot = x >= cx0 && x < cx1 && y >= cy0 && y < cy1;
                c.w(0, 0, 0, if hot { 1.0 } else { 0.0 });
                c.w(1, 0, 0, 1.0);
            }),
            vec![
                Arg::dat(self.u, self.s_pt, Access::Write),
                Arg::dat(self.kappa, self.s_pt, Access::Write),
            ],
        );
    }

    /// One timestep: Laplacian into the temp, then the explicit update.
    pub fn step(&self, ctx: &mut impl Record) {
        let interior = [
            (0, self.nx as isize),
            (0, self.ny as isize),
            (0, 1),
        ];
        // Both step kernels are recorded as declarative kernel IR: the
        // native executor runs the closure *derived* from the IR, the
        // vector executor compiles it into row programs — bit-identical
        // either way.
        let mut k = kir::KirBuilder::new();
        let l = k.let_(
            kir::read(0, [-1, 0, 0]) + kir::read(0, [1, 0, 0]) + kir::read(0, [0, -1, 0])
                + kir::read(0, [0, 1, 0])
                - kir::lit(4.0) * kir::read(0, [0, 0, 0]),
        );
        k.store(2, kir::read(1, [0, 0, 0]) * l);
        ctx.par_loop_ir(
            "diff_lap",
            self.block,
            interior,
            k.build(),
            vec![
                Arg::dat(self.u, self.s_star, Access::Read),
                Arg::dat(self.kappa, self.s_pt, Access::Read),
                Arg::dat(self.lap, self.s_pt, Access::Write),
            ],
            1.0,
        );
        let mut k = kir::KirBuilder::new();
        k.store(
            0,
            kir::read(0, [0, 0, 0]) + kir::lit(self.alpha) * kir::read(1, [0, 0, 0]),
        );
        ctx.par_loop_ir(
            "diff_update",
            self.block,
            interior,
            k.build(),
            vec![
                Arg::dat(self.u, self.s_pt, Access::ReadWrite),
                Arg::dat(self.lap, self.s_pt, Access::Read),
            ],
            1.0,
        );
    }

    /// Total heat (a conserved quantity away from the walls) — a chain
    /// trigger point.
    pub fn total_heat(&self, ctx: &mut impl Drive) -> f64 {
        self.record_total_heat(ctx);
        ctx.reduction_result(self.sum)
    }

    /// Standard driver: init, mark cyclic, run `steps` steps with a chain
    /// boundary per `chain_steps` steps.
    pub fn run(&self, ctx: &mut impl Drive, steps: usize, chain_steps: usize) {
        self.init(ctx);
        ctx.flush();
        ctx.reset_metrics();
        ctx.set_cyclic_phase(true);
        for s in 0..steps {
            self.step(ctx);
            if (s + 1) % chain_steps.max(1) == 0 {
                ctx.flush();
            }
        }
        ctx.flush();
    }

    /// Record the init and step chains **once** into `b` (the
    /// record-once API): replay them with
    /// [`crate::program::Session::replay`]. `chain_steps` timesteps are
    /// recorded into the step chain, so one replay is one chain boundary
    /// — the exact analogue of the legacy driver's flush cadence.
    pub fn record_chains(&self, b: &mut ProgramBuilder, chain_steps: usize) -> DiffusionChains {
        let init = b.record_chain("diff_init", |r| self.init(r));
        let step = b.record_chain("diff_step", |r| {
            for _ in 0..chain_steps.max(1) {
                self.step(r);
            }
        });
        let sum = b.record_chain("diff_sum", |r| self.record_total_heat(r));
        DiffusionChains { init, step, sum }
    }

    /// Record the total-heat reduction loop (without triggering); pair
    /// with [`crate::ops::Drive::reduction_result`] on [`Self::sum`].
    fn record_total_heat(&self, ctx: &mut impl Record) {
        let interior = [
            (0, self.nx as isize),
            (0, self.ny as isize),
            (0, 1),
        ];
        let mut k = kir::KirBuilder::new();
        k.reduce(0, RedOp::Sum, kir::read(0, [0, 0, 0]));
        ctx.par_loop_ir(
            "diff_sum",
            self.block,
            interior,
            k.build(),
            vec![
                Arg::dat(self.u, self.s_pt, Access::Read),
                Arg::GblRed {
                    red: self.sum,
                    op: RedOp::Sum,
                },
            ],
            1.0,
        );
    }
}

/// Replay handles of a frozen diffusion program
/// ([`Diffusion2D::record_chains`]).
pub struct DiffusionChains {
    pub init: ChainId,
    pub step: ChainId,
    pub sum: ChainId,
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::coordinator::{Config, Platform};
    use crate::memory::{AppCalib, Link};
    use crate::ops::OpsContext;

    fn ctx(platform: Platform) -> OpsContext {
        OpsContext::new(Config::new(platform, AppCalib::CLOVERLEAF_2D).build_engine())
    }

    #[test]
    fn heat_is_conserved_while_away_from_walls() {
        let mut c = ctx(Platform::KnlFlatDdr4);
        let app = Diffusion2D::new(&mut c, 32, 32, 1);
        app.init(&mut c);
        let before = app.total_heat(&mut c);
        for _ in 0..5 {
            app.step(&mut c);
        }
        let after = app.total_heat(&mut c);
        // Hot square far from walls; 5 steps of alpha=0.1 diffusion can't
        // reach the boundary, so interior heat is conserved.
        assert!(
            (before - after).abs() < 1e-9 * before.abs(),
            "{before} vs {after}"
        );
    }

    #[test]
    fn tiled_gpu_matches_flat_numerics() {
        let run = |platform| {
            let mut c = ctx(platform);
            let app = Diffusion2D::new(&mut c, 48, 48, 1);
            app.run(&mut c, 10, 2);
            c.fetch(app.u)
        };
        let a = run(Platform::KnlFlatDdr4);
        let b = run(Platform::GpuExplicit {
            link: Link::PciE,
            cyclic: true,
            prefetch: true,
        });
        let c_ = run(Platform::KnlCacheTiled);
        assert_eq!(a, b);
        assert_eq!(a, c_);
    }

    #[test]
    fn diffusion_decays_peak() {
        let mut c = ctx(Platform::KnlFlatDdr4);
        let app = Diffusion2D::new(&mut c, 32, 32, 1);
        app.init(&mut c);
        let peak0 = c.value_at(app.u, [16, 16, 0]);
        for _ in 0..20 {
            app.step(&mut c);
        }
        let peak1 = c.value_at(app.u, [16, 16, 0]);
        assert!(peak1 < peak0);
        assert!(peak1 > 0.0);
    }
}
