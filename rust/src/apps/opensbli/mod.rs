//! OpenSBLI-style 3D Taylor–Green vortex: compressible Navier–Stokes in
//! conservative form, 4th-order central differences, 3-stage Runge–Kutta,
//! periodic in all directions.
//!
//! Matches the paper's structure: **29 datasets** (5 conserved + 5 RK
//! saves + 5 residuals + 5 primitives + 9 velocity-gradient work arrays),
//! 9 distinct stencils, ~9 grid loops per RK stage with **no reductions
//! in the bulk**, so chains can tile across an arbitrary number of
//! timesteps (`steps_per_chain`, the §5.3 depth study). One kernel — the
//! RHS/residual evaluation — dominates runtime and is latency-sensitive
//! (the paper: 60% on KNL, 68% on the P100); its `bw_efficiency` models
//! that.
//!
//! Periodic boundaries use [`crate::ops::Drive::exchange_periodic`] at
//! chain boundaries with halos deep enough for the whole chain (4 cells
//! of validity consumed per stage → depth `12 × steps_per_chain`), with
//! redundant halo-deep computation inside the chain — the standard OPS
//! MPI+tiling execution scheme.

use crate::ops::kernel::kernel;
use crate::ops::kir;
use crate::ops::stencil::shapes;
use crate::ops::{
    Access, Arg, BlockId, DatasetId, Declare, Drive, RedOp, Record, ReductionId, StencilId,
};
use std::f64::consts::PI;

/// Validity consumed per RK stage: the gradient loops eat 2 cells; the
/// residual reads primitives/conserved at radius 2 from the *same*
/// validity level (its viscous terms use direct second/mixed derivatives
/// of the primitives, and the stored gradient tensor only pointwise).
const SHRINK_PER_STAGE: usize = 2;
/// RK3 stage coefficients (u = save + dt*c_s*R(u)).
const RK_C: [f64; 3] = [1.0 / 3.0, 0.5, 1.0];

/// Relative bandwidth-efficiency of the dominant RHS kernel (calibrated
/// so its runtime share lands at the paper's 60–68%).
const RESIDUAL_EFF: f64 = 0.30;
/// Relative efficiency of the light kernels (the paper: "the average
/// bandwidth of all the other kernels is 450 GB/s" vs a 170 GB/s app
/// average on the P100).
const LIGHT_EFF: f64 = 1.6;

/// 4th-order central first derivative along `d` of IR argument `a`:
/// `(8(f₁ − f₋₁) − (f₂ − f₋₂)) / 12h` — the same association order as
/// the handwritten closures this module used to carry.
fn d1_ir(a: usize, d: usize, inv12h: f64) -> kir::Expr {
    let off = |s: i32| {
        let mut p = [0i32; 3];
        p[d] = s;
        p
    };
    (kir::lit(8.0) * (kir::read(a, off(1)) - kir::read(a, off(-1)))
        - (kir::read(a, off(2)) - kir::read(a, off(-2))))
        * kir::lit(inv12h)
}

/// 4th-order central second derivative along `d` of IR argument `a`.
fn d2_ir(a: usize, d: usize, inv12h2: f64) -> kir::Expr {
    let off = |s: i32| {
        let mut p = [0i32; 3];
        p[d] = s;
        p
    };
    (-(kir::read(a, off(2)) + kir::read(a, off(-2)))
        + kir::lit(16.0) * (kir::read(a, off(1)) + kir::read(a, off(-1)))
        - kir::lit(30.0) * kir::read(a, [0, 0, 0]))
        * kir::lit(inv12h2)
}

/// Mixed second derivative `∂²/∂x_i∂x_j` (`i ≠ j`) of IR argument `a`
/// from the four in-plane corners.
fn cross_ir(a: usize, i: usize, j: usize, inv4hh: f64) -> kir::Expr {
    let off = |si: i32, sj: i32| {
        let mut p = [0i32; 3];
        p[i] = si;
        p[j] += sj;
        p
    };
    (kir::read(a, off(1, 1)) - kir::read(a, off(1, -1)) - kir::read(a, off(-1, 1))
        + kir::read(a, off(-1, -1)))
        * kir::lit(inv4hh)
}

pub struct OpenSbli {
    pub block: BlockId,
    /// Grid points per dimension (anisotropic resolution of the 2π box:
    /// benches use tall-z grids so the skewed tiles have room).
    pub n: [usize; 3],
    /// Grid spacing per dimension (2π / n).
    pub h: [f64; 3],
    pub dt: f64,
    pub steps_per_chain: usize,
    pub halo_depth: usize,

    /// Conserved: rho, rhou, rhov, rhow, rhoE.
    pub q: [DatasetId; 5],
    /// RK saves.
    pub qs: [DatasetId; 5],
    /// Residuals.
    pub res: [DatasetId; 5],
    /// Primitives: u, v, w, p, t.
    pub prim: [DatasetId; 5],
    /// Velocity-gradient tensor: `wk[3*i+j] = d u_i / d x_j`.
    pub wk: [DatasetId; 9],

    s_pt: StencilId,
    s_d1: [StencilId; 3], // 4th-order derivative lines (radius 2)
    s_full: StencilId,    // radius-2 star (residual kernel)

    pub r_ke: ReductionId,

    pub gamma: f64,
    pub mach: f64,
    pub re: f64,
    pub pr: f64,
}

impl OpenSbli {
    /// `steps_per_chain` controls how many timesteps one lazy chain spans
    /// (the paper tiles over 1–3 timesteps, 5 for unified memory).
    pub fn new<D: Declare>(ctx: &mut D, n: usize, steps_per_chain: usize, model_scale: u64) -> Self {
        Self::new_aniso(ctx, [n, n, n], steps_per_chain, model_scale)
    }

    /// Anisotropic-resolution variant: same 2π-periodic box, different
    /// point counts per dimension (benches use tall z).
    pub fn new_aniso<D: Declare>(
        ctx: &mut D,
        n: [usize; 3],
        steps_per_chain: usize,
        model_scale: u64,
    ) -> Self {
        let halo_depth = SHRINK_PER_STAGE * 3 * steps_per_chain;
        assert!(
            halo_depth <= n[0].min(n[1]).min(n[2]),
            "grid {n:?} too small for {steps_per_chain} steps/chain (needs halo {halo_depth})"
        );
        ctx.set_model_elem_bytes(8 * model_scale.max(1));
        let block = ctx.decl_block("tgv", n);
        let hd = halo_depth as i32;
        let h3 = [hd, hd, hd];
        let size = n;
        let dat = |ctx: &mut D, nme: &str| ctx.decl_dat(block, nme, size, h3, h3);

        let q = [
            dat(ctx, "rho"),
            dat(ctx, "rhou"),
            dat(ctx, "rhov"),
            dat(ctx, "rhow"),
            dat(ctx, "rhoE"),
        ];
        let qs = [
            dat(ctx, "rho_s"),
            dat(ctx, "rhou_s"),
            dat(ctx, "rhov_s"),
            dat(ctx, "rhow_s"),
            dat(ctx, "rhoE_s"),
        ];
        let res = [
            dat(ctx, "res_rho"),
            dat(ctx, "res_rhou"),
            dat(ctx, "res_rhov"),
            dat(ctx, "res_rhow"),
            dat(ctx, "res_rhoE"),
        ];
        let prim = [
            dat(ctx, "u"),
            dat(ctx, "v"),
            dat(ctx, "w"),
            dat(ctx, "p"),
            dat(ctx, "t"),
        ];
        let wk = [
            dat(ctx, "wk0"),
            dat(ctx, "wk1"),
            dat(ctx, "wk2"),
            dat(ctx, "wk3"),
            dat(ctx, "wk4"),
            dat(ctx, "wk5"),
            dat(ctx, "wk6"),
            dat(ctx, "wk7"),
            dat(ctx, "wk8"),
        ];

        let s_pt = ctx.decl_stencil("sbli_000", shapes::point());
        let mk_line = |ctx: &mut D, nme: &str, d: usize| {
            let pts: Vec<[i32; 3]> = (-2..=2)
                .map(|k| {
                    let mut p = [0i32; 3];
                    p[d] = k;
                    p
                })
                .collect();
            ctx.decl_stencil(nme, pts)
        };
        let s_d1 = [
            mk_line(ctx, "d1_x", 0),
            mk_line(ctx, "d1_y", 1),
            mk_line(ctx, "d1_z", 2),
        ];
        // residual reads: radius-2 star + the 12 in-plane corners used by
        // the mixed second derivatives of the viscous terms.
        let mut full_pts = shapes::star3d(2);
        for &(a, b) in &[(1, 1), (1, -1), (-1, 1), (-1, -1)] {
            full_pts.push([a, b, 0]);
            full_pts.push([a, 0, b]);
            full_pts.push([0, a, b]);
        }
        let s_full = ctx.decl_stencil("star2c_3d", full_pts);

        let r_ke = ctx.decl_reduction("ke", RedOp::Sum);

        let h = [
            2.0 * PI / n[0] as f64,
            2.0 * PI / n[1] as f64,
            2.0 * PI / n[2] as f64,
        ];
        OpenSbli {
            block,
            n,
            h,
            dt: 0.1 * h[0].min(h[1]).min(h[2]), // fixed conservative dt (the
            // chain-rule convective form aliases on coarse grids; no
            // reductions in the bulk, as the paper notes)
            steps_per_chain,
            halo_depth,
            q,
            qs,
            res,
            prim,
            wk,
            s_pt,
            s_d1,
            s_full,
            r_ke,
            gamma: 1.4,
            mach: 0.1,
            re: 1600.0,
            pr: 0.71,
        }
    }

    fn range(&self, ext: isize) -> crate::ops::Range3 {
        [
            (-ext, self.n[0] as isize + ext),
            (-ext, self.n[1] as isize + ext),
            (-ext, self.n[2] as isize + ext),
        ]
    }

    // ---------------------------------------------------------------- init

    /// Standard TGV initial condition (Mach 0.1 compressible setup).
    pub fn initialise(&self, ctx: &mut impl Record) {
        let h = self.h;
        let gamma = self.gamma;
        let mach = self.mach;
        let ext = self.halo_depth as isize;
        ctx.par_loop_eff(
            "sbli_init",
            self.block,
            self.range(ext),
            kernel(move |c| {
                let [i, j, k] = c.idx();
                let x = i as f64 * h[0];
                let y = j as f64 * h[1];
                let z = k as f64 * h[2];
                let u = x.sin() * y.cos() * z.cos();
                let v = -x.cos() * y.sin() * z.cos();
                let w = 0.0;
                let p0 = 1.0 / (gamma * mach * mach);
                let p = p0 + ((2.0 * x).cos() + (2.0 * y).cos()) * ((2.0 * z).cos() + 2.0) / 16.0;
                let rho = 1.0;
                let e = p / (gamma - 1.0) + 0.5 * rho * (u * u + v * v + w * w);
                c.w3(0, 0, 0, 0, rho);
                c.w3(1, 0, 0, 0, rho * u);
                c.w3(2, 0, 0, 0, rho * v);
                c.w3(3, 0, 0, 0, rho * w);
                c.w3(4, 0, 0, 0, e);
            }),
            (0..5)
                .map(|i| Arg::dat(self.q[i], self.s_pt, Access::Write))
                .collect(),
            LIGHT_EFF,
        );
    }

    // ------------------------------------------------------------ kernels
    //
    // The bulk grid kernels are recorded as declarative kernel IR
    // (`par_loop_ir`): the native executor interprets the closure
    // *derived* from the IR, the vector executor compiles the same IR
    // into row programs, so both backends compute identical bits. Each
    // expression tree mirrors the original handwritten closure term by
    // term (association order preserved). Only the trig-heavy
    // `sbli_init` stays a handwritten closure.

    /// Save the conserved state at the start of a timestep.
    fn rk_save(&self, ctx: &mut impl Record, ext: isize) {
        let mut k = kir::KirBuilder::new();
        for i in 0..5 {
            k.store(5 + i, kir::read(i, [0, 0, 0]));
        }
        ctx.par_loop_ir(
            "sbli_rk_save",
            self.block,
            self.range(ext),
            k.build(),
            (0..5)
                .map(|i| Arg::dat(self.q[i], self.s_pt, Access::Read))
                .chain((0..5).map(|i| Arg::dat(self.qs[i], self.s_pt, Access::Write)))
                .collect(),
            LIGHT_EFF,
        );
    }

    /// Primitives from conserved (pointwise).
    fn primitives(&self, ctx: &mut impl Record, ext: isize) {
        let gamma = self.gamma;
        let o = [0, 0, 0];
        let mut k = kir::KirBuilder::new();
        let rho = k.let_(kir::read(0, o).max(1e-12));
        let u = k.let_(kir::read(1, o) / rho.clone());
        let v = k.let_(kir::read(2, o) / rho.clone());
        let w = k.let_(kir::read(3, o) / rho.clone());
        let p = k.let_(
            kir::lit(gamma - 1.0)
                * (kir::read(4, o)
                    - kir::lit(0.5)
                        * rho.clone()
                        * (u.clone() * u.clone() + v.clone() * v.clone() + w.clone() * w.clone())),
        );
        k.store(5, u);
        k.store(6, v);
        k.store(7, w);
        k.store(8, p.clone());
        k.store(9, kir::lit(gamma) * p / rho);
        ctx.par_loop_ir(
            "sbli_primitives",
            self.block,
            self.range(ext),
            k.build(),
            (0..5)
                .map(|i| Arg::dat(self.q[i], self.s_pt, Access::Read))
                .chain((0..5).map(|i| Arg::dat(self.prim[i], self.s_pt, Access::Write)))
                .collect(),
            LIGHT_EFF,
        );
    }

    /// Velocity-gradient tensor: one loop per velocity component writing
    /// its three derivatives.
    fn velocity_gradients(&self, ctx: &mut impl Record, ext: isize) {
        let inv12h = [
            1.0 / (12.0 * self.h[0]),
            1.0 / (12.0 * self.h[1]),
            1.0 / (12.0 * self.h[2]),
        ];
        for vi in 0..3 {
            // args 0..3 are the same velocity with per-direction
            // derivative stencils
            let mut k = kir::KirBuilder::new();
            for d in 0..3 {
                k.store(3 + d, d1_ir(d, d, inv12h[d]));
            }
            ctx.par_loop_ir(
                &format!("sbli_grad_u{vi}"),
                self.block,
                self.range(ext),
                k.build(),
                vec![
                    Arg::dat(self.prim[vi], self.s_d1[0], Access::Read),
                    Arg::dat(self.prim[vi], self.s_d1[1], Access::Read),
                    Arg::dat(self.prim[vi], self.s_d1[2], Access::Read),
                    Arg::dat(self.wk[3 * vi], self.s_pt, Access::Write),
                    Arg::dat(self.wk[3 * vi + 1], self.s_pt, Access::Write),
                    Arg::dat(self.wk[3 * vi + 2], self.s_pt, Access::Write),
                ],
                LIGHT_EFF,
            );
        }
    }

    /// The dominant RHS kernel: convective + viscous + heat-flux terms
    /// into the residual arrays. Latency-sensitive (paper: 60–68% of
    /// runtime).
    ///
    /// Argument map: 0..5 conserved, 5..10 primitives, 10..19 gradient
    /// tensor, 19..24 residuals (write).
    fn residual(&self, ctx: &mut impl Record, ext: isize) {
        let inv12h = [
            1.0 / (12.0 * self.h[0]),
            1.0 / (12.0 * self.h[1]),
            1.0 / (12.0 * self.h[2]),
        ];
        let inv12h2 = [
            1.0 / (12.0 * self.h[0] * self.h[0]),
            1.0 / (12.0 * self.h[1] * self.h[1]),
            1.0 / (12.0 * self.h[2] * self.h[2]),
        ];
        let inv4hh = [
            [0.0, 0.25 / (self.h[0] * self.h[1]), 0.25 / (self.h[0] * self.h[2])],
            [0.25 / (self.h[1] * self.h[0]), 0.0, 0.25 / (self.h[1] * self.h[2])],
            [0.25 / (self.h[2] * self.h[0]), 0.25 / (self.h[2] * self.h[1]), 0.0],
        ];
        let mu = 1.0 / self.re;
        let kappa = mu * self.gamma / (self.pr * (self.gamma - 1.0));
        let mut args: Vec<Arg> = (0..5)
            .map(|i| Arg::dat(self.q[i], self.s_full, Access::Read))
            .collect();
        args.extend((0..5).map(|i| Arg::dat(self.prim[i], self.s_full, Access::Read)));
        args.extend((0..9).map(|i| Arg::dat(self.wk[i], self.s_pt, Access::Read)));
        args.extend((0..5).map(|i| Arg::dat(self.res[i], self.s_pt, Access::Write)));

        let o = [0, 0, 0];
        // stored gradient tensor (pointwise)
        let g = |i: usize, j: usize| kir::read(10 + 3 * i + j, o);
        let mut k = kir::KirBuilder::new();
        let u = [
            k.let_(kir::read(5, o)),
            k.let_(kir::read(6, o)),
            k.let_(kir::read(7, o)),
        ];
        let p = k.let_(kir::read(8, o));
        let e = k.let_(kir::read(4, o));

        // --- convective terms (chain rule over stored fields); the
        // explicit lit(0.0) seeds mirror the closure's `+=` chains (a
        // folded-away seed would flip -0.0 sums) ---
        let mut div_m = kir::lit(0.0);
        let mut conv_mom = [kir::lit(0.0), kir::lit(0.0), kir::lit(0.0)];
        let mut conv_e = kir::lit(0.0);
        for j in 0..3 {
            div_m = div_m + d1_ir(1 + j, j, inv12h[j]);
            for (i, cm) in conv_mom.iter_mut().enumerate() {
                *cm = cm.clone()
                    + (u[j].clone() * d1_ir(1 + i, j, inv12h[j]) + kir::read(1 + i, o) * g(j, j));
            }
            conv_e = conv_e
                + (u[j].clone() * (d1_ir(4, j, inv12h[j]) + d1_ir(8, j, inv12h[j]))
                    + (e.clone() + p.clone()) * g(j, j));
        }

        // --- viscous terms via direct second/mixed derivatives of the
        // primitives (radius ≤ 2 reads; no derivative of wk, which
        // keeps the per-stage halo consumption at 2) ---
        let divu = k.let_(g(0, 0) + g(1, 1) + g(2, 2));
        let mut visc_mom = Vec::with_capacity(3);
        for i in 0..3 {
            // Σ_j ∂²u_i/∂x_j²
            let mut lap_ui = kir::lit(0.0);
            for j in 0..3 {
                lap_ui = lap_ui + d2_ir(5 + i, j, inv12h2[j]);
            }
            // ∂(div u)/∂x_i = Σ_j ∂²u_j/∂x_i∂x_j
            let mut ddiv_dxi = kir::lit(0.0);
            for j in 0..3 {
                ddiv_dxi = ddiv_dxi
                    + if i == j {
                        d2_ir(5 + j, i, inv12h2[i])
                    } else {
                        cross_ir(5 + j, i, j, inv4hh[i][j])
                    };
            }
            visc_mom.push(k.let_(kir::lit(mu) * (lap_ui + ddiv_dxi / 3.0)));
        }
        // energy: Σ_ij ∂(u_i τ_ij)/∂x_j = Σ_ij g_ij τ_ij + Σ_i u_i Σ_j ∂τ_ij/∂x_j
        let mut visc_e = kir::lit(0.0);
        for i in 0..3 {
            for j in 0..3 {
                let tau = if i == j {
                    kir::lit(mu) * (g(i, j) + g(j, i) - kir::lit(2.0 / 3.0) * divu.clone())
                } else {
                    // the closure subtracts a literal 0.0 here; `x - 0.0`
                    // is a bitwise identity, so no mirror is needed
                    kir::lit(mu) * (g(i, j) + g(j, i))
                };
                visc_e = visc_e + tau * g(i, j);
            }
            visc_e = visc_e + u[i].clone() * visc_mom[i].clone();
        }
        let lap_t =
            d2_ir(9, 0, inv12h2[0]) + d2_ir(9, 1, inv12h2[1]) + d2_ir(9, 2, inv12h2[2]);

        k.store(19, -div_m);
        for (i, cm) in conv_mom.into_iter().enumerate() {
            k.store(20 + i, -cm - d1_ir(8, i, inv12h[i]) + visc_mom[i].clone());
        }
        k.store(23, -conv_e + visc_e + kir::lit(kappa) * lap_t);

        ctx.par_loop_ir(
            "sbli_residual",
            self.block,
            self.range(ext),
            k.build(),
            args,
            RESIDUAL_EFF,
        );
    }

    /// RK stage update: q = q_save + dt·c_s·res.
    fn rk_update(&self, ctx: &mut impl Record, stage: usize, ext: isize) {
        let coef = RK_C[stage] * self.dt;
        let mut args: Vec<Arg> = (0..5)
            .map(|i| Arg::dat(self.qs[i], self.s_pt, Access::Read))
            .collect();
        args.extend((0..5).map(|i| Arg::dat(self.res[i], self.s_pt, Access::Read)));
        args.extend((0..5).map(|i| Arg::dat(self.q[i], self.s_pt, Access::Write)));
        let mut k = kir::KirBuilder::new();
        for i in 0..5 {
            k.store(
                10 + i,
                kir::read(i, [0, 0, 0]) + kir::lit(coef) * kir::read(5 + i, [0, 0, 0]),
            );
        }
        ctx.par_loop_ir(
            &format!("sbli_rk_update{stage}"),
            self.block,
            self.range(ext),
            k.build(),
            args,
            LIGHT_EFF,
        );
    }

    // ------------------------------------------------------------ driver

    /// Refresh periodic halos of the conserved fields to full depth —
    /// chain boundary (flushes the queue).
    pub fn exchange_halos(&self, ctx: &mut impl Drive) {
        for i in 0..5 {
            for dim in 0..3 {
                ctx.exchange_periodic(self.q[i], dim, self.halo_depth);
            }
        }
    }

    /// Queue one timestep's loops. `chain_pos` is the timestep's index
    /// within the current chain (drives the deep-halo range shrinking).
    pub fn step(&mut self, ctx: &mut impl Record, chain_pos: usize) {
        let mut v = (self.halo_depth - SHRINK_PER_STAGE * 3 * chain_pos) as isize;
        self.rk_save(ctx, v);
        for stage in 0..3 {
            self.primitives(ctx, v);
            self.velocity_gradients(ctx, v - 2);
            self.residual(ctx, v - 2);
            self.rk_update(ctx, stage, v - 2);
            v -= SHRINK_PER_STAGE as isize;
        }
    }

    /// Volume-averaged kinetic energy (trigger point, used between
    /// chains as the physics monitor).
    pub fn kinetic_energy(&self, ctx: &mut impl Drive) -> f64 {
        let n3 = (self.n[0] * self.n[1] * self.n[2]) as f64;
        let o = [0, 0, 0];
        let mut k = kir::KirBuilder::new();
        let rho = k.let_(kir::read(0, o).max(1e-12));
        let ke = kir::lit(0.5)
            * (kir::read(1, o) * kir::read(1, o)
                + kir::read(2, o) * kir::read(2, o)
                + kir::read(3, o) * kir::read(3, o))
            / rho;
        k.reduce(0, RedOp::Sum, ke / kir::lit(n3));
        ctx.par_loop_ir(
            "sbli_ke",
            self.block,
            self.range(0),
            k.build(),
            (0..4)
                .map(|i| Arg::dat(self.q[i], self.s_pt, Access::Read))
                .chain(std::iter::once(Arg::GblRed {
                    red: self.r_ke,
                    op: RedOp::Sum,
                }))
                .collect(),
            LIGHT_EFF,
        );
        ctx.reduction_result(self.r_ke)
    }

    /// Record one whole chain of `steps_per_chain` timesteps **once**
    /// (the record-once API): replay it with
    /// [`crate::program::Session::replay`], calling
    /// [`Self::exchange_halos`] between replays exactly as the legacy
    /// driver does between chains. OpenSBLI has no data-dependent
    /// control flow (fixed `dt`, no reductions in the bulk), so the
    /// whole multi-step chain freezes cleanly.
    pub fn record_step_chain(
        &mut self,
        b: &mut crate::program::ProgramBuilder,
    ) -> crate::program::ChainId {
        let spc = self.steps_per_chain;
        b.record_chain("sbli_steps", |r| {
            for s in 0..spc {
                self.step(r, s);
            }
        })
    }

    /// Benchmark driver: `chains` chains of `steps_per_chain` timesteps.
    pub fn run(&mut self, ctx: &mut impl Drive, chains: usize) {
        self.initialise(ctx);
        ctx.flush();
        ctx.reset_metrics();
        ctx.set_cyclic_phase(true);
        for _ in 0..chains {
            self.exchange_halos(ctx); // flushes the previous chain
            for s in 0..self.steps_per_chain {
                self.step(ctx, s);
            }
        }
        ctx.flush();
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::coordinator::{Config, Platform};
    use crate::memory::{AppCalib, Link};
    use crate::ops::OpsContext;

    fn ctx(p: Platform) -> OpsContext {
        OpsContext::new(Config::new(p, AppCalib::OPENSBLI).build_engine())
    }

    #[test]
    fn dataset_count_matches_paper() {
        let mut c = ctx(Platform::KnlFlatDdr4);
        let _app = OpenSbli::new(&mut c, 16, 1, 1);
        assert_eq!(c.datasets().len(), 29, "paper: 29 datasets");
    }

    #[test]
    fn ke_starts_at_tgv_value_and_decays() {
        let mut c = ctx(Platform::KnlFlatDdr4);
        let mut app = OpenSbli::new(&mut c, 16, 1, 1);
        app.initialise(&mut c);
        let ke0 = app.kinetic_energy(&mut c);
        // TGV volume-averaged KE = 1/8 (ρ=1)
        assert!((ke0 - 0.125).abs() < 0.01, "ke0 = {ke0}");
        for _ in 0..3 {
            app.exchange_halos(&mut c);
            app.step(&mut c, 0);
        }
        let ke1 = app.kinetic_energy(&mut c);
        assert!(ke1.is_finite());
        // 4th-order central differences on a coarse 16^3 grid are not
        // discretely energy-conservative; allow sub-1% drift over 3 steps.
        assert!(ke1 > 0.0 && ke1 < ke0 * 1.01, "ke {ke0} -> {ke1}");
    }

    #[test]
    fn fields_stay_finite_over_chains() {
        let mut c = ctx(Platform::KnlFlatDdr4);
        let mut app = OpenSbli::new(&mut c, 24, 2, 1);
        app.run(&mut c, 2);
        for i in 0..5 {
            let buf = c.fetch(app.q[i]);
            assert!(buf.iter().all(|v| v.is_finite()), "field {i} has NaN/inf");
        }
    }

    #[test]
    fn multi_step_chain_matches_single_step_chains() {
        // Tiling across 2 timesteps with deep halos must give the same
        // interior answer as two 1-step chains.
        let run = |spc: usize| {
            let mut c = ctx(Platform::KnlFlatDdr4);
            let mut app = OpenSbli::new(&mut c, 24, spc, 1);
            app.initialise(&mut c);
            c.flush();
            for _ in 0..(2 / spc) {
                app.exchange_halos(&mut c);
                for s in 0..spc {
                    app.step(&mut c, s);
                }
            }
            c.flush();
            let ds = c.dataset(app.q[1]).clone();
            let buf = c.fetch(app.q[1]);
            let n = app.n[0] as isize;
            let mut vals = vec![];
            for z in 0..n {
                for y in 0..n {
                    for x in 0..n {
                        vals.push(buf[ds.offset([x, y, z]) as usize]);
                    }
                }
            }
            vals
        };
        let a = run(1);
        let b = run(2);
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!(
                (x - y).abs() <= 1e-12 * x.abs().max(1.0),
                "mismatch at {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn tiled_matches_untiled_bitexact() {
        let run = |p: Platform| {
            let mut c = ctx(p);
            let mut app = OpenSbli::new(&mut c, 16, 1, 1);
            app.run(&mut c, 2);
            c.fetch(app.q[4])
        };
        let a = run(Platform::KnlFlatDdr4);
        let b = run(Platform::KnlCacheTiled);
        let g = run(Platform::GpuExplicit {
            link: Link::NvLink,
            cyclic: true,
            prefetch: true,
        });
        assert_eq!(a, b);
        assert_eq!(a, g);
    }

    #[test]
    fn residual_dominates_runtime() {
        // use a bench-shaped grid: the tiny cube of the other tests has a
        // different halo-to-interior ratio and skews the byte shares
        let mut c = ctx(Platform::GpuBaseline { link: Link::PciE });
        let mut app = OpenSbli::new_aniso(&mut c, [16, 16, 256], 1, 1);
        app.run(&mut c, 3);
        let m = c.metrics();
        let hot = &m.per_loop["sbli_residual"];
        let share = hot.time_s / m.loop_time_s;
        assert!(
            share > 0.5 && share < 0.85,
            "residual share {share} outside the paper's 60-68% band"
        );
    }
}
