//! CloverLeaf 2D: the Mantevo hydro mini-app (compressible Euler on a
//! staggered Cartesian grid, explicit second-order predictor–corrector
//! Lagrangian step + directionally-split van-Leer advection), expressed
//! as OPS-style parallel loops.
//!
//! Faithful to the structure the paper measures: **25 datasets** per
//! gridpoint (7 cell-centred state fields, 4 node-centred velocities,
//! 4 face fluxes, 7 work arrays, 3 geometry fields), multi-point
//! staggered stencils, and one long loop chain per timestep terminated by
//! the `calc_dt` reduction (the OPS trigger point). Simplifications vs
//! the original (documented in DESIGN.md): uniform grid spacing (the 1D
//! `celldx/celldy` tables become loop constants) and reflective halo
//! loops standing in for MPI halo exchange + boundary conditions.

pub mod kernels;

use crate::ops::kernel::kernel;
use crate::ops::kir;
use crate::ops::stencil::shapes;
use crate::ops::{
    Access, Arg, BlockId, DatasetId, Declare, Drive, RedOp, Record, ReductionId, StencilId,
};

const G_SMALL: f64 = 1.0e-16;
const G_BIG: f64 = 1.0e21;

/// Simulation state: all handles + run parameters.
pub struct CloverLeaf2D {
    pub block: BlockId,
    pub nx: usize,
    pub ny: usize,
    pub dx: f64,
    pub dy: f64,
    pub gamma: f64,
    pub dtinit: f64,
    pub dt: f64,

    // cell-centred fields
    pub density0: DatasetId,
    pub density1: DatasetId,
    pub energy0: DatasetId,
    pub energy1: DatasetId,
    pub pressure: DatasetId,
    pub viscosity: DatasetId,
    pub soundspeed: DatasetId,
    // node-centred velocities
    pub xvel0: DatasetId,
    pub xvel1: DatasetId,
    pub yvel0: DatasetId,
    pub yvel1: DatasetId,
    // face fluxes
    pub vol_flux_x: DatasetId,
    pub vol_flux_y: DatasetId,
    pub mass_flux_x: DatasetId,
    pub mass_flux_y: DatasetId,
    // work arrays (named after their primary roles)
    pub work1: DatasetId, // pre_vol
    pub work2: DatasetId, // post_vol
    pub work3: DatasetId, // node_flux
    pub work4: DatasetId, // node_mass_post
    pub work5: DatasetId, // node_mass_pre
    pub work6: DatasetId, // mom_flux
    pub work7: DatasetId, // ener_flux
    // geometry (2D fields, as in the original)
    pub volume: DatasetId,
    pub xarea: DatasetId,
    pub yarea: DatasetId,

    // stencils
    s_pt: StencilId,
    s_cell_to_node: StencilId, // node reads cells at (-1..0)^2
    s_node_to_cell: StencilId, // cell reads nodes at (0..1)^2
    s_xp1: StencilId,          // (0,0),(1,0)
    s_yp1: StencilId,          // (0,0),(0,1)
    s_xm1: StencilId,          // (-1,0),(0,0)
    s_ym1: StencilId,          // (0,-1),(0,0)
    s_star: StencilId,
    s_adv_x: StencilId,   // (-2..1, 0)
    s_adv_y: StencilId,   // (0, -2..1)
    s_mom_x: StencilId,   // (-1..2, 0)
    s_mom_y: StencilId,   // (0, -1..2)
    s_nflux_x: StencilId, // (0,-1),(0,0),(1,-1),(1,0)
    s_nflux_y: StencilId, // (-1,0),(0,0),(-1,1),(0,1)
    s_halo_x: StencilId, // (-4..4, 0): x-edge mirror reads
    s_halo_y: StencilId, // (0, -4..4): y-edge mirror reads

    // reductions
    pub r_dt: ReductionId,
    pub r_vol: ReductionId,
    pub r_mass: ReductionId,
    pub r_ie: ReductionId,
    pub r_ke: ReductionId,
    pub r_press: ReductionId,

    /// Sweep alternation (xy / yx), as in the original.
    step_parity: bool,
}

/// Result of `field_summary` — the paper's per-app sanity table.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FieldSummary {
    pub volume: f64,
    pub mass: f64,
    pub internal_energy: f64,
    pub kinetic_energy: f64,
    pub pressure: f64,
}

/// Van-Leer limited difference as kernel IR (mirrors [`limited`]
/// term-by-term; the data-dependent branch becomes a `select`).
fn limited_ir(diffuw: kir::Expr, diffdw: kir::Expr, sigma: kir::Expr) -> kir::Expr {
    let auw = diffuw.clone().abs();
    let adw = diffdw.clone().abs();
    let wind = diffdw.clone().le(0.0).select(kir::lit(-1.0), kir::lit(1.0));
    let val = (kir::lit(1.0) - sigma.clone())
        * wind
        * (kir::lit(1.0 / 6.0)
            * ((kir::lit(1.0) + sigma.clone()) * auw.clone()
                + (kir::lit(2.0) - sigma) * adw.clone()))
        .min(auw)
        .min(adw);
    (diffuw * diffdw).gt(0.0).select(val, kir::lit(0.0))
}

/// Van-Leer-style limited difference used by the advection kernels.
#[inline]
fn limited(diffuw: f64, diffdw: f64, sigma: f64) -> f64 {
    if diffuw * diffdw > 0.0 {
        let auw = diffuw.abs();
        let adw = diffdw.abs();
        let wind = if diffdw <= 0.0 { -1.0 } else { 1.0 };
        let one_by_six = 1.0 / 6.0;
        (1.0 - sigma)
            * wind
            * (one_by_six * ((1.0 + sigma) * auw + (2.0 - sigma) * adw))
                .min(auw)
                .min(adw)
    } else {
        0.0
    }
}

impl CloverLeaf2D {
    /// Declare all datasets/stencils. `model_scale` multiplies modelled
    /// bytes per element so a small grid can stand in for a paper-sized
    /// problem inside the memory simulators.
    pub fn new<D: Declare>(ctx: &mut D, nx: usize, ny: usize, model_scale: u64) -> Self {
        ctx.set_model_elem_bytes(8 * model_scale.max(1));
        let block = ctx.decl_block("clover", [nx, ny, 1]);
        let h = [2, 2, 0];
        let cell = [nx, ny, 1];
        let node = [nx + 1, ny + 1, 1];
        let xface = [nx + 1, ny, 1];
        let yface = [nx, ny + 1, 1];

        let dat = |ctx: &mut D, n: &str, s: [usize; 3]| ctx.decl_dat(block, n, s, h, h);

        let density0 = dat(ctx, "density0", cell);
        let density1 = dat(ctx, "density1", cell);
        let energy0 = dat(ctx, "energy0", cell);
        let energy1 = dat(ctx, "energy1", cell);
        let pressure = dat(ctx, "pressure", cell);
        let viscosity = dat(ctx, "viscosity", cell);
        let soundspeed = dat(ctx, "soundspeed", cell);
        let xvel0 = dat(ctx, "xvel0", node);
        let xvel1 = dat(ctx, "xvel1", node);
        let yvel0 = dat(ctx, "yvel0", node);
        let yvel1 = dat(ctx, "yvel1", node);
        let vol_flux_x = dat(ctx, "vol_flux_x", xface);
        let vol_flux_y = dat(ctx, "vol_flux_y", yface);
        let mass_flux_x = dat(ctx, "mass_flux_x", xface);
        let mass_flux_y = dat(ctx, "mass_flux_y", yface);
        let work1 = dat(ctx, "work1", node);
        let work2 = dat(ctx, "work2", node);
        let work3 = dat(ctx, "work3", node);
        let work4 = dat(ctx, "work4", node);
        let work5 = dat(ctx, "work5", node);
        let work6 = dat(ctx, "work6", node);
        let work7 = dat(ctx, "work7", node);
        let volume = dat(ctx, "volume", cell);
        let xarea = dat(ctx, "xarea", xface);
        let yarea = dat(ctx, "yarea", yface);

        let s_pt = ctx.decl_stencil("s2d_00", shapes::point());
        let s_cell_to_node = ctx.decl_stencil(
            "cell_to_node",
            shapes::offsets2d(&[(0, 0), (-1, 0), (0, -1), (-1, -1)]),
        );
        let s_node_to_cell = ctx.decl_stencil(
            "node_to_cell",
            shapes::offsets2d(&[(0, 0), (1, 0), (0, 1), (1, 1)]),
        );
        let s_xp1 = ctx.decl_stencil("xp1", shapes::offsets2d(&[(0, 0), (1, 0)]));
        let s_yp1 = ctx.decl_stencil("yp1", shapes::offsets2d(&[(0, 0), (0, 1)]));
        let s_xm1 = ctx.decl_stencil("xm1", shapes::offsets2d(&[(-1, 0), (0, 0)]));
        let s_ym1 = ctx.decl_stencil("ym1", shapes::offsets2d(&[(0, -1), (0, 0)]));
        let s_star = ctx.decl_stencil("star1", shapes::star2d(1));
        let s_adv_x =
            ctx.decl_stencil("adv_x", shapes::offsets2d(&[(-2, 0), (-1, 0), (0, 0), (1, 0)]));
        let s_adv_y =
            ctx.decl_stencil("adv_y", shapes::offsets2d(&[(0, -2), (0, -1), (0, 0), (0, 1)]));
        let s_mom_x =
            ctx.decl_stencil("mom_x", shapes::offsets2d(&[(-1, 0), (0, 0), (1, 0), (2, 0)]));
        let s_mom_y =
            ctx.decl_stencil("mom_y", shapes::offsets2d(&[(0, -1), (0, 0), (0, 1), (0, 2)]));
        let s_nflux_x = ctx.decl_stencil(
            "nflux_x",
            shapes::offsets2d(&[(0, -1), (0, 0), (1, -1), (1, 0)]),
        );
        let s_nflux_y = ctx.decl_stencil(
            "nflux_y",
            shapes::offsets2d(&[(-1, 0), (0, 0), (-1, 1), (0, 1)]),
        );
        let s_halo_x = ctx.decl_stencil(
            "halo_mirror_x",
            (-4..=4).map(|k| [k, 0, 0]).collect(),
        );
        let s_halo_y = ctx.decl_stencil(
            "halo_mirror_y",
            (-4..=4).map(|k| [0, k, 0]).collect(),
        );

        let r_dt = ctx.decl_reduction("dt", RedOp::Min);
        let r_vol = ctx.decl_reduction("vol", RedOp::Sum);
        let r_mass = ctx.decl_reduction("mass", RedOp::Sum);
        let r_ie = ctx.decl_reduction("ie", RedOp::Sum);
        let r_ke = ctx.decl_reduction("ke", RedOp::Sum);
        let r_press = ctx.decl_reduction("press", RedOp::Sum);

        CloverLeaf2D {
            block,
            nx,
            ny,
            dx: 10.0 / nx as f64,
            dy: 10.0 / ny as f64,
            gamma: 1.4,
            dtinit: 0.04,
            dt: 0.04,
            density0,
            density1,
            energy0,
            energy1,
            pressure,
            viscosity,
            soundspeed,
            xvel0,
            xvel1,
            yvel0,
            yvel1,
            vol_flux_x,
            vol_flux_y,
            mass_flux_x,
            mass_flux_y,
            work1,
            work2,
            work3,
            work4,
            work5,
            work6,
            work7,
            volume,
            xarea,
            yarea,
            s_pt,
            s_cell_to_node,
            s_node_to_cell,
            s_xp1,
            s_yp1,
            s_xm1,
            s_ym1,
            s_star,
            s_adv_x,
            s_adv_y,
            s_mom_x,
            s_mom_y,
            s_nflux_x,
            s_nflux_y,
            s_halo_x,
            s_halo_y,
            r_dt,
            r_vol,
            r_mass,
            r_ie,
            r_ke,
            r_press,
            step_parity: false,
        }
    }

    fn cells(&self) -> crate::ops::Range3 {
        [(0, self.nx as isize), (0, self.ny as isize), (0, 1)]
    }

    fn cells_h(&self, d: isize) -> crate::ops::Range3 {
        [
            (-d, self.nx as isize + d),
            (-d, self.ny as isize + d),
            (0, 1),
        ]
    }

    fn nodes(&self) -> crate::ops::Range3 {
        [(0, self.nx as isize + 1), (0, self.ny as isize + 1), (0, 1)]
    }

    // ---------------------------------------------------------------- init

    /// Two-state shock problem (the standard clover.in setup): ambient
    /// (ρ=0.2, e=1.0) with a dense energetic box in the lower-left corner
    /// (ρ=1.0, e=2.5). Also fills the geometry fields.
    pub fn initialise(&self, ctx: &mut impl Record) {
        let (dx, dy) = (self.dx, self.dy);
        let (nx, ny) = (self.nx as isize, self.ny as isize);
        ctx.par_loop(
            "cl2d_init_geom",
            self.block,
            self.cells_h(2),
            kernel(move |c| {
                c.w(0, 0, 0, dx * dy);
                c.w(1, 0, 0, dy);
                c.w(2, 0, 0, dx);
            }),
            vec![
                Arg::dat(self.volume, self.s_pt, Access::Write),
                Arg::dat(self.xarea, self.s_pt, Access::Write),
                Arg::dat(self.yarea, self.s_pt, Access::Write),
            ],
        );
        let (bx, by) = (nx / 2, ny / 2);
        ctx.par_loop(
            "cl2d_init_state",
            self.block,
            self.cells_h(2),
            kernel(move |c| {
                let [x, y, _] = c.idx();
                let in_box = x >= 0 && x < bx && y >= 0 && y < by;
                if in_box {
                    c.w(0, 0, 0, 1.0);
                    c.w(1, 0, 0, 2.5);
                } else {
                    c.w(0, 0, 0, 0.2);
                    c.w(1, 0, 0, 1.0);
                }
            }),
            vec![
                Arg::dat(self.density0, self.s_pt, Access::Write),
                Arg::dat(self.energy0, self.s_pt, Access::Write),
            ],
        );
        ctx.par_loop(
            "cl2d_init_vel",
            self.block,
            [(-2, nx + 3), (-2, ny + 3), (0, 1)],
            kernel(|c| {
                c.w(0, 0, 0, 0.0);
                c.w(1, 0, 0, 0.0);
                c.w(2, 0, 0, 0.0);
                c.w(3, 0, 0, 0.0);
            }),
            vec![
                Arg::dat(self.xvel0, self.s_pt, Access::Write),
                Arg::dat(self.yvel0, self.s_pt, Access::Write),
                Arg::dat(self.xvel1, self.s_pt, Access::Write),
                Arg::dat(self.yvel1, self.s_pt, Access::Write),
            ],
        );
        self.ideal_gas(ctx, false);
        self.halo_cell(ctx, "halo_pressure", self.pressure);
        self.halo_cell(ctx, "halo_density0", self.density0);
        self.halo_cell(ctx, "halo_energy0", self.energy0);
    }

    // ------------------------------------------------------------ kernels

    /// Equation of state: pressure + soundspeed from density/energy.
    pub fn ideal_gas(&self, ctx: &mut impl Record, predict: bool) {
        let gamma = self.gamma;
        let (den, ener) = if predict {
            (self.density1, self.energy1)
        } else {
            (self.density0, self.energy0)
        };
        // EOS as kernel IR: the tree mirrors the original closure
        // term-by-term, so the derived closure is bit-identical.
        let mut k = kir::KirBuilder::new();
        let d = k.let_(kir::read(0, [0, 0, 0]).max(G_SMALL));
        let e = kir::read(1, [0, 0, 0]);
        let v = k.let_(kir::lit(1.0) / d.clone());
        let p = k.let_(kir::lit(gamma - 1.0) * d.clone() * e);
        let pe = kir::lit(gamma - 1.0) * d.clone();
        let pv = -d * p.clone() * v.clone(); // dp/dv along isochor, as in the original
        let ss2 = v.clone() * v * (p.clone() * pe - pv);
        k.store(2, p);
        k.store(3, ss2.max(G_SMALL).sqrt());
        ctx.par_loop_ir(
            "cl2d_ideal_gas",
            self.block,
            self.cells(),
            k.build(),
            vec![
                Arg::dat(den, self.s_pt, Access::Read),
                Arg::dat(ener, self.s_pt, Access::Read),
                Arg::dat(self.pressure, self.s_pt, Access::Write),
                Arg::dat(self.soundspeed, self.s_pt, Access::Write),
            ],
            1.0,
        );
    }

    /// Tensor artificial viscosity (Wilkins-style, as in CloverLeaf).
    pub fn viscosity_kernel(&self, ctx: &mut impl Record) {
        let (dx, dy) = (self.dx, self.dy);
        ctx.par_loop(
            "cl2d_viscosity",
            self.block,
            self.cells(),
            kernel(move |c| {
                let ugrad = 0.5 * ((c.r(1, 1, 0) + c.r(1, 1, 1)) - (c.r(1, 0, 0) + c.r(1, 0, 1)));
                let vgrad = 0.5 * ((c.r(2, 0, 1) + c.r(2, 1, 1)) - (c.r(2, 0, 0) + c.r(2, 1, 0)));
                let div = dy * ugrad + dx * vgrad;
                let strain2 = 0.5 * ((c.r(1, 0, 1) + c.r(1, 1, 1)) - (c.r(1, 0, 0) + c.r(1, 1, 0)))
                    / dy
                    + 0.5 * ((c.r(2, 1, 0) + c.r(2, 1, 1)) - (c.r(2, 0, 0) + c.r(2, 0, 1))) / dx;
                let pgradx = (c.r(0, 1, 0) - c.r(0, -1, 0)) / (2.0 * dx);
                let pgrady = (c.r(0, 0, 1) - c.r(0, 0, -1)) / (2.0 * dy);
                let pgradx2 = pgradx * pgradx;
                let pgrady2 = pgrady * pgrady;
                let limiter = ((0.5 * ugrad / dx) * pgradx2
                    + (0.5 * vgrad / dy) * pgrady2
                    + strain2 * pgradx * pgrady)
                    / (pgradx2 + pgrady2).max(G_SMALL);
                if limiter > 0.0 || div >= 0.0 {
                    c.w(4, 0, 0, 0.0);
                } else {
                    let pgx = pgradx.abs().max(G_SMALL);
                    let pgy = pgrady.abs().max(G_SMALL);
                    let pgrad = (pgradx2 + pgrady2).sqrt();
                    let xgrad = (dx * pgrad / pgx).abs();
                    let ygrad = (dy * pgrad / pgy).abs();
                    let grad = xgrad.min(ygrad);
                    let grad2 = grad * grad;
                    c.w(4, 0, 0, 2.0 * c.r(3, 0, 0) * grad2 * limiter * limiter);
                }
            }),
            vec![
                Arg::dat(self.pressure, self.s_star, Access::Read),
                Arg::dat(self.xvel0, self.s_node_to_cell, Access::Read),
                Arg::dat(self.yvel0, self.s_node_to_cell, Access::Read),
                Arg::dat(self.density0, self.s_pt, Access::Read),
                Arg::dat(self.viscosity, self.s_pt, Access::Write),
            ],
        );
    }

    /// CFL timestep: min over cells of sound/viscous/velocity limits.
    /// Returns the chosen dt — the chain trigger point.
    pub fn calc_dt(&mut self, ctx: &mut impl Drive) -> f64 {
        let (dx, dy) = (self.dx, self.dy);
        ctx.par_loop(
            "cl2d_calc_dt",
            self.block,
            self.cells(),
            kernel(move |c| {
                let cc = c.r(1, 0, 0) * c.r(1, 0, 0)
                    + 2.0 * c.r(2, 0, 0) / c.r(0, 0, 0).max(G_SMALL);
                let cc = cc.max(G_SMALL).sqrt();
                let dtct = 0.7 * dx.min(dy) / cc;
                let mut du: f64 = G_SMALL;
                let mut dv: f64 = G_SMALL;
                for &(ox, oy) in &[(0, 0), (1, 0), (0, 1), (1, 1)] {
                    du = du.max(c.r(3, ox, oy).abs());
                    dv = dv.max(c.r(4, ox, oy).abs());
                }
                let dtut = 0.5 * dx / du;
                let dtvt = 0.5 * dy / dv;
                let div = (c.r(3, 1, 0) + c.r(3, 1, 1) - c.r(3, 0, 0) - c.r(3, 0, 1)) / dx
                    + (c.r(4, 0, 1) + c.r(4, 1, 1) - c.r(4, 0, 0) - c.r(4, 1, 0)) / dy;
                let dtdivt = if div < -G_SMALL { -0.5 / div } else { G_BIG };
                c.red_min(0, dtct.min(dtut).min(dtvt).min(dtdivt).min(G_BIG));
            }),
            vec![
                Arg::dat(self.density0, self.s_pt, Access::Read),
                Arg::dat(self.soundspeed, self.s_pt, Access::Read),
                Arg::dat(self.viscosity, self.s_pt, Access::Read),
                Arg::dat(self.xvel0, self.s_node_to_cell, Access::Read),
                Arg::dat(self.yvel0, self.s_node_to_cell, Access::Read),
                Arg::GblRed {
                    red: self.r_dt,
                    op: RedOp::Min,
                },
            ],
        );
        let dt_cand = ctx.reduction_result(self.r_dt);
        self.dt = dt_cand.min(self.dt * 1.5).min(self.dtinit);
        self.dt
    }

    /// PdV: volume-change update of energy and density. The predictor
    /// uses `xvel0` only with dt/2; the corrector the vel0+vel1 average
    /// with the full dt — exactly the original's two branches.
    pub fn pdv(&self, ctx: &mut impl Record, predict: bool) {
        let dt = self.dt;
        // Per-face flux: area × frac × (sum of the two face-node
        // velocities; predictor doubles vel0, corrector adds vel1).
        let face = |area: usize, ao: [i32; 3], v0: usize, v1: usize, o1: [i32; 3], o2: [i32; 3]| {
            if predict {
                kir::read(area, ao)
                    * kir::lit(0.25 * dt * 0.5)
                    * kir::lit(2.0)
                    * (kir::read(v0, o1) + kir::read(v0, o2))
            } else {
                kir::read(area, ao)
                    * kir::lit(0.25 * dt)
                    * (kir::read(v0, o1) + kir::read(v0, o2) + kir::read(v1, o1)
                        + kir::read(v1, o2))
            }
        };
        let lf = face(5, [0, 0, 0], 1, 2, [0, 0, 0], [0, 1, 0]);
        let rf = face(5, [1, 0, 0], 1, 2, [1, 0, 0], [1, 1, 0]);
        let bf = face(6, [0, 0, 0], 3, 4, [0, 0, 0], [1, 0, 0]);
        let tf = face(6, [0, 1, 0], 3, 4, [0, 1, 0], [1, 1, 0]);
        let mut k = kir::KirBuilder::new();
        let total_flux = k.let_(rf - lf + tf - bf);
        let vol = k.let_(kir::read(7, [0, 0, 0]));
        let volume_change = vol.clone() / (vol.clone() + total_flux.clone()).max(G_SMALL);
        let d0 = k.let_(kir::read(0, [0, 0, 0]));
        let recip = kir::lit(1.0) / (d0.clone() * vol).max(G_SMALL);
        let e1 = kir::read(8, [0, 0, 0])
            - (kir::read(9, [0, 0, 0]) + kir::read(10, [0, 0, 0])) * total_flux * recip;
        k.store(11, e1);
        k.store(12, d0 * volume_change);
        ctx.par_loop_ir(
            if predict { "cl2d_pdv_predict" } else { "cl2d_pdv" },
            self.block,
            self.cells(),
            k.build(),
            vec![
                Arg::dat(self.density0, self.s_pt, Access::Read),
                Arg::dat(self.xvel0, self.s_node_to_cell, Access::Read),
                Arg::dat(self.xvel1, self.s_node_to_cell, Access::Read),
                Arg::dat(self.yvel0, self.s_node_to_cell, Access::Read),
                Arg::dat(self.yvel1, self.s_node_to_cell, Access::Read),
                Arg::dat(self.xarea, self.s_yp1, Access::Read),
                Arg::dat(self.yarea, self.s_xp1, Access::Read),
                Arg::dat(self.volume, self.s_pt, Access::Read),
                Arg::dat(self.energy0, self.s_pt, Access::Read),
                Arg::dat(self.pressure, self.s_pt, Access::Read),
                Arg::dat(self.viscosity, self.s_pt, Access::Read),
                Arg::dat(self.energy1, self.s_pt, Access::Write),
                Arg::dat(self.density1, self.s_pt, Access::Write),
            ],
            1.0,
        );
    }

    /// Revert: discard the predictor state.
    pub fn revert(&self, ctx: &mut impl Record) {
        let mut k = kir::KirBuilder::new();
        k.store(2, kir::read(0, [0, 0, 0]));
        k.store(3, kir::read(1, [0, 0, 0]));
        ctx.par_loop_ir(
            "cl2d_revert",
            self.block,
            self.cells(),
            k.build(),
            vec![
                Arg::dat(self.density0, self.s_pt, Access::Read),
                Arg::dat(self.energy0, self.s_pt, Access::Read),
                Arg::dat(self.density1, self.s_pt, Access::Write),
                Arg::dat(self.energy1, self.s_pt, Access::Write),
            ],
            1.0,
        );
    }

    /// Accelerate: nodal momentum update from pressure + viscosity
    /// gradients.
    pub fn accelerate(&self, ctx: &mut impl Record) {
        let dt = self.dt;
        let (dx, dy) = (self.dx, self.dy);
        let vol = dx * dy;
        let mut k = kir::KirBuilder::new();
        let nodal_mass = kir::lit(0.25)
            * (kir::read(0, [-1, -1, 0])
                + kir::read(0, [0, -1, 0])
                + kir::read(0, [0, 0, 0])
                + kir::read(0, [-1, 0, 0]))
            * kir::lit(vol);
        let sbm = k.let_(kir::lit(0.25 * dt) / nodal_mass.max(G_SMALL));
        let diff = |a: usize, hi: [i32; 3], lo: [i32; 3]| kir::read(a, hi) - kir::read(a, lo);
        let dpx = diff(1, [0, 0, 0], [-1, 0, 0]) + diff(1, [0, -1, 0], [-1, -1, 0]);
        let dvx = diff(2, [0, 0, 0], [-1, 0, 0]) + diff(2, [0, -1, 0], [-1, -1, 0]);
        let dpy = diff(1, [0, 0, 0], [0, -1, 0]) + diff(1, [-1, 0, 0], [-1, -1, 0]);
        let dvy = diff(2, [0, 0, 0], [0, -1, 0]) + diff(2, [-1, 0, 0], [-1, -1, 0]);
        let xv = kir::read(3, [0, 0, 0]) - sbm.clone() * kir::lit(dy) * (dpx + dvx);
        let yv = kir::read(4, [0, 0, 0]) - sbm * kir::lit(dx) * (dpy + dvy);
        k.store(5, xv);
        k.store(6, yv);
        ctx.par_loop_ir(
            "cl2d_accelerate",
            self.block,
            self.nodes(),
            k.build(),
            vec![
                Arg::dat(self.density0, self.s_cell_to_node, Access::Read),
                Arg::dat(self.pressure, self.s_cell_to_node, Access::Read),
                Arg::dat(self.viscosity, self.s_cell_to_node, Access::Read),
                Arg::dat(self.xvel0, self.s_pt, Access::Read),
                Arg::dat(self.yvel0, self.s_pt, Access::Read),
                Arg::dat(self.xvel1, self.s_pt, Access::Write),
                Arg::dat(self.yvel1, self.s_pt, Access::Write),
            ],
            1.0,
        );
    }

    /// Face volume fluxes from the time-averaged velocities.
    pub fn flux_calc(&self, ctx: &mut impl Record) {
        let dt = self.dt;
        let mut k = kir::KirBuilder::new();
        k.store(
            3,
            kir::lit(0.25 * dt)
                * kir::read(0, [0, 0, 0])
                * (kir::read(1, [0, 0, 0])
                    + kir::read(1, [0, 1, 0])
                    + kir::read(2, [0, 0, 0])
                    + kir::read(2, [0, 1, 0])),
        );
        ctx.par_loop_ir(
            "cl2d_flux_calc_x",
            self.block,
            [(0, self.nx as isize + 1), (0, self.ny as isize), (0, 1)],
            k.build(),
            vec![
                Arg::dat(self.xarea, self.s_pt, Access::Read),
                Arg::dat(self.xvel0, self.s_yp1, Access::Read),
                Arg::dat(self.xvel1, self.s_yp1, Access::Read),
                Arg::dat(self.vol_flux_x, self.s_pt, Access::Write),
            ],
            1.0,
        );
        let mut k = kir::KirBuilder::new();
        k.store(
            3,
            kir::lit(0.25 * dt)
                * kir::read(0, [0, 0, 0])
                * (kir::read(1, [0, 0, 0])
                    + kir::read(1, [1, 0, 0])
                    + kir::read(2, [0, 0, 0])
                    + kir::read(2, [1, 0, 0])),
        );
        ctx.par_loop_ir(
            "cl2d_flux_calc_y",
            self.block,
            [(0, self.nx as isize), (0, self.ny as isize + 1), (0, 1)],
            k.build(),
            vec![
                Arg::dat(self.yarea, self.s_pt, Access::Read),
                Arg::dat(self.yvel0, self.s_xp1, Access::Read),
                Arg::dat(self.yvel1, self.s_xp1, Access::Read),
                Arg::dat(self.vol_flux_y, self.s_pt, Access::Write),
            ],
            1.0,
        );
    }

    /// Cell-centred advection (density + energy), one direction:
    /// pre/post volumes → limited upwind fluxes → conservative update.
    pub fn advec_cell(&self, ctx: &mut impl Record, xdir: bool, first_sweep: bool) {
        let (vol_flux, mass_flux) = if xdir {
            (self.vol_flux_x, self.mass_flux_x)
        } else {
            (self.vol_flux_y, self.mass_flux_y)
        };

        // pass 1: pre/post volumes into work1/work2 (the sweep flags are
        // record-time constants, so the telescoping unrolls into the IR)
        {
            let mut k = kir::KirBuilder::new();
            let vol = k.let_(kir::read(0, [0, 0, 0]));
            let dfx = kir::read(1, [1, 0, 0]) - kir::read(1, [0, 0, 0]);
            let dfy = kir::read(2, [0, 1, 0]) - kir::read(2, [0, 0, 0]);
            let (pre, post) = if first_sweep {
                let pre = k.let_(vol + dfx.clone() + dfy.clone());
                let post = pre.clone() - if xdir { dfx } else { dfy };
                (pre, post)
            } else {
                let pre = vol.clone() + if xdir { dfx } else { dfy };
                (pre, vol)
            };
            k.store(3, pre);
            k.store(4, post);
            ctx.par_loop_ir(
                if xdir { "cl2d_advec_cell_x_pre" } else { "cl2d_advec_cell_y_pre" },
                self.block,
                self.cells_h(2),
                k.build(),
                vec![
                    Arg::dat(self.volume, self.s_pt, Access::Read),
                    Arg::dat(self.vol_flux_x, self.s_xp1, Access::Read),
                    Arg::dat(self.vol_flux_y, self.s_yp1, Access::Read),
                    Arg::dat(self.work1, self.s_pt, Access::Write),
                    Arg::dat(self.work2, self.s_pt, Access::Write),
                ],
                1.0,
            );
        }

        // pass 2: donor-cell + van Leer limited mass & energy fluxes
        {
            let range = if xdir {
                [(0, self.nx as isize + 1), (0, self.ny as isize), (0, 1)]
            } else {
                [(0, self.nx as isize), (0, self.ny as isize + 1), (0, 1)]
            };
            let adv_st = if xdir { self.s_adv_x } else { self.s_adv_y };
            let o = |kk: i32| if xdir { [kk, 0, 0] } else { [0, kk, 0] };
            // Both upwind orientations are built as subtrees and the sign
            // of the volume flux selects between them — the selected side
            // evaluates the exact arithmetic the branchy closure ran.
            let mut k = kir::KirBuilder::new();
            let vf = k.let_(kir::read(0, [0, 0, 0]));
            let orient = |k: &mut kir::KirBuilder, upwind: i32, donor: i32, downwind: i32| {
                let (ou, od, ow) = (o(upwind), o(donor), o(downwind));
                let pre_donor = k.let_(kir::read(1, od).max(G_SMALL));
                let sigmat = vf.clone().abs() / pre_donor.clone();
                let den_d = k.let_(kir::read(2, od));
                let lim_d = limited_ir(
                    den_d.clone() - kir::read(2, ou),
                    kir::read(2, ow) - den_d.clone(),
                    sigmat,
                );
                let mf = k.let_(vf.clone() * (den_d.clone() + lim_d));
                let sigmam = mf.clone().abs() / (den_d * pre_donor).max(G_SMALL);
                let en_d = k.let_(kir::read(3, od));
                let lim_e = limited_ir(
                    en_d.clone() - kir::read(3, ou),
                    kir::read(3, ow) - en_d.clone(),
                    sigmam,
                );
                (mf.clone(), mf * (en_d + lim_e))
            };
            let (mf_up, ef_up) = orient(&mut k, -2, -1, 0);
            let (mf_dn, ef_dn) = orient(&mut k, 1, 0, -1);
            let cond = vf.gt(0.0);
            k.store(4, cond.clone().select(mf_up, mf_dn));
            k.store(5, cond.select(ef_up, ef_dn));
            ctx.par_loop_ir(
                if xdir { "cl2d_advec_cell_x_flux" } else { "cl2d_advec_cell_y_flux" },
                self.block,
                range,
                k.build(),
                vec![
                    Arg::dat(vol_flux, self.s_pt, Access::Read),
                    Arg::dat(self.work1, adv_st, Access::Read),
                    Arg::dat(self.density1, adv_st, Access::Read),
                    Arg::dat(self.energy1, adv_st, Access::Read),
                    Arg::dat(mass_flux, self.s_pt, Access::Write),
                    Arg::dat(self.work7, self.s_pt, Access::Write),
                ],
                1.0,
            );
        }

        // pass 3: conservative update of density1/energy1
        {
            let st1 = if xdir { self.s_xp1 } else { self.s_yp1 };
            let o1 = if xdir { [1, 0, 0] } else { [0, 1, 0] };
            let mut k = kir::KirBuilder::new();
            let pre_vol = kir::read(0, [0, 0, 0]);
            let post_vol = kir::read(1, [0, 0, 0]);
            let den = kir::read(2, [0, 0, 0]);
            let en = kir::read(3, [0, 0, 0]);
            let pre_mass = k.let_(den * pre_vol);
            let post_mass = k.let_(pre_mass.clone() + kir::read(4, [0, 0, 0]) - kir::read(4, o1));
            let post_en = (en * pre_mass + kir::read(5, [0, 0, 0]) - kir::read(5, o1))
                / post_mass.clone().max(G_SMALL);
            k.store(2, post_mass / post_vol.max(G_SMALL));
            k.store(3, post_en);
            ctx.par_loop_ir(
                if xdir { "cl2d_advec_cell_x_upd" } else { "cl2d_advec_cell_y_upd" },
                self.block,
                self.cells(),
                k.build(),
                vec![
                    Arg::dat(self.work1, self.s_pt, Access::Read),
                    Arg::dat(self.work2, self.s_pt, Access::Read),
                    Arg::dat(self.density1, self.s_pt, Access::ReadWrite),
                    Arg::dat(self.energy1, self.s_pt, Access::ReadWrite),
                    Arg::dat(mass_flux, st1, Access::Read),
                    Arg::dat(self.work7, st1, Access::Read),
                ],
                1.0,
            );
        }
    }

    /// Momentum advection for one velocity component along one direction:
    /// node fluxes → node masses → limited momentum flux → update.
    pub fn advec_mom(&self, ctx: &mut impl Record, vel: DatasetId, xdir: bool) {
        let (mass_flux, st_adv, st_m1, st_nflux) = if xdir {
            (self.mass_flux_x, self.s_mom_x, self.s_xm1, self.s_nflux_x)
        } else {
            (self.mass_flux_y, self.s_mom_y, self.s_ym1, self.s_nflux_y)
        };
        let xd = xdir;
        let (nx, ny) = (self.nx as isize, self.ny as isize);
        let nodes_h = [(-1, nx + 2), (-1, ny + 2), (0, 1)];

        // node flux (work3) from face mass fluxes
        ctx.par_loop(
            if xdir { "cl2d_mom_node_flux_x" } else { "cl2d_mom_node_flux_y" },
            self.block,
            nodes_h,
            kernel(move |c| {
                let f = if xd {
                    0.25 * (c.r(0, 0, -1) + c.r(0, 0, 0) + c.r(0, 1, -1) + c.r(0, 1, 0))
                } else {
                    0.25 * (c.r(0, -1, 0) + c.r(0, 0, 0) + c.r(0, -1, 1) + c.r(0, 0, 1))
                };
                c.w(1, 0, 0, f);
            }),
            vec![
                Arg::dat(mass_flux, st_nflux, Access::Read),
                Arg::dat(self.work3, self.s_pt, Access::Write),
            ],
        );

        // node mass post (work4) / pre (work5) from density1 + node flux
        ctx.par_loop(
            if xdir { "cl2d_mom_node_mass_x" } else { "cl2d_mom_node_mass_y" },
            self.block,
            nodes_h,
            kernel(move |c| {
                let post = 0.25
                    * (c.r(0, -1, -1) + c.r(0, 0, -1) + c.r(0, 0, 0) + c.r(0, -1, 0));
                let pre = post
                    - if xd {
                        c.r(1, 0, 0) - c.r(1, -1, 0)
                    } else {
                        c.r(1, 0, 0) - c.r(1, 0, -1)
                    };
                c.w(2, 0, 0, post);
                c.w(3, 0, 0, pre);
            }),
            vec![
                Arg::dat(self.density1, self.s_cell_to_node, Access::Read),
                Arg::dat(self.work3, st_m1, Access::Read),
                Arg::dat(self.work4, self.s_pt, Access::Write),
                Arg::dat(self.work5, self.s_pt, Access::Write),
            ],
        );

        // limited momentum flux (work6)
        let flux_range = [(-1, nx + 1), (-1, ny + 1), (0, 1)];
        ctx.par_loop(
            if xdir { "cl2d_mom_flux_x" } else { "cl2d_mom_flux_y" },
            self.block,
            flux_range,
            kernel(move |c| {
                let o = |k: isize| if xd { (k, 0) } else { (0, k) };
                let nf = c.r(0, 0, 0);
                let (upwind, donor, downwind): (isize, isize, isize) = if nf < 0.0 {
                    (2, 1, 0)
                } else {
                    (-1, 0, 1)
                };
                let (ux, uy) = o(upwind);
                let (dx_, dy_) = o(donor);
                let (wx, wy) = o(downwind);
                let v_d = c.r(2, dx_, dy_);
                let v_u = c.r(2, ux, uy);
                let v_w = c.r(2, wx, wy);
                let sigma = nf.abs() / c.r(1, dx_, dy_).max(G_SMALL);
                let vdiffuw = v_d - v_u;
                let vdiffdw = v_w - v_d;
                let limiter = if vdiffuw * vdiffdw > 0.0 {
                    let auw = vdiffuw.abs();
                    let adw = vdiffdw.abs();
                    let wind = if vdiffdw <= 0.0 { -1.0 } else { 1.0 };
                    wind * (((2.0 - sigma) * adw + (1.0 + sigma) * auw) / 6.0)
                        .min(auw)
                        .min(adw)
                } else {
                    0.0
                };
                c.w(3, 0, 0, nf * (v_d + limiter * (1.0 - sigma)));
            }),
            vec![
                Arg::dat(self.work3, self.s_pt, Access::Read),
                Arg::dat(self.work5, st_adv, Access::Read),
                Arg::dat(vel, st_adv, Access::Read),
                Arg::dat(self.work6, self.s_pt, Access::Write),
            ],
        );

        // velocity update
        ctx.par_loop(
            if xdir { "cl2d_mom_vel_x" } else { "cl2d_mom_vel_y" },
            self.block,
            self.nodes(),
            kernel(move |c| {
                let o = |k: isize| if xd { (k, 0) } else { (0, k) };
                let (mx, my) = o(-1);
                let v = (c.r(0, 0, 0) * c.r(1, 0, 0) + c.r(2, mx, my) - c.r(2, 0, 0))
                    / c.r(3, 0, 0).max(G_SMALL);
                c.w(0, 0, 0, v);
            }),
            vec![
                Arg::dat(vel, self.s_pt, Access::ReadWrite),
                Arg::dat(self.work5, self.s_pt, Access::Read),
                Arg::dat(self.work6, st_m1, Access::Read),
                Arg::dat(self.work4, self.s_pt, Access::Read),
            ],
        );
    }

    /// Copy the advected state back to level 0.
    pub fn reset_field(&self, ctx: &mut impl Record) {
        let mut k = kir::KirBuilder::new();
        k.store(2, kir::read(0, [0, 0, 0]));
        k.store(3, kir::read(1, [0, 0, 0]));
        ctx.par_loop_ir(
            "cl2d_reset_field",
            self.block,
            self.cells(),
            k.build(),
            vec![
                Arg::dat(self.density1, self.s_pt, Access::Read),
                Arg::dat(self.energy1, self.s_pt, Access::Read),
                Arg::dat(self.density0, self.s_pt, Access::Write),
                Arg::dat(self.energy0, self.s_pt, Access::Write),
            ],
            1.0,
        );
        let mut k = kir::KirBuilder::new();
        k.store(2, kir::read(0, [0, 0, 0]));
        k.store(3, kir::read(1, [0, 0, 0]));
        ctx.par_loop_ir(
            "cl2d_reset_vel",
            self.block,
            self.nodes(),
            k.build(),
            vec![
                Arg::dat(self.xvel1, self.s_pt, Access::Read),
                Arg::dat(self.yvel1, self.s_pt, Access::Read),
                Arg::dat(self.xvel0, self.s_pt, Access::Write),
                Arg::dat(self.yvel0, self.s_pt, Access::Write),
            ],
            1.0,
        );
    }

    fn halo_cell(&self, ctx: &mut impl Record, name: &str, d: DatasetId) {
        kernels::halo_strips(
            ctx,
            self.block,
            name,
            d,
            self.s_halo_x,
            self.s_halo_y,
            self.nx as isize,
            self.ny as isize,
            false,
            false,
            false,
            false,
        );
    }

    fn halo_vel(&self, ctx: &mut impl Record, name: &str, d: DatasetId, flip_x: bool, flip_y: bool) {
        kernels::halo_strips(
            ctx,
            self.block,
            name,
            d,
            self.s_halo_x,
            self.s_halo_y,
            self.nx as isize + 1,
            self.ny as isize + 1,
            true,
            true,
            flip_x,
            flip_y,
        );
    }

    fn update_halo_hydro(&self, ctx: &mut impl Record) {
        self.halo_cell(ctx, "halo_density1", self.density1);
        self.halo_cell(ctx, "halo_energy1", self.energy1);
        self.halo_cell(ctx, "halo_pressure", self.pressure);
        self.halo_cell(ctx, "halo_viscosity", self.viscosity);
    }

    fn update_halo_vel(&self, ctx: &mut impl Record) {
        self.halo_vel(ctx, "halo_xvel1", self.xvel1, true, false);
        self.halo_vel(ctx, "halo_yvel1", self.yvel1, false, true);
    }

    // ------------------------------------------------------------ driver

    /// EOS + viscosity block that precedes the `calc_dt` trigger.
    fn pre_dt(&self, ctx: &mut impl Record) {
        self.ideal_gas(ctx, false);
        self.halo_cell(ctx, "halo_pressure", self.pressure);
        self.viscosity_kernel(ctx);
        self.halo_cell(ctx, "halo_viscosity", self.viscosity);
    }

    /// Lagrangian step + split advection for one parity. All kernels
    /// capture the *current* `self.dt` by value, so this block records
    /// cleanly into a frozen chain.
    fn post_dt(&self, ctx: &mut impl Record, xfirst: bool) {
        self.pdv(ctx, true);
        self.ideal_gas(ctx, true);
        self.update_halo_hydro(ctx);
        self.revert(ctx);
        self.accelerate(ctx);
        self.update_halo_vel(ctx);
        self.pdv(ctx, false);
        self.flux_calc(ctx);

        if xfirst {
            self.advec_cell(ctx, true, true);
            self.halo_cell(ctx, "halo_density1", self.density1);
            self.halo_cell(ctx, "halo_energy1", self.energy1);
            self.advec_mom(ctx, self.xvel1, true);
            self.advec_mom(ctx, self.yvel1, true);
            self.advec_cell(ctx, false, false);
            self.advec_mom(ctx, self.xvel1, false);
            self.advec_mom(ctx, self.yvel1, false);
        } else {
            self.advec_cell(ctx, false, true);
            self.halo_cell(ctx, "halo_density1", self.density1);
            self.halo_cell(ctx, "halo_energy1", self.energy1);
            self.advec_mom(ctx, self.xvel1, false);
            self.advec_mom(ctx, self.yvel1, false);
            self.advec_cell(ctx, true, false);
            self.advec_mom(ctx, self.xvel1, true);
            self.advec_mom(ctx, self.yvel1, true);
        }
        self.reset_field(ctx);
    }

    /// One full timestep (the paper's per-iteration chain). Returns dt.
    pub fn step(&mut self, ctx: &mut impl Drive) -> f64 {
        self.pre_dt(ctx);
        let dt = self.calc_dt(ctx); // <-- chain trigger (reduction)
        let xfirst = !self.step_parity;
        self.step_parity = !self.step_parity;
        self.post_dt(ctx, xfirst);
        dt
    }

    /// Record one **fixed-`dt` double step** (both advection parities,
    /// no `calc_dt`, no summary) once — the record-once API for frozen
    /// replay via [`crate::program::Session::replay`] /
    /// [`crate::program::Session::replay_fused`]. The adaptive timestep
    /// is a reduction trigger, so a frozen chain pins `dt = dtinit`
    /// (`dt` is captured by value at record time); recording both
    /// parities makes the chain self-similar under repetition, which is
    /// what temporal fusion needs.
    pub fn record_step_chain(
        &mut self,
        b: &mut crate::program::ProgramBuilder,
    ) -> crate::program::ChainId {
        self.dt = self.dtinit;
        b.record_chain("cl2d_step2", |r| {
            for xfirst in [true, false] {
                self.pre_dt(r);
                self.post_dt(r, xfirst);
            }
        })
    }

    /// Conserved-quantity summary (trigger point; every N steps in the
    /// paper's runs — the "one long loop chain with poor overlap").
    pub fn field_summary(&self, ctx: &mut impl Drive) -> FieldSummary {
        let mut k = kir::KirBuilder::new();
        let vol = k.let_(kir::read(0, [0, 0, 0]));
        let den = k.let_(kir::read(1, [0, 0, 0]));
        let en = kir::read(2, [0, 0, 0]);
        let press = kir::read(3, [0, 0, 0]);
        let sq = |o: [i32; 3]| {
            let x = kir::read(4, o);
            let y = kir::read(5, o);
            x.clone() * x + y.clone() * y
        };
        let vsqrd = kir::lit(0.25)
            * (sq([0, 0, 0]) + sq([1, 0, 0]) + sq([0, 1, 0]) + sq([1, 1, 0]));
        let mass = k.let_(den.clone() * vol.clone());
        k.reduce(0, RedOp::Sum, vol);
        k.reduce(1, RedOp::Sum, mass.clone());
        k.reduce(2, RedOp::Sum, mass.clone() * en);
        k.reduce(3, RedOp::Sum, kir::lit(0.5) * mass.clone() * vsqrd);
        k.reduce(4, RedOp::Sum, mass * press / den.max(G_SMALL));
        ctx.par_loop_ir(
            "cl2d_field_summary",
            self.block,
            self.cells(),
            k.build(),
            vec![
                Arg::dat(self.volume, self.s_pt, Access::Read),
                Arg::dat(self.density0, self.s_pt, Access::Read),
                Arg::dat(self.energy0, self.s_pt, Access::Read),
                Arg::dat(self.pressure, self.s_pt, Access::Read),
                Arg::dat(self.xvel0, self.s_node_to_cell, Access::Read),
                Arg::dat(self.yvel0, self.s_node_to_cell, Access::Read),
                Arg::GblRed { red: self.r_vol, op: RedOp::Sum },
                Arg::GblRed { red: self.r_mass, op: RedOp::Sum },
                Arg::GblRed { red: self.r_ie, op: RedOp::Sum },
                Arg::GblRed { red: self.r_ke, op: RedOp::Sum },
                Arg::GblRed { red: self.r_press, op: RedOp::Sum },
            ],
            1.0,
        );
        let volume = ctx.reduction_result(self.r_vol);
        let mass = ctx.reduction_result(self.r_mass);
        let internal_energy = ctx.reduction_result(self.r_ie);
        let kinetic_energy = ctx.reduction_result(self.r_ke);
        let pressure = ctx.reduction_result(self.r_press);
        FieldSummary {
            volume,
            mass,
            internal_energy,
            kinetic_energy,
            pressure,
        }
    }

    /// Standard benchmark driver: initialise (untimed), then `steps`
    /// timesteps with a field summary every `summary_every` steps.
    pub fn run(&mut self, ctx: &mut impl Drive, steps: usize, summary_every: usize) {
        self.initialise(ctx);
        ctx.flush();
        ctx.reset_metrics();
        ctx.set_cyclic_phase(true);
        for s in 0..steps {
            self.step(ctx);
            if summary_every > 0 && (s + 1) % summary_every == 0 {
                let _ = self.field_summary(ctx);
            }
        }
        ctx.flush();
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::coordinator::{Config, Platform};
    use crate::memory::{AppCalib, Link};
    use crate::ops::OpsContext;

    fn ctx(p: Platform) -> OpsContext {
        OpsContext::new(Config::new(p, AppCalib::CLOVERLEAF_2D).build_engine())
    }

    #[test]
    fn mass_is_conserved() {
        let mut c = ctx(Platform::KnlFlatDdr4);
        let mut app = CloverLeaf2D::new(&mut c, 24, 24, 1);
        app.initialise(&mut c);
        let s0 = app.field_summary(&mut c);
        for _ in 0..5 {
            app.step(&mut c);
        }
        let s1 = app.field_summary(&mut c);
        assert!(
            ((s1.mass - s0.mass) / s0.mass).abs() < 1e-10,
            "mass drift: {} -> {}",
            s0.mass,
            s1.mass
        );
        assert!((s1.volume - s0.volume).abs() < 1e-9 * s0.volume);
    }

    #[test]
    fn shock_develops_kinetic_energy() {
        let mut c = ctx(Platform::KnlFlatDdr4);
        let mut app = CloverLeaf2D::new(&mut c, 24, 24, 1);
        app.initialise(&mut c);
        let s0 = app.field_summary(&mut c);
        assert!(s0.kinetic_energy.abs() < 1e-12);
        for _ in 0..10 {
            app.step(&mut c);
        }
        let s1 = app.field_summary(&mut c);
        assert!(s1.kinetic_energy > 1e-8, "ke = {}", s1.kinetic_energy);
        let e0 = s0.internal_energy + s0.kinetic_energy;
        let e1 = s1.internal_energy + s1.kinetic_energy;
        assert!(((e1 - e0) / e0).abs() < 0.05, "energy drift {e0} -> {e1}");
    }

    #[test]
    fn dt_stays_positive_and_bounded() {
        let mut c = ctx(Platform::KnlFlatDdr4);
        let mut app = CloverLeaf2D::new(&mut c, 16, 16, 1);
        app.initialise(&mut c);
        for _ in 0..8 {
            let dt = app.step(&mut c);
            assert!(dt > 0.0 && dt <= app.dtinit + 1e-12, "dt = {dt}");
        }
    }

    #[test]
    fn fields_stay_finite_and_positive() {
        let mut c = ctx(Platform::KnlFlatDdr4);
        let mut app = CloverLeaf2D::new(&mut c, 20, 20, 1);
        app.initialise(&mut c);
        for _ in 0..10 {
            app.step(&mut c);
        }
        let den = c.fetch(app.density0);
        let en = c.fetch(app.energy0);
        assert!(den.iter().all(|v| v.is_finite()));
        assert!(en.iter().all(|v| v.is_finite()));
        let ds = c.dataset(app.density0).clone();
        for y in 0..app.ny as isize {
            for x in 0..app.nx as isize {
                let v = den[ds.offset([x, y, 0]) as usize];
                assert!(v > 0.0, "density must stay positive at ({x},{y}): {v}");
            }
        }
    }

    #[test]
    fn tiled_run_matches_untiled_bitexact() {
        let run = |p: Platform| {
            let mut c = ctx(p);
            let mut app = CloverLeaf2D::new(&mut c, 20, 20, 1);
            app.run(&mut c, 4, 2);
            (
                c.fetch(app.density0),
                c.fetch(app.energy0),
                c.fetch(app.xvel0),
            )
        };
        let a = run(Platform::KnlFlatDdr4);
        let b = run(Platform::KnlCacheTiled);
        let g = run(Platform::GpuExplicit {
            link: Link::NvLink,
            cyclic: true,
            prefetch: true,
        });
        let u = run(Platform::GpuUnified {
            link: Link::PciE,
            tiled: true,
            prefetch: true,
        });
        assert_eq!(a.0, b.0, "density0 tiled KNL");
        assert_eq!(a.1, b.1, "energy0 tiled KNL");
        assert_eq!(a.2, b.2, "xvel0 tiled KNL");
        assert_eq!(a.0, g.0, "density0 GPU explicit");
        assert_eq!(a.0, u.0, "density0 GPU unified");
    }

    #[test]
    fn chain_has_paper_scale_loop_count() {
        let mut c = ctx(Platform::KnlFlatDdr4);
        let mut app = CloverLeaf2D::new(&mut c, 16, 16, 1);
        app.initialise(&mut c);
        c.flush();
        // one full step, counting loops queued before each flush
        app.ideal_gas(&mut c, false);
        app.halo_cell(&mut c, "halo_pressure", app.pressure);
        app.viscosity_kernel(&mut c);
        app.halo_cell(&mut c, "halo_viscosity", app.viscosity);
        let mut n = c.queued_loops() + 1; // + calc_dt
        let _ = app.calc_dt(&mut c);
        app.pdv(&mut c, true);
        app.ideal_gas(&mut c, true);
        app.update_halo_hydro(&mut c);
        app.revert(&mut c);
        app.accelerate(&mut c);
        app.update_halo_vel(&mut c);
        app.pdv(&mut c, false);
        app.flux_calc(&mut c);
        app.advec_cell(&mut c, true, true);
        app.advec_mom(&mut c, app.xvel1, true);
        app.advec_mom(&mut c, app.yvel1, true);
        app.advec_cell(&mut c, false, false);
        app.advec_mom(&mut c, app.xvel1, false);
        app.advec_mom(&mut c, app.yvel1, false);
        app.reset_field(&mut c);
        n += c.queued_loops();
        assert!(n > 60, "chain too short: {n}");
        c.flush();
    }

    #[test]
    fn dataset_count_matches_paper() {
        let mut c = ctx(Platform::KnlFlatDdr4);
        let _app = CloverLeaf2D::new(&mut c, 8, 8, 1);
        assert_eq!(c.datasets().len(), 25, "paper: 25 variables/gridpoint");
    }
}
