//! Shared kernel helpers for CloverLeaf: reflective halo-strip loops.
//!
//! In the original, `update_halo` is an MPI exchange plus physical
//! boundary conditions; on our single modelled rank it reduces to the
//! boundary conditions — eight small strip loops (two per edge direction
//! per field) that mirror interior values into the depth-2 halo,
//! optionally flipping the sign of the normal velocity component. These
//! strips also exercise the tiling planner's handling of partial-range
//! loops (they land in the first/last tiles only).

use crate::ops::kernel::kernel;
use crate::ops::{Access, Arg, BlockId, Ctx, DatasetId, Record, StencilId};

/// Mirror offset for the low-side halo at logical index `i` (< 0):
/// cell-centred fields reflect about the face at −½ (`i' = −1−i`),
/// node-centred fields about node 0 (`i' = −i`).
#[inline]
fn mirror_lo(i: isize, node: bool) -> isize {
    if node {
        -2 * i // offset to i' = -i
    } else {
        -1 - 2 * i // offset to i' = -1-i
    }
}

/// Mirror offset for the high-side halo at logical index `i` (≥ size):
/// `size` is the dataset's interior extent.
#[inline]
fn mirror_hi(i: isize, size: isize, node: bool) -> isize {
    if node {
        2 * (size - 1) - 2 * i
    } else {
        2 * size - 2 * i - 1
    }
}

#[allow(clippy::too_many_arguments)]
/// Emit the four halo-strip loops for dataset `d` of interior size
/// `sx`×`sy`. `st_halo_x`/`st_halo_y` must cover mirror offsets ±4 along
/// their own direction only — keeping the strips out of the *other*
/// direction's skew computation.
pub fn halo_strips(
    ctx: &mut impl Record,
    block: BlockId,
    name: &str,
    d: DatasetId,
    st_halo_x: StencilId,
    st_halo_y: StencilId,
    sx: isize,
    sy: isize,
    node_x: bool,
    node_y: bool,
    flip_x: bool,
    flip_y: bool,
) {
    let sgn_y = if flip_y { -1.0 } else { 1.0 };
    let sgn_x = if flip_x { -1.0 } else { 1.0 };

    // bottom / top strips (write halo rows, read mirrored interior rows)
    ctx.par_loop(
        &format!("{name}_bot"),
        block,
        [(-2, sx + 2), (-2, 0), (0, 1)],
        kernel(move |c: &mut Ctx| {
            let [_, y, _] = c.idx();
            let v = c.r(0, 0, mirror_lo(y, node_y));
            c.w(0, 0, 0, sgn_y * v);
        }),
        vec![Arg::dat(d, st_halo_y, Access::ReadWrite)],
    );
    ctx.par_loop(
        &format!("{name}_top"),
        block,
        [(-2, sx + 2), (sy, sy + 2), (0, 1)],
        kernel(move |c: &mut Ctx| {
            let [_, y, _] = c.idx();
            let v = c.r(0, 0, mirror_hi(y, sy, node_y));
            c.w(0, 0, 0, sgn_y * v);
        }),
        vec![Arg::dat(d, st_halo_y, Access::ReadWrite)],
    );
    // left / right strips (full padded y so corners are refreshed too)
    ctx.par_loop(
        &format!("{name}_left"),
        block,
        [(-2, 0), (-2, sy + 2), (0, 1)],
        kernel(move |c: &mut Ctx| {
            let [x, _, _] = c.idx();
            let v = c.r(0, mirror_lo(x, node_x), 0);
            c.w(0, 0, 0, sgn_x * v);
        }),
        vec![Arg::dat(d, st_halo_x, Access::ReadWrite)],
    );
    ctx.par_loop(
        &format!("{name}_right"),
        block,
        [(sx, sx + 2), (-2, sy + 2), (0, 1)],
        kernel(move |c: &mut Ctx| {
            let [x, _, _] = c.idx();
            let v = c.r(0, mirror_hi(x, sx, node_x), 0);
            c.w(0, 0, 0, sgn_x * v);
        }),
        vec![Arg::dat(d, st_halo_x, Access::ReadWrite)],
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mirror_formulas() {
        // cells: -1 -> 0, -2 -> 1
        assert_eq!(-1 + mirror_lo(-1, false), 0);
        assert_eq!(-2 + mirror_lo(-2, false), 1);
        // nodes: -1 -> 1, -2 -> 2
        assert_eq!(-1 + mirror_lo(-1, true), 1);
        assert_eq!(-2 + mirror_lo(-2, true), 2);
        // cells hi (size 8): 8 -> 7, 9 -> 6
        assert_eq!(8 + mirror_hi(8, 8, false), 7);
        assert_eq!(9 + mirror_hi(9, 8, false), 6);
        // nodes hi (size 9, last interior 8): 9 -> 7, 10 -> 6
        assert_eq!(9 + mirror_hi(9, 9, true), 7);
        assert_eq!(10 + mirror_hi(10, 9, true), 6);
    }
}
