//! The sharded multi-device engine.
//!
//! [`ShardedEngine`] implements [`Engine`] over N *inner* engines (one
//! per modelled rank — each its own KNL, explicitly-streamed GPU or
//! unified-memory GPU) under a 1D/2D [`Decomposition`]:
//!
//! * **Numerics** run in lockstep loop order: for every loop of the
//!   chain, each rank executes its restricted slice through the shared
//!   executor. Because a parallel loop never reads what it writes
//!   (the no-aliasing contract) and the slices tile the iteration range
//!   exactly, the result is bit-for-bit identical to single-device
//!   untiled execution — verified in `tests/sharding_equivalence.rs`.
//!   (Sum reductions fold per-rank partials in rank order, the modelled
//!   `MPI_Allreduce`; min/max reductions are bitwise order-independent.)
//!
//! * **Time** is modelled per rank: each rank's restricted sub-chain is
//!   replayed through its inner engine with a no-op executor, so the
//!   inner engine's own discrete-event clock (tiling, 3-slot streaming,
//!   cache simulation…) prices the rank's compute. The chain's
//!   [`HaloExchange`] is costed over the configured [`Interconnect`] and
//!   — when overlap is enabled — hidden under the rank's *interior*
//!   compute, with only the boundary-strip fraction serialised after it.
//!   The chain's wall time is the slowest rank (bulk-synchronous steps).

use super::decomp::{decompose, DecompKind, Decomposition};
use super::halo::HaloExchange;
use super::interconnect::Interconnect;
use crate::codec::CodecSpec;
use crate::exec::timeline::{EventKind, StreamClass, Timeline, TraceEvent};
use crate::memory::calib_util::GB;
use crate::exec::{Engine, Executor, Metrics, NullExecutor, RankStat, World};
use crate::ops::{Dataset, LoopInst, Reduction};
use crate::tiling::analysis::{chain_structure_fingerprint, ChainAnalysis};
use std::collections::HashMap;
use std::sync::Arc;

/// Namespace an inner stream/event name under rank `r`, idempotently:
/// a name already carrying this rank's prefix (forwarded from an inner
/// layer that namespaced it, e.g. a future nested sharding or a scratch
/// ledger drained twice) is left alone. A literal `r0:r0:compute` row
/// would split one rank's attribution across two ledger keys and
/// desynchronise streams from the span tree (`obs::namespace` applies
/// the same innermost-prefix idempotence to span names).
fn rank_ns(r: usize, name: &str) -> String {
    let prefix = format!("r{r}:");
    if name.starts_with(&prefix) {
        name.to_string()
    } else {
        format!("{prefix}{name}")
    }
}

/// N modelled ranks, each owning an inner memory engine.
pub struct ShardedEngine {
    kind: DecompKind,
    link: Interconnect,
    /// Overlap halo exchange with interior compute (the fig12 ablation
    /// switch: `false` serialises exchange after compute).
    pub overlap: bool,
    /// Codec on the inter-rank link (inherited from the topology's
    /// slowest-boundary link by the config layer). Halo payloads are
    /// read-only snapshots of the neighbour's strip, so the codec's
    /// read-only ratio applies.
    codec: Option<CodecSpec>,
    inner: Vec<Box<dyn Engine>>,
    inner_label: String,
    /// Per-rank memo of restricted-sub-chain analyses, keyed by the
    /// structural fingerprint of (rank chain, rank dataset views) — the
    /// per-rank half of the record-once/replay-many amortisation: a
    /// timestepped app re-shards the same chain every step, and each
    /// rank's `O(L²)` dependency analysis runs once instead of per step.
    rank_analysis: Vec<HashMap<u64, Arc<ChainAnalysis>>>,
}

impl ShardedEngine {
    pub fn new(
        inner: Vec<Box<dyn Engine>>,
        kind: DecompKind,
        link: Interconnect,
        overlap: bool,
    ) -> Self {
        assert!(!inner.is_empty(), "sharded engine needs at least one rank");
        let inner_label = inner[0].describe();
        let rank_analysis = (0..inner.len()).map(|_| HashMap::new()).collect();
        ShardedEngine {
            kind,
            link,
            overlap,
            codec: None,
            inner,
            inner_label,
            rank_analysis,
        }
    }

    /// Attach (or clear) the inter-rank link codec. Identity codecs are
    /// stripped at schedule time, so `Some(ratio 1.0)` models exactly
    /// like `None`.
    pub fn with_codec(mut self, codec: Option<CodecSpec>) -> Self {
        self.codec = codec;
        self
    }

    /// The inter-rank link codec, if any.
    pub fn codec(&self) -> Option<CodecSpec> {
        self.codec
    }

    pub fn ranks(&self) -> usize {
        self.inner.len()
    }
}

impl Engine for ShardedEngine {
    fn run_chain(&mut self, chain: &[LoopInst], world: &mut World<'_>, cyclic_phase: bool) {
        self.run_chain_analyzed(chain, None, world, cyclic_phase);
    }

    // The whole-chain analysis is not directly applicable here — each
    // rank prices a *restricted* sub-chain over resized dataset views —
    // so the sharded layer keeps its own per-rank analysis memo instead
    // (see `rank_analysis`).
    fn run_chain_analyzed(
        &mut self,
        chain: &[LoopInst],
        _analysis: Option<&ChainAnalysis>,
        world: &mut World<'_>,
        cyclic_phase: bool,
    ) {
        if chain.is_empty() {
            return;
        }
        world.metrics.chains += 1;
        let sp = crate::obs::span("sharded");
        sp.field("loops", chain.len());
        sp.field("ranks", self.inner.len());
        let ranks = self.inner.len();
        let decomp: Decomposition = decompose(chain, ranks, self.kind);

        // ---- numerics: lockstep loop order, each rank its slice --------
        for l in chain {
            for r in 0..ranks {
                if let Some(slice) = decomp.restrict(r, &l.range) {
                    world
                        .exec
                        .run_loop(l, slice, world.datasets, world.store, world.reds);
                }
            }
        }

        // ---- time: per-rank sub-chain replay + halo exchange -----------
        // Every rank's schedule goes into one event graph: a compute
        // span (interior + boundary, from the inner engine's own
        // timeline-built clock) and an exchange event on the rank's
        // interconnect link. With overlap on, the exchange posts at the
        // chain start and only the boundary strip waits on it; with
        // overlap off it serialises after the rank's compute. The
        // chain's wall clock is the graph's makespan (bulk-synchronous
        // steps: the slowest rank).
        let plan = HaloExchange::plan(chain, world.datasets, world.stencils, &decomp);
        if world.metrics.per_rank.len() < ranks {
            world.metrics.per_rank.resize(ranks, RankStat::default());
        }
        let chain_t0 = world.metrics.elapsed_s;
        let tracing = world.metrics.trace_enabled();
        let mut tl = Timeline::new(false); // the solver; traces are forwarded below
        let mut wall_exchange = 0.0f64;
        let mut messages = 0u64;
        for r in 0..ranks {
            // Spans recorded by the rank's inner engine carry the same
            // `r{r}:` prefix as its re-namespaced streams and trace
            // events, so a sharded span tree attributes work per rank.
            let _ns = crate::obs::namespace(&format!("r{r}"));
            let rsp = crate::obs::span("rank");
            rsp.field("rank", r);
            let rank_chain: Vec<LoopInst> = chain
                .iter()
                .filter_map(|l| {
                    decomp.restrict(r, &l.range).map(|slice| {
                        let mut c = l.clone();
                        c.range = slice;
                        c
                    })
                })
                .collect();

            let mut scratch = Metrics::new();
            if tracing {
                scratch.enable_trace();
            }
            if !rank_chain.is_empty() {
                // Per-rank dataset views: along partitioned axes
                // perpendicular to the inner engine's tiled dimension, a
                // rank's slab cross-section is only its owned share of
                // the global extent. Without this a 2D decomposition
                // would charge every rank full-width planes for tile
                // transfers, double-counting bytes across ranks (the
                // halo planner already divides by the perpendicular
                // rank count).
                let tile_dim = crate::tiling::plan::pick_tile_dim(&rank_chain);
                let mut rank_datasets: Vec<Dataset> = world.datasets.to_vec();
                for axis in 0..decomp.axes() {
                    let dim = decomp.dims[axis];
                    if dim == tile_dim {
                        continue;
                    }
                    let global = decomp.extent[axis].len().max(1) as usize;
                    let owned = decomp.domains[r].owned[axis].len() as usize;
                    if owned == 0 || owned >= global {
                        continue;
                    }
                    for ds in &mut rank_datasets {
                        ds.size[dim] = (ds.size[dim] * owned / global).max(1);
                    }
                }
                // Per-rank cached analysis (one shared Program, N rank
                // "sessions"): identical re-sharded chains hit the memo.
                let fp =
                    chain_structure_fingerprint(&rank_chain, &rank_datasets, world.stencils);
                let rank_a = self.rank_analysis[r]
                    .entry(fp)
                    .or_insert_with(|| {
                        Arc::new(ChainAnalysis::build(
                            &rank_chain,
                            &rank_datasets,
                            world.stencils,
                        ))
                    })
                    .clone();
                let mut model = NullExecutor;
                let mut no_reds: Vec<Reduction> = vec![];
                let mut rank_world = World {
                    datasets: &rank_datasets,
                    stencils: world.stencils,
                    store: &mut *world.store,
                    reds: &mut no_reds,
                    metrics: &mut scratch,
                    exec: &mut model,
                };
                self.inner[r].run_chain_analyzed(
                    &rank_chain,
                    Some(&rank_a),
                    &mut rank_world,
                    cyclic_phase,
                );
            }
            let compute = scratch.elapsed_s;
            let rank_bytes = scratch.loop_bytes;
            let rank_loop_time = scratch.loop_time_s;

            let ex = plan.rank_cost(&decomp, r, self.link);
            // Link codec: halo payloads are read-only, so the read-only
            // ratio applies. `rank_cost` prices each message as
            // latency + bytes/bw, so the wire time recomputes exactly
            // from the message count and the compressed byte total.
            let codec = self.codec.filter(|c| !c.is_identity() && ex.messages > 0);
            let (ex_time, ex_wire) = match &codec {
                Some(c) => {
                    let wire = c.wire_bytes_for(ex.bytes, true);
                    let spec = self.link.spec();
                    (
                        ex.messages as f64 * spec.latency_s + wire as f64 / (spec.bw_gbs * GB),
                        wire,
                    )
                }
                None => (ex.time_s, ex.bytes),
            };
            let (c_time, d_time) = match &codec {
                Some(c) => (c.compress_time_s(ex.bytes), c.decompress_time_s(ex.bytes)),
                None => (0.0, 0.0),
            };
            // The rank's event sub-graph. Both compute spans ride one
            // `r{r}:compute` solver resource; the exchange gets the
            // rank's `r{r}:link`, codec kernels the rank's `r{r}:codec`.
            // (These solver events are *not* traced: the trace shows the
            // inner engine's real per-stream events, forwarded below,
            // plus the link/codec events.)
            let rc = tl.resource(&format!("r{r}:compute"), StreamClass::Compute);
            let rl = tl.resource(&format!("r{r}:link"), StreamClass::Exchange);
            let rk = codec
                .as_ref()
                .map(|_| tl.resource(&format!("r{r}:codec"), StreamClass::Codec));
            // Schedule the exchange path from `start`: with a codec,
            // compress → wire → decompress chained on dependency edges;
            // without, just the wire event. Returns (wire event start,
            // usable-data time).
            let schedule_exchange = |tl: &mut Timeline, start: f64| -> (f64, f64) {
                match (&codec, rk) {
                    (Some(_), Some(rko)) => {
                        let c_end =
                            tl.push_at(rko, EventKind::Compress, "", start, c_time, ex.bytes);
                        let x_end = tl.push_at(rl, EventKind::Exchange, "", c_end, ex_time, ex_wire);
                        let d_end =
                            tl.push_at(rko, EventKind::Decompress, "", x_end, d_time, ex.bytes);
                        tl.wait_until(rl, d_end);
                        (c_end, d_end)
                    }
                    _ => {
                        let x_end = tl.push_at(rl, EventKind::Exchange, "", start, ex_time, ex_wire);
                        (start, x_end)
                    }
                }
            };
            let (ex_start, ex_path) = if self.overlap {
                // Exchange posts at chain start; interior compute runs
                // under it; the boundary strip waits on usable halo data
                // (decompress end when a codec is attached).
                let boundary = compute * plan.boundary_fraction(&decomp, r);
                tl.push(rc, EventKind::Compute, "", compute - boundary, 0);
                let (ws, done) = schedule_exchange(&mut tl, 0.0);
                tl.wait_until(rc, done);
                tl.push(rc, EventKind::Compute, "", boundary, 0);
                (ws, done)
            } else {
                // Ablation: exchange strictly after the rank's compute.
                let c_end = tl.push(rc, EventKind::Compute, "", compute, 0);
                let (ws, done) = schedule_exchange(&mut tl, c_end);
                (ws, done - c_end)
            };
            wall_exchange = wall_exchange.max(ex_path);
            messages += ex.messages;

            // Attribution: the rank's inner streams, re-namespaced per
            // rank (concurrent ranks must not pool one "compute" row),
            // plus the link exchange.
            for (name, st) in scratch.take_per_resource() {
                world.metrics.record_stream(
                    &rank_ns(r, &name),
                    st.class,
                    st.busy_s,
                    st.bytes,
                    st.events,
                );
            }
            if ex.messages > 0 {
                world.metrics.record_stream(
                    &format!("r{r}:link"),
                    StreamClass::Exchange,
                    ex_time,
                    ex_wire,
                    ex.messages,
                );
                if codec.is_some() {
                    world.metrics.record_stream(
                        &rank_ns(r, "codec"),
                        StreamClass::Codec,
                        c_time + d_time,
                        ex.bytes,
                        2,
                    );
                    world.metrics.codec_bytes_saved += ex.bytes - ex_wire;
                }
            }
            if tracing {
                // Forward the inner engine's events onto the global
                // clock under the rank's namespace (ranks run
                // concurrently from the chain start), and add the link
                // exchange event.
                for mut ev in scratch.take_trace_events() {
                    ev.resource = rank_ns(r, &ev.resource);
                    ev.start_s += chain_t0;
                    ev.end_s += chain_t0;
                    world.metrics.push_trace_event(ev);
                }
                if ex.messages > 0 {
                    world.metrics.push_trace_event(TraceEvent {
                        resource: format!("r{r}:link"),
                        class: StreamClass::Exchange,
                        kind: EventKind::Exchange,
                        label: "halo exchange".into(),
                        start_s: chain_t0 + ex_start,
                        end_s: chain_t0 + ex_start + ex_time,
                        bytes: ex_wire,
                    });
                    if codec.is_some() {
                        world.metrics.push_trace_event(TraceEvent {
                            resource: format!("r{r}:codec"),
                            class: StreamClass::Codec,
                            kind: EventKind::Compress,
                            label: "halo compress".into(),
                            start_s: chain_t0 + ex_start - c_time,
                            end_s: chain_t0 + ex_start,
                            bytes: ex.bytes,
                        });
                        world.metrics.push_trace_event(TraceEvent {
                            resource: format!("r{r}:codec"),
                            class: StreamClass::Codec,
                            kind: EventKind::Decompress,
                            label: "halo decompress".into(),
                            start_s: chain_t0 + ex_start + ex_time,
                            end_s: chain_t0 + ex_start + ex_time + d_time,
                            bytes: ex.bytes,
                        });
                    }
                }
            }

            // Fold the rank's model metrics into the global sink without
            // double-counting wall time or chains. Per-rank intra-node
            // halo time is dropped too: summing it across concurrent
            // ranks would report serialised time (it is already inside
            // each rank's compute makespan); the global halo_time_s
            // carries only the sharded layer's wall-clock exchange.
            scratch.elapsed_s = 0.0;
            scratch.chains = 0;
            scratch.halo_time_s = 0.0;
            world.metrics.merge(&scratch);
            let rs = &mut world.metrics.per_rank[r];
            rs.compute_s += compute;
            rs.exchange_s += ex_path;
            rs.exchange_bytes += ex.bytes;
            rs.loop_bytes += rank_bytes;
            rs.loop_time_s += rank_loop_time;
        }
        // Wall clock = the event graph's makespan (slowest rank).
        world.metrics.elapsed_s += tl.makespan();
        world.metrics.halo_time_s += wall_exchange;
        world.metrics.halo_exchanges += messages;
    }

    /// Forward to every rank's inner engine.
    fn reset_transient(&mut self) {
        for e in &mut self.inner {
            e.reset_transient();
        }
    }

    fn describe(&self) -> String {
        format!(
            "Sharded x{} ({}, {}) | per-rank: {}{}",
            self.inner.len(),
            self.kind.label(),
            self.link.name(),
            self.inner_label,
            if self.overlap { "" } else { " [no-overlap]" },
        )
    }

    /// Each rank holds its share of the (block-decomposed) problem.
    fn fits(&self, problem_bytes: u64) -> bool {
        let share = problem_bytes / self.inner.len() as u64;
        self.inner.iter().all(|e| e.fits(share))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::NativeExecutor;
    use crate::memory::{AppCalib, GpuCalib, GpuExplicitEngine, GpuOpts, Link, PlainEngine};
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::{shapes, StencilId};
    use crate::ops::*;

    const APP: AppCalib = AppCalib::CLOVERLEAF_2D;

    fn fixture(ny: usize) -> (Vec<Dataset>, Vec<Stencil>, DataStore, Vec<LoopInst>) {
        let mut datasets = vec![];
        let mut store = DataStore::new();
        for (i, name) in ["state", "temp"].iter().enumerate() {
            let d = Dataset {
                id: DatasetId(i as u32),
                block: BlockId(0),
                name: name.to_string(),
                size: [32, ny, 1],
                halo_lo: [1, 1, 0],
                halo_hi: [1, 1, 0],
                elem_bytes: 8,
            };
            store.alloc(&d);
            datasets.push(d);
        }
        let stencils = vec![
            Stencil {
                id: StencilId(0),
                name: "pt".into(),
                points: shapes::point(),
            },
            Stencil {
                id: StencilId(1),
                name: "star".into(),
                points: shapes::star2d(1),
            },
        ];
        let range: Range3 = [(0, 32), (0, ny as isize), (0, 1)];
        let chain = vec![
            LoopInst {
                name: "seed".into(),
                block: BlockId(0),
                range: [(-1, 33), (-1, ny as isize + 1), (0, 1)],
                args: vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)],
                kernel: kernel(|c| {
                    let [x, y, _] = c.idx();
                    c.w(0, 0, 0, (x * 3 + y) as f64 * 0.5);
                }),
                kernel_ir: None,
                seq: 0,
                bw_efficiency: 1.0,
            },
            LoopInst {
                name: "smooth".into(),
                block: BlockId(0),
                range,
                args: vec![
                    Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                    Arg::dat(DatasetId(1), StencilId(0), Access::Write),
                ],
                kernel: kernel(|c| {
                    let v = c.r(0, -1, 0) + c.r(0, 1, 0) + c.r(0, 0, -1) + c.r(0, 0, 1);
                    c.w(1, 0, 0, 0.25 * v);
                }),
                kernel_ir: None,
                seq: 1,
                bw_efficiency: 1.0,
            },
            LoopInst {
                name: "fold".into(),
                block: BlockId(0),
                range,
                args: vec![
                    Arg::dat(DatasetId(1), StencilId(1), Access::Read),
                    Arg::dat(DatasetId(0), StencilId(0), Access::ReadWrite),
                ],
                kernel: kernel(|c| {
                    let v = c.r(0, 0, -1) + c.r(0, 0, 1);
                    let s = c.r(1, 0, 0);
                    c.w(1, 0, 0, s + 0.1 * v);
                }),
                kernel_ir: None,
                seq: 2,
                bw_efficiency: 1.0,
            },
        ];
        (datasets, stencils, store, chain)
    }

    fn gpu_rank() -> Box<dyn Engine> {
        Box::new(
            GpuExplicitEngine::new(
                GpuCalib {
                    hbm_bytes: 64 << 10,
                    ..GpuCalib::default()
                },
                APP,
                Link::PciE,
                GpuOpts::default(),
            )
            .unwrap(),
        )
    }

    fn run_sharded(
        ranks: usize,
        kind: DecompKind,
        overlap: bool,
        chains: usize,
    ) -> (Vec<Vec<f64>>, Metrics) {
        let (datasets, stencils, mut store, chain) = fixture(128);
        let mut reds = vec![];
        let mut metrics = Metrics::new();
        let mut exec = NativeExecutor::new();
        let inner = (0..ranks).map(|_| gpu_rank()).collect();
        let mut e = ShardedEngine::new(inner, kind, Interconnect::InfiniBand, overlap);
        for _ in 0..chains {
            let mut world = World {
                datasets: &datasets,
                stencils: &stencils,
                store: &mut store,
                reds: &mut reds,
                metrics: &mut metrics,
                exec: &mut exec,
            };
            e.run_chain(&chain, &mut world, true);
        }
        let bufs = datasets.iter().map(|d| store.buf(d.id).to_vec()).collect();
        (bufs, metrics)
    }

    fn run_reference(chains: usize) -> Vec<Vec<f64>> {
        let (datasets, _stencils, mut store, chain) = fixture(128);
        let mut reds: Vec<Reduction> = vec![];
        let mut exec = NativeExecutor::new();
        for _ in 0..chains {
            for l in &chain {
                exec.run_loop(l, l.range, &datasets, &mut store, &mut reds);
            }
        }
        datasets.iter().map(|d| store.buf(d.id).to_vec()).collect()
    }

    #[test]
    fn sharded_numerics_match_untiled_bitexact() {
        let want = run_reference(3);
        for kind in [DecompKind::OneD, DecompKind::TwoD] {
            for ranks in [1, 2, 4] {
                let (got, _) = run_sharded(ranks, kind, true, 3);
                assert_eq!(want, got, "x{ranks} {}", kind.label());
            }
        }
    }

    #[test]
    fn per_rank_stats_are_populated() {
        let (_, m) = run_sharded(4, DecompKind::OneD, true, 2);
        assert_eq!(m.per_rank.len(), 4);
        for (r, rs) in m.per_rank.iter().enumerate() {
            assert!(rs.compute_s > 0.0, "rank {r} compute");
            assert!(rs.loop_bytes > 0, "rank {r} bytes");
        }
        // interior ranks exchange on two faces, edges on one
        assert!(m.per_rank[1].exchange_bytes > m.per_rank[0].exchange_bytes);
        assert!(m.halo_exchanges > 0);
    }

    #[test]
    fn overlap_hides_exchange_time() {
        let (_, with) = run_sharded(4, DecompKind::OneD, true, 4);
        let (_, without) = run_sharded(4, DecompKind::OneD, false, 4);
        assert!(
            with.elapsed_s < without.elapsed_s,
            "overlap must shorten the makespan: {} !< {}",
            with.elapsed_s,
            without.elapsed_s
        );
    }

    #[test]
    fn strong_scaling_speedup() {
        let (_, m1) = run_sharded(1, DecompKind::OneD, true, 2);
        let (_, m4) = run_sharded(4, DecompKind::OneD, true, 2);
        assert!(
            m4.elapsed_s < m1.elapsed_s,
            "4 ranks must beat 1: {} !< {}",
            m4.elapsed_s,
            m1.elapsed_s
        );
    }

    #[test]
    fn two_d_planes_are_not_double_counted() {
        // Under a 2D grid each rank's tile transfers must be charged its
        // slab cross-section, not full-width planes: summed h2d stays
        // close to the single-rank total instead of doubling.
        let (_, m1) = run_sharded(1, DecompKind::OneD, true, 1);
        let (_, m2) = run_sharded(4, DecompKind::TwoD, true, 1);
        assert!(
            m2.h2d_bytes < m1.h2d_bytes * 3 / 2,
            "2D sharded h2d {} should not double-count vs x1 {}",
            m2.h2d_bytes,
            m1.h2d_bytes
        );
    }

    #[test]
    fn rank_streams_are_namespaced_and_traced() {
        let (datasets, stencils, mut store, chain) = fixture(128);
        let mut reds = vec![];
        let mut metrics = Metrics::new();
        metrics.enable_trace();
        let mut exec = NativeExecutor::new();
        let inner = (0..2).map(|_| gpu_rank()).collect();
        let mut e = ShardedEngine::new(inner, DecompKind::OneD, Interconnect::InfiniBand, true);
        {
            let mut world = World {
                datasets: &datasets,
                stencils: &stencils,
                store: &mut store,
                reds: &mut reds,
                metrics: &mut metrics,
                exec: &mut exec,
            };
            e.run_chain(&chain, &mut world, true);
        }
        // inner streams are re-namespaced per rank; links appear too
        for r in 0..2 {
            for s in ["compute", "upload", "link"] {
                let key = format!("r{r}:{s}");
                assert!(metrics.per_resource.contains_key(&key), "missing {key}");
            }
        }
        assert!(
            !metrics.per_resource.contains_key("compute"),
            "un-namespaced inner stream leaked into the global ledger"
        );
        // forwarded trace events carry the rank prefix and an exchange
        use crate::exec::timeline::EventKind;
        assert!(metrics
            .trace_events()
            .iter()
            .all(|ev| ev.resource.starts_with("r0:") || ev.resource.starts_with("r1:")));
        assert!(metrics
            .trace_events()
            .iter()
            .any(|ev| ev.kind == EventKind::Exchange));
        assert!(metrics
            .trace_events()
            .iter()
            .any(|ev| ev.kind == EventKind::Compute));
    }

    #[test]
    fn link_codec_compresses_halos_and_identity_is_bitexact() {
        use crate::codec::CodecSpec;
        let run = |codec: Option<CodecSpec>| {
            let (datasets, stencils, mut store, chain) = fixture(128);
            let mut reds = vec![];
            let mut metrics = Metrics::new();
            let mut exec = NativeExecutor::new();
            let inner = (0..2).map(|_| gpu_rank()).collect();
            let mut e =
                ShardedEngine::new(inner, DecompKind::OneD, Interconnect::InfiniBand, true)
                    .with_codec(codec);
            for _ in 0..2 {
                let mut world = World {
                    datasets: &datasets,
                    stencils: &stencils,
                    store: &mut store,
                    reds: &mut reds,
                    metrics: &mut metrics,
                    exec: &mut exec,
                };
                e.run_chain(&chain, &mut world, true);
            }
            let bufs: Vec<Vec<f64>> =
                datasets.iter().map(|d| store.buf(d.id).to_vec()).collect();
            (bufs, metrics)
        };
        let (dp, mp) = run(None);

        let (di, mi) = run(Some(CodecSpec::new(1.0)));
        assert_eq!(dp, di);
        assert_eq!(mp.elapsed_s, mi.elapsed_s, "identity codec is bit-identical");
        assert_eq!(mi.codec_bytes_saved, 0);
        assert!(!mi.per_resource.contains_key("r0:codec"));

        let (dz, mz) = run(Some(CodecSpec::ZFP));
        assert_eq!(dp, dz, "codec is a timeline model — numerics untouched");
        assert!(mz.codec_bytes_saved > 0);
        assert!(mz.per_resource.contains_key("r0:codec"));
        assert!(mz.per_resource.contains_key("r1:codec"));
        assert!(
            mz.per_resource["r0:link"].bytes < mp.per_resource["r0:link"].bytes,
            "the link ships wire bytes"
        );
        assert_eq!(
            mz.per_rank[0].exchange_bytes, mp.per_rank[0].exchange_bytes,
            "per-rank ledger keeps logical bytes"
        );

        // halos are read-only, so the read-only ratio override bites
        let ro = CodecSpec {
            ro_ratio: Some(7.0),
            ..CodecSpec::ZFP
        };
        let (_, mro) = run(Some(ro));
        assert!(
            mro.codec_bytes_saved > mz.codec_bytes_saved,
            "{} !> {}",
            mro.codec_bytes_saved,
            mz.codec_bytes_saved
        );
    }

    #[test]
    fn rank_prefix_is_idempotent() {
        assert_eq!(rank_ns(0, "compute"), "r0:compute");
        assert_eq!(rank_ns(0, "hbm:upload"), "r0:hbm:upload");
        // already-prefixed names are left alone (no r0:r0: rows)
        assert_eq!(rank_ns(0, "r0:compute"), "r0:compute");
        // another rank's prefix is NOT this rank's — it still wraps
        assert_eq!(rank_ns(1, "r0:compute"), "r1:r0:compute");
        // the match is exact: "r10:" does not alias "r1:"
        assert_eq!(rank_ns(1, "r10:compute"), "r1:r10:compute");
    }

    #[test]
    fn fits_divides_across_ranks() {
        let inner: Vec<Box<dyn Engine>> = (0..4)
            .map(|_| {
                Box::new(PlainEngine::knl_flat_mcdram(240.0, 1000)) as Box<dyn Engine>
            })
            .collect();
        let e = ShardedEngine::new(inner, DecompKind::OneD, Interconnect::InfiniBand, true);
        assert!(e.fits(4000));
        assert!(!e.fits(4100));
        assert!(e.describe().contains("Sharded x4"));
    }
}
