//! Sharded multi-device execution.
//!
//! This subsystem scales the out-of-core machinery across N modelled
//! ranks — the natural next axis after the paper's single-device
//! evaluation, following the companion OPS work on run-time tiling
//! across MPI ranks (arXiv 1704.00693):
//!
//! * [`decomp`] — 1D/2D [`Decomposition`] of a chain's iteration space
//!   with per-rank owned ranges derived exactly like tile boundaries;
//! * [`interconnect`] — [`Interconnect`] calibration (PCIe peer, NVLink,
//!   inter-node InfiniBand) in the style of [`crate::memory::Link`];
//! * [`halo`] — the [`HaloExchange`] planner: per-dataset exchange depth
//!   (stencil radius + chain skew) and byte counts from
//!   [`crate::tiling::footprint::Interval`] intersections;
//! * [`sharded`] — [`ShardedEngine`], an [`crate::exec::Engine`] that
//!   runs each rank's tiled sub-chain on its own inner engine, injects
//!   exchange events into the discrete-event clock and overlaps
//!   communication with interior-tile compute.
//!
//! Select it with `Platform::Sharded` / the `xN` platform-spec suffix
//! (`gpu-explicit:nvlink:cyclic:x4:ib`) or the CLI `--ranks` flag.

pub mod decomp;
pub mod halo;
pub mod interconnect;
pub mod sharded;

pub use decomp::{decompose, DecompKind, Decomposition, RankDomain};
pub use halo::{ExchangeRec, HaloExchange, RankExchange};
pub use interconnect::Interconnect;
pub use sharded::ShardedEngine;
