//! Inter-rank interconnect calibration.
//!
//! The same shape as [`crate::memory::Link`] (achieved bandwidth + per
//! -message latency), but for *rank-to-rank* transfers: PCIe peer-to-peer
//! between GPUs under one root complex, NVLink peer connections, and
//! inter-node InfiniBand. Numbers are the commonly measured achieved
//! figures for the paper's hardware generation (P100 era): PCIe gen3 P2P
//! ≈ 10 GB/s, NVLink 1.0 peer ≈ 35 GB/s, EDR InfiniBand ≈ 12 GB/s with
//! the lowest latency of the three.

use crate::memory::hierarchy::GB;

/// Rank-to-rank interconnect between modelled devices/nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Interconnect {
    /// PCIe gen3 peer-to-peer (GPUs under one switch).
    PciePeer,
    /// NVLink 1.0 peer connection.
    NvLink,
    /// Inter-node EDR InfiniBand.
    InfiniBand,
}

impl Interconnect {
    /// Achieved bandwidth per direction, GB/s.
    pub fn bw_gbs(self) -> f64 {
        match self {
            Interconnect::PciePeer => 10.0,
            Interconnect::NvLink => 35.0,
            Interconnect::InfiniBand => 12.0,
        }
    }

    /// Per-message latency, seconds.
    pub fn latency_s(self) -> f64 {
        match self {
            Interconnect::PciePeer => 10e-6,
            Interconnect::NvLink => 8e-6,
            Interconnect::InfiniBand => 2e-6,
        }
    }

    /// Time to move `bytes` in one message.
    pub fn time_s(self, bytes: u64) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            self.latency_s() + bytes as f64 / (self.bw_gbs() * GB)
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Interconnect::PciePeer => "PCIe-peer",
            Interconnect::NvLink => "NVLink",
            Interconnect::InfiniBand => "IB",
        }
    }

    /// Parse a spec token (`peer` | `nvlink` | `ib`).
    pub fn parse(tok: &str) -> Option<Self> {
        match tok {
            "peer" | "pcie-peer" => Some(Interconnect::PciePeer),
            "nvlink" => Some(Interconnect::NvLink),
            "ib" | "infiniband" => Some(Interconnect::InfiniBand),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_includes_latency() {
        let t = Interconnect::InfiniBand.time_s(12_000_000_000);
        assert!((t - (1.0 + 2e-6)).abs() < 1e-9);
        assert_eq!(Interconnect::PciePeer.time_s(0), 0.0);
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Interconnect::parse("peer"), Some(Interconnect::PciePeer));
        assert_eq!(Interconnect::parse("nvlink"), Some(Interconnect::NvLink));
        assert_eq!(Interconnect::parse("ib"), Some(Interconnect::InfiniBand));
        assert_eq!(Interconnect::parse("nvlnk"), None);
    }

    #[test]
    fn nvlink_fastest_ib_lowest_latency() {
        assert!(Interconnect::NvLink.bw_gbs() > Interconnect::PciePeer.bw_gbs());
        assert!(Interconnect::InfiniBand.latency_s() < Interconnect::PciePeer.latency_s());
    }
}
