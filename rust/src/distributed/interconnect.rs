//! Inter-rank interconnect calibration.
//!
//! A thin shim over [`crate::topology::LinkSpec`] — the unified
//! bandwidth/latency edge description that also models the host↔device
//! [`crate::memory::Link`] and every tier boundary of a
//! [`crate::topology::Topology`]. The three calibrated rank-to-rank
//! links are [`LinkSpec::PCIE_PEER`], [`LinkSpec::NVLINK_PEER`] and
//! [`LinkSpec::INFINIBAND`] (commonly measured achieved figures for the
//! paper's hardware generation: PCIe gen3 P2P ≈ 10 GB/s, NVLink 1.0
//! peer ≈ 35 GB/s, EDR InfiniBand ≈ 12 GB/s with the lowest latency of
//! the three); this enum survives as the compact spec-token form
//! (`peer` / `nvlink` / `ib`).
//!
//! [`LinkSpec::PCIE_PEER`]: crate::topology::LinkSpec::PCIE_PEER
//! [`LinkSpec::NVLINK_PEER`]: crate::topology::LinkSpec::NVLINK_PEER
//! [`LinkSpec::INFINIBAND`]: crate::topology::LinkSpec::INFINIBAND

use crate::topology::LinkSpec;

/// Rank-to-rank interconnect between modelled devices/nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Interconnect {
    /// PCIe gen3 peer-to-peer (GPUs under one switch).
    PciePeer,
    /// NVLink 1.0 peer connection.
    NvLink,
    /// Inter-node EDR InfiniBand.
    InfiniBand,
}

impl Interconnect {
    /// The unified link description this variant stands for.
    pub fn spec(self) -> LinkSpec {
        match self {
            Interconnect::PciePeer => LinkSpec::PCIE_PEER,
            Interconnect::NvLink => LinkSpec::NVLINK_PEER,
            Interconnect::InfiniBand => LinkSpec::INFINIBAND,
        }
    }

    /// Achieved bandwidth per direction, GB/s.
    #[deprecated(
        since = "0.4.0",
        note = "use Interconnect::spec().bw_gbs (topology::LinkSpec)"
    )]
    pub fn bw_gbs(self) -> f64 {
        self.spec().bw_gbs
    }

    /// Per-message latency, seconds.
    #[deprecated(
        since = "0.4.0",
        note = "use Interconnect::spec().latency_s (topology::LinkSpec)"
    )]
    pub fn latency_s(self) -> f64 {
        self.spec().latency_s
    }

    /// Time to move `bytes` in one message.
    #[deprecated(
        since = "0.4.0",
        note = "use Interconnect::spec().time_s (topology::LinkSpec)"
    )]
    pub fn time_s(self, bytes: u64) -> f64 {
        self.spec().time_s(bytes)
    }

    pub fn name(self) -> &'static str {
        match self {
            Interconnect::PciePeer => "PCIe-peer",
            Interconnect::NvLink => "NVLink",
            Interconnect::InfiniBand => "IB",
        }
    }

    /// Parse a spec token (`peer` | `nvlink` | `ib`).
    pub fn parse(tok: &str) -> Option<Self> {
        match tok {
            "peer" | "pcie-peer" => Some(Interconnect::PciePeer),
            "nvlink" => Some(Interconnect::NvLink),
            "ib" | "infiniband" => Some(Interconnect::InfiniBand),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_includes_latency() {
        let t = Interconnect::InfiniBand.spec().time_s(12_000_000_000);
        assert!((t - (1.0 + 2e-6)).abs() < 1e-9);
        assert_eq!(Interconnect::PciePeer.spec().time_s(0), 0.0);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_delegate_to_linkspec() {
        for ic in [
            Interconnect::PciePeer,
            Interconnect::NvLink,
            Interconnect::InfiniBand,
        ] {
            assert_eq!(ic.bw_gbs(), ic.spec().bw_gbs);
            assert_eq!(ic.latency_s(), ic.spec().latency_s);
            assert_eq!(ic.time_s(1 << 22), ic.spec().time_s(1 << 22));
        }
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Interconnect::parse("peer"), Some(Interconnect::PciePeer));
        assert_eq!(Interconnect::parse("nvlink"), Some(Interconnect::NvLink));
        assert_eq!(Interconnect::parse("ib"), Some(Interconnect::InfiniBand));
        assert_eq!(Interconnect::parse("nvlnk"), None);
    }

    #[test]
    fn nvlink_fastest_ib_lowest_latency() {
        assert!(Interconnect::NvLink.spec().bw_gbs > Interconnect::PciePeer.spec().bw_gbs);
        assert!(
            Interconnect::InfiniBand.spec().latency_s < Interconnect::PciePeer.spec().latency_s
        );
    }
}
