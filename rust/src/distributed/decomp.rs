//! Domain decomposition: partitioning a chain's iteration space across
//! modelled ranks.
//!
//! The decomposition is derived from the *chain*, not the block: the
//! global extent along each partitioned dimension is the union of the
//! chain's loop ranges (so boundary strip loops that reach into halos are
//! covered), and per-rank boundaries are computed exactly like
//! [`crate::tiling::plan::plan_chain`] computes tile boundaries — the
//! first/last rank absorb anything outside the interior boundaries. A
//! loop restricted to every rank in turn therefore tiles its iteration
//! range exactly: no point is dropped, none is computed twice.

use crate::ops::{LoopInst, Range3};
use crate::tiling::footprint::Interval;
use crate::tiling::plan::pick_tile_dim;

/// Decomposition shape: slabs along one dimension, or a 2D rank grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecompKind {
    /// Slabs along the outermost iterated dimension (y for 2D problems,
    /// z for 3D) — the classic stencil-code decomposition.
    OneD,
    /// A 2D rank grid over the two slowest-varying iterated dimensions
    /// (x×y for 2D problems, y×z for 3D).
    TwoD,
}

impl DecompKind {
    pub fn label(self) -> &'static str {
        match self {
            DecompKind::OneD => "1D",
            DecompKind::TwoD => "2D",
        }
    }
}

/// One rank's share of the domain.
#[derive(Debug, Clone)]
pub struct RankDomain {
    pub rank: usize,
    /// Coordinate in the rank grid (`coord[1] == 0` for 1D).
    pub coord: [usize; 2],
    /// Owned interval per partitioned axis, on the chain's global extent.
    pub owned: [Interval; 2],
}

/// A 1D/2D partition of a chain's iteration space across `ranks` ranks.
#[derive(Debug, Clone)]
pub struct Decomposition {
    pub kind: DecompKind,
    /// The partitioned dimensions (`dims[1]` is meaningful only for 2D).
    pub dims: [usize; 2],
    /// Rank-grid shape along `dims` (`grid[1] == 1` for 1D).
    pub grid: [usize; 2],
    /// Global chain extent along each partitioned axis.
    pub extent: [Interval; 2],
    pub domains: Vec<RankDomain>,
}

/// Global `[min lo, max hi)` of the chain along dimension `dim`.
fn chain_extent(chain: &[LoopInst], dim: usize) -> Interval {
    let lo = chain.iter().map(|l| l.range[dim].0).min().unwrap_or(0);
    let hi = chain.iter().map(|l| l.range[dim].1).max().unwrap_or(1);
    Interval::new(lo, hi.max(lo + 1))
}

/// Near-square factorisation `a * b == ranks` with `a <= b`.
fn factor2(ranks: usize) -> (usize, usize) {
    let mut a = (ranks as f64).sqrt() as usize;
    while a > 1 && ranks % a != 0 {
        a -= 1;
    }
    (a.max(1), ranks / a.max(1))
}

/// Build the decomposition of `chain` over `ranks` ranks.
pub fn decompose(chain: &[LoopInst], ranks: usize, kind: DecompKind) -> Decomposition {
    let ranks = ranks.max(1);
    let tile_dim = pick_tile_dim(chain);
    let dims = match kind {
        DecompKind::OneD => [tile_dim, 0],
        // 2D problems: split x and y; 3D: split y and z.
        DecompKind::TwoD => {
            if tile_dim == 2 {
                [1, 2]
            } else {
                [0, 1]
            }
        }
    };
    let extent = [chain_extent(chain, dims[0]), chain_extent(chain, dims[1])];
    let grid = match kind {
        DecompKind::OneD => [ranks, 1],
        DecompKind::TwoD => {
            let (a, b) = factor2(ranks);
            // Larger factor on the larger extent.
            if extent[0].len() >= extent[1].len() {
                [b, a]
            } else {
                [a, b]
            }
        }
    };

    let boundary = |axis: usize, i: usize| -> isize {
        let e = extent[axis];
        e.lo + e.len() * i as isize / grid[axis] as isize
    };

    let mut domains = Vec::with_capacity(ranks);
    for r in 0..ranks {
        let coord = [r % grid[0], r / grid[0]];
        let owned = [
            Interval::new(boundary(0, coord[0]), boundary(0, coord[0] + 1)),
            Interval::new(boundary(1, coord[1]), boundary(1, coord[1] + 1)),
        ];
        domains.push(RankDomain {
            rank: r,
            coord,
            owned,
        });
    }

    Decomposition {
        kind,
        dims,
        grid,
        extent,
        domains,
    }
}

impl Decomposition {
    pub fn ranks(&self) -> usize {
        self.domains.len()
    }

    /// Number of partitioned axes (1 or 2).
    pub fn axes(&self) -> usize {
        match self.kind {
            DecompKind::OneD => 1,
            DecompKind::TwoD => 2,
        }
    }

    /// Ranks perpendicular to `axis` — the divisor that turns a global
    /// cross-section into one rank's slab cross-section.
    pub fn perpendicular(&self, axis: usize) -> usize {
        match self.kind {
            DecompKind::OneD => 1,
            DecompKind::TwoD => self.grid[1 - axis].max(1),
        }
    }

    /// Restrict a loop range to rank `r`'s domain (`None` when the rank
    /// contributes no points). First/last ranks along each axis absorb
    /// the loop's own overhang past the interior boundaries, exactly as
    /// tile 0 / tile T-1 do in the tiling plan.
    pub fn restrict(&self, r: usize, range: &Range3) -> Option<Range3> {
        let d = &self.domains[r];
        let mut out = *range;
        for axis in 0..self.axes() {
            let dim = self.dims[axis];
            let (llo, lhi) = range[dim];
            let start = if d.coord[axis] == 0 {
                llo
            } else {
                d.owned[axis].lo.clamp(llo, lhi)
            };
            let end = if d.coord[axis] + 1 == self.grid[axis] {
                lhi
            } else {
                d.owned[axis].hi.clamp(llo, lhi)
            };
            if start >= end {
                return None;
            }
            out[dim] = (start, end);
        }
        Some(out)
    }

    /// Does rank `r` have a neighbour below / above along `axis`?
    pub fn neighbours(&self, r: usize, axis: usize) -> (bool, bool) {
        let c = self.domains[r].coord[axis];
        (c > 0, c + 1 < self.grid[axis])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::kernel::kernel;
    use crate::ops::parloop::range_points;
    use crate::ops::BlockId;

    fn lp(range: Range3) -> LoopInst {
        LoopInst {
            name: "l".into(),
            block: BlockId(0),
            range,
            args: vec![],
            kernel: kernel(|_| {}),
            kernel_ir: None,
            seq: 0,
            bw_efficiency: 1.0,
        }
    }

    fn coverage(chain: &[LoopInst], d: &Decomposition) {
        for l in chain {
            let total: u64 = (0..d.ranks())
                .filter_map(|r| d.restrict(r, &l.range))
                .map(|rr| range_points(&rr))
                .sum();
            assert_eq!(total, range_points(&l.range), "points covered exactly");
            // disjointness along the partitioned dims: slices must abut
            for axis in 0..d.axes() {
                let dim = d.dims[axis];
                let mut ivs: Vec<(isize, isize)> = (0..d.ranks())
                    .filter_map(|r| d.restrict(r, &l.range))
                    .map(|rr| rr[dim])
                    .collect();
                ivs.sort();
                ivs.dedup();
                let mut cursor = l.range[dim].0;
                for (lo, hi) in ivs {
                    assert!(lo >= cursor, "overlap along dim {dim}");
                    cursor = cursor.max(hi);
                }
                assert_eq!(cursor, l.range[dim].1);
            }
        }
    }

    #[test]
    fn one_d_partitions_exactly() {
        let chain = vec![lp([(0, 16), (-2, 66), (0, 1)]), lp([(0, 16), (0, 64), (0, 1)])];
        let d = decompose(&chain, 4, DecompKind::OneD);
        assert_eq!(d.dims[0], 1);
        assert_eq!(d.grid, [4, 1]);
        coverage(&chain, &d);
    }

    #[test]
    fn two_d_partitions_exactly() {
        let chain = vec![lp([(-2, 18), (-2, 66), (0, 1)]), lp([(0, 16), (0, 64), (0, 1)])];
        let d = decompose(&chain, 4, DecompKind::TwoD);
        assert_eq!(d.dims, [0, 1]);
        assert_eq!(d.grid[0] * d.grid[1], 4);
        coverage(&chain, &d);
    }

    #[test]
    fn three_d_chains_partition_outer_dims() {
        let chain = vec![lp([(0, 8), (0, 8), (0, 32)])];
        let d1 = decompose(&chain, 2, DecompKind::OneD);
        assert_eq!(d1.dims[0], 2, "1D splits z for 3D problems");
        let d2 = decompose(&chain, 4, DecompKind::TwoD);
        assert_eq!(d2.dims, [1, 2]);
        coverage(&chain, &d1);
        coverage(&chain, &d2);
    }

    #[test]
    fn degenerate_extent_gives_empty_ranks() {
        // extent 1 along y: only one rank can own the single plane.
        let chain = vec![lp([(0, 64), (0, 1), (0, 1)])];
        let d = decompose(&chain, 4, DecompKind::OneD);
        coverage(&chain, &d);
        let non_empty = (0..4).filter(|&r| d.restrict(r, &chain[0].range).is_some());
        assert_eq!(non_empty.count(), 1);
    }

    #[test]
    fn single_rank_owns_everything() {
        let chain = vec![lp([(-1, 17), (-1, 65), (0, 1)])];
        let d = decompose(&chain, 1, DecompKind::TwoD);
        assert_eq!(d.restrict(0, &chain[0].range), Some(chain[0].range));
    }

    #[test]
    fn factorisation_is_near_square() {
        assert_eq!(factor2(8), (2, 4));
        assert_eq!(factor2(4), (2, 2));
        assert_eq!(factor2(7), (1, 7));
        assert_eq!(factor2(1), (1, 1));
    }
}
