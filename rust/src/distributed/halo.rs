//! Inter-rank halo-exchange planning.
//!
//! The planner reuses the tiler's machinery: per-dataset read radii come
//! from the chain's stencils, the skew shifts from
//! [`crate::tiling::dependency::compute_shifts`], and the exchanged
//! regions are [`Interval`] intersections between a rank's read
//! footprint (owned slab grown by the exchange depth) and its
//! neighbours' owned slabs — the same construction the tile planner uses
//! for left/right edges, lifted to rank granularity.
//!
//! One exchange per chain suffices when its depth covers radius + skew
//! (the companion OPS-MPI-tiling scheme, arXiv 1704.00693): every loop of
//! the chain can then run rank-locally, with boundary tiles redundantly
//! deep.

use super::decomp::Decomposition;
use super::interconnect::Interconnect;
use crate::ops::{Dataset, DatasetId, LoopInst, Stencil};
use crate::tiling::dependency::compute_shifts;
use crate::tiling::footprint::Interval;

/// One dataset's exchange requirement along one partitioned axis.
#[derive(Debug, Clone)]
pub struct ExchangeRec {
    pub dat: DatasetId,
    /// Index into `decomp.dims`.
    pub axis: usize,
    /// Exchange depth in planes (read radius + chain skew).
    pub depth: u64,
    /// Bytes of one rank-local plane (global representative cross-section
    /// divided by the ranks perpendicular to this axis).
    pub plane_bytes: u64,
}

/// The per-chain halo-exchange plan.
#[derive(Debug, Clone, Default)]
pub struct HaloExchange {
    pub recs: Vec<ExchangeRec>,
    /// Largest skew shift folded into the depths (diagnostics).
    pub max_shift: isize,
}

/// Cost of one rank's exchanges for a chain.
#[derive(Debug, Clone, Copy, Default)]
pub struct RankExchange {
    pub time_s: f64,
    pub bytes: u64,
    pub messages: u64,
}

impl HaloExchange {
    /// Plan the chain's exchanges under `decomp`.
    pub fn plan(
        chain: &[LoopInst],
        datasets: &[Dataset],
        stencils: &[Stencil],
        decomp: &Decomposition,
    ) -> Self {
        let mut recs = Vec::new();
        let mut max_shift_all = 0isize;
        for axis in 0..decomp.axes() {
            let dim = decomp.dims[axis];
            let shifts = compute_shifts(chain, stencils, dim);
            let max_shift = shifts.iter().copied().max().unwrap_or(0);
            max_shift_all = max_shift_all.max(max_shift);
            // Widest read radius per dataset along this dim.
            let mut radius = vec![0i32; datasets.len()];
            for l in chain {
                for (d, s, acc) in l.dat_args() {
                    if acc.reads() {
                        let r = stencils[s.0 as usize].radius(dim);
                        let e = &mut radius[d.0 as usize];
                        *e = (*e).max(r);
                    }
                }
            }
            let perp = decomp.perpendicular(axis) as u64;
            for (di, &r) in radius.iter().enumerate() {
                if r == 0 {
                    continue;
                }
                let ds = &datasets[di];
                let depth = (r as isize + max_shift).max(1) as u64;
                recs.push(ExchangeRec {
                    dat: ds.id,
                    axis,
                    depth,
                    plane_bytes: (ds.repr_plane_bytes() / perp).max(1),
                });
            }
        }
        HaloExchange {
            recs,
            max_shift: max_shift_all,
        }
    }

    /// The interval of planes rank `r` receives from its lower / upper
    /// neighbour along `axis` for an exchange of depth `depth`: the
    /// rank's grown read footprint intersected with the neighbour side of
    /// the global extent.
    fn faces(&self, decomp: &Decomposition, r: usize, axis: usize, depth: u64) -> (Interval, Interval) {
        let owned = decomp.domains[r].owned[axis];
        let global = decomp.extent[axis];
        let read_fp = Interval::new(owned.lo - depth as isize, owned.hi + depth as isize);
        let (lo_n, hi_n) = decomp.neighbours(r, axis);
        let lo_face = if lo_n {
            read_fp.intersect(&Interval::new(global.lo, owned.lo))
        } else {
            Interval::empty()
        };
        let hi_face = if hi_n {
            read_fp.intersect(&Interval::new(owned.hi, global.hi))
        } else {
            Interval::empty()
        };
        (lo_face, hi_face)
    }

    /// Exchange cost for rank `r`: one message per (dataset, face) at the
    /// interconnect's latency + bandwidth.
    pub fn rank_cost(&self, decomp: &Decomposition, r: usize, link: Interconnect) -> RankExchange {
        let mut out = RankExchange::default();
        for rec in &self.recs {
            let (lo, hi) = self.faces(decomp, r, rec.axis, rec.depth);
            for face in [lo, hi] {
                if face.is_empty() {
                    continue;
                }
                let bytes = face.len() as u64 * rec.plane_bytes;
                out.time_s += link.spec().time_s(bytes);
                out.bytes += bytes;
                out.messages += 1;
            }
        }
        out
    }

    /// Fraction of rank `r`'s compute that touches halo-adjacent strips —
    /// the part that cannot overlap with the exchange. Per axis:
    /// exchanged planes over owned extent, summed and capped.
    pub fn boundary_fraction(&self, decomp: &Decomposition, r: usize) -> f64 {
        let mut frac = 0.0;
        for axis in 0..decomp.axes() {
            let owned = decomp.domains[r].owned[axis].len().max(1) as f64;
            let depth = self
                .recs
                .iter()
                .filter(|rec| rec.axis == axis)
                .map(|rec| rec.depth)
                .max()
                .unwrap_or(0);
            let (lo, hi) = {
                let (l, h) = self.faces(decomp, r, axis, depth);
                (l.len() as f64, h.len() as f64)
            };
            frac += (lo + hi) / owned;
        }
        frac.min(0.95)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::decomp::{decompose, DecompKind};
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::{shapes, StencilId};
    use crate::ops::{Access, Arg, BlockId};

    fn fixture() -> (Vec<Dataset>, Vec<Stencil>, Vec<LoopInst>) {
        let mk_ds = |i: u32, name: &str| Dataset {
            id: DatasetId(i),
            block: BlockId(0),
            name: name.into(),
            size: [64, 256, 1],
            halo_lo: [2, 2, 0],
            halo_hi: [2, 2, 0],
            elem_bytes: 8,
        };
        let datasets = vec![mk_ds(0, "state"), mk_ds(1, "temp")];
        let stencils = vec![
            Stencil {
                id: StencilId(0),
                name: "pt".into(),
                points: shapes::point(),
            },
            Stencil {
                id: StencilId(1),
                name: "star".into(),
                points: shapes::star2d(1),
            },
        ];
        let range = [(0, 64), (0, 256), (0, 1)];
        let chain = vec![
            LoopInst {
                name: "mk".into(),
                block: BlockId(0),
                range,
                args: vec![
                    Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                    Arg::dat(DatasetId(1), StencilId(0), Access::Write),
                ],
                kernel: kernel(|_| {}),
                kernel_ir: None,
                seq: 0,
                bw_efficiency: 1.0,
            },
            LoopInst {
                name: "use".into(),
                block: BlockId(0),
                range,
                args: vec![
                    Arg::dat(DatasetId(1), StencilId(1), Access::Read),
                    Arg::dat(DatasetId(0), StencilId(0), Access::Write),
                ],
                kernel: kernel(|_| {}),
                kernel_ir: None,
                seq: 1,
                bw_efficiency: 1.0,
            },
        ];
        (datasets, stencils, chain)
    }

    #[test]
    fn depth_covers_radius_plus_skew() {
        let (datasets, stencils, chain) = fixture();
        let d = decompose(&chain, 4, DecompKind::OneD);
        let plan = HaloExchange::plan(&chain, &datasets, &stencils, &d);
        // both datasets are read with radius 1; the chain skew is 1.
        assert_eq!(plan.recs.len(), 2);
        for rec in &plan.recs {
            assert_eq!(rec.depth, 2, "radius 1 + skew 1");
        }
    }

    #[test]
    fn interior_ranks_pay_two_faces_edges_one() {
        let (datasets, stencils, chain) = fixture();
        let d = decompose(&chain, 4, DecompKind::OneD);
        let plan = HaloExchange::plan(&chain, &datasets, &stencils, &d);
        let edge = plan.rank_cost(&d, 0, Interconnect::InfiniBand);
        let mid = plan.rank_cost(&d, 1, Interconnect::InfiniBand);
        assert_eq!(edge.messages, plan.recs.len() as u64);
        assert_eq!(mid.messages, 2 * plan.recs.len() as u64);
        assert!(mid.bytes > edge.bytes);
        assert!(mid.time_s > edge.time_s);
    }

    #[test]
    fn point_only_chains_need_no_exchange() {
        let (datasets, stencils, mut chain) = fixture();
        // rewrite both loops to point stencils
        for l in &mut chain {
            for a in &mut l.args {
                if let Arg::Dat { stencil, .. } = a {
                    *stencil = StencilId(0);
                }
            }
        }
        let d = decompose(&chain, 4, DecompKind::OneD);
        let plan = HaloExchange::plan(&chain, &datasets, &stencils, &d);
        assert!(plan.recs.is_empty());
        let c = plan.rank_cost(&d, 1, Interconnect::NvLink);
        assert_eq!(c.messages, 0);
        assert_eq!(c.time_s, 0.0);
    }

    #[test]
    fn single_rank_exchanges_nothing() {
        let (datasets, stencils, chain) = fixture();
        let d = decompose(&chain, 1, DecompKind::OneD);
        let plan = HaloExchange::plan(&chain, &datasets, &stencils, &d);
        let c = plan.rank_cost(&d, 0, Interconnect::PciePeer);
        assert_eq!(c.messages, 0);
    }

    #[test]
    fn two_d_splits_cross_sections() {
        let (datasets, stencils, chain) = fixture();
        let d = decompose(&chain, 4, DecompKind::TwoD);
        let plan = HaloExchange::plan(&chain, &datasets, &stencils, &d);
        // two axes, two read datasets -> 4 recs; each plane divided by the
        // perpendicular rank count.
        assert_eq!(plan.recs.len(), 4);
        for rec in &plan.recs {
            let full = datasets[rec.dat.0 as usize].repr_plane_bytes();
            assert_eq!(rec.plane_bytes, full / d.perpendicular(rec.axis) as u64);
        }
    }

    #[test]
    fn boundary_fraction_bounded_and_positive() {
        let (datasets, stencils, chain) = fixture();
        let d = decompose(&chain, 4, DecompKind::OneD);
        let plan = HaloExchange::plan(&chain, &datasets, &stencils, &d);
        let f = plan.boundary_fraction(&d, 1);
        assert!(f > 0.0 && f <= 0.95, "fraction {f}");
        // edge rank has one face only -> smaller fraction
        assert!(plan.boundary_fraction(&d, 0) < f);
    }
}
