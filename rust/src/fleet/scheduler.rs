//! Admission, placement and the discrete-event serving loop.
//!
//! The scheduler walks a [`Workload`] trace on a virtual clock: each
//! request is placed on a cluster target by a [`Policy`], executed for
//! real (the full modelled engine — the service time *is* the engine's
//! modelled `elapsed_s`, the numerics are bit-exact against a solo
//! run), and its completion advances the target's availability.
//! Identical-fingerprint requests share one frozen [`Program`] when
//! batching is on, so freeze-time `ChainAnalysis` and process-wide
//! `TunedPlanCache` entries are built once and hit from every other
//! tenant — the cross-tenant amortisation this layer exists to
//! exercise.
//!
//! [`Scenario`]s inject failures and elasticity mid-trace: a rank
//! failure re-decomposes the sharded target onto its survivors (the
//! in-flight request is retried there, wasted time and all), scale-up
//! adds a member, scale-down retires one.

use std::collections::{BTreeSet, HashMap};
use std::sync::Arc;

use crate::bench_support::{model_scale, store_checksum};
use crate::exec::Metrics;
use crate::ops::Drive;
use crate::program::{ChainId, Program, ProgramBuilder, Session};

use super::cluster::{Cluster, FleetTarget};
use super::workload::{FleetApp, Request, Workload};

/// Minimum temporal-fusion depth the serving loop replays at.
///
/// Plain `Session::replay` charges one `analysis_builds` per *session*
/// (each session's first use of a frozen chain), so N tenants sharing a
/// Program would still count N builds. `Session::replay_fused` with
/// `k >= 2` memoises the fused analysis on the shared [`Program`]
/// itself — exactly one session per `(chain, k)` pays the build, every
/// other tenant counts a reuse hit. Serving therefore never replays
/// below depth 2 (members may pin deeper). Requests need `steps >= 2`
/// for the depth not to clamp back to plain replay.
pub const FUSE_FLOOR: u32 = 2;

/// Placement policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Lowest-id target idle at release time; when none is idle, the
    /// one that frees earliest (ties to lowest id).
    FirstFit,
    /// Minimise modelled completion: `max(release, free) + est_service`,
    /// where the estimate is the last observed service of this
    /// fingerprint on that target, falling back to a topology
    /// bytes-over-bottleneck-bandwidth guess.
    BestFit,
    /// Prefer targets whose fastest tier holds the whole problem
    /// (resident class before streaming class), then earliest-free.
    TierAware,
}

impl Policy {
    pub fn parse(s: &str) -> crate::Result<Policy> {
        match s {
            "first-fit" => Ok(Policy::FirstFit),
            "best-fit" => Ok(Policy::BestFit),
            "tier-aware" => Ok(Policy::TierAware),
            other => crate::bail!(
                "unknown placement policy {other:?} (first-fit|best-fit|tier-aware)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::FirstFit => "first-fit",
            Policy::BestFit => "best-fit",
            Policy::TierAware => "tier-aware",
        }
    }
}

/// A failure/elasticity event injected at a virtual time.
#[derive(Debug, Clone, PartialEq)]
pub enum Scenario {
    /// Target loses one rank at `at_s`: re-decompose onto the
    /// survivors (`x<N>` → `x<N-1>`), retrying the in-flight request
    /// there; an unsharded target is retired outright instead.
    RankFailure { target: usize, at_s: f64 },
    /// A new member (any fleet member spec) joins at `at_s`.
    ScaleUp { member: String, at_s: f64 },
    /// Target stops taking new requests at `at_s` (drains in-flight).
    ScaleDown { target: usize, at_s: f64 },
}

impl Scenario {
    /// Parse `fail:<target>@<t>`, `up:<member-spec>@<t>`,
    /// `down:<target>@<t>`. The split is at the *last* `@` — member
    /// specs contain `:` but never `@`.
    pub fn parse(s: &str) -> crate::Result<Scenario> {
        let Some((head, at)) = s.rsplit_once('@') else {
            crate::bail!("scenario {s:?} needs an @<time_s> suffix");
        };
        let at_s: f64 = at
            .parse()
            .ok()
            .filter(|t: &f64| t.is_finite() && *t >= 0.0)
            .ok_or_else(|| crate::err!("bad scenario time {at:?} in {s:?}"))?;
        let idx = |digits: &str| -> crate::Result<usize> {
            digits
                .parse()
                .map_err(|_| crate::err!("bad target index {digits:?} in scenario {s:?}"))
        };
        if let Some(t) = head.strip_prefix("fail:") {
            Ok(Scenario::RankFailure { target: idx(t)?, at_s })
        } else if let Some(spec) = head.strip_prefix("up:") {
            // validate the member grammar now, not mid-trace
            FleetTarget::parse(usize::MAX, spec)?;
            Ok(Scenario::ScaleUp { member: spec.to_string(), at_s })
        } else if let Some(t) = head.strip_prefix("down:") {
            Ok(Scenario::ScaleDown { target: idx(t)?, at_s })
        } else {
            crate::bail!("unknown scenario {s:?} (fail:<i>@t | up:<spec>@t | down:<i>@t)")
        }
    }

    pub fn at_s(&self) -> f64 {
        match self {
            Scenario::RankFailure { at_s, .. }
            | Scenario::ScaleUp { at_s, .. }
            | Scenario::ScaleDown { at_s, .. } => *at_s,
        }
    }
}

/// Serving options.
#[derive(Debug, Clone)]
pub struct FleetOpts {
    pub policy: Policy,
    /// Share one frozen Program per `(app, scale)` fingerprint across
    /// tenants (on by default; off freezes per request — same numerics,
    /// no cross-tenant amortisation).
    pub batching: bool,
    pub scenarios: Vec<Scenario>,
    /// Collect per-request engine timelines onto the serving clock.
    pub trace: bool,
}

impl Default for FleetOpts {
    fn default() -> Self {
        FleetOpts {
            policy: Policy::FirstFit,
            batching: true,
            scenarios: Vec::new(),
            trace: false,
        }
    }
}

/// One completed request.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub id: u32,
    pub tenant: u32,
    pub app: FleetApp,
    pub size_gb: f64,
    pub fingerprint: u64,
    /// Target that completed the request.
    pub target: usize,
    /// Release time (closed-loop follow-ups release at their
    /// predecessor's completion).
    pub arrival_s: f64,
    pub start_s: f64,
    pub end_s: f64,
    /// Modelled engine time of the completing attempt.
    pub service_s: f64,
    /// `end - arrival`: queueing + service (+ any failed attempt).
    pub latency_s: f64,
    pub checksum: u64,
    pub oom: bool,
    /// The request survived a rank failure or target retirement.
    pub retried: bool,
}

/// Per-target serving report.
#[derive(Debug, Clone)]
pub struct TargetStat {
    pub id: usize,
    pub spec: String,
    pub requests: u64,
    pub busy_s: f64,
    /// `busy / makespan`.
    pub util: f64,
    /// Dominant stream of the work this target ran.
    pub bound: String,
    pub degraded: bool,
    pub retired: bool,
}

/// The result of serving one workload on one cluster.
#[derive(Debug, Clone)]
pub struct FleetRun {
    pub outcomes: Vec<RequestOutcome>,
    /// Aggregate of every request's engine metrics; `elapsed_s` is the
    /// serving makespan and `program_freeze_s` the total freeze time of
    /// the *distinct* Programs built (merge would double-count the
    /// shared one per tenant). The `request_latency_s` histogram in
    /// `metrics.obs` holds every request latency.
    pub metrics: Metrics,
    pub makespan_s: f64,
    pub distinct_fingerprints: usize,
    /// Frozen Programs actually built (== distinct fingerprints when
    /// batching, == requests when not).
    pub programs_built: u64,
    pub failovers: u64,
    pub retired: u64,
    pub added: u64,
    /// Final composition (post-scenario), parseable by `Cluster::parse`.
    pub cluster_spec: String,
    pub policy: Policy,
    pub per_target: Vec<TargetStat>,
}

impl FleetRun {
    pub fn completed(&self) -> usize {
        self.outcomes.len()
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.outcomes.len() as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    /// Latency quantile (upper histogram-bucket bound) over all
    /// completed requests.
    pub fn latency_quantile(&self, q: f64) -> f64 {
        self.metrics
            .histogram_quantiles("request_latency_s", &[q])
            .map(|v| v[0])
            .unwrap_or(0.0)
    }
}

/// Run one request solo on one member — the same execution recipe the
/// serving loop uses (same fused depth floor), so a fleet outcome of
/// the same `(member, app, size, steps)` must match this checksum
/// bit-for-bit. Returns `(service_s, checksum)`.
pub fn solo_run(
    member: &FleetTarget,
    app: FleetApp,
    size_gb: f64,
    steps: usize,
) -> crate::Result<(f64, u64)> {
    let scale = model_scale(app.base_bytes(), size_gb);
    let mut b = ProgramBuilder::new();
    let chain = app.declare_with_chain(&mut b, scale);
    let program = Arc::new(b.freeze()?);
    let done = execute(member, app, scale, steps, &program, chain, false);
    Ok((done.service_s, done.checksum))
}

/// One executed attempt.
struct Attempt {
    service_s: f64,
    checksum: u64,
    oom: bool,
    metrics: Metrics,
    trace: Vec<crate::exec::timeline::TraceEvent>,
}

fn execute(
    member: &FleetTarget,
    app: FleetApp,
    scale: u64,
    steps: usize,
    program: &Arc<Program>,
    chain: ChainId,
    trace: bool,
) -> Attempt {
    let cfg = member.config(app.calib());
    let mut sess = Session::new(program.clone(), &cfg);
    if trace {
        sess.metrics_mut().enable_trace();
    }
    app.initialise(scale, &mut sess);
    sess.flush();
    sess.reset_metrics();
    sess.set_cyclic_phase(true);
    let k = member.fuse.max(FUSE_FLOOR) as usize;
    sess.replay_fused(chain, steps, k);
    sess.flush();
    let checksum = store_checksum(&sess);
    let oom = sess.oom();
    let mut metrics = sess.metrics().clone();
    let trace = metrics.take_trace_events();
    Attempt {
        service_s: metrics.elapsed_s,
        checksum,
        oom,
        metrics,
        trace,
    }
}

/// The frozen-Program registry: one Program per `(app, scale)` when
/// batching, a fresh freeze per request when not.
struct Programs {
    batching: bool,
    map: HashMap<(FleetApp, u64), (Arc<Program>, ChainId)>,
    freeze_total_s: f64,
    built: u64,
}

impl Programs {
    fn get(&mut self, app: FleetApp, scale: u64) -> crate::Result<(Arc<Program>, ChainId)> {
        if self.batching {
            if let Some((p, c)) = self.map.get(&(app, scale)) {
                return Ok((p.clone(), *c));
            }
        }
        let mut b = ProgramBuilder::new();
        let chain = app.declare_with_chain(&mut b, scale);
        let program = Arc::new(b.freeze()?);
        self.freeze_total_s += program.freeze_s();
        self.built += 1;
        if self.batching {
            self.map.insert((app, scale), (program.clone(), chain));
        }
        Ok((program, chain))
    }
}

/// One target's serving state.
struct Server {
    member: FleetTarget,
    free_at: f64,
    available_from: f64,
    busy_s: f64,
    requests: u64,
    degraded: bool,
    retired: bool,
    metrics: Metrics,
}

impl Server {
    fn new(member: FleetTarget, available_from: f64) -> Server {
        Server {
            member,
            free_at: available_from,
            available_from,
            busy_s: 0.0,
            requests: 0,
            degraded: false,
            retired: false,
            metrics: Metrics::default(),
        }
    }

    /// Earliest start this target could give a request released at `rel`.
    fn earliest(&self, rel: f64) -> f64 {
        rel.max(self.free_at).max(self.available_from)
    }
}

/// Topology fallback for the best-fit estimate: bytes moved over the
/// bottleneck bandwidth (fastest-tier bandwidth when the problem is
/// resident, the slowest crossing link when it streams), split across
/// ranks. A placement heuristic only — real service is modelled by the
/// engine at dispatch.
fn heuristic_service_s(member: &FleetTarget, bytes: u64, steps: usize) -> f64 {
    let topo = member.topology();
    let moved_gb = bytes as f64 * steps as f64 / 1e9;
    let fastest = topo.fastest();
    let resident = fastest.capacity_bytes.is_none_or(|c| bytes <= c);
    let bw = if resident {
        fastest.bw_gbs
    } else {
        topo.links()
            .iter()
            .map(|l| l.bw_gbs)
            .fold(fastest.bw_gbs, f64::min)
    };
    moved_gb / bw.max(1e-9) / member.target.ranks().max(1) as f64
}

/// Serve `workload` on `cluster`. Deterministic: the same
/// (cluster, workload, opts) triple yields bit-identical placements,
/// latencies and checksums.
pub fn serve(cluster: &Cluster, workload: &Workload, opts: &FleetOpts) -> crate::Result<FleetRun> {
    crate::ensure!(!cluster.is_empty(), "cannot serve on an empty fleet");
    crate::ensure!(
        workload.steps >= 2,
        "fleet requests replay fused (>= 2 steps) so freeze-time analysis is \
         shared across tenants; got steps={}",
        workload.steps
    );

    crate::obs::reset();
    let root = crate::obs::span("fleet");
    root.field("targets", cluster.len());
    root.field("requests", workload.total());
    root.field("policy", opts.policy.name());

    let mut servers: Vec<Server> = cluster
        .targets
        .iter()
        .map(|m| Server::new(m.clone(), 0.0))
        .collect();
    let mut scenarios: Vec<(Scenario, bool)> = {
        let mut v: Vec<_> = opts.scenarios.iter().map(|s| (s.clone(), false)).collect();
        v.sort_by(|a, b| a.0.at_s().total_cmp(&b.0.at_s()));
        v
    };

    // Split the trace into released requests and closed-loop follow-ups
    // (released at their predecessor's completion).
    let mut ready: Vec<Request> = Vec::new();
    let mut held: Vec<std::collections::VecDeque<Request>> =
        (0..workload.tenants).map(|_| Default::default()).collect();
    for r in workload.generate() {
        if r.seq == 0 || r.arrival_s > 0.0 {
            ready.push(r);
        } else {
            held[r.tenant as usize].push_back(r);
        }
    }

    let mut programs = Programs {
        batching: opts.batching,
        map: HashMap::new(),
        freeze_total_s: 0.0,
        built: 0,
    };
    let mut estimates: HashMap<(u64, usize), f64> = HashMap::new();
    let mut aggregate = Metrics::default();
    if opts.trace {
        aggregate.enable_trace();
    }
    let mut outcomes: Vec<RequestOutcome> = Vec::new();
    let mut failovers = 0u64;
    let mut retired = 0u64;
    let mut added = 0u64;

    while !ready.is_empty() {
        // Earliest release wins, ties to generation order.
        let next = ready
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.arrival_s.total_cmp(&b.arrival_s).then(a.id.cmp(&b.id))
            })
            .map(|(i, _)| i)
            .expect("ready is non-empty");
        let req = ready.remove(next);

        // Elasticity scenarios due by this release apply before
        // placement (rank failures apply at dispatch — they intercept
        // the request whose service spans them).
        for (sc, applied) in scenarios.iter_mut() {
            if *applied || sc.at_s() > req.arrival_s {
                continue;
            }
            match sc {
                Scenario::ScaleUp { member, at_s } => {
                    let m = FleetTarget::parse(servers.len(), member)?;
                    servers.push(Server::new(m, *at_s));
                    added += 1;
                    *applied = true;
                }
                Scenario::ScaleDown { target, .. } => {
                    crate::ensure!(
                        *target < servers.len(),
                        "scale-down of unknown target {target}"
                    );
                    if !servers[*target].retired {
                        servers[*target].retired = true;
                        retired += 1;
                    }
                    *applied = true;
                }
                Scenario::RankFailure { .. } => {}
            }
        }

        let scale = model_scale(req.app.base_bytes(), req.size_gb);
        let (program, chain) = programs.get(req.app, scale)?;
        let fingerprint = program.fingerprint();
        let bytes = program.problem_bytes();

        let mut release = req.arrival_s;
        let mut retried_req = false;
        let outcome = 'placement: loop {
            // Eligible targets: live and big enough for the problem.
            let mut eligible: Vec<usize> = servers
                .iter()
                .enumerate()
                .filter(|(_, s)| !s.retired && s.member.topology().fits(bytes))
                .map(|(i, _)| i)
                .collect();
            crate::ensure!(
                !eligible.is_empty(),
                "no serving target left that fits request {} ({} GB)",
                req.id,
                req.size_gb
            );
            let pick = match opts.policy {
                Policy::FirstFit => eligible
                    .iter()
                    .copied()
                    .find(|&i| servers[i].earliest(release) <= release)
                    .unwrap_or_else(|| {
                        eligible.sort_by(|&a, &b| {
                            servers[a]
                                .earliest(release)
                                .total_cmp(&servers[b].earliest(release))
                                .then(a.cmp(&b))
                        });
                        eligible[0]
                    }),
                Policy::BestFit => {
                    eligible.sort_by(|&a, &b| {
                        let done = |i: usize| {
                            let s = &servers[i];
                            let est = estimates.get(&(fingerprint, i)).copied().unwrap_or_else(
                                || heuristic_service_s(&s.member, bytes, req.steps),
                            );
                            s.earliest(release) + est
                        };
                        done(a).total_cmp(&done(b)).then(a.cmp(&b))
                    });
                    eligible[0]
                }
                Policy::TierAware => {
                    eligible.sort_by(|&a, &b| {
                        let class = |i: usize| -> (u8, f64, usize) {
                            let s = &servers[i];
                            let resident = s
                                .member
                                .topology()
                                .fastest()
                                .capacity_bytes
                                .is_none_or(|c| bytes <= c);
                            (u8::from(!resident), s.earliest(release), i)
                        };
                        class(a).partial_cmp(&class(b)).expect("finite times")
                    });
                    eligible[0]
                }
            };

            let start = servers[pick].earliest(release);
            let sp = crate::obs::span("request");
            sp.field("id", req.id);
            sp.field("tenant", req.tenant);
            sp.field("app", req.app.name());
            sp.field("target", pick);
            sp.field("retry", u8::from(retried_req));
            let attempt = execute(
                &servers[pick].member,
                req.app,
                scale,
                req.steps,
                &program,
                chain,
                opts.trace,
            );
            drop(sp);
            estimates.insert((fingerprint, pick), attempt.service_s);
            let end = start + attempt.service_s;

            // A rank failure whose instant lands inside (or before) this
            // attempt's service interval intercepts it.
            let failure = scenarios.iter_mut().find(|(sc, applied)| {
                matches!(sc, Scenario::RankFailure { target, .. } if *target == pick)
                    && !*applied
                    && sc.at_s() < end
            });
            if let Some((sc, applied)) = failure {
                let at_s = sc.at_s();
                *applied = true;
                let wasted = (at_s - start).max(0.0);
                if wasted > 0.0 {
                    // The attempt ran until the failure: its modelled
                    // work happened, so its counters (and timeline)
                    // fold in; the checksum is discarded with the rerun.
                    servers[pick].busy_s += wasted;
                    servers[pick].metrics.merge(&attempt.metrics);
                    aggregate.merge(&attempt.metrics);
                    aggregate.absorb_trace_events(&attempt.trace, start, &format!("t{pick}:"));
                    failovers += 1;
                    retried_req = true;
                }
                match servers[pick].member.degrade() {
                    Ok(m) => {
                        servers[pick].member = m;
                        servers[pick].degraded = true;
                        // the degraded engine is a different platform;
                        // stale observations would mislead best-fit
                        estimates.retain(|(_, i), _| *i != pick);
                    }
                    Err(_) => {
                        // Unsharded: nothing to re-decompose onto —
                        // retire the target and place elsewhere.
                        servers[pick].retired = true;
                        retired += 1;
                        if wasted == 0.0 {
                            failovers += 1;
                            retried_req = true;
                        }
                    }
                }
                servers[pick].free_at = at_s.max(servers[pick].free_at);
                release = release.max(at_s);
                continue 'placement;
            }

            servers[pick].free_at = end;
            servers[pick].busy_s += attempt.service_s;
            servers[pick].requests += 1;
            servers[pick].metrics.merge(&attempt.metrics);
            aggregate.merge(&attempt.metrics);
            aggregate.absorb_trace_events(&attempt.trace, start, &format!("t{pick}:"));
            aggregate
                .obs
                .record("request_latency_s", end - req.arrival_s);
            break RequestOutcome {
                id: req.id,
                tenant: req.tenant,
                app: req.app,
                size_gb: req.size_gb,
                fingerprint,
                target: pick,
                arrival_s: req.arrival_s,
                start_s: start,
                end_s: end,
                service_s: attempt.service_s,
                latency_s: end - req.arrival_s,
                checksum: attempt.checksum,
                oom: attempt.oom,
                retried: retried_req,
            };
        };

        // Closed loop: completion releases the tenant's next request.
        if let Some(mut follow) = held[req.tenant as usize].pop_front() {
            follow.arrival_s = outcome.end_s;
            ready.push(follow);
        }
        outcomes.push(outcome);
    }

    // Scenarios after the last dispatch still shape the final cluster.
    for (sc, applied) in scenarios.iter_mut().filter(|(_, a)| !*a) {
        *applied = true;
        match sc {
            Scenario::ScaleUp { member, at_s } => {
                let m = FleetTarget::parse(servers.len(), member)?;
                servers.push(Server::new(m, *at_s));
                added += 1;
            }
            Scenario::ScaleDown { target, .. } | Scenario::RankFailure { target, .. }
                if *target >= servers.len() =>
            {
                crate::bail!("scenario names unknown target {target}")
            }
            Scenario::ScaleDown { target, .. } => {
                if !servers[*target].retired {
                    servers[*target].retired = true;
                    retired += 1;
                }
            }
            Scenario::RankFailure { target, .. } => match servers[*target].member.degrade() {
                Ok(m) => {
                    servers[*target].member = m;
                    servers[*target].degraded = true;
                }
                Err(_) => {
                    if !servers[*target].retired {
                        servers[*target].retired = true;
                        retired += 1;
                    }
                }
            },
        }
    }

    drop(root);
    let st = crate::obs::span_stats();
    aggregate.spans_recorded = st.total;
    aggregate.span_max_depth = st.max_depth;

    let makespan_s = outcomes.iter().map(|o| o.end_s).fold(0.0f64, f64::max);
    aggregate.elapsed_s = makespan_s;
    aggregate.program_freeze_s = programs.freeze_total_s;
    let distinct: BTreeSet<u64> = outcomes.iter().map(|o| o.fingerprint).collect();

    let per_target: Vec<TargetStat> = servers
        .iter()
        .map(|s| TargetStat {
            id: s.member.id,
            spec: s.member.spec.clone(),
            requests: s.requests,
            busy_s: s.busy_s,
            util: if makespan_s > 0.0 {
                (s.busy_s / makespan_s).min(1.0)
            } else {
                0.0
            },
            bound: s.metrics.bound().name().to_string(),
            degraded: s.degraded,
            retired: s.retired,
        })
        .collect();
    let members: Vec<String> = servers
        .iter()
        .filter(|s| !s.retired)
        .map(|s| s.member.spec.clone())
        .collect();

    Ok(FleetRun {
        outcomes,
        metrics: aggregate,
        makespan_s,
        distinct_fingerprints: distinct.len(),
        programs_built: programs.built,
        failovers,
        retired,
        added,
        cluster_spec: format!("fleet:{}", members.join(",")),
        policy: opts.policy,
        per_target,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::workload::Workload;

    fn tiny(spec: &str, workload: &str, opts: FleetOpts) -> FleetRun {
        let cluster = Cluster::parse(spec).unwrap();
        let w = Workload::parse(workload).unwrap();
        serve(&cluster, &w, &opts).unwrap()
    }

    #[test]
    fn policy_and_scenario_parse() {
        assert_eq!(Policy::parse("best-fit").unwrap(), Policy::BestFit);
        assert!(Policy::parse("round-robin").is_err());
        assert_eq!(
            Scenario::parse("fail:0@0.5").unwrap(),
            Scenario::RankFailure { target: 0, at_s: 0.5 }
        );
        let up = Scenario::parse("up:gpu-explicit:pcie:cyclic@1.5").unwrap();
        assert_eq!(
            up,
            Scenario::ScaleUp { member: "gpu-explicit:pcie:cyclic".into(), at_s: 1.5 }
        );
        assert!(Scenario::parse("fail:0").is_err());
        assert!(Scenario::parse("up:no-such-platform@1").is_err());
        assert!(Scenario::parse("explode:0@1").is_err());
    }

    #[test]
    fn closed_loop_batched_serving_shares_one_analysis() {
        let run = tiny(
            "fleet:gpu-explicit:pcie:cyclic*2",
            "tenants=4,reqs=1,apps=cloverleaf2d,sizes=0.005,steps=4,seed=3",
            FleetOpts::default(),
        );
        assert_eq!(run.completed(), 4);
        assert_eq!(run.distinct_fingerprints, 1);
        assert_eq!(run.programs_built, 1, "batching freezes once");
        assert_eq!(
            run.metrics.analysis_builds, 1,
            "fused analysis memoised on the shared Program"
        );
        assert!(run.metrics.analysis_reuse_hits > 0);
        // identical requests on identical targets: identical numerics
        let c0 = run.outcomes[0].checksum;
        assert!(run.outcomes.iter().all(|o| o.checksum == c0));
        // two equal targets split four equal requests two apiece
        assert!(run.per_target.iter().all(|t| t.requests == 2), "{:?}", run.per_target);
        assert!(run.makespan_s > 0.0 && run.throughput_rps() > 0.0);
        assert!(run.latency_quantile(0.99) >= run.latency_quantile(0.5));
    }

    #[test]
    fn policies_place_on_every_live_target() {
        for policy in [Policy::FirstFit, Policy::BestFit, Policy::TierAware] {
            let run = tiny(
                "fleet:gpu-explicit:pcie:cyclic,gpu-explicit:nvlink:cyclic",
                "tenants=4,reqs=1,apps=cloverleaf2d,sizes=0.005,steps=4,seed=5",
                FleetOpts { policy, ..FleetOpts::default() },
            );
            assert_eq!(run.completed(), 4, "{:?}", policy);
            assert!(
                run.per_target.iter().all(|t| t.requests > 0),
                "{:?} starved a target: {:?}",
                policy,
                run.per_target
            );
        }
    }

    #[test]
    fn elasticity_scenarios_reshape_the_cluster() {
        let run = tiny(
            "fleet:gpu-explicit:pcie:cyclic*2",
            "tenants=6,reqs=1,apps=cloverleaf2d,sizes=0.005,steps=4,arrival=open@1000,seed=9",
            FleetOpts {
                scenarios: vec![
                    Scenario::parse("up:gpu-explicit:nvlink:cyclic@0.0001").unwrap(),
                    Scenario::parse("down:0@0.001").unwrap(),
                ],
                ..FleetOpts::default()
            },
        );
        assert_eq!(run.completed(), 6);
        assert_eq!(run.added, 1);
        assert_eq!(run.retired, 1);
        assert_eq!(run.per_target.len(), 3);
        assert!(run.per_target[0].retired);
        // the final spec drops the retired member, keeps the new one
        assert_eq!(
            run.cluster_spec,
            "fleet:gpu-explicit:pcie:cyclic,gpu-explicit:nvlink:cyclic"
        );
    }
}
