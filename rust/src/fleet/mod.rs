//! Fleet-scale multi-tenant serving: many concurrent stencil Programs
//! on a heterogeneous cluster of modelled targets.
//!
//! The engine models one out-of-core stencil run; this layer turns it
//! into a *service*. A [`Cluster`] is a declarative set of serving
//! targets (`fleet:` spec grammar — any run-target spec, `*<count>`
//! multiplicities, named presets). A [`Workload`] is a deterministic
//! seeded trace of tenant requests (app × size × steps, open- or
//! closed-loop arrivals). [`serve`] walks the trace on a virtual clock:
//! a placement [`Policy`] picks a target per request, the request runs
//! for real (service time = the engine's modelled makespan, numerics
//! bit-exact against a solo run), and identical-fingerprint requests
//! share one frozen [`Program`](crate::program::Program) — so
//! freeze-time `ChainAnalysis` and process-wide tuned-plan cache
//! entries are built once and amortised across every tenant.
//! [`Scenario`]s inject rank failures (re-decomposition onto
//! survivors, in-flight retry) and scale-up/down mid-trace.
//!
//! Reports: [`report::fleet_json`] (flat `fleet_*` record for `--json`
//! and `BENCH_fleet.json`), [`report::summary`], a `fleet` span tree
//! under `--spans`, and per-request engine timelines interleaved onto
//! the serving clock under `--trace`.

pub mod cluster;
pub mod report;
pub mod scheduler;
pub mod workload;

pub use cluster::{Cluster, FleetTarget, PRESETS};
pub use report::{fleet_json, summary};
pub use scheduler::{
    serve, solo_run, FleetOpts, FleetRun, Policy, RequestOutcome, Scenario, TargetStat,
    FUSE_FLOOR,
};
pub use workload::{Arrival, FleetApp, Request, Workload};
