//! Deterministic seeded workload generation: who asks for what, when.
//!
//! A [`Workload`] describes a population of tenants, each issuing
//! requests drawn (seeded, reproducibly) from an app × size menu, under
//! an open-loop arrival process (global Poisson stream at a fixed rate)
//! or a closed loop (each tenant issues its next request the moment the
//! previous one completes). Same seed ⇒ bit-identical request trace —
//! the property `tests/prop_fleet.rs` pins.
//!
//! Spec grammar (`--workload`): comma-separated `key=value` pairs,
//! list values joined with `|`:
//!
//! ```text
//! tenants=8,reqs=2,apps=cloverleaf2d|opensbli,sizes=0.01|0.02,steps=4,arrival=open@200,seed=7
//! ```

use crate::program::{ChainId, ProgramBuilder};

/// The paper applications a fleet request can run. Grids are fixed and
/// small (real numerics, modelled bytes scaled by problem size) so a
/// serving trace of dozens of requests stays test-sized; two requests
/// with the same `(app, size)` freeze byte-identical Programs and so
/// share one fingerprint.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FleetApp {
    CloverLeaf2D,
    CloverLeaf3D,
    OpenSbli,
}

/// CloverLeaf 2D fleet grid.
pub const CL2D_GRID: (usize, usize) = (8, 256);
/// CloverLeaf 3D fleet grid.
pub const CL3D_GRID: [usize; 3] = [8, 8, 64];
/// OpenSBLI (tall-z) fleet grid and steps-per-chain.
pub const SBLI_GRID: [usize; 3] = [16, 16, 96];
pub const SBLI_STEPS_PER_CHAIN: usize = 2;

impl FleetApp {
    pub fn parse(s: &str) -> crate::Result<FleetApp> {
        match s {
            "cloverleaf2d" | "cl2d" => Ok(FleetApp::CloverLeaf2D),
            "cloverleaf3d" | "cl3d" => Ok(FleetApp::CloverLeaf3D),
            "opensbli" | "sbli" => Ok(FleetApp::OpenSbli),
            other => crate::bail!(
                "unknown fleet app {other:?} (cloverleaf2d|cloverleaf3d|opensbli)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FleetApp::CloverLeaf2D => "cloverleaf2d",
            FleetApp::CloverLeaf3D => "cloverleaf3d",
            FleetApp::OpenSbli => "opensbli",
        }
    }

    /// Modelled bytes of this app's fleet grid at `model_scale = 1`.
    pub fn base_bytes(&self) -> u64 {
        crate::bench_support::base_bytes(|b| {
            self.declare(b, 1);
        })
    }

    /// Declare this app's fleet grid into a builder at `scale` and
    /// record its fixed-`dt` step chain — the record-once half every
    /// same-fingerprint tenant shares.
    pub fn declare_with_chain(&self, b: &mut ProgramBuilder, scale: u64) -> ChainId {
        match self {
            FleetApp::CloverLeaf2D => {
                let mut app =
                    crate::apps::cloverleaf2d::CloverLeaf2D::new(b, CL2D_GRID.0, CL2D_GRID.1, scale);
                app.record_step_chain(b)
            }
            FleetApp::CloverLeaf3D => {
                let mut app = crate::apps::cloverleaf3d::CloverLeaf3D::new(
                    b,
                    CL3D_GRID[0],
                    CL3D_GRID[1],
                    CL3D_GRID[2],
                    scale,
                );
                app.record_step_chain(b)
            }
            FleetApp::OpenSbli => {
                let mut app = crate::apps::opensbli::OpenSbli::new_aniso(
                    b,
                    SBLI_GRID,
                    SBLI_STEPS_PER_CHAIN,
                    scale,
                );
                app.record_step_chain(b)
            }
        }
    }

    /// Declarations only (for [`FleetApp::base_bytes`] and the
    /// per-request initialiser, which needs the dataset handles but not
    /// the chain).
    fn declare(&self, b: &mut ProgramBuilder, scale: u64) {
        match self {
            FleetApp::CloverLeaf2D => {
                crate::apps::cloverleaf2d::CloverLeaf2D::new(b, CL2D_GRID.0, CL2D_GRID.1, scale);
            }
            FleetApp::CloverLeaf3D => {
                crate::apps::cloverleaf3d::CloverLeaf3D::new(
                    b,
                    CL3D_GRID[0],
                    CL3D_GRID[1],
                    CL3D_GRID[2],
                    scale,
                );
            }
            FleetApp::OpenSbli => {
                crate::apps::opensbli::OpenSbli::new_aniso(b, SBLI_GRID, SBLI_STEPS_PER_CHAIN, scale);
            }
        }
    }

    /// Write this app's initial fields into a session bound to a
    /// Program frozen from [`FleetApp::declare_with_chain`] at the same
    /// `scale`. Declaration order is deterministic, so a throwaway
    /// builder reproduces the dataset handles of the shared Program.
    pub fn initialise(&self, scale: u64, sess: &mut crate::program::Session) {
        let mut b = ProgramBuilder::new();
        match self {
            FleetApp::CloverLeaf2D => {
                let app = crate::apps::cloverleaf2d::CloverLeaf2D::new(
                    &mut b,
                    CL2D_GRID.0,
                    CL2D_GRID.1,
                    scale,
                );
                app.initialise(sess);
            }
            FleetApp::CloverLeaf3D => {
                let app = crate::apps::cloverleaf3d::CloverLeaf3D::new(
                    &mut b,
                    CL3D_GRID[0],
                    CL3D_GRID[1],
                    CL3D_GRID[2],
                    scale,
                );
                app.initialise(sess);
            }
            FleetApp::OpenSbli => {
                let app = crate::apps::opensbli::OpenSbli::new_aniso(
                    &mut b,
                    SBLI_GRID,
                    SBLI_STEPS_PER_CHAIN,
                    scale,
                );
                app.initialise(sess);
            }
        }
    }

    /// The app's memory-model calibration.
    pub fn calib(&self) -> crate::memory::AppCalib {
        match self {
            FleetApp::CloverLeaf2D => crate::memory::AppCalib::CLOVERLEAF_2D,
            FleetApp::CloverLeaf3D => crate::memory::AppCalib::CLOVERLEAF_3D,
            FleetApp::OpenSbli => crate::memory::AppCalib::OPENSBLI,
        }
    }
}

/// The arrival process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// Open loop: one global Poisson stream at `rate_rps` requests per
    /// modelled second; tenants take arrivals round-robin.
    Open { rate_rps: f64 },
    /// Closed loop: each tenant issues request `j + 1` at the modelled
    /// completion instant of request `j` (zero think time).
    Closed,
}

/// One serving request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Global id (generation order; ties in the event loop break on it).
    pub id: u32,
    pub tenant: u32,
    /// Index within the tenant's sequence.
    pub seq: u32,
    pub app: FleetApp,
    pub size_gb: f64,
    /// Replay steps of the recorded step chain.
    pub steps: usize,
    /// Absolute modelled arrival. Closed-loop requests with `seq > 0`
    /// carry 0 here; the scheduler releases them at the predecessor's
    /// completion.
    pub arrival_s: f64,
}

/// A deterministic request-trace generator.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    pub tenants: u32,
    /// Requests per tenant.
    pub per_tenant: u32,
    pub apps: Vec<FleetApp>,
    pub sizes_gb: Vec<f64>,
    pub steps: usize,
    pub arrival: Arrival,
    pub seed: u64,
}

impl Default for Workload {
    fn default() -> Self {
        Workload {
            tenants: 4,
            per_tenant: 1,
            apps: vec![FleetApp::CloverLeaf2D],
            sizes_gb: vec![0.01],
            steps: 4,
            arrival: Arrival::Closed,
            seed: 0xF1EE7,
        }
    }
}

impl Workload {
    /// Parse the `--workload` grammar; absent keys keep their defaults,
    /// an empty spec is the default workload.
    pub fn parse(spec: &str) -> crate::Result<Workload> {
        let mut w = Workload::default();
        for pair in spec.split(',').filter(|p| !p.is_empty()) {
            let Some((key, val)) = pair.split_once('=') else {
                crate::bail!("bad workload token {pair:?} (expected key=value)");
            };
            let num = |what: &str| -> crate::Result<u32> {
                val.parse::<u32>()
                    .ok()
                    .filter(|n| *n >= 1)
                    .ok_or_else(|| crate::err!("bad workload {what} {val:?} (expected >= 1)"))
            };
            match key {
                "tenants" => w.tenants = num("tenant count")?,
                "reqs" => w.per_tenant = num("request count")?,
                "steps" => w.steps = num("step count")? as usize,
                "seed" => {
                    w.seed = val
                        .parse()
                        .map_err(|_| crate::err!("bad workload seed {val:?}"))?
                }
                "apps" => {
                    w.apps = val
                        .split('|')
                        .map(FleetApp::parse)
                        .collect::<crate::Result<Vec<_>>>()?;
                    crate::ensure!(!w.apps.is_empty(), "empty workload app list");
                }
                "sizes" => {
                    w.sizes_gb = val
                        .split('|')
                        .map(|s| {
                            s.parse::<f64>()
                                .ok()
                                .filter(|g| *g > 0.0 && g.is_finite())
                                .ok_or_else(|| crate::err!("bad workload size {s:?} (GB > 0)"))
                        })
                        .collect::<crate::Result<Vec<_>>>()?;
                }
                "arrival" => {
                    w.arrival = match val.split_once('@') {
                        None if val == "closed" => Arrival::Closed,
                        Some(("open", rate)) => {
                            let r: f64 = rate.parse().ok().filter(|r| *r > 0.0).ok_or_else(
                                || crate::err!("bad open-loop rate {rate:?} (rps > 0)"),
                            )?;
                            Arrival::Open { rate_rps: r }
                        }
                        _ => crate::bail!(
                            "bad arrival {val:?} (expected closed or open@<rate_rps>)"
                        ),
                    }
                }
                other => crate::bail!(
                    "unknown workload key {other:?} \
                     (tenants|reqs|apps|sizes|steps|arrival|seed)"
                ),
            }
        }
        crate::ensure!(
            w.tenants as u64 * w.per_tenant as u64 <= 4096,
            "workload too large (max 4096 requests)"
        );
        Ok(w)
    }

    /// Total requests in the trace.
    pub fn total(&self) -> u32 {
        self.tenants * self.per_tenant
    }

    /// Generate the request trace. Deterministic: the same spec (seed
    /// included) yields a bit-identical `Vec<Request>`.
    pub fn generate(&self) -> Vec<Request> {
        let mut rng = Rng::new(self.seed);
        let mut out = Vec::with_capacity(self.total() as usize);
        // Requests are minted in global arrival order, tenants
        // round-robin, so open-loop inter-arrival gaps accumulate over
        // one stream the way a shared front door sees them.
        let mut clock = 0.0f64;
        for g in 0..self.total() {
            let tenant = g % self.tenants;
            let seq = g / self.tenants;
            let app = self.apps[rng.pick(self.apps.len())];
            let size_gb = self.sizes_gb[rng.pick(self.sizes_gb.len())];
            let arrival_s = match self.arrival {
                Arrival::Open { rate_rps } => {
                    clock += rng.exp(rate_rps);
                    clock
                }
                Arrival::Closed => 0.0,
            };
            out.push(Request {
                id: g,
                tenant,
                seq,
                app,
                size_gb,
                steps: self.steps,
                arrival_s,
            });
        }
        out
    }
}

/// xorshift64* — the same deterministic-seeded idiom the tuner search
/// uses; good enough spread for menu picks and exponential gaps, zero
/// dependencies.
#[derive(Debug, Clone)]
pub(crate) struct Rng(u64);

impl Rng {
    pub(crate) fn new(seed: u64) -> Self {
        // a zero state would be absorbing; fold in a non-zero constant
        Rng(seed ^ 0x9E37_79B9_7F4A_7C15)
    }

    pub(crate) fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, 1)`.
    pub(crate) fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform index into a menu of `n` options.
    pub(crate) fn pick(&mut self, n: usize) -> usize {
        (self.next_u64() % n.max(1) as u64) as usize
    }

    /// Exponential inter-arrival gap at `rate` events per second.
    pub(crate) fn exp(&mut self, rate: f64) -> f64 {
        -(1.0 - self.f64()).ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_trace() {
        let w = Workload::parse(
            "tenants=3,reqs=2,apps=cloverleaf2d|opensbli,sizes=0.01|0.02,arrival=open@100,seed=42",
        )
        .unwrap();
        let a = w.generate();
        let b = w.generate();
        assert_eq!(a, b);
        assert_eq!(a.len(), 6);
        // arrivals strictly increase along the global stream
        for pair in a.windows(2) {
            assert!(pair[1].arrival_s > pair[0].arrival_s);
        }
        // a different seed moves at least the arrival times
        let mut w2 = w.clone();
        w2.seed = 43;
        assert_ne!(w2.generate(), a);
    }

    #[test]
    fn closed_loop_releases_only_first_requests() {
        let w = Workload::parse("tenants=2,reqs=3,seed=1").unwrap();
        let trace = w.generate();
        assert_eq!(trace.len(), 6);
        assert!(trace.iter().all(|r| r.arrival_s == 0.0));
        assert_eq!(trace.iter().filter(|r| r.seq == 0).count(), 2);
    }

    #[test]
    fn spec_errors_are_caught() {
        assert!(Workload::parse("tenants=0").is_err());
        assert!(Workload::parse("nonsense").is_err());
        assert!(Workload::parse("apps=quake").is_err());
        assert!(Workload::parse("sizes=-1").is_err());
        assert!(Workload::parse("arrival=open@0").is_err());
        assert!(Workload::parse("arrival=sometimes").is_err());
        assert!(Workload::parse("tenants=100,reqs=100").is_err());
    }

    #[test]
    fn fleet_apps_declare_and_fingerprint_stably() {
        for app in [FleetApp::CloverLeaf2D, FleetApp::CloverLeaf3D, FleetApp::OpenSbli] {
            assert!(app.base_bytes() > 0, "{:?}", app);
            let mut b = crate::program::ProgramBuilder::new();
            let chain = app.declare_with_chain(&mut b, 2);
            let p1 = b.freeze().unwrap();
            assert!(!p1.chain(chain).loops.is_empty());
            let mut b2 = crate::program::ProgramBuilder::new();
            app.declare_with_chain(&mut b2, 2);
            let p2 = b2.freeze().unwrap();
            assert_eq!(
                p1.fingerprint(),
                p2.fingerprint(),
                "same app+scale must share one fingerprint ({:?})",
                app
            );
        }
    }
}
