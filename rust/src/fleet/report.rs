//! Serving reports: the flat `--json` record and the human summary.

use std::fmt::Write as _;

use super::scheduler::FleetRun;
use crate::obs::esc;

/// Mean request latency over the completed trace.
pub fn mean_latency_s(run: &FleetRun) -> f64 {
    if run.outcomes.is_empty() {
        return 0.0;
    }
    run.outcomes.iter().map(|o| o.latency_s).sum::<f64>() / run.outcomes.len() as f64
}

/// One flat JSON object describing the serving run — fixed `fleet_*`
/// scalars plus one `fleet_target_<i>_*` family per target (the schema
/// pin in `tests/json_roundtrip.rs` covers both). Latency quantiles are
/// the `request_latency_s` histogram's upper bucket bounds.
pub fn fleet_json(run: &FleetRun) -> String {
    let m = &run.metrics;
    let mut s = String::with_capacity(1024);
    s.push('{');
    let _ = write!(
        s,
        concat!(
            "\"fleet_spec\":\"{}\",\"policy\":\"{}\",",
            "\"fleet_targets\":{},\"fleet_requests\":{},\"fleet_completed\":{},",
            "\"fleet_distinct_fingerprints\":{},\"fleet_programs_built\":{},",
            "\"fleet_failovers\":{},\"fleet_retired\":{},\"fleet_added\":{},",
            "\"fleet_makespan_s\":{:.9},\"fleet_throughput_rps\":{:.4},",
            "\"p50_latency_s\":{:.9},\"p99_latency_s\":{:.9},\"mean_latency_s\":{:.9},",
            "\"fleet_analysis_builds\":{},\"fleet_analysis_reuse_hits\":{},",
            "\"fleet_tune_evals\":{},\"fleet_tune_cache_hits\":{},",
            "\"fleet_program_freeze_s\":{:.9},\"oom\":{}"
        ),
        esc(&run.cluster_spec),
        run.policy.name(),
        run.per_target.len(),
        run.completed(),
        run.completed(),
        run.distinct_fingerprints,
        run.programs_built,
        run.failovers,
        run.retired,
        run.added,
        run.makespan_s,
        run.throughput_rps(),
        run.latency_quantile(0.5),
        run.latency_quantile(0.99),
        mean_latency_s(run),
        m.analysis_builds,
        m.analysis_reuse_hits,
        m.tune_evals,
        m.tune_cache_hits,
        m.program_freeze_s,
        run.outcomes.iter().any(|o| o.oom),
    );
    for t in &run.per_target {
        let state = if t.retired {
            "retired"
        } else if t.degraded {
            "degraded"
        } else {
            "live"
        };
        let _ = write!(
            s,
            concat!(
                ",\"fleet_target_{i}_spec\":\"{}\",\"fleet_target_{i}_requests\":{},",
                "\"fleet_target_{i}_util\":{:.4},\"fleet_target_{i}_bound\":\"{}\",",
                "\"fleet_target_{i}_state\":\"{}\""
            ),
            esc(&t.spec),
            t.requests,
            t.util,
            esc(&t.bound),
            state,
            i = t.id,
        );
    }
    s.push('}');
    s
}

/// Multi-line human summary of a serving run.
pub fn summary(run: &FleetRun) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "fleet {} policy={} requests={} makespan={:.6}s throughput={:.2} req/s",
        run.cluster_spec,
        run.policy.name(),
        run.completed(),
        run.makespan_s,
        run.throughput_rps(),
    );
    let _ = writeln!(
        s,
        "  latency p50={:.6}s p99={:.6}s mean={:.6}s",
        run.latency_quantile(0.5),
        run.latency_quantile(0.99),
        mean_latency_s(run),
    );
    let _ = writeln!(
        s,
        "  sharing: fingerprints={} programs_built={} analysis_builds={} \
         analysis_reuse_hits={} tune_evals={} tune_cache_hits={} freeze={:.6}s",
        run.distinct_fingerprints,
        run.programs_built,
        run.metrics.analysis_builds,
        run.metrics.analysis_reuse_hits,
        run.metrics.tune_evals,
        run.metrics.tune_cache_hits,
        run.metrics.program_freeze_s,
    );
    if run.failovers + run.retired + run.added > 0 {
        let _ = writeln!(
            s,
            "  scenarios: failovers={} retired={} added={}",
            run.failovers, run.retired, run.added,
        );
    }
    for t in &run.per_target {
        let mut flags = String::new();
        if t.degraded {
            flags.push_str(" degraded");
        }
        if t.retired {
            flags.push_str(" retired");
        }
        let _ = writeln!(
            s,
            "  target {}: {} requests={} busy={:.6}s util={:.1}% bound={}{}",
            t.id,
            t.spec,
            t.requests,
            t.busy_s,
            t.util * 100.0,
            t.bound,
            flags,
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::{serve, Cluster, FleetOpts, Workload};

    #[test]
    fn fleet_json_is_flat_and_balanced() {
        let cluster = Cluster::parse("fleet:small").unwrap();
        let w = Workload::parse("tenants=2,reqs=1,sizes=0.005,steps=4,seed=2").unwrap();
        let run = serve(&cluster, &w, &FleetOpts::default()).unwrap();
        let json = fleet_json(&run);
        assert!(json.starts_with('{') && json.ends_with('}'));
        // every pinned scalar plus both per-target families must appear
        for key in [
            "\"fleet_spec\":",
            "\"policy\":",
            "\"fleet_requests\":2",
            "\"fleet_distinct_fingerprints\":1",
            "\"p50_latency_s\":",
            "\"p99_latency_s\":",
            "\"fleet_tune_cache_hits\":",
            "\"fleet_target_0_util\":",
            "\"fleet_target_1_state\":\"live\"",
            "\"oom\":false",
        ] {
            assert!(json.contains(key), "{key} missing in {json}");
        }
        let summary = summary(&run);
        assert!(summary.contains("throughput="));
        assert!(summary.contains("target 1:"));
    }
}
