//! Declarative clusters: the set of heterogeneous serving targets a
//! fleet run schedules onto.
//!
//! A cluster is parsed from a `fleet:` spec — a comma-separated list of
//! *members*, each any execution-target spec the run grammar already
//! accepts ([`Config::parse_spec_opts`]: legacy platform heads, `tiers:`
//! stacks — including `~c:` link codecs and `codec<spec>` tokens —
//! sharded `x<N>` suffixes, `tuned` and `fuse<k>` tokens), with an
//! optional `*<count>` multiplicity suffix:
//!
//! ```text
//! fleet:gpu-explicit:pcie:cyclic:tuned*2,knl-cache-tiled
//! fleet:hetero                       (a named preset)
//! ```
//!
//! Commas and `*` never appear inside a member spec (tier stacks join
//! tiers with `+`, options with `:`), so the split is unambiguous.

use crate::coordinator::config::{Config, Platform, Target};
use crate::memory::AppCalib;
use crate::topology::Topology;

/// One serving target of a cluster.
#[derive(Debug, Clone)]
pub struct FleetTarget {
    /// Position in the cluster (stable across the run; placement,
    /// scenarios and the per-target report refer to it).
    pub id: usize,
    /// The member spec this target was parsed from (multiplicity
    /// expanded away).
    pub spec: String,
    pub target: Target,
    /// Wrap this target's engine in the cost-model auto-tuner.
    pub tuned: bool,
    /// Temporal-fusion depth from the member spec (`1` = unset; the
    /// scheduler deepens to its own floor — see `fleet::scheduler`).
    pub fuse: u32,
}

impl FleetTarget {
    /// Parse one member spec (no multiplicity suffix).
    pub fn parse(id: usize, member: &str) -> crate::Result<FleetTarget> {
        let (target, tuned, fuse, _codec) = Config::parse_spec_opts(member)?;
        crate::ensure!(
            fuse != 0,
            "fleet member {member:?} asks the tuner for a fusion depth (fuse0); \
             fleet members pin an explicit depth"
        );
        Ok(FleetTarget {
            id,
            spec: member.to_string(),
            target,
            tuned,
            fuse,
        })
    }

    /// The run configuration a request executes under on this target.
    pub fn config(&self, app: AppCalib) -> Config {
        let cfg = Config::for_target(self.target.clone(), app).with_fuse(self.fuse);
        if self.tuned {
            cfg.with_tuning(crate::tuner::TuneOpts::default())
                .expect("tuned member specs are validated at parse time")
        } else {
            cfg
        }
    }

    /// The member's memory topology (for capacity-aware placement and
    /// service estimates).
    pub fn topology(&self) -> Topology {
        self.config(AppCalib::CLOVERLEAF_2D).topology()
    }

    /// Display label.
    pub fn label(&self) -> String {
        self.target.label()
    }

    /// Re-decompose onto the survivors after losing one rank: `x<N>`
    /// becomes `x<N-1>`, collapsing to the inner single-device target
    /// when only one rank survives. Errors on unsharded members — a
    /// single-device target has no survivors to re-decompose onto (the
    /// scheduler retires it instead).
    pub fn degrade(&self) -> crate::Result<FleetTarget> {
        let ranks = self.target.ranks();
        crate::ensure!(
            ranks > 1,
            "target {:?} is not sharded: a rank failure retires it outright",
            self.spec
        );
        let survivors = ranks - 1;
        let target = match &self.target {
            // Platform::sharded(1) is an identity (the `x1` convenience),
            // so the one-survivor collapse is explicit.
            Target::Platform(Platform::Sharded { inner, .. }) if survivors == 1 => {
                Target::Platform(inner.to_platform())
            }
            t => t.clone().sharded(survivors)?,
        };
        let spec = format!("{}{}", target.spec(), if self.tuned { ":tuned" } else { "" });
        Ok(FleetTarget {
            id: self.id,
            spec,
            target,
            tuned: self.tuned,
            fuse: self.fuse,
        })
    }
}

/// A declarative set of serving targets.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub targets: Vec<FleetTarget>,
}

/// Named cluster presets (`fleet:<name>`), mirroring the topology-preset
/// idiom: each expands to a member list in the same grammar.
pub const PRESETS: &[(&str, &str)] = &[
    ("small", "gpu-explicit:pcie:cyclic*2"),
    (
        "hetero",
        "gpu-explicit:nvlink:cyclic,gpu-explicit:pcie:cyclic,knl-cache-tiled",
    ),
    (
        "sharded",
        "gpu-explicit:nvlink:cyclic:x2,gpu-explicit:pcie:cyclic",
    ),
    ("tuned-pair", "gpu-explicit:pcie:cyclic:tuned*2"),
];

impl Cluster {
    /// Parse a cluster spec: an optional `fleet:` prefix, then either a
    /// preset name from [`PRESETS`] or a comma-separated member list
    /// with optional `*<count>` multiplicities.
    pub fn parse(spec: &str) -> crate::Result<Cluster> {
        let body = spec.strip_prefix("fleet:").unwrap_or(spec);
        let body = match PRESETS.iter().find(|(name, _)| *name == body) {
            Some((_, expansion)) => expansion,
            None => body,
        };
        crate::ensure!(!body.is_empty(), "empty fleet spec");
        let mut targets = Vec::new();
        for member in body.split(',') {
            let (member, count) = match member.rsplit_once('*') {
                Some((m, digits)) => {
                    let n: usize = digits.parse().map_err(|_| {
                        crate::err!("bad multiplicity {digits:?} in fleet member {member:?}")
                    })?;
                    crate::ensure!(
                        (1..=64).contains(&n),
                        "fleet member multiplicity {n} out of range (1..=64)"
                    );
                    (m, n)
                }
                None => (member, 1),
            };
            for _ in 0..count {
                targets.push(FleetTarget::parse(targets.len(), member)?);
            }
        }
        crate::ensure!(targets.len() <= 256, "fleet too large (max 256 targets)");
        Ok(Cluster { targets })
    }

    /// Canonical member list (multiplicity expanded; parseable by
    /// [`Cluster::parse`]).
    pub fn spec(&self) -> String {
        let members: Vec<&str> = self.targets.iter().map(|t| t.spec.as_str()).collect();
        format!("fleet:{}", members.join(","))
    }

    pub fn len(&self) -> usize {
        self.targets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.targets.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn member_list_with_multiplicity_expands() {
        let c = Cluster::parse("fleet:gpu-explicit:pcie:cyclic*2,knl-cache-tiled").unwrap();
        assert_eq!(c.len(), 3);
        assert_eq!(c.targets[0].spec, c.targets[1].spec);
        assert_eq!(c.targets[2].spec, "knl-cache-tiled");
        assert_eq!(c.targets[0].id, 0);
        assert_eq!(c.targets[2].id, 2);
        // canonical spec reparses to the same cluster
        let c2 = Cluster::parse(&c.spec()).unwrap();
        assert_eq!(c2.len(), 3);
        assert_eq!(c2.targets[2].spec, c.targets[2].spec);
    }

    #[test]
    fn presets_expand_and_tuned_members_carry_the_flag() {
        for (name, _) in PRESETS {
            let c = Cluster::parse(&format!("fleet:{name}")).unwrap();
            assert!(!c.is_empty(), "{name}");
        }
        let c = Cluster::parse("tuned-pair").unwrap();
        assert_eq!(c.len(), 2);
        assert!(c.targets.iter().all(|t| t.tuned));
    }

    #[test]
    fn tiers_members_with_plus_and_colon_parse_inside_a_list() {
        let c = Cluster::parse(
            "fleet:tiers:hbm=1m@509.7+host=inf@11:cyclic,gpu-explicit:nvlink:cyclic:fuse4",
        )
        .unwrap();
        assert_eq!(c.len(), 2);
        assert!(c.targets[0].target.tiered().is_some());
        assert_eq!(c.targets[1].fuse, 4);
    }

    #[test]
    fn bad_specs_are_rejected() {
        assert!(Cluster::parse("").is_err());
        assert!(Cluster::parse("fleet:no-such-platform").is_err());
        assert!(Cluster::parse("fleet:knl-cache-tiled*0").is_err());
        assert!(Cluster::parse("fleet:knl-cache-tiled*banana").is_err());
        // fuse0 (tuner-chosen depth) is not a pinnable member option
        assert!(Cluster::parse("fleet:gpu-explicit:pcie:cyclic:fuse0").is_err());
    }

    #[test]
    fn degrade_redecomposes_onto_survivors() {
        let c = Cluster::parse("fleet:gpu-explicit:pcie:cyclic:x3").unwrap();
        let d = c.targets[0].degrade().unwrap();
        assert_eq!(d.target.ranks(), 2);
        let dd = d.degrade().unwrap();
        assert_eq!(dd.target.ranks(), 1, "one survivor collapses to single-device");
        assert!(dd.degrade().is_err(), "nothing left to re-decompose onto");
        // an unsharded member cannot degrade
        let single = Cluster::parse("fleet:knl-cache-tiled").unwrap();
        assert!(single.targets[0].degrade().is_err());
    }
}
