//! Minimal error plumbing with an `anyhow`-shaped surface.
//!
//! The build environment is hermetic (no crates.io access), so the crate
//! carries its own string-based error type plus the three macros the code
//! base actually uses ([`err!`](crate::err), [`bail!`](crate::bail),
//! [`ensure!`](crate::ensure)) and a [`Context`] extension trait.

use std::fmt;

/// A string-backed error with an optional context chain.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }

    /// Prepend a context line (the `anyhow` chain rendered flat).
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Error {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error::msg(s)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e.to_string())
    }
}

/// Crate-wide result type.
pub type Result<T> = std::result::Result<T, Error>;

/// Construct an [`Error`] from a format string (the `anyhow!` analogue).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::errors::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

/// `anyhow::Context`-style extension for results and options.
pub trait Context<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("bad {}", 7)
    }

    #[test]
    fn bail_formats() {
        assert_eq!(fails().unwrap_err().to_string(), "bad 7");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).unwrap_err().to_string().contains("-1"));
    }

    #[test]
    fn context_wraps() {
        let e: Result<()> = Err(err!("inner")).context("outer");
        assert_eq!(e.unwrap_err().to_string(), "outer: inner");
        let o: Result<i32> = None.context("missing");
        assert_eq!(o.unwrap_err().to_string(), "missing");
    }
}
