//! Link codecs: modelled compression on tier-boundary and inter-rank
//! traffic.
//!
//! Out-of-core runs are bandwidth-bound on their slowest link; Shen et
//! al. (arXiv 2204.11315) show GPU stencil state compresses 2–5× with
//! error-bounded lossy codecs, turning the host boundary from a wall
//! into a stream. A [`CodecSpec`] attaches to one link of a
//! [`crate::topology::Topology`] (the `~c:` tier annotation) or to the
//! inter-rank interconnect and describes three modelled quantities:
//!
//! * **ratio** — logical bytes per wire byte (`wire = ceil(bytes/ratio)`);
//! * **compress / decompress throughput** (GB/s) — the codec kernels'
//!   achieved rates, paid on a dedicated per-link `codec` timeline
//!   stream so they overlap transfers and compute like every other
//!   stream, and so [`crate::exec::Metrics::bound`] can attribute a run
//!   as *codec-bound* when the codec kernels, not the wire, dominate;
//! * an optional **read-only ratio** — halo exchanges and read-only
//!   uploads ship immutable data, which typically compresses better;
//!   when set, those paths use it instead of `ratio`.
//!
//! The codec is a *timeline and byte-ledger model only*: numerics are
//! untouched by construction, and a `ratio = 1.0` codec is bit-identical
//! (clocks, bytes, ledger) to no codec at all — engines bypass the codec
//! path entirely for [`CodecSpec::is_identity`] specs.

use crate::memory::calib_util::GB;

/// Default modelled compression throughput, GB/s (cuZFP-class fixed-rate
/// kernel on a V100-generation part).
pub const DEFAULT_COMPRESS_GBS: f64 = 50.0;
/// Default modelled decompression throughput, GB/s.
pub const DEFAULT_DECOMPRESS_GBS: f64 = 80.0;

/// One link's compression model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CodecSpec {
    /// Logical-to-wire byte ratio (≥ 1.0; 1.0 = identity).
    pub ratio: f64,
    /// Compression throughput over the logical bytes, GB/s.
    pub compress_gbs: f64,
    /// Decompression throughput over the logical bytes, GB/s.
    pub decompress_gbs: f64,
    /// Ratio override for read-only data (halo planes, read-only
    /// uploads); `None` falls back to `ratio`.
    pub ro_ratio: Option<f64>,
}

impl CodecSpec {
    /// A codec with the default throughput calibration.
    pub const fn new(ratio: f64) -> Self {
        CodecSpec {
            ratio,
            compress_gbs: DEFAULT_COMPRESS_GBS,
            decompress_gbs: DEFAULT_DECOMPRESS_GBS,
            ro_ratio: None,
        }
    }

    /// ZFP fixed-accuracy calibration: Shen et al. report 2–5×
    /// compression on out-of-core GPU stencil state; 3.5 is the midpoint
    /// of their reported band, throughputs at the defaults.
    pub const ZFP: CodecSpec = CodecSpec::new(3.5);

    /// Validate the spec's numerics; errors name the offending field.
    pub fn validate(&self) -> crate::Result<()> {
        crate::ensure!(
            self.ratio.is_finite() && self.ratio >= 1.0,
            "codec ratio {} must be a finite value >= 1.0",
            self.ratio
        );
        crate::ensure!(
            self.compress_gbs.is_finite() && self.compress_gbs > 0.0,
            "codec compress throughput {} GB/s must be finite and positive",
            self.compress_gbs
        );
        crate::ensure!(
            self.decompress_gbs.is_finite() && self.decompress_gbs > 0.0,
            "codec decompress throughput {} GB/s must be finite and positive",
            self.decompress_gbs
        );
        if let Some(ro) = self.ro_ratio {
            crate::ensure!(
                ro.is_finite() && ro >= 1.0,
                "codec read-only ratio {ro} must be a finite value >= 1.0"
            );
        }
        Ok(())
    }

    /// Whether this codec changes nothing: engines skip the codec path
    /// entirely (bit-identical to no codec).
    pub fn is_identity(&self) -> bool {
        self.ratio == 1.0 && self.ro_ratio.map_or(true, |r| r == 1.0)
    }

    /// The ratio applied to a transfer; read-only data may use the
    /// override.
    pub fn ratio_for(&self, read_only: bool) -> f64 {
        if read_only {
            self.ro_ratio.unwrap_or(self.ratio)
        } else {
            self.ratio
        }
    }

    /// Bytes on the wire for `bytes` logical bytes (0 stays 0; anything
    /// else compresses to at least one byte).
    pub fn wire_bytes(&self, bytes: u64) -> u64 {
        self.wire_bytes_for(bytes, false)
    }

    /// [`CodecSpec::wire_bytes`] with the read-only ratio selection.
    pub fn wire_bytes_for(&self, bytes: u64, read_only: bool) -> u64 {
        if bytes == 0 {
            return 0;
        }
        ((bytes as f64 / self.ratio_for(read_only)).ceil() as u64).max(1)
    }

    /// Time the compression kernel occupies the codec stream, seconds.
    pub fn compress_time_s(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            bytes as f64 / (self.compress_gbs * GB)
        }
    }

    /// Time the decompression kernel occupies the codec stream, seconds.
    pub fn decompress_time_s(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            bytes as f64 / (self.decompress_gbs * GB)
        }
    }

    /// Parse the value grammar shared by the `~c:` tier annotation, the
    /// `codec` spec token and the `--codec` flag:
    ///
    /// ```text
    /// <ratio>                          e.g. 3.5
    /// <ratio>@<cgbs>/<dgbs>            e.g. 3.5@50/80
    /// <ratio>@<cgbs>/<dgbs>/<ro>       e.g. 3.5@50/80/5
    /// ```
    pub fn parse(tok: &str) -> crate::Result<CodecSpec> {
        let bad = |what: &str| crate::err!("codec spec {tok:?}: bad {what}");
        let (ratio_str, rest) = match tok.split_once('@') {
            Some((r, rest)) => (r, Some(rest)),
            None => (tok, None),
        };
        let ratio: f64 = ratio_str.parse().map_err(|_| bad("ratio"))?;
        let mut spec = CodecSpec::new(ratio);
        if let Some(rest) = rest {
            let mut parts = rest.split('/');
            let c = parts.next().ok_or_else(|| bad("throughputs"))?;
            let d = parts
                .next()
                .ok_or_else(|| crate::err!("codec spec {tok:?}: expected <cgbs>/<dgbs> after '@'"))?;
            spec.compress_gbs = c.parse().map_err(|_| bad("compress throughput"))?;
            spec.decompress_gbs = d.parse().map_err(|_| bad("decompress throughput"))?;
            if let Some(ro) = parts.next() {
                spec.ro_ratio = Some(ro.parse().map_err(|_| bad("read-only ratio"))?);
            }
            crate::ensure!(
                parts.next().is_none(),
                "codec spec {tok:?}: too many '/' segments"
            );
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Exact inverse of [`CodecSpec::parse`]: the short form when the
    /// throughputs are at the defaults and no read-only override is set,
    /// the long form otherwise.
    pub fn render(&self) -> String {
        let default_tp = self.compress_gbs == DEFAULT_COMPRESS_GBS
            && self.decompress_gbs == DEFAULT_DECOMPRESS_GBS;
        match (default_tp, self.ro_ratio) {
            (true, None) => format!("{}", self.ratio),
            (_, None) => format!("{}@{}/{}", self.ratio, self.compress_gbs, self.decompress_gbs),
            (_, Some(ro)) => format!(
                "{}@{}/{}/{}",
                self.ratio, self.compress_gbs, self.decompress_gbs, ro
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_and_long_forms_round_trip() {
        let cases = [
            CodecSpec::new(3.5),
            CodecSpec::new(1.0),
            CodecSpec {
                ratio: 2.25,
                compress_gbs: 12.5,
                decompress_gbs: 40.0,
                ro_ratio: None,
            },
            CodecSpec {
                ratio: 4.0,
                compress_gbs: 50.0,
                decompress_gbs: 80.0,
                ro_ratio: Some(6.5),
            },
        ];
        for c in cases {
            let s = c.render();
            let p = CodecSpec::parse(&s).unwrap_or_else(|e| panic!("{s}: {e}"));
            assert_eq!(p, c, "{s}");
        }
        // the ro form always renders long (throughputs included) so the
        // slash positions stay unambiguous
        assert_eq!(cases[3].render(), "4@50/80/6.5");
        assert_eq!(cases[0].render(), "3.5");
    }

    #[test]
    fn malformed_specs_are_typed_errors() {
        for bad in ["", "x", "0.5", "-3", "3.5@", "3.5@50", "3.5@a/b", "3.5@50/0", "3.5@50/80/0.2", "3.5@50/80/5/9", "inf", "nan"] {
            let e = CodecSpec::parse(bad);
            assert!(e.is_err(), "{bad:?} must be rejected");
            let msg = e.unwrap_err().to_string();
            assert!(msg.contains("codec"), "{bad:?}: {msg}");
        }
    }

    #[test]
    fn wire_bytes_and_times() {
        let c = CodecSpec::new(3.5);
        assert_eq!(c.wire_bytes(0), 0);
        assert_eq!(c.wire_bytes(1), 1);
        assert_eq!(c.wire_bytes(35), 10);
        assert_eq!(c.wire_bytes(36), 11, "wire bytes round up");
        assert_eq!(c.compress_time_s(0), 0.0);
        let t = c.compress_time_s(50_000_000_000);
        assert!((t - 1.0).abs() < 1e-12, "{t}");
        let t = c.decompress_time_s(80_000_000_000);
        assert!((t - 1.0).abs() < 1e-12, "{t}");
    }

    #[test]
    fn identity_and_read_only_selection() {
        assert!(CodecSpec::new(1.0).is_identity());
        assert!(!CodecSpec::new(1.5).is_identity());
        let mut c = CodecSpec::new(1.0);
        c.ro_ratio = Some(2.0);
        assert!(!c.is_identity(), "an ro override is not identity");
        let z = CodecSpec {
            ro_ratio: Some(7.0),
            ..CodecSpec::ZFP
        };
        assert_eq!(z.ratio_for(false), 3.5);
        assert_eq!(z.ratio_for(true), 7.0);
        assert_eq!(z.wire_bytes_for(70, true), 10);
        assert_eq!(CodecSpec::ZFP.ratio_for(true), 3.5, "no override falls back");
    }

    #[test]
    fn zfp_preset_is_valid() {
        CodecSpec::ZFP.validate().unwrap();
        assert_eq!(CodecSpec::ZFP.ratio, 3.5);
    }
}
