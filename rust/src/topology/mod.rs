//! Declarative memory-topology descriptions.
//!
//! The paper's evaluation hardcodes two-level memory pairings —
//! MCDRAM/DDR4 on KNL, HBM/host over PCIe or NVLink on the P100 — and
//! the reproduction's `Platform` enum mirrored that closure: every new
//! machine needed a new enum variant and a new engine. This module
//! opens the space up by making the platform *data*:
//!
//! * a [`Tier`] is one level of the memory hierarchy — a name, a
//!   capacity and a streaming bandwidth;
//! * a [`LinkSpec`] is the edge between two adjacent tiers (achieved
//!   bandwidth + per-transfer launch latency). It subsumes the two
//!   previously duplicated interconnect notions,
//!   [`crate::memory::Link`] (host↔device) and
//!   [`crate::distributed::Interconnect`] (rank↔rank), both of which
//!   are now thin shims over the constants here;
//! * a [`Topology`] is an ordered stack of tiers, fastest first, with
//!   one link per adjacent pair.
//!
//! Topologies come from three places: the [`presets`] that reproduce
//! the paper's calibrated machines exactly (`knl`,
//! `gpu-explicit-pcie`, `gpu-explicit-nvlink`, `unified-pcie`,
//! `unified-nvlink`, `plain`), the compact [`spec`] grammar for custom
//! stacks (`tiers:hbm=16g@509.7+host=48g@11~0.00001+nvme=inf@6~0.00002`),
//! and code ([`Topology::new`]). [`Topology::spec`] renders the
//! canonical spec string, which round-trips through
//! [`crate::coordinator::Config::parse_spec`].
//!
//! The generic [`crate::memory::TieredEngine`] lowers *any* valid
//! topology onto the discrete-event timeline by applying the paper's
//! Algorithm-1 tiling recursively at every capacity boundary — so a
//! three-tier HBM→host→NVMe stack models problems larger than host
//! DRAM, extending the paper's "beyond 16 GB" to "beyond DRAM".

pub mod presets;
pub mod spec;

pub use presets::{preset, presets};

use crate::codec::CodecSpec;
use crate::memory::calib_util::GB;

/// Default per-transfer launch latency of a link the spec grammar
/// leaves unannotated (the paper's measured PCIe launch cost).
pub const DEFAULT_LINK_LATENCY_S: f64 = 10e-6;

/// One memory tier: a named level of the hierarchy.
#[derive(Debug, Clone, PartialEq)]
pub struct Tier {
    /// Short identifier (`hbm`, `host`, `nvme`, …). Must be unique
    /// within a topology and stick to `[A-Za-z0-9_-]` so it survives
    /// the spec grammar.
    pub name: String,
    /// Capacity in bytes; `None` = unbounded. Only the last (slowest)
    /// tier of a topology may be unbounded — every other tier is a
    /// capacity boundary the tiler must respect.
    pub capacity_bytes: Option<u64>,
    /// Achieved streaming bandwidth, GB/s. For the fastest tier this is
    /// the device-local copy bandwidth (tile edge copies); for lower
    /// tiers it is the achieved bandwidth of the link into the tier
    /// above (the spec grammar derives [`LinkSpec`] edges from it).
    pub bw_gbs: f64,
}

impl Tier {
    pub fn new(name: &str, capacity_bytes: Option<u64>, bw_gbs: f64) -> Self {
        Tier {
            name: name.to_string(),
            capacity_bytes,
            bw_gbs,
        }
    }
}

/// One interconnect edge: achieved bandwidth plus per-transfer launch
/// latency. The unified replacement for the duplicated
/// `memory::hierarchy::Link` / `distributed::interconnect::Interconnect`
/// calibrations — both enums now delegate here (see the constants).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    /// Achieved bandwidth per direction, GB/s.
    pub bw_gbs: f64,
    /// Per-transfer launch latency, seconds.
    pub latency_s: f64,
}

impl LinkSpec {
    pub const fn new(bw_gbs: f64, latency_s: f64) -> Self {
        LinkSpec { bw_gbs, latency_s }
    }

    /// PCIe gen3 x16 host link — the paper measures ~11 GB/s achieved.
    pub const PCIE_HOST: LinkSpec = LinkSpec::new(11.0, 10e-6);
    /// NVLink 1.0 to a Power8 host — ~30 GB/s achieved.
    pub const NVLINK_HOST: LinkSpec = LinkSpec::new(30.0, 8e-6);
    /// PCIe gen3 peer-to-peer between GPUs under one switch.
    pub const PCIE_PEER: LinkSpec = LinkSpec::new(10.0, 10e-6);
    /// NVLink 1.0 peer connection.
    pub const NVLINK_PEER: LinkSpec = LinkSpec::new(35.0, 8e-6);
    /// Inter-node EDR InfiniBand.
    pub const INFINIBAND: LinkSpec = LinkSpec::new(12.0, 2e-6);

    /// Time to move `bytes` in one transfer (0 for no bytes — the same
    /// contract the legacy `Link::time_s` had).
    pub fn time_s(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            self.latency_s + bytes as f64 / (self.bw_gbs * GB)
        }
    }
}

/// An ordered memory-tier stack, fastest tier first, with one
/// [`LinkSpec`] per adjacent pair (`links()[i]` connects tier `i` to
/// tier `i + 1`). Construction validates the stack, so every held
/// `Topology` is well-formed.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    /// Preset name when this topology is one of [`presets`]; `None`
    /// for custom stacks. Cosmetic — equality of stacks is what
    /// [`Topology::same_stack`] compares.
    pub name: Option<String>,
    tiers: Vec<Tier>,
    links: Vec<LinkSpec>,
    /// Per-link compression models (`codecs[i]` rides on `links[i]`);
    /// `None` everywhere unless the spec grammar's `~c:` annotation or
    /// [`Topology::with_codecs`] attached one.
    codecs: Vec<Option<CodecSpec>>,
}

/// Upper bound on tier count — enough for any plausible machine while
/// keeping degenerate specs (and the recursion depth under
/// `TieredEngine`) bounded.
pub const MAX_TIERS: usize = 8;

impl Topology {
    /// Validate and build a topology. Typed [`crate::errors`] errors
    /// name the offending tier:
    ///
    /// * 1..=[`MAX_TIERS`] tiers, names unique, non-empty and limited
    ///   to `[A-Za-z0-9_-]`;
    /// * capacities non-zero; only the last tier may be unbounded;
    /// * bandwidths finite and positive; link latencies finite, ≥ 0;
    /// * exactly one link per adjacent tier pair.
    pub fn new(name: Option<&str>, tiers: Vec<Tier>, links: Vec<LinkSpec>) -> crate::Result<Self> {
        crate::ensure!(!tiers.is_empty(), "a topology needs at least one tier");
        crate::ensure!(
            tiers.len() <= MAX_TIERS,
            "too many tiers: {} (max {MAX_TIERS})",
            tiers.len()
        );
        crate::ensure!(
            links.len() + 1 == tiers.len(),
            "a {}-tier stack needs {} link(s), got {}",
            tiers.len(),
            tiers.len() - 1,
            links.len()
        );
        for (i, t) in tiers.iter().enumerate() {
            crate::ensure!(!t.name.is_empty(), "tier {i} has an empty name");
            crate::ensure!(
                t.name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-'),
                "tier name {:?} has characters outside [A-Za-z0-9_-]",
                t.name
            );
            crate::ensure!(
                tiers[..i].iter().all(|p| p.name != t.name),
                "duplicate tier name {:?}",
                t.name
            );
            crate::ensure!(
                t.capacity_bytes != Some(0),
                "tier {:?}: zero capacity",
                t.name
            );
            crate::ensure!(
                t.capacity_bytes.is_some() || i + 1 == tiers.len(),
                "tier {:?} is unbounded but not the last tier (every boundary above \
                 the home tier must be a finite capacity)",
                t.name
            );
            crate::ensure!(
                t.bw_gbs.is_finite() && t.bw_gbs > 0.0,
                "tier {:?}: bandwidth must be a positive finite GB/s figure, got {}",
                t.name,
                t.bw_gbs
            );
        }
        for (i, l) in links.iter().enumerate() {
            crate::ensure!(
                l.bw_gbs.is_finite() && l.bw_gbs > 0.0,
                "link {}→{}: bandwidth must be a positive finite GB/s figure, got {}",
                tiers[i + 1].name,
                tiers[i].name,
                l.bw_gbs
            );
            crate::ensure!(
                l.latency_s.is_finite() && l.latency_s >= 0.0,
                "link {}→{}: latency must be finite and non-negative, got {}",
                tiers[i + 1].name,
                tiers[i].name,
                l.latency_s
            );
            // The spec grammar derives a link's bandwidth from the
            // lower tier's `@bw`; enforcing the same identity here
            // keeps `Topology::spec()` a faithful description of every
            // constructible topology (render→parse is exact).
            crate::ensure!(
                l.bw_gbs == tiers[i + 1].bw_gbs,
                "link {}→{}: bandwidth {} must equal tier {:?}'s bandwidth {} (the \
                 grammar derives links from the lower tier's @bw — set it there)",
                tiers[i + 1].name,
                tiers[i].name,
                l.bw_gbs,
                tiers[i + 1].name,
                tiers[i + 1].bw_gbs
            );
        }
        let codecs = vec![None; links.len()];
        Ok(Topology {
            name: name.map(str::to_string),
            tiers,
            links,
            codecs,
        })
    }

    /// Attach per-link codecs (one slot per link; `None` = uncompressed
    /// link). Validates every spec; errors name the link.
    pub fn with_codecs(mut self, codecs: Vec<Option<CodecSpec>>) -> crate::Result<Self> {
        crate::ensure!(
            codecs.len() == self.links.len(),
            "a {}-link stack needs {} codec slot(s), got {}",
            self.links.len(),
            self.links.len(),
            codecs.len()
        );
        for (i, c) in codecs.iter().enumerate() {
            if let Some(c) = c {
                c.validate().map_err(|e| {
                    crate::err!(
                        "link {}→{}: {e}",
                        self.tiers[i + 1].name,
                        self.tiers[i].name
                    )
                })?;
            }
        }
        self.codecs = codecs;
        Ok(self)
    }

    /// Attach `codec` to every link (the `codec` spec token / `--codec`
    /// flag semantics). Errors on single-tier stacks (no links) and when
    /// the `~c:` grammar already attached a codec somewhere — the two
    /// sources must not silently override each other.
    pub fn with_codec_all(&self, codec: CodecSpec) -> crate::Result<Self> {
        crate::ensure!(
            !self.links.is_empty(),
            "topology {:?} has a single tier — no links to attach a codec to",
            self.label()
        );
        crate::ensure!(
            !self.has_codec(),
            "topology {:?} already carries a ~c: codec in its tiers: spec; \
             drop the codec token/flag or the tier annotation",
            self.label()
        );
        self.clone().with_codecs(vec![Some(codec); self.links.len()])
    }

    /// The same stack with every codec removed (the tuner's codec-off
    /// candidate).
    pub fn without_codecs(&self) -> Self {
        let mut t = self.clone();
        t.codecs = vec![None; t.links.len()];
        t
    }

    /// Build a stack whose links are derived from the lower tiers'
    /// bandwidths (the spec-grammar convention): `links[i]` gets
    /// `tiers[i + 1].bw_gbs` and `latencies[i]` (one entry per link).
    pub fn from_tiers(
        name: Option<&str>,
        tiers: Vec<Tier>,
        latencies: &[f64],
    ) -> crate::Result<Self> {
        crate::ensure!(
            !tiers.is_empty() && latencies.len() + 1 == tiers.len(),
            "a {}-tier stack needs {} link latencies, got {}",
            tiers.len(),
            tiers.len().max(1) - 1,
            latencies.len()
        );
        let links = tiers
            .iter()
            .skip(1)
            .zip(latencies)
            .map(|(t, lat)| LinkSpec::new(t.bw_gbs, *lat))
            .collect();
        Self::new(name, tiers, links)
    }

    pub fn num_tiers(&self) -> usize {
        self.tiers.len()
    }

    pub fn tiers(&self) -> &[Tier] {
        &self.tiers
    }

    pub fn links(&self) -> &[LinkSpec] {
        &self.links
    }

    pub fn tier(&self, i: usize) -> &Tier {
        &self.tiers[i]
    }

    /// The link between tier `i` (faster) and tier `i + 1` (slower).
    pub fn link(&self, i: usize) -> LinkSpec {
        self.links[i]
    }

    /// The codec riding on link `i`, if any (out-of-range is `None`).
    pub fn codec(&self, i: usize) -> Option<CodecSpec> {
        self.codecs.get(i).copied().flatten()
    }

    /// All per-link codec slots (`codecs()[i]` rides on `links()[i]`).
    pub fn codecs(&self) -> &[Option<CodecSpec>] {
        &self.codecs
    }

    /// Whether any link carries a codec.
    pub fn has_codec(&self) -> bool {
        self.codecs.iter().any(Option::is_some)
    }

    /// The fastest (compute-adjacent) tier.
    pub fn fastest(&self) -> &Tier {
        &self.tiers[0]
    }

    /// The slowest tier — where data lives at rest.
    pub fn home(&self) -> &Tier {
        self.tiers.last().expect("validated: at least one tier")
    }

    /// Whether a problem of `bytes` fits the home tier at all.
    pub fn fits(&self, bytes: u64) -> bool {
        match self.home().capacity_bytes {
            None => true,
            Some(cap) => bytes <= cap,
        }
    }

    /// Human label: the tier names joined fastest→slowest
    /// (`hbm+host+nvme`).
    pub fn label(&self) -> String {
        self.tiers
            .iter()
            .map(|t| t.name.as_str())
            .collect::<Vec<_>>()
            .join("+")
    }

    /// The canonical spec string: `tiers:<preset-name>` when this is an
    /// unmodified preset, the full tier grammar otherwise. Round-trips
    /// through [`crate::coordinator::Config::parse_spec`] either way.
    pub fn spec(&self) -> String {
        if let Some(n) = &self.name {
            if presets::preset(n).as_ref() == Some(self) {
                return format!("tiers:{n}");
            }
        }
        self.spec_full()
    }

    /// The full tier grammar, numbers spelled out (what
    /// `--list-platforms` shows so users can copy and edit a preset).
    pub fn spec_full(&self) -> String {
        spec::render(self)
    }

    /// Structural equality: same tiers, links and codecs,
    /// names-of-the-stack included but the cosmetic preset
    /// [`Topology::name`] ignored.
    pub fn same_stack(&self, other: &Topology) -> bool {
        self.tiers == other.tiers && self.links == other.links && self.codecs == other.codecs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hbm_host() -> Topology {
        Topology::new(
            None,
            vec![
                Tier::new("hbm", Some(16 << 30), 509.7),
                Tier::new("host", None, 11.0),
            ],
            vec![LinkSpec::PCIE_HOST],
        )
        .unwrap()
    }

    #[test]
    fn linkspec_time_matches_legacy_formula() {
        let t = LinkSpec::PCIE_HOST.time_s(11_000_000_000);
        assert!((t - (1.0 + 10e-6)).abs() < 1e-9);
        assert_eq!(LinkSpec::PCIE_HOST.time_s(0), 0.0);
        assert!(LinkSpec::NVLINK_HOST.bw_gbs > LinkSpec::PCIE_HOST.bw_gbs);
        assert!(LinkSpec::INFINIBAND.latency_s < LinkSpec::PCIE_PEER.latency_s);
    }

    #[test]
    fn validation_rejects_malformed_stacks() {
        // zero capacity
        let e = Topology::new(
            None,
            vec![Tier::new("a", Some(0), 10.0), Tier::new("b", None, 1.0)],
            vec![LinkSpec::new(1.0, 0.0)],
        )
        .unwrap_err();
        assert!(e.to_string().contains("zero capacity"), "{e}");
        // duplicate names
        let e = Topology::new(
            None,
            vec![
                Tier::new("x", Some(1), 10.0),
                Tier::new("x", None, 1.0),
            ],
            vec![LinkSpec::new(1.0, 0.0)],
        )
        .unwrap_err();
        assert!(e.to_string().contains("duplicate tier name"), "{e}");
        // unbounded middle tier
        let e = Topology::new(
            None,
            vec![
                Tier::new("a", Some(1), 10.0),
                Tier::new("b", None, 5.0),
                Tier::new("c", None, 1.0),
            ],
            vec![LinkSpec::new(5.0, 0.0), LinkSpec::new(1.0, 0.0)],
        )
        .unwrap_err();
        assert!(e.to_string().contains("unbounded"), "{e}");
        // wrong link count
        assert!(Topology::new(None, vec![Tier::new("a", None, 1.0)], vec![LinkSpec::PCIE_HOST])
            .is_err());
        // bad bandwidth
        let e = Topology::new(
            None,
            vec![Tier::new("a", Some(1), 0.0), Tier::new("b", None, 1.0)],
            vec![LinkSpec::new(1.0, 0.0)],
        )
        .unwrap_err();
        assert!(e.to_string().contains("bandwidth"), "{e}");
        // bad name characters
        assert!(Topology::new(
            None,
            vec![Tier::new("a=b", Some(1), 1.0), Tier::new("c", None, 1.0)],
            vec![LinkSpec::new(1.0, 0.0)],
        )
        .is_err());
        // link bandwidth must be the lower tier's bandwidth, or the
        // rendered spec would misdescribe the modelled machine
        let e = Topology::new(
            None,
            vec![Tier::new("a", Some(1), 10.0), Tier::new("b", None, 5.0)],
            vec![LinkSpec::new(3.0, 0.0)],
        )
        .unwrap_err();
        assert!(e.to_string().contains("must equal tier"), "{e}");
    }

    #[test]
    fn accessors_and_fits() {
        let t = hbm_host();
        assert_eq!(t.num_tiers(), 2);
        assert_eq!(t.fastest().name, "hbm");
        assert_eq!(t.home().name, "host");
        assert!(t.fits(u64::MAX), "unbounded home tier fits anything");
        assert_eq!(t.label(), "hbm+host");
        assert_eq!(t.link(0), LinkSpec::PCIE_HOST);

        let bounded = Topology::new(
            None,
            vec![
                Tier::new("hbm", Some(16 << 30), 509.7),
                Tier::new("nvme", Some(1 << 40), 6.0),
            ],
            vec![LinkSpec::new(6.0, 20e-6)],
        )
        .unwrap();
        assert!(bounded.fits(1 << 40));
        assert!(!bounded.fits((1 << 40) + 1));
    }

    #[test]
    fn codec_attachment_and_removal() {
        use crate::codec::CodecSpec;
        let t = hbm_host();
        assert!(!t.has_codec());
        assert_eq!(t.codec(0), None);
        assert_eq!(t.codec(99), None, "out of range is None, not a panic");

        let c = t.with_codec_all(CodecSpec::ZFP).unwrap();
        assert!(c.has_codec());
        assert_eq!(c.codec(0), Some(CodecSpec::ZFP));
        assert_eq!(c.codecs(), &[Some(CodecSpec::ZFP)]);
        assert!(!t.same_stack(&c), "codecs are part of the stack identity");
        assert!(c.without_codecs().same_stack(&t));

        // double attachment is a conflict, not a silent override
        let e = c.with_codec_all(CodecSpec::new(2.0)).unwrap_err();
        assert!(e.to_string().contains("already carries"), "{e}");

        // wrong slot count and invalid specs are typed errors
        let e = t.clone().with_codecs(vec![]).unwrap_err();
        assert!(e.to_string().contains("codec slot"), "{e}");
        let e = t
            .clone()
            .with_codecs(vec![Some(CodecSpec::new(0.5))])
            .unwrap_err();
        assert!(e.to_string().contains("host→hbm"), "{e}");

        // single-tier stacks have no links to compress
        let solo = Topology::new(None, vec![Tier::new("ddr", None, 90.0)], vec![]).unwrap();
        let e = solo.with_codec_all(CodecSpec::ZFP).unwrap_err();
        assert!(e.to_string().contains("single tier"), "{e}");
    }

    #[test]
    fn same_stack_ignores_cosmetic_name() {
        let a = hbm_host();
        let mut b = a.clone();
        b.name = Some("custom".into());
        assert!(a.same_stack(&b));
        assert_ne!(a, b, "full equality still sees the name");
    }
}
