//! The compact tier-stack spec grammar.
//!
//! ```text
//! stack    := <preset-name> | tier ( "+" tier )+
//! tier     := name "=" capacity "@" bw ( "~" annot )*
//! annot    := latency | "c:" codec
//! capacity := <integer>[k|m|g|t]        (binary suffixes: k=2^10 … t=2^40)
//!           | inf                       (unbounded; last tier only)
//! bw       := <float>                   (achieved GB/s)
//! latency  := <float>                   (seconds; the link INTO the tier
//!                                        above — not allowed on the first
//!                                        tier, defaults to 10e-6)
//! codec    := <ratio>[@<cgbs>/<dgbs>[/<ro>]]   (see [`crate::codec`];
//!                                        annotates the same link as the
//!                                        latency, so not on the first
//!                                        tier either)
//! ```
//!
//! Examples (all as the `:`-separated platform-spec token after the
//! `tiers` head, e.g. `--platform tiers:knl`):
//!
//! * `tiers:knl` — a [`super::presets`] name;
//! * `tiers:hbm=16g@509.7+host=inf@11` — today's P100/PCIe machine;
//! * `tiers:hbm=16g@509.7+host=48g@11~0.00001+nvme=inf@6~0.00002` — a
//!   three-tier stack that keeps computing past host DRAM;
//! * `tiers:hbm=16g@509.7+host=512g@11~c:3.5` — PCIe host link with a
//!   3.5× codec at default compress/decompress throughputs
//!   (`~c:3.5@50/80` spells them out).
//!
//! [`render`] is the exact inverse: capacities print with the largest
//! exact binary suffix, floats with Rust's shortest round-trip
//! formatting, every non-first tier carries its `~latency`, and links
//! with a codec append `~c:<codec>` ([`CodecSpec::render`]), so
//! `parse_stack(render(t))` reproduces `t` tier-for-tier.

use super::{presets, Tier, Topology, DEFAULT_LINK_LATENCY_S};
use crate::codec::CodecSpec;

/// Parse one `tiers:` stack body (the part after the `tiers:` head):
/// either a preset name or a `+`-separated tier list. Malformed tier
/// tokens produce typed [`crate::errors`] errors naming the token.
pub fn parse_stack(stack: &str) -> crate::Result<Topology> {
    if let Some(p) = presets::preset(stack) {
        return Ok(p);
    }
    crate::ensure!(
        !stack.is_empty(),
        "empty tiers: spec (expected a preset name or name=cap@bw+… stack; \
         see --list-platforms)"
    );
    let toks: Vec<&str> = stack.split('+').collect();
    crate::ensure!(
        toks.len() >= 2,
        "single-tier spec {stack:?}: a tier stack needs at least 2 tiers \
         (fastest first; use a preset or a legacy platform head for flat memory)"
    );
    let mut tiers = Vec::with_capacity(toks.len());
    let mut latencies = Vec::with_capacity(toks.len().saturating_sub(1));
    let mut codecs = Vec::with_capacity(toks.len().saturating_sub(1));
    for (i, tok) in toks.iter().enumerate() {
        let (tier, latency, codec) = parse_tier(tok)?;
        match latency {
            Some(lat) => {
                crate::ensure!(
                    i > 0,
                    "tier token {tok:?}: a ~latency annotates the link into the \
                     tier above — the first (fastest) tier has none"
                );
                latencies.push(lat);
            }
            None => {
                if i > 0 {
                    latencies.push(DEFAULT_LINK_LATENCY_S);
                }
            }
        }
        match codec {
            Some(c) => {
                crate::ensure!(
                    i > 0,
                    "tier token {tok:?}: a ~c: codec annotates the link into the \
                     tier above — the first (fastest) tier has none"
                );
                codecs.push(Some(c));
            }
            None => {
                if i > 0 {
                    codecs.push(None);
                }
            }
        }
        // Name collisions get the dedicated message before Topology::new
        // so the error names the offending *token*.
        crate::ensure!(
            tiers.iter().all(|t: &Tier| t.name != tier.name),
            "tier token {tok:?}: duplicate tier name {:?}",
            tier.name
        );
        crate::ensure!(
            tier.capacity_bytes != Some(0),
            "tier token {tok:?}: zero capacity"
        );
        tiers.push(tier);
    }
    Topology::from_tiers(None, tiers, &latencies)?.with_codecs(codecs)
}

/// Parse one `name=capacity@bw[~latency][~c:codec]` token (the two `~`
/// annotations may come in either order).
fn parse_tier(tok: &str) -> crate::Result<(Tier, Option<f64>, Option<CodecSpec>)> {
    let (name, rest) = tok.split_once('=').ok_or_else(|| {
        crate::err!("tier token {tok:?}: expected name=capacity@bw[~latency][~c:codec]")
    })?;
    crate::ensure!(!name.is_empty(), "tier token {tok:?}: empty tier name");
    let (cap_str, rest) = rest
        .split_once('@')
        .ok_or_else(|| crate::err!("tier token {tok:?}: missing @bandwidth"))?;
    // Neither a latency float nor a codec value contains '~', so the
    // annotations split cleanly.
    let mut segs = rest.split('~');
    let bw_str = segs.next().expect("split yields at least one piece");
    let capacity = parse_capacity(tok, cap_str)?;
    let bw: f64 = bw_str
        .parse()
        .map_err(|_| crate::err!("tier token {tok:?}: bad bandwidth {bw_str:?} (GB/s float)"))?;
    let mut latency = None;
    let mut codec = None;
    for seg in segs {
        if let Some(cs) = seg.strip_prefix("c:") {
            crate::ensure!(
                codec.is_none(),
                "tier token {tok:?}: more than one ~c: codec annotation"
            );
            codec = Some(
                CodecSpec::parse(cs)
                    .map_err(|e| crate::err!("tier token {tok:?}: {e}"))?,
            );
        } else {
            crate::ensure!(
                latency.is_none(),
                "tier token {tok:?}: more than one ~latency annotation"
            );
            latency = Some(seg.parse::<f64>().map_err(|_| {
                crate::err!("tier token {tok:?}: bad link latency {seg:?} (seconds, e.g. 0.00001)")
            })?);
        }
    }
    Ok((Tier::new(name, capacity, bw), latency, codec))
}

/// Parse a capacity: decimal integer with an optional binary suffix, or
/// `inf` for unbounded.
fn parse_capacity(tok: &str, s: &str) -> crate::Result<Option<u64>> {
    if s == "inf" {
        return Ok(None);
    }
    crate::ensure!(!s.is_empty(), "tier token {tok:?}: empty capacity");
    let (digits, mult) = match s.chars().last() {
        Some(c) if c.is_ascii_digit() => (s, 1u64),
        Some('k') => (&s[..s.len() - 1], 1u64 << 10),
        Some('m') => (&s[..s.len() - 1], 1u64 << 20),
        Some('g') => (&s[..s.len() - 1], 1u64 << 30),
        Some('t') => (&s[..s.len() - 1], 1u64 << 40),
        Some(c) => crate::bail!(
            "tier token {tok:?}: unknown capacity suffix {c:?} (expected k|m|g|t|inf)"
        ),
        None => unreachable!("guarded by the emptiness check"),
    };
    let n: u64 = digits.parse().map_err(|_| {
        crate::err!("tier token {tok:?}: bad capacity {s:?} (integer with optional k|m|g|t)")
    })?;
    let bytes = n
        .checked_mul(mult)
        .ok_or_else(|| crate::err!("tier token {tok:?}: capacity {s:?} overflows u64 bytes"))?;
    Ok(Some(bytes))
}

/// Render a capacity with the largest exact binary suffix.
fn render_capacity(cap: Option<u64>) -> String {
    match cap {
        None => "inf".into(),
        Some(c) if c > 0 && c % (1 << 40) == 0 => format!("{}t", c >> 40),
        Some(c) if c > 0 && c % (1 << 30) == 0 => format!("{}g", c >> 30),
        Some(c) if c > 0 && c % (1 << 20) == 0 => format!("{}m", c >> 20),
        Some(c) if c > 0 && c % (1 << 10) == 0 => format!("{}k", c >> 10),
        Some(c) => format!("{c}"),
    }
}

/// Render the full canonical spec string (`tiers:` head included) —
/// the exact inverse of [`parse_stack`] modulo the cosmetic preset
/// name.
pub fn render(topo: &Topology) -> String {
    let mut out = String::from("tiers:");
    for (i, t) in topo.tiers().iter().enumerate() {
        if i > 0 {
            out.push('+');
        }
        out.push_str(&t.name);
        out.push('=');
        out.push_str(&render_capacity(t.capacity_bytes));
        out.push('@');
        out.push_str(&format!("{}", t.bw_gbs));
        if i > 0 {
            out.push('~');
            out.push_str(&format!("{}", topo.link(i - 1).latency_s));
            if let Some(c) = topo.codec(i - 1) {
                out.push_str("~c:");
                out.push_str(&c.render());
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::LinkSpec;

    #[test]
    fn three_tier_example_parses() {
        let t = parse_stack("hbm=16g@509.7+host=48g@11~0.00001+nvme=inf@6~0.00002").unwrap();
        assert_eq!(t.num_tiers(), 3);
        assert_eq!(t.tier(0).name, "hbm");
        assert_eq!(t.tier(0).capacity_bytes, Some(16 << 30));
        assert_eq!(t.tier(1).capacity_bytes, Some(48 << 30));
        assert_eq!(t.tier(2).capacity_bytes, None);
        assert_eq!(t.link(0), LinkSpec::new(11.0, 1e-5));
        assert_eq!(t.link(1), LinkSpec::new(6.0, 2e-5));
        assert_eq!(t.label(), "hbm+host+nvme");
    }

    #[test]
    fn default_latency_applies_when_unannotated() {
        let t = parse_stack("hbm=16g@509.7+host=inf@11").unwrap();
        assert_eq!(t.link(0).latency_s, super::DEFAULT_LINK_LATENCY_S);
        assert_eq!(t.link(0).bw_gbs, 11.0);
    }

    #[test]
    fn codec_annotations_parse_in_both_forms_and_orders() {
        use crate::codec::CodecSpec;
        let t = parse_stack("hbm=16g@509.7+host=512g@11~c:3.5+nvme=inf@6~0.00002").unwrap();
        assert_eq!(t.codec(0), Some(CodecSpec::new(3.5)));
        assert_eq!(t.codec(1), None);
        assert_eq!(t.link(0).latency_s, super::DEFAULT_LINK_LATENCY_S);

        // long form, after the latency
        let t = parse_stack("hbm=16g@509.7+host=inf@11~1e-5~c:2.5@12/40").unwrap();
        let c = t.codec(0).unwrap();
        assert_eq!((c.ratio, c.compress_gbs, c.decompress_gbs), (2.5, 12.0, 40.0));
        assert_eq!(t.link(0).latency_s, 1e-5);

        // annotation order is free: codec first, latency second
        let t2 = parse_stack("hbm=16g@509.7+host=inf@11~c:2.5@12/40~1e-5").unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn codec_annotations_reject_malformed_and_first_tier() {
        let cases = [
            (
                "hbm=16g@550~c:3.5+host=inf@11",
                "a ~c: codec annotates the link into the tier above",
            ),
            ("hbm=16g@550+host=inf@11~c:0.5", "codec"),
            ("hbm=16g@550+host=inf@11~c:", "codec"),
            ("hbm=16g@550+host=inf@11~c:3.5~c:2", "more than one ~c:"),
            ("hbm=16g@550+host=inf@11~1e-5~2e-5", "more than one ~latency"),
        ];
        for (spec, needle) in cases {
            let e = parse_stack(spec).unwrap_err().to_string();
            assert!(e.contains(needle), "{spec}: {e}");
        }
    }

    #[test]
    fn render_round_trips() {
        for s in [
            "hbm=16g@509.7+host=inf@11",
            "hbm=16g@509.7+host=48g@11~0.00001+nvme=inf@6~0.00002",
            "a=1023@3.5+b=1k@2+c=inf@0.25~0.5",
            "hbm=16g@509.7+host=512g@11~c:3.5",
            "hbm=16g@509.7+host=48g@11~c:2.5@12/40/5+nvme=inf@6~c:1.5",
        ] {
            let t = parse_stack(s).unwrap();
            let r = render(&t);
            let t2 = parse_stack(r.strip_prefix("tiers:").unwrap()).unwrap();
            assert_eq!(t, t2, "{s} → {r}");
        }
    }

    #[test]
    fn capacity_suffixes_are_binary_and_render_largest() {
        assert_eq!(parse_capacity("x", "16g").unwrap(), Some(16u64 << 30));
        assert_eq!(parse_capacity("x", "4t").unwrap(), Some(4u64 << 40));
        assert_eq!(parse_capacity("x", "3k").unwrap(), Some(3u64 << 10));
        assert_eq!(parse_capacity("x", "777").unwrap(), Some(777));
        assert_eq!(parse_capacity("x", "inf").unwrap(), None);
        assert_eq!(render_capacity(Some(16 << 30)), "16g");
        assert_eq!(render_capacity(Some(1 << 40)), "1t");
        assert_eq!(render_capacity(Some(777)), "777");
        assert_eq!(render_capacity(None), "inf");
    }

    #[test]
    fn malformed_tokens_name_the_token() {
        let cases = [
            ("hbm=0g@550+host=inf@11", "zero capacity"),
            ("hbm=16q@550+host=inf@11", "unknown capacity suffix"),
            ("hbm=16g@550+hbm=inf@11", "duplicate tier name"),
            ("hbm=16g@550", "single-tier"),
            ("hbm=16g+host=inf@11", "missing @bandwidth"),
            ("hbm=16g@fast+host=inf@11", "bad bandwidth"),
            ("hbm=16g@550~1e-5+host=inf@11", "first (fastest) tier"),
            ("=16g@550+host=inf@11", "empty tier name"),
            ("bogus", "single-tier"),
            ("hbm=16g@550+host=inf@11~slow", "bad link latency"),
        ];
        for (spec, needle) in cases {
            let e = parse_stack(spec).unwrap_err().to_string();
            assert!(e.contains(needle), "{spec}: {e}");
        }
        // overflow
        assert!(parse_stack("a=99999999999t@1+b=inf@1").is_err());
    }

    #[test]
    fn preset_names_resolve() {
        let t = parse_stack("gpu-explicit-pcie").unwrap();
        assert_eq!(t.name.as_deref(), Some("gpu-explicit-pcie"));
        assert_eq!(t.tier(0).name, "hbm");
    }
}
