//! Named preset topologies — the paper's calibrated machines as data.
//!
//! Every preset's *stack* reproduces the corresponding calibration
//! numbers exactly (they come from the same
//! [`crate::memory::hierarchy`] defaults the engines use).
//! **Execution** equivalence holds for the explicit-streaming stacks:
//! `tiers:gpu-explicit-{pcie,nvlink}` on the generic
//! [`crate::memory::TieredEngine`] model the same clocks as the legacy
//! `gpu-explicit:*` platforms — pinned bit-exactly in
//! `tests/tiling_equivalence.rs`. The `knl` and `unified-*` presets
//! *describe* those machines' memory stacks; running them through the
//! generic engine models explicit streaming over that stack with the
//! app's GPU compute calibration — it does **not** reproduce the
//! MCDRAM cache simulator or the page-fault model (use the legacy
//! `knl-cache*` / `gpu-unified` heads for those). `--list-platforms`
//! prints this table with each preset's canonical spec string.

use super::{LinkSpec, Tier, Topology};
use crate::codec::CodecSpec;
use crate::memory::hierarchy::{GpuCalib, KnlCalib, Link};

/// All named presets, in display order.
pub fn presets() -> Vec<Topology> {
    let k = KnlCalib::default();
    let g = GpuCalib::default();
    vec![
        knl_cache(&k),
        gpu_explicit(&g, Link::PciE),
        gpu_explicit(&g, Link::NvLink),
        gpu_explicit_zfp(&g),
        gpu_unified(&g, Link::PciE),
        gpu_unified(&g, Link::NvLink),
        plain(&k),
    ]
}

/// Look a preset up by name.
pub fn preset(name: &str) -> Option<Topology> {
    presets().into_iter().find(|t| t.name.as_deref() == Some(name))
}

/// KNL cache mode: MCDRAM (§5.2 cache-mode STREAM bandwidth) backed by
/// unbounded DDR4. The MCDRAM↔DDR4 path has no per-transfer launch
/// latency — cache fills are hardware, not API calls.
pub fn knl_cache(k: &KnlCalib) -> Topology {
    Topology::new(
        Some("knl"),
        vec![
            Tier::new("mcdram", Some(k.mcdram_bytes), k.bw_mcdram_cache),
            Tier::new("ddr4", None, k.bw_ddr4),
        ],
        vec![LinkSpec::new(k.bw_ddr4, 0.0)],
    )
    .expect("preset topologies are well-formed")
}

/// P100 explicit streaming (§5.3): HBM2 at the measured device-copy
/// bandwidth over the host link. Stacks whose innermost link is the
/// calibrated NVLink host link (this preset's `-nvlink` variant, or
/// any hand-spelled equivalent) additionally model the §5.3
/// graphics-clock boost when built into an engine.
pub fn gpu_explicit(g: &GpuCalib, link: Link) -> Topology {
    gpu_stack("gpu-explicit", g, link)
}

/// [`gpu_explicit`] over PCIe with a ZFP-class codec on the host link:
/// Shen et al. (arXiv 2204.11315) report 2–5× fixed-accuracy
/// compression on out-of-core GPU stencil state — [`CodecSpec::ZFP`]
/// models the midpoint of that band at cuZFP-class kernel throughputs.
pub fn gpu_explicit_zfp(g: &GpuCalib) -> Topology {
    let mut t = gpu_stack("gpu-explicit", g, Link::PciE)
        .with_codecs(vec![Some(CodecSpec::ZFP)])
        .expect("preset topologies are well-formed");
    t.name = Some("gpu-explicit-pcie-zfp".to_string());
    t
}

/// P100 unified memory (§5.4): the same physical stack as
/// [`gpu_explicit`] — the page-migration behaviour is the engine's, not
/// the topology's.
pub fn gpu_unified(g: &GpuCalib, link: Link) -> Topology {
    gpu_stack("unified", g, link)
}

fn gpu_stack(kind: &str, g: &GpuCalib, link: Link) -> Topology {
    let (suffix, spec) = match link {
        Link::PciE => ("pcie", LinkSpec::PCIE_HOST),
        Link::NvLink => ("nvlink", LinkSpec::NVLINK_HOST),
    };
    let name = format!("{kind}-{suffix}");
    Topology::new(
        Some(name.as_str()),
        vec![
            Tier::new("hbm", Some(g.hbm_bytes), g.bw_device),
            Tier::new("host", None, spec.bw_gbs),
        ],
        vec![spec],
    )
    .expect("preset topologies are well-formed")
}

/// A single flat tier: unbounded DRAM at the paper's DDR4 STREAM
/// bandwidth (§5.2). The degenerate one-tier topology — no streaming,
/// no boundaries.
pub fn plain(k: &KnlCalib) -> Topology {
    Topology::new(
        Some("plain"),
        vec![Tier::new("dram", None, k.bw_ddr4)],
        vec![],
    )
    .expect("preset topologies are well-formed")
}

/// A single flat tier with explicit numbers — the compat mapping for
/// the flat `Platform` variants (flat MCDRAM, GPU baseline, …).
pub fn flat(tier_name: &str, capacity_bytes: Option<u64>, bw_gbs: f64) -> Topology {
    Topology::new(None, vec![Tier::new(tier_name, capacity_bytes, bw_gbs)], vec![])
        .expect("flat topologies are well-formed")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_reproduce_paper_calibrations() {
        let knl = preset("knl").unwrap();
        assert_eq!(knl.tier(0).capacity_bytes, Some(16 << 30));
        assert!((knl.tier(0).bw_gbs - 291.0).abs() < 1e-12);
        assert!((knl.tier(1).bw_gbs - 60.8).abs() < 1e-12);
        assert_eq!(knl.link(0).latency_s, 0.0);

        let gpu = preset("gpu-explicit-pcie").unwrap();
        assert_eq!(gpu.tier(0).capacity_bytes, Some(16 << 30));
        assert!((gpu.tier(0).bw_gbs - 509.7).abs() < 1e-12);
        assert_eq!(gpu.link(0), LinkSpec::PCIE_HOST);

        let nv = preset("gpu-explicit-nvlink").unwrap();
        assert_eq!(nv.link(0), LinkSpec::NVLINK_HOST);

        assert_eq!(preset("plain").unwrap().num_tiers(), 1);
        assert!(preset("bogus").is_none());

        let zfp = preset("gpu-explicit-pcie-zfp").unwrap();
        assert_eq!(zfp.codec(0), Some(CodecSpec::ZFP));
        assert!(zfp.without_codecs().same_stack(&preset("gpu-explicit-pcie").unwrap()));
    }

    #[test]
    fn preset_specs_use_their_names() {
        for p in presets() {
            let name = p.name.clone().unwrap();
            assert_eq!(p.spec(), format!("tiers:{name}"));
            // the full grammar is still printable for every preset
            assert!(p.spec_full().starts_with("tiers:"), "{}", p.spec_full());
        }
    }

    #[test]
    fn unified_shares_the_gpu_stack() {
        let a = preset("gpu-explicit-nvlink").unwrap();
        let b = preset("unified-nvlink").unwrap();
        assert!(a.same_stack(&b));
        assert_ne!(a, b);
    }
}
