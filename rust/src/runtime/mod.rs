//! PJRT runtime: loads AOT-compiled XLA programs (HLO text emitted by
//! `python/compile/aot.py`) and executes them on the CPU PJRT client.
//!
//! This is the request-path end of the three-layer architecture: Python
//! (JAX + Pallas) runs **once** at build time to produce
//! `artifacts/*.hlo.txt`; the Rust coordinator loads and runs them with
//! no Python anywhere near the hot path.
//!
//! Interchange is HLO *text*, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while
//! the text parser reassigns ids (see /opt/xla-example/README.md).

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled, ready-to-run XLA program.
pub struct LoadedArtifact {
    exe: xla::PjRtLoadedExecutable,
    /// Number of outputs in the result tuple.
    pub num_outputs: usize,
}

impl LoadedArtifact {
    /// Execute with the given inputs; returns the flattened output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let res = self
            .exe
            .execute::<xla::Literal>(inputs)
            .context("PJRT execution failed")?;
        let lit = res[0][0]
            .to_literal_sync()
            .context("device->host transfer failed")?;
        // aot.py lowers with return_tuple=True.
        let parts = lit.to_tuple().context("untupling result failed")?;
        Ok(parts)
    }
}

/// One entry of `artifacts/manifest.txt` (written by aot.py): which HLO
/// file implements which kernel, and the dataset names it consumes and
/// produces, in argument order.
///
/// Line format (whitespace-separated `key=value`, lists comma-separated):
/// `kernel=diff_lap file=diff_lap.hlo.txt inputs=u,kappa outputs=lap shape=66,66`
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Kernel (par_loop) name this artifact implements.
    pub kernel: String,
    /// HLO text file, relative to the manifest.
    pub file: String,
    /// Input dataset names, in argument order.
    pub inputs: Vec<String>,
    /// Output dataset names, in tuple order.
    pub outputs: Vec<String>,
    /// Padded array shape the program was lowered for ([y,x] or [z,y,x]).
    pub shape: Vec<usize>,
}

impl ArtifactSpec {
    /// Parse one manifest line (empty / `#` lines yield `None`).
    pub fn parse_line(line: &str) -> Result<Option<Self>> {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            return Ok(None);
        }
        let mut kernel = None;
        let mut file = None;
        let mut inputs = vec![];
        let mut outputs = vec![];
        let mut shape = vec![];
        for tok in line.split_whitespace() {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad manifest token {tok:?}"))?;
            match k {
                "kernel" => kernel = Some(v.to_string()),
                "file" => file = Some(v.to_string()),
                "inputs" => inputs = v.split(',').map(str::to_string).collect(),
                "outputs" => outputs = v.split(',').map(str::to_string).collect(),
                "shape" => {
                    shape = v
                        .split(',')
                        .map(|x| x.parse::<usize>())
                        .collect::<std::result::Result<Vec<_>, _>>()
                        .with_context(|| format!("bad shape in {line:?}"))?
                }
                other => anyhow::bail!("unknown manifest key {other:?}"),
            }
        }
        Ok(Some(ArtifactSpec {
            kernel: kernel.ok_or_else(|| anyhow::anyhow!("manifest line missing kernel="))?,
            file: file.ok_or_else(|| anyhow::anyhow!("manifest line missing file="))?,
            inputs,
            outputs,
            shape,
        }))
    }
}

/// The PJRT runtime: one CPU client, many loaded executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO text file.
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedArtifact> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(LoadedArtifact {
            exe,
            num_outputs: 0,
        })
    }

    /// Load the artifact manifest and compile every listed program.
    pub fn load_manifest(
        &self,
        manifest_path: &Path,
    ) -> Result<HashMap<String, (ArtifactSpec, LoadedArtifact)>> {
        let text = std::fs::read_to_string(manifest_path)
            .with_context(|| format!("reading {manifest_path:?}"))?;
        let specs: Vec<ArtifactSpec> = text
            .lines()
            .map(ArtifactSpec::parse_line)
            .collect::<Result<Vec<_>>>()?
            .into_iter()
            .flatten()
            .collect();
        let dir = manifest_path
            .parent()
            .map(PathBuf::from)
            .unwrap_or_default();
        let mut out = HashMap::new();
        for spec in specs {
            let mut art = self.load_hlo_text(&dir.join(&spec.file))?;
            art.num_outputs = spec.outputs.len();
            out.insert(spec.kernel.clone(), (spec, art));
        }
        Ok(out)
    }
}

/// Default artifacts directory (relative to the repo root / CWD).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("OPS_OC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_line_parses() {
        let s = ArtifactSpec::parse_line(
            "kernel=diff_lap file=a.hlo.txt inputs=u,kappa outputs=lap shape=66,66",
        )
        .unwrap()
        .unwrap();
        assert_eq!(s.kernel, "diff_lap");
        assert_eq!(s.inputs, vec!["u", "kappa"]);
        assert_eq!(s.outputs, vec!["lap"]);
        assert_eq!(s.shape, vec![66, 66]);
    }

    #[test]
    fn comments_and_blanks_skip() {
        assert!(ArtifactSpec::parse_line("# hi").unwrap().is_none());
        assert!(ArtifactSpec::parse_line("   ").unwrap().is_none());
    }

    #[test]
    fn bad_lines_error() {
        assert!(ArtifactSpec::parse_line("nonsense").is_err());
        assert!(ArtifactSpec::parse_line("kernel=x").is_err()); // missing file
        assert!(ArtifactSpec::parse_line("kernel=x file=y shape=a,b").is_err());
    }
}
