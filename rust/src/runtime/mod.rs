//! PJRT runtime: loads AOT-compiled XLA programs (HLO text emitted by
//! `python/compile/aot.py`) and executes them on the CPU PJRT client.
//!
//! This is the request-path end of the three-layer architecture: Python
//! (JAX + Pallas) runs **once** at build time to produce
//! `artifacts/*.hlo.txt`; the Rust coordinator loads and runs them with
//! no Python anywhere near the hot path.
//!
//! Interchange is HLO *text*, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The XLA bindings are an **optional** dependency gated behind the
//! `xla` cargo feature (the default build is hermetic). Without the
//! feature, [`Runtime::cpu`] returns an error and the manifest/spec
//! parsing — which the tests exercise — still works.

// The real backend references the external `xla` (xla_extension)
// bindings, which the hermetic manifest deliberately omits. Surface one
// actionable diagnostic instead of a wall of unresolved-import errors:
// to use the feature, add the dependency to rust/Cargo.toml and delete
// this guard (see rust/README.md).
#[cfg(feature = "xla")]
compile_error!(
    "the `xla` feature requires the external `xla` (xla_extension) bindings: \
     add the dependency to rust/Cargo.toml and remove this guard — see rust/README.md"
);

use crate::errors::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Literal type handed to [`LoadedArtifact::run`]. With the `xla`
/// feature this is `xla::Literal`; without it, an uninhabitable stub.
#[cfg(feature = "xla")]
pub type Literal = xla::Literal;

/// Stub literal for builds without the `xla` feature. Never constructed:
/// the only producer is the (also stubbed) [`Runtime`].
#[cfg(not(feature = "xla"))]
pub struct Literal;

/// A compiled, ready-to-run XLA program.
#[cfg(feature = "xla")]
pub struct LoadedArtifact {
    exe: xla::PjRtLoadedExecutable,
    /// Number of outputs in the result tuple.
    pub num_outputs: usize,
}

#[cfg(feature = "xla")]
impl LoadedArtifact {
    /// Execute with the given inputs; returns the flattened output tuple.
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let res = self
            .exe
            .execute::<Literal>(inputs)
            .context("PJRT execution failed")?;
        let lit = res[0][0]
            .to_literal_sync()
            .context("device->host transfer failed")?;
        // aot.py lowers with return_tuple=True.
        let parts = lit.to_tuple().context("untupling result failed")?;
        Ok(parts)
    }
}

/// Stub artifact for builds without the `xla` feature.
#[cfg(not(feature = "xla"))]
pub struct LoadedArtifact {
    /// Number of outputs in the result tuple.
    pub num_outputs: usize,
}

#[cfg(not(feature = "xla"))]
impl LoadedArtifact {
    pub fn run(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
        Err(crate::err!(
            "ops-oc was built without the `xla` feature; PJRT execution is unavailable"
        ))
    }
}

/// One entry of `artifacts/manifest.txt` (written by aot.py): which HLO
/// file implements which kernel, and the dataset names it consumes and
/// produces, in argument order.
///
/// Line format (whitespace-separated `key=value`, lists comma-separated):
/// `kernel=diff_lap file=diff_lap.hlo.txt inputs=u,kappa outputs=lap shape=66,66`
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Kernel (par_loop) name this artifact implements.
    pub kernel: String,
    /// HLO text file, relative to the manifest.
    pub file: String,
    /// Input dataset names, in argument order.
    pub inputs: Vec<String>,
    /// Output dataset names, in tuple order.
    pub outputs: Vec<String>,
    /// Padded array shape the program was lowered for ([y,x] or [z,y,x]).
    pub shape: Vec<usize>,
}

impl ArtifactSpec {
    /// Parse one manifest line (empty / `#` lines yield `None`).
    pub fn parse_line(line: &str) -> Result<Option<Self>> {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            return Ok(None);
        }
        let mut kernel = None;
        let mut file = None;
        let mut inputs = vec![];
        let mut outputs = vec![];
        let mut shape = vec![];
        for tok in line.split_whitespace() {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| crate::err!("bad manifest token {tok:?}"))?;
            match k {
                "kernel" => kernel = Some(v.to_string()),
                "file" => file = Some(v.to_string()),
                "inputs" => inputs = v.split(',').map(str::to_string).collect(),
                "outputs" => outputs = v.split(',').map(str::to_string).collect(),
                "shape" => {
                    shape = v
                        .split(',')
                        .map(|x| x.parse::<usize>())
                        .collect::<std::result::Result<Vec<_>, _>>()
                        .with_context(|| format!("bad shape in {line:?}"))?
                }
                other => crate::bail!("unknown manifest key {other:?}"),
            }
        }
        Ok(Some(ArtifactSpec {
            kernel: kernel.ok_or_else(|| crate::err!("manifest line missing kernel="))?,
            file: file.ok_or_else(|| crate::err!("manifest line missing file="))?,
            inputs,
            outputs,
            shape,
        }))
    }
}

/// The PJRT runtime: one CPU client, many loaded executables.
#[cfg(feature = "xla")]
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "xla")]
impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO text file.
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedArtifact> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| crate::err!("non-utf8 path {path:?}"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(LoadedArtifact {
            exe,
            num_outputs: 0,
        })
    }

    /// Load the artifact manifest and compile every listed program.
    pub fn load_manifest(
        &self,
        manifest_path: &Path,
    ) -> Result<HashMap<String, (ArtifactSpec, LoadedArtifact)>> {
        let text = std::fs::read_to_string(manifest_path)
            .with_context(|| format!("reading {manifest_path:?}"))?;
        let specs: Vec<ArtifactSpec> = text
            .lines()
            .map(ArtifactSpec::parse_line)
            .collect::<Result<Vec<_>>>()?
            .into_iter()
            .flatten()
            .collect();
        let dir = manifest_path
            .parent()
            .map(PathBuf::from)
            .unwrap_or_default();
        let mut out = HashMap::new();
        for spec in specs {
            let mut art = self.load_hlo_text(&dir.join(&spec.file))?;
            art.num_outputs = spec.outputs.len();
            out.insert(spec.kernel.clone(), (spec, art));
        }
        Ok(out)
    }
}

/// Stub runtime for builds without the `xla` feature: every constructor
/// reports the backend as unavailable so callers can fall back or skip.
#[cfg(not(feature = "xla"))]
pub struct Runtime {}

#[cfg(not(feature = "xla"))]
impl Runtime {
    pub fn cpu() -> Result<Self> {
        Err(crate::err!(
            "ops-oc was built without the `xla` feature; PJRT is unavailable"
        ))
    }

    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    pub fn load_hlo_text(&self, _path: &Path) -> Result<LoadedArtifact> {
        Err(crate::err!("PJRT unavailable (built without `xla`)"))
    }

    pub fn load_manifest(
        &self,
        _manifest_path: &Path,
    ) -> Result<HashMap<String, (ArtifactSpec, LoadedArtifact)>> {
        Err(crate::err!("PJRT unavailable (built without `xla`)"))
    }
}

/// Default artifacts directory (relative to the repo root / CWD).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("OPS_OC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_line_parses() {
        let s = ArtifactSpec::parse_line(
            "kernel=diff_lap file=a.hlo.txt inputs=u,kappa outputs=lap shape=66,66",
        )
        .unwrap()
        .unwrap();
        assert_eq!(s.kernel, "diff_lap");
        assert_eq!(s.inputs, vec!["u", "kappa"]);
        assert_eq!(s.outputs, vec!["lap"]);
        assert_eq!(s.shape, vec![66, 66]);
    }

    #[test]
    fn comments_and_blanks_skip() {
        assert!(ArtifactSpec::parse_line("# hi").unwrap().is_none());
        assert!(ArtifactSpec::parse_line("   ").unwrap().is_none());
    }

    #[test]
    fn bad_lines_error() {
        assert!(ArtifactSpec::parse_line("nonsense").is_err());
        assert!(ArtifactSpec::parse_line("kernel=x").is_err()); // missing file
        assert!(ArtifactSpec::parse_line("kernel=x file=y shape=a,b").is_err());
    }
}
