//! [`Session`] — binds one shared, frozen [`Program`] to an engine,
//! an executor, a data store and a metrics sink, and executes chains:
//! frozen chains via [`Session::replay`] (record once, replay many,
//! analysis from freeze time), or dynamically recorded loops whose
//! analyses are memoised by structural fingerprint (so even re-recorded
//! chains — e.g. a new `dt` baked into kernels each step — reuse the
//! expensive dependency/footprint/skew computation).

use super::builder::{validate_loop, ChainId, Program};
use crate::coordinator::Config;
use crate::exec::{Engine, ExecBackend, Executor, Metrics, NativeExecutor, VectorExecutor, World};
use crate::lazy::LoopQueue;
use crate::ops::surface::{Drive, Record};
use crate::ops::{
    Arg, BlockId, DataStore, Dataset, Kernel, KernelIr, LoopInst, Range3, Reduction, ReductionId,
    Stencil,
};
use crate::tiling::analysis::{chain_structure_eq, chain_structure_fingerprint, ChainAnalysis};
use std::collections::HashMap;
use std::sync::Arc;

/// One execution of a [`Program`]: engine + executor + data + metrics.
/// Many sessions can share one `Arc<Program>` — different platforms,
/// modelled ranks, or tuner candidates — each with independent data,
/// reduction slots and clocks.
pub struct Session {
    program: Arc<Program>,
    store: DataStore,
    reds: Vec<Reduction>,
    queue: LoopQueue,
    engine: Box<dyn Engine>,
    exec: Box<dyn Executor>,
    metrics: Metrics,
    cyclic_phase: bool,
    oom: bool,
    /// Memoised analyses of dynamically recorded chains, keyed by
    /// structural fingerprint. The recorded structure is kept alongside
    /// the analysis so a hit can be verified: a 64-bit fingerprint
    /// collision must not silently reuse another chain's shifts/plans.
    dyn_analysis: HashMap<u64, (Vec<LoopInst>, Arc<ChainAnalysis>)>,
    /// Which frozen chains this session has replayed at least once
    /// (drives the `analysis_builds` / `analysis_reuse_hits` counters).
    frozen_used: Vec<bool>,
    /// Executor fallback-loop count at the last metrics reset — the
    /// executor's counter is cumulative, the metric covers the timed
    /// region.
    kir_fallback_base: u64,
}

impl Session {
    /// Bind `program` to the engine `cfg` describes (tuned engines
    /// included), with the executor backend `cfg.exec` selects.
    pub fn new(program: Arc<Program>, cfg: &Config) -> Self {
        let mut s = Self::with_engine(program, cfg.build_engine());
        if cfg.exec == ExecBackend::Vector {
            s.set_executor(Box::new(VectorExecutor::new()));
        }
        s
    }

    /// Bind `program` to an explicit engine. Like
    /// [`Session::rebind_engine`], the engine's transient cross-chain
    /// state is reset: a session must not inherit prefetch credit from
    /// chains it never ran, whether the engine arrives at construction
    /// or mid-session.
    pub fn with_engine(program: Arc<Program>, mut engine: Box<dyn Engine>) -> Self {
        engine.reset_transient();
        let mut store = DataStore::new();
        for d in program.datasets() {
            store.alloc(d);
        }
        let reds = program.reductions().to_vec();
        let mut metrics = Metrics::new();
        metrics.program_freeze_s = program.freeze_s();
        metrics.kir_kernels_compiled = program.kir_kernels_compiled();
        metrics.exec_backend = "native".to_string();
        let frozen_used = vec![false; program.chains().len()];
        Session {
            store,
            reds,
            queue: LoopQueue::new(),
            engine,
            exec: Box::new(NativeExecutor::new()),
            metrics,
            cyclic_phase: false,
            oom: false,
            dyn_analysis: HashMap::new(),
            frozen_used,
            kir_fallback_base: 0,
            program,
        }
    }

    /// Swap in a different numeric executor (e.g. the vector or PJRT
    /// backend).
    pub fn set_executor(&mut self, exec: Box<dyn Executor>) {
        self.exec = exec;
        self.metrics.exec_backend = self.exec.name().to_string();
        self.kir_fallback_base = self.exec.kir_loop_stats().1;
    }

    /// Rebind this session to a different memory engine. Pending
    /// dynamically recorded loops are flushed through the old engine
    /// first (they were priced under its clock), and the incoming
    /// engine's transient cross-chain state is reset
    /// ([`Engine::reset_transient`]): a pre-used GPU streaming engine
    /// must not apply prefetch credit earned under chains this session
    /// never ran.
    ///
    /// The metrics' per-resource attribution ledger is keyed by the
    /// outgoing engine's stream names; carrying it across the rebind
    /// would keep reporting the *old* engine's `util_*` classes (e.g. a
    /// stale `upload` row after a streaming→plain rebind, or the
    /// reverse: a plain→tiered rebind diluting the new per-tier rows).
    /// The ledger restarts empty at the rebind boundary, so `bound()` /
    /// `stream_util` describe the engine that is actually bound.
    pub fn rebind_engine(&mut self, mut engine: Box<dyn Engine>) {
        self.flush_dynamic();
        engine.reset_transient();
        let _ = self.metrics.take_per_resource();
        self.engine = engine;
    }

    /// The shared program this session executes.
    pub fn program(&self) -> &Arc<Program> {
        &self.program
    }

    // ---- frozen-chain execution -----------------------------------------

    /// Replay a frozen chain `steps` times — each replay is one chain
    /// boundary (one engine `run_chain`), and every replay after the
    /// first reuses the freeze-time analysis (`analysis_reuse_hits`).
    /// Any dynamically queued loops are flushed first to preserve
    /// program order.
    pub fn replay(&mut self, chain: ChainId, steps: usize) {
        self.flush();
        let program = self.program.clone();
        let spec = program.chain(chain);
        if spec.loops.is_empty() {
            return;
        }
        let analysis = program.analysis(chain).clone();
        let sp = crate::obs::span("replay");
        sp.field("chain", &spec.name);
        sp.field("steps", steps);
        for _ in 0..steps {
            if self.frozen_used[chain.0 as usize] {
                self.metrics.analysis_reuse_hits += 1;
            } else {
                self.frozen_used[chain.0 as usize] = true;
                self.metrics.analysis_builds += 1;
            }
            self.run_now(&spec.loops, program.datasets(), program.stencils(), &analysis);
        }
    }

    /// Replay a frozen chain once.
    pub fn run_chain(&mut self, chain: ChainId) {
        self.replay(chain, 1);
    }

    /// Replay a frozen chain `steps` times, fusing `k` consecutive
    /// steps into one skewed super-chain per engine `run_chain` — the
    /// temporal-tiling extension of Reguly et al. (1704.00693): each
    /// tile's data crosses the slowest tier boundary once per `k` steps
    /// instead of once per step. Numerics are bit-exact against
    /// [`Session::replay`] with the same `steps`: the super-chain is
    /// the base chain's loops concatenated `k` times, executed in the
    /// same order, and its skew shifts equal `compute_shifts` of that
    /// concatenation (see
    /// [`crate::tiling::dependency::compute_fused_shifts`]).
    ///
    /// `k` is clamped to `[1, steps]`; `k <= 1` is exactly `replay`.
    /// `steps % k` trailing steps run unfused. The fused analysis is
    /// built once per `(chain, k)` and memoised on the shared
    /// [`Program`], so sessions across platforms/ranks amortise it.
    pub fn replay_fused(&mut self, chain: ChainId, steps: usize, k: usize) {
        let k = k.clamp(1, steps.max(1));
        if k <= 1 {
            return self.replay(chain, steps);
        }
        self.flush();
        let program = self.program.clone();
        let spec = program.chain(chain);
        if spec.loops.is_empty() {
            return;
        }
        let (fused, built) = program.fused(chain, k as u32);
        let batches = steps / k;
        let rem = steps % k;
        let sp = crate::obs::span("fuse");
        sp.field("chain", &spec.name);
        sp.field("k", k);
        sp.field("batches", batches);
        self.frozen_used[chain.0 as usize] = true;
        for i in 0..batches {
            if i == 0 && built {
                self.metrics.analysis_builds += 1;
            } else {
                self.metrics.analysis_reuse_hits += 1;
            }
            self.metrics.fused_steps += k as u64;
            self.run_now(
                &fused.loops,
                program.datasets(),
                program.stencils(),
                &fused.analysis,
            );
        }
        drop(sp);
        if rem > 0 {
            self.replay(chain, rem);
        }
    }

    // ---- dynamic recording ----------------------------------------------

    /// Loops currently queued (dynamic recording path).
    pub fn queued_loops(&self) -> usize {
        self.queue.len()
    }

    fn flush_dynamic(&mut self) {
        let chain = self.queue.take_chain();
        if chain.is_empty() {
            return;
        }
        let program = self.program.clone();
        let fp = chain_structure_fingerprint(&chain, program.datasets(), program.stencils());
        // A memo hit is only trusted after verifying structural
        // equality: the fingerprint is 64-bit FNV, and a collision
        // would silently replay another chain's shifts and tile plans
        // (wrong numerics). Some(None) below marks exactly that case.
        let memo = self
            .dyn_analysis
            .get(&fp)
            .map(|(s, a)| chain_structure_eq(&chain, s).then(|| a.clone()));
        let analysis = match memo {
            Some(Some(a)) => {
                self.metrics.analysis_reuse_hits += 1;
                a
            }
            occupied => {
                let a = Arc::new(ChainAnalysis::build(
                    &chain,
                    program.datasets(),
                    program.stencils(),
                ));
                // On collision the slot stays with its first owner —
                // the colliding chain just rebuilds each flush rather
                // than the two thrashing the entry.
                if occupied.is_none() {
                    self.dyn_analysis.insert(fp, (chain.clone(), a.clone()));
                }
                self.metrics.analysis_builds += 1;
                a
            }
        };
        self.run_now(&chain, program.datasets(), program.stencils(), &analysis);
    }

    /// Test hook: force a dynamic-analysis memo entry under an
    /// arbitrary fingerprint, simulating a 64-bit FNV collision.
    #[cfg(test)]
    fn poison_dyn_analysis(&mut self, fp: u64, loops: Vec<LoopInst>, analysis: Arc<ChainAnalysis>) {
        self.dyn_analysis.insert(fp, (loops, analysis));
    }

    /// Run one analysed chain through the engine.
    fn run_now(
        &mut self,
        chain: &[LoopInst],
        datasets: &[Dataset],
        stencils: &[Stencil],
        analysis: &ChainAnalysis,
    ) {
        let sp = crate::obs::span("chain");
        sp.field("loops", chain.len());
        if !self.engine.fits(analysis.chain_bytes) {
            self.oom = true;
        }
        let mut world = World {
            datasets,
            stencils,
            store: &mut self.store,
            reds: &mut self.reds,
            metrics: &mut self.metrics,
            exec: self.exec.as_mut(),
        };
        self.engine
            .run_chain_analyzed(chain, Some(analysis), &mut world, self.cyclic_phase);
        self.metrics.kir_fallback_loops = self
            .exec
            .kir_loop_stats()
            .1
            .saturating_sub(self.kir_fallback_base);
    }

    // ---- introspection ---------------------------------------------------

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }

    /// Did any executed chain exceed the engine's memory?
    pub fn oom(&self) -> bool {
        self.oom
    }

    pub fn problem_bytes(&self) -> u64 {
        self.program.problem_bytes()
    }

    pub fn engine_description(&self) -> String {
        self.engine.describe()
    }

    pub fn dataset(&self, id: crate::ops::DatasetId) -> &Dataset {
        self.program.dataset(id)
    }

    pub fn datasets(&self) -> &[Dataset] {
        self.program.datasets()
    }

    pub fn stencils(&self) -> &[Stencil] {
        self.program.stencils()
    }

    /// Direct (untimed) access for initialisation from host files etc.
    pub fn store_mut(&mut self) -> &mut DataStore {
        &mut self.store
    }

    pub fn store(&self) -> &DataStore {
        &self.store
    }
}

impl Record for Session {
    fn par_loop_eff(
        &mut self,
        name: &str,
        block: BlockId,
        range: Range3,
        kernel: Kernel,
        args: Vec<Arg>,
        bw_efficiency: f64,
    ) {
        validate_loop(
            "session",
            name,
            &args,
            self.program.datasets(),
            self.program.stencils(),
        );
        self.queue.push(LoopInst {
            name: name.to_string(),
            block,
            range,
            args,
            kernel,
            kernel_ir: None,
            seq: 0,
            bw_efficiency,
        });
    }

    fn par_loop_ir(
        &mut self,
        name: &str,
        block: BlockId,
        range: Range3,
        ir: KernelIr,
        args: Vec<Arg>,
        bw_efficiency: f64,
    ) {
        validate_loop(
            "session",
            name,
            &args,
            self.program.datasets(),
            self.program.stencils(),
        );
        let ir = Arc::new(ir);
        self.queue.push(LoopInst {
            name: name.to_string(),
            block,
            range,
            args,
            kernel: ir.to_kernel(),
            kernel_ir: Some(ir),
            seq: 0,
            bw_efficiency,
        });
    }
}

impl Drive for Session {
    fn flush(&mut self) {
        self.flush_dynamic();
    }

    fn reduction_result(&mut self, id: ReductionId) -> f64 {
        self.flush_dynamic();
        let r = &mut self.reds[id.0 as usize];
        let v = r.value;
        r.reset();
        v
    }

    fn fetch(&mut self, id: crate::ops::DatasetId) -> Vec<f64> {
        self.flush_dynamic();
        self.store.buf(id).to_vec()
    }

    fn value_at(&mut self, id: crate::ops::DatasetId, idx: [isize; 3]) -> f64 {
        self.flush_dynamic();
        let off = self.program.dataset(id).offset(idx) as usize;
        self.store.buf(id)[off]
    }

    fn exchange_periodic(&mut self, id: crate::ops::DatasetId, dim: usize, depth: usize) {
        self.flush_dynamic();
        let ds = self.program.dataset(id).clone();
        let sp = crate::obs::span("halo");
        sp.field("dataset", &ds.name);
        let t0 = self.metrics.elapsed_s;
        let t = crate::ops::api::periodic_exchange(&ds, &mut self.store, dim, depth);
        sp.field("model_s", t);
        self.metrics.halo_time_s += t;
        self.metrics.halo_exchanges += 1;
        self.metrics.obs.record("halo_exchange_s", t);
        self.metrics.elapsed_s += t;
        // Periodic boundary wraps run outside any engine chain; attribute
        // them to an exchange stream so the bottleneck ledger sees them.
        use crate::exec::timeline::{EventKind, StreamClass, TraceEvent};
        self.metrics
            .record_stream("periodic", StreamClass::Exchange, t, 0, 1);
        if self.metrics.trace_enabled() {
            self.metrics.push_trace_event(TraceEvent {
                resource: "periodic".into(),
                class: StreamClass::Exchange,
                kind: EventKind::Halo,
                label: format!("periodic {}", ds.name),
                start_s: t0,
                end_s: t0 + t,
                bytes: 0,
            });
        }
    }

    fn set_cyclic_phase(&mut self, on: bool) {
        self.cyclic_phase = on;
    }

    fn reset_metrics(&mut self) {
        let freeze = self.metrics.program_freeze_s;
        let backend = std::mem::take(&mut self.metrics.exec_backend);
        let compiled = self.metrics.kir_kernels_compiled;
        let tracing = self.metrics.trace_enabled();
        self.metrics = Metrics::new();
        // The freeze cost is a per-Session constant, not part of any
        // timed region — keep reporting it after warm-up resets. Same
        // for the executor backend and the freeze-time kernel-compile
        // count; the fallback-loop counter restarts with the timed
        // region.
        self.metrics.program_freeze_s = freeze;
        self.metrics.exec_backend = backend;
        self.metrics.kir_kernels_compiled = compiled;
        self.kir_fallback_base = self.exec.kir_loop_stats().1;
        // Tracing is a session-level switch: a warm-up reset drops the
        // initialisation events but keeps collecting — the exported
        // trace covers exactly the timed region.
        if tracing {
            self.metrics.enable_trace();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Platform;
    use crate::memory::{AppCalib, Link};
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::shapes;
    use crate::ops::surface::Declare;
    use crate::ops::{Access, RedOp};
    use crate::program::ProgramBuilder;

    /// A two-loop diffusion-shaped program with one frozen step chain.
    fn fixture() -> (Arc<Program>, ChainId, crate::ops::DatasetId) {
        let mut b = ProgramBuilder::new();
        let blk = b.decl_block("g", [16, 16, 1]);
        let u = b.decl_dat(blk, "u", [16, 16, 1], [1, 1, 0], [1, 1, 0]);
        let tmp = b.decl_dat(blk, "tmp", [16, 16, 1], [1, 1, 0], [1, 1, 0]);
        let pt = b.decl_stencil("pt", shapes::point());
        let star = b.decl_stencil("star", shapes::star2d(1));
        let interior = [(0isize, 16isize), (0isize, 16isize), (0isize, 1isize)];
        let step = b.record_chain("step", |r| {
            r.par_loop(
                "lap",
                blk,
                interior,
                kernel(|c| {
                    let l = c.r(0, -1, 0) + c.r(0, 1, 0) + c.r(0, 0, -1) + c.r(0, 0, 1)
                        - 4.0 * c.r(0, 0, 0);
                    c.w(1, 0, 0, l);
                }),
                vec![
                    Arg::dat(u, star, Access::Read),
                    Arg::dat(tmp, pt, Access::Write),
                ],
            );
            r.par_loop(
                "upd",
                blk,
                interior,
                kernel(|c| {
                    let v = c.r(0, 0, 0) + 0.1 * c.r(1, 0, 0);
                    c.w(0, 0, 0, v);
                }),
                vec![
                    Arg::dat(u, pt, Access::ReadWrite),
                    Arg::dat(tmp, pt, Access::Read),
                ],
            );
        });
        (Arc::new(b.freeze().unwrap()), step, u)
    }

    fn cfg(p: Platform) -> Config {
        Config::new(p, AppCalib::CLOVERLEAF_2D)
    }

    #[test]
    fn replay_counts_one_build_then_reuse_hits() {
        let (prog, step, _) = fixture();
        let mut s = Session::new(prog, &cfg(Platform::KnlCacheTiled));
        s.replay(step, 10);
        assert_eq!(s.metrics().analysis_builds, 1);
        assert_eq!(s.metrics().analysis_reuse_hits, 9);
        assert_eq!(s.metrics().chains, 10);
        // replaying again keeps reusing
        s.replay(step, 5);
        assert_eq!(s.metrics().analysis_builds, 1);
        assert_eq!(s.metrics().analysis_reuse_hits, 14);
    }

    #[test]
    fn replay_is_bit_exact_with_dynamic_recording() {
        let (prog, step, u) = fixture();
        let p = Platform::GpuExplicit {
            link: Link::PciE,
            cyclic: true,
            prefetch: true,
        };
        let mut frozen = Session::new(prog.clone(), &cfg(p));
        frozen.set_cyclic_phase(true);
        frozen.replay(step, 4);
        let a = frozen.fetch(u);

        // the same loops re-recorded dynamically per step
        let mut dynamic = Session::new(prog.clone(), &cfg(p));
        dynamic.set_cyclic_phase(true);
        for _ in 0..4 {
            for l in &prog.chain(step).loops {
                dynamic.par_loop_eff(
                    &l.name,
                    l.block,
                    l.range,
                    l.kernel.clone(),
                    l.args.clone(),
                    l.bw_efficiency,
                );
            }
            dynamic.flush();
        }
        let b = dynamic.fetch(u);
        assert_eq!(a, b);
        // the dynamic path memoises too: one build, three hits
        assert_eq!(dynamic.metrics().analysis_builds, 1);
        assert_eq!(dynamic.metrics().analysis_reuse_hits, 3);
        // and both modelled the same schedule
        assert_eq!(frozen.metrics().elapsed_s, dynamic.metrics().elapsed_s);
        assert_eq!(frozen.metrics().tiles, dynamic.metrics().tiles);
    }

    /// Re-record a frozen chain's loops through the dynamic path.
    fn record_dynamically(s: &mut Session, prog: &Arc<Program>, chain: ChainId) {
        for l in &prog.chain(chain).loops {
            s.par_loop_eff(
                &l.name,
                l.block,
                l.range,
                l.kernel.clone(),
                l.args.clone(),
                l.bw_efficiency,
            );
        }
        s.flush();
    }

    #[test]
    fn dynamic_memo_rejects_fingerprint_collisions() {
        let (prog, step, u) = fixture();
        let p = Platform::KnlCacheTiled;

        // Reference: a clean dynamic session.
        let mut clean = Session::new(prog.clone(), &cfg(p));
        record_dynamically(&mut clean, &prog, step);
        let want = clean.fetch(u);

        // Poisoned: the step chain's fingerprint maps to a *different*
        // chain's structure + analysis — a forced 64-bit collision.
        // Reversing the loops flips the dependency direction, so its
        // analysis carries the wrong skew shifts.
        let loops = &prog.chain(step).loops;
        let fp = chain_structure_fingerprint(loops, prog.datasets(), prog.stencils());
        let wrong: Vec<LoopInst> = loops.iter().rev().cloned().collect();
        assert!(!chain_structure_eq(loops, &wrong), "collision fixture must differ");
        let wrong_analysis = Arc::new(ChainAnalysis::build(
            &wrong,
            prog.datasets(),
            prog.stencils(),
        ));
        let mut s = Session::new(prog.clone(), &cfg(p));
        s.poison_dyn_analysis(fp, wrong.clone(), wrong_analysis);
        record_dynamically(&mut s, &prog, step);
        assert_eq!(s.metrics().analysis_builds, 1, "collision must rebuild");
        assert_eq!(s.metrics().analysis_reuse_hits, 0);
        assert_eq!(s.fetch(u), want, "collision must not corrupt numerics");
        // The slot stays with its first owner: the colliding chain
        // rebuilds on every flush instead of thrashing the entry.
        record_dynamically(&mut s, &prog, step);
        assert_eq!(s.metrics().analysis_builds, 2);
        assert_eq!(s.metrics().analysis_reuse_hits, 0);
    }

    #[test]
    fn replay_fused_is_bit_exact_and_counts_fused_steps() {
        let (prog, step, u) = fixture();
        let p = Platform::GpuExplicit {
            link: Link::PciE,
            cyclic: true,
            prefetch: true,
        };
        let mut plain = Session::new(prog.clone(), &cfg(p));
        plain.set_cyclic_phase(true);
        plain.replay(step, 10);
        let want = plain.fetch(u);

        // k=3 over 10 steps: three fused batches plus one unfused tail.
        let mut fused = Session::new(prog.clone(), &cfg(p));
        fused.set_cyclic_phase(true);
        fused.replay_fused(step, 10, 3);
        assert_eq!(fused.fetch(u), want, "fused numerics must match k=1");
        assert_eq!(fused.metrics().fused_steps, 9);
        assert_eq!(fused.metrics().chains, 4, "3 super-chains + 1 tail");

        // k=1 is exactly replay; k > steps clamps to one super-chain.
        let mut one = Session::new(prog.clone(), &cfg(p));
        one.set_cyclic_phase(true);
        one.replay_fused(step, 10, 1);
        assert_eq!(one.fetch(u), want);
        assert_eq!(one.metrics().fused_steps, 0);
        assert_eq!(one.metrics().chains, 10);

        let mut big = Session::new(prog, &cfg(p));
        big.set_cyclic_phase(true);
        big.replay_fused(step, 10, 64);
        assert_eq!(big.fetch(u), want);
        assert_eq!(big.metrics().fused_steps, 10);
        assert_eq!(big.metrics().chains, 1);
    }

    #[test]
    fn fused_analysis_is_memoised_on_the_shared_program() {
        let (prog, step, u) = fixture();
        let mut a = Session::new(prog.clone(), &cfg(Platform::KnlCacheTiled));
        a.replay_fused(step, 4, 2);
        let mut b = Session::new(prog.clone(), &cfg(Platform::KnlCacheTiled));
        b.replay_fused(step, 4, 2);
        assert_eq!(a.metrics().analysis_builds, 1);
        assert_eq!(a.metrics().analysis_reuse_hits, 1);
        // the second session hits the program-level (chain, k) memo
        assert_eq!(b.metrics().analysis_builds, 0);
        assert_eq!(b.metrics().analysis_reuse_hits, 2);
        assert_eq!(a.fetch(u), b.fetch(u));
    }

    #[test]
    fn sessions_share_one_program_independently() {
        let (prog, step, u) = fixture();
        let mut knl = Session::new(prog.clone(), &cfg(Platform::KnlCacheTiled));
        let mut gpu = Session::new(
            prog.clone(),
            &cfg(Platform::GpuExplicit {
                link: Link::NvLink,
                cyclic: false,
                prefetch: false,
            }),
        );
        knl.replay(step, 3);
        gpu.replay(step, 3);
        assert_eq!(knl.fetch(u), gpu.fetch(u), "numerics engine-independent");
        assert!(knl.metrics().elapsed_s != gpu.metrics().elapsed_s);
        assert_eq!(Arc::strong_count(knl.program()), 3);
    }

    #[test]
    fn reductions_and_reset_metrics_work() {
        let mut b = ProgramBuilder::new();
        let blk = b.decl_block("g", [4, 4, 1]);
        let d = b.decl_dat(blk, "d", [4, 4, 1], [0; 3], [0; 3]);
        let pt = b.decl_stencil("pt", shapes::point());
        let sum = b.decl_reduction("sum", RedOp::Sum);
        let fill = b.record_chain("fill", |r| {
            r.par_loop(
                "ones",
                blk,
                [(0, 4), (0, 4), (0, 1)],
                kernel(|c| c.w(0, 0, 0, 1.0)),
                vec![Arg::dat(d, pt, Access::Write)],
            );
        });
        let reduce = b.record_chain("reduce", |r| {
            r.par_loop(
                "sum",
                blk,
                [(0, 4), (0, 4), (0, 1)],
                kernel(|c| {
                    let v = c.r(0, 0, 0);
                    c.red_sum(0, v);
                }),
                vec![
                    Arg::dat(d, pt, Access::Read),
                    Arg::GblRed {
                        red: sum,
                        op: RedOp::Sum,
                    },
                ],
            );
        });
        let prog = Arc::new(b.freeze().unwrap());
        let mut s = Session::new(prog, &cfg(Platform::KnlFlatDdr4));
        s.run_chain(fill);
        s.run_chain(reduce);
        assert_eq!(s.reduction_result(sum), 16.0);
        assert_eq!(s.reduction_result(sum), 0.0, "handle resets");
        let freeze = s.metrics().program_freeze_s;
        s.reset_metrics();
        assert_eq!(s.metrics().analysis_builds, 0);
        assert_eq!(s.metrics().program_freeze_s, freeze);
    }

    #[test]
    fn rebind_engine_resets_prefetch_credit() {
        use crate::exec::{Engine, Metrics, NativeExecutor, World};
        use crate::memory::{GpuCalib, GpuExplicitEngine, GpuOpts};

        let (prog, step, _) = fixture();
        let mk_engine = || {
            GpuExplicitEngine::new(
                GpuCalib {
                    hbm_bytes: 4 << 10, // force several tiles on the 16x16 grid
                    ..GpuCalib::default()
                },
                AppCalib::CLOVERLEAF_2D,
                Link::PciE,
                GpuOpts::default(),
            )
            .unwrap()
        };

        // Price one chain on an engine directly (no Session): returns
        // the chain's modelled wall clock, leaving any earned prefetch
        // credit on the engine.
        let run_once = |e: &mut GpuExplicitEngine| -> f64 {
            let (wprog, wstep, _) = fixture();
            let spec = wprog.chain(wstep);
            let mut store = crate::ops::DataStore::new();
            wprog.datasets().iter().for_each(|d| store.alloc(d));
            let mut reds: Vec<crate::ops::Reduction> = vec![];
            let mut metrics = Metrics::new();
            let mut exec = NativeExecutor::new();
            let mut world = World {
                datasets: wprog.datasets(),
                stencils: wprog.stencils(),
                store: &mut store,
                reds: &mut reds,
                metrics: &mut metrics,
                exec: &mut exec,
            };
            e.run_chain(&spec.loops, &mut world, true);
            metrics.elapsed_s
        };

        // Control: the credit is real — on a bare engine, a second chain
        // models faster than the first (tile 0's upload is shortened).
        let mut warmed = mk_engine();
        let cold_direct = run_once(&mut warmed);
        let warm_direct = run_once(&mut warmed);
        assert!(
            warm_direct < cold_direct,
            "fixture must actually exercise the credit: {warm_direct} !< {cold_direct}"
        );

        // Baseline: a session on a cold engine.
        let mut cold = Session::with_engine(prog.clone(), Box::new(mk_engine()));
        cold.set_cyclic_phase(true);
        cold.replay(step, 1);
        let cold_t = cold.metrics().elapsed_s;

        // Rebinding the warmed engine (which now carries credit from two
        // chains this session never ran) must reproduce the cold clock:
        // the stale credit is reset at the rebind boundary.
        let mut s = Session::with_engine(prog.clone(), Box::new(mk_engine()));
        s.set_cyclic_phase(true);
        s.rebind_engine(Box::new(warmed));
        s.replay(step, 1);
        assert_eq!(
            s.metrics().elapsed_s,
            cold_t,
            "rebound engine must not carry prefetch credit"
        );

        // Binding a warmed engine at construction resets it too.
        let mut warmed2 = mk_engine();
        let _ = run_once(&mut warmed2);
        let mut fresh = Session::with_engine(prog, Box::new(warmed2));
        fresh.set_cyclic_phase(true);
        fresh.replay(step, 1);
        assert_eq!(
            fresh.metrics().elapsed_s,
            cold_t,
            "with_engine must not inherit prefetch credit either"
        );
    }

    #[test]
    fn rebind_engine_restarts_the_stream_ledger() {
        use crate::exec::Engine;
        use crate::memory::{GpuCalib, GpuExplicitEngine, GpuOpts, PlainEngine};

        let (prog, step, _) = fixture();
        let gpu = || -> Box<dyn Engine> {
            Box::new(
                GpuExplicitEngine::new(
                    GpuCalib {
                        hbm_bytes: 4 << 10, // force streaming on the 16x16 grid
                        ..GpuCalib::default()
                    },
                    AppCalib::CLOVERLEAF_2D,
                    Link::PciE,
                    GpuOpts::default(),
                )
                .unwrap(),
            )
        };
        let plain = || -> Box<dyn Engine> { Box::new(PlainEngine::knl_flat_ddr4(50.0)) };

        // Cold reference: the plain engine from the start.
        let mut cold = Session::with_engine(prog.clone(), plain());
        cold.replay(step, 2);
        let cold_keys: Vec<String> = cold.metrics().per_resource.keys().cloned().collect();

        // Streaming first, then rebind to plain: the ledger must not
        // keep reporting the streaming engine's upload/download rows.
        let mut s = Session::with_engine(prog.clone(), gpu());
        s.set_cyclic_phase(true);
        s.replay(step, 2);
        assert!(
            s.metrics().per_resource.contains_key("upload"),
            "precondition: the streaming engine attributed transfers"
        );
        s.rebind_engine(plain());
        s.replay(step, 2);
        let keys: Vec<String> = s.metrics().per_resource.keys().cloned().collect();
        assert_eq!(
            keys, cold_keys,
            "rebound session's stream ledger must match a cold session's"
        );
        assert!(!s.metrics().per_resource.contains_key("upload"));
        assert_eq!(s.metrics().bound(), cold.metrics().bound());
    }

    #[test]
    fn oom_flag_mirrors_engine_capacity() {
        let (prog, step, _) = fixture();
        let mut s = Session::with_engine(
            prog,
            Box::new(crate::memory::PlainEngine {
                bw_gbs: 100.0,
                mem_limit: Some(16),
                launch_s: 0.0,
                halo: None,
                label: "tiny".into(),
            }),
        );
        s.replay(step, 1);
        assert!(s.oom());
    }
}
