//! [`ProgramBuilder`] — declarations plus record-once chain capture —
//! and the frozen, immutable [`Program`] artifact it produces.

use crate::ops::surface::{Declare, Record};
use crate::ops::{
    Arg, Block, BlockId, Dataset, DatasetId, Kernel, KernelIr, LoopInst, Range3, RedOp, Reduction,
    ReductionId, Stencil, StencilId,
};
use crate::tiling::analysis::{chain_structure_fingerprint, fuse_chain, ChainAnalysis, Fnv};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Handle to one named, frozen chain of a [`Program`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ChainId(pub u32);

/// A named, frozen loop chain: the unit [`crate::program::Session::replay`]
/// executes. Recorded **once** (kernels close over their captured
/// arguments), then replayed any number of times.
pub struct ChainSpec {
    pub name: String,
    pub loops: Vec<LoopInst>,
}

/// Records loops into one [`ChainSpec`] during
/// [`ProgramBuilder::record_chain`]. Implements [`Record`], so any
/// app method that records loops can target a frozen chain unchanged.
pub struct ChainRecorder<'a> {
    datasets: &'a [Dataset],
    stencils: &'a [Stencil],
    name: String,
    loops: Vec<LoopInst>,
}

impl ChainRecorder<'_> {
    /// Loops recorded so far.
    pub fn len(&self) -> usize {
        self.loops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.loops.is_empty()
    }
}

impl Record for ChainRecorder<'_> {
    fn par_loop_eff(
        &mut self,
        name: &str,
        block: BlockId,
        range: Range3,
        kernel: Kernel,
        args: Vec<Arg>,
        bw_efficiency: f64,
    ) {
        validate_loop(&self.name, name, &args, self.datasets, self.stencils);
        let seq = self.loops.len() as u64;
        self.loops.push(LoopInst {
            name: name.to_string(),
            block,
            range,
            args,
            kernel,
            kernel_ir: None,
            seq,
            bw_efficiency,
        });
    }

    fn par_loop_ir(
        &mut self,
        name: &str,
        block: BlockId,
        range: Range3,
        ir: KernelIr,
        args: Vec<Arg>,
        bw_efficiency: f64,
    ) {
        validate_loop(&self.name, name, &args, self.datasets, self.stencils);
        let ir = Arc::new(ir);
        let seq = self.loops.len() as u64;
        self.loops.push(LoopInst {
            name: name.to_string(),
            block,
            range,
            args,
            kernel: ir.to_kernel(),
            kernel_ir: Some(ir),
            seq,
            bw_efficiency,
        });
    }
}

/// Validate handles + the no-aliasing contract of one recorded loop
/// (shared by the frozen recorder and the session's dynamic queue; same
/// panics as the legacy `OpsContext::par_loop`).
pub(crate) fn validate_loop(
    chain: &str,
    name: &str,
    args: &[Arg],
    datasets: &[Dataset],
    stencils: &[Stencil],
) {
    let mut written: Vec<DatasetId> = vec![];
    let mut seen: Vec<DatasetId> = vec![];
    for a in args {
        if let Arg::Dat { dat, stencil, acc } = a {
            assert!(
                (dat.0 as usize) < datasets.len(),
                "{chain}: loop {name}: undeclared dataset {dat:?}"
            );
            assert!(
                (stencil.0 as usize) < stencils.len(),
                "{chain}: loop {name}: undeclared stencil {stencil:?}"
            );
            if acc.writes() {
                written.push(*dat);
            }
            seen.push(*dat);
        }
    }
    for w in &written {
        assert!(
            seen.iter().filter(|d| *d == w).count() == 1,
            "{chain}: loop {name}: dataset {w:?} written while aliased by another argument"
        );
    }
}

/// Builds a [`Program`]: owns the declarations, records named frozen
/// chains, and validates everything at [`ProgramBuilder::freeze`].
///
/// Declaration errors (zero-sized blocks/datasets, zero element size,
/// negative halos) are *deferred*: the offending call still returns a
/// handle so declaration code stays linear, and `freeze` reports the
/// first problem as a typed [`crate::errors`] error — nothing is ever
/// silently planned over.
#[derive(Default)]
pub struct ProgramBuilder {
    blocks: Vec<Block>,
    datasets: Vec<Dataset>,
    stencils: Vec<Stencil>,
    reds: Vec<Reduction>,
    chains: Vec<ChainSpec>,
    /// Builder-level default for [`Declare::set_model_elem_bytes`];
    /// overridable per dataset via [`ProgramBuilder::decl_dat_elem`].
    elem_bytes: u64,
    errors: Vec<String>,
}

impl ProgramBuilder {
    pub fn new() -> Self {
        ProgramBuilder {
            elem_bytes: 8,
            ..Default::default()
        }
    }

    /// Declare a dataset with an explicit per-dataset element size,
    /// bypassing the builder default — the fix for the legacy
    /// `set_model_elem_bytes` footgun (which silently applied only to
    /// *subsequently* declared datasets).
    pub fn decl_dat_elem(
        &mut self,
        block: BlockId,
        name: &str,
        size: [usize; 3],
        halo_lo: [i32; 3],
        halo_hi: [i32; 3],
        elem_bytes: u64,
    ) -> DatasetId {
        let id = DatasetId(self.datasets.len() as u32);
        if size.iter().any(|&s| s == 0) {
            self.errors.push(format!(
                "dataset {name:?}: zero-sized interior {size:?} (every dimension must be >= 1)"
            ));
        }
        if elem_bytes == 0 {
            self.errors
                .push(format!("dataset {name:?}: element size must be >= 1 byte"));
        }
        if halo_lo.iter().chain(&halo_hi).any(|&h| h < 0) {
            self.errors.push(format!(
                "dataset {name:?}: negative halo depth ({halo_lo:?}/{halo_hi:?})"
            ));
        }
        if (block.0 as usize) >= self.blocks.len() {
            self.errors
                .push(format!("dataset {name:?}: undeclared block {block:?}"));
        }
        self.datasets.push(Dataset {
            id,
            block,
            name: name.to_string(),
            size,
            halo_lo,
            halo_hi,
            elem_bytes,
        });
        id
    }

    /// Record the loops `f` emits as the named frozen chain; returns its
    /// replay handle. The chain's dependency/footprint/skew analysis is
    /// computed once, at [`ProgramBuilder::freeze`] — never at replay.
    pub fn record_chain<F>(&mut self, name: &str, f: F) -> ChainId
    where
        F: FnOnce(&mut ChainRecorder<'_>),
    {
        let mut rec = ChainRecorder {
            datasets: &self.datasets,
            stencils: &self.stencils,
            name: name.to_string(),
            loops: Vec::new(),
        };
        f(&mut rec);
        let loops = rec.loops;
        let id = ChainId(self.chains.len() as u32);
        self.chains.push(ChainSpec {
            name: name.to_string(),
            loops,
        });
        id
    }

    /// Modelled total bytes of all declared datasets (used to size the
    /// model-scale factor before freezing).
    pub fn problem_bytes(&self) -> u64 {
        self.datasets.iter().map(|d| d.bytes()).sum()
    }

    pub fn datasets(&self) -> &[Dataset] {
        &self.datasets
    }

    pub fn stencils(&self) -> &[Stencil] {
        &self.stencils
    }

    /// Validate and freeze into an immutable [`Program`]:
    ///
    /// * deferred declaration errors surface first;
    /// * every recorded loop's stencil reach is checked against the
    ///   declared halo depths (typed error naming the dataset and the
    ///   offending offset — replacing the planner's silent out-of-bounds
    ///   clamp for frozen chains);
    /// * each chain's [`ChainAnalysis`] is computed and stored, and the
    ///   whole artifact is fingerprinted.
    pub fn freeze(self) -> crate::Result<Program> {
        let t0 = std::time::Instant::now();
        let sp = crate::obs::span("freeze");
        sp.field("chains", self.chains.len());
        sp.field("datasets", self.datasets.len());
        if let Some(e) = self.errors.first() {
            crate::bail!("program declaration error: {e}");
        }
        for spec in &self.chains {
            for l in &spec.loops {
                validate_stencil_reach(&spec.name, l, &self.datasets, &self.stencils)?;
            }
        }
        // Compile every distinct kernel IR's row plan now, so replay
        // never pays the lazy compile; count the vectorisable ones for
        // the report (`kir_kernels_compiled`).
        let mut seen_irs: Vec<*const KernelIr> = Vec::new();
        let mut kir_compiled = 0u64;
        for spec in &self.chains {
            for l in &spec.loops {
                if let Some(ir) = &l.kernel_ir {
                    let p = Arc::as_ptr(ir);
                    if !seen_irs.contains(&p) {
                        seen_irs.push(p);
                        if ir.is_vectorizable() {
                            kir_compiled += 1;
                        }
                    }
                }
            }
        }
        let analyses: Vec<Arc<ChainAnalysis>> = self
            .chains
            .iter()
            .map(|c| {
                let asp = crate::obs::span("analyze");
                asp.field("chain", &c.name);
                asp.field("loops", c.loops.len());
                Arc::new(ChainAnalysis::build(&c.loops, &self.datasets, &self.stencils))
            })
            .collect();
        let mut h = Fnv::new();
        h.write_u64(chain_structure_fingerprint(&[], &self.datasets, &self.stencils));
        h.write_u64(self.chains.len() as u64);
        for a in &analyses {
            h.write_u64(a.fingerprint);
        }
        Ok(Program {
            blocks: self.blocks,
            datasets: self.datasets,
            stencils: self.stencils,
            reds: self.reds,
            chains: self.chains,
            analyses,
            fused: Mutex::new(HashMap::new()),
            fingerprint: h.finish(),
            freeze_s: t0.elapsed().as_secs_f64(),
            kir_compiled,
        })
    }
}

/// A memoised temporal super-chain: `k` consecutive time steps of one
/// frozen chain concatenated into a single replayable chain, with the
/// cross-step skew analysis precomputed
/// ([`crate::tiling::analysis::ChainAnalysis::build_fused`]). Built
/// lazily by [`Program::fused`] and shared by every
/// [`crate::program::Session`] replaying the program.
pub struct FusedChain {
    /// `k` concatenated copies of the base chain's loops.
    pub loops: Vec<LoopInst>,
    /// Time steps one run of `loops` advances.
    pub k: u32,
    /// The super-chain's analysis, cross-step shifts included.
    pub analysis: Arc<ChainAnalysis>,
}

/// Freeze-time stencil validation: every declared access of every
/// recorded loop must stay inside the dataset's halo-padded extent.
fn validate_stencil_reach(
    chain: &str,
    l: &LoopInst,
    datasets: &[Dataset],
    stencils: &[Stencil],
) -> crate::Result<()> {
    for (dat, st, _) in l.dat_args() {
        let ds = &datasets[dat.0 as usize];
        let s = &stencils[st.0 as usize];
        for d in 0..3 {
            let (lo, hi) = l.range[d];
            if hi <= lo {
                continue;
            }
            let dlo = -(ds.halo_lo[d] as isize);
            let dhi = ds.size[d] as isize + ds.halo_hi[d] as isize - 1;
            for p in &s.points {
                let reach_lo = lo + p[d] as isize;
                let reach_hi = hi - 1 + p[d] as isize;
                crate::ensure!(
                    reach_lo >= dlo && reach_hi <= dhi,
                    "chain {chain:?}: loop {:?}: stencil {:?} offset {p:?} reaches \
                     index {} of dataset {:?} along dim {d} (valid {dlo}..={dhi} \
                     for halo depths {:?}/{:?})",
                    l.name,
                    s.name,
                    if reach_lo < dlo { reach_lo } else { reach_hi },
                    ds.name,
                    ds.halo_lo,
                    ds.halo_hi,
                );
            }
        }
    }
    Ok(())
}

impl Declare for ProgramBuilder {
    fn set_model_elem_bytes(&mut self, elem_bytes: u64) {
        if elem_bytes == 0 {
            self.errors
                .push("model element size must be >= 1 byte".to_string());
        }
        self.elem_bytes = elem_bytes.max(1);
    }

    fn decl_block(&mut self, name: &str, size: [usize; 3]) -> BlockId {
        let id = BlockId(self.blocks.len() as u32);
        if size[0] == 0 || size[1] == 0 {
            self.errors.push(format!(
                "block {name:?}: zero-sized extent {size:?} (x and y must be >= 1)"
            ));
        }
        let dims = if size[2] > 1 { 3 } else { 2 };
        self.blocks.push(Block {
            id,
            name: name.to_string(),
            size,
            dims,
        });
        id
    }

    fn decl_dat(
        &mut self,
        block: BlockId,
        name: &str,
        size: [usize; 3],
        halo_lo: [i32; 3],
        halo_hi: [i32; 3],
    ) -> DatasetId {
        let elem = self.elem_bytes;
        self.decl_dat_elem(block, name, size, halo_lo, halo_hi, elem)
    }

    fn decl_stencil(&mut self, name: &str, points: Vec<[i32; 3]>) -> StencilId {
        let id = StencilId(self.stencils.len() as u32);
        self.stencils.push(Stencil {
            id,
            name: name.to_string(),
            points,
        });
        id
    }

    fn decl_reduction(&mut self, name: &str, op: RedOp) -> ReductionId {
        let id = ReductionId(self.reds.len() as u32);
        self.reds.push(Reduction::new(id, name, op));
        id
    }
}

/// An immutable, fingerprintable execution artifact: declarations,
/// named frozen chains, and their once-computed analyses. Share one
/// `Arc<Program>` across any number of [`crate::program::Session`]s —
/// different platforms, modelled ranks, or tuner candidates.
pub struct Program {
    blocks: Vec<Block>,
    datasets: Vec<Dataset>,
    stencils: Vec<Stencil>,
    reds: Vec<Reduction>,
    chains: Vec<ChainSpec>,
    analyses: Vec<Arc<ChainAnalysis>>,
    /// Lazily-built fused super-chains, keyed by (chain, k). Interior
    /// mutability keeps the frozen artifact shareable as `Arc<Program>`
    /// while letting the first fused replay pay the unroll once.
    fused: Mutex<HashMap<(u32, u32), Arc<FusedChain>>>,
    fingerprint: u64,
    freeze_s: f64,
    /// Distinct kernel IRs that compiled to a vector row plan at freeze.
    kir_compiled: u64,
}

impl Program {
    pub fn blocks(&self) -> &[Block] {
        &self.blocks
    }

    pub fn datasets(&self) -> &[Dataset] {
        &self.datasets
    }

    pub fn dataset(&self, id: DatasetId) -> &Dataset {
        &self.datasets[id.0 as usize]
    }

    pub fn stencils(&self) -> &[Stencil] {
        &self.stencils
    }

    /// The reduction-slot template; each Session clones its own copy.
    pub fn reductions(&self) -> &[Reduction] {
        &self.reds
    }

    pub fn chains(&self) -> &[ChainSpec] {
        &self.chains
    }

    pub fn chain(&self, id: ChainId) -> &ChainSpec {
        &self.chains[id.0 as usize]
    }

    pub fn chain_by_name(&self, name: &str) -> Option<ChainId> {
        self.chains
            .iter()
            .position(|c| c.name == name)
            .map(|i| ChainId(i as u32))
    }

    /// The frozen analysis of one chain (computed at freeze time).
    pub fn analysis(&self, id: ChainId) -> &Arc<ChainAnalysis> {
        &self.analyses[id.0 as usize]
    }

    /// The fused super-chain of `k` consecutive steps of `id`, unrolled
    /// and analysed on first request and memoised for the life of the
    /// program. Returns the chain plus whether this call built it (the
    /// caller accounts `analysis_builds` vs `analysis_reuse_hits`).
    /// `k` is clamped to at least 1; `k = 1` memoises a copy of the
    /// base chain under the same machinery.
    pub fn fused(&self, id: ChainId, k: u32) -> (Arc<FusedChain>, bool) {
        let k = k.max(1);
        // Recover from poisoning: the memo is shared by every session
        // (tenant) of this program, and a tenant panicking mid-build
        // must not wedge it for the rest. Recovery is sound — the only
        // write is the insert of a fully-built Arc after the build
        // succeeds, so a poisoned map holds no partial entry.
        let mut memo = self.fused.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(f) = memo.get(&(id.0, k)) {
            return (f.clone(), false);
        }
        let sp = crate::obs::span("fuse-analyze");
        let spec = &self.chains[id.0 as usize];
        sp.field("chain", &spec.name);
        sp.field("k", k);
        let f = Arc::new(FusedChain {
            loops: fuse_chain(&spec.loops, k as usize),
            k,
            analysis: Arc::new(ChainAnalysis::build_fused(
                &spec.loops,
                &self.datasets,
                &self.stencils,
                k as usize,
            )),
        });
        memo.insert((id.0, k), f.clone());
        (f, true)
    }

    /// Structural digest of the whole artifact (declarations + every
    /// chain) — what the auto-tuner keys its cache on instead of
    /// re-hashing raw chains.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Host seconds the freeze (validation + per-chain analysis) took.
    pub fn freeze_s(&self) -> f64 {
        self.freeze_s
    }

    /// Distinct kernel IRs that compiled to a vector row plan at freeze
    /// time (the [`crate::exec::VectorExecutor`] fast path).
    pub fn kir_kernels_compiled(&self) -> u64 {
        self.kir_compiled
    }

    /// Modelled total bytes of all declared datasets.
    pub fn problem_bytes(&self) -> u64 {
        self.datasets.iter().map(|d| d.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::shapes;
    use crate::ops::Access;

    fn small_builder() -> (ProgramBuilder, BlockId, DatasetId, StencilId) {
        let mut b = ProgramBuilder::new();
        let blk = b.decl_block("g", [8, 8, 1]);
        let d = b.decl_dat(blk, "d", [8, 8, 1], [1, 1, 0], [1, 1, 0]);
        let s = b.decl_stencil("pt", shapes::point());
        (b, blk, d, s)
    }

    #[test]
    fn record_freeze_and_lookup() {
        let (mut b, blk, d, s) = small_builder();
        let id = b.record_chain("step", |r| {
            r.par_loop(
                "w",
                blk,
                [(0, 8), (0, 8), (0, 1)],
                kernel(|c| c.w(0, 0, 0, 1.0)),
                vec![Arg::dat(d, s, Access::Write)],
            );
        });
        let p = b.freeze().unwrap();
        assert_eq!(p.chain(id).loops.len(), 1);
        assert_eq!(p.chain_by_name("step"), Some(id));
        assert_eq!(p.chain_by_name("nope"), None);
        assert_eq!(p.analysis(id).shifts.len(), 1);
        assert!(p.fingerprint() != 0);
        assert!(p.freeze_s() >= 0.0);
        assert_eq!(p.problem_bytes(), 10 * 10 * 8);
    }

    #[test]
    fn fused_memo_recovers_from_poisoning() {
        let (mut b, blk, d, s) = small_builder();
        let id = b.record_chain("step", |r| {
            r.par_loop(
                "w",
                blk,
                [(0, 8), (0, 8), (0, 1)],
                kernel(|c| c.w(0, 0, 0, 1.0)),
                vec![Arg::dat(d, s, Access::Write)],
            );
        });
        let p = b.freeze().unwrap();
        let (f1, built) = p.fused(id, 2);
        assert!(built);
        // Poison the memo the way a panicking tenant would: unwind
        // while holding the guard (poisoning is per-mutex, not
        // per-thread, so same-thread catch_unwind reproduces it).
        let poison = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = p.fused.lock().unwrap_or_else(|e| e.into_inner());
            panic!("tenant panicked while holding the fused memo");
        }));
        assert!(poison.is_err());
        assert!(p.fused.is_poisoned(), "the panic must actually poison");
        // Other tenants of the shared program still hit the memo...
        let (f2, built2) = p.fused(id, 2);
        assert!(!built2, "memoised entry survives the poisoning");
        assert!(Arc::ptr_eq(&f1, &f2));
        // ...and can still build new depths.
        let (_, built3) = p.fused(id, 3);
        assert!(built3);
    }

    #[test]
    fn fingerprint_is_shape_sensitive() {
        let mk = |ny: isize| {
            let (mut b, blk, d, s) = small_builder();
            b.record_chain("step", |r| {
                r.par_loop(
                    "w",
                    blk,
                    [(0, 8), (0, ny), (0, 1)],
                    kernel(|c| c.w(0, 0, 0, 1.0)),
                    vec![Arg::dat(d, s, Access::Write)],
                );
            });
            b.freeze().unwrap().fingerprint()
        };
        assert_eq!(mk(8), mk(8));
        assert_ne!(mk(8), mk(4));
    }

    #[test]
    fn zero_sized_declarations_are_typed_errors() {
        let mut b = ProgramBuilder::new();
        let blk = b.decl_block("g", [0, 8, 1]);
        let _ = blk;
        let e = b.freeze().unwrap_err().to_string();
        assert!(e.contains("zero-sized"), "{e}");

        let mut b = ProgramBuilder::new();
        let blk = b.decl_block("g", [8, 8, 1]);
        b.decl_dat(blk, "empty", [8, 0, 1], [0; 3], [0; 3]);
        let e = b.freeze().unwrap_err().to_string();
        assert!(e.contains("empty") && e.contains("zero-sized"), "{e}");
    }

    #[test]
    fn zero_elem_bytes_is_a_typed_error() {
        let mut b = ProgramBuilder::new();
        let blk = b.decl_block("g", [8, 8, 1]);
        b.decl_dat_elem(blk, "d", [8, 8, 1], [0; 3], [0; 3], 0);
        let e = b.freeze().unwrap_err().to_string();
        assert!(e.contains("element size"), "{e}");
    }

    #[test]
    fn per_dataset_elem_bytes_overrides_builder_default() {
        let mut b = ProgramBuilder::new();
        let blk = b.decl_block("g", [8, 8, 1]);
        b.set_model_elem_bytes(8 * 1024);
        let scaled = b.decl_dat(blk, "scaled", [8, 8, 1], [0; 3], [0; 3]);
        let exact = b.decl_dat_elem(blk, "exact", [8, 8, 1], [0; 3], [0; 3], 8);
        let p = b.freeze().unwrap();
        assert_eq!(p.dataset(scaled).elem_bytes, 8 * 1024);
        assert_eq!(p.dataset(exact).elem_bytes, 8);
    }

    #[test]
    fn stencil_reach_beyond_halo_fails_freeze_with_named_offset() {
        let (mut b, blk, d, _) = small_builder();
        let wide = b.decl_stencil("star2", shapes::star2d(2)); // halo is 1
        b.record_chain("bad", |r| {
            r.par_loop(
                "read_wide",
                blk,
                [(0, 8), (0, 8), (0, 1)],
                kernel(|_| {}),
                vec![Arg::dat(d, wide, Access::Read)],
            );
        });
        let e = b.freeze().unwrap_err().to_string();
        assert!(e.contains("\"d\""), "names the dataset: {e}");
        assert!(e.contains("star2"), "names the stencil: {e}");
        assert!(e.contains("bad"), "names the chain: {e}");
        assert!(e.contains('['), "names the offending offset: {e}");
    }

    #[test]
    fn stencil_within_halo_freezes_fine() {
        let (mut b, blk, d, _) = small_builder();
        let star = b.decl_stencil("star1", shapes::star2d(1));
        b.record_chain("ok", |r| {
            r.par_loop(
                "read",
                blk,
                [(0, 8), (0, 8), (0, 1)],
                kernel(|_| {}),
                vec![Arg::dat(d, star, Access::Read)],
            );
        });
        assert!(b.freeze().is_ok());
    }

    #[test]
    #[should_panic(expected = "aliased")]
    fn recorder_rejects_aliased_writes() {
        let (mut b, blk, d, s) = small_builder();
        b.record_chain("bad", |r| {
            r.par_loop(
                "alias",
                blk,
                [(0, 8), (0, 8), (0, 1)],
                kernel(|_| {}),
                vec![
                    Arg::dat(d, s, Access::Write),
                    Arg::dat(d, s, Access::Read),
                ],
            );
        });
    }
}
