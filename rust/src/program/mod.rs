//! The record-once / replay-many execution API.
//!
//! The legacy [`crate::ops::OpsContext`] is a god object: declarations,
//! lazy queue, engine, executor and metrics in one struct, with the
//! chain dependency/footprint analysis re-run at **every** flush even
//! though a time-stepped stencil code replays the same chain thousands
//! of times. This module splits it into three layers:
//!
//! 1. [`ProgramBuilder`] — owns blocks/datasets/stencils/reductions and
//!    records loops into named, frozen [`ChainSpec`]s via
//!    [`ProgramBuilder::record_chain`]. A step is recorded **once**,
//!    closing over its handle arguments, not re-issued per iteration.
//!    Declaration errors (zero-sized blocks/datasets, zero element
//!    sizes) and stencil reach beyond declared halos are typed
//!    [`crate::errors`] errors at [`ProgramBuilder::freeze`].
//! 2. [`Program`] — an immutable, fingerprintable artifact whose
//!    per-chain footprint/dependency/skew analysis
//!    ([`crate::tiling::analysis::ChainAnalysis`]) is computed once at
//!    freeze time and stored with it.
//! 3. [`Session`] — binds a `Arc<Program>` to an engine + executor +
//!    data store + metrics; [`Session::replay`] drives execution, and
//!    multiple independent sessions share one program (different
//!    platforms, modelled ranks, or tuner candidates).
//!
//! Sessions also accept dynamically recorded loops (apps whose chains
//! depend on data, e.g. CloverLeaf's `dt`): the recorded chain's
//! analysis is memoised by structural fingerprint, so identical shapes
//! re-recorded every step still amortise the analysis — the run-time
//! tiling result of Reguly et al. (1704.00693). Reuse is visible as
//! `analysis_builds` / `analysis_reuse_hits` / `program_freeze_s` in
//! [`crate::exec::Metrics`] and the `--json` record.

pub mod builder;
pub mod session;

pub use builder::{ChainId, ChainRecorder, ChainSpec, FusedChain, Program, ProgramBuilder};
pub use session::Session;
