//! Lazy execution: parallel loops are recorded, not run (§3).
//!
//! The queue accumulates [`LoopInst`]s until an API call that returns
//! data to user space (a reduction result, a dataset fetch) forces the
//! chain to execute. The longer the chain, the more loops the tiling
//! analysis can fuse over — OPS cannot "see ahead" past a trigger point,
//! which is exactly why the Cyclic optimisation of §4.1 needs an
//! application-provided flag.

use crate::ops::LoopInst;

/// The deferred loop queue.
#[derive(Default)]
pub struct LoopQueue {
    pending: Vec<LoopInst>,
    next_seq: u64,
    /// Total loops ever enqueued.
    pub total_enqueued: u64,
    /// Number of chain executions triggered.
    pub flushes: u64,
}

impl LoopQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a loop; assigns its sequence number.
    pub fn push(&mut self, mut l: LoopInst) {
        l.seq = self.next_seq;
        self.next_seq += 1;
        self.total_enqueued += 1;
        self.pending.push(l);
    }

    /// Take the pending chain for execution (trigger point reached).
    pub fn take_chain(&mut self) -> Vec<LoopInst> {
        if !self.pending.is_empty() {
            self.flushes += 1;
        }
        std::mem::take(&mut self.pending)
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::kernel::kernel;
    use crate::ops::BlockId;

    fn lp() -> LoopInst {
        LoopInst {
            name: "l".into(),
            block: BlockId(0),
            range: [(0, 1), (0, 1), (0, 1)],
            args: vec![],
            kernel: kernel(|_| {}),
            kernel_ir: None,
            seq: 0,
            bw_efficiency: 1.0,
        }
    }

    #[test]
    fn sequence_numbers_are_global() {
        let mut q = LoopQueue::new();
        q.push(lp());
        q.push(lp());
        let c1 = q.take_chain();
        assert_eq!(c1.len(), 2);
        assert_eq!(c1[1].seq, 1);
        q.push(lp());
        let c2 = q.take_chain();
        assert_eq!(c2[0].seq, 2, "seq continues across chains");
        assert_eq!(q.flushes, 2);
        assert_eq!(q.total_enqueued, 3);
    }

    #[test]
    fn empty_flush_not_counted() {
        let mut q = LoopQueue::new();
        assert!(q.take_chain().is_empty());
        assert_eq!(q.flushes, 0);
    }
}
