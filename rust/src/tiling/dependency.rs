//! Cross-loop dependency analysis: per-loop skew shifts and per-dataset
//! chain-level access classification.

use crate::ops::{Access, DatasetId, LoopInst, Stencil};
use std::collections::HashMap;

/// Chain-level summary of how one dataset is used — drives the §4.1
/// data-movement optimisations.
#[derive(Debug, Clone, Default)]
pub struct DatChainInfo {
    /// Dataset is read somewhere in the chain.
    pub read: bool,
    /// Dataset is written somewhere in the chain.
    pub written: bool,
    /// The first touch is a pure `Write` over the touching loop's range —
    /// previous contents are dead, so the dataset need not be uploaded.
    pub write_first: bool,
}

impl DatChainInfo {
    /// Read-only datasets are never copied back (§4.1 opt 1a).
    pub fn skip_download(&self) -> bool {
        !self.written
    }
    /// Write-first datasets are never uploaded (§4.1 opt 1b).
    pub fn skip_upload(&self) -> bool {
        self.write_first
    }
}

/// Summarise chain-level access per dataset.
pub fn chain_access_summary(chain: &[LoopInst]) -> HashMap<DatasetId, DatChainInfo> {
    let mut out: HashMap<DatasetId, DatChainInfo> = HashMap::new();
    for l in chain {
        for (dat, _st, acc) in l.dat_args() {
            let e = out.entry(dat).or_default();
            let first_touch = !e.read && !e.written;
            if first_touch && acc == Access::Write {
                e.write_first = true;
            }
            if acc.reads() {
                // A read before any write disqualifies write-first; a read
                // *after* the first write keeps it (the data is produced
                // within the chain).
                if !e.written {
                    e.write_first = false;
                }
                e.read = true;
            }
            if acc.writes() {
                e.written = true;
            }
        }
    }
    out
}

/// Compute per-loop skew shifts along `tile_dim`.
///
/// Invariant established: for any two loops `l < l'` with a dependency on
/// dataset `D` (flow: `l` writes, `l'` reads; anti: `l` reads, `l'`
/// writes; output: both write), we require
/// `shift(l) >= shift(l') + radius(reader's stencil on D)`, so that by the
/// time tile `t` runs loop `l'`, every point it touches (within ±radius of
/// its sub-range) has already been produced by loop `l` in tiles `<= t`,
/// and no point still needed by a later tile's `l'` has been overwritten.
///
/// Shifts come purely from the (transitive) dependency constraints;
/// independent loops keep shift 0, so unrelated boundary strips don't
/// inflate the skew. The last loop always has shift 0.
pub fn compute_shifts(chain: &[LoopInst], stencils: &[Stencil], tile_dim: usize) -> Vec<isize> {
    let n = chain.len();
    let mut shifts = vec![0isize; n];
    if n == 0 {
        return shifts;
    }
    // Walk backward; for loop l, look at all later loops l' and collect
    // dependency constraints. O(L^2 · args) — fine for chains of a few
    // hundred loops (CloverLeaf 3D: ~600), and measured in the perf pass.
    for l in (0..n.saturating_sub(1)).rev() {
        let mut s = 0isize; // pure dependency constraints
        for lp in (l + 1)..n {
            for (dat_l, st_l, acc_l) in chain[l].dat_args() {
                for (dat_p, st_p, acc_p) in chain[lp].dat_args() {
                    if dat_l != dat_p {
                        continue;
                    }
                    // flow: l writes, l' reads -> reader is l'
                    if acc_l.writes() && acc_p.reads() {
                        let r = stencils[st_p.0 as usize].radius(tile_dim) as isize;
                        s = s.max(shifts[lp] + r);
                    }
                    // anti: l reads, l' writes -> reader is l
                    if acc_l.reads() && acc_p.writes() {
                        let r = stencils[st_l.0 as usize].radius(tile_dim) as isize;
                        s = s.max(shifts[lp] + r);
                    }
                    // output: both write -> no reordering of the same
                    // point across tiles (shift(l) >= shift(l'))
                    if acc_l.writes() && acc_p.writes() {
                        s = s.max(shifts[lp]);
                    }
                }
            }
        }
        shifts[l] = s;
    }
    shifts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::{shapes, StencilId};
    use crate::ops::{Arg, BlockId, DatasetId};

    fn st(id: u32, pts: Vec<[i32; 3]>) -> Stencil {
        Stencil {
            id: StencilId(id),
            name: format!("s{id}"),
            points: pts,
        }
    }

    fn lp(args: Vec<Arg>) -> LoopInst {
        LoopInst {
            name: "l".into(),
            block: BlockId(0),
            range: [(0, 16), (0, 16), (0, 1)],
            args,
            kernel: kernel(|_| {}),
            seq: 0,
            bw_efficiency: 1.0,
        }
    }

    #[test]
    fn flow_dependency_accumulates_radius() {
        let stencils = vec![st(0, shapes::point()), st(1, shapes::star2d(1))];
        // l0 writes A; l1 reads A (r=1), writes B; l2 reads B (r=1), writes C.
        let chain = vec![
            lp(vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)]),
            lp(vec![
                Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                Arg::dat(DatasetId(1), StencilId(0), Access::Write),
            ]),
            lp(vec![
                Arg::dat(DatasetId(1), StencilId(1), Access::Read),
                Arg::dat(DatasetId(2), StencilId(0), Access::Write),
            ]),
        ];
        let shifts = compute_shifts(&chain, &stencils, 1);
        assert_eq!(shifts, vec![2, 1, 0]);
    }

    #[test]
    fn independent_loops_have_zero_shift() {
        let stencils = vec![st(0, shapes::point())];
        let chain = vec![
            lp(vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)]),
            lp(vec![Arg::dat(DatasetId(1), StencilId(0), Access::Write)]),
        ];
        let shifts = compute_shifts(&chain, &stencils, 1);
        assert_eq!(shifts, vec![0, 0]);
    }

    #[test]
    fn anti_dependency_uses_reader_radius() {
        let stencils = vec![st(0, shapes::point()), st(1, shapes::star2d(2))];
        // l0 reads A with radius 2; l1 writes A.
        let chain = vec![
            lp(vec![
                Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                Arg::dat(DatasetId(1), StencilId(0), Access::Write),
            ]),
            lp(vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)]),
        ];
        let shifts = compute_shifts(&chain, &stencils, 1);
        assert_eq!(shifts, vec![2, 0]);
    }

    #[test]
    fn chain_summary_classifies() {
        let chain = vec![
            // A: write-first temp; B: read-only; C: read then written
            lp(vec![
                Arg::dat(DatasetId(0), StencilId(0), Access::Write),
                Arg::dat(DatasetId(1), StencilId(0), Access::Read),
            ]),
            lp(vec![
                Arg::dat(DatasetId(0), StencilId(0), Access::Read),
                Arg::dat(DatasetId(2), StencilId(0), Access::Read),
            ]),
            lp(vec![Arg::dat(DatasetId(2), StencilId(0), Access::Write)]),
        ];
        let s = chain_access_summary(&chain);
        assert!(s[&DatasetId(0)].write_first);
        assert!(s[&DatasetId(0)].skip_upload());
        assert!(!s[&DatasetId(0)].skip_download());
        assert!(s[&DatasetId(1)].skip_download());
        assert!(!s[&DatasetId(1)].skip_upload());
        assert!(!s[&DatasetId(2)].skip_upload());
        assert!(!s[&DatasetId(2)].skip_download());
    }

    #[test]
    fn rw_first_touch_is_not_write_first() {
        let chain = vec![lp(vec![Arg::dat(
            DatasetId(0),
            StencilId(0),
            Access::ReadWrite,
        )])];
        let s = chain_access_summary(&chain);
        assert!(!s[&DatasetId(0)].write_first);
    }
}
