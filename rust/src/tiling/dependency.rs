//! Cross-loop dependency analysis: per-loop skew shifts and per-dataset
//! chain-level access classification.

use crate::ops::{Access, DatasetId, LoopInst, Stencil};
use std::collections::HashMap;

/// Chain-level summary of how one dataset is used — drives the §4.1
/// data-movement optimisations.
#[derive(Debug, Clone, Default)]
pub struct DatChainInfo {
    /// Dataset is read somewhere in the chain.
    pub read: bool,
    /// Dataset is written somewhere in the chain.
    pub written: bool,
    /// The first touch is a pure `Write` over the touching loop's range —
    /// previous contents are dead, so the dataset need not be uploaded.
    pub write_first: bool,
}

impl DatChainInfo {
    /// Read-only datasets are never copied back (§4.1 opt 1a).
    pub fn skip_download(&self) -> bool {
        !self.written
    }
    /// Write-first datasets are never uploaded (§4.1 opt 1b).
    pub fn skip_upload(&self) -> bool {
        self.write_first
    }
}

/// Summarise chain-level access per dataset.
pub fn chain_access_summary(chain: &[LoopInst]) -> HashMap<DatasetId, DatChainInfo> {
    let mut out: HashMap<DatasetId, DatChainInfo> = HashMap::new();
    for l in chain {
        for (dat, _st, acc) in l.dat_args() {
            let e = out.entry(dat).or_default();
            let first_touch = !e.read && !e.written;
            if first_touch && acc == Access::Write {
                e.write_first = true;
            }
            if acc.reads() {
                // A read before any write disqualifies write-first; a read
                // *after* the first write keeps it (the data is produced
                // within the chain).
                if !e.written {
                    e.write_first = false;
                }
                e.read = true;
            }
            if acc.writes() {
                e.written = true;
            }
        }
    }
    out
}

/// Compute per-loop skew shifts along `tile_dim`.
///
/// Invariant established: for any two loops `l < l'` with a dependency on
/// dataset `D` (flow: `l` writes, `l'` reads; anti: `l` reads, `l'`
/// writes; output: both write), we require
/// `shift(l) >= shift(l') + radius(reader's stencil on D)`, so that by the
/// time tile `t` runs loop `l'`, every point it touches (within ±radius of
/// its sub-range) has already been produced by loop `l` in tiles `<= t`,
/// and no point still needed by a later tile's `l'` has been overwritten.
///
/// Shifts come purely from the (transitive) dependency constraints;
/// independent loops keep shift 0, so unrelated boundary strips don't
/// inflate the skew. The last loop always has shift 0.
pub fn compute_shifts(chain: &[LoopInst], stencils: &[Stencil], tile_dim: usize) -> Vec<isize> {
    let n = chain.len();
    let mut shifts = vec![0isize; n];
    if n == 0 {
        return shifts;
    }
    // Walk backward; for loop l, look at all later loops l' and collect
    // dependency constraints. O(L^2 · args) — fine for chains of a few
    // hundred loops (CloverLeaf 3D: ~600), and measured in the perf pass.
    for l in (0..n.saturating_sub(1)).rev() {
        let mut s = 0isize; // pure dependency constraints
        for lp in (l + 1)..n {
            if let Some(r) = dep_radius(&chain[l], &chain[lp], stencils, tile_dim) {
                s = s.max(shifts[lp] + r);
            }
        }
        shifts[l] = s;
    }
    shifts
}

/// The skew constraint one ordered loop pair contributes, if any: the
/// maximum over every shared-dataset argument pair of the dependency's
/// reader radius along `tile_dim` (flow: `earlier` writes / `later`
/// reads — the later stencil's radius; anti: `earlier` reads / `later`
/// writes — the earlier stencil's radius; output: both write — 0).
/// `None` means the pair is independent: it must contribute no shift.
///
/// This is the per-pair kernel [`compute_shifts`] folds backward over a
/// chain, factored out so [`compute_fused_shifts`] can evaluate the
/// same constraint between loops of *different* time steps of a fused
/// super-chain (the pair's constraint depends only on the two loops'
/// access modes and stencils, never on their positions).
pub fn dep_radius(
    earlier: &LoopInst,
    later: &LoopInst,
    stencils: &[Stencil],
    tile_dim: usize,
) -> Option<isize> {
    let mut out: Option<isize> = None;
    for (dat_e, st_e, acc_e) in earlier.dat_args() {
        for (dat_l, st_l, acc_l) in later.dat_args() {
            if dat_e != dat_l {
                continue;
            }
            let mut hit = |r: isize| out = Some(out.map_or(r, |c| c.max(r)));
            // flow: earlier writes, later reads -> reader is `later`
            if acc_e.writes() && acc_l.reads() {
                hit(stencils[st_l.0 as usize].radius(tile_dim) as isize);
            }
            // anti: earlier reads, later writes -> reader is `earlier`
            if acc_e.reads() && acc_l.writes() {
                hit(stencils[st_e.0 as usize].radius(tile_dim) as isize);
            }
            // output: both write -> no reordering of the same point
            // across tiles (shift(earlier) >= shift(later))
            if acc_e.writes() && acc_l.writes() {
                hit(0);
            }
        }
    }
    out
}

/// Per-loop skew shifts for a *fused super-chain*: `k` consecutive time
/// steps of `chain` run back-to-back as one chain of `k · chain.len()`
/// loops. Returns the shifts in super-chain order (step 0's loops
/// first), bit-identical to `compute_shifts` on the concatenated chain
/// but in O(k·L²·A²) instead of O((kL)²·A²).
///
/// The recurrence walks steps backward: step `k-1` gets the base
/// [`compute_shifts`] result, and step `s` layers the cross-step
/// constraints of step `s+1` on top —
/// `S_s(l) = max(0, max_{l'>l} dep ⇒ S_s(l')+r, max_{l'} dep ⇒ S_{s+1}(l')+r)`.
/// Cross-step dependencies at distance ≥ 2 need no terms of their own:
/// whenever loops `(l, l')` depend at distance `d`, the same pair
/// depends at distance 1 with the same radius (the constraint is
/// position-independent), and shifts are monotone non-increasing in the
/// step index, so the distance-1 term dominates.
pub fn compute_fused_shifts(
    chain: &[LoopInst],
    stencils: &[Stencil],
    tile_dim: usize,
    k: usize,
) -> Vec<isize> {
    let n = chain.len();
    let k = k.max(1);
    let mut out = vec![0isize; n * k];
    if n == 0 {
        return out;
    }
    // Pairwise constraints are reused k times each: precompute them.
    // rad[l * n + lp] constrains earlier-loop l against later-loop lp.
    let mut rad: Vec<Option<isize>> = Vec::with_capacity(n * n);
    for l in 0..n {
        for lp in 0..n {
            rad.push(dep_radius(&chain[l], &chain[lp], stencils, tile_dim));
        }
    }
    for s in (0..k).rev() {
        for l in (0..n).rev() {
            let mut sh = 0isize;
            for lp in (l + 1)..n {
                if let Some(r) = rad[l * n + lp] {
                    sh = sh.max(out[s * n + lp] + r);
                }
            }
            if s + 1 < k {
                // every loop of the next step is a later loop, the
                // same-index copy included (a loop that rewrites a
                // dataset it reads depends on its own next-step copy)
                for lp in 0..n {
                    if let Some(r) = rad[l * n + lp] {
                        sh = sh.max(out[(s + 1) * n + lp] + r);
                    }
                }
            }
            out[s * n + l] = sh;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::{shapes, StencilId};
    use crate::ops::{Arg, BlockId, DatasetId};

    fn st(id: u32, pts: Vec<[i32; 3]>) -> Stencil {
        Stencil {
            id: StencilId(id),
            name: format!("s{id}"),
            points: pts,
        }
    }

    fn lp(args: Vec<Arg>) -> LoopInst {
        LoopInst {
            name: "l".into(),
            block: BlockId(0),
            range: [(0, 16), (0, 16), (0, 1)],
            args,
            kernel: kernel(|_| {}),
            kernel_ir: None,
            seq: 0,
            bw_efficiency: 1.0,
        }
    }

    #[test]
    fn flow_dependency_accumulates_radius() {
        let stencils = vec![st(0, shapes::point()), st(1, shapes::star2d(1))];
        // l0 writes A; l1 reads A (r=1), writes B; l2 reads B (r=1), writes C.
        let chain = vec![
            lp(vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)]),
            lp(vec![
                Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                Arg::dat(DatasetId(1), StencilId(0), Access::Write),
            ]),
            lp(vec![
                Arg::dat(DatasetId(1), StencilId(1), Access::Read),
                Arg::dat(DatasetId(2), StencilId(0), Access::Write),
            ]),
        ];
        let shifts = compute_shifts(&chain, &stencils, 1);
        assert_eq!(shifts, vec![2, 1, 0]);
    }

    #[test]
    fn independent_loops_have_zero_shift() {
        let stencils = vec![st(0, shapes::point())];
        let chain = vec![
            lp(vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)]),
            lp(vec![Arg::dat(DatasetId(1), StencilId(0), Access::Write)]),
        ];
        let shifts = compute_shifts(&chain, &stencils, 1);
        assert_eq!(shifts, vec![0, 0]);
    }

    #[test]
    fn anti_dependency_uses_reader_radius() {
        let stencils = vec![st(0, shapes::point()), st(1, shapes::star2d(2))];
        // l0 reads A with radius 2; l1 writes A.
        let chain = vec![
            lp(vec![
                Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                Arg::dat(DatasetId(1), StencilId(0), Access::Write),
            ]),
            lp(vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)]),
        ];
        let shifts = compute_shifts(&chain, &stencils, 1);
        assert_eq!(shifts, vec![2, 0]);
    }

    #[test]
    fn chain_summary_classifies() {
        let chain = vec![
            // A: write-first temp; B: read-only; C: read then written
            lp(vec![
                Arg::dat(DatasetId(0), StencilId(0), Access::Write),
                Arg::dat(DatasetId(1), StencilId(0), Access::Read),
            ]),
            lp(vec![
                Arg::dat(DatasetId(0), StencilId(0), Access::Read),
                Arg::dat(DatasetId(2), StencilId(0), Access::Read),
            ]),
            lp(vec![Arg::dat(DatasetId(2), StencilId(0), Access::Write)]),
        ];
        let s = chain_access_summary(&chain);
        assert!(s[&DatasetId(0)].write_first);
        assert!(s[&DatasetId(0)].skip_upload());
        assert!(!s[&DatasetId(0)].skip_download());
        assert!(s[&DatasetId(1)].skip_download());
        assert!(!s[&DatasetId(1)].skip_upload());
        assert!(!s[&DatasetId(2)].skip_upload());
        assert!(!s[&DatasetId(2)].skip_download());
    }

    #[test]
    fn fused_shifts_match_concatenated_chain() {
        let stencils = vec![st(0, shapes::point()), st(1, shapes::star2d(1))];
        // the flow fixture above, fused over several depths: the fast
        // per-step recurrence must agree with compute_shifts run on the
        // literal k-fold concatenation, bit for bit
        let chain = vec![
            lp(vec![
                Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                Arg::dat(DatasetId(1), StencilId(0), Access::Write),
            ]),
            lp(vec![
                Arg::dat(DatasetId(1), StencilId(1), Access::Read),
                Arg::dat(DatasetId(0), StencilId(0), Access::ReadWrite),
            ]),
        ];
        for k in [1usize, 2, 3, 7] {
            let concat: Vec<LoopInst> = (0..k).flat_map(|_| chain.clone()).collect();
            assert_eq!(
                compute_fused_shifts(&chain, &stencils, 1, k),
                compute_shifts(&concat, &stencils, 1),
                "k = {k}"
            );
        }
    }

    #[test]
    fn fused_shifts_step_zero_grows_with_k_and_last_step_is_base() {
        let stencils = vec![st(0, shapes::point()), st(1, shapes::star2d(1))];
        let chain = vec![
            lp(vec![
                Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                Arg::dat(DatasetId(1), StencilId(0), Access::Write),
            ]),
            lp(vec![
                Arg::dat(DatasetId(1), StencilId(1), Access::Read),
                Arg::dat(DatasetId(0), StencilId(0), Access::ReadWrite),
            ]),
        ];
        let base = compute_shifts(&chain, &stencils, 1);
        let k = 5;
        let fused = compute_fused_shifts(&chain, &stencils, 1, k);
        assert_eq!(&fused[(k - 1) * 2..], &base[..], "last step is unfused");
        for s in 0..k - 1 {
            for l in 0..2 {
                assert!(
                    fused[s * 2 + l] >= fused[(s + 1) * 2 + l],
                    "shifts are monotone non-increasing over steps"
                );
            }
        }
        assert!(fused[0] > base[0], "earlier steps accumulate cross-step skew");
    }

    #[test]
    fn fused_shifts_of_independent_loops_stay_zero() {
        let stencils = vec![st(0, shapes::point())];
        let chain = vec![
            lp(vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)]),
            lp(vec![Arg::dat(DatasetId(1), StencilId(0), Access::Write)]),
        ];
        // pure writes DO output-depend on their own next-step copies
        // (shift >= next step's shift), but with zero radius everywhere
        // the whole super-chain stays unshifted
        assert!(compute_fused_shifts(&chain, &stencils, 1, 9)
            .iter()
            .all(|&s| s == 0));
    }

    #[test]
    fn dep_radius_is_position_independent() {
        let stencils = vec![st(0, shapes::point()), st(1, shapes::star2d(2))];
        let w = lp(vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)]);
        let r = lp(vec![Arg::dat(DatasetId(0), StencilId(1), Access::Read)]);
        let other = lp(vec![Arg::dat(DatasetId(1), StencilId(0), Access::Write)]);
        assert_eq!(dep_radius(&w, &r, &stencils, 1), Some(2), "flow");
        assert_eq!(dep_radius(&r, &w, &stencils, 1), Some(2), "anti");
        assert_eq!(dep_radius(&w, &w, &stencils, 1), Some(0), "output");
        assert_eq!(dep_radius(&w, &other, &stencils, 1), None, "independent");
    }

    #[test]
    fn rw_first_touch_is_not_write_first() {
        let chain = vec![lp(vec![Arg::dat(
            DatasetId(0),
            StencilId(0),
            Access::ReadWrite,
        )])];
        let s = chain_access_summary(&chain);
        assert!(!s[&DatasetId(0)].write_first);
    }
}
