//! Cached per-chain analysis: everything the tiler and the §4.1
//! data-movement optimisations derive from a loop chain, computed once
//! and reusable across flushes, engines and sessions.
//!
//! The paper's run-time tiling companion (Reguly et al., 1704.00693)
//! observes that time-stepped stencil codes replay the *same* loop chain
//! thousands of times, so the dependency/footprint analysis — `O(L²·A²)`
//! over loops and arguments — should be paid once and amortised. A
//! [`ChainAnalysis`] packages that result:
//!
//! * the structural **fingerprint** that identifies the chain shape,
//! * the tiled dimension and per-loop **skew shifts**
//!   ([`super::dependency::compute_shifts`]),
//! * the per-dataset **access summary** (read-only / write-first
//!   classification driving upload/download skipping),
//! * the chain's total **bytes** (fits-in-memory decisions),
//! * a memo of **tile plans** keyed by plan source and slot target, so
//!   even the per-tile footprint construction is reused when the same
//!   chain meets the same engine budget again.
//!
//! Engines accept an `Option<&ChainAnalysis>` through
//! [`crate::exec::Engine::run_chain_analyzed`]; `None` (the legacy eager
//! path) rebuilds the analysis per flush, exactly as the seed did.

use super::dependency::{
    chain_access_summary, compute_fused_shifts, compute_shifts, DatChainInfo,
};
use super::footprint::Interval;
use super::plan::{self, pick_tile_dim, PlanSource, TilePlan};
use crate::ops::{Dataset, DatasetId, LoopInst, Stencil};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// FNV-1a 64-bit — the crate is dependency-free, and the caches only
/// need a stable, well-mixed digest (collisions are astronomically
/// unlikely at the handful of chain shapes a run sees).
#[derive(Debug, Clone, Copy)]
pub struct Fnv(u64);

impl Fnv {
    pub fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for b in bytes {
            self.0 ^= *b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write_bytes(&v.to_le_bytes());
    }

    pub fn write_i64(&mut self, v: i64) {
        self.write_bytes(&v.to_le_bytes());
    }

    pub fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write_bytes(s.as_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

/// Digest of everything about a chain that the cost models can see,
/// *excluding* the §4.1 cyclic-phase flag: per-loop iteration ranges,
/// bandwidth efficiencies and dataset arguments (dataset, stencil,
/// access mode), the geometry of every dataset, and every stencil's
/// points. Loop *names* and kernel bodies are deliberately excluded —
/// they do not affect modelled time, which is what lets a re-recorded
/// chain with a fresh `dt` baked into its kernels still hit the
/// analysis cache.
pub fn chain_structure_fingerprint(
    chain: &[LoopInst],
    datasets: &[Dataset],
    stencils: &[Stencil],
) -> u64 {
    let mut h = Fnv::new();
    h.write_u64(chain.len() as u64);
    for l in chain {
        for (lo, hi) in &l.range {
            h.write_i64(*lo as i64);
            h.write_i64(*hi as i64);
        }
        h.write_f64(l.bw_efficiency);
        for (dat, st, acc) in l.dat_args() {
            h.write_u64(dat.0 as u64);
            h.write_u64(st.0 as u64);
            h.write_u64(acc.reads() as u64 | (acc.writes() as u64) << 1);
        }
    }
    h.write_u64(datasets.len() as u64);
    for ds in datasets {
        for ((sz, lo), hi) in ds.size.iter().zip(&ds.halo_lo).zip(&ds.halo_hi) {
            h.write_u64(*sz as u64);
            h.write_i64(*lo as i64);
            h.write_i64(*hi as i64);
        }
        h.write_u64(ds.elem_bytes);
    }
    h.write_u64(stencils.len() as u64);
    for s in stencils {
        h.write_u64(s.points.len() as u64);
        for p in &s.points {
            for c in p {
                h.write_i64(*c as i64);
            }
        }
    }
    h.finish()
}

/// Structural equality on exactly the facets
/// [`chain_structure_fingerprint`] hashes — the collision check behind
/// the dynamic-analysis memo: a 64-bit fingerprint hit is only trusted
/// when the structures actually match. Declarations (datasets,
/// stencils) are not compared: both chains come from the same frozen
/// program, whose declaration tables are immutable.
pub fn chain_structure_eq(a: &[LoopInst], b: &[LoopInst]) -> bool {
    let facets = |l: &LoopInst| {
        (
            l.range,
            l.bw_efficiency.to_bits(),
            l.dat_args()
                .map(|(d, s, acc)| (d.0, s.0, acc.reads(), acc.writes()))
                .collect::<Vec<_>>(),
        )
    };
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| facets(x) == facets(y))
}

/// Mix the cyclic-phase flag into a structural fingerprint — the full
/// cache key the tuner uses (the cyclic flag changes modelled transfer
/// traffic, so tuned choices must not alias across it).
pub fn with_cyclic(structural: u64, cyclic_phase: bool) -> u64 {
    let mut h = Fnv::new();
    h.write_u64(cyclic_phase as u64);
    h.write_u64(structural);
    h.finish()
}

/// Full chain digest including the cyclic flag — see
/// [`chain_structure_fingerprint`] for what is (and is not) hashed.
pub fn chain_fingerprint(
    chain: &[LoopInst],
    datasets: &[Dataset],
    stencils: &[Stencil],
    cyclic_phase: bool,
) -> u64 {
    with_cyclic(
        chain_structure_fingerprint(chain, datasets, stencils),
        cyclic_phase,
    )
}

/// The fused super-chain itself: `k` consecutive time steps of `chain`
/// concatenated into one chain of `k · chain.len()` loops, so a single
/// tiled pass streams each tile's data across the slowest memory
/// boundary once per `k` steps instead of once per step. Running the
/// result through any engine executes exactly the loop sequence `k`
/// back-to-back replays would — numerics are bit-identical by
/// construction; only the schedule (and therefore the modelled traffic)
/// changes.
pub fn fuse_chain(chain: &[LoopInst], k: usize) -> Vec<LoopInst> {
    let k = k.max(1);
    let mut out = Vec::with_capacity(chain.len() * k);
    for _ in 0..k {
        out.extend(chain.iter().cloned());
    }
    out
}

/// Plan-memo key: the plan source discriminant plus its parameter
/// (`Auto` → the heuristic slot target, `Fixed` → the tile count).
type PlanKey = (u8, u64);

/// The once-per-chain analysis record (see the module docs).
#[derive(Debug)]
pub struct ChainAnalysis {
    /// Structural fingerprint ([`chain_structure_fingerprint`]).
    pub fingerprint: u64,
    /// The dimension tiling happens along ([`pick_tile_dim`]).
    pub tile_dim: usize,
    /// Per-loop skew shifts ([`compute_shifts`]).
    pub shifts: Vec<isize>,
    /// Per-dataset chain-level access classification
    /// ([`chain_access_summary`]).
    pub summary: HashMap<DatasetId, DatChainInfo>,
    /// Total bytes of all datasets the chain touches
    /// ([`plan::chain_bytes`]).
    pub chain_bytes: u64,
    /// Memoised tile plans per (source, target) — shared across the
    /// sessions holding this analysis.
    plans: Mutex<HashMap<PlanKey, Arc<TilePlan>>>,
}

impl ChainAnalysis {
    /// The engines' shared eager-path fallback: hand back the supplied
    /// cached analysis, or build a fresh one into `slot` (the caller's
    /// stack slot) exactly as every flush did before the Program/Session
    /// split.
    pub fn resolve<'a>(
        analysis: Option<&'a ChainAnalysis>,
        slot: &'a mut Option<ChainAnalysis>,
        chain: &[LoopInst],
        datasets: &[Dataset],
        stencils: &[Stencil],
    ) -> &'a ChainAnalysis {
        match analysis {
            Some(a) => a,
            None => slot.insert(ChainAnalysis::build(chain, datasets, stencils)),
        }
    }

    /// Run the full dependency/footprint/skew analysis for one chain.
    pub fn build(chain: &[LoopInst], datasets: &[Dataset], stencils: &[Stencil]) -> Self {
        let tile_dim = pick_tile_dim(chain);
        ChainAnalysis {
            fingerprint: chain_structure_fingerprint(chain, datasets, stencils),
            tile_dim,
            shifts: compute_shifts(chain, stencils, tile_dim),
            summary: chain_access_summary(chain),
            chain_bytes: plan::chain_bytes(chain, datasets),
            plans: Mutex::new(HashMap::new()),
        }
    }

    /// Run the analysis for the *fused super-chain* of `k` consecutive
    /// time steps of `chain` (see [`fuse_chain`]): identical to
    /// [`ChainAnalysis::build`] on the concatenation, but with the skew
    /// shifts computed by the O(k·L²) per-step recurrence
    /// ([`compute_fused_shifts`]) instead of the O((kL)²) rescan. The
    /// tile dimension, per-dataset summary and chain bytes are those of
    /// the base chain — fusing repeats the same loops over the same
    /// datasets, so only the shifts (and the fingerprint) change.
    pub fn build_fused(
        chain: &[LoopInst],
        datasets: &[Dataset],
        stencils: &[Stencil],
        k: usize,
    ) -> Self {
        let k = k.max(1);
        let tile_dim = pick_tile_dim(chain);
        let fused = fuse_chain(chain, k);
        ChainAnalysis {
            fingerprint: chain_structure_fingerprint(&fused, datasets, stencils),
            tile_dim,
            shifts: compute_fused_shifts(chain, stencils, tile_dim, k),
            summary: chain_access_summary(chain),
            chain_bytes: plan::chain_bytes(chain, datasets),
            plans: Mutex::new(HashMap::new()),
        }
    }

    /// Build (or fetch the memoised) tile plan for this chain under
    /// `source`, reusing the precomputed shifts. Matches
    /// [`PlanSource::plan`] exactly, including the single-plane-floor
    /// fallback on degenerate `Auto` targets.
    pub fn plan(
        &self,
        source: PlanSource,
        chain: &[LoopInst],
        datasets: &[Dataset],
        stencils: &[Stencil],
        heuristic_target: u64,
    ) -> Arc<TilePlan> {
        let key: PlanKey = match source {
            PlanSource::Auto => (0, heuristic_target),
            PlanSource::Fixed(n) => (1, n as u64),
        };
        if let Some(p) = self.plans.lock().unwrap().get(&key) {
            return p.clone();
        }
        let built = Arc::new(match source {
            PlanSource::Fixed(n) => {
                plan::plan_chain_with(chain, datasets, stencils, n, self.tile_dim, &self.shifts)
            }
            PlanSource::Auto => plan::plan_auto_with(
                chain,
                datasets,
                stencils,
                heuristic_target,
                self.tile_dim,
                &self.shifts,
            )
            .unwrap_or_else(|_| {
                plan::plan_chain_with(
                    chain,
                    datasets,
                    stencils,
                    usize::MAX,
                    self.tile_dim,
                    &self.shifts,
                )
            }),
        });
        self.plans
            .lock()
            .unwrap()
            .insert(key, built.clone());
        built
    }

    /// Number of memoised plans (tests/diagnostics).
    pub fn cached_plans(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    /// Union of the chain's footprint intervals for one dataset across
    /// all tiles of a memoised plan — diagnostics helper.
    pub fn full_interval(&self, plan: &TilePlan, d: DatasetId) -> Interval {
        let mut iv = Interval::empty();
        for t in &plan.tiles {
            if let Some(fp) = &t.footprints[d.0 as usize] {
                iv = iv.hull(&fp.full);
            }
        }
        iv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::{shapes, StencilId};
    use crate::ops::{Access, Arg, BlockId};

    fn fixture() -> (Vec<LoopInst>, Vec<Dataset>, Vec<Stencil>) {
        let datasets = vec![
            Dataset {
                id: DatasetId(0),
                block: BlockId(0),
                name: "a".into(),
                size: [16, 64, 1],
                halo_lo: [1, 1, 0],
                halo_hi: [1, 1, 0],
                elem_bytes: 8,
            },
            Dataset {
                id: DatasetId(1),
                block: BlockId(0),
                name: "b".into(),
                size: [16, 64, 1],
                halo_lo: [1, 1, 0],
                halo_hi: [1, 1, 0],
                elem_bytes: 8,
            },
        ];
        let stencils = vec![
            Stencil {
                id: StencilId(0),
                name: "pt".into(),
                points: shapes::point(),
            },
            Stencil {
                id: StencilId(1),
                name: "star".into(),
                points: shapes::star2d(1),
            },
        ];
        let range = [(0, 16), (0, 64), (0, 1)];
        let chain = vec![
            LoopInst {
                name: "produce".into(),
                block: BlockId(0),
                range,
                args: vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)],
                kernel: kernel(|_| {}),
                kernel_ir: None,
                seq: 0,
                bw_efficiency: 1.0,
            },
            LoopInst {
                name: "consume".into(),
                block: BlockId(0),
                range,
                args: vec![
                    Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                    Arg::dat(DatasetId(1), StencilId(0), Access::Write),
                ],
                kernel: kernel(|_| {}),
                kernel_ir: None,
                seq: 1,
                bw_efficiency: 1.0,
            },
        ];
        (chain, datasets, stencils)
    }

    #[test]
    fn analysis_matches_direct_computation() {
        let (chain, datasets, stencils) = fixture();
        let a = ChainAnalysis::build(&chain, &datasets, &stencils);
        assert_eq!(a.tile_dim, pick_tile_dim(&chain));
        assert_eq!(a.shifts, compute_shifts(&chain, &stencils, a.tile_dim));
        assert_eq!(a.chain_bytes, plan::chain_bytes(&chain, &datasets));
        assert!(a.summary[&DatasetId(0)].write_first);
        assert!(a.summary[&DatasetId(1)].skip_upload());
    }

    #[test]
    fn memoised_plans_match_plan_source() {
        let (chain, datasets, stencils) = fixture();
        let a = ChainAnalysis::build(&chain, &datasets, &stencils);
        let target = a.chain_bytes / 3;
        let p1 = a.plan(PlanSource::Auto, &chain, &datasets, &stencils, target);
        let direct = PlanSource::Auto.plan(&chain, &datasets, &stencils, target);
        assert_eq!(p1.num_tiles(), direct.num_tiles());
        assert_eq!(p1.shifts, direct.shifts);
        // second request is memoised (same Arc)
        let p2 = a.plan(PlanSource::Auto, &chain, &datasets, &stencils, target);
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(a.cached_plans(), 1);
        // a fixed source gets its own entry
        let f = a.plan(PlanSource::Fixed(4), &chain, &datasets, &stencils, target);
        assert_eq!(f.num_tiles(), 4);
        assert_eq!(a.cached_plans(), 2);
    }

    #[test]
    fn degenerate_auto_target_falls_back_to_single_plane_floor() {
        let (chain, datasets, stencils) = fixture();
        let a = ChainAnalysis::build(&chain, &datasets, &stencils);
        let p = a.plan(PlanSource::Auto, &chain, &datasets, &stencils, 1);
        let direct = PlanSource::Auto.plan(&chain, &datasets, &stencils, 1);
        assert_eq!(p.num_tiles(), direct.num_tiles());
    }

    #[test]
    fn fused_analysis_matches_analysis_of_concatenated_chain() {
        let (chain, datasets, stencils) = fixture();
        for k in [1usize, 2, 4] {
            let fused_chain = fuse_chain(&chain, k);
            assert_eq!(fused_chain.len(), chain.len() * k);
            let fast = ChainAnalysis::build_fused(&chain, &datasets, &stencils, k);
            let naive = ChainAnalysis::build(&fused_chain, &datasets, &stencils);
            assert_eq!(fast.fingerprint, naive.fingerprint, "k = {k}");
            assert_eq!(fast.tile_dim, naive.tile_dim, "k = {k}");
            assert_eq!(fast.shifts, naive.shifts, "k = {k}");
            assert_eq!(fast.chain_bytes, naive.chain_bytes, "k = {k}");
            for (d, info) in &naive.summary {
                let f = &fast.summary[d];
                assert_eq!(
                    (f.read, f.written, f.write_first),
                    (info.read, info.written, info.write_first),
                    "k = {k}"
                );
            }
        }
    }

    #[test]
    fn structure_fingerprint_ignores_cyclic_but_full_does_not() {
        let (chain, datasets, stencils) = fixture();
        let s = chain_structure_fingerprint(&chain, &datasets, &stencils);
        assert_eq!(
            with_cyclic(s, true),
            chain_fingerprint(&chain, &datasets, &stencils, true)
        );
        assert_ne!(
            chain_fingerprint(&chain, &datasets, &stencils, true),
            chain_fingerprint(&chain, &datasets, &stencils, false)
        );
    }
}
