//! Construction of the skewed tile plan for one loop chain.

use super::dependency::compute_shifts;
use super::footprint::{DatFootprint, Interval};
use crate::ops::{DatasetId, Dataset, LoopInst, Range3, Stencil};

/// One tile of the schedule.
#[derive(Debug, Clone)]
pub struct Tile {
    /// Per-loop (chain order) iteration sub-range; `None` when this tile
    /// contributes no points for that loop.
    pub loop_ranges: Vec<Option<Range3>>,
    /// Per-dataset (dense by `DatasetId`) footprint; `None` when the
    /// dataset is not touched by this tile.
    pub footprints: Vec<Option<DatFootprint>>,
}

/// The full skewed tiling schedule for a chain.
#[derive(Debug, Clone)]
pub struct TilePlan {
    /// Dimension being tiled (1 for 2D problems, 2 for 3D).
    pub tile_dim: usize,
    /// Unshifted tile boundaries `B_0 … B_T` along the tiled dimension.
    pub boundaries: Vec<isize>,
    /// Per-loop skew shift.
    pub shifts: Vec<isize>,
    pub tiles: Vec<Tile>,
}

impl TilePlan {
    pub fn num_tiles(&self) -> usize {
        self.tiles.len()
    }

    /// Full footprint of tile `t` in bytes, summed over datasets.
    pub fn full_footprint_bytes(&self, t: usize, datasets: &[Dataset]) -> u64 {
        self.tiles[t]
            .footprints
            .iter()
            .enumerate()
            .filter_map(|(d, fp)| {
                fp.as_ref()
                    .map(|f| f.full_bytes(&datasets[d], self.tile_dim))
            })
            .sum()
    }

    /// Largest tile footprint — what must fit in fast memory (per slot).
    pub fn max_footprint_bytes(&self, datasets: &[Dataset]) -> u64 {
        (0..self.tiles.len())
            .map(|t| self.full_footprint_bytes(t, datasets))
            .max()
            .unwrap_or(0)
    }

    /// The "left edge" of tile `t` for dataset `d`: overlap with the
    /// previous tile's footprint (empty for tile 0).
    pub fn left_edge(&self, t: usize, d: DatasetId) -> Interval {
        if t == 0 {
            return Interval::empty();
        }
        match (
            &self.tiles[t].footprints[d.0 as usize],
            &self.tiles[t - 1].footprints[d.0 as usize],
        ) {
            (Some(cur), Some(prev)) => cur.full.intersect(&prev.full),
            _ => Interval::empty(),
        }
    }

    /// The "right edge" of tile `t` for dataset `d`: overlap with the next
    /// tile's footprint (empty for the last tile).
    pub fn right_edge(&self, t: usize, d: DatasetId) -> Interval {
        if t + 1 >= self.tiles.len() {
            return Interval::empty();
        }
        match (
            &self.tiles[t].footprints[d.0 as usize],
            &self.tiles[t + 1].footprints[d.0 as usize],
        ) {
            (Some(cur), Some(next)) => cur.full.intersect(&next.full),
            _ => Interval::empty(),
        }
    }

    /// "Right footprint" of tile `t` for dataset `d`: full minus the left
    /// edge — the part that must be freshly uploaded (the left edge is
    /// satisfied by the device-device edge copy from the previous slot).
    pub fn right_footprint(&self, t: usize, d: DatasetId) -> Interval {
        match &self.tiles[t].footprints[d.0 as usize] {
            Some(f) => {
                let le = self.left_edge(t, d);
                if le.is_empty() {
                    f.full
                } else {
                    Interval::new(le.hi, f.full.hi)
                }
            }
            None => Interval::empty(),
        }
    }

    /// "Left footprint" of the *written* region of tile `t` for dataset
    /// `d`: written minus the right edge — safe to download as soon as the
    /// tile finishes (the overlap will be (re)written by the next tile and
    /// downloaded there).
    pub fn left_written_footprint(&self, t: usize, d: DatasetId) -> Interval {
        match &self.tiles[t].footprints[d.0 as usize] {
            Some(f) => {
                if f.written.is_empty() {
                    return Interval::empty();
                }
                let re = self.right_edge(t, d);
                if re.is_empty() {
                    f.written
                } else {
                    Interval::new(f.written.lo, f.written.hi.min(re.lo))
                }
            }
            None => Interval::empty(),
        }
    }
}

/// Pick the tiled dimension for a chain: the outermost (slowest-varying)
/// dimension in which the chain actually iterates.
pub fn pick_tile_dim(chain: &[LoopInst]) -> usize {
    let extent = |d: usize| {
        chain
            .iter()
            .map(|l| (l.range[d].1 - l.range[d].0).max(0))
            .max()
            .unwrap_or(0)
    };
    if extent(2) > 1 {
        2
    } else {
        1
    }
}

/// Total bytes of all datasets touched by a chain — the "problem size"
/// used for fits-in-memory decisions and the figures' x axes.
pub fn chain_bytes(chain: &[LoopInst], datasets: &[Dataset]) -> u64 {
    let mut seen = vec![false; datasets.len()];
    let mut total = 0u64;
    for l in chain {
        for (d, _, _) in l.dat_args() {
            if !seen[d.0 as usize] {
                seen[d.0 as usize] = true;
                total += datasets[d.0 as usize].bytes();
            }
        }
    }
    total
}

/// Build the plan for a fixed number of tiles (clamped to `[1, extent]`,
/// so any requested count — including `usize::MAX` for "single-plane
/// tiles" — degenerates gracefully).
pub fn plan_chain(
    chain: &[LoopInst],
    datasets: &[Dataset],
    stencils: &[Stencil],
    num_tiles: usize,
) -> TilePlan {
    let tile_dim = pick_tile_dim(chain);
    let shifts = compute_shifts(chain, stencils, tile_dim);
    plan_chain_with(chain, datasets, stencils, num_tiles, tile_dim, &shifts)
}

/// [`plan_chain`] with the dependency analysis supplied: the tiled
/// dimension and per-loop skew shifts come from a precomputed
/// [`crate::tiling::analysis::ChainAnalysis`] instead of being rerun —
/// the record-once/replay-many seam. `shifts` must have one entry per
/// chain loop and match `tile_dim` (both are what [`compute_shifts`]
/// would produce; anything else voids the reordering guarantee).
pub fn plan_chain_with(
    chain: &[LoopInst],
    datasets: &[Dataset],
    stencils: &[Stencil],
    num_tiles: usize,
    tile_dim: usize,
    shifts: &[isize],
) -> TilePlan {
    // Global extent of the tiled dimension across the chain.
    let glo = chain
        .iter()
        .map(|l| l.range[tile_dim].0)
        .min()
        .unwrap_or(0);
    let ghi = chain
        .iter()
        .map(|l| l.range[tile_dim].1)
        .max()
        .unwrap_or(1);
    let extent = (ghi - glo).max(1);
    let t = num_tiles.clamp(1, extent as usize);

    let mut boundaries = Vec::with_capacity(t + 1);
    for i in 0..=t {
        boundaries.push(glo + extent * i as isize / t as isize);
    }

    let mut tiles = Vec::with_capacity(t);
    for ti in 0..t {
        let mut loop_ranges: Vec<Option<Range3>> = Vec::with_capacity(chain.len());
        let mut footprints: Vec<Option<DatFootprint>> = vec![None; datasets.len()];
        for (li, l) in chain.iter().enumerate() {
            let (llo, lhi) = l.range[tile_dim];
            let start = if ti == 0 {
                llo
            } else {
                (boundaries[ti] + shifts[li]).clamp(llo, lhi)
            };
            let end = if ti == t - 1 {
                lhi
            } else {
                (boundaries[ti + 1] + shifts[li]).clamp(llo, lhi)
            };
            if start >= end {
                loop_ranges.push(None);
                continue;
            }
            let mut r = l.range;
            r[tile_dim] = (start, end);
            loop_ranges.push(Some(r));

            // Accumulate footprints.
            for (dat, st, acc) in l.dat_args() {
                let ds = &datasets[dat.0 as usize];
                let s = &stencils[st.0 as usize];
                let lo_ext = s.min_extent()[tile_dim] as isize;
                let hi_ext = s.max_extent()[tile_dim] as isize;
                let dlo = -(ds.halo_lo[tile_dim] as isize);
                let dhi = ds.size[tile_dim] as isize + ds.halo_hi[tile_dim] as isize;
                let acc_iv = Interval::new(start + lo_ext, end + hi_ext).clamp_to(dlo, dhi);
                let fp = footprints[dat.0 as usize].get_or_insert(DatFootprint {
                    full: Interval::empty(),
                    written: Interval::empty(),
                });
                fp.full = fp.full.hull(&acc_iv);
                if acc.writes() {
                    let w_iv = Interval::new(start + lo_ext, end + hi_ext).clamp_to(dlo, dhi);
                    fp.written = fp.written.hull(&w_iv);
                }
            }
        }
        tiles.push(Tile {
            loop_ranges,
            footprints,
        });
    }

    TilePlan {
        tile_dim,
        boundaries,
        shifts: shifts.to_vec(),
        tiles,
    }
}

/// Build a plan whose largest tile footprint fits `target_bytes`,
/// increasing the tile count geometrically until it does.
///
/// Degenerate inputs are typed [`crate::errors`] errors rather than
/// panics or silently-infeasible plans:
///
/// * an **empty chain** cannot be tiled;
/// * a **zero slot target** leaves no fast-memory budget at all (a chain
///   that touches no datasets is trivially a single tile and is accepted
///   before this check);
/// * a target **smaller than one halo-widened slab** — even single-plane
///   tiles exceed it, so no legal plan can meet the budget.
///
/// Callers that want the seed's old best-effort behaviour on a degenerate
/// target (stream at the single-plane floor) should go through
/// [`PlanSource::plan`], which encodes exactly that fallback.
pub fn plan_auto(
    chain: &[LoopInst],
    datasets: &[Dataset],
    stencils: &[Stencil],
    target_bytes: u64,
) -> crate::Result<TilePlan> {
    crate::ensure!(!chain.is_empty(), "cannot tile an empty loop chain");
    let tile_dim = pick_tile_dim(chain);
    let shifts = compute_shifts(chain, stencils, tile_dim);
    plan_auto_with(chain, datasets, stencils, target_bytes, tile_dim, &shifts)
}

/// [`plan_auto`] with the dependency analysis supplied (see
/// [`plan_chain_with`]): the growth loop re-sizes tiles without ever
/// re-running the `O(L²·A²)` shift computation.
pub fn plan_auto_with(
    chain: &[LoopInst],
    datasets: &[Dataset],
    stencils: &[Stencil],
    target_bytes: u64,
    tile_dim: usize,
    shifts: &[isize],
) -> crate::Result<TilePlan> {
    crate::ensure!(!chain.is_empty(), "cannot tile an empty loop chain");
    let glo = chain
        .iter()
        .map(|l| l.range[tile_dim].0)
        .min()
        .unwrap_or(0);
    let ghi = chain
        .iter()
        .map(|l| l.range[tile_dim].1)
        .max()
        .unwrap_or(1);
    let extent = (ghi - glo).max(1) as u64;

    // First estimate from per-plane bytes of the touched datasets.
    let mut seen = vec![false; datasets.len()];
    let mut plane_bytes = 0u64;
    for l in chain {
        for (d, _, _) in l.dat_args() {
            if !seen[d.0 as usize] {
                seen[d.0 as usize] = true;
                plane_bytes += datasets[d.0 as usize].plane_bytes(tile_dim);
            }
        }
    }
    if plane_bytes == 0 {
        // The chain touches no datasets: nothing to stream, one tile.
        return Ok(plan_chain_with(chain, datasets, stencils, 1, tile_dim, shifts));
    }
    crate::ensure!(
        target_bytes > 0,
        "slot target is zero: no fast-memory budget to size tiles against"
    );
    let total = plane_bytes * extent;
    let mut n = if total <= target_bytes {
        1
    } else {
        total.div_ceil(target_bytes) as usize
    };

    loop {
        let plan = plan_chain_with(chain, datasets, stencils, n, tile_dim, shifts);
        let maxfp = plan.max_footprint_bytes(datasets);
        if maxfp <= target_bytes {
            return Ok(plan);
        }
        if n as u64 >= extent {
            let tiles = plan.num_tiles();
            crate::bail!(
                "slot target {target_bytes} B is smaller than one halo-widened slab: \
                 even single-plane tiles ({tiles} of them) need {maxfp} B"
            );
        }
        n = (n * 5 / 4 + 1).min(extent as usize);
    }
}

/// Where an engine gets its tile plan from — the seam the auto-tuner
/// threads through every memory engine.
///
/// The seed hardcoded an `HBM/3`-style `plan_auto` call in each engine;
/// engines now hold a `PlanSource` instead, so benches can pin tile
/// counts and [`crate::tuner`] can inject searched plans without
/// touching engine internals.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlanSource {
    /// Auto-size tiles to the engine's heuristic slot target (the seed
    /// `HBM/3` behaviour).
    #[default]
    Auto,
    /// A fixed tile count chosen externally (benches, the auto-tuner).
    Fixed(usize),
}

impl PlanSource {
    /// Build the plan for a chain. `heuristic_target` is the engine's
    /// slot budget in bytes (e.g. `HBM/3 · 0.92`), used by [`Auto`].
    ///
    /// [`Auto`]: PlanSource::Auto
    pub fn plan(
        &self,
        chain: &[LoopInst],
        datasets: &[Dataset],
        stencils: &[Stencil],
        heuristic_target: u64,
    ) -> TilePlan {
        match self {
            PlanSource::Fixed(n) => plan_chain(chain, datasets, stencils, *n),
            PlanSource::Auto => plan_auto(chain, datasets, stencils, heuristic_target)
                .unwrap_or_else(|_| {
                    // Degenerate target or chain: stream at the
                    // single-plane floor, exactly the seed's best-effort
                    // behaviour when the budget can never be met.
                    plan_chain(chain, datasets, stencils, usize::MAX)
                }),
        }
    }

    /// [`Self::plan`] against a precomputed [`ChainAnalysis`]: the skew
    /// shifts come from the analysis, and the resulting plan is memoised
    /// inside it, so a replayed chain re-plans in O(1) after its first
    /// execution on a given engine budget.
    ///
    /// [`ChainAnalysis`]: crate::tiling::analysis::ChainAnalysis
    pub fn plan_analyzed(
        &self,
        chain: &[LoopInst],
        datasets: &[Dataset],
        stencils: &[Stencil],
        heuristic_target: u64,
        analysis: &crate::tiling::analysis::ChainAnalysis,
    ) -> std::sync::Arc<TilePlan> {
        analysis.plan(*self, chain, datasets, stencils, heuristic_target)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::{shapes, StencilId};
    use crate::ops::{Access, Arg, BlockId};

    fn dataset(id: u32, ny: usize) -> Dataset {
        Dataset {
            id: DatasetId(id),
            block: BlockId(0),
            name: format!("d{id}"),
            size: [16, ny, 1],
            halo_lo: [2, 2, 0],
            halo_hi: [2, 2, 0],
            elem_bytes: 8,
        }
    }

    fn st(id: u32, pts: Vec<[i32; 3]>) -> Stencil {
        Stencil {
            id: StencilId(id),
            name: format!("s{id}"),
            points: pts,
        }
    }

    fn lp(name: &str, ny: isize, args: Vec<Arg>) -> LoopInst {
        LoopInst {
            name: name.into(),
            block: BlockId(0),
            range: [(0, 16), (0, ny), (0, 1)],
            args,
            kernel: kernel(|_| {}),
            kernel_ir: None,
            seq: 0,
            bw_efficiency: 1.0,
        }
    }

    fn two_loop_chain() -> (Vec<LoopInst>, Vec<Dataset>, Vec<Stencil>) {
        let datasets = vec![dataset(0, 64), dataset(1, 64)];
        let stencils = vec![st(0, shapes::point()), st(1, shapes::star2d(1))];
        let chain = vec![
            lp(
                "produce",
                64,
                vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)],
            ),
            lp(
                "consume",
                64,
                vec![
                    Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                    Arg::dat(DatasetId(1), StencilId(0), Access::Write),
                ],
            ),
        ];
        (chain, datasets, stencils)
    }

    #[test]
    fn ranges_partition_each_loop() {
        let (chain, datasets, stencils) = two_loop_chain();
        let plan = plan_chain(&chain, &datasets, &stencils, 4);
        assert_eq!(plan.tile_dim, 1);
        for (li, l) in chain.iter().enumerate() {
            let mut cursor = l.range[1].0;
            for tile in &plan.tiles {
                if let Some(r) = &tile.loop_ranges[li] {
                    assert_eq!(r[1].0, cursor, "tiles must abut for loop {li}");
                    cursor = r[1].1;
                }
            }
            assert_eq!(cursor, l.range[1].1, "tiles must cover loop {li}");
        }
    }

    #[test]
    fn earlier_loop_leads_by_shift() {
        let (chain, datasets, stencils) = two_loop_chain();
        let plan = plan_chain(&chain, &datasets, &stencils, 4);
        assert_eq!(plan.shifts, vec![1, 0]);
        // In every non-final tile, the producer's end must be >= the
        // consumer's end + 1 (the consumer reads ±1).
        for t in 0..plan.tiles.len() - 1 {
            let pr = plan.tiles[t].loop_ranges[0].as_ref().unwrap();
            let cr = plan.tiles[t].loop_ranges[1].as_ref().unwrap();
            assert!(pr[1].1 >= cr[1].1 + 1);
        }
    }

    #[test]
    fn footprints_cover_stencil_reach() {
        let (chain, datasets, stencils) = two_loop_chain();
        let plan = plan_chain(&chain, &datasets, &stencils, 4);
        // dataset 0 is read at ±1 around the consumer range.
        for t in 0..plan.tiles.len() {
            let cr = match &plan.tiles[t].loop_ranges[1] {
                Some(r) => r[1],
                None => continue,
            };
            let fp = plan.tiles[t].footprints[0].as_ref().unwrap();
            assert!(fp.full.lo <= cr.0 - 1);
            assert!(fp.full.hi >= cr.1 + 1);
        }
    }

    #[test]
    fn edges_are_consistent() {
        let (chain, datasets, stencils) = two_loop_chain();
        let plan = plan_chain(&chain, &datasets, &stencils, 4);
        for t in 1..plan.tiles.len() {
            let le = plan.left_edge(t, DatasetId(0));
            let re_prev = plan.right_edge(t - 1, DatasetId(0));
            assert_eq!(le, re_prev, "left edge of t == right edge of t-1");
            assert!(!le.is_empty(), "overlapping stencil reads create edges");
        }
        assert!(plan.left_edge(0, DatasetId(0)).is_empty());
        assert!(plan
            .right_edge(plan.tiles.len() - 1, DatasetId(0))
            .is_empty());
    }

    #[test]
    fn right_footprint_plus_left_edge_covers_full() {
        let (chain, datasets, stencils) = two_loop_chain();
        let plan = plan_chain(&chain, &datasets, &stencils, 4);
        for t in 0..plan.tiles.len() {
            let full = plan.tiles[t].footprints[0].as_ref().unwrap().full;
            let le = plan.left_edge(t, DatasetId(0));
            let rf = plan.right_footprint(t, DatasetId(0));
            assert_eq!(le.len() + rf.len(), full.len());
        }
    }

    #[test]
    fn auto_plan_respects_target() {
        let (chain, datasets, stencils) = two_loop_chain();
        let total = chain_bytes(&chain, &datasets);
        let plan = plan_auto(&chain, &datasets, &stencils, total / 3).unwrap();
        assert!(plan.num_tiles() >= 3);
        assert!(plan.max_footprint_bytes(&datasets) <= total / 3);
    }

    #[test]
    fn single_tile_when_it_fits() {
        let (chain, datasets, stencils) = two_loop_chain();
        let plan = plan_auto(&chain, &datasets, &stencils, u64::MAX).unwrap();
        assert_eq!(plan.num_tiles(), 1);
    }

    #[test]
    fn degenerate_targets_are_typed_errors() {
        let (chain, datasets, stencils) = two_loop_chain();
        // empty chain
        let e = plan_auto(&[], &datasets, &stencils, u64::MAX).unwrap_err();
        assert!(e.to_string().contains("empty loop chain"), "{e}");
        // zero target
        let e = plan_auto(&chain, &datasets, &stencils, 0).unwrap_err();
        assert!(e.to_string().contains("slot target is zero"), "{e}");
        // target below one halo-widened slab
        let e = plan_auto(&chain, &datasets, &stencils, 1).unwrap_err();
        assert!(e.to_string().contains("halo-widened slab"), "{e}");
    }

    #[test]
    fn zero_dataset_chain_is_a_single_tile() {
        let stencils = vec![st(0, shapes::point())];
        let chain = vec![lp("red_only", 64, vec![])];
        let plan = plan_auto(&chain, &[], &stencils, 0).unwrap();
        assert_eq!(plan.num_tiles(), 1);
    }

    #[test]
    fn plan_source_auto_matches_plan_auto_and_falls_back() {
        let (chain, datasets, stencils) = two_loop_chain();
        let total = chain_bytes(&chain, &datasets);
        let a = PlanSource::Auto.plan(&chain, &datasets, &stencils, total / 3);
        let b = plan_auto(&chain, &datasets, &stencils, total / 3).unwrap();
        assert_eq!(a.num_tiles(), b.num_tiles());
        // infeasible target: the fallback is the single-plane floor
        let f = PlanSource::Auto.plan(&chain, &datasets, &stencils, 1);
        assert_eq!(f.num_tiles() as isize, 64);
        // fixed counts pass through (clamped to the extent)
        let p = PlanSource::Fixed(5).plan(&chain, &datasets, &stencils, 0);
        assert_eq!(p.num_tiles(), 5);
        let p = PlanSource::Fixed(usize::MAX).plan(&chain, &datasets, &stencils, 0);
        assert_eq!(p.num_tiles() as isize, 64);
    }

    #[test]
    fn boundary_strip_loops_land_in_correct_tiles() {
        // A loop that only touches rows 0..2 must only appear in tile 0
        // (plus skew).
        let datasets = vec![dataset(0, 64)];
        let stencils = vec![st(0, shapes::point())];
        let chain = vec![
            lp(
                "strip",
                2,
                vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)],
            ),
            lp(
                "full",
                64,
                vec![Arg::dat(DatasetId(0), StencilId(0), Access::ReadWrite)],
            ),
        ];
        let plan = plan_chain(&chain, &datasets, &stencils, 8);
        let mut strip_points = 0isize;
        for tile in &plan.tiles {
            if let Some(r) = &tile.loop_ranges[0] {
                strip_points += r[1].1 - r[1].0;
            }
        }
        assert_eq!(strip_points, 2);
        assert!(plan.tiles[0].loop_ranges[0].is_some());
        assert!(plan.tiles[4].loop_ranges[0].is_none());
    }

    #[test]
    fn chain_bytes_counts_unique_datasets() {
        let (chain, datasets, _) = two_loop_chain();
        let b = chain_bytes(&chain, &datasets);
        assert_eq!(b, datasets[0].bytes() + datasets[1].bytes());
    }
}
