//! Tile footprints along the tiled dimension (Fig. 2 of the paper).
//!
//! For each tile and each dataset we track the *interval* of the tiled
//! dimension that the tile touches. From consecutive tiles' intervals the
//! paper's regions follow:
//!
//! * **full footprint** — everything the tile accesses;
//! * **left edge** — overlap with the *previous* tile's footprint;
//! * **right edge** — overlap with the *next* tile's footprint;
//! * **left footprint** — full minus right edge (safe to download once
//!   the tile finished; the overlap belongs to the next tile);
//! * **right footprint** — full minus left edge (what must be freshly
//!   uploaded; the overlap arrives via a device-device edge copy).

use crate::ops::Dataset;

/// A half-open interval `[lo, hi)` along the tiled dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interval {
    pub lo: isize,
    pub hi: isize,
}

impl Interval {
    pub fn new(lo: isize, hi: isize) -> Self {
        Interval { lo, hi }
    }

    pub fn empty() -> Self {
        Interval { lo: 0, hi: 0 }
    }

    #[inline]
    pub fn len(&self) -> isize {
        (self.hi - self.lo).max(0)
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.hi <= self.lo
    }

    /// Union as the convex hull (intervals in a chain overlap heavily, so
    /// the hull is the right conservative choice for footprints).
    pub fn hull(&self, other: &Interval) -> Interval {
        if self.is_empty() {
            *other
        } else if other.is_empty() {
            *self
        } else {
            Interval::new(self.lo.min(other.lo), self.hi.max(other.hi))
        }
    }

    pub fn intersect(&self, other: &Interval) -> Interval {
        let lo = self.lo.max(other.lo);
        let hi = self.hi.min(other.hi);
        if hi <= lo {
            Interval::empty()
        } else {
            Interval::new(lo, hi)
        }
    }

    pub fn clamp_to(&self, lo: isize, hi: isize) -> Interval {
        self.intersect(&Interval::new(lo, hi))
    }
}

/// Per-tile, per-dataset footprint.
#[derive(Debug, Clone)]
pub struct DatFootprint {
    /// Full accessed interval (reads extended by stencil extents).
    pub full: Interval,
    /// Interval actually written by the tile.
    pub written: Interval,
}

impl DatFootprint {
    /// Bytes of the full footprint for dataset `ds` when tiling `dim`.
    pub fn full_bytes(&self, ds: &Dataset, dim: usize) -> u64 {
        ds.plane_bytes(dim) * self.full.len() as u64
    }

    /// Bytes written.
    pub fn written_bytes(&self, ds: &Dataset, dim: usize) -> u64 {
        ds.plane_bytes(dim) * self.written.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hull_and_intersect() {
        let a = Interval::new(0, 10);
        let b = Interval::new(5, 20);
        assert_eq!(a.hull(&b), Interval::new(0, 20));
        assert_eq!(a.intersect(&b), Interval::new(5, 10));
        let c = Interval::new(30, 40);
        assert!(a.intersect(&c).is_empty());
    }

    #[test]
    fn hull_with_empty_is_identity() {
        let a = Interval::new(3, 7);
        assert_eq!(a.hull(&Interval::empty()), a);
        assert_eq!(Interval::empty().hull(&a), a);
    }

    #[test]
    fn clamp() {
        let a = Interval::new(-5, 100);
        assert_eq!(a.clamp_to(0, 50), Interval::new(0, 50));
    }

    #[test]
    fn empty_len_zero() {
        assert_eq!(Interval::new(7, 3).len(), 0);
        assert!(Interval::new(7, 3).is_empty());
    }
}
