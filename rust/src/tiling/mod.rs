//! Cache-blocking skewed tiling over lazily-collected loop chains (§3–§4
//! of the paper).
//!
//! Given a chain of parallel loops with full access descriptors, we
//! compute, per loop, a *shift* (the skew) from backward dependency
//! analysis, partition the tiled dimension into tiles, and derive per-tile
//! per-loop iteration sub-ranges plus per-tile per-dataset *footprints*
//! (the paper's full/left/right footprints and left/right edges, Fig. 2).
//!
//! The schedule guarantee: executing tiles in order, and loops in chain
//! order within each tile over their shifted sub-ranges, computes exactly
//! what the untiled chain computes. Integration and property tests verify
//! this bit-for-bit.

pub mod analysis;
pub mod dependency;
pub mod footprint;
pub mod plan;

pub use analysis::{chain_fingerprint, chain_structure_fingerprint, ChainAnalysis, Fnv};
pub use dependency::{chain_access_summary, compute_shifts, DatChainInfo};
pub use footprint::{DatFootprint, Interval};
pub use plan::{plan_auto, plan_chain, PlanSource, Tile, TilePlan};
