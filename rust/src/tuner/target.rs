//! Tunable platform descriptions.
//!
//! A [`TunerTarget`] holds the calibration constants of one tunable
//! platform and can instantiate a *fresh* engine for any search
//! [`Candidate`] — the engines themselves are the cost models, so
//! "build + replay with a null executor" *is* candidate scoring.

use super::candidate::{Candidate, Fnv};
use crate::distributed::{DecompKind, Interconnect, ShardedEngine};
use crate::exec::Engine;
use crate::memory::{
    AppCalib, GpuCalib, GpuExplicitEngine, GpuOpts, KnlCalib, KnlEngine, Link, TieredEngine,
    UnifiedCalib, UnifiedEngine,
};
use crate::ops::{Dataset, LoopInst, Stencil};
use crate::tiling::plan::PlanSource;
use crate::topology::Topology;

/// One tunable platform with its calibrations.
#[derive(Debug, Clone)]
pub enum TunerTarget {
    /// KNL cache mode with skewed tiling.
    Knl { calib: KnlCalib, app: AppCalib },
    /// Explicit 3-slot GPU streaming (Algorithm 1).
    GpuExplicit {
        calib: GpuCalib,
        app: AppCalib,
        link: Link,
        /// The *configured* toggles — the heuristic candidate reproduces
        /// them; the search may deviate.
        opts: GpuOpts,
    },
    /// Unified-memory GPU.
    GpuUnified {
        gpu: GpuCalib,
        um: UnifiedCalib,
        app: AppCalib,
        link: Link,
        tiled: bool,
        prefetch: bool,
    },
    /// The generic N-tier engine on a declarative [`Topology`]; the
    /// candidate's tile count applies to the innermost (fastest)
    /// boundary, where the §4.1 toggles also live.
    Tiered {
        topo: Topology,
        /// App-calibrated achieved compute bandwidth, GB/s (NVLink
        /// presets arrive pre-boosted).
        compute_bw: f64,
        launch_s: f64,
        /// Configured toggles — the heuristic candidate reproduces
        /// them; the search may deviate.
        opts: GpuOpts,
    },
    /// N ranks of `inner`, candidates applied uniformly per rank.
    Sharded {
        inner: Box<TunerTarget>,
        ranks: u32,
        kind: DecompKind,
        link: Interconnect,
        overlap: bool,
    },
}

impl TunerTarget {
    /// A fresh engine configured for `cand` (cold clock and caches).
    pub fn build(&self, cand: Candidate) -> Box<dyn Engine> {
        match self {
            TunerTarget::Knl { calib, app } => {
                let mut e = KnlEngine::new(calib.clone(), *app, true);
                e.plan = plan_source(cand);
                Box::new(e)
            }
            TunerTarget::GpuExplicit {
                calib, app, link, ..
            } => {
                let opts = GpuOpts {
                    cyclic: cand.cyclic,
                    prefetch: cand.prefetch,
                    slots: cand.slots.clamp(2, 3),
                };
                let mut e = GpuExplicitEngine::new(calib.clone(), *app, *link, opts)
                    .expect("clamped slots are always valid");
                e.plan = plan_source(cand);
                Box::new(e)
            }
            TunerTarget::GpuUnified {
                gpu,
                um,
                app,
                link,
                tiled,
                ..
            } => {
                // An explicit tile count implies the tiled schedule; the
                // heuristic candidate keeps the configured mode.
                let tiled = *tiled || cand.tiles.is_some();
                let mut e =
                    UnifiedEngine::new(gpu.clone(), um.clone(), *app, *link, tiled, cand.prefetch);
                e.plan = plan_source(cand);
                Box::new(e)
            }
            TunerTarget::Tiered {
                topo,
                compute_bw,
                launch_s,
                ..
            } => {
                let cand_opts = GpuOpts {
                    cyclic: cand.cyclic,
                    prefetch: cand.prefetch,
                    slots: cand.slots.clamp(2, 3),
                };
                // The codec toggle: `false` strips every link codec, so
                // the search can price compression against raw transfer.
                let topo = if cand.codec { topo.clone() } else { topo.without_codecs() };
                let mut e = TieredEngine::new(topo, *compute_bw, *launch_s, cand_opts)
                    .expect("clamped slots are always valid");
                if !e.plans.is_empty() {
                    e.plans[0] = plan_source(cand);
                }
                Box::new(e)
            }
            TunerTarget::Sharded {
                inner,
                ranks,
                kind,
                link,
                overlap,
            } => {
                // Halo exchanges inherit the inner stack's boundary
                // codec exactly like `Config::build_tiered_engine`.
                let halo = match inner.as_ref() {
                    TunerTarget::Tiered { topo, .. } if cand.codec => {
                        topo.codec(topo.num_tiers().saturating_sub(2))
                    }
                    _ => None,
                };
                let engines = (0..(*ranks).max(1)).map(|_| inner.build(cand)).collect();
                Box::new(ShardedEngine::new(engines, *kind, *link, *overlap).with_codec(halo))
            }
        }
    }

    /// The candidate that reproduces the seed heuristic exactly: `Auto`
    /// plan sizing plus the platform's configured toggles.
    pub fn heuristic(&self) -> Candidate {
        match self {
            TunerTarget::Knl { .. } => Candidate {
                tiles: None,
                slots: 0,
                cyclic: false,
                prefetch: false,
                fuse: 1,
                codec: false,
            },
            TunerTarget::GpuExplicit { opts, .. } => Candidate {
                tiles: None,
                slots: opts.slots.clamp(2, 3),
                cyclic: opts.cyclic,
                prefetch: opts.prefetch,
                fuse: 1,
                codec: false,
            },
            TunerTarget::GpuUnified { prefetch, .. } => Candidate {
                tiles: None,
                slots: 0,
                cyclic: false,
                prefetch: *prefetch,
                fuse: 1,
                codec: false,
            },
            TunerTarget::Tiered { topo, opts, .. } => Candidate {
                tiles: None,
                slots: opts.slots.clamp(2, 3),
                cyclic: opts.cyclic,
                prefetch: opts.prefetch,
                fuse: 1,
                // the configured state: annotated stacks run compressed
                codec: topo.has_codec(),
            },
            TunerTarget::Sharded { inner, .. } => inner.heuristic(),
        }
    }

    /// The platform's toggle space: candidates differing only in the
    /// discrete switches, with `tiles` left unset (the search crosses
    /// each variant with its tile-count ladder). Order is fixed, which
    /// keeps the search deterministic.
    pub fn toggle_variants(&self) -> Vec<Candidate> {
        match self {
            TunerTarget::Knl { .. } => vec![self.heuristic()],
            TunerTarget::GpuExplicit { .. } | TunerTarget::Tiered { .. } => {
                // Codec-carrying stacks cross the per-link codec on/off
                // toggle into the space; everywhere else it is
                // normalised to `false` (no aliased candidates).
                let codec_dims: &[bool] = match self {
                    TunerTarget::Tiered { topo, .. } if topo.has_codec() => &[true, false],
                    _ => &[false],
                };
                let mut v = Vec::with_capacity(8 * codec_dims.len());
                for slots in [3u8, 2] {
                    for cyclic in [true, false] {
                        for prefetch in [true, false] {
                            for &codec in codec_dims {
                                v.push(Candidate {
                                    tiles: None,
                                    slots,
                                    cyclic,
                                    prefetch,
                                    fuse: 1,
                                    codec,
                                });
                            }
                        }
                    }
                }
                v
            }
            TunerTarget::GpuUnified { .. } => [true, false]
                .into_iter()
                .map(|prefetch| Candidate {
                    tiles: None,
                    slots: 0,
                    cyclic: false,
                    prefetch,
                    fuse: 1,
                    codec: false,
                })
                .collect(),
            TunerTarget::Sharded { inner, .. } => inner.toggle_variants(),
        }
    }

    /// The tile count the heuristic auto-sizing would pick for this
    /// chain — the centre of the search ladder. For sharded targets the
    /// per-rank chains are roughly `1/ranks` of the global extent, so
    /// the inner count is divided accordingly.
    pub fn heuristic_tiles(
        &self,
        chain: &[LoopInst],
        datasets: &[Dataset],
        stencils: &[Stencil],
    ) -> usize {
        match self {
            TunerTarget::Knl { calib, app } => {
                let target = KnlEngine::new(calib.clone(), *app, true).tile_target();
                PlanSource::Auto
                    .plan(chain, datasets, stencils, target)
                    .num_tiles()
            }
            TunerTarget::GpuExplicit {
                calib, app, link, opts,
            } => {
                // Tolerate out-of-range slots the same way `build` does
                // (TunerTarget fields are public, so nothing upstream is
                // guaranteed to have validated them): clamp, don't panic.
                let opts = GpuOpts {
                    slots: opts.slots.clamp(2, 3),
                    ..*opts
                };
                let target = GpuExplicitEngine::new(calib.clone(), *app, *link, opts)
                    .expect("clamped slots are always valid")
                    .slot_target();
                PlanSource::Auto
                    .plan(chain, datasets, stencils, target)
                    .num_tiles()
            }
            TunerTarget::GpuUnified {
                gpu,
                um,
                app,
                link,
                tiled,
                prefetch,
            } => {
                let target =
                    UnifiedEngine::new(gpu.clone(), um.clone(), *app, *link, *tiled, *prefetch)
                        .tile_target();
                PlanSource::Auto
                    .plan(chain, datasets, stencils, target)
                    .num_tiles()
            }
            TunerTarget::Tiered { topo, opts, .. } => {
                let target = crate::memory::tiered::slot_target_for(topo, opts.slots, 0);
                PlanSource::Auto
                    .plan(chain, datasets, stencils, target)
                    .num_tiles()
            }
            TunerTarget::Sharded { inner, .. } => {
                (inner.heuristic_tiles(chain, datasets, stencils) / self.tile_dim_split(chain))
                    .max(1)
            }
        }
    }

    /// How many ways the decomposition splits the *tiled* dimension of
    /// this chain (1 for single-device targets). Derived from the real
    /// [`crate::distributed::decompose`] grid — not a sqrt estimate —
    /// so non-square rank counts (x8:2d → a 2×4 grid) are exact.
    /// Candidate tile counts apply to the per-rank chains, whose extent
    /// is the global extent over this; the search caps its ladder and
    /// probes accordingly so it does not waste budget on counts that
    /// clamp to identical per-rank plans.
    pub fn tile_dim_split(&self, chain: &[LoopInst]) -> usize {
        match self {
            TunerTarget::Sharded { ranks, kind, .. } => {
                let d = crate::distributed::decompose(chain, (*ranks).max(1) as usize, *kind);
                let tile_dim = crate::tiling::plan::pick_tile_dim(chain);
                let mut split = 1usize;
                for axis in 0..d.axes() {
                    if d.dims[axis] == tile_dim {
                        split = d.grid[axis];
                    }
                }
                split.max(1)
            }
            _ => 1,
        }
    }

    /// Whether `Fixed(heuristic_tiles(..))` with the heuristic toggles
    /// builds exactly the plan the `Auto` heuristic builds — true for
    /// unsharded tiled targets (the search can skip that redundant
    /// evaluation). False for sharded targets (per-rank `Auto` counts
    /// need not equal the global estimate over the split) and for
    /// untiled unified memory (an explicit count switches the engine
    /// into the tiled schedule, a genuinely different candidate).
    pub fn fixed_heuristic_is_redundant(&self) -> bool {
        match self {
            TunerTarget::Knl { .. } | TunerTarget::GpuExplicit { .. } => true,
            // Two-tier stacks plan exactly like the GPU engine; deeper
            // stacks re-plan the innermost level per outer tile, so a
            // fixed global count is a genuinely different candidate.
            TunerTarget::Tiered { topo, .. } => topo.num_tiers() <= 2,
            TunerTarget::GpuUnified { tiled, .. } => *tiled,
            TunerTarget::Sharded { .. } => false,
        }
    }

    /// Stable digest of the platform + calibration constants — half of
    /// the tuned-plan cache key. Uses the `Debug` rendering, which spells
    /// out every calibration float.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv::new();
        h.write_str(&format!("{self:?}"));
        h.finish()
    }
}

fn plan_source(cand: Candidate) -> PlanSource {
    match cand.tiles {
        Some(n) => PlanSource::Fixed(n as usize),
        None => PlanSource::Auto,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpu_target(cyclic: bool, prefetch: bool) -> TunerTarget {
        TunerTarget::GpuExplicit {
            calib: GpuCalib::default(),
            app: AppCalib::CLOVERLEAF_2D,
            link: Link::PciE,
            opts: GpuOpts {
                cyclic,
                prefetch,
                slots: 3,
            },
        }
    }

    #[test]
    fn heuristic_reproduces_configured_toggles() {
        let h = gpu_target(true, false).heuristic();
        assert_eq!(h.tiles, None);
        assert_eq!(h.slots, 3);
        assert!(h.cyclic && !h.prefetch);
    }

    #[test]
    fn toggle_spaces_have_expected_sizes() {
        assert_eq!(gpu_target(true, true).toggle_variants().len(), 8);
        let knl = TunerTarget::Knl {
            calib: KnlCalib::default(),
            app: AppCalib::CLOVERLEAF_2D,
        };
        assert_eq!(knl.toggle_variants().len(), 1);
        let sharded = TunerTarget::Sharded {
            inner: Box::new(gpu_target(true, true)),
            ranks: 4,
            kind: DecompKind::OneD,
            link: Interconnect::NvLink,
            overlap: true,
        };
        assert_eq!(sharded.toggle_variants().len(), 8);
    }

    #[test]
    fn digests_distinguish_platforms_and_calibs() {
        let a = gpu_target(true, true).digest();
        let b = gpu_target(true, false).digest();
        assert_ne!(a, b, "configured toggles are part of the digest");
        let small = TunerTarget::GpuExplicit {
            calib: GpuCalib {
                hbm_bytes: 1 << 20,
                ..GpuCalib::default()
            },
            app: AppCalib::CLOVERLEAF_2D,
            link: Link::PciE,
            opts: GpuOpts::default(),
        };
        assert_ne!(gpu_target(true, true).digest(), small.digest());
    }

    #[test]
    fn build_applies_candidate() {
        let t = gpu_target(false, false);
        let e = t.build(Candidate {
            tiles: Some(7),
            slots: 2,
            cyclic: true,
            prefetch: true,
            fuse: 1,
            codec: false,
        });
        let d = e.describe();
        assert!(d.contains("Cyclic") && d.contains("Prefetch"), "{d}");
    }

    #[test]
    fn codec_toggle_doubles_annotated_tiered_spaces() {
        let tiered = |stack: &str| TunerTarget::Tiered {
            topo: crate::topology::spec::parse_stack(stack).unwrap(),
            compute_bw: 80.0,
            launch_s: 1e-5,
            opts: GpuOpts {
                cyclic: false,
                prefetch: false,
                slots: 3,
            },
        };
        let with = tiered("hbm=16g@509.7+host=inf@11~c:3.5");
        assert!(with.heuristic().codec, "annotated stacks run compressed by default");
        assert_eq!(with.toggle_variants().len(), 16);
        assert!(with.toggle_variants().iter().any(|c| !c.codec));
        // codec-free stacks keep the 8-variant space, normalised false
        let without = tiered("hbm=16g@509.7+host=inf@11");
        assert!(!without.heuristic().codec);
        assert_eq!(without.toggle_variants().len(), 8);
        assert!(without.toggle_variants().iter().all(|c| !c.codec));
        // both codec states build (the stripped twin drops the codecs)
        with.build(with.heuristic());
        with.build(Candidate {
            codec: false,
            ..with.heuristic()
        });
    }
}
