//! The deterministic, seeded search.
//!
//! Three phases, all scored on the engines' own discrete-event clocks:
//!
//! 1. **Pruned exhaustive** over the platform's toggle space crossed
//!    with a geometric tile-count ladder centred on the heuristic count;
//! 2. **Coordinate descent** on the tile count from the incumbent
//!    (unit steps first, then `n/8` strides, while it keeps improving);
//! 3. **Seeded xorshift probes** of uniform random tile counts with the
//!    remaining budget.
//!
//! The heuristic candidate is evaluated first and displaced only by a
//! *strictly* smaller modelled time, so the final choice can never model
//! slower than the heuristic, and evaluation order is fixed, so the same
//! inputs and seed always yield the same plan.

use super::cache::{TunedChoice, TunedPlanCache};
use super::candidate::{chain_fingerprint, Candidate, Fnv, TuneOpts};
use super::target::TunerTarget;
use crate::exec::{Engine, Metrics, NullExecutor, World};
use crate::ops::{DataStore, Dataset, LoopInst, Reduction, Stencil};
use crate::tiling::analysis::fuse_chain;
use crate::tiling::plan::pick_tile_dim;
use std::collections::HashSet;

/// Modelled wall time of one chain on a fresh engine, with numerics
/// suppressed (the [`NullExecutor`]) — the tuner's scoring primitive,
/// public so tests can recompute scores independently.
pub fn model_chain_time(
    engine: &mut dyn Engine,
    chain: &[LoopInst],
    datasets: &[Dataset],
    stencils: &[Stencil],
    cyclic_phase: bool,
) -> f64 {
    let mut metrics = Metrics::new();
    let mut store = DataStore::new();
    let mut reds: Vec<Reduction> = vec![];
    let mut null = NullExecutor;
    let mut world = World {
        datasets,
        stencils,
        store: &mut store,
        reds: &mut reds,
        metrics: &mut metrics,
        exec: &mut null,
    };
    engine.run_chain(chain, &mut world, cyclic_phase);
    metrics.elapsed_s
}

/// Deterministic xorshift64* (same generator the property tests use).
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n.max(1)
    }
}

/// Global extent of the tiled dimension — the ceiling on useful tile
/// counts (mirrors `plan_auto`'s computation).
fn chain_extent(chain: &[LoopInst]) -> usize {
    let dim = pick_tile_dim(chain);
    let glo = chain.iter().map(|l| l.range[dim].0).min().unwrap_or(0);
    let ghi = chain.iter().map(|l| l.range[dim].1).max().unwrap_or(1);
    (ghi - glo).max(1) as usize
}

/// Run the search for one chain on one platform. Deterministic: same
/// inputs and `opts.seed` ⇒ same [`TunedChoice`], bit for bit.
pub fn tune(
    target: &TunerTarget,
    opts: &TuneOpts,
    chain: &[LoopInst],
    datasets: &[Dataset],
    stencils: &[Stencil],
    cyclic_phase: bool,
) -> TunedChoice {
    let heuristic = target.heuristic();
    if chain.is_empty() {
        return TunedChoice {
            candidate: heuristic,
            tuned_model_s: 0.0,
            heuristic_model_s: 0.0,
            evals: 0,
        };
    }

    let budget = opts.budget.max(1);
    let sp = crate::obs::span("tune");
    sp.field("budget", budget);
    sp.field("loops", chain.len());
    let mut evals = 0u32;
    let mut seen: HashSet<Candidate> = HashSet::new();
    let score = |cand: Candidate, evals: &mut u32| -> f64 {
        *evals += 1;
        let csp = crate::obs::span("candidate");
        csp.field("eval", *evals);
        model_chain_time(
            &mut *target.build(cand),
            chain,
            datasets,
            stencils,
            cyclic_phase,
        )
    };

    // Phase 0: the heuristic owns the incumbent slot until something is
    // strictly better.
    seen.insert(heuristic);
    let heuristic_s = score(heuristic, &mut evals);
    let mut best = (heuristic, heuristic_s);

    // Useful tile counts top out at the *per-rank* extent: sharded
    // candidates apply to rank sub-chains, and `plan_chain` clamps
    // anything beyond their extent to the same single-plane plan.
    let extent = (chain_extent(chain) / target.tile_dim_split(chain)).max(1);
    let n_h = target
        .heuristic_tiles(chain, datasets, stencils)
        .clamp(1, extent);
    // On unsharded tiled targets, Fixed(n_h) with the heuristic toggles
    // rebuilds the exact plan Phase 0 already scored — pre-mark it seen
    // so the ladder does not spend an evaluation on it.
    if target.fixed_heuristic_is_redundant() {
        seen.insert(heuristic.with_tiles(n_h as u32));
    }

    // Phase 1: toggle grid × tile-count ladder around the heuristic.
    let ladder: Vec<usize> = [
        n_h,
        n_h.saturating_sub(1).max(1),
        n_h + 1,
        (n_h / 2).max(1),
        n_h * 3 / 4,
        n_h * 5 / 4,
        n_h * 3 / 2,
        n_h * 2,
        n_h * 4,
        1,
        2,
        3,
    ]
    .into_iter()
    .map(|n| n.clamp(1, extent))
    .fold(Vec::new(), |mut acc, n| {
        if !acc.contains(&n) {
            acc.push(n);
        }
        acc
    });

    'grid: for toggles in target.toggle_variants() {
        for &n in &ladder {
            if evals >= budget {
                break 'grid;
            }
            let cand = toggles.with_tiles(n as u32);
            if !seen.insert(cand) {
                continue;
            }
            let s = score(cand, &mut evals);
            if s < best.1 {
                best = (cand, s);
            }
        }
    }

    // Phase 2: coordinate descent on the tile count of the incumbent.
    let mut cur_n = best.0.tiles.map(|n| n as usize).unwrap_or(n_h);
    loop {
        let mut improved = false;
        let strides = [1usize, (cur_n / 8).max(1), (cur_n / 4).max(1)];
        for stride in strides {
            for dir in [-1isize, 1] {
                if evals >= budget {
                    break;
                }
                let next = cur_n.saturating_add_signed(dir * stride as isize);
                let next = next.clamp(1, extent);
                if next == cur_n {
                    continue;
                }
                let cand = best.0.with_tiles(next as u32);
                if !seen.insert(cand) {
                    continue;
                }
                let s = score(cand, &mut evals);
                if s < best.1 {
                    best = (cand, s);
                    cur_n = next;
                    improved = true;
                }
            }
        }
        if !improved || evals >= budget {
            break;
        }
    }

    // Phase 3: seeded uniform probes with whatever budget remains.
    let mut rng = Rng::new(opts.seed);
    let mut misses = 0u32;
    while evals < budget && extent > 1 && misses < budget.saturating_mul(4) {
        let n = 1 + rng.below(extent as u64) as usize;
        let cand = best.0.with_tiles(n as u32);
        if !seen.insert(cand) {
            // Small extents exhaust quickly; bail once probes stop
            // finding fresh candidates.
            misses += 1;
            continue;
        }
        let s = score(cand, &mut evals);
        if s < best.1 {
            best = (cand, s);
        }
    }

    TunedChoice {
        candidate: best.0,
        tuned_model_s: best.1,
        heuristic_model_s: heuristic_s,
        evals,
    }
}

/// Tune the temporal-fusion depth `k` for one chain on one platform:
/// score the modelled **per-step** time of the k-fold super-chain
/// (`model_chain_time(fuse_chain(chain, k)) / k`) over a geometric grid
/// `{1, 2, 4, …} ∩ [1, max_k]`, on the platform's heuristic toggles.
///
/// `k = 1` is evaluated first and owns the incumbent slot — fusion is
/// chosen only on a *strictly* smaller per-step time, so the returned
/// depth can never model slower than unfused replay. The result is
/// memoised in the process-wide [`TunedPlanCache`] under a fuse-salted
/// key (the plain toggle/tile search and the fuse search must not
/// alias). `heuristic_model_s` reports the `k = 1` per-step time.
pub fn tune_fuse(
    target: &TunerTarget,
    opts: &TuneOpts,
    chain: &[LoopInst],
    datasets: &[Dataset],
    stencils: &[Stencil],
    cyclic_phase: bool,
    max_k: u32,
) -> TunedChoice {
    let heuristic = target.heuristic();
    if chain.is_empty() || max_k <= 1 {
        return TunedChoice {
            candidate: heuristic,
            tuned_model_s: 0.0,
            heuristic_model_s: 0.0,
            evals: 0,
        };
    }
    let fp = chain_fingerprint(chain, datasets, stencils, cyclic_phase);
    let mut salt = Fnv::new();
    salt.write_str("fuse");
    salt.write_u64(target.digest());
    salt.write_u64(max_k as u64);
    let key = (fp, salt.finish());
    if let Some(c) = TunedPlanCache::get(key) {
        return c;
    }

    let sp = crate::obs::span("tune-fuse");
    sp.field("max_k", max_k);
    sp.field("loops", chain.len());
    let budget = opts.budget.max(1);
    let mut evals = 0u32;
    let mut score_k = |k: u32, evals: &mut u32| -> f64 {
        *evals += 1;
        let csp = crate::obs::span("candidate");
        csp.field("fuse", k);
        let fused = fuse_chain(chain, k as usize);
        model_chain_time(
            &mut *target.build(heuristic),
            &fused,
            datasets,
            stencils,
            cyclic_phase,
        ) / k as f64
    };
    let base_s = score_k(1, &mut evals);
    let mut best = (heuristic, base_s);
    let mut k = 2u32;
    while k <= max_k && evals < budget {
        let s = score_k(k, &mut evals);
        if s < best.1 {
            best = (Candidate { fuse: k, ..heuristic }, s);
        }
        k = k.saturating_mul(2);
    }
    let choice = TunedChoice {
        candidate: best.0,
        tuned_model_s: best.1,
        heuristic_model_s: base_s,
        evals,
    };
    TunedPlanCache::insert(key, choice);
    choice
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::{AppCalib, GpuCalib, GpuOpts, Link};
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::{shapes, StencilId};
    use crate::ops::{Access, Arg, BlockId, DatasetId};

    fn fixture(ny: usize) -> (Vec<LoopInst>, Vec<Dataset>, Vec<Stencil>) {
        let mut datasets = vec![];
        for i in 0..2u32 {
            datasets.push(Dataset {
                id: DatasetId(i),
                block: BlockId(0),
                name: format!("d{i}"),
                size: [32, ny, 1],
                halo_lo: [2, 2, 0],
                halo_hi: [2, 2, 0],
                elem_bytes: 8,
            });
        }
        let stencils = vec![
            Stencil {
                id: StencilId(0),
                name: "pt".into(),
                points: shapes::point(),
            },
            Stencil {
                id: StencilId(1),
                name: "star".into(),
                points: shapes::star2d(1),
            },
        ];
        let range = [(0, 32), (0, ny as isize), (0, 1)];
        let chain = vec![
            LoopInst {
                name: "a".into(),
                block: BlockId(0),
                range,
                args: vec![
                    Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                    Arg::dat(DatasetId(1), StencilId(0), Access::Write),
                ],
                kernel: kernel(|_| {}),
                kernel_ir: None,
                seq: 0,
                bw_efficiency: 1.0,
            },
            LoopInst {
                name: "b".into(),
                block: BlockId(0),
                range,
                args: vec![
                    Arg::dat(DatasetId(1), StencilId(1), Access::Read),
                    Arg::dat(DatasetId(0), StencilId(0), Access::ReadWrite),
                ],
                kernel: kernel(|_| {}),
                kernel_ir: None,
                seq: 1,
                bw_efficiency: 1.0,
            },
        ];
        (chain, datasets, stencils)
    }

    fn target() -> TunerTarget {
        TunerTarget::GpuExplicit {
            calib: GpuCalib {
                hbm_bytes: 256 << 10,
                ..GpuCalib::default()
            },
            app: AppCalib::CLOVERLEAF_2D,
            link: Link::PciE,
            opts: GpuOpts::default(),
        }
    }

    #[test]
    fn tuned_never_models_slower_than_heuristic() {
        let (chain, datasets, stencils) = fixture(512);
        let t = target();
        let choice = tune(&t, &TuneOpts::default(), &chain, &datasets, &stencils, true);
        assert!(choice.tuned_model_s <= choice.heuristic_model_s);
        assert!(choice.evals >= 1 && choice.evals <= TuneOpts::default().budget);
        // the stored heuristic score is reproducible from scratch
        let h = model_chain_time(
            &mut *t.build(t.heuristic()),
            &chain,
            &datasets,
            &stencils,
            true,
        );
        assert_eq!(h, choice.heuristic_model_s);
    }

    #[test]
    fn tuning_is_deterministic() {
        let (chain, datasets, stencils) = fixture(384);
        let t = target();
        let opts = TuneOpts {
            budget: 32,
            seed: 42,
        };
        let a = tune(&t, &opts, &chain, &datasets, &stencils, true);
        let b = tune(&t, &opts, &chain, &datasets, &stencils, true);
        assert_eq!(a.candidate, b.candidate);
        assert_eq!(a.tuned_model_s, b.tuned_model_s);
        assert_eq!(a.evals, b.evals);
    }

    #[test]
    fn budget_of_one_returns_the_heuristic() {
        let (chain, datasets, stencils) = fixture(256);
        let t = target();
        let opts = TuneOpts { budget: 1, seed: 7 };
        let c = tune(&t, &opts, &chain, &datasets, &stencils, true);
        assert_eq!(c.candidate, t.heuristic());
        assert_eq!(c.evals, 1);
        assert_eq!(c.tuned_model_s, c.heuristic_model_s);
    }

    #[test]
    fn fuse_choice_is_argmin_of_the_k_grid_and_never_worse() {
        let (chain, datasets, stencils) = fixture(512);
        let t = target();
        let opts = TuneOpts::default();
        let choice = tune_fuse(&t, &opts, &chain, &datasets, &stencils, true, 8);
        assert!(
            choice.tuned_model_s <= choice.heuristic_model_s,
            "tuned k must never model slower than k=1"
        );
        assert_eq!(choice.evals, 4, "grid {{1,2,4,8}}");
        // reproduce the argmin from scratch (ties keep the smaller k)
        let mut want = (1u32, f64::INFINITY);
        for k in [1u32, 2, 4, 8] {
            let fused = fuse_chain(&chain, k as usize);
            let s = model_chain_time(
                &mut *t.build(t.heuristic()),
                &fused,
                &datasets,
                &stencils,
                true,
            ) / k as f64;
            if s < want.1 {
                want = (k, s);
            }
        }
        assert_eq!(choice.candidate.fuse, want.0);
        assert_eq!(choice.tuned_model_s, want.1);
        // non-fuse dimensions stay on the heuristic toggles
        assert_eq!(
            Candidate { fuse: 1, ..choice.candidate },
            t.heuristic()
        );
        // second call hits the process-wide cache and agrees bit-for-bit
        let again = tune_fuse(&t, &opts, &chain, &datasets, &stencils, true, 8);
        assert_eq!(again.candidate, choice.candidate);
        assert_eq!(again.tuned_model_s, choice.tuned_model_s);
    }

    #[test]
    fn fuse_grid_of_one_short_circuits_to_unfused() {
        let (chain, datasets, stencils) = fixture(128);
        let c = tune_fuse(
            &target(),
            &TuneOpts::default(),
            &chain,
            &datasets,
            &stencils,
            true,
            1,
        );
        assert_eq!(c.candidate.fuse, 1);
        assert_eq!(c.evals, 0);
    }

    #[test]
    fn empty_chain_short_circuits() {
        let (_, datasets, stencils) = fixture(64);
        let c = tune(
            &target(),
            &TuneOpts::default(),
            &[],
            &datasets,
            &stencils,
            true,
        );
        assert_eq!(c.evals, 0);
    }
}
