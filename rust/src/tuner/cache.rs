//! The process-wide tuned-plan cache.
//!
//! Keyed by `(chain fingerprint, platform+options digest)` so that
//! repeated chains within a run (a timestepped app re-enqueues the same
//! chain every step) and repeated cells of a sweep reuse the search
//! result instead of re-evaluating the cost model. The cache stores the
//! *choice* — candidate plus its modelled and heuristic times — not the
//! plan itself; plans are rebuilt deterministically from the candidate.
//!
//! The cache is safe to share across unrelated runs in one process: the
//! key digests every model input (chain structure, dataset geometry,
//! stencils, calibration constants, budget and seed), and the stored
//! choice is itself the output of a deterministic search, so a hit
//! returns exactly what a fresh search would.

use super::candidate::Candidate;
use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

/// A finished tuning decision for one (chain, platform) pair.
#[derive(Debug, Clone, Copy)]
pub struct TunedChoice {
    /// The winning configuration.
    pub candidate: Candidate,
    /// Modelled chain time of the winner, seconds (from a cold engine).
    pub tuned_model_s: f64,
    /// Modelled chain time of the heuristic plan, seconds. Invariant:
    /// `tuned_model_s <= heuristic_model_s` — the heuristic is evaluated
    /// first and displaced only by strictly better candidates.
    pub heuristic_model_s: f64,
    /// Cost-model evaluations the search spent.
    pub evals: u32,
}

type Key = (u64, u64);

fn cache() -> &'static Mutex<HashMap<Key, TunedChoice>> {
    static CACHE: OnceLock<Mutex<HashMap<Key, TunedChoice>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Facade over the process-wide cache.
pub struct TunedPlanCache;

impl TunedPlanCache {
    pub fn get(key: Key) -> Option<TunedChoice> {
        cache().lock().unwrap().get(&key).copied()
    }

    pub fn insert(key: Key, choice: TunedChoice) {
        cache().lock().unwrap().insert(key, choice);
    }

    /// Number of cached choices (diagnostics/tests).
    pub fn len() -> usize {
        cache().lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_roundtrip() {
        let key = (0xDEAD_BEEF_u64, 0xC0FFEE_u64);
        assert!(TunedPlanCache::get(key).is_none());
        let c = TunedChoice {
            candidate: Candidate {
                tiles: Some(4),
                slots: 3,
                cyclic: true,
                prefetch: true,
                fuse: 1,
            },
            tuned_model_s: 1.5,
            heuristic_model_s: 2.0,
            evals: 12,
        };
        TunedPlanCache::insert(key, c);
        let got = TunedPlanCache::get(key).expect("cached");
        assert_eq!(got.candidate, c.candidate);
        assert_eq!(got.evals, 12);
        assert!(TunedPlanCache::len() >= 1);
    }
}
