//! The process-wide tuned-plan cache.
//!
//! Keyed by `(chain fingerprint, platform+options digest)` so that
//! repeated chains within a run (a timestepped app re-enqueues the same
//! chain every step) and repeated cells of a sweep reuse the search
//! result instead of re-evaluating the cost model. The cache stores the
//! *choice* — candidate plus its modelled and heuristic times — not the
//! plan itself; plans are rebuilt deterministically from the candidate.
//!
//! The cache is safe to share across unrelated runs in one process: the
//! key digests every model input (chain structure, dataset geometry,
//! stencils, calibration constants, budget and seed), and the stored
//! choice is itself the output of a deterministic search, so a hit
//! returns exactly what a fresh search would.

use super::candidate::Candidate;
use std::collections::HashMap;
use std::sync::{Mutex, MutexGuard, OnceLock};

/// A finished tuning decision for one (chain, platform) pair.
#[derive(Debug, Clone, Copy)]
pub struct TunedChoice {
    /// The winning configuration.
    pub candidate: Candidate,
    /// Modelled chain time of the winner, seconds (from a cold engine).
    pub tuned_model_s: f64,
    /// Modelled chain time of the heuristic plan, seconds. Invariant:
    /// `tuned_model_s <= heuristic_model_s` — the heuristic is evaluated
    /// first and displaced only by strictly better candidates.
    pub heuristic_model_s: f64,
    /// Cost-model evaluations the search spent.
    pub evals: u32,
}

type Key = (u64, u64);

fn cache() -> &'static Mutex<HashMap<Key, TunedChoice>> {
    static CACHE: OnceLock<Mutex<HashMap<Key, TunedChoice>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Lock the cache, recovering from poisoning: the cache is shared by
/// every tenant in the process, and a panicking candidate evaluation
/// must not wedge it for everyone else. Recovery is sound because every
/// write is a single `HashMap` insert of a fully-built value — a
/// panicking holder can leave no half-written entry behind.
fn locked() -> MutexGuard<'static, HashMap<Key, TunedChoice>> {
    cache().lock().unwrap_or_else(|e| e.into_inner())
}

/// Facade over the process-wide cache.
pub struct TunedPlanCache;

impl TunedPlanCache {
    pub fn get(key: Key) -> Option<TunedChoice> {
        locked().get(&key).copied()
    }

    pub fn insert(key: Key, choice: TunedChoice) {
        locked().insert(key, choice);
    }

    /// Number of cached choices (diagnostics/tests).
    pub fn len() -> usize {
        locked().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_roundtrip() {
        let key = (0xDEAD_BEEF_u64, 0xC0FFEE_u64);
        assert!(TunedPlanCache::get(key).is_none());
        let c = TunedChoice {
            candidate: Candidate {
                tiles: Some(4),
                slots: 3,
                cyclic: true,
                prefetch: true,
                fuse: 1,
                codec: false,
            },
            tuned_model_s: 1.5,
            heuristic_model_s: 2.0,
            evals: 12,
        };
        TunedPlanCache::insert(key, c);
        let got = TunedPlanCache::get(key).expect("cached");
        assert_eq!(got.candidate, c.candidate);
        assert_eq!(got.evals, 12);
        assert!(TunedPlanCache::len() >= 1);
    }

    #[test]
    fn poisoned_lock_recovers_for_other_tenants() {
        let key = (0x5E1F_0001_u64, 0xBAD_u64);
        let c = TunedChoice {
            candidate: Candidate {
                tiles: None,
                slots: 3,
                cyclic: false,
                prefetch: false,
                fuse: 1,
                codec: false,
            },
            tuned_model_s: 0.5,
            heuristic_model_s: 0.5,
            evals: 1,
        };
        TunedPlanCache::insert(key, c);
        // Poison the shared mutex the way a panicking candidate
        // evaluation would: panic while holding the guard. Unwinding
        // through a held guard poisons it even on the same thread.
        let poison = std::panic::catch_unwind(|| {
            let _guard = cache().lock().unwrap_or_else(|e| e.into_inner());
            panic!("candidate evaluation panicked while holding the cache");
        });
        assert!(poison.is_err());
        assert!(cache().is_poisoned(), "the panic must actually poison");
        // Every other tenant still reads and writes through the facade.
        let got = TunedPlanCache::get(key).expect("poisoning must not lose the cache");
        assert_eq!(got.candidate, c.candidate);
        let key2 = (0x5E1F_0002_u64, 0xBAD_u64);
        TunedPlanCache::insert(key2, c);
        assert!(TunedPlanCache::get(key2).is_some());
    }
}
