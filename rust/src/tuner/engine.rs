//! [`TunedEngine`] — the auto-tuner as an ordinary memory engine.
//!
//! Wraps a [`TunerTarget`]; on every chain it fingerprints the inputs,
//! consults the process-wide [`TunedPlanCache`] (searching on a miss),
//! then delegates execution — real numerics included — to an inner
//! engine configured for the winning candidate. Inner engines are kept
//! per candidate, so chains that tune to the same choice (the common
//! case: a timestepped app repeats one chain shape) accumulate
//! cross-chain model state — prefetch credit, cache warmth, resident
//! sets — exactly as on an untuned engine. Chains that pick *different*
//! candidates run on separate engines whose state is independent; the
//! never-worse guarantee is about per-chain cold-engine model scores,
//! not the warm cross-chain wall clock.

use super::cache::{TunedChoice, TunedPlanCache};
use super::candidate::{chain_fingerprint, Candidate, Fnv, TuneOpts};
use super::search::tune;
use super::target::TunerTarget;
use crate::exec::{Engine, World};
use crate::ops::LoopInst;
use crate::tiling::analysis::{self, ChainAnalysis};
use std::collections::HashMap;

/// Auto-tuning wrapper around a tunable platform.
pub struct TunedEngine {
    target: TunerTarget,
    opts: TuneOpts,
    /// Platform + options digest: the cache-key half that does not
    /// depend on the chain.
    digest: u64,
    engines: HashMap<Candidate, Box<dyn Engine>>,
    /// A heuristic-configured instance kept for capacity queries and
    /// the label — capacity is a platform constant, so one probe engine
    /// serves every `fits` call.
    probe: Box<dyn Engine>,
    label: String,
}

impl TunedEngine {
    pub fn new(target: TunerTarget, opts: TuneOpts) -> Self {
        let mut h = Fnv::new();
        h.write_u64(target.digest());
        h.write_u64(opts.budget as u64);
        h.write_u64(opts.seed);
        let probe = target.build(target.heuristic());
        let label = probe.describe();
        TunedEngine {
            digest: h.finish(),
            target,
            opts,
            engines: HashMap::new(),
            probe,
            label,
        }
    }

    /// The most recent decision for a chain (tests/diagnostics).
    pub fn choice_for(
        &self,
        chain: &[LoopInst],
        datasets: &[crate::ops::Dataset],
        stencils: &[crate::ops::Stencil],
        cyclic_phase: bool,
    ) -> Option<TunedChoice> {
        let fp = chain_fingerprint(chain, datasets, stencils, cyclic_phase);
        TunedPlanCache::get((fp, self.digest))
    }
}

impl Engine for TunedEngine {
    fn run_chain(&mut self, chain: &[LoopInst], world: &mut World<'_>, cyclic_phase: bool) {
        self.run_chain_analyzed(chain, None, world, cyclic_phase);
    }

    fn run_chain_analyzed(
        &mut self,
        chain: &[LoopInst],
        analysis: Option<&ChainAnalysis>,
        world: &mut World<'_>,
        cyclic_phase: bool,
    ) {
        if chain.is_empty() {
            return;
        }
        // With a frozen Program the chain's structural digest is already
        // computed — the cache key costs one hash mix instead of an
        // O(chain) FNV pass.
        let fp = match analysis {
            Some(a) => analysis::with_cyclic(a.fingerprint, cyclic_phase),
            None => chain_fingerprint(chain, world.datasets, world.stencils, cyclic_phase),
        };
        let key = (fp, self.digest);
        let choice = match TunedPlanCache::get(key) {
            Some(c) => {
                world.metrics.tune_cache_hits += 1;
                c
            }
            None => {
                let c = tune(
                    &self.target,
                    &self.opts,
                    chain,
                    world.datasets,
                    world.stencils,
                    cyclic_phase,
                );
                TunedPlanCache::insert(key, c);
                world.metrics.tune_evals += c.evals as u64;
                c
            }
        };
        world.metrics.tuned_model_s += choice.tuned_model_s;
        world.metrics.heuristic_model_s += choice.heuristic_model_s;

        let engine = self
            .engines
            .entry(choice.candidate)
            .or_insert_with(|| self.target.build(choice.candidate));
        engine.run_chain_analyzed(chain, analysis, world, cyclic_phase);
    }

    /// Forward to every candidate-configured inner engine (and the
    /// capacity probe, for symmetry).
    fn reset_transient(&mut self) {
        for e in self.engines.values_mut() {
            e.reset_transient();
        }
        self.probe.reset_transient();
    }

    fn describe(&self) -> String {
        format!("auto-tuned [{}]", self.label)
    }

    fn fits(&self, problem_bytes: u64) -> bool {
        // Capacity is a platform property, not a plan property: ask the
        // cached heuristic-configured instance.
        self.probe.fits(problem_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{Executor, Metrics, NativeExecutor};
    use crate::memory::{AppCalib, GpuCalib, GpuOpts, Link};
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::{shapes, StencilId};
    use crate::ops::*;

    fn fixture(ny: usize) -> (Vec<Dataset>, Vec<Stencil>, Vec<LoopInst>) {
        let mut datasets = vec![];
        for i in 0..2u32 {
            datasets.push(Dataset {
                id: DatasetId(i),
                block: BlockId(0),
                name: format!("d{i}"),
                size: [32, ny, 1],
                halo_lo: [2, 2, 0],
                halo_hi: [2, 2, 0],
                elem_bytes: 8,
            });
        }
        let stencils = vec![
            Stencil {
                id: StencilId(0),
                name: "pt".into(),
                points: shapes::point(),
            },
            Stencil {
                id: StencilId(1),
                name: "star".into(),
                points: shapes::star2d(1),
            },
        ];
        let range = [(0, 32), (0, ny as isize), (0, 1)];
        let chain = vec![
            LoopInst {
                name: "mk".into(),
                block: BlockId(0),
                range,
                args: vec![
                    Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                    Arg::dat(DatasetId(1), StencilId(0), Access::Write),
                ],
                kernel: kernel(|c| {
                    let v = c.r(0, -1, 0) + c.r(0, 1, 0);
                    c.w(1, 0, 0, 0.5 * v);
                }),
                kernel_ir: None,
                seq: 0,
                bw_efficiency: 1.0,
            },
            LoopInst {
                name: "fold".into(),
                block: BlockId(0),
                range,
                args: vec![
                    Arg::dat(DatasetId(1), StencilId(1), Access::Read),
                    Arg::dat(DatasetId(0), StencilId(0), Access::ReadWrite),
                ],
                kernel: kernel(|c| {
                    let v = c.r(0, 0, -1) + c.r(0, 0, 1);
                    let s = c.r(1, 0, 0);
                    c.w(1, 0, 0, s + 0.1 * v);
                }),
                kernel_ir: None,
                seq: 1,
                bw_efficiency: 1.0,
            },
        ];
        (datasets, stencils, chain)
    }

    fn tuned_engine(seed: u64) -> TunedEngine {
        TunedEngine::new(
            TunerTarget::GpuExplicit {
                calib: GpuCalib {
                    hbm_bytes: 256 << 10,
                    ..GpuCalib::default()
                },
                app: AppCalib::CLOVERLEAF_2D,
                link: Link::PciE,
                opts: GpuOpts::default(),
            },
            TuneOpts {
                budget: 24,
                seed,
            },
        )
    }

    fn run(e: &mut dyn Engine, chains: usize, seed_data: u64) -> (Vec<Vec<f64>>, Metrics) {
        let (datasets, stencils, chain) = fixture(512);
        let mut store = DataStore::new();
        for d in &datasets {
            store.alloc(d);
            for (i, v) in store.buf_mut(d.id).iter_mut().enumerate() {
                *v = ((i as u64).wrapping_mul(seed_data) % 1000) as f64 * 1e-3;
            }
        }
        let mut reds: Vec<Reduction> = vec![];
        let mut metrics = Metrics::new();
        let mut exec = NativeExecutor::new();
        for _ in 0..chains {
            let mut world = World {
                datasets: &datasets,
                stencils: &stencils,
                store: &mut store,
                reds: &mut reds,
                metrics: &mut metrics,
                exec: &mut exec,
            };
            e.run_chain(&chain, &mut world, true);
        }
        (
            datasets.iter().map(|d| store.buf(d.id).to_vec()).collect(),
            metrics,
        )
    }

    #[test]
    fn tuned_numerics_match_untiled_reference() {
        let (datasets, _, chain) = fixture(512);
        let mut store_ref = DataStore::new();
        for d in &datasets {
            store_ref.alloc(d);
            for (i, v) in store_ref.buf_mut(d.id).iter_mut().enumerate() {
                *v = ((i as u64).wrapping_mul(97) % 1000) as f64 * 1e-3;
            }
        }
        let mut reds: Vec<Reduction> = vec![];
        let mut exec = NativeExecutor::new();
        for _ in 0..2 {
            for l in &chain {
                exec.run_loop(l, l.range, &datasets, &mut store_ref, &mut reds);
            }
        }
        let want: Vec<Vec<f64>> = datasets.iter().map(|d| store_ref.buf(d.id).to_vec()).collect();

        let mut e = tuned_engine(11);
        let (got, m) = run(&mut e, 2, 97);
        assert_eq!(want, got, "tuning must not change numerics");
        assert!(m.tune_evals > 0, "first chain must search");
        assert!(m.tune_cache_hits >= 1, "second chain must hit the cache");
        assert!(m.tuned_model_s <= m.heuristic_model_s);
    }

    #[test]
    fn describe_and_fits_delegate() {
        let e = tuned_engine(5);
        assert!(e.describe().starts_with("auto-tuned ["), "{}", e.describe());
        assert!(e.fits(u64::MAX / 4), "explicit streaming fits anything");
    }
}
