//! Search-space points, tuning options, and the chain fingerprint that
//! keys the tuned-plan cache.

/// One point of the tuner's search space.
///
/// Fields that a platform does not expose are normalised to fixed values
/// by [`super::target::TunerTarget::toggle_variants`] (e.g. `slots: 0`
/// on KNL), so `Candidate` is usable as a map key without aliasing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Candidate {
    /// Tile count along the tiled dimension; `None` means the engine's
    /// own heuristic auto-sizing (the `HBM/3`-style seed behaviour).
    pub tiles: Option<u32>,
    /// GPU-explicit buffering depth (2 or 3); 0 where not applicable.
    pub slots: u8,
    /// §4.1 Cyclic toggle (GPU-explicit).
    pub cyclic: bool,
    /// Prefetch toggle (GPU-explicit and unified memory).
    pub prefetch: bool,
    /// Temporal fusion depth `k` (steps per super-chain,
    /// [`crate::program::Session::replay_fused`]); 1 = unfused. The
    /// toggle/tile search holds this at 1 — [`super::tune_fuse`] owns
    /// the k dimension — so plain tuning never aliases across depths.
    pub fuse: u32,
    /// Keep the stack's link codecs enabled (tiered stacks that carry
    /// `~c:` annotations); normalised to `false` everywhere else, so
    /// codec-free platforms never alias across this field.
    pub codec: bool,
}

impl Candidate {
    /// The same toggles with an explicit tile count.
    pub fn with_tiles(self, n: u32) -> Candidate {
        Candidate {
            tiles: Some(n),
            ..self
        }
    }
}

/// Tuning options: evaluation budget and search seed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TuneOpts {
    /// Maximum cost-model evaluations per (chain, platform) pair. The
    /// heuristic always gets the first evaluation, so a budget of 1
    /// degenerates to the untuned plan.
    pub budget: u32,
    /// Seed for the exploration probes. Same seed ⇒ same plan.
    pub seed: u64,
}

impl Default for TuneOpts {
    fn default() -> Self {
        TuneOpts {
            budget: 48,
            seed: 0x0C0FFEE5,
        }
    }
}

/// The chain digest and FNV hasher now live with the cached-analysis
/// machinery in [`crate::tiling::analysis`] (the Program/Session layer
/// reuses them); re-exported here so tuner call sites keep compiling.
pub use crate::tiling::analysis::{chain_fingerprint, Fnv};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::{shapes, StencilId};
    use crate::ops::{Access, Arg, BlockId, Dataset, DatasetId, LoopInst, Stencil};

    fn fixture(ny: usize, eff: f64) -> (Vec<LoopInst>, Vec<Dataset>, Vec<Stencil>) {
        let datasets = vec![Dataset {
            id: DatasetId(0),
            block: BlockId(0),
            name: "d".into(),
            size: [16, ny, 1],
            halo_lo: [1, 1, 0],
            halo_hi: [1, 1, 0],
            elem_bytes: 8,
        }];
        let stencils = vec![Stencil {
            id: StencilId(0),
            name: "pt".into(),
            points: shapes::point(),
        }];
        let chain = vec![LoopInst {
            name: "w".into(),
            block: BlockId(0),
            range: [(0, 16), (0, ny as isize), (0, 1)],
            args: vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)],
            kernel: kernel(|_| {}),
            kernel_ir: None,
            seq: 0,
            bw_efficiency: eff,
        }];
        (chain, datasets, stencils)
    }

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        let (c1, d1, s1) = fixture(64, 1.0);
        let a = chain_fingerprint(&c1, &d1, &s1, true);
        let b = chain_fingerprint(&c1, &d1, &s1, true);
        assert_eq!(a, b, "same inputs must hash identically");
        // every modelled input perturbs the digest
        let (c2, d2, s2) = fixture(65, 1.0);
        assert_ne!(a, chain_fingerprint(&c2, &d2, &s2, true), "range");
        let (c3, d3, s3) = fixture(64, 0.9);
        assert_ne!(a, chain_fingerprint(&c3, &d3, &s3, true), "bw eff");
        assert_ne!(a, chain_fingerprint(&c1, &d1, &s1, false), "cyclic");
        let (mut c4, d4, s4) = fixture(64, 1.0);
        c4[0].args = vec![Arg::dat(DatasetId(0), StencilId(0), Access::ReadWrite)];
        assert_ne!(a, chain_fingerprint(&c4, &d4, &s4, true), "access");
    }

    #[test]
    fn loop_names_do_not_perturb_the_digest() {
        let (mut c, d, s) = fixture(64, 1.0);
        let a = chain_fingerprint(&c, &d, &s, true);
        c[0].name = "renamed".into();
        assert_eq!(a, chain_fingerprint(&c, &d, &s, true));
    }

    #[test]
    fn candidate_with_tiles_keeps_toggles() {
        let c = Candidate {
            tiles: None,
            slots: 3,
            cyclic: true,
            prefetch: false,
            fuse: 4,
            codec: true,
        };
        let t = c.with_tiles(7);
        assert_eq!(t.tiles, Some(7));
        assert_eq!(t.slots, 3);
        assert!(t.cyclic && !t.prefetch);
        assert_eq!(t.fuse, 4);
        assert!(t.codec);
    }
}
