//! Search-space points, tuning options, and the chain fingerprint that
//! keys the tuned-plan cache.

use crate::ops::{Dataset, LoopInst, Stencil};

/// One point of the tuner's search space.
///
/// Fields that a platform does not expose are normalised to fixed values
/// by [`super::target::TunerTarget::toggle_variants`] (e.g. `slots: 0`
/// on KNL), so `Candidate` is usable as a map key without aliasing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Candidate {
    /// Tile count along the tiled dimension; `None` means the engine's
    /// own heuristic auto-sizing (the `HBM/3`-style seed behaviour).
    pub tiles: Option<u32>,
    /// GPU-explicit buffering depth (2 or 3); 0 where not applicable.
    pub slots: u8,
    /// §4.1 Cyclic toggle (GPU-explicit).
    pub cyclic: bool,
    /// Prefetch toggle (GPU-explicit and unified memory).
    pub prefetch: bool,
}

impl Candidate {
    /// The same toggles with an explicit tile count.
    pub fn with_tiles(self, n: u32) -> Candidate {
        Candidate {
            tiles: Some(n),
            ..self
        }
    }
}

/// Tuning options: evaluation budget and search seed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TuneOpts {
    /// Maximum cost-model evaluations per (chain, platform) pair. The
    /// heuristic always gets the first evaluation, so a budget of 1
    /// degenerates to the untuned plan.
    pub budget: u32,
    /// Seed for the exploration probes. Same seed ⇒ same plan.
    pub seed: u64,
}

impl Default for TuneOpts {
    fn default() -> Self {
        TuneOpts {
            budget: 48,
            seed: 0x0C0FFEE5,
        }
    }
}

/// FNV-1a 64-bit — the crate is dependency-free, and the cache key only
/// needs a stable, well-mixed digest (collisions are astronomically
/// unlikely at the handful of chains a run sees).
#[derive(Debug, Clone, Copy)]
pub struct Fnv(u64);

impl Fnv {
    pub fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for b in bytes {
            self.0 ^= *b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write_bytes(&v.to_le_bytes());
    }

    pub fn write_i64(&mut self, v: i64) {
        self.write_bytes(&v.to_le_bytes());
    }

    pub fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write_bytes(s.as_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

/// Digest of everything about a chain that the cost models can see:
/// per-loop iteration ranges, bandwidth efficiencies and dataset
/// arguments (dataset, stencil, access mode), the geometry of every
/// dataset, every stencil's points, and the §4.1 cyclic-phase flag.
/// Loop *names* and kernel bodies are deliberately excluded — they do
/// not affect modelled time.
pub fn chain_fingerprint(
    chain: &[LoopInst],
    datasets: &[Dataset],
    stencils: &[Stencil],
    cyclic_phase: bool,
) -> u64 {
    let mut h = Fnv::new();
    h.write_u64(cyclic_phase as u64);
    h.write_u64(chain.len() as u64);
    for l in chain {
        for (lo, hi) in &l.range {
            h.write_i64(*lo as i64);
            h.write_i64(*hi as i64);
        }
        h.write_f64(l.bw_efficiency);
        for (dat, st, acc) in l.dat_args() {
            h.write_u64(dat.0 as u64);
            h.write_u64(st.0 as u64);
            h.write_u64(acc.reads() as u64 | (acc.writes() as u64) << 1);
        }
    }
    h.write_u64(datasets.len() as u64);
    for ds in datasets {
        for ((sz, lo), hi) in ds.size.iter().zip(&ds.halo_lo).zip(&ds.halo_hi) {
            h.write_u64(*sz as u64);
            h.write_i64(*lo as i64);
            h.write_i64(*hi as i64);
        }
        h.write_u64(ds.elem_bytes);
    }
    h.write_u64(stencils.len() as u64);
    for s in stencils {
        h.write_u64(s.points.len() as u64);
        for p in &s.points {
            for c in p {
                h.write_i64(*c as i64);
            }
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::{shapes, StencilId};
    use crate::ops::{Access, Arg, BlockId, DatasetId};

    fn fixture(ny: usize, eff: f64) -> (Vec<LoopInst>, Vec<Dataset>, Vec<Stencil>) {
        let datasets = vec![Dataset {
            id: DatasetId(0),
            block: BlockId(0),
            name: "d".into(),
            size: [16, ny, 1],
            halo_lo: [1, 1, 0],
            halo_hi: [1, 1, 0],
            elem_bytes: 8,
        }];
        let stencils = vec![Stencil {
            id: StencilId(0),
            name: "pt".into(),
            points: shapes::point(),
        }];
        let chain = vec![LoopInst {
            name: "w".into(),
            block: BlockId(0),
            range: [(0, 16), (0, ny as isize), (0, 1)],
            args: vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)],
            kernel: kernel(|_| {}),
            seq: 0,
            bw_efficiency: eff,
        }];
        (chain, datasets, stencils)
    }

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        let (c1, d1, s1) = fixture(64, 1.0);
        let a = chain_fingerprint(&c1, &d1, &s1, true);
        let b = chain_fingerprint(&c1, &d1, &s1, true);
        assert_eq!(a, b, "same inputs must hash identically");
        // every modelled input perturbs the digest
        let (c2, d2, s2) = fixture(65, 1.0);
        assert_ne!(a, chain_fingerprint(&c2, &d2, &s2, true), "range");
        let (c3, d3, s3) = fixture(64, 0.9);
        assert_ne!(a, chain_fingerprint(&c3, &d3, &s3, true), "bw eff");
        assert_ne!(a, chain_fingerprint(&c1, &d1, &s1, false), "cyclic");
        let (mut c4, d4, s4) = fixture(64, 1.0);
        c4[0].args = vec![Arg::dat(DatasetId(0), StencilId(0), Access::ReadWrite)];
        assert_ne!(a, chain_fingerprint(&c4, &d4, &s4, true), "access");
    }

    #[test]
    fn loop_names_do_not_perturb_the_digest() {
        let (mut c, d, s) = fixture(64, 1.0);
        let a = chain_fingerprint(&c, &d, &s, true);
        c[0].name = "renamed".into();
        assert_eq!(a, chain_fingerprint(&c, &d, &s, true));
    }

    #[test]
    fn candidate_with_tiles_keeps_toggles() {
        let c = Candidate {
            tiles: None,
            slots: 3,
            cyclic: true,
            prefetch: false,
        };
        let t = c.with_tiles(7);
        assert_eq!(t.tiles, Some(7));
        assert_eq!(t.slots, 3);
        assert!(t.cyclic && !t.prefetch);
    }
}
