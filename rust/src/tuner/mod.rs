//! Cost-model-driven tile-plan auto-tuner.
//!
//! Every memory engine ships a *heuristic* plan: auto-size tiles so one
//! slot fits an equal share of fast memory (`HBM/3` for the explicit GPU
//! engine, an MCDRAM occupancy share on KNL, most of HBM for unified
//! memory). The heuristic is robust but rarely optimal — tile count
//! trades per-tile latencies and redundant edge bytes against overlap
//! granularity, and the §4.1 cyclic/prefetch/slot toggles interact with
//! it. This module searches that space.
//!
//! The design rests on one observation: **the engines already are the
//! cost models**. Running a chain through an engine with the no-op
//! [`crate::exec::NullExecutor`] prices a schedule on the engine's own
//! discrete-event clock without touching data. So the tuner scores a
//! candidate by building a *fresh* engine configured for it ([`target`])
//! and replaying the chain model-only ([`search`]). Because the
//! heuristic itself is just another candidate — evaluated first, and
//! displaced only by a *strictly* better score — the chosen plan can
//! **never model slower than the heuristic**, a property enforced by
//! `tests/prop_tuner.rs` over randomised chains, datasets and platforms.
//!
//! The search ([`search::tune`]) is deterministic and seeded: a pruned
//! exhaustive pass over the platform's toggle space crossed with a
//! geometric tile-count ladder around the heuristic count, coordinate
//! descent on the tile count from the incumbent, then seeded xorshift
//! probes until the evaluation budget is spent. Same inputs + same seed
//! ⇒ same plan, bit for bit.
//!
//! Temporal fusion adds an orthogonal dimension: [`search::tune_fuse`]
//! scores the modelled **per-step** time of k-fold fused super-chains
//! ([`crate::tiling::analysis::fuse_chain`]) over a geometric k-grid,
//! with `k = 1` evaluated first and displaced only by strictly better
//! depths — so a driver that asks the tuner for a fusion depth
//! ([`crate::coordinator::Config`] with `fuse = 0`) is never worse than
//! unfused replay.
//!
//! Results are memoised in the process-wide [`cache::TunedPlanCache`],
//! keyed by (chain fingerprint, platform digest, tuning options), so the
//! repeated identical chains of a timestepped app — and repeated cells
//! of a sweep — tune once and reuse the choice. [`engine::TunedEngine`]
//! wraps any tunable platform behind the ordinary [`crate::exec::Engine`]
//! trait; numerics are untouched (candidates only re-schedule, so tuned
//! execution stays bit-exact — `tests/tiling_equivalence.rs` and
//! `tests/sharding_equivalence.rs` hold it to the same bar as tiling).

pub mod cache;
pub mod candidate;
pub mod engine;
pub mod search;
pub mod target;

pub use cache::{TunedChoice, TunedPlanCache};
pub use candidate::{chain_fingerprint, Candidate, TuneOpts};
pub use engine::TunedEngine;
pub use search::{model_chain_time, tune, tune_fuse};
pub use target::TunerTarget;
