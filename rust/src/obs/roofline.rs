//! Roofline report: modelled achieved GB/s per stream vs topology peak.
//!
//! §5.1 defines *Average Bandwidth* as bytes touched per loop divided by
//! modelled runtime; the roofline view decomposes that single number by
//! resource. Every timeline stream the run exercised becomes a row —
//! bytes it moved, the busy time it took, the achieved GB/s those imply,
//! and the peak GB/s of the tier or link the stream models — plus a
//! per-kernel ledger (the §5.1 bytes/time table) sorted by where the
//! time went. Sharded `r<k>:` prefixes are stripped so rank replicas of
//! one physical stream aggregate into a single row.

use crate::exec::{Metrics, StreamClass};
use crate::topology::Topology;
use std::collections::BTreeMap;

/// One stream row: achieved vs peak for a tier or link.
#[derive(Debug, Clone, PartialEq)]
pub struct RooflineRow {
    /// Stream name (rank prefix stripped), e.g. `compute`, `upload`,
    /// `host:download`, `ddr4`.
    pub name: String,
    pub class: StreamClass,
    /// Peak GB/s of the tier/link this stream models.
    pub peak_gbs: f64,
    /// bytes / busy-time, GB/s (0 when the stream was never busy).
    pub achieved_gbs: f64,
    /// busy-time / makespan, clamped to [0, 1].
    pub busy_frac: f64,
    pub bytes: u64,
}

impl RooflineRow {
    /// achieved / peak — how close the stream ran to its roof.
    pub fn frac_of_peak(&self) -> f64 {
        if self.peak_gbs > 0.0 {
            self.achieved_gbs / self.peak_gbs
        } else {
            0.0
        }
    }
}

/// One kernel's §5.1 ledger entry: bytes touched, modelled time, and
/// the average bandwidth they imply.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelLedger {
    pub name: String,
    pub bytes: u64,
    pub time_s: f64,
    pub achieved_gbs: f64,
    pub invocations: u64,
}

/// The full report: stream rows (name-ordered) and the kernel ledger
/// (time-ordered, hottest first).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Roofline {
    pub rows: Vec<RooflineRow>,
    pub kernels: Vec<KernelLedger>,
}

/// Strip a sharded `r<digits>:` rank prefix so per-rank replicas of one
/// stream fold into a single roofline row.
fn strip_rank(name: &str) -> &str {
    if let Some((head, rest)) = name.split_once(':') {
        if let Some(digits) = head.strip_prefix('r') {
            if !digits.is_empty() && digits.bytes().all(|b| b.is_ascii_digit()) {
                return rest;
            }
        }
    }
    name
}

/// Peak GB/s the topology promises for a stream. Compute streams roof
/// at the fastest tier's bandwidth (the §3 model runs kernels out of
/// fast memory); a stream named exactly like a tier uses that tier's
/// bandwidth; a `tier:direction` boundary stream uses the link below
/// that tier; anything else is a legacy two-tier transfer stream on
/// link 0.
fn peak_for(topo: &Topology, name: &str, class: StreamClass) -> f64 {
    if class == StreamClass::Compute {
        return topo.fastest().bw_gbs;
    }
    if let Some(tier) = topo.tiers().iter().find(|t| t.name == name) {
        return tier.bw_gbs;
    }
    let links = topo.links();
    if let Some((tier_name, _dir)) = name.split_once(':') {
        if let Some(i) = topo.tiers().iter().position(|t| t.name == tier_name) {
            if !links.is_empty() {
                return topo.link(i.min(links.len() - 1)).bw_gbs;
            }
        }
    }
    if !links.is_empty() {
        topo.link(0).bw_gbs
    } else {
        topo.fastest().bw_gbs
    }
}

/// Build the report from a finished run's metrics. Exchange streams are
/// modelled on interconnects outside the memory topology, so they get a
/// ledger row in `Metrics` but no roofline row here.
pub fn build(topo: &Topology, m: &Metrics) -> Roofline {
    let mut agg: BTreeMap<String, (StreamClass, f64, u64)> = BTreeMap::new();
    for (name, st) in &m.per_resource {
        if st.class == StreamClass::Exchange {
            continue;
        }
        let e = agg
            .entry(strip_rank(name).to_string())
            .or_insert((st.class, 0.0, 0));
        e.1 += st.busy_s;
        e.2 += st.bytes;
    }
    let rows = agg
        .into_iter()
        .map(|(name, (class, busy_s, bytes))| {
            let achieved_gbs = if busy_s > 0.0 {
                bytes as f64 / busy_s / 1e9
            } else {
                0.0
            };
            let busy_frac = if m.elapsed_s > 0.0 {
                (busy_s / m.elapsed_s).min(1.0)
            } else {
                0.0
            };
            RooflineRow {
                peak_gbs: peak_for(topo, &name, class),
                name,
                class,
                achieved_gbs,
                busy_frac,
                bytes,
            }
        })
        .collect();

    let mut kernels: Vec<KernelLedger> = m
        .per_loop
        .iter()
        .map(|(name, st)| KernelLedger {
            name: name.clone(),
            bytes: st.bytes,
            time_s: st.time_s,
            achieved_gbs: st.bandwidth_gbs(),
            invocations: st.invocations,
        })
        .collect();
    kernels.sort_by(|a, b| b.time_s.total_cmp(&a.time_s).then(a.name.cmp(&b.name)));
    Roofline { rows, kernels }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        crate::topology::preset("gpu-explicit-pcie").unwrap()
    }

    #[test]
    fn rank_prefixes_fold_into_one_row() {
        assert_eq!(strip_rank("r0:upload"), "upload");
        assert_eq!(strip_rank("r12:host:download"), "host:download");
        assert_eq!(strip_rank("rank:upload"), "rank:upload");
        assert_eq!(strip_rank("r:upload"), "r:upload");
        assert_eq!(strip_rank("compute"), "compute");

        let mut m = Metrics::new();
        m.record_stream("r0:upload", StreamClass::Upload, 0.5, 4_000_000_000, 2);
        m.record_stream("r1:upload", StreamClass::Upload, 0.5, 4_000_000_000, 2);
        m.elapsed_s = 1.0;
        let r = build(&topo(), &m);
        assert_eq!(r.rows.len(), 1);
        let row = &r.rows[0];
        assert_eq!(row.name, "upload");
        assert_eq!(row.bytes, 8_000_000_000);
        assert!((row.achieved_gbs - 8.0).abs() < 1e-9);
        assert!((row.busy_frac - 1.0).abs() < 1e-12);
    }

    #[test]
    fn peaks_come_from_the_topology() {
        let t = topo();
        let fast = t.fastest().bw_gbs;
        let link = t.link(0).bw_gbs;
        assert_eq!(peak_for(&t, "compute", StreamClass::Compute), fast);
        assert_eq!(peak_for(&t, "upload", StreamClass::Upload), link);
        assert_eq!(peak_for(&t, "download", StreamClass::Download), link);

        // deeper topology: tier-named and tier:direction streams
        let (target, _) = crate::coordinator::Config::parse_spec(
            "tiers:hbm=64k@509.7+host=256k@11~0.00001+nvme=inf@6~0.00002:cyclic",
        )
        .unwrap();
        let deep =
            crate::coordinator::Config::for_target(target, crate::memory::AppCalib::CLOVERLEAF_2D)
                .topology();
        assert_eq!(peak_for(&deep, "host", StreamClass::Upload), 11.0);
        assert_eq!(peak_for(&deep, "host:upload", StreamClass::Upload), 11.0);
        assert_eq!(peak_for(&deep, "nvme:download", StreamClass::Download), 6.0);
    }

    #[test]
    fn exchange_streams_are_ledger_only() {
        let mut m = Metrics::new();
        m.record_stream("halo", StreamClass::Exchange, 0.1, 1_000_000, 1);
        m.record_stream("compute", StreamClass::Compute, 0.2, 2_000_000_000, 4);
        m.elapsed_s = 0.25;
        let r = build(&topo(), &m);
        assert_eq!(r.rows.len(), 1);
        assert_eq!(r.rows[0].name, "compute");
        assert!(r.rows[0].frac_of_peak() > 0.0);
    }

    #[test]
    fn kernel_ledger_is_hottest_first() {
        let mut m = Metrics::new();
        m.record_loop("warm", 1_000_000_000, 0.01);
        m.record_loop("hot", 4_000_000_000, 0.04);
        m.record_loop("cold", 500_000_000, 0.005);
        let r = build(&topo(), &m);
        let names: Vec<&str> = r.kernels.iter().map(|k| k.name.as_str()).collect();
        assert_eq!(names, ["hot", "warm", "cold"]);
        assert!((r.kernels[0].achieved_gbs - 100.0).abs() < 1e-9);
        assert_eq!(r.kernels[0].invocations, 1);
    }
}
