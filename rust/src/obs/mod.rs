//! Unified observability: lifecycle spans, a metrics registry, and
//! roofline reporting.
//!
//! The paper's whole evaluation (§5.1–§5.3) is an observability
//! exercise — *Average Bandwidth* is bytes touched per loop divided by
//! modelled runtime, and the per-platform claims rest on attributing
//! where that time went. This module is the substrate those numbers
//! flow through:
//!
//! * [`span`] — hierarchical RAII lifecycle spans on **host** time
//!   (`Program::freeze`, per-chain analysis, tuner candidate scoring,
//!   `Session::replay` steps, per-tile engine execution, halo
//!   exchanges). Spans carry structured `key=value` fields for the
//!   *modelled* quantities they wrap, nest parent/child per thread, and
//!   export as a JSON tree ([`spans_json`], the CLI's `--spans`) or
//!   alongside the Chrome trace
//!   ([`crate::exec::chrome_trace_json_with_spans`]).
//! * [`hist`] — [`Registry`] of counters, gauges and log-linear-bucket
//!   [`Histogram`]s (p50/p90/p99 bounds that provably bracket the exact
//!   quantile, exact mergeable counts, ≲6% relative bucket error). The
//!   registry lives on [`crate::exec::Metrics`] (`metrics.obs`), so
//!   per-chain/per-tier series merge across sweep cells and sharded
//!   ranks exactly like the scalar fields.
//! * [`roofline`] — modelled achieved GB/s per stream vs the
//!   [`crate::topology::Topology`] peak of that tier/link, plus the
//!   per-kernel §5.1 bytes/time ledger — printed by the run summary and
//!   emitted under stable `roofline_*` keys in `--json`.
//!
//! Spans are thread-local (engines take `&mut Metrics`, guards must not
//! borrow it); benches and the CLI call [`reset`] once per cell.

pub mod hist;
pub mod roofline;
pub mod span;

pub use hist::{Histogram, Registry};
pub use roofline::{KernelLedger, Roofline, RooflineRow};
pub use span::{
    namespace, reset, snapshot_spans, span, span_stats, spans_json, NamespaceGuard, SpanGuard,
    SpanRec, SpanStats,
};

/// Minimal JSON string escaping shared by the span/telemetry renderers
/// (same contract as the Chrome-trace exporter's).
pub(crate) fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}
