//! Metrics registry: counters, gauges, and log-linear histograms.
//!
//! The histogram buckets by the top 16 bits of the IEEE-754 double: the
//! sign+exponent plus the four leading mantissa bits. That is a
//! *log-linear* layout — every power-of-two binade splits into 16
//! linear sub-buckets — so a bucket's width is at most 1/16 of its
//! lower edge (≲6.25% relative error) across the whole positive f64
//! range, with no configuration and O(1) recording. Quantiles come back
//! as `(lo, hi)` **bounds** that provably bracket the exact rank-order
//! statistic (the property suite in `tests/prop_obs.rs` verifies this
//! against sorted samples); counts/min/max merge exactly, so sharded
//! ranks and sweep cells combine without precision questions.

use std::collections::BTreeMap;

/// Bucket index of a sample: top 16 bits of its bit pattern. All
/// non-positive samples land in bucket 0.
fn bucket_of(v: f64) -> u32 {
    if !v.is_finite() || v <= 0.0 {
        return 0;
    }
    (v.to_bits() >> 48) as u32
}

/// Lower edge of a bucket (exact: the smallest double whose top 16 bits
/// equal `idx`).
fn bucket_lo(idx: u32) -> f64 {
    f64::from_bits((idx as u64) << 48)
}

/// A log-linear-bucket histogram with exact count/min/max/sum side
/// ledgers. `merge(a, b)` is equivalent to recording `a ∪ b` (bucket
/// counts, min, max and quantile bounds exactly; the floating `sum` to
/// summation order).
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    buckets: BTreeMap<u32, u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: BTreeMap::new(),
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample (non-finite samples are ignored).
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        *self.buckets.entry(bucket_of(v)).or_insert(0) += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold another histogram in (bucket-exact).
    pub fn merge(&mut self, other: &Histogram) {
        for (&b, &n) in &other.buckets {
            *self.buckets.entry(b).or_insert(0) += n;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Occupied buckets as `(index, count)`, ascending (tests and
    /// merge-equivalence checks).
    pub fn buckets(&self) -> impl Iterator<Item = (u32, u64)> + '_ {
        self.buckets.iter().map(|(&b, &n)| (b, n))
    }

    /// `(lo, hi)` bracketing the exact q-quantile under the rank rule
    /// `rank = ceil(q·count)` clamped to `1..=count` (so `q=0` is the
    /// minimum, `q=1` the maximum). `None` on an empty histogram. The
    /// true k-th smallest sample lies in `[lo, hi]`, and for positive
    /// samples `hi − lo ≤ lo/16`.
    pub fn quantile_bounds(&self, q: f64) -> Option<(f64, f64)> {
        if self.count == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (&b, &n) in &self.buckets {
            seen += n;
            if seen >= rank {
                // bucket 0 holds every non-positive sample: its true
                // lower edge is the recorded minimum, not 0.0
                let raw_lo = if b == 0 { f64::NEG_INFINITY } else { bucket_lo(b) };
                let lo = raw_lo.max(self.min);
                let hi = bucket_lo(b + 1).min(self.max);
                return Some((lo, hi.max(lo)));
            }
        }
        None
    }

    /// Point estimate: the upper bound of [`Histogram::quantile_bounds`]
    /// (a conservative "no better than" read for latency-style series).
    pub fn quantile(&self, q: f64) -> Option<f64> {
        self.quantile_bounds(q).map(|(_, hi)| hi)
    }
}

/// A named-series registry: monotone counters, last-write gauges, and
/// [`Histogram`]s. Lives on [`crate::exec::Metrics`] (`metrics.obs`);
/// label series by suffixing the name (`tile_compute_s:hbm`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Histogram>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter_add(&mut self, name: &str, n: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += n;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_set(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Record one sample into the named histogram (created on first
    /// sight).
    pub fn record(&mut self, name: &str, v: f64) {
        self.hists.entry(name.to_string()).or_default().record(v);
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.hists.get(name)
    }

    /// All histograms, name-ordered (deterministic report iteration).
    pub fn histograms(&self) -> impl Iterator<Item = (&str, &Histogram)> {
        self.hists.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// All counters, name-ordered.
    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, &v)| (k.as_str(), v))
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }

    /// Fold another registry in: counters add, gauges take the other's
    /// value, histograms merge bucket-exactly.
    pub fn merge(&mut self, other: &Registry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.hists {
            self.hists.entry(k.clone()).or_default().merge(h);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_width_is_bounded() {
        for v in [1e-9, 3.7e-4, 0.5, 1.0, 1.05, 7.3, 1e6, 1e300] {
            let b = bucket_of(v);
            let (lo, hi) = (bucket_lo(b), bucket_lo(b + 1));
            assert!(lo <= v && v < hi, "{v} not in [{lo},{hi})");
            assert!(hi - lo <= lo / 16.0 + f64::EPSILON, "{v}: [{lo},{hi})");
        }
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(-3.0), 0);
        assert_eq!(bucket_of(f64::NAN), 0);
    }

    #[test]
    fn quantiles_bracket_a_known_sample() {
        let mut h = Histogram::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 10);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 10.0);
        assert!((h.mean() - 5.5).abs() < 1e-12);
        // p50 under the ceil-rank rule is the 5th smallest = 5.0
        let (lo, hi) = h.quantile_bounds(0.5).unwrap();
        assert!(lo <= 5.0 && 5.0 <= hi, "[{lo},{hi}]");
        // extremes pin to min/max exactly
        assert_eq!(h.quantile_bounds(0.0).unwrap().0, 1.0);
        assert_eq!(h.quantile_bounds(1.0).unwrap().1, 10.0);
        assert!(h.quantile(0.99).unwrap() <= 10.0);
    }

    #[test]
    fn empty_histogram_is_well_defined() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile_bounds(0.5), None);
        assert_eq!(h.quantile(0.5), None);
    }

    #[test]
    fn merge_matches_union_recording() {
        let a_vals = [0.1, 0.2, 0.35];
        let b_vals = [0.15, 4.0, 0.001, 0.2];
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut u = Histogram::new();
        for v in a_vals {
            a.record(v);
            u.record(v);
        }
        for v in b_vals {
            b.record(v);
            u.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), u.count());
        assert_eq!(a.min(), u.min());
        assert_eq!(a.max(), u.max());
        assert_eq!(
            a.buckets().collect::<Vec<_>>(),
            u.buckets().collect::<Vec<_>>()
        );
        for q in [0.0, 0.25, 0.5, 0.9, 1.0] {
            assert_eq!(a.quantile_bounds(q), u.quantile_bounds(q), "q={q}");
        }
        assert!((a.sum() - u.sum()).abs() <= 1e-12 * u.sum().abs());
    }

    #[test]
    fn registry_series_accumulate_and_merge() {
        let mut r = Registry::new();
        r.counter_add("tiles", 3);
        r.counter_add("tiles", 2);
        r.gauge_set("scale", 8.0);
        r.record("loop_time_s", 0.5);
        assert_eq!(r.counter("tiles"), 5);
        assert_eq!(r.counter("absent"), 0);
        assert_eq!(r.gauge("scale"), Some(8.0));
        assert!(!r.is_empty());

        let mut other = Registry::new();
        other.counter_add("tiles", 10);
        other.record("loop_time_s", 1.5);
        other.record("halo_s", 0.1);
        r.merge(&other);
        assert_eq!(r.counter("tiles"), 15);
        assert_eq!(r.histogram("loop_time_s").unwrap().count(), 2);
        assert_eq!(r.histogram("halo_s").unwrap().count(), 1);
        assert_eq!(r.histograms().count(), 2);
        assert_eq!(Registry::new().is_empty(), true);
    }
}
