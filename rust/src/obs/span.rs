//! Hierarchical lifecycle spans: RAII guards on a thread-local tracer.
//!
//! A [`span`] opens a named interval on **host** time (the same clock
//! `Program::freeze` already reports); dropping the guard closes it.
//! Open spans form a stack, so every span records its parent and depth
//! — the export is a proper tree. Guards carry structured `key=value`
//! [`SpanGuard::field`]s for the *modelled* quantities of the interval
//! they wrap (a chain's makespan, a halo's bytes), keeping the host
//! clock and the simulated clock cleanly separated.
//!
//! The tracer is deliberately thread-local: engines run under
//! `&mut World` (which owns `&mut Metrics`), so a guard holding a
//! metrics borrow across a whole chain would not compile. Per-thread
//! state also isolates parallel tests for free. Benches and the CLI
//! call [`reset`] once per cell; [`snapshot_spans`] closes still-open
//! spans *in the copy only*, so it is safe to export mid-run.
//!
//! Sharded runs wrap each modelled rank in a [`namespace`] guard: every
//! span opened while it lives gets a `r3:`-style name prefix, so nested
//! rank spans don't collide in merged exports (the same re-namespacing
//! the per-rank timeline streams get).

use std::cell::RefCell;
use std::fmt::Display;
use std::marker::PhantomData;
use std::time::Instant;

/// One recorded span: a named host-time interval in the span tree.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRec {
    /// Index into the thread's span list (creation order).
    pub id: u32,
    /// Parent span id; `None` for roots.
    pub parent: Option<u32>,
    /// Full name, namespace prefixes included (`r0:gpu_explicit`).
    pub name: String,
    /// Nesting depth (roots are 0).
    pub depth: u32,
    /// Host seconds since the tracer epoch ([`reset`]).
    pub start_s: f64,
    /// Host end time; open spans report their snapshot time.
    pub end_s: f64,
    /// Structured `key=value` fields, in attachment order.
    pub fields: Vec<(String, String)>,
}

/// Aggregate span accounting for one thread ([`span_stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpanStats {
    /// Spans recorded since the last [`reset`].
    pub total: u64,
    /// Deepest nesting seen (a single root span counts 1).
    pub max_depth: u64,
    /// Spans currently open.
    pub open: u64,
    /// Spans dropped at the retention cap.
    pub dropped: u64,
}

struct Tracer {
    epoch: Instant,
    spans: Vec<SpanRec>,
    stack: Vec<u32>,
    prefixes: Vec<String>,
    dropped: u64,
}

impl Tracer {
    fn new() -> Self {
        Tracer {
            epoch: Instant::now(),
            spans: Vec::new(),
            stack: Vec::new(),
            prefixes: Vec::new(),
            dropped: 0,
        }
    }

    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }
}

thread_local! {
    static TRACER: RefCell<Tracer> = RefCell::new(Tracer::new());
}

/// Per-thread retention cap: beyond this, [`span`] hands out no-op
/// guards and counts the drops — a long sweep cannot grow memory
/// unboundedly if a caller forgets to [`reset`] between cells.
const MAX_SPANS: usize = 1 << 20;

const DROPPED_ID: u32 = u32::MAX;

/// RAII guard for one open span; dropping it closes the interval.
/// `!Send` — spans belong to the thread that opened them.
pub struct SpanGuard {
    id: u32,
    _not_send: PhantomData<*const ()>,
}

/// RAII guard for one active name prefix (see [`namespace`]).
pub struct NamespaceGuard {
    /// Whether this guard actually pushed a prefix (false when the same
    /// prefix was already innermost — see [`namespace`]); only what was
    /// pushed is popped on drop.
    pushed: bool,
    _not_send: PhantomData<*const ()>,
}

/// Clear the thread's span state and restart its epoch. Call once per
/// run/bench cell before the work the export should cover.
pub fn reset() {
    TRACER.with(|t| *t.borrow_mut() = Tracer::new());
}

/// Open a span as a child of the innermost open span (or as a root).
pub fn span(name: &str) -> SpanGuard {
    TRACER.with(|t| {
        let mut tr = t.borrow_mut();
        if tr.spans.len() >= MAX_SPANS {
            tr.dropped += 1;
            return SpanGuard {
                id: DROPPED_ID,
                _not_send: PhantomData,
            };
        }
        let id = tr.spans.len() as u32;
        let parent = tr.stack.last().copied();
        let depth = tr.stack.len() as u32;
        let full = if tr.prefixes.is_empty() {
            name.to_string()
        } else {
            format!("{}:{name}", tr.prefixes.join(":"))
        };
        let start = tr.now();
        tr.spans.push(SpanRec {
            id,
            parent,
            name: full,
            depth,
            start_s: start,
            end_s: start,
            fields: Vec::new(),
        });
        tr.stack.push(id);
        SpanGuard {
            id,
            _not_send: PhantomData,
        }
    })
}

/// Push a name prefix applied to every span opened while the returned
/// guard lives (`namespace("r2")` + `span("rank")` → `r2:rank`).
/// Prefixes stack: nested namespaces join with `:` — except that
/// re-entering the *innermost* active prefix is idempotent (a sharded
/// engine replaying a chain through a rank that is itself namespaced
/// must not mint `r0:r0:…` span names; streams and trace events guard
/// the same way in `distributed::sharded`).
pub fn namespace(prefix: &str) -> NamespaceGuard {
    let pushed = TRACER.with(|t| {
        let mut tr = t.borrow_mut();
        if tr.prefixes.last().is_some_and(|p| p == prefix) {
            false
        } else {
            tr.prefixes.push(prefix.to_string());
            true
        }
    });
    NamespaceGuard {
        pushed,
        _not_send: PhantomData,
    }
}

impl Drop for NamespaceGuard {
    fn drop(&mut self) {
        if !self.pushed {
            return;
        }
        TRACER.with(|t| {
            t.borrow_mut().prefixes.pop();
        });
    }
}

impl SpanGuard {
    /// Attach one structured field (recorded in attachment order).
    pub fn field(&self, key: &str, value: impl Display) {
        if self.id == DROPPED_ID {
            return;
        }
        TRACER.with(|t| {
            let mut tr = t.borrow_mut();
            if let Some(s) = tr.spans.get_mut(self.id as usize) {
                s.fields.push((key.to_string(), value.to_string()));
            }
        });
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.id == DROPPED_ID {
            return;
        }
        TRACER.with(|t| {
            let mut tr = t.borrow_mut();
            let end = tr.now();
            // Pop down to and including this span. Guards normally drop
            // LIFO; if an inner guard leaked, its (still-open) children
            // are force-closed at the same instant so the tree stays
            // well-nested.
            while let Some(top) = tr.stack.pop() {
                if let Some(s) = tr.spans.get_mut(top as usize) {
                    s.end_s = end;
                }
                if top == self.id {
                    break;
                }
            }
        });
    }
}

/// Copy the thread's span list; spans still open are closed at "now"
/// *in the copy only* (the live tree is untouched).
pub fn snapshot_spans() -> Vec<SpanRec> {
    TRACER.with(|t| {
        let tr = t.borrow();
        let now = tr.now();
        let mut out = tr.spans.clone();
        for &id in &tr.stack {
            if let Some(s) = out.get_mut(id as usize) {
                s.end_s = now;
            }
        }
        out
    })
}

/// Aggregate counts for the thread's tracer (fed into
/// `Metrics::spans_recorded` / `span_max_depth` by the cell runners).
pub fn span_stats() -> SpanStats {
    TRACER.with(|t| {
        let tr = t.borrow();
        SpanStats {
            total: tr.spans.len() as u64,
            max_depth: tr.spans.iter().map(|s| s.depth as u64 + 1).max().unwrap_or(0),
            open: tr.stack.len() as u64,
            dropped: tr.dropped,
        }
    })
}

/// Render spans as a nested JSON tree:
/// `{"spans":[{name,start_s,end_s,fields?,children?},…],"count":N,"max_depth":D}`
/// — the payload of the CLI's `--spans <path>`.
pub fn spans_json(spans: &[SpanRec]) -> String {
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); spans.len()];
    let mut roots: Vec<usize> = Vec::new();
    for (i, s) in spans.iter().enumerate() {
        match s.parent {
            Some(p) if (p as usize) < i => children[p as usize].push(i),
            _ => roots.push(i),
        }
    }
    let max_depth = spans.iter().map(|s| s.depth as u64 + 1).max().unwrap_or(0);
    let mut out = String::from("{\"spans\":[");
    for (k, &r) in roots.iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        render(spans, &children, r, &mut out);
    }
    out.push_str(&format!(
        "],\"count\":{},\"max_depth\":{max_depth}}}",
        spans.len()
    ));
    out
}

fn render(spans: &[SpanRec], children: &[Vec<usize>], i: usize, out: &mut String) {
    let s = &spans[i];
    out.push_str(&format!(
        "{{\"name\":\"{}\",\"start_s\":{:.9},\"end_s\":{:.9}",
        super::esc(&s.name),
        s.start_s,
        s.end_s
    ));
    if !s.fields.is_empty() {
        out.push_str(",\"fields\":{");
        for (j, (k, v)) in s.fields.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":\"{}\"", super::esc(k), super::esc(v)));
        }
        out.push('}');
    }
    if !children[i].is_empty() {
        out.push_str(",\"children\":[");
        for (j, &c) in children[i].iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            render(spans, children, c, out);
        }
        out.push(']');
    }
    out.push('}');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_nest_and_close_in_order() {
        reset();
        {
            let outer = span("outer");
            outer.field("k", 42);
            {
                let inner = span("inner");
                inner.field("what", "child");
            }
            let _second = span("second");
        }
        let spans = snapshot_spans();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].name, "outer");
        assert_eq!(spans[0].parent, None);
        assert_eq!(spans[0].depth, 0);
        assert_eq!(spans[1].name, "inner");
        assert_eq!(spans[1].parent, Some(0));
        assert_eq!(spans[1].depth, 1);
        assert_eq!(spans[2].parent, Some(0));
        assert_eq!(spans[0].fields, vec![("k".to_string(), "42".to_string())]);
        for s in &spans {
            assert!(s.end_s >= s.start_s, "{}", s.name);
            if let Some(p) = s.parent {
                let p = &spans[p as usize];
                assert!(s.start_s >= p.start_s && s.end_s <= p.end_s);
            }
        }
        let st = span_stats();
        assert_eq!(st.total, 3);
        assert_eq!(st.max_depth, 2);
        assert_eq!(st.open, 0);
        assert_eq!(st.dropped, 0);
    }

    #[test]
    fn namespace_prefixes_span_names() {
        reset();
        {
            let _root = span("run");
            for r in 0..2 {
                let _ns = namespace(&format!("r{r}"));
                let _s = span("rank");
            }
        }
        let spans = snapshot_spans();
        let names: Vec<&str> = spans.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["run", "r0:rank", "r1:rank"]);
    }

    #[test]
    fn nested_namespaces_join() {
        reset();
        {
            let _a = namespace("outer");
            let _b = namespace("inner");
            let _s = span("leaf");
        }
        assert_eq!(snapshot_spans()[0].name, "outer:inner:leaf");
        // prefixes popped on drop
        let _t = span("plain");
        drop(_t);
        assert_eq!(snapshot_spans()[1].name, "plain");
    }

    #[test]
    fn reentering_the_innermost_namespace_is_idempotent() {
        reset();
        {
            let _a = namespace("r0");
            let _b = namespace("r0"); // same innermost prefix: no-op
            let _s = span("leaf");
        }
        assert_eq!(snapshot_spans()[0].name, "r0:leaf");
        // the no-op guard must not pop the prefix it didn't push
        {
            let _a = namespace("r0");
            {
                let _b = namespace("r0");
            } // dropping the inner guard leaves "r0" active
            let _s = span("still");
        }
        assert_eq!(snapshot_spans()[1].name, "r0:still");
        // distinct prefixes still stack, even when non-adjacent repeats
        {
            let _a = namespace("r0");
            let _b = namespace("mid");
            let _c = namespace("r0"); // not innermost-adjacent: stacks
            let _s = span("deep");
        }
        assert_eq!(snapshot_spans()[2].name, "r0:mid:r0:deep");
        let _t = span("plain");
        drop(_t);
        assert_eq!(snapshot_spans()[3].name, "plain");
    }

    #[test]
    fn snapshot_closes_open_spans_in_copy_only() {
        reset();
        let g = span("open");
        let snap = snapshot_spans();
        assert_eq!(snap.len(), 1);
        assert!(snap[0].end_s >= snap[0].start_s);
        assert_eq!(span_stats().open, 1);
        drop(g);
        assert_eq!(span_stats().open, 0);
    }

    #[test]
    fn reset_clears_everything() {
        reset();
        {
            let _s = span("gone");
        }
        assert_eq!(span_stats().total, 1);
        reset();
        assert_eq!(span_stats(), SpanStats::default());
        assert!(snapshot_spans().is_empty());
    }

    #[test]
    fn json_tree_shape() {
        reset();
        {
            let p = span("parent");
            p.field("chain", "flux \"x\"");
            let _c = span("child");
        }
        let json = spans_json(&snapshot_spans());
        assert!(json.starts_with("{\"spans\":["));
        assert!(json.contains("\"name\":\"parent\""));
        assert!(json.contains("\"children\":[{\"name\":\"child\""));
        assert!(json.contains("\"fields\":{\"chain\":\"flux \\\"x\\\"\"}"));
        assert!(json.ends_with("\"count\":2,\"max_depth\":2}"));
    }
}
