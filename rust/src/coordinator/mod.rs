//! The coordinator: configuration, the run driver, and reporting.
//!
//! This is the "leader" layer of the stack: it owns process lifecycle,
//! builds the [`crate::OpsContext`] for a configured platform, runs the
//! application's timestep driver, and renders the paper's metrics.

pub mod config;
pub mod report;

pub use config::{Config, InnerPlatform, Platform};
pub use report::{json_record, print_summary, Summary};

use crate::exec::Metrics;
use crate::ops::OpsContext;

/// Run an application closure under a configuration and return the final
/// metrics. `steps` is forwarded to the app driver.
///
/// The app closure receives a fresh context wired to the configured
/// engine and must: declare its data, run `steps` timesteps, and leave
/// results queriable. Metrics are reset after initialisation by the app
/// itself (via [`OpsContext::reset_metrics`]) so the timed region matches
/// the paper's.
pub fn run_app<F>(cfg: &Config, steps: usize, app: F) -> (Metrics, bool)
where
    F: FnOnce(&mut OpsContext, usize),
{
    let mut ctx = OpsContext::new(cfg.build_engine());
    app(&mut ctx, steps);
    ctx.flush();
    (ctx.metrics().clone(), ctx.oom())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::AppCalib;
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::shapes;
    use crate::ops::{Access, Arg};

    #[test]
    fn run_app_collects_metrics() {
        let cfg = Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_2D);
        let (m, oom) = run_app(&cfg, 3, |ctx, steps| {
            let b = ctx.decl_block("g", [16, 16, 1]);
            let d = ctx.decl_dat(b, "d", [16, 16, 1], [1, 1, 0], [1, 1, 0]);
            let s = ctx.decl_stencil("pt", shapes::point());
            for _ in 0..steps {
                ctx.par_loop(
                    "set",
                    b,
                    [(0, 16), (0, 16), (0, 1)],
                    kernel(|c| c.w(0, 0, 0, 1.0)),
                    vec![Arg::dat(d, s, Access::Write)],
                );
            }
        });
        assert!(!oom);
        assert_eq!(m.per_loop["set"].invocations, 3);
    }
}
