//! The coordinator: configuration, the run driver, and reporting.
//!
//! This is the "leader" layer of the stack: it owns process lifecycle,
//! builds a [`crate::program::Program`] + [`crate::program::Session`]
//! for a configured platform (or the deprecated [`crate::OpsContext`]
//! shim), runs the application's timestep driver, and renders the
//! paper's metrics.

pub mod config;
pub mod report;

pub use config::{Config, InnerPlatform, Platform, Target, TieredTarget};
pub use report::{json_record, print_summary, print_summary_with_topology, Summary};

use crate::exec::Metrics;
use crate::ops::surface::Drive;
#[allow(deprecated)]
use crate::ops::OpsContext;
use crate::program::{ProgramBuilder, Session};
use std::sync::Arc;

/// Run an application under a configuration through the Program/Session
/// API and return the final metrics.
///
/// `build` declares the application's data on a fresh
/// [`ProgramBuilder`] (returning its handles); the builder is then
/// frozen — surfacing declaration/stencil errors as typed
/// [`crate::errors`] errors — and `drive` runs `steps` timesteps on a
/// [`Session`] bound to the configured engine. Metrics are reset after
/// initialisation by the app itself (via
/// [`crate::ops::Drive::reset_metrics`]) so the timed region matches
/// the paper's.
pub fn run_program<T, B, F>(cfg: &Config, steps: usize, build: B, drive: F) -> crate::Result<(Metrics, bool)>
where
    B: FnOnce(&mut ProgramBuilder) -> T,
    F: FnOnce(&mut Session, T, usize),
{
    let mut b = ProgramBuilder::new();
    let handles = build(&mut b);
    let program = Arc::new(b.freeze()?);
    let mut session = Session::new(program, cfg);
    drive(&mut session, handles, steps);
    session.flush();
    Ok((session.metrics().clone(), session.oom()))
}

/// Run an application closure under a configuration and return the final
/// metrics. `steps` is forwarded to the app driver.
///
/// Deprecated alongside [`OpsContext`]: this drives the legacy eager
/// context, which re-analyses every chain at every flush. Use
/// [`run_program`].
#[deprecated(
    since = "0.3.0",
    note = "use run_program (ProgramBuilder/Session) — the eager OpsContext path \
            re-analyses every chain at every flush"
)]
#[allow(deprecated)]
pub fn run_app<F>(cfg: &Config, steps: usize, app: F) -> (Metrics, bool)
where
    F: FnOnce(&mut OpsContext, usize),
{
    let mut ctx = OpsContext::new(cfg.build_engine());
    app(&mut ctx, steps);
    ctx.flush();
    (ctx.metrics().clone(), ctx.oom())
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::memory::AppCalib;
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::shapes;
    use crate::ops::{Access, Arg, Declare, Drive as _, Record};

    #[test]
    fn run_program_collects_metrics_and_reuses_analysis() {
        let cfg = Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_2D);
        let (m, oom) = run_program(
            &cfg,
            3,
            |b| {
                let blk = b.decl_block("g", [16, 16, 1]);
                let d = b.decl_dat(blk, "d", [16, 16, 1], [1, 1, 0], [1, 1, 0]);
                let s = b.decl_stencil("pt", shapes::point());
                (blk, d, s)
            },
            |sess, (blk, d, s), steps| {
                for _ in 0..steps {
                    sess.par_loop(
                        "set",
                        blk,
                        [(0, 16), (0, 16), (0, 1)],
                        kernel(|c| c.w(0, 0, 0, 1.0)),
                        vec![Arg::dat(d, s, Access::Write)],
                    );
                    sess.flush();
                }
            },
        )
        .unwrap();
        assert!(!oom);
        assert_eq!(m.per_loop["set"].invocations, 3);
        assert_eq!(m.analysis_builds, 1, "one shape, analysed once");
        assert_eq!(m.analysis_reuse_hits, 2);
    }

    #[test]
    fn run_program_surfaces_freeze_errors() {
        let cfg = Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_2D);
        let r = run_program(
            &cfg,
            1,
            |b| {
                let blk = b.decl_block("g", [0, 16, 1]);
                let _ = blk;
            },
            |_sess, _h, _steps| {},
        );
        assert!(r.unwrap_err().to_string().contains("zero-sized"));
    }

    #[test]
    fn run_app_collects_metrics() {
        let cfg = Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_2D);
        let (m, oom) = run_app(&cfg, 3, |ctx, steps| {
            let b = ctx.decl_block("g", [16, 16, 1]);
            let d = ctx.decl_dat(b, "d", [16, 16, 1], [1, 1, 0], [1, 1, 0]);
            let s = ctx.decl_stencil("pt", shapes::point());
            for _ in 0..steps {
                ctx.par_loop(
                    "set",
                    b,
                    [(0, 16), (0, 16), (0, 1)],
                    kernel(|c| c.w(0, 0, 0, 1.0)),
                    vec![Arg::dat(d, s, Access::Write)],
                );
            }
        });
        assert!(!oom);
        assert_eq!(m.per_loop["set"].invocations, 3);
    }
}
