//! Human-readable reporting of the paper's metrics.

use crate::exec::timeline::StreamClass;
use crate::exec::Metrics;
use crate::topology::Topology;

/// A rendered summary of one run.
#[derive(Debug, Clone)]
pub struct Summary {
    pub label: String,
    pub problem_gb: f64,
    pub avg_bw_gbs: f64,
    pub eff_bw_gbs: f64,
    pub cache_hit_rate: f64,
    pub tiles: u64,
    pub h2d_gb: f64,
    pub d2h_gb: f64,
    pub elapsed_s: f64,
    pub oom: bool,
}

/// One machine-readable metrics record (the `--json` output of
/// `ops-oc run`/`sweep`; BENCH_*.json trajectories collect these).
/// Hand-rendered: the crate is dependency-free, and the record is flat.
///
/// `topology` is the run's declarative memory stack
/// ([`crate::coordinator::Config::topology`]) — reported as its
/// canonical spec string plus, on multi-tier stacks, one
/// `util_tier_<tier>_<upload|download>` utilisation field per per-tier
/// stream the engine actually ran.
pub fn json_record(
    app: &str,
    platform: &str,
    ranks: u32,
    size_gb: f64,
    topology: &Topology,
    m: &Metrics,
    oom: bool,
) -> String {
    let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
    let tuned = m.tune_evals + m.tune_cache_hits > 0;
    // Per-tier stream attribution: the tiered engine names its per-
    // boundary streams `{tier}:upload` / `{tier}:download`; under
    // sharding each rank's copy is re-namespaced `r{r}:{tier}:{dir}`,
    // so — like `Metrics::stream_util` — the field reports the busiest
    // instance across ranks.
    let tier_util = |tier: &str, dir: &str| -> Option<f64> {
        if m.elapsed_s <= 0.0 {
            return None;
        }
        let plain = format!("{tier}:{dir}");
        let ranked = format!(":{plain}");
        m.per_resource
            .iter()
            .filter(|(name, _)| name.as_str() == plain || name.ends_with(&ranked))
            .map(|(_, st)| (st.busy_s / m.elapsed_s).min(1.0))
            .reduce(f64::max)
    };
    let mut tier_utils = String::new();
    for tier in topology.tiers() {
        for dir in ["upload", "download", "codec"] {
            if let Some(u) = tier_util(&tier.name, dir) {
                tier_utils.push_str(&format!(
                    ",\"util_tier_{}_{dir}\":{u:.4}",
                    esc(&tier.name)
                ));
            }
        }
    }
    // Telemetry fields: histogram quantiles (`p50_*`/`p90_*`/`p99_*`)
    // from the obs registry, and per-stream roofline rows keyed under a
    // stable `roofline_*` prefix (see `crate::obs::roofline`).
    let san = |s: &str| -> String {
        s.chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect()
    };
    let mut obs_fields = String::new();
    for (name, h) in m.obs.histograms() {
        let key = san(name);
        for (label, q) in [("p50", 0.5), ("p90", 0.9), ("p99", 0.99)] {
            if let Some(v) = h.quantile(q) {
                obs_fields.push_str(&format!(",\"{label}_{key}\":{v:.9}"));
            }
        }
    }
    for row in &crate::obs::roofline::build(topology, m).rows {
        let key = san(&row.name);
        obs_fields.push_str(&format!(
            ",\"roofline_{key}_peak_gbs\":{:.3},\"roofline_{key}_achieved_gbs\":{:.3},\"roofline_{key}_frac\":{:.4}",
            row.peak_gbs,
            row.achieved_gbs,
            row.frac_of_peak(),
        ));
    }
    format!(
        concat!(
            "{{\"app\":\"{}\",\"platform\":\"{}\",\"topology\":\"{}\",",
            "\"ranks\":{},\"size_gb\":{:.3},",
            "\"oom\":{},\"runtime_s\":{:.6},\"avg_bandwidth_gbs\":{:.3},",
            "\"eff_bandwidth_gbs\":{:.3},\"halo_time_s\":{:.6},\"tiles\":{},",
            "\"bound\":\"{}\",\"util_compute\":{:.4},\"util_upload\":{:.4},",
            "\"util_download\":{:.4},\"util_exchange\":{:.4},",
            "\"util_codec\":{:.4},\"codec_bytes_saved\":{}{},",
            "\"tuned\":{},\"tune_evals\":{},\"tune_cache_hits\":{},",
            "\"tuned_model_s\":{:.6},\"heuristic_model_s\":{:.6},",
            "\"tune_model_speedup\":{:.4},",
            "\"analysis_builds\":{},\"analysis_reuse_hits\":{},",
            "\"fused_steps\":{},",
            "\"exec_backend\":\"{}\",\"kir_kernels_compiled\":{},",
            "\"kir_fallback_loops\":{},",
            "\"program_freeze_s\":{:.6},",
            "\"spans_recorded\":{},\"span_max_depth\":{}{}}}"
        ),
        esc(app),
        esc(platform),
        esc(&topology.spec()),
        ranks,
        size_gb,
        oom,
        m.elapsed_s,
        m.average_bandwidth_gbs(),
        m.effective_bandwidth_gbs(),
        m.halo_time_s,
        m.tiles,
        m.bound().name(),
        m.stream_util(StreamClass::Compute),
        m.stream_util(StreamClass::Upload),
        m.stream_util(StreamClass::Download),
        m.stream_util(StreamClass::Exchange),
        m.stream_util(StreamClass::Codec),
        m.codec_bytes_saved,
        tier_utils,
        tuned,
        m.tune_evals,
        m.tune_cache_hits,
        m.tuned_model_s,
        m.heuristic_model_s,
        m.tune_model_speedup(),
        m.analysis_builds,
        m.analysis_reuse_hits,
        m.fused_steps,
        esc(&m.exec_backend),
        m.kir_kernels_compiled,
        m.kir_fallback_loops,
        m.program_freeze_s,
        m.spans_recorded,
        m.span_max_depth,
        obs_fields,
    )
}

impl Summary {
    pub fn from_metrics(label: &str, problem_bytes: u64, m: &Metrics, oom: bool) -> Self {
        Summary {
            label: label.to_string(),
            problem_gb: problem_bytes as f64 / 1e9,
            avg_bw_gbs: m.average_bandwidth_gbs(),
            eff_bw_gbs: m.effective_bandwidth_gbs(),
            cache_hit_rate: m.cache_hit_rate(),
            tiles: m.tiles,
            h2d_gb: m.h2d_bytes as f64 / 1e9,
            d2h_gb: m.d2h_bytes as f64 / 1e9,
            elapsed_s: m.elapsed_s,
            oom,
        }
    }

    /// One row of the figures' tables.
    pub fn row(&self) -> String {
        if self.oom {
            format!(
                "{:<38} {:>8.1}  {:>10}  {:>10}",
                self.label, self.problem_gb, "OOM", "-"
            )
        } else {
            format!(
                "{:<38} {:>8.1}  {:>10.1}  {:>10.1}",
                self.label, self.problem_gb, self.avg_bw_gbs, self.eff_bw_gbs
            )
        }
    }
}

/// Print a full run summary, including the per-kernel hot list.
pub fn print_summary(label: &str, problem_bytes: u64, m: &Metrics, oom: bool) {
    let s = Summary::from_metrics(label, problem_bytes, m, oom);
    println!("== {label} ==");
    println!("  problem size        : {:.2} GB (modelled)", s.problem_gb);
    if oom {
        println!("  RESULT              : OOM (does not fit modelled memory)");
        return;
    }
    println!("  average bandwidth   : {:.1} GB/s (paper §5.1 metric)", s.avg_bw_gbs);
    println!("  effective bandwidth : {:.1} GB/s (incl. transfers/halos)", s.eff_bw_gbs);
    println!("  modelled time       : {:.4} s", s.elapsed_s);
    if m.cache_hits + m.cache_misses > 0 {
        println!("  MCDRAM hit rate     : {:.1} %", s.cache_hit_rate * 100.0);
    }
    if m.tiles > 0 {
        println!("  tiles executed      : {}", m.tiles);
    }
    if m.h2d_bytes + m.d2h_bytes > 0 {
        println!(
            "  transfers           : {:.2} GB H2D, {:.2} GB D2H, {:.2} GB D2D",
            s.h2d_gb,
            s.d2h_gb,
            m.d2d_bytes as f64 / 1e9
        );
    }
    if m.codec_bytes_saved > 0 {
        println!(
            "  link codecs         : {:.2} GB saved on the wire",
            m.codec_bytes_saved as f64 / 1e9
        );
    }
    if m.page_faults > 0 {
        println!("  page faults         : {}", m.page_faults);
    }
    if m.tune_evals + m.tune_cache_hits > 0 {
        println!(
            "  auto-tuner          : {:.2}x modelled speedup vs heuristic ({} evals, {} cache hits)",
            m.tune_model_speedup(),
            m.tune_evals,
            m.tune_cache_hits
        );
    }
    if m.halo_exchanges > 0 {
        println!(
            "  halo exchanges      : {} ({:.4} s)",
            m.halo_exchanges, m.halo_time_s
        );
    }
    if !m.per_resource.is_empty() {
        println!("  bound by            : {} stream", m.bound().name());
        if let Some((name, u)) = m.bound_resource() {
            println!("  busiest stream      : {} ({:.0}%)", name, u * 100.0);
        }
        print!("  stream utilisation  :");
        for class in StreamClass::ALL {
            let u = m.stream_util(class);
            if u > 0.0 {
                print!(" {} {:.0}%", class.name(), u * 100.0);
            }
        }
        println!();
        // Namespaced transfer streams — a multi-tier stack's per-tier
        // pairs and/or a sharded run's per-rank copies — by name.
        let detailed: Vec<_> = m
            .per_resource
            .iter()
            .filter(|(k, st)| {
                k.contains(':')
                    && matches!(
                        st.class,
                        StreamClass::Upload | StreamClass::Download | StreamClass::Codec
                    )
            })
            .collect();
        if !detailed.is_empty() && m.elapsed_s > 0.0 {
            print!("  stream detail       :");
            for (k, st) in detailed {
                print!(
                    " {} {:.0}% ({:.2} GB)",
                    k,
                    (st.busy_s / m.elapsed_s).min(1.0) * 100.0,
                    st.bytes as f64 / 1e9
                );
            }
            println!();
        }
    }
    if m.analysis_builds + m.analysis_reuse_hits > 0 {
        println!(
            "  chain analysis      : {} built, {} reused (freeze {:.6} s)",
            m.analysis_builds, m.analysis_reuse_hits, m.program_freeze_s
        );
    }
    if !m.per_rank.is_empty() {
        println!("  per-rank (sharded):");
        for (r, rs) in m.per_rank.iter().enumerate() {
            println!(
                "    rank {:<3} compute {:>9.4} s  exchange {:>9.4} s ({:>7.3} GB)  avg bw {:>7.1} GB/s",
                r,
                rs.compute_s,
                rs.exchange_s,
                rs.exchange_bytes as f64 / 1e9,
                rs.average_bandwidth_gbs(),
            );
        }
        let agg_bytes: u64 = m.per_rank.iter().map(|r| r.loop_bytes).sum();
        let agg_time: f64 = m.per_rank.iter().map(|r| r.loop_time_s).sum();
        if agg_time > 0.0 {
            println!(
                "    aggregate           : {:.1} GB/s weighted Average Bandwidth over {} ranks",
                agg_bytes as f64 / agg_time / 1e9,
                m.per_rank.len()
            );
        }
    }
    let hot = m.hottest(5);
    if !hot.is_empty() {
        println!("  hottest kernels:");
        for (name, st) in hot {
            println!(
                "    {:<28} {:>8} calls  {:>8.1} GB/s  {:>6.1} % time",
                name,
                st.invocations,
                st.bandwidth_gbs(),
                100.0 * st.time_s / m.loop_time_s.max(1e-30)
            );
        }
    }
    if let Some(qs) = m.histogram_quantiles("loop_time_s", &[0.5, 0.99]) {
        println!(
            "  loop time quantiles : p50 {:.6} s, p99 {:.6} s ({} samples)",
            qs[0],
            qs[1],
            m.obs.histogram("loop_time_s").map_or(0, |h| h.count())
        );
    }
    if m.spans_recorded > 0 {
        println!(
            "  lifecycle spans     : {} recorded, max depth {}",
            m.spans_recorded, m.span_max_depth
        );
    }
}

/// [`print_summary`] plus a per-stream roofline table: modelled achieved
/// GB/s on every stream against the topology's peak for that stream's
/// tier or link, and the §5.1 per-kernel bytes ledger.
pub fn print_summary_with_topology(
    label: &str,
    problem_bytes: u64,
    topology: &Topology,
    m: &Metrics,
    oom: bool,
) {
    print_summary(label, problem_bytes, m, oom);
    if oom {
        return;
    }
    let roof = crate::obs::roofline::build(topology, m);
    if !roof.rows.is_empty() {
        println!("  roofline (modelled achieved vs topology peak):");
        for row in &roof.rows {
            println!(
                "    {:<18} {:>8.1} / {:<8.1} GB/s  {:>5.1} % of peak  (busy {:>5.1} %)",
                row.name,
                row.achieved_gbs,
                row.peak_gbs,
                row.frac_of_peak() * 100.0,
                row.busy_frac * 100.0,
            );
        }
    }
    if !roof.kernels.is_empty() {
        println!("  kernel bytes ledger (§5.1):");
        for k in roof.kernels.iter().take(5) {
            println!(
                "    {:<28} {:>9.3} GB  {:>8.1} GB/s  x{}",
                k.name,
                k.bytes as f64 / 1e9,
                k.achieved_gbs,
                k.invocations,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_formats_oom() {
        let m = Metrics::new();
        let s = Summary::from_metrics("x", 1 << 30, &m, true);
        assert!(s.row().contains("OOM"));
    }

    fn topo() -> Topology {
        crate::topology::preset("gpu-explicit-pcie").unwrap()
    }

    #[test]
    fn json_record_is_flat_and_escaped() {
        let mut m = Metrics::new();
        m.record_loop("k", 2_000_000_000, 0.01);
        m.elapsed_s = 0.04;
        let j = json_record("cloverleaf\"2d", "GPU explicit", 4, 48.0, &topo(), &m, false);
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"ranks\":4"));
        assert!(j.contains("\"size_gb\":48.000"));
        assert!(j.contains("\\\"2d"));
        assert!(j.contains("\"topology\":\"tiers:gpu-explicit-pcie\""));
        assert!(j.contains("\"avg_bandwidth_gbs\":200.000"));
        assert!(j.contains("\"oom\":false"));
        assert!(j.contains("\"tuned\":false"));
        assert!(j.contains("\"tune_model_speedup\":1.0000"));
        assert!(j.contains("\"bound\":\"idle\""));
        assert!(j.contains("\"fused_steps\":0"));
        assert!(j.contains("\"exec_backend\":\"\""));
        assert!(j.contains("\"kir_kernels_compiled\":0"));
        assert!(j.contains("\"kir_fallback_loops\":0"));
        assert!(j.contains("\"spans_recorded\":0"));
        assert!(j.contains("\"p50_loop_time_s\":"));
        assert!(j.contains("\"util_compute\":0.0000"));
        assert!(j.contains("\"util_codec\":0.0000"));
        assert!(j.contains("\"codec_bytes_saved\":0"));
        assert!(!j.contains("util_tier_"), "no per-tier streams ran: {j}");
    }

    #[test]
    fn json_record_reports_codec_streams() {
        use crate::exec::timeline::StreamClass;
        let t = crate::topology::spec::parse_stack(
            "hbm=16g@509.7+host=inf@11~c:3.5",
        )
        .unwrap();
        let mut m = Metrics::new();
        m.record_loop("k", 1_000_000_000, 0.01);
        m.elapsed_s = 0.02;
        m.record_stream("host:codec", StreamClass::Codec, 0.012, 1 << 20, 4);
        m.codec_bytes_saved = 123;
        let j = json_record("a", "p", 1, 6.0, &t, &m, false);
        assert!(j.contains("\"util_codec\":0.6000"), "{j}");
        assert!(j.contains("\"codec_bytes_saved\":123"), "{j}");
        assert!(j.contains("\"util_tier_host_codec\":0.6000"), "{j}");
        assert!(j.contains("~c:3.5"), "spec renders the annotation: {j}");
    }

    #[test]
    fn json_record_reports_bottleneck_attribution() {
        use crate::exec::timeline::StreamClass;
        let mut m = Metrics::new();
        m.record_loop("k", 1_000_000_000, 0.01);
        m.elapsed_s = 0.02;
        m.record_stream("compute", StreamClass::Compute, 0.005, 0, 3);
        m.record_stream("upload", StreamClass::Upload, 0.018, 1 << 20, 3);
        let j = json_record("a", "p", 1, 6.0, &topo(), &m, false);
        assert!(j.contains("\"bound\":\"upload\""), "{j}");
        assert!(j.contains("\"util_upload\":0.9000"), "{j}");
        assert!(j.contains("\"util_compute\":0.2500"), "{j}");
        assert!(j.contains("\"util_download\":0.0000"), "{j}");
    }

    #[test]
    fn json_record_reports_per_tier_utilisation() {
        use crate::exec::timeline::StreamClass;
        let t = crate::topology::spec::parse_stack(
            "hbm=16g@509.7+host=48g@11~0.00001+nvme=inf@6~0.00002",
        )
        .unwrap();
        let mut m = Metrics::new();
        m.record_loop("k", 1_000_000_000, 0.01);
        m.elapsed_s = 0.02;
        m.record_stream("hbm:upload", StreamClass::Upload, 0.01, 1 << 20, 4);
        m.record_stream("hbm:download", StreamClass::Download, 0.002, 1 << 18, 4);
        m.record_stream("host:upload", StreamClass::Upload, 0.016, 1 << 21, 2);
        let j = json_record("a", "p", 1, 6.0, &t, &m, false);
        assert!(j.contains("\"topology\":\"tiers:hbm=16g@509.7"), "{j}");
        assert!(j.contains("\"util_tier_hbm_upload\":0.5000"), "{j}");
        assert!(j.contains("\"util_tier_hbm_download\":0.1000"), "{j}");
        assert!(j.contains("\"util_tier_host_upload\":0.8000"), "{j}");
        assert!(!j.contains("util_tier_host_download"), "stream never ran: {j}");
        assert!(!j.contains("util_tier_nvme"), "home tier has no streams: {j}");
    }

    #[test]
    fn per_tier_utilisation_sees_rank_namespaced_streams() {
        use crate::exec::timeline::StreamClass;
        let t = crate::topology::spec::parse_stack(
            "hbm=16g@509.7+host=48g@11~0.00001+nvme=inf@6~0.00002",
        )
        .unwrap();
        let mut m = Metrics::new();
        m.elapsed_s = 0.02;
        // a sharded tiered run re-namespaces each rank's tier streams
        m.record_stream("r0:hbm:upload", StreamClass::Upload, 0.01, 1 << 20, 4);
        m.record_stream("r1:hbm:upload", StreamClass::Upload, 0.016, 1 << 20, 4);
        m.record_stream("r0:host:download", StreamClass::Download, 0.004, 1 << 18, 2);
        let j = json_record("a", "p", 2, 6.0, &t, &m, false);
        // busiest instance across ranks, like stream_util
        assert!(j.contains("\"util_tier_hbm_upload\":0.8000"), "{j}");
        assert!(j.contains("\"util_tier_host_download\":0.2000"), "{j}");
        assert!(!j.contains("util_tier_host_upload"), "{j}");
    }

    #[test]
    fn json_record_reports_tuner_fields() {
        let mut m = Metrics::new();
        m.record_loop("k", 1_000_000_000, 0.01);
        m.elapsed_s = 0.02;
        m.tune_evals = 32;
        m.tune_cache_hits = 3;
        m.tuned_model_s = 0.018;
        m.heuristic_model_s = 0.027;
        let j = json_record("a", "p", 1, 6.0, &topo(), &m, false);
        assert!(j.contains("\"tuned\":true"));
        assert!(j.contains("\"tune_evals\":32"));
        assert!(j.contains("\"tune_cache_hits\":3"));
        assert!(j.contains("\"tuned_model_s\":0.018000"));
        assert!(j.contains("\"heuristic_model_s\":0.027000"));
        assert!(j.contains("\"tune_model_speedup\":1.5000"));
    }

    #[test]
    fn summary_captures_metrics() {
        let mut m = Metrics::new();
        m.record_loop("k", 1_000_000_000, 0.01);
        m.elapsed_s = 0.02;
        let s = Summary::from_metrics("x", 2_000_000_000, &m, false);
        assert!((s.avg_bw_gbs - 100.0).abs() < 1e-9);
        assert!((s.eff_bw_gbs - 50.0).abs() < 1e-9);
        assert!((s.problem_gb - 2.0).abs() < 1e-12);
    }
}
