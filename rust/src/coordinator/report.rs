//! Human-readable reporting of the paper's metrics.

use crate::exec::Metrics;

/// A rendered summary of one run.
#[derive(Debug, Clone)]
pub struct Summary {
    pub label: String,
    pub problem_gb: f64,
    pub avg_bw_gbs: f64,
    pub eff_bw_gbs: f64,
    pub cache_hit_rate: f64,
    pub tiles: u64,
    pub h2d_gb: f64,
    pub d2h_gb: f64,
    pub elapsed_s: f64,
    pub oom: bool,
}

impl Summary {
    pub fn from_metrics(label: &str, problem_bytes: u64, m: &Metrics, oom: bool) -> Self {
        Summary {
            label: label.to_string(),
            problem_gb: problem_bytes as f64 / 1e9,
            avg_bw_gbs: m.average_bandwidth_gbs(),
            eff_bw_gbs: m.effective_bandwidth_gbs(),
            cache_hit_rate: m.cache_hit_rate(),
            tiles: m.tiles,
            h2d_gb: m.h2d_bytes as f64 / 1e9,
            d2h_gb: m.d2h_bytes as f64 / 1e9,
            elapsed_s: m.elapsed_s,
            oom,
        }
    }

    /// One row of the figures' tables.
    pub fn row(&self) -> String {
        if self.oom {
            format!(
                "{:<38} {:>8.1}  {:>10}  {:>10}",
                self.label, self.problem_gb, "OOM", "-"
            )
        } else {
            format!(
                "{:<38} {:>8.1}  {:>10.1}  {:>10.1}",
                self.label, self.problem_gb, self.avg_bw_gbs, self.eff_bw_gbs
            )
        }
    }
}

/// Print a full run summary, including the per-kernel hot list.
pub fn print_summary(label: &str, problem_bytes: u64, m: &Metrics, oom: bool) {
    let s = Summary::from_metrics(label, problem_bytes, m, oom);
    println!("== {label} ==");
    println!("  problem size        : {:.2} GB (modelled)", s.problem_gb);
    if oom {
        println!("  RESULT              : OOM (does not fit modelled memory)");
        return;
    }
    println!("  average bandwidth   : {:.1} GB/s (paper §5.1 metric)", s.avg_bw_gbs);
    println!("  effective bandwidth : {:.1} GB/s (incl. transfers/halos)", s.eff_bw_gbs);
    println!("  modelled time       : {:.4} s", s.elapsed_s);
    if m.cache_hits + m.cache_misses > 0 {
        println!("  MCDRAM hit rate     : {:.1} %", s.cache_hit_rate * 100.0);
    }
    if m.tiles > 0 {
        println!("  tiles executed      : {}", m.tiles);
    }
    if m.h2d_bytes + m.d2h_bytes > 0 {
        println!(
            "  transfers           : {:.2} GB H2D, {:.2} GB D2H, {:.2} GB D2D",
            s.h2d_gb,
            s.d2h_gb,
            m.d2d_bytes as f64 / 1e9
        );
    }
    if m.page_faults > 0 {
        println!("  page faults         : {}", m.page_faults);
    }
    if m.halo_exchanges > 0 {
        println!(
            "  halo exchanges      : {} ({:.4} s)",
            m.halo_exchanges, m.halo_time_s
        );
    }
    let hot = m.hottest(5);
    if !hot.is_empty() {
        println!("  hottest kernels:");
        for (name, st) in hot {
            println!(
                "    {:<28} {:>8} calls  {:>8.1} GB/s  {:>6.1} % time",
                name,
                st.invocations,
                st.bandwidth_gbs(),
                100.0 * st.time_s / m.loop_time_s.max(1e-30)
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_formats_oom() {
        let m = Metrics::new();
        let s = Summary::from_metrics("x", 1 << 30, &m, true);
        assert!(s.row().contains("OOM"));
    }

    #[test]
    fn summary_captures_metrics() {
        let mut m = Metrics::new();
        m.record_loop("k", 1_000_000_000, 0.01);
        m.elapsed_s = 0.02;
        let s = Summary::from_metrics("x", 2_000_000_000, &m, false);
        assert!((s.avg_bw_gbs - 100.0).abs() < 1e-9);
        assert!((s.eff_bw_gbs - 50.0).abs() < 1e-9);
        assert!((s.problem_gb - 2.0).abs() < 1e-12);
    }
}
