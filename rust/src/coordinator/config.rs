//! Configuration: which modelled platform to run on, with which
//! calibrations. Loadable from compact spec strings for the CLI
//! launcher, constructible in code for benches and tests.
//!
//! ## Platform spec grammar
//!
//! ```text
//! spec        := head (":" token)*
//! head        := knl-flat-ddr4 | knl-flat-mcdram | knl-cache |
//!                knl-cache-tiled | gpu-baseline | gpu-explicit |
//!                gpu-unified
//!              | tiers:<stack>            (declarative tier topology)
//! stack       := <preset-name> | name=cap@bw[~lat] ("+" …)+
//!                                         (see crate::topology::spec)
//! token       := pcie | nvlink            (host link, GPU heads)
//!              | cyclic | prefetch        (gpu-explicit, tiers)
//!              | tiled | prefetch         (gpu-unified)
//!              | x<N>                     (shard across N ranks)
//! shard token := peer | nvlink | ib       (interconnect, after x<N>)
//!              | 1d | 2d                  (decomposition, after x<N>)
//!              | no-overlap               (ablation, after x<N>)
//! ```
//!
//! Tokens before `x<N>` configure the inner (per-rank) platform, tokens
//! after it the sharding layer. Unknown tokens are **rejected** — e.g.
//! `gpu-explicit:nvlnk` is an error, not silently PCIe.
//!
//! The closed [`Platform`] enum survives as a thin compatibility layer:
//! each variant maps to a preset [`Topology`]
//! ([`Platform::topology`]), while the open half of the space — custom
//! tier stacks on the generic [`TieredEngine`] — parses from the
//! `tiers:` head into a [`Target::Tiered`] and rides the same
//! [`Config`].

use crate::codec::CodecSpec;
use crate::distributed::{DecompKind, Interconnect, ShardedEngine};
use crate::exec::{Engine, ExecBackend};
use crate::memory::{
    AppCalib, GpuCalib, GpuExplicitEngine, GpuOpts, KnlCalib, KnlEngine, Link, PlainEngine,
    TieredEngine, UnifiedCalib, UnifiedEngine,
};
use crate::topology::{self, LinkSpec, Topology};
use crate::tuner::{TuneOpts, TunedEngine, TunerTarget};

/// Per-rank platforms a sharded configuration can host (each rank owns a
/// full out-of-core memory engine).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InnerPlatform {
    /// KNL cache mode with skewed tiling sized to MCDRAM.
    KnlCacheTiled,
    /// P100 with explicit 3-slot streaming (Algorithm 1).
    GpuExplicit {
        link: Link,
        cyclic: bool,
        prefetch: bool,
    },
    /// P100 with unified memory.
    GpuUnified {
        link: Link,
        tiled: bool,
        prefetch: bool,
    },
}

impl InnerPlatform {
    /// The equivalent single-device platform.
    pub fn to_platform(self) -> Platform {
        match self {
            InnerPlatform::KnlCacheTiled => Platform::KnlCacheTiled,
            InnerPlatform::GpuExplicit {
                link,
                cyclic,
                prefetch,
            } => Platform::GpuExplicit {
                link,
                cyclic,
                prefetch,
            },
            InnerPlatform::GpuUnified {
                link,
                tiled,
                prefetch,
            } => Platform::GpuUnified {
                link,
                tiled,
                prefetch,
            },
        }
    }

    /// The shardable view of a single-device platform (`None` for
    /// platforms that only exist unsharded, e.g. flat MCDRAM).
    pub fn try_from_platform(p: Platform) -> Option<Self> {
        match p {
            Platform::KnlCacheTiled => Some(InnerPlatform::KnlCacheTiled),
            Platform::GpuExplicit {
                link,
                cyclic,
                prefetch,
            } => Some(InnerPlatform::GpuExplicit {
                link,
                cyclic,
                prefetch,
            }),
            Platform::GpuUnified {
                link,
                tiled,
                prefetch,
            } => Some(InnerPlatform::GpuUnified {
                link,
                tiled,
                prefetch,
            }),
            _ => None,
        }
    }

    /// Host link of the inner platform, if it has one (used to pick a
    /// default inter-rank interconnect).
    fn host_link(self) -> Option<Link> {
        match self {
            InnerPlatform::KnlCacheTiled => None,
            InnerPlatform::GpuExplicit { link, .. } | InnerPlatform::GpuUnified { link, .. } => {
                Some(link)
            }
        }
    }
}

/// The execution environments of the paper's evaluation, plus the
/// sharded multi-device extension.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Platform {
    /// KNL flat mode, DDR4 only (numactl to DDR4).
    KnlFlatDdr4,
    /// KNL flat mode, MCDRAM only — refuses problems > 16 GB.
    KnlFlatMcdram,
    /// KNL cache mode, untiled.
    KnlCache,
    /// KNL cache mode with skewed tiling sized to MCDRAM.
    KnlCacheTiled,
    /// P100 with all data resident — refuses problems > 16 GB.
    GpuBaseline { link: Link },
    /// P100 with explicit 3-slot streaming (Algorithm 1).
    GpuExplicit {
        link: Link,
        cyclic: bool,
        prefetch: bool,
    },
    /// P100 with unified memory.
    GpuUnified {
        link: Link,
        tiled: bool,
        prefetch: bool,
    },
    /// N modelled ranks, each running `inner`, exchanging halos over
    /// `link` under a 1D/2D decomposition.
    Sharded {
        ranks: u32,
        inner: InnerPlatform,
        link: Interconnect,
        decomp: DecompKind,
        /// Overlap halo exchange with interior compute (`false` is the
        /// fig12 ablation).
        overlap: bool,
    },
}

impl Platform {
    pub fn label(&self) -> String {
        match self {
            Platform::KnlFlatDdr4 => "KNL flat DDR4".into(),
            Platform::KnlFlatMcdram => "KNL flat MCDRAM".into(),
            Platform::KnlCache => "KNL cache".into(),
            Platform::KnlCacheTiled => "KNL cache tiled".into(),
            Platform::GpuBaseline { link } => format!("GPU baseline {}", link.name()),
            Platform::GpuExplicit {
                link,
                cyclic,
                prefetch,
            } => format!(
                "GPU explicit {} {}{}",
                link.name(),
                if *cyclic { "Cyclic" } else { "NoCyclic" },
                if *prefetch { " Prefetch" } else { " NoPrefetch" }
            ),
            Platform::GpuUnified {
                link,
                tiled,
                prefetch,
            } => format!(
                "GPU unified {}{}{}",
                link.name(),
                if *tiled { " tiled" } else { "" },
                if *prefetch { " prefetch" } else { "" }
            ),
            Platform::Sharded {
                ranks,
                inner,
                link,
                decomp,
                overlap,
            } => format!(
                "{} x{} ({}, {}{})",
                inner.to_platform().label(),
                ranks,
                decomp.label(),
                link.name(),
                if *overlap { "" } else { ", no-overlap" }
            ),
        }
    }

    /// The canonical spec string of this platform: parseable by
    /// [`Config::parse_platform`], round-tripping to `self` for every
    /// constructible platform (sharded forms need `ranks >= 2`; `x1`
    /// collapses to the single-device platform by design). Property-
    /// tested in `tests/program_equivalence.rs`.
    pub fn spec(&self) -> String {
        fn link_tok(l: Link) -> &'static str {
            match l {
                Link::PciE => "pcie",
                Link::NvLink => "nvlink",
            }
        }
        match self {
            Platform::KnlFlatDdr4 => "knl-flat-ddr4".into(),
            Platform::KnlFlatMcdram => "knl-flat-mcdram".into(),
            Platform::KnlCache => "knl-cache".into(),
            Platform::KnlCacheTiled => "knl-cache-tiled".into(),
            Platform::GpuBaseline { link } => format!("gpu-baseline:{}", link_tok(*link)),
            Platform::GpuExplicit {
                link,
                cyclic,
                prefetch,
            } => format!(
                "gpu-explicit:{}{}{}",
                link_tok(*link),
                if *cyclic { ":cyclic" } else { "" },
                if *prefetch { ":prefetch" } else { "" }
            ),
            Platform::GpuUnified {
                link,
                tiled,
                prefetch,
            } => format!(
                "gpu-unified:{}{}{}",
                link_tok(*link),
                if *tiled { ":tiled" } else { "" },
                if *prefetch { ":prefetch" } else { "" }
            ),
            Platform::Sharded {
                ranks,
                inner,
                link,
                decomp,
                overlap,
            } => format!(
                "{}:x{}:{}:{}{}",
                inner.to_platform().spec(),
                ranks,
                match link {
                    Interconnect::PciePeer => "peer",
                    Interconnect::NvLink => "nvlink",
                    Interconnect::InfiniBand => "ib",
                },
                match decomp {
                    DecompKind::OneD => "1d",
                    DecompKind::TwoD => "2d",
                },
                if *overlap { "" } else { ":no-overlap" }
            ),
        }
    }

    /// Number of modelled ranks (1 for single-device platforms).
    pub fn ranks(&self) -> u32 {
        match self {
            Platform::Sharded { ranks, .. } => *ranks,
            _ => 1,
        }
    }

    /// The declarative [`Topology`] this legacy variant stands for —
    /// the compatibility mapping from the closed enum into the open
    /// tier-stack space, built from the supplied calibrations so custom
    /// `KnlCalib`/`GpuCalib` numbers flow through. Sharded platforms
    /// map to their per-rank inner topology.
    pub fn topology(&self, knl: &KnlCalib, gpu: &GpuCalib) -> Topology {
        use crate::topology::presets;
        match self {
            Platform::KnlFlatDdr4 => presets::flat("ddr4", None, knl.bw_ddr4),
            Platform::KnlFlatMcdram => {
                presets::flat("mcdram", Some(knl.mcdram_bytes), knl.bw_mcdram_flat)
            }
            Platform::KnlCache | Platform::KnlCacheTiled => presets::knl_cache(knl),
            Platform::GpuBaseline { .. } => {
                presets::flat("hbm", Some(gpu.hbm_bytes), gpu.bw_device)
            }
            Platform::GpuExplicit { link, .. } => presets::gpu_explicit(gpu, *link),
            Platform::GpuUnified { link, .. } => presets::gpu_unified(gpu, *link),
            Platform::Sharded { inner, .. } => inner.to_platform().topology(knl, gpu),
        }
    }

    /// Shard `self` across `ranks` ranks with default sharding settings
    /// (1D decomposition, overlap on, interconnect matched to the inner
    /// host link). Errors when the platform cannot be sharded.
    pub fn sharded(self, ranks: u32) -> crate::Result<Platform> {
        crate::ensure!(ranks <= 64, "rank count {ranks} out of range (1..=64)");
        if ranks <= 1 {
            return Ok(self);
        }
        if let Platform::Sharded { ranks: _, inner, link, decomp, overlap } = self {
            return Ok(Platform::Sharded { ranks, inner, link, decomp, overlap });
        }
        let inner = InnerPlatform::try_from_platform(self).ok_or_else(|| {
            crate::err!(
                "platform {:?} cannot be sharded (use knl-cache-tiled, gpu-explicit or gpu-unified)",
                self.label()
            )
        })?;
        let link = match inner.host_link() {
            Some(Link::NvLink) => Interconnect::NvLink,
            _ => Interconnect::PciePeer,
        };
        Ok(Platform::Sharded {
            ranks,
            inner,
            link,
            decomp: DecompKind::OneD,
            overlap: true,
        })
    }
}

/// A declarative execution target: a custom tier stack on the generic
/// [`TieredEngine`], optionally sharded across modelled ranks (each
/// rank owning its own copy of the inner topology).
#[derive(Debug, Clone, PartialEq)]
pub struct TieredTarget {
    /// The memory stack every (rank-local) engine schedules against.
    pub topology: Topology,
    /// §4.1 optimisation switches (`cyclic`/`prefetch` spec tokens;
    /// slots fixed at the paper's triple buffering).
    pub opts: GpuOpts,
    /// Modelled ranks; 1 = unsharded.
    pub ranks: u32,
    /// Inter-rank interconnect (when `ranks > 1`).
    pub link: Interconnect,
    pub decomp: DecompKind,
    /// Overlap halo exchange with interior compute.
    pub overlap: bool,
}

/// Whether a stack's innermost link is the calibrated NVLink host link
/// — the data-driven predicate behind both the default inter-rank
/// interconnect and the §5.3 clock boost.
fn nvlink_host_stack(topology: &Topology) -> bool {
    topology.num_tiers() >= 2 && topology.link(0) == LinkSpec::NVLINK_HOST
}

impl TieredTarget {
    /// An unsharded target with the §4.1 toggles off — the state the
    /// bare `tiers:<stack>` spec parses to. The default inter-rank
    /// interconnect mirrors [`Platform::sharded`]'s inference: an
    /// NVLink-host stack gets NVLink peer links, everything else PCIe
    /// peer (override with a `peer|nvlink|ib` shard token).
    pub fn new(topology: Topology) -> Self {
        let link = if nvlink_host_stack(&topology) {
            Interconnect::NvLink
        } else {
            Interconnect::PciePeer
        };
        TieredTarget {
            topology,
            opts: GpuOpts {
                cyclic: false,
                prefetch: false,
                slots: 3,
            },
            ranks: 1,
            link,
            decomp: DecompKind::OneD,
            overlap: true,
        }
    }

    pub fn label(&self) -> String {
        let mut s = format!("Tiered {}", self.topology.label());
        if self.opts.cyclic {
            s.push_str(" Cyclic");
        }
        if self.opts.prefetch {
            s.push_str(" Prefetch");
        }
        if self.ranks > 1 {
            s.push_str(&format!(
                " x{} ({}, {}{})",
                self.ranks,
                self.decomp.label(),
                self.link.name(),
                if self.overlap { "" } else { ", no-overlap" }
            ));
        }
        s
    }

    /// Canonical spec string, round-tripping through
    /// [`Config::parse_spec`].
    pub fn spec(&self) -> String {
        let mut s = self.topology.spec();
        if self.opts.cyclic {
            s.push_str(":cyclic");
        }
        if self.opts.prefetch {
            s.push_str(":prefetch");
        }
        if self.ranks > 1 {
            s.push_str(&format!(":x{}", self.ranks));
            s.push_str(match self.link {
                Interconnect::PciePeer => ":peer",
                Interconnect::NvLink => ":nvlink",
                Interconnect::InfiniBand => ":ib",
            });
            s.push_str(match self.decomp {
                DecompKind::OneD => ":1d",
                DecompKind::TwoD => ":2d",
            });
            if !self.overlap {
                s.push_str(":no-overlap");
            }
        }
        s
    }
}

/// What a platform spec resolves to: a legacy [`Platform`] variant or a
/// declarative tier stack. The common operations (label, rank count,
/// canonical spec, sharding) are uniform across both.
#[derive(Debug, Clone, PartialEq)]
pub enum Target {
    Platform(Platform),
    Tiered(TieredTarget),
}

impl Target {
    pub fn label(&self) -> String {
        match self {
            Target::Platform(p) => p.label(),
            Target::Tiered(t) => t.label(),
        }
    }

    pub fn ranks(&self) -> u32 {
        match self {
            Target::Platform(p) => p.ranks(),
            Target::Tiered(t) => t.ranks,
        }
    }

    /// Canonical spec string (parseable by [`Config::parse_spec`]).
    pub fn spec(&self) -> String {
        match self {
            Target::Platform(p) => p.spec(),
            Target::Tiered(t) => t.spec(),
        }
    }

    /// The legacy platform, when this is one.
    pub fn platform(&self) -> Option<Platform> {
        match self {
            Target::Platform(p) => Some(*p),
            Target::Tiered(_) => None,
        }
    }

    /// The tiered target, when this is one.
    pub fn tiered(&self) -> Option<&TieredTarget> {
        match self {
            Target::Platform(_) => None,
            Target::Tiered(t) => Some(t),
        }
    }

    /// Attach `codec` to every link of the target's tier stack — the
    /// `codec` spec token and `--codec` flag funnel through here.
    /// Errors for legacy platform targets (their closed topologies take
    /// codecs via `tiers:` stacks, e.g. `tiers:gpu-explicit-pcie-zfp`)
    /// and for stacks that already carry a `~c:` tier annotation.
    pub fn with_codec(self, codec: CodecSpec) -> crate::Result<Target> {
        match self {
            Target::Platform(p) => crate::bail!(
                "platform {:?} takes no codec token — legacy platform targets take \
                 codecs via tiers: stacks (e.g. tiers:gpu-explicit-pcie-zfp)",
                p.label()
            ),
            Target::Tiered(mut t) => {
                t.topology = t.topology.with_codec_all(codec)?;
                Ok(Target::Tiered(t))
            }
        }
    }

    /// Shard across `ranks` with default sharding settings (mirrors
    /// [`Platform::sharded`]; tiered targets are always shardable).
    pub fn sharded(self, ranks: u32) -> crate::Result<Target> {
        match self {
            Target::Platform(p) => Ok(Target::Platform(p.sharded(ranks)?)),
            Target::Tiered(mut t) => {
                crate::ensure!(ranks <= 64, "rank count {ranks} out of range (1..=64)");
                t.ranks = ranks.max(1);
                Ok(Target::Tiered(t))
            }
        }
    }
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// The legacy platform view. When [`Config::tiered`] is set this is
    /// a placeholder — every consumer should go through
    /// [`Config::target`], [`Config::label`], [`Config::ranks`] and
    /// [`Config::topology`], which resolve the active side.
    pub platform: Platform,
    /// The declarative tier-stack target; overrides `platform` when
    /// set.
    pub tiered: Option<TieredTarget>,
    pub app: AppCalib,
    pub knl: KnlCalib,
    pub gpu: GpuCalib,
    pub um: UnifiedCalib,
    /// When set, wrap the engine in the cost-model auto-tuner
    /// ([`crate::tuner`]); `None` runs the seed heuristics.
    pub tune: Option<TuneOpts>,
    /// Temporal fusion depth for step replays
    /// ([`crate::program::Session::replay_fused`]): `1` = off (every
    /// step is its own chain), `k > 1` = fuse `k` steps per
    /// super-chain, `0` = ask the tuner ([`crate::tuner::tune_fuse`])
    /// to pick the depth per chain. Engines ignore this field — the
    /// step drivers (CLI/bench runners) consume it.
    pub fuse: u32,
    /// Which numeric executor [`crate::program::Session::new`] builds
    /// (the `--exec` CLI seam). Numerics are bit-identical across
    /// backends; only the loop-body machinery differs.
    pub exec: ExecBackend,
}

/// A `x<N>` ranks token (`x4` → 4).
fn parse_ranks_token(tok: &str) -> Option<u32> {
    tok.strip_prefix('x')
        .filter(|digits| !digits.is_empty())
        .and_then(|digits| digits.parse::<u32>().ok())
}

/// A compact `fuse<k>` fusion token (`fuse4` → 4, `fuse0` → tuner-auto).
fn parse_fuse_token(tok: &str) -> Option<u32> {
    tok.strip_prefix("fuse")
        .filter(|digits| !digits.is_empty())
        .and_then(|digits| digits.parse::<u32>().ok())
}

impl Config {
    pub fn new(platform: Platform, app: AppCalib) -> Self {
        Config {
            platform,
            tiered: None,
            app,
            knl: KnlCalib::default(),
            gpu: GpuCalib::default(),
            um: UnifiedCalib::default(),
            tune: None,
            fuse: 1,
            exec: ExecBackend::default(),
        }
    }

    /// Set the temporal fusion depth (see [`Config::fuse`]).
    pub fn with_fuse(mut self, k: u32) -> Self {
        self.fuse = k;
        self
    }

    /// Select the numeric executor backend (see [`Config::exec`]).
    pub fn with_exec(mut self, exec: ExecBackend) -> Self {
        self.exec = exec;
        self
    }

    /// Build a configuration for any parse target — the uniform
    /// constructor the CLI and spec-driven tests use.
    pub fn for_target(target: Target, app: AppCalib) -> Self {
        match target {
            Target::Platform(p) => Config::new(p, app),
            Target::Tiered(t) => {
                let mut cfg = Config::new(Platform::KnlFlatDdr4, app);
                cfg.tiered = Some(t);
                cfg
            }
        }
    }

    /// The active target (tiered when set, the legacy platform
    /// otherwise).
    pub fn target(&self) -> Target {
        match &self.tiered {
            Some(t) => Target::Tiered(t.clone()),
            None => Target::Platform(self.platform),
        }
    }

    /// Label of the active target.
    pub fn label(&self) -> String {
        self.target().label()
    }

    /// Rank count of the active target.
    pub fn ranks(&self) -> u32 {
        self.target().ranks()
    }

    /// The declarative topology of the active target: the tiered stack
    /// itself, or the preset the legacy platform maps to
    /// ([`Platform::topology`]) — what the `--json` record reports.
    pub fn topology(&self) -> Topology {
        match &self.tiered {
            Some(t) => t.topology.clone(),
            None => self.platform.topology(&self.knl, &self.gpu),
        }
    }

    /// The §5.3 graphics-clock boost: NVLink-attached P100s clock
    /// slightly higher, so any stack whose innermost link is the
    /// calibrated NVLink host link models it — keyed on the topology
    /// *data*, not the preset name, so a hand-spelled
    /// `host=inf@30~0.000008` stack behaves identically to
    /// `tiers:gpu-explicit-nvlink`.
    fn tiered_boost(&self, t: &TieredTarget) -> f64 {
        if nvlink_host_stack(&t.topology) {
            self.gpu.nvlink_clock_boost
        } else {
            1.0
        }
    }

    /// Enable the auto-tuner. Errors when the platform has no tile plan
    /// to search (flat modes, resident baselines, untiled cache mode).
    /// Tiered targets always have one.
    pub fn with_tuning(mut self, opts: TuneOpts) -> crate::Result<Self> {
        crate::ensure!(
            self.tuner_target().is_some(),
            "platform {:?} is not tunable (tile plans exist on knl-cache-tiled, \
             gpu-explicit, gpu-unified, tiers: stacks and their sharded forms)",
            self.label()
        );
        self.tune = Some(opts);
        Ok(self)
    }

    /// The tuner's view of this platform, when it is tunable.
    pub fn tuner_target(&self) -> Option<TunerTarget> {
        if let Some(t) = &self.tiered {
            if t.topology.num_tiers() < 2 {
                // A flat single tier has no tile plan to search — the
                // same rejection the legacy grammar gives gpu-baseline.
                return None;
            }
            let inner = TunerTarget::Tiered {
                topo: t.topology.clone(),
                compute_bw: self.app.gpu * self.tiered_boost(t),
                launch_s: self.gpu.launch_s,
                opts: t.opts,
            };
            return Some(if t.ranks > 1 {
                TunerTarget::Sharded {
                    inner: Box::new(inner),
                    ranks: t.ranks,
                    kind: t.decomp,
                    link: t.link,
                    overlap: t.overlap,
                }
            } else {
                inner
            });
        }
        self.platform_tuner_target()
    }

    /// The legacy-platform half of [`Config::tuner_target`].
    fn platform_tuner_target(&self) -> Option<TunerTarget> {
        fn inner_target(cfg: &Config, p: Platform) -> Option<TunerTarget> {
            match p {
                Platform::KnlCacheTiled => Some(TunerTarget::Knl {
                    calib: cfg.knl.clone(),
                    app: cfg.app,
                }),
                Platform::GpuExplicit {
                    link,
                    cyclic,
                    prefetch,
                } => Some(TunerTarget::GpuExplicit {
                    calib: cfg.gpu.clone(),
                    app: cfg.app,
                    link,
                    opts: GpuOpts {
                        cyclic,
                        prefetch,
                        slots: 3,
                    },
                }),
                Platform::GpuUnified {
                    link,
                    tiled,
                    prefetch,
                } => Some(TunerTarget::GpuUnified {
                    gpu: cfg.gpu.clone(),
                    um: cfg.um.clone(),
                    app: cfg.app,
                    link,
                    tiled,
                    prefetch,
                }),
                _ => None,
            }
        }
        match self.platform {
            Platform::Sharded {
                ranks,
                inner,
                link,
                decomp,
                overlap,
            } => Some(TunerTarget::Sharded {
                inner: Box::new(inner_target(self, inner.to_platform())?),
                ranks,
                kind: decomp,
                link,
                overlap,
            }),
            p => inner_target(self, p),
        }
    }

    /// Parse one single-device platform from `head` plus its option
    /// tokens, rejecting anything not in the head's vocabulary.
    fn parse_single(head: &str, toks: &[&str]) -> crate::Result<Platform> {
        let allowed: &[&str] = match head {
            "knl-flat-ddr4" | "knl-flat-mcdram" | "knl-cache" | "knl-cache-tiled" => &[],
            "gpu-baseline" => &["pcie", "nvlink"],
            "gpu-explicit" => &["pcie", "nvlink", "cyclic", "prefetch"],
            "gpu-unified" => &["pcie", "nvlink", "tiled", "prefetch"],
            other => crate::bail!(
                "unknown platform {other:?} (knl-flat-ddr4|knl-flat-mcdram|knl-cache|\
                 knl-cache-tiled|gpu-baseline|gpu-explicit|gpu-unified|tiers:<stack> — \
                 see --list-platforms)"
            ),
        };
        for t in toks {
            crate::ensure!(
                allowed.contains(t),
                "unknown token {t:?} for platform {head:?} (expected one of {allowed:?})"
            );
        }
        let link = if toks.contains(&"nvlink") {
            Link::NvLink
        } else {
            Link::PciE
        };
        let flag = |name: &str| toks.contains(&name);
        Ok(match head {
            "knl-flat-ddr4" => Platform::KnlFlatDdr4,
            "knl-flat-mcdram" => Platform::KnlFlatMcdram,
            "knl-cache" => Platform::KnlCache,
            "knl-cache-tiled" => Platform::KnlCacheTiled,
            "gpu-baseline" => Platform::GpuBaseline { link },
            "gpu-explicit" => Platform::GpuExplicit {
                link,
                cyclic: flag("cyclic"),
                prefetch: flag("prefetch"),
            },
            _ => Platform::GpuUnified {
                link,
                tiled: flag("tiled"),
                prefetch: flag("prefetch"),
            },
        })
    }

    /// Parse a compact platform spec string (see the module docs for the
    /// grammar): e.g. `knl-cache-tiled`, `gpu-explicit:nvlink:cyclic:prefetch`,
    /// `gpu-unified:pcie:tiled`, `gpu-explicit:nvlink:cyclic:x4:ib:2d`.
    pub fn parse_platform(spec: &str) -> crate::Result<Platform> {
        let mut parts = spec.split(':');
        let head = parts.next().unwrap_or("");
        let rest: Vec<&str> = parts.collect();

        let xpos = rest.iter().position(|t| parse_ranks_token(t).is_some());
        let (inner_toks, shard_toks) = match xpos {
            Some(i) => (&rest[..i], &rest[i + 1..]),
            None => (&rest[..], &rest[rest.len()..]),
        };
        let single = Self::parse_single(head, inner_toks)?;
        let Some(i) = xpos else { return Ok(single) };

        let ranks = parse_ranks_token(rest[i]).unwrap();
        crate::ensure!(
            (1..=64).contains(&ranks),
            "rank count {ranks} out of range (1..=64)"
        );
        // `x1` is accepted for rank-sweep convenience and means "no
        // sharding": with no shard tokens it works on any platform.
        if ranks == 1 && shard_toks.is_empty() {
            return Ok(single);
        }
        let mut platform = single.sharded(ranks)?;
        if ranks == 1 {
            // shard tokens after `x1` are still validated against a
            // throwaway sharded form (requires a shardable platform).
            platform = single.sharded(2)?;
        }
        if let Platform::Sharded {
            ref mut link,
            ref mut decomp,
            ref mut overlap,
            ..
        } = platform
        {
            for t in shard_toks {
                if let Some(ic) = Interconnect::parse(t) {
                    *link = ic;
                } else {
                    match *t {
                        "1d" => *decomp = DecompKind::OneD,
                        "2d" => *decomp = DecompKind::TwoD,
                        "no-overlap" => *overlap = false,
                        other => crate::bail!(
                            "unknown shard token {other:?} (expected peer|nvlink|ib|1d|2d|no-overlap)"
                        ),
                    }
                }
            }
        }
        if ranks == 1 {
            return Ok(single);
        }
        Ok(platform)
    }

    /// Parse any execution-target spec: the legacy platform heads
    /// ([`Config::parse_platform`]) or the declarative `tiers:` head —
    /// a preset name or tier stack ([`crate::topology::spec`]),
    /// followed by optional `cyclic`/`prefetch` toggles and the same
    /// `x<N>` sharding suffix the legacy grammar uses:
    /// `tiers:hbm=16g@509.7+host=inf@11:cyclic:x4:ib:2d`.
    pub fn parse_target(spec: &str) -> crate::Result<Target> {
        let Some(body) = spec.strip_prefix("tiers:") else {
            return Ok(Target::Platform(Self::parse_platform(spec)?));
        };
        let mut parts = body.split(':');
        let mut stack = parts.next().unwrap_or("").to_string();
        let mut toks: Vec<&str> = parts.collect();
        // A `~c:` codec annotation carries a ':' inside the stack token,
        // which the token split above cut off — stitch the value piece(s)
        // back on before handing the stack to the topology parser.
        while stack.ends_with("~c") && !toks.is_empty() {
            stack.push(':');
            stack.push_str(toks.remove(0));
        }
        let topo = topology::spec::parse_stack(&stack)?;
        let xpos = toks.iter().position(|t| parse_ranks_token(t).is_some());
        let (inner_toks, shard_toks) = match xpos {
            Some(i) => (&toks[..i], &toks[i + 1..]),
            None => (&toks[..], &toks[toks.len()..]),
        };
        let mut tt = TieredTarget::new(topo);
        for t in inner_toks {
            match *t {
                "cyclic" => tt.opts.cyclic = true,
                "prefetch" => tt.opts.prefetch = true,
                other => crate::bail!(
                    "unknown token {other:?} for tiers: platform (expected cyclic|prefetch|x<N>)"
                ),
            }
        }
        if let Some(i) = xpos {
            let ranks = parse_ranks_token(toks[i]).unwrap();
            crate::ensure!(
                (1..=64).contains(&ranks),
                "rank count {ranks} out of range (1..=64)"
            );
            // Stage the shard tokens, then apply only when actually
            // sharding: `x1` means "no sharding" — its tokens are
            // validated but discarded, exactly like the legacy grammar,
            // so `TieredTarget::spec()` round-trips.
            let (mut link, mut decomp, mut overlap) = (tt.link, tt.decomp, tt.overlap);
            for t in shard_toks {
                if let Some(ic) = Interconnect::parse(t) {
                    link = ic;
                } else {
                    match *t {
                        "1d" => decomp = DecompKind::OneD,
                        "2d" => decomp = DecompKind::TwoD,
                        "no-overlap" => overlap = false,
                        other => crate::bail!(
                            "unknown shard token {other:?} (expected peer|nvlink|ib|1d|2d|no-overlap)"
                        ),
                    }
                }
            }
            if ranks > 1 {
                tt.ranks = ranks;
                tt.link = link;
                tt.decomp = decomp;
                tt.overlap = overlap;
            }
        }
        Ok(Target::Tiered(tt))
    }

    /// Parse a target spec that may additionally carry the `tuned`
    /// token (position-independent): `gpu-explicit:nvlink:tuned`,
    /// `knl-cache-tiled:tuned:x4:ib`, `tiers:gpu-explicit-pcie:tuned`.
    /// Returns the target plus whether tuning was requested; `tuned` on
    /// a platform with no tile plan to search is rejected.
    /// [`Config::parse_platform`] itself keeps the strict grammar (it
    /// rejects `tuned` like any unknown token).
    pub fn parse_spec(spec: &str) -> crate::Result<(Target, bool)> {
        let (target, tuned, fuse, _codec) = Self::parse_spec_opts(spec)?;
        crate::ensure!(
            fuse == 1,
            "spec {spec:?} sets a temporal fusion depth, which this entry \
             point cannot carry — use Config::parse_spec_opts (CLI: --fuse)"
        );
        Ok((target, tuned))
    }

    /// Like [`Config::parse_spec`], but additionally recognising the
    /// temporal-fusion and codec tokens, in either spelling and at any
    /// position: `fuse:<k>` (a `fuse` token followed by a bare depth)
    /// or the compact `fuse<k>`, and `codec:<spec>` / `codec<spec>`
    /// with the codec-value grammar of [`CodecSpec::parse`] — e.g.
    /// `tiers:gpu-explicit-pcie:cyclic:fuse:4` or
    /// `tiers:gpu-explicit-pcie:codec3.5:x2`. Returns
    /// `(target, tuned, fuse, codec)` with `fuse = 1` when no token is
    /// present; `fuse0` (tuner-auto) requires a tunable target, like
    /// `tuned`. A `codec` token is **already applied** to the returned
    /// target (every link of its stack, via [`Target::with_codec`]) —
    /// the fourth element only reports it, so the CLI can detect
    /// conflicts with the `--codec` flag.
    pub fn parse_spec_opts(spec: &str) -> crate::Result<(Target, bool, u32, Option<CodecSpec>)> {
        let toks: Vec<&str> = spec.split(':').collect();
        let mut tuned = false;
        let mut fuse: Option<u32> = None;
        let mut codec: Option<CodecSpec> = None;
        let set_fuse = |k: u32, fuse: &mut Option<u32>| -> crate::Result<()> {
            crate::ensure!(
                fuse.replace(k).is_none(),
                "duplicate fuse token in spec {spec:?}"
            );
            Ok(())
        };
        let set_codec = |c: CodecSpec, codec: &mut Option<CodecSpec>| -> crate::Result<()> {
            crate::ensure!(
                codec.replace(c).is_none(),
                "duplicate codec token in spec {spec:?}"
            );
            Ok(())
        };
        let mut rest: Vec<&str> = Vec::with_capacity(toks.len());
        let mut i = 0usize;
        while i < toks.len() {
            let t = toks[i];
            if t == "tuned" {
                tuned = true;
            } else if t == "fuse" {
                // the `fuse:<k>` spelling: the depth rides in the next
                // token (never a valid bare token in any head grammar)
                let Some(k) = toks.get(i + 1).and_then(|d| d.parse::<u32>().ok()) else {
                    crate::bail!("fuse token needs a depth: fuse:<k> or fuse<k> in {spec:?}")
                };
                set_fuse(k, &mut fuse)?;
                i += 1;
            } else if let Some(k) = parse_fuse_token(t) {
                set_fuse(k, &mut fuse)?;
            } else if t == "codec" {
                // the `codec:<spec>` spelling, mirroring `fuse:<k>`
                let Some(v) = toks.get(i + 1) else {
                    crate::bail!(
                        "codec token needs a value: codec:<spec> or codec<spec> in {spec:?}"
                    )
                };
                let c = CodecSpec::parse(v)
                    .map_err(|e| crate::err!("codec token in {spec:?}: {e}"))?;
                set_codec(c, &mut codec)?;
                i += 1;
            } else if let Some(v) = t.strip_prefix("codec").filter(|v| !v.is_empty()) {
                let c = CodecSpec::parse(v)
                    .map_err(|e| crate::err!("codec token in {spec:?}: {e}"))?;
                set_codec(c, &mut codec)?;
            } else {
                rest.push(t);
            }
            i += 1;
        }
        let mut target = Self::parse_target(&rest.join(":"))?;
        if let Some(c) = codec {
            target = target.with_codec(c)?;
        }
        if tuned || fuse == Some(0) {
            // validate tunability with a throwaway default-calib config
            Config::for_target(target.clone(), AppCalib::CLOVERLEAF_2D)
                .with_tuning(TuneOpts::default())?;
        }
        Ok((target, tuned, fuse.unwrap_or(1), codec))
    }

    /// Instantiate the memory engine for this configuration. With
    /// [`Config::tune`] set (and a tunable platform) the engine is
    /// wrapped in the cost-model auto-tuner.
    pub fn build_engine(&self) -> Box<dyn Engine> {
        if let Some(opts) = self.tune {
            if let Some(target) = self.tuner_target() {
                return Box::new(TunedEngine::new(target, opts));
            }
            // `tune` is a pub field, so it can be set without going
            // through `with_tuning`'s validation; surface the misuse in
            // debug builds instead of silently running untuned.
            debug_assert!(
                false,
                "Config.tune set on non-tunable platform {:?}",
                self.label()
            );
        }
        if let Some(t) = &self.tiered {
            return self.build_tiered_engine(t);
        }
        match self.platform {
            Platform::KnlFlatDdr4 => {
                Box::new(PlainEngine::knl_flat_ddr4(self.app.knl_ddr4))
            }
            Platform::KnlFlatMcdram => Box::new(PlainEngine::knl_flat_mcdram(
                self.app.knl_mcdram,
                self.knl.mcdram_bytes,
            )),
            Platform::KnlCache => {
                Box::new(KnlEngine::new(self.knl.clone(), self.app, false))
            }
            Platform::KnlCacheTiled => {
                Box::new(KnlEngine::new(self.knl.clone(), self.app, true))
            }
            Platform::GpuBaseline { link } => {
                let boost = if link == Link::NvLink {
                    self.gpu.nvlink_clock_boost
                } else {
                    1.0
                };
                Box::new(PlainEngine::gpu_baseline(
                    self.app.gpu * boost,
                    self.gpu.hbm_bytes,
                    self.gpu.launch_s,
                ))
            }
            Platform::GpuExplicit {
                link,
                cyclic,
                prefetch,
            } => Box::new(
                GpuExplicitEngine::new(
                    self.gpu.clone(),
                    self.app,
                    link,
                    GpuOpts { cyclic, prefetch, slots: 3 },
                )
                .expect("slots: 3 is always valid"),
            ),
            Platform::GpuUnified {
                link,
                tiled,
                prefetch,
            } => Box::new(UnifiedEngine::new(
                self.gpu.clone(),
                self.um.clone(),
                self.app,
                link,
                tiled,
                prefetch,
            )),
            Platform::Sharded {
                ranks,
                inner,
                link,
                decomp,
                overlap,
            } => {
                let rank_cfg = Config {
                    platform: inner.to_platform(),
                    tiered: None,
                    app: self.app,
                    knl: self.knl.clone(),
                    gpu: self.gpu.clone(),
                    um: self.um.clone(),
                    tune: None,
                    fuse: 1,
                    exec: self.exec,
                };
                let engines = (0..ranks.max(1)).map(|_| rank_cfg.build_engine()).collect();
                Box::new(ShardedEngine::new(engines, decomp, link, overlap))
            }
        }
    }

    /// Instantiate the generic [`TieredEngine`] (per rank, when
    /// sharded) for a tiered target. Compute bandwidth is the app's
    /// calibrated GPU baseline — the tier stack describes *memory*, the
    /// app calibration describes the *device* doing the computing —
    /// with the NVLink presets' clock boost folded in.
    fn build_tiered_engine(&self, t: &TieredTarget) -> Box<dyn Engine> {
        let mk = || -> Box<dyn Engine> {
            Box::new(
                TieredEngine::new(
                    t.topology.clone(),
                    self.app.gpu * self.tiered_boost(t),
                    self.gpu.launch_s,
                    t.opts,
                )
                .expect("parse/TieredTarget::new produce valid GpuOpts"),
            )
        };
        if t.ranks > 1 {
            // Halo exchanges ride the slowest boundary link, so they
            // inherit that link's codec (the outermost one).
            let halo = t.topology.codec(t.topology.num_tiers().saturating_sub(2));
            let engines = (0..t.ranks).map(|_| mk()).collect();
            Box::new(
                ShardedEngine::new(engines, t.decomp, t.link, t.overlap).with_codec(halo),
            )
        } else {
            mk()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_platform_builds() {
        let platforms = [
            Platform::KnlFlatDdr4,
            Platform::KnlFlatMcdram,
            Platform::KnlCache,
            Platform::KnlCacheTiled,
            Platform::GpuBaseline { link: Link::PciE },
            Platform::GpuExplicit {
                link: Link::NvLink,
                cyclic: true,
                prefetch: true,
            },
            Platform::GpuUnified {
                link: Link::PciE,
                tiled: true,
                prefetch: false,
            },
            Platform::Sharded {
                ranks: 4,
                inner: InnerPlatform::GpuExplicit {
                    link: Link::NvLink,
                    cyclic: true,
                    prefetch: true,
                },
                link: Interconnect::NvLink,
                decomp: DecompKind::TwoD,
                overlap: true,
            },
        ];
        for p in platforms {
            let cfg = Config::new(p, AppCalib::CLOVERLEAF_2D);
            let e = cfg.build_engine();
            assert!(!e.describe().is_empty());
        }
    }

    #[test]
    fn platform_spec_strings_parse() {
        assert_eq!(
            Config::parse_platform("knl-cache-tiled").unwrap(),
            Platform::KnlCacheTiled
        );
        assert_eq!(
            Config::parse_platform("gpu-explicit:nvlink:cyclic:prefetch").unwrap(),
            Platform::GpuExplicit {
                link: Link::NvLink,
                cyclic: true,
                prefetch: true
            }
        );
        assert_eq!(
            Config::parse_platform("gpu-unified:pcie:tiled").unwrap(),
            Platform::GpuUnified {
                link: Link::PciE,
                tiled: true,
                prefetch: false
            }
        );
        // link token is position-independent and optional
        assert_eq!(
            Config::parse_platform("gpu-explicit:cyclic").unwrap(),
            Platform::GpuExplicit {
                link: Link::PciE,
                cyclic: true,
                prefetch: false
            }
        );
        assert!(Config::parse_platform("bogus").is_err());
    }

    #[test]
    fn sharded_specs_parse() {
        assert_eq!(
            Config::parse_platform("gpu-explicit:nvlink:cyclic:x4").unwrap(),
            Platform::Sharded {
                ranks: 4,
                inner: InnerPlatform::GpuExplicit {
                    link: Link::NvLink,
                    cyclic: true,
                    prefetch: false
                },
                link: Interconnect::NvLink,
                decomp: DecompKind::OneD,
                overlap: true,
            }
        );
        assert_eq!(
            Config::parse_platform("knl-cache-tiled:x8:ib:2d:no-overlap").unwrap(),
            Platform::Sharded {
                ranks: 8,
                inner: InnerPlatform::KnlCacheTiled,
                link: Interconnect::InfiniBand,
                decomp: DecompKind::TwoD,
                overlap: false,
            }
        );
        // x1 collapses to the single-device platform
        assert_eq!(
            Config::parse_platform("gpu-unified:pcie:x1").unwrap(),
            Platform::GpuUnified {
                link: Link::PciE,
                tiled: false,
                prefetch: false
            }
        );
        // …even for non-shardable platforms (rank-sweep convenience),
        assert_eq!(
            Config::parse_platform("gpu-baseline:x1").unwrap(),
            Platform::GpuBaseline { link: Link::PciE }
        );
        // but shard tokens after x1 still require a shardable platform
        assert_eq!(
            Config::parse_platform("gpu-unified:x1:ib").unwrap(),
            Platform::GpuUnified {
                link: Link::PciE,
                tiled: false,
                prefetch: false
            }
        );
        assert!(Config::parse_platform("gpu-baseline:x1:ib").is_err());
        // non-shardable platforms refuse xN
        assert!(Config::parse_platform("knl-flat-mcdram:x4").is_err());
        assert!(Config::parse_platform("gpu-baseline:x2").is_err());
    }

    #[test]
    fn unknown_tokens_are_rejected() {
        // the motivating bug: a typo'd link silently fell back to PCIe
        assert!(Config::parse_platform("gpu-explicit:nvlnk").is_err());
        assert!(Config::parse_platform("gpu-explicit:nvlink:cylic").is_err());
        assert!(Config::parse_platform("gpu-unified:cyclic").is_err());
        assert!(Config::parse_platform("knl-cache-tiled:prefetch").is_err());
        assert!(Config::parse_platform("gpu-explicit:x4:ethernet").is_err());
        assert!(Config::parse_platform("gpu-explicit:x0").is_err());
        assert!(Config::parse_platform("gpu-explicit:x999").is_err());
    }

    #[test]
    fn tuned_spec_token_parses_and_validates() {
        let (p, tuned) = Config::parse_spec("gpu-explicit:nvlink:cyclic:tuned").unwrap();
        assert!(tuned);
        assert_eq!(
            p.platform().unwrap(),
            Platform::GpuExplicit {
                link: Link::NvLink,
                cyclic: true,
                prefetch: false
            }
        );
        let (p2, t2) = Config::parse_spec("knl-cache-tiled").unwrap();
        assert!(!t2);
        assert_eq!(p2.platform().unwrap(), Platform::KnlCacheTiled);
        // the token composes with sharding, position-independently
        let (p3, t3) = Config::parse_spec("knl-cache-tiled:tuned:x4:ib").unwrap();
        assert!(t3);
        assert_eq!(p3.ranks(), 4);
        // platforms with no tile plan reject it
        assert!(Config::parse_spec("gpu-baseline:tuned").is_err());
        assert!(Config::parse_spec("knl-cache:tuned").is_err());
        // multi-tier stacks are tunable; a flat single tier is not
        let (t4, tuned4) = Config::parse_spec("tiers:gpu-explicit-pcie:tuned").unwrap();
        assert!(tuned4);
        assert!(t4.tiered().is_some());
        assert!(Config::parse_spec("tiers:plain:tuned").is_err());
        // the strict grammar itself still rejects it as unknown
        assert!(Config::parse_platform("gpu-explicit:tuned").is_err());
    }

    #[test]
    fn fuse_spec_tokens_parse_in_both_spellings() {
        // compact fuse<k>, position-independent
        let (t, tuned, fuse, _) = Config::parse_spec_opts("gpu-explicit:fuse4:nvlink").unwrap();
        assert!(!tuned);
        assert_eq!(fuse, 4);
        assert_eq!(
            t.platform().unwrap(),
            Platform::GpuExplicit {
                link: Link::NvLink,
                cyclic: false,
                prefetch: false
            }
        );
        // the fuse:<k> spelling, composing with tiers and sharding
        let (t, _, fuse, _) =
            Config::parse_spec_opts("tiers:gpu-explicit-pcie:cyclic:fuse:8:x2").unwrap();
        assert_eq!(fuse, 8);
        assert_eq!(t.ranks(), 2);
        assert!(t.tiered().unwrap().opts.cyclic);
        // absent token defaults to 1 (off)
        let (_, _, fuse, _) = Config::parse_spec_opts("knl-cache-tiled").unwrap();
        assert_eq!(fuse, 1);
        // fuse0 = tuner-auto: requires a tunable target, like `tuned`
        let (_, _, fuse, _) = Config::parse_spec_opts("gpu-explicit:fuse0").unwrap();
        assert_eq!(fuse, 0);
        assert!(Config::parse_spec_opts("gpu-baseline:fuse0").is_err());
        // malformed and duplicate tokens are rejected, not dropped
        assert!(Config::parse_spec_opts("gpu-explicit:fuse").is_err());
        assert!(Config::parse_spec_opts("gpu-explicit:fuse:x4").is_err());
        assert!(Config::parse_spec_opts("gpu-explicit:fuse2:fuse:3").is_err());
        // the fuse-unaware entry points cannot silently drop the depth
        assert!(Config::parse_spec("gpu-explicit:fuse4").is_err());
        assert!(Config::parse_platform("gpu-explicit:fuse4").is_err());
    }

    #[test]
    fn codec_spec_tokens_parse_and_apply() {
        // compact codec<spec> attaches the codec to every link
        let (t, _, _, c) = Config::parse_spec_opts("tiers:gpu-explicit-pcie:codec3.5").unwrap();
        assert_eq!(c, Some(CodecSpec::new(3.5)));
        assert_eq!(t.tiered().unwrap().topology.codec(0), Some(CodecSpec::new(3.5)));
        // the codec:<spec> spelling, position-independent and composing
        // with the other option tokens
        let (t, _, fuse, _) =
            Config::parse_spec_opts("tiers:gpu-explicit-pcie:cyclic:codec:2@12/40:fuse4")
                .unwrap();
        assert_eq!(fuse, 4);
        let cs = t.tiered().unwrap().topology.codec(0).unwrap();
        assert!((cs.ratio - 2.0).abs() < 1e-12);
        assert!((cs.compress_gbs - 12.0).abs() < 1e-12);
        // inline ~c: annotations survive the ':'-split of the tiers body
        // (they are tier grammar, not the codec token)
        let (t, _, _, c) =
            Config::parse_spec_opts("tiers:hbm=16g@509.7+host=512g@11~c:3.5").unwrap();
        assert!(c.is_none());
        assert_eq!(t.tiered().unwrap().topology.codec(0), Some(CodecSpec::new(3.5)));
        // …also per-link mid-spec, with trailing tokens, and the
        // canonical spec round-trips
        let (t, _, _, _) = Config::parse_spec_opts(
            "tiers:hbm=16g@509.7+host=48g@11~c:2.5@12/40+nvme=inf@6~c:1.5:cyclic:x2:ib",
        )
        .unwrap();
        let tt = t.tiered().unwrap();
        assert!(tt.opts.cyclic && tt.ranks == 2);
        assert!(tt.topology.codec(0).is_some() && tt.topology.codec(1).is_some());
        let (t2, _, _, _) = Config::parse_spec_opts(&t.spec()).unwrap();
        assert_eq!(t, t2, "{}", t.spec());
        // misuse is a typed error: legacy platforms take no codec token,
        // annotated stacks reject a second source, values must parse,
        // single-tier stacks have no links
        assert!(Config::parse_spec_opts("gpu-explicit:codec3.5").is_err());
        assert!(Config::parse_spec_opts("tiers:gpu-explicit-pcie-zfp:codec3.5").is_err());
        assert!(Config::parse_spec_opts("tiers:gpu-explicit-pcie:codec3.5:codec:2").is_err());
        assert!(Config::parse_spec_opts("tiers:gpu-explicit-pcie:codec").is_err());
        assert!(Config::parse_spec_opts("tiers:gpu-explicit-pcie:codec:bogus").is_err());
        assert!(Config::parse_spec_opts("tiers:plain:codec3.5").is_err());
    }

    #[test]
    fn sharded_tiered_engines_inherit_the_boundary_codec() {
        // the halo codec rides the outermost link's ~c: annotation
        let (t, _, _, _) = Config::parse_spec_opts(
            "tiers:hbm=16g@509.7+host=inf@11~c:3.5:x2",
        )
        .unwrap();
        let cfg = Config::for_target(t, AppCalib::CLOVERLEAF_2D);
        let d = cfg.build_engine().describe();
        assert!(d.contains("Sharded x2"), "{d}");
    }

    #[test]
    fn tiers_specs_parse_into_tiered_targets() {
        let (t, tuned) =
            Config::parse_spec("tiers:hbm=16g@509.7+host=48g@11~0.00001+nvme=inf@6~0.00002")
                .unwrap();
        assert!(!tuned);
        let tt = t.tiered().unwrap();
        assert_eq!(tt.topology.num_tiers(), 3);
        assert_eq!(tt.ranks, 1);
        assert!(!tt.opts.cyclic && !tt.opts.prefetch);

        // toggles + sharding compose like the legacy grammar
        let (t, _) =
            Config::parse_spec("tiers:gpu-explicit-nvlink:cyclic:prefetch:x4:ib:2d").unwrap();
        let tt = t.tiered().unwrap();
        assert!(tt.opts.cyclic && tt.opts.prefetch);
        assert_eq!(tt.ranks, 4);
        assert_eq!(tt.link, Interconnect::InfiniBand);
        assert_eq!(tt.decomp, DecompKind::TwoD);
        assert_eq!(t.ranks(), 4);
        assert!(t.label().contains("x4"), "{}", t.label());

        // x1 collapses to unsharded — shard tokens are validated but
        // discarded (so the canonical spec round-trips), like legacy x1
        let (t, _) = Config::parse_spec("tiers:gpu-explicit-pcie:x1").unwrap();
        assert_eq!(t.ranks(), 1);
        let (t, _) = Config::parse_spec("tiers:gpu-explicit-pcie:x1:ib").unwrap();
        assert_eq!(t.ranks(), 1);
        assert_eq!(t.tiered().unwrap().link, Interconnect::PciePeer);
        let (t2, _) = Config::parse_spec(&t.spec()).unwrap();
        assert_eq!(t, t2);
        assert!(Config::parse_spec("tiers:gpu-explicit-pcie:x1:ethernet").is_err());

        // unknown tokens are rejected at both positions
        assert!(Config::parse_spec("tiers:gpu-explicit-pcie:tiled").is_err());
        assert!(Config::parse_spec("tiers:gpu-explicit-pcie:x4:ethernet").is_err());
        // malformed stacks surface the topology parser's typed errors
        assert!(Config::parse_spec("tiers:hbm=0g@550+host=inf@11").is_err());
        assert!(Config::parse_spec("tiers:hbm=16g@550").is_err());
    }

    #[test]
    fn tiered_target_specs_round_trip() {
        for spec in [
            "tiers:gpu-explicit-pcie",
            "tiers:knl",
            "tiers:hbm=16g@509.7+host=48g@11~0.00001+nvme=inf@6~0.00002",
            "tiers:gpu-explicit-nvlink:cyclic:prefetch:x4:ib:2d:no-overlap",
            "tiers:hbm=16g@509.7+host=inf@11~0.00001:prefetch:x2:peer:1d",
        ] {
            let (t, _) = Config::parse_spec(spec).unwrap();
            let (t2, _) = Config::parse_spec(&t.spec()).unwrap();
            assert_eq!(t, t2, "{spec} → {}", t.spec());
        }
    }

    #[test]
    fn tiered_configs_build_tiered_engines() {
        let (t, _) = Config::parse_spec("tiers:gpu-explicit-pcie").unwrap();
        let cfg = Config::for_target(t, AppCalib::CLOVERLEAF_2D);
        assert!(cfg.build_engine().describe().starts_with("Tiered"), "{}", cfg.label());
        assert!(cfg.tuner_target().is_some(), "tiered stacks are tunable");

        // sharded tiered: per-rank inner topologies under the sharding layer
        let (t, _) = Config::parse_spec("tiers:gpu-explicit-pcie:x4:ib").unwrap();
        let cfg = Config::for_target(t, AppCalib::CLOVERLEAF_2D);
        let d = cfg.build_engine().describe();
        assert!(d.contains("Sharded x4") && d.contains("Tiered"), "{d}");
        assert_eq!(cfg.ranks(), 4);

        // a bounded home tier bounds the problem
        let (t, _) = Config::parse_spec("tiers:hbm=1m@500+nvme=1g@6~0.00002").unwrap();
        let cfg = Config::for_target(t, AppCalib::CLOVERLEAF_2D);
        let e = cfg.build_engine();
        assert!(e.fits(1 << 30));
        assert!(!e.fits((1 << 30) + 1));
    }

    #[test]
    fn platform_topology_maps_every_variant() {
        let knl = KnlCalib::default();
        let gpu = GpuCalib::default();
        let cases: [(Platform, usize, Option<&str>); 6] = [
            (Platform::KnlFlatDdr4, 1, None),
            (Platform::KnlFlatMcdram, 1, None),
            (Platform::KnlCacheTiled, 2, Some("knl")),
            (Platform::GpuBaseline { link: Link::PciE }, 1, None),
            (
                Platform::GpuExplicit {
                    link: Link::NvLink,
                    cyclic: true,
                    prefetch: true,
                },
                2,
                Some("gpu-explicit-nvlink"),
            ),
            (
                Platform::GpuUnified {
                    link: Link::PciE,
                    tiled: false,
                    prefetch: false,
                },
                2,
                Some("unified-pcie"),
            ),
        ];
        for (p, tiers, name) in cases {
            let topo = p.topology(&knl, &gpu);
            assert_eq!(topo.num_tiers(), tiers, "{}", p.label());
            assert_eq!(topo.name.as_deref(), name, "{}", p.label());
        }
        // custom calibrations flow through the mapping
        let small = GpuCalib {
            hbm_bytes: 1 << 20,
            ..GpuCalib::default()
        };
        let topo = Platform::GpuExplicit {
            link: Link::PciE,
            cyclic: false,
            prefetch: false,
        }
        .topology(&knl, &small);
        assert_eq!(topo.tier(0).capacity_bytes, Some(1 << 20));
        // sharded platforms map to their inner topology
        let p = Config::parse_platform("gpu-explicit:pcie:x4").unwrap();
        assert_eq!(p.topology(&knl, &gpu).name.as_deref(), Some("gpu-explicit-pcie"));
    }

    #[test]
    fn tuned_engine_wraps_tunable_platforms() {
        let cfg = Config::new(Platform::KnlCacheTiled, AppCalib::CLOVERLEAF_2D)
            .with_tuning(crate::tuner::TuneOpts::default())
            .unwrap();
        assert!(
            cfg.build_engine().describe().starts_with("auto-tuned"),
            "{}",
            cfg.build_engine().describe()
        );
        let bad = Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_2D)
            .with_tuning(crate::tuner::TuneOpts::default());
        assert!(bad.is_err());
        // sharded platforms tune through to their inner engines
        let p = Config::parse_platform("gpu-explicit:pcie:x4").unwrap();
        let cfg = Config::new(p, AppCalib::CLOVERLEAF_2D)
            .with_tuning(crate::tuner::TuneOpts::default())
            .unwrap();
        assert!(cfg.tuner_target().is_some());
        assert!(cfg.build_engine().describe().starts_with("auto-tuned"));
    }

    #[test]
    fn flat_mcdram_refuses_oversized() {
        let cfg = Config::new(Platform::KnlFlatMcdram, AppCalib::CLOVERLEAF_2D);
        let e = cfg.build_engine();
        assert!(!e.fits(17 * (1 << 30)));
        assert!(e.fits(15 * (1 << 30)));
    }

    #[test]
    fn sharded_fits_divides_by_ranks() {
        let p = Config::parse_platform("gpu-explicit:pcie:x4").unwrap();
        let cfg = Config::new(p, AppCalib::CLOVERLEAF_2D);
        let e = cfg.build_engine();
        // explicit streaming fits anything; the label mentions sharding
        assert!(e.fits(u64::MAX / 8));
        assert!(e.describe().contains("Sharded x4"));
    }

    #[test]
    fn sharded_method_enforces_rank_bound() {
        let p = Platform::GpuExplicit {
            link: Link::PciE,
            cyclic: true,
            prefetch: true,
        };
        assert!(p.sharded(64).is_ok());
        assert!(p.sharded(65).is_err(), "--ranks must honour the 1..=64 bound");
        assert_eq!(p.sharded(1).unwrap(), p, "ranks=1 is a no-op");
    }

    #[test]
    fn spec_round_trips_through_the_parser() {
        let cases = [
            Platform::KnlFlatMcdram,
            Platform::GpuBaseline { link: Link::NvLink },
            Platform::GpuExplicit {
                link: Link::PciE,
                cyclic: false,
                prefetch: true,
            },
            Platform::GpuUnified {
                link: Link::NvLink,
                tiled: true,
                prefetch: false,
            },
            Platform::Sharded {
                ranks: 8,
                inner: InnerPlatform::GpuUnified {
                    link: Link::PciE,
                    tiled: true,
                    prefetch: true,
                },
                link: Interconnect::PciePeer,
                decomp: DecompKind::TwoD,
                overlap: false,
            },
        ];
        for p in cases {
            assert_eq!(Config::parse_platform(&p.spec()).unwrap(), p, "{}", p.spec());
        }
    }

    #[test]
    fn ranks_helper_and_labels() {
        let p = Config::parse_platform("gpu-explicit:nvlink:x4:ib").unwrap();
        assert_eq!(p.ranks(), 4);
        assert_eq!(Platform::KnlCache.ranks(), 1);
        let l = p.label();
        assert!(l.contains("x4") && l.contains("IB"), "label: {l}");
    }
}
