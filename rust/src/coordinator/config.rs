//! Configuration: which modelled platform to run on, with which
//! calibrations. Loadable from compact spec strings for the CLI
//! launcher, constructible in code for benches and tests.
//!
//! ## Platform spec grammar
//!
//! ```text
//! spec        := head (":" token)*
//! head        := knl-flat-ddr4 | knl-flat-mcdram | knl-cache |
//!                knl-cache-tiled | gpu-baseline | gpu-explicit |
//!                gpu-unified
//! token       := pcie | nvlink            (host link, GPU heads)
//!              | cyclic | prefetch        (gpu-explicit)
//!              | tiled | prefetch         (gpu-unified)
//!              | x<N>                     (shard across N ranks)
//! shard token := peer | nvlink | ib       (interconnect, after x<N>)
//!              | 1d | 2d                  (decomposition, after x<N>)
//!              | no-overlap               (ablation, after x<N>)
//! ```
//!
//! Tokens before `x<N>` configure the inner (per-rank) platform, tokens
//! after it the sharding layer. Unknown tokens are **rejected** — e.g.
//! `gpu-explicit:nvlnk` is an error, not silently PCIe.

use crate::distributed::{DecompKind, Interconnect, ShardedEngine};
use crate::exec::Engine;
use crate::memory::{
    AppCalib, GpuCalib, GpuExplicitEngine, GpuOpts, KnlCalib, KnlEngine, Link, PlainEngine,
    UnifiedCalib, UnifiedEngine,
};
use crate::tuner::{TuneOpts, TunedEngine, TunerTarget};

/// Per-rank platforms a sharded configuration can host (each rank owns a
/// full out-of-core memory engine).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InnerPlatform {
    /// KNL cache mode with skewed tiling sized to MCDRAM.
    KnlCacheTiled,
    /// P100 with explicit 3-slot streaming (Algorithm 1).
    GpuExplicit {
        link: Link,
        cyclic: bool,
        prefetch: bool,
    },
    /// P100 with unified memory.
    GpuUnified {
        link: Link,
        tiled: bool,
        prefetch: bool,
    },
}

impl InnerPlatform {
    /// The equivalent single-device platform.
    pub fn to_platform(self) -> Platform {
        match self {
            InnerPlatform::KnlCacheTiled => Platform::KnlCacheTiled,
            InnerPlatform::GpuExplicit {
                link,
                cyclic,
                prefetch,
            } => Platform::GpuExplicit {
                link,
                cyclic,
                prefetch,
            },
            InnerPlatform::GpuUnified {
                link,
                tiled,
                prefetch,
            } => Platform::GpuUnified {
                link,
                tiled,
                prefetch,
            },
        }
    }

    /// The shardable view of a single-device platform (`None` for
    /// platforms that only exist unsharded, e.g. flat MCDRAM).
    pub fn try_from_platform(p: Platform) -> Option<Self> {
        match p {
            Platform::KnlCacheTiled => Some(InnerPlatform::KnlCacheTiled),
            Platform::GpuExplicit {
                link,
                cyclic,
                prefetch,
            } => Some(InnerPlatform::GpuExplicit {
                link,
                cyclic,
                prefetch,
            }),
            Platform::GpuUnified {
                link,
                tiled,
                prefetch,
            } => Some(InnerPlatform::GpuUnified {
                link,
                tiled,
                prefetch,
            }),
            _ => None,
        }
    }

    /// Host link of the inner platform, if it has one (used to pick a
    /// default inter-rank interconnect).
    fn host_link(self) -> Option<Link> {
        match self {
            InnerPlatform::KnlCacheTiled => None,
            InnerPlatform::GpuExplicit { link, .. } | InnerPlatform::GpuUnified { link, .. } => {
                Some(link)
            }
        }
    }
}

/// The execution environments of the paper's evaluation, plus the
/// sharded multi-device extension.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Platform {
    /// KNL flat mode, DDR4 only (numactl to DDR4).
    KnlFlatDdr4,
    /// KNL flat mode, MCDRAM only — refuses problems > 16 GB.
    KnlFlatMcdram,
    /// KNL cache mode, untiled.
    KnlCache,
    /// KNL cache mode with skewed tiling sized to MCDRAM.
    KnlCacheTiled,
    /// P100 with all data resident — refuses problems > 16 GB.
    GpuBaseline { link: Link },
    /// P100 with explicit 3-slot streaming (Algorithm 1).
    GpuExplicit {
        link: Link,
        cyclic: bool,
        prefetch: bool,
    },
    /// P100 with unified memory.
    GpuUnified {
        link: Link,
        tiled: bool,
        prefetch: bool,
    },
    /// N modelled ranks, each running `inner`, exchanging halos over
    /// `link` under a 1D/2D decomposition.
    Sharded {
        ranks: u32,
        inner: InnerPlatform,
        link: Interconnect,
        decomp: DecompKind,
        /// Overlap halo exchange with interior compute (`false` is the
        /// fig12 ablation).
        overlap: bool,
    },
}

impl Platform {
    pub fn label(&self) -> String {
        match self {
            Platform::KnlFlatDdr4 => "KNL flat DDR4".into(),
            Platform::KnlFlatMcdram => "KNL flat MCDRAM".into(),
            Platform::KnlCache => "KNL cache".into(),
            Platform::KnlCacheTiled => "KNL cache tiled".into(),
            Platform::GpuBaseline { link } => format!("GPU baseline {}", link.name()),
            Platform::GpuExplicit {
                link,
                cyclic,
                prefetch,
            } => format!(
                "GPU explicit {} {}{}",
                link.name(),
                if *cyclic { "Cyclic" } else { "NoCyclic" },
                if *prefetch { " Prefetch" } else { " NoPrefetch" }
            ),
            Platform::GpuUnified {
                link,
                tiled,
                prefetch,
            } => format!(
                "GPU unified {}{}{}",
                link.name(),
                if *tiled { " tiled" } else { "" },
                if *prefetch { " prefetch" } else { "" }
            ),
            Platform::Sharded {
                ranks,
                inner,
                link,
                decomp,
                overlap,
            } => format!(
                "{} x{} ({}, {}{})",
                inner.to_platform().label(),
                ranks,
                decomp.label(),
                link.name(),
                if *overlap { "" } else { ", no-overlap" }
            ),
        }
    }

    /// The canonical spec string of this platform: parseable by
    /// [`Config::parse_platform`], round-tripping to `self` for every
    /// constructible platform (sharded forms need `ranks >= 2`; `x1`
    /// collapses to the single-device platform by design). Property-
    /// tested in `tests/program_equivalence.rs`.
    pub fn spec(&self) -> String {
        fn link_tok(l: Link) -> &'static str {
            match l {
                Link::PciE => "pcie",
                Link::NvLink => "nvlink",
            }
        }
        match self {
            Platform::KnlFlatDdr4 => "knl-flat-ddr4".into(),
            Platform::KnlFlatMcdram => "knl-flat-mcdram".into(),
            Platform::KnlCache => "knl-cache".into(),
            Platform::KnlCacheTiled => "knl-cache-tiled".into(),
            Platform::GpuBaseline { link } => format!("gpu-baseline:{}", link_tok(*link)),
            Platform::GpuExplicit {
                link,
                cyclic,
                prefetch,
            } => format!(
                "gpu-explicit:{}{}{}",
                link_tok(*link),
                if *cyclic { ":cyclic" } else { "" },
                if *prefetch { ":prefetch" } else { "" }
            ),
            Platform::GpuUnified {
                link,
                tiled,
                prefetch,
            } => format!(
                "gpu-unified:{}{}{}",
                link_tok(*link),
                if *tiled { ":tiled" } else { "" },
                if *prefetch { ":prefetch" } else { "" }
            ),
            Platform::Sharded {
                ranks,
                inner,
                link,
                decomp,
                overlap,
            } => format!(
                "{}:x{}:{}:{}{}",
                inner.to_platform().spec(),
                ranks,
                match link {
                    Interconnect::PciePeer => "peer",
                    Interconnect::NvLink => "nvlink",
                    Interconnect::InfiniBand => "ib",
                },
                match decomp {
                    DecompKind::OneD => "1d",
                    DecompKind::TwoD => "2d",
                },
                if *overlap { "" } else { ":no-overlap" }
            ),
        }
    }

    /// Number of modelled ranks (1 for single-device platforms).
    pub fn ranks(&self) -> u32 {
        match self {
            Platform::Sharded { ranks, .. } => *ranks,
            _ => 1,
        }
    }

    /// Shard `self` across `ranks` ranks with default sharding settings
    /// (1D decomposition, overlap on, interconnect matched to the inner
    /// host link). Errors when the platform cannot be sharded.
    pub fn sharded(self, ranks: u32) -> crate::Result<Platform> {
        crate::ensure!(ranks <= 64, "rank count {ranks} out of range (1..=64)");
        if ranks <= 1 {
            return Ok(self);
        }
        if let Platform::Sharded { ranks: _, inner, link, decomp, overlap } = self {
            return Ok(Platform::Sharded { ranks, inner, link, decomp, overlap });
        }
        let inner = InnerPlatform::try_from_platform(self).ok_or_else(|| {
            crate::err!(
                "platform {:?} cannot be sharded (use knl-cache-tiled, gpu-explicit or gpu-unified)",
                self.label()
            )
        })?;
        let link = match inner.host_link() {
            Some(Link::NvLink) => Interconnect::NvLink,
            _ => Interconnect::PciePeer,
        };
        Ok(Platform::Sharded {
            ranks,
            inner,
            link,
            decomp: DecompKind::OneD,
            overlap: true,
        })
    }
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub platform: Platform,
    pub app: AppCalib,
    pub knl: KnlCalib,
    pub gpu: GpuCalib,
    pub um: UnifiedCalib,
    /// When set, wrap the engine in the cost-model auto-tuner
    /// ([`crate::tuner`]); `None` runs the seed heuristics.
    pub tune: Option<TuneOpts>,
}

/// A `x<N>` ranks token (`x4` → 4).
fn parse_ranks_token(tok: &str) -> Option<u32> {
    tok.strip_prefix('x')
        .filter(|digits| !digits.is_empty())
        .and_then(|digits| digits.parse::<u32>().ok())
}

impl Config {
    pub fn new(platform: Platform, app: AppCalib) -> Self {
        Config {
            platform,
            app,
            knl: KnlCalib::default(),
            gpu: GpuCalib::default(),
            um: UnifiedCalib::default(),
            tune: None,
        }
    }

    /// Enable the auto-tuner. Errors when the platform has no tile plan
    /// to search (flat modes, resident baselines, untiled cache mode).
    pub fn with_tuning(mut self, opts: TuneOpts) -> crate::Result<Self> {
        crate::ensure!(
            self.tuner_target().is_some(),
            "platform {:?} is not tunable (tile plans exist on knl-cache-tiled, \
             gpu-explicit, gpu-unified and their sharded forms)",
            self.platform.label()
        );
        self.tune = Some(opts);
        Ok(self)
    }

    /// The tuner's view of this platform, when it is tunable.
    pub fn tuner_target(&self) -> Option<TunerTarget> {
        fn inner_target(cfg: &Config, p: Platform) -> Option<TunerTarget> {
            match p {
                Platform::KnlCacheTiled => Some(TunerTarget::Knl {
                    calib: cfg.knl.clone(),
                    app: cfg.app,
                }),
                Platform::GpuExplicit {
                    link,
                    cyclic,
                    prefetch,
                } => Some(TunerTarget::GpuExplicit {
                    calib: cfg.gpu.clone(),
                    app: cfg.app,
                    link,
                    opts: GpuOpts {
                        cyclic,
                        prefetch,
                        slots: 3,
                    },
                }),
                Platform::GpuUnified {
                    link,
                    tiled,
                    prefetch,
                } => Some(TunerTarget::GpuUnified {
                    gpu: cfg.gpu.clone(),
                    um: cfg.um.clone(),
                    app: cfg.app,
                    link,
                    tiled,
                    prefetch,
                }),
                _ => None,
            }
        }
        match self.platform {
            Platform::Sharded {
                ranks,
                inner,
                link,
                decomp,
                overlap,
            } => Some(TunerTarget::Sharded {
                inner: Box::new(inner_target(self, inner.to_platform())?),
                ranks,
                kind: decomp,
                link,
                overlap,
            }),
            p => inner_target(self, p),
        }
    }

    /// Parse one single-device platform from `head` plus its option
    /// tokens, rejecting anything not in the head's vocabulary.
    fn parse_single(head: &str, toks: &[&str]) -> crate::Result<Platform> {
        let allowed: &[&str] = match head {
            "knl-flat-ddr4" | "knl-flat-mcdram" | "knl-cache" | "knl-cache-tiled" => &[],
            "gpu-baseline" => &["pcie", "nvlink"],
            "gpu-explicit" => &["pcie", "nvlink", "cyclic", "prefetch"],
            "gpu-unified" => &["pcie", "nvlink", "tiled", "prefetch"],
            other => crate::bail!(
                "unknown platform {other:?} (knl-flat-ddr4|knl-flat-mcdram|knl-cache|\
                 knl-cache-tiled|gpu-baseline|gpu-explicit|gpu-unified)"
            ),
        };
        for t in toks {
            crate::ensure!(
                allowed.contains(t),
                "unknown token {t:?} for platform {head:?} (expected one of {allowed:?})"
            );
        }
        let link = if toks.contains(&"nvlink") {
            Link::NvLink
        } else {
            Link::PciE
        };
        let flag = |name: &str| toks.contains(&name);
        Ok(match head {
            "knl-flat-ddr4" => Platform::KnlFlatDdr4,
            "knl-flat-mcdram" => Platform::KnlFlatMcdram,
            "knl-cache" => Platform::KnlCache,
            "knl-cache-tiled" => Platform::KnlCacheTiled,
            "gpu-baseline" => Platform::GpuBaseline { link },
            "gpu-explicit" => Platform::GpuExplicit {
                link,
                cyclic: flag("cyclic"),
                prefetch: flag("prefetch"),
            },
            _ => Platform::GpuUnified {
                link,
                tiled: flag("tiled"),
                prefetch: flag("prefetch"),
            },
        })
    }

    /// Parse a compact platform spec string (see the module docs for the
    /// grammar): e.g. `knl-cache-tiled`, `gpu-explicit:nvlink:cyclic:prefetch`,
    /// `gpu-unified:pcie:tiled`, `gpu-explicit:nvlink:cyclic:x4:ib:2d`.
    pub fn parse_platform(spec: &str) -> crate::Result<Platform> {
        let mut parts = spec.split(':');
        let head = parts.next().unwrap_or("");
        let rest: Vec<&str> = parts.collect();

        let xpos = rest.iter().position(|t| parse_ranks_token(t).is_some());
        let (inner_toks, shard_toks) = match xpos {
            Some(i) => (&rest[..i], &rest[i + 1..]),
            None => (&rest[..], &rest[rest.len()..]),
        };
        let single = Self::parse_single(head, inner_toks)?;
        let Some(i) = xpos else { return Ok(single) };

        let ranks = parse_ranks_token(rest[i]).unwrap();
        crate::ensure!(
            (1..=64).contains(&ranks),
            "rank count {ranks} out of range (1..=64)"
        );
        // `x1` is accepted for rank-sweep convenience and means "no
        // sharding": with no shard tokens it works on any platform.
        if ranks == 1 && shard_toks.is_empty() {
            return Ok(single);
        }
        let mut platform = single.sharded(ranks)?;
        if ranks == 1 {
            // shard tokens after `x1` are still validated against a
            // throwaway sharded form (requires a shardable platform).
            platform = single.sharded(2)?;
        }
        if let Platform::Sharded {
            ref mut link,
            ref mut decomp,
            ref mut overlap,
            ..
        } = platform
        {
            for t in shard_toks {
                if let Some(ic) = Interconnect::parse(t) {
                    *link = ic;
                } else {
                    match *t {
                        "1d" => *decomp = DecompKind::OneD,
                        "2d" => *decomp = DecompKind::TwoD,
                        "no-overlap" => *overlap = false,
                        other => crate::bail!(
                            "unknown shard token {other:?} (expected peer|nvlink|ib|1d|2d|no-overlap)"
                        ),
                    }
                }
            }
        }
        if ranks == 1 {
            return Ok(single);
        }
        Ok(platform)
    }

    /// Parse a platform spec that may additionally carry the `tuned`
    /// token (position-independent): `gpu-explicit:nvlink:tuned`,
    /// `knl-cache-tiled:tuned:x4:ib`. Returns the platform plus whether
    /// tuning was requested; `tuned` on a platform with no tile plan to
    /// search is rejected. [`Config::parse_platform`] itself keeps the
    /// strict grammar (it rejects `tuned` like any unknown token).
    pub fn parse_spec(spec: &str) -> crate::Result<(Platform, bool)> {
        let mut tuned = false;
        let rest: Vec<&str> = spec
            .split(':')
            .filter(|t| {
                if *t == "tuned" {
                    tuned = true;
                    false
                } else {
                    true
                }
            })
            .collect();
        let platform = Self::parse_platform(&rest.join(":"))?;
        if tuned {
            // validate tunability with a throwaway default-calib config
            Config::new(platform, AppCalib::CLOVERLEAF_2D).with_tuning(TuneOpts::default())?;
        }
        Ok((platform, tuned))
    }

    /// Instantiate the memory engine for this configuration. With
    /// [`Config::tune`] set (and a tunable platform) the engine is
    /// wrapped in the cost-model auto-tuner.
    pub fn build_engine(&self) -> Box<dyn Engine> {
        if let Some(opts) = self.tune {
            if let Some(target) = self.tuner_target() {
                return Box::new(TunedEngine::new(target, opts));
            }
            // `tune` is a pub field, so it can be set without going
            // through `with_tuning`'s validation; surface the misuse in
            // debug builds instead of silently running untuned.
            debug_assert!(
                false,
                "Config.tune set on non-tunable platform {:?}",
                self.platform.label()
            );
        }
        match self.platform {
            Platform::KnlFlatDdr4 => {
                Box::new(PlainEngine::knl_flat_ddr4(self.app.knl_ddr4))
            }
            Platform::KnlFlatMcdram => Box::new(PlainEngine::knl_flat_mcdram(
                self.app.knl_mcdram,
                self.knl.mcdram_bytes,
            )),
            Platform::KnlCache => {
                Box::new(KnlEngine::new(self.knl.clone(), self.app, false))
            }
            Platform::KnlCacheTiled => {
                Box::new(KnlEngine::new(self.knl.clone(), self.app, true))
            }
            Platform::GpuBaseline { link } => {
                let boost = if link == Link::NvLink {
                    self.gpu.nvlink_clock_boost
                } else {
                    1.0
                };
                Box::new(PlainEngine::gpu_baseline(
                    self.app.gpu * boost,
                    self.gpu.hbm_bytes,
                    self.gpu.launch_s,
                ))
            }
            Platform::GpuExplicit {
                link,
                cyclic,
                prefetch,
            } => Box::new(
                GpuExplicitEngine::new(
                    self.gpu.clone(),
                    self.app,
                    link,
                    GpuOpts { cyclic, prefetch, slots: 3 },
                )
                .expect("slots: 3 is always valid"),
            ),
            Platform::GpuUnified {
                link,
                tiled,
                prefetch,
            } => Box::new(UnifiedEngine::new(
                self.gpu.clone(),
                self.um.clone(),
                self.app,
                link,
                tiled,
                prefetch,
            )),
            Platform::Sharded {
                ranks,
                inner,
                link,
                decomp,
                overlap,
            } => {
                let rank_cfg = Config {
                    platform: inner.to_platform(),
                    app: self.app,
                    knl: self.knl.clone(),
                    gpu: self.gpu.clone(),
                    um: self.um.clone(),
                    tune: None,
                };
                let engines = (0..ranks.max(1)).map(|_| rank_cfg.build_engine()).collect();
                Box::new(ShardedEngine::new(engines, decomp, link, overlap))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_platform_builds() {
        let platforms = [
            Platform::KnlFlatDdr4,
            Platform::KnlFlatMcdram,
            Platform::KnlCache,
            Platform::KnlCacheTiled,
            Platform::GpuBaseline { link: Link::PciE },
            Platform::GpuExplicit {
                link: Link::NvLink,
                cyclic: true,
                prefetch: true,
            },
            Platform::GpuUnified {
                link: Link::PciE,
                tiled: true,
                prefetch: false,
            },
            Platform::Sharded {
                ranks: 4,
                inner: InnerPlatform::GpuExplicit {
                    link: Link::NvLink,
                    cyclic: true,
                    prefetch: true,
                },
                link: Interconnect::NvLink,
                decomp: DecompKind::TwoD,
                overlap: true,
            },
        ];
        for p in platforms {
            let cfg = Config::new(p, AppCalib::CLOVERLEAF_2D);
            let e = cfg.build_engine();
            assert!(!e.describe().is_empty());
        }
    }

    #[test]
    fn platform_spec_strings_parse() {
        assert_eq!(
            Config::parse_platform("knl-cache-tiled").unwrap(),
            Platform::KnlCacheTiled
        );
        assert_eq!(
            Config::parse_platform("gpu-explicit:nvlink:cyclic:prefetch").unwrap(),
            Platform::GpuExplicit {
                link: Link::NvLink,
                cyclic: true,
                prefetch: true
            }
        );
        assert_eq!(
            Config::parse_platform("gpu-unified:pcie:tiled").unwrap(),
            Platform::GpuUnified {
                link: Link::PciE,
                tiled: true,
                prefetch: false
            }
        );
        // link token is position-independent and optional
        assert_eq!(
            Config::parse_platform("gpu-explicit:cyclic").unwrap(),
            Platform::GpuExplicit {
                link: Link::PciE,
                cyclic: true,
                prefetch: false
            }
        );
        assert!(Config::parse_platform("bogus").is_err());
    }

    #[test]
    fn sharded_specs_parse() {
        assert_eq!(
            Config::parse_platform("gpu-explicit:nvlink:cyclic:x4").unwrap(),
            Platform::Sharded {
                ranks: 4,
                inner: InnerPlatform::GpuExplicit {
                    link: Link::NvLink,
                    cyclic: true,
                    prefetch: false
                },
                link: Interconnect::NvLink,
                decomp: DecompKind::OneD,
                overlap: true,
            }
        );
        assert_eq!(
            Config::parse_platform("knl-cache-tiled:x8:ib:2d:no-overlap").unwrap(),
            Platform::Sharded {
                ranks: 8,
                inner: InnerPlatform::KnlCacheTiled,
                link: Interconnect::InfiniBand,
                decomp: DecompKind::TwoD,
                overlap: false,
            }
        );
        // x1 collapses to the single-device platform
        assert_eq!(
            Config::parse_platform("gpu-unified:pcie:x1").unwrap(),
            Platform::GpuUnified {
                link: Link::PciE,
                tiled: false,
                prefetch: false
            }
        );
        // …even for non-shardable platforms (rank-sweep convenience),
        assert_eq!(
            Config::parse_platform("gpu-baseline:x1").unwrap(),
            Platform::GpuBaseline { link: Link::PciE }
        );
        // but shard tokens after x1 still require a shardable platform
        assert_eq!(
            Config::parse_platform("gpu-unified:x1:ib").unwrap(),
            Platform::GpuUnified {
                link: Link::PciE,
                tiled: false,
                prefetch: false
            }
        );
        assert!(Config::parse_platform("gpu-baseline:x1:ib").is_err());
        // non-shardable platforms refuse xN
        assert!(Config::parse_platform("knl-flat-mcdram:x4").is_err());
        assert!(Config::parse_platform("gpu-baseline:x2").is_err());
    }

    #[test]
    fn unknown_tokens_are_rejected() {
        // the motivating bug: a typo'd link silently fell back to PCIe
        assert!(Config::parse_platform("gpu-explicit:nvlnk").is_err());
        assert!(Config::parse_platform("gpu-explicit:nvlink:cylic").is_err());
        assert!(Config::parse_platform("gpu-unified:cyclic").is_err());
        assert!(Config::parse_platform("knl-cache-tiled:prefetch").is_err());
        assert!(Config::parse_platform("gpu-explicit:x4:ethernet").is_err());
        assert!(Config::parse_platform("gpu-explicit:x0").is_err());
        assert!(Config::parse_platform("gpu-explicit:x999").is_err());
    }

    #[test]
    fn tuned_spec_token_parses_and_validates() {
        let (p, tuned) = Config::parse_spec("gpu-explicit:nvlink:cyclic:tuned").unwrap();
        assert!(tuned);
        assert_eq!(
            p,
            Platform::GpuExplicit {
                link: Link::NvLink,
                cyclic: true,
                prefetch: false
            }
        );
        let (p2, t2) = Config::parse_spec("knl-cache-tiled").unwrap();
        assert!(!t2);
        assert_eq!(p2, Platform::KnlCacheTiled);
        // the token composes with sharding, position-independently
        let (p3, t3) = Config::parse_spec("knl-cache-tiled:tuned:x4:ib").unwrap();
        assert!(t3);
        assert_eq!(p3.ranks(), 4);
        // platforms with no tile plan reject it
        assert!(Config::parse_spec("gpu-baseline:tuned").is_err());
        assert!(Config::parse_spec("knl-cache:tuned").is_err());
        // the strict grammar itself still rejects it as unknown
        assert!(Config::parse_platform("gpu-explicit:tuned").is_err());
    }

    #[test]
    fn tuned_engine_wraps_tunable_platforms() {
        let cfg = Config::new(Platform::KnlCacheTiled, AppCalib::CLOVERLEAF_2D)
            .with_tuning(crate::tuner::TuneOpts::default())
            .unwrap();
        assert!(
            cfg.build_engine().describe().starts_with("auto-tuned"),
            "{}",
            cfg.build_engine().describe()
        );
        let bad = Config::new(Platform::KnlFlatDdr4, AppCalib::CLOVERLEAF_2D)
            .with_tuning(crate::tuner::TuneOpts::default());
        assert!(bad.is_err());
        // sharded platforms tune through to their inner engines
        let p = Config::parse_platform("gpu-explicit:pcie:x4").unwrap();
        let cfg = Config::new(p, AppCalib::CLOVERLEAF_2D)
            .with_tuning(crate::tuner::TuneOpts::default())
            .unwrap();
        assert!(cfg.tuner_target().is_some());
        assert!(cfg.build_engine().describe().starts_with("auto-tuned"));
    }

    #[test]
    fn flat_mcdram_refuses_oversized() {
        let cfg = Config::new(Platform::KnlFlatMcdram, AppCalib::CLOVERLEAF_2D);
        let e = cfg.build_engine();
        assert!(!e.fits(17 * (1 << 30)));
        assert!(e.fits(15 * (1 << 30)));
    }

    #[test]
    fn sharded_fits_divides_by_ranks() {
        let p = Config::parse_platform("gpu-explicit:pcie:x4").unwrap();
        let cfg = Config::new(p, AppCalib::CLOVERLEAF_2D);
        let e = cfg.build_engine();
        // explicit streaming fits anything; the label mentions sharding
        assert!(e.fits(u64::MAX / 8));
        assert!(e.describe().contains("Sharded x4"));
    }

    #[test]
    fn sharded_method_enforces_rank_bound() {
        let p = Platform::GpuExplicit {
            link: Link::PciE,
            cyclic: true,
            prefetch: true,
        };
        assert!(p.sharded(64).is_ok());
        assert!(p.sharded(65).is_err(), "--ranks must honour the 1..=64 bound");
        assert_eq!(p.sharded(1).unwrap(), p, "ranks=1 is a no-op");
    }

    #[test]
    fn spec_round_trips_through_the_parser() {
        let cases = [
            Platform::KnlFlatMcdram,
            Platform::GpuBaseline { link: Link::NvLink },
            Platform::GpuExplicit {
                link: Link::PciE,
                cyclic: false,
                prefetch: true,
            },
            Platform::GpuUnified {
                link: Link::NvLink,
                tiled: true,
                prefetch: false,
            },
            Platform::Sharded {
                ranks: 8,
                inner: InnerPlatform::GpuUnified {
                    link: Link::PciE,
                    tiled: true,
                    prefetch: true,
                },
                link: Interconnect::PciePeer,
                decomp: DecompKind::TwoD,
                overlap: false,
            },
        ];
        for p in cases {
            assert_eq!(Config::parse_platform(&p.spec()).unwrap(), p, "{}", p.spec());
        }
    }

    #[test]
    fn ranks_helper_and_labels() {
        let p = Config::parse_platform("gpu-explicit:nvlink:x4:ib").unwrap();
        assert_eq!(p.ranks(), 4);
        assert_eq!(Platform::KnlCache.ranks(), 1);
        let l = p.label();
        assert!(l.contains("x4") && l.contains("IB"), "label: {l}");
    }
}
