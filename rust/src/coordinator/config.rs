//! Configuration: which modelled platform to run on, with which
//! calibrations. Loadable from TOML for the launcher, constructible in
//! code for benches and tests.

use crate::exec::Engine;
use crate::memory::{
    AppCalib, GpuCalib, GpuExplicitEngine, GpuOpts, KnlCalib, KnlEngine, Link, PlainEngine,
    UnifiedCalib, UnifiedEngine,
};

/// The execution environments of the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Platform {
    /// KNL flat mode, DDR4 only (numactl to DDR4).
    KnlFlatDdr4,
    /// KNL flat mode, MCDRAM only — refuses problems > 16 GB.
    KnlFlatMcdram,
    /// KNL cache mode, untiled.
    KnlCache,
    /// KNL cache mode with skewed tiling sized to MCDRAM.
    KnlCacheTiled,
    /// P100 with all data resident — refuses problems > 16 GB.
    GpuBaseline { link: Link },
    /// P100 with explicit 3-slot streaming (Algorithm 1).
    GpuExplicit {
        link: Link,
        cyclic: bool,
        prefetch: bool,
    },
    /// P100 with unified memory.
    GpuUnified {
        link: Link,
        tiled: bool,
        prefetch: bool,
    },
}

impl Platform {
    pub fn label(&self) -> String {
        match self {
            Platform::KnlFlatDdr4 => "KNL flat DDR4".into(),
            Platform::KnlFlatMcdram => "KNL flat MCDRAM".into(),
            Platform::KnlCache => "KNL cache".into(),
            Platform::KnlCacheTiled => "KNL cache tiled".into(),
            Platform::GpuBaseline { link } => format!("GPU baseline {}", link.name()),
            Platform::GpuExplicit {
                link,
                cyclic,
                prefetch,
            } => format!(
                "GPU explicit {} {}{}",
                link.name(),
                if *cyclic { "Cyclic" } else { "NoCyclic" },
                if *prefetch { " Prefetch" } else { " NoPrefetch" }
            ),
            Platform::GpuUnified {
                link,
                tiled,
                prefetch,
            } => format!(
                "GPU unified {}{}{}",
                link.name(),
                if *tiled { " tiled" } else { "" },
                if *prefetch { " prefetch" } else { "" }
            ),
        }
    }
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub platform: Platform,
    pub app: AppCalib,
    pub knl: KnlCalib,
    pub gpu: GpuCalib,
    pub um: UnifiedCalib,
}

impl Config {
    pub fn new(platform: Platform, app: AppCalib) -> Self {
        Config {
            platform,
            app,
            knl: KnlCalib::default(),
            gpu: GpuCalib::default(),
            um: UnifiedCalib::default(),
        }
    }

    /// Parse a compact platform spec string (used by the CLI launcher and
    /// config files): e.g. `knl-cache-tiled`, `gpu-explicit:nvlink:cyclic:prefetch`,
    /// `gpu-unified:pcie:tiled`, `gpu-baseline:pcie`.
    pub fn parse_platform(spec: &str) -> anyhow::Result<Platform> {
        let mut parts = spec.split(':');
        let head = parts.next().unwrap_or("");
        let rest: Vec<&str> = parts.collect();
        let link = || -> anyhow::Result<Link> {
            match rest.first().copied() {
                Some("pcie") | None => Ok(Link::PciE),
                Some("nvlink") => Ok(Link::NvLink),
                Some(x) => anyhow::bail!("unknown link {x:?} (pcie|nvlink)"),
            }
        };
        let flag = |name: &str| rest.iter().any(|p| *p == name);
        Ok(match head {
            "knl-flat-ddr4" => Platform::KnlFlatDdr4,
            "knl-flat-mcdram" => Platform::KnlFlatMcdram,
            "knl-cache" => Platform::KnlCache,
            "knl-cache-tiled" => Platform::KnlCacheTiled,
            "gpu-baseline" => Platform::GpuBaseline { link: link()? },
            "gpu-explicit" => Platform::GpuExplicit {
                link: link()?,
                cyclic: flag("cyclic"),
                prefetch: flag("prefetch"),
            },
            "gpu-unified" => Platform::GpuUnified {
                link: link()?,
                tiled: flag("tiled"),
                prefetch: flag("prefetch"),
            },
            other => anyhow::bail!("unknown platform {other:?}"),
        })
    }

    /// Instantiate the memory engine for this configuration.
    pub fn build_engine(&self) -> Box<dyn Engine> {
        match self.platform {
            Platform::KnlFlatDdr4 => {
                Box::new(PlainEngine::knl_flat_ddr4(self.app.knl_ddr4))
            }
            Platform::KnlFlatMcdram => Box::new(PlainEngine::knl_flat_mcdram(
                self.app.knl_mcdram,
                self.knl.mcdram_bytes,
            )),
            Platform::KnlCache => {
                Box::new(KnlEngine::new(self.knl.clone(), self.app, false))
            }
            Platform::KnlCacheTiled => {
                Box::new(KnlEngine::new(self.knl.clone(), self.app, true))
            }
            Platform::GpuBaseline { link } => {
                let boost = if link == Link::NvLink {
                    self.gpu.nvlink_clock_boost
                } else {
                    1.0
                };
                Box::new(PlainEngine::gpu_baseline(
                    self.app.gpu * boost,
                    self.gpu.hbm_bytes,
                    self.gpu.launch_s,
                ))
            }
            Platform::GpuExplicit {
                link,
                cyclic,
                prefetch,
            } => Box::new(GpuExplicitEngine::new(
                self.gpu.clone(),
                self.app,
                link,
                GpuOpts { cyclic, prefetch, slots: 3 },
            )),
            Platform::GpuUnified {
                link,
                tiled,
                prefetch,
            } => Box::new(UnifiedEngine::new(
                self.gpu.clone(),
                self.um.clone(),
                self.app,
                link,
                tiled,
                prefetch,
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_platform_builds() {
        let platforms = [
            Platform::KnlFlatDdr4,
            Platform::KnlFlatMcdram,
            Platform::KnlCache,
            Platform::KnlCacheTiled,
            Platform::GpuBaseline { link: Link::PciE },
            Platform::GpuExplicit {
                link: Link::NvLink,
                cyclic: true,
                prefetch: true,
            },
            Platform::GpuUnified {
                link: Link::PciE,
                tiled: true,
                prefetch: false,
            },
        ];
        for p in platforms {
            let cfg = Config::new(p, AppCalib::CLOVERLEAF_2D);
            let e = cfg.build_engine();
            assert!(!e.describe().is_empty());
        }
    }

    #[test]
    fn platform_spec_strings_parse() {
        assert_eq!(
            Config::parse_platform("knl-cache-tiled").unwrap(),
            Platform::KnlCacheTiled
        );
        assert_eq!(
            Config::parse_platform("gpu-explicit:nvlink:cyclic:prefetch").unwrap(),
            Platform::GpuExplicit {
                link: Link::NvLink,
                cyclic: true,
                prefetch: true
            }
        );
        assert_eq!(
            Config::parse_platform("gpu-unified:pcie:tiled").unwrap(),
            Platform::GpuUnified {
                link: Link::PciE,
                tiled: true,
                prefetch: false
            }
        );
        assert!(Config::parse_platform("bogus").is_err());
    }

    #[test]
    fn flat_mcdram_refuses_oversized() {
        let cfg = Config::new(Platform::KnlFlatMcdram, AppCalib::CLOVERLEAF_2D);
        let e = cfg.build_engine();
        assert!(!e.fits(17 * (1 << 30)));
        assert!(e.fits(15 * (1 << 30)));
    }
}
